"""Generic MR training loop (CPU-scale; the distributed LM loop lives in
repro/train/loop.py).

Handles: jit'd update step, sparsity-mask annealing (`sparsify_after`),
NaN guards (restore last good params — the single-process analogue of the
fault-tolerant restart), and loss history.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

from repro.train.optimizer import Optimizer, adamw, apply_updates

__all__ = ["FitResult", "fit"]


@dataclass
class FitResult:
    params: Any
    history: list = field(default_factory=list)
    nan_restarts: int = 0


def fit(model, params, batches: Iterator, *, steps: int,
        optimizer: Optimizer | None = None, lr: float = 3e-3,
        sparsify_after: float = 0.5, log_every: int = 0,
        post_step: Callable | None = None) -> FitResult:
    """Fit an MR model (Merinda / Emily / PinnSR — anything with .loss).

    sparsify_after: fraction of `steps` after which the top-|Theta| mask is
    enabled (the paper's pruning phase).
    """
    opt = optimizer or adamw(lr=lr)
    opt_state = opt.init(params)

    @partial(jax.jit, static_argnames=("sparsify",))
    def update(params, opt_state, batch, sparsify: bool):
        (loss, aux), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch, sparsify)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, loss, aux

    history = []
    nan_restarts = 0
    last_good = params
    sparsify_step = int(steps * sparsify_after)
    for step, batch in enumerate(batches):
        if step >= steps:
            break
        sparsify = step >= sparsify_step
        params, opt_state, loss, aux = update(params, opt_state, batch, sparsify)
        lv = float(loss)
        if not jnp.isfinite(loss):
            # NaN guard: single-process restart-from-last-good.
            params = last_good
            opt_state = opt.init(params)
            nan_restarts += 1
            continue
        last_good = params
        history.append(lv)
        if log_every and step % log_every == 0:
            extras = {k: float(v) for k, v in aux.items()}
            print(f"  step {step:5d}  loss {lv:.6f}  " +
                  " ".join(f"{k}={v:.5f}" for k, v in extras.items()))
        if post_step is not None:
            params = post_step(step, params)
    return FitResult(params=params, history=history, nan_restarts=nan_restarts)
