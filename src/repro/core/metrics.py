"""Shared MR evaluation metrics (Table I)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.library import PolyLibrary
from repro.kernels.rk4.ops import rk4_poly_solve

__all__ = ["reconstruction_mse", "coefficient_error"]


def reconstruction_mse(lib: PolyLibrary, theta, y_win, u_win, dt: float
                       ) -> float:
    """Paper Table-I metric: re-integrate the recovered sparse model from
    each window's initial condition and MSE against the measured window.
    Identical protocol for MERINDA / EMILY / PINN+SR.

    A mis-recovered polynomial model can DIVERGE under integration (cubic
    terms); diverged trajectories are clamped to 10x the data envelope so a
    bad model scores a large-but-finite MSE instead of NaN."""
    B = y_win.shape[0]
    theta = jnp.asarray(theta)
    theta_b = jnp.broadcast_to(theta[None], (B,) + theta.shape)
    y_est = rk4_poly_solve(theta_b, y_win[:, 0, :], u_win, dt=dt,
                           library=lib)
    bound = 10.0 * jnp.max(jnp.abs(y_win))
    y_est = jnp.clip(jnp.nan_to_num(y_est, nan=bound, posinf=bound,
                                    neginf=-bound), -bound, bound)
    return float(jnp.mean(jnp.square(y_est - y_win)))


def coefficient_error(theta, theta_true) -> float:
    """Relative L2 error on the stacked coefficient matrix."""
    num = jnp.linalg.norm(jnp.asarray(theta) - jnp.asarray(theta_true))
    den = jnp.linalg.norm(jnp.asarray(theta_true)) + 1e-12
    return float(num / den)
