"""EMILY baseline — NODE-layer-based model recovery (the paper's comparator).

EMILY (Banerjee, Kaiser & Gupta, PMLR 2024) extracts sparse models from
implicit dynamics via an autoencoder whose latent dynamics are a Neural ODE:
the forward pass of every NODE cell integrates a learned rhs
h_phi(z, u) with an ODE solver (paper Eq. 3) — the block MERINDA replaces.

Pipeline here:
  1. Fit a neural ODE  dY/dt = MLP_phi(Y, U)  by integrating windows with RK4
     and minimizing trajectory MSE (the NODE forward pass — deliberately the
     expensive architecture: 4 MLP evaluations per RK4 step per timestep,
     inside the training graph).
  2. Extract the sparse model: evaluate the learned rhs on the data manifold
     and STLSQ-regress it onto the polynomial library -> Theta.

Reconstruction MSE is then measured exactly as for MERINDA (re-integrate the
recovered sparse model).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.library import make_library
from repro.core.odeint import rk4_step
from repro.core.sparse_regression import stlsq

__all__ = ["EmilyConfig", "Emily"]


@dataclass(frozen=True)
class EmilyConfig:
    n: int
    m: int
    order: int = 2
    hidden: int = 64            # width of the NODE rhs MLP
    depth: int = 2
    dt: float = 0.01
    stlsq_threshold: float = 0.05

    @property
    def library(self):
        return make_library(self.n, self.m, self.order)


class Emily:
    def __init__(self, cfg: EmilyConfig):
        self.cfg = cfg
        self.lib = cfg.library

    def init(self, key):
        cfg = self.cfg
        dims = [cfg.n + cfg.m] + [cfg.hidden] * cfg.depth + [cfg.n]
        keys = jax.random.split(key, len(dims) - 1)
        layers = []
        for k, (a, b) in zip(keys, zip(dims[:-1], dims[1:])):
            s = 1.0 / jnp.sqrt(a)
            layers.append({
                "w": jax.random.uniform(k, (a, b), minval=-s, maxval=s),
                "b": jnp.zeros((b,)),
            })
        # zero-init the output layer: integration starts on the data manifold.
        layers[-1]["w"] = jnp.zeros_like(layers[-1]["w"])
        return {"mlp": layers}

    # ------------------------------------------------------------------ #
    def rhs(self, params, y, u):
        x = jnp.concatenate([y, u], axis=-1) if self.cfg.m else y
        for layer in params["mlp"][:-1]:
            x = jnp.tanh(x @ layer["w"] + layer["b"])
        out = x @ params["mlp"][-1]["w"] + params["mlp"][-1]["b"]
        return out

    # ------------------------------------------------------------------ #
    def node_forward(self, params, y0, u_win):
        """The NODE cell forward pass: RK4 integration of the learned rhs."""
        def f(y, u):
            return self.rhs(params, y, u)

        def step(y, u):
            y = rk4_step(f, y, u, self.cfg.dt)
            return y, y

        _, ys = jax.lax.scan(step, y0, jnp.swapaxes(u_win, 0, 1))
        return jnp.concatenate([y0[:, None], jnp.swapaxes(ys, 0, 1)], axis=1)

    # ------------------------------------------------------------------ #
    def loss(self, params, batch, sparsify_enable=False):
        del sparsify_enable  # sparsity happens post-hoc via STLSQ
        y_win, u_win = batch
        y_est = self.node_forward(params, y_win[:, 0, :], u_win)
        mse = jnp.mean(jnp.square(y_est - y_win))
        return mse, {"ode_loss": mse}

    # ------------------------------------------------------------------ #
    def recover(self, params, y_win, u_win):
        """STLSQ of the learned NODE rhs onto the polynomial library."""
        y = y_win[:, :-1, :].reshape(-1, self.cfg.n)
        u = u_win.reshape(y.shape[0], self.cfg.m)
        dy = self.rhs(params, y, u)
        phi = self.lib.eval(y, u if self.cfg.m else None)
        return stlsq(phi, dy, threshold=self.cfg.stlsq_threshold)
