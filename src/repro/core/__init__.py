# The paper's primary contribution: MERINDA model recovery (GRU neural-flow
# replacement of NODE layers) plus the EMILY / PINN+SR baselines it is
# evaluated against, and the fleet-twinning production layer.
from repro.core.emily import Emily, EmilyConfig
from repro.core.fleet import FleetConfig, FleetMerinda
from repro.core.library import PolyLibrary, make_library, n_library_terms
from repro.core.merinda import Merinda, MerindaConfig
from repro.core.pinn_sr import PinnSR, PinnSRConfig
from repro.core.sparse_regression import masked_ridge, stlsq
from repro.core.trainer import FitResult, fit

__all__ = [
    "Emily", "EmilyConfig", "FleetConfig", "FleetMerinda", "PolyLibrary",
    "make_library", "n_library_terms", "Merinda", "MerindaConfig", "PinnSR",
    "PinnSRConfig", "masked_ridge", "stlsq", "FitResult", "fit",
]
