"""Polynomial feature library for sparse model recovery.

The recovered model has the form  dY/dt = Theta @ Phi(Y, U)  where Phi is a
library of monomials of total degree <= `order` over the augmented variable
vector  X~ = [1, Y_1..Y_n, U_1..U_m].

Each library term is stored as `order` indices into X~ (index 0 is the
constant 1), so evaluation is a gather + product — the exact formulation the
fused RK4 Pallas kernel consumes (see kernels/rk4).
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

__all__ = ["PolyLibrary", "make_library", "n_library_terms"]


def n_library_terms(n_vars: int, order: int) -> int:
    """Number of monomials of total degree <= order in n_vars variables.

    Equals C(order + n_vars, n_vars) — the count quoted in the paper as
    C(M + n, n).
    """
    return math.comb(order + n_vars, n_vars)


@dataclass(frozen=True, eq=False)
class PolyLibrary:
    """A fixed polynomial library Phi over states Y (n dims) and inputs U (m dims).

    Hash/eq are defined by (n, m, order) — the enumeration is deterministic —
    so a PolyLibrary can be passed as a static jit argument.
    """

    n: int                      # state dimension |Y|
    m: int                      # input dimension |U|
    order: int                  # max total degree M
    term_indices: np.ndarray    # [L, order] int32 indices into [1, Y, U]
    names: tuple[str, ...] = field(default=())

    def __hash__(self):
        return hash((self.n, self.m, self.order))

    def __eq__(self, other):
        return (isinstance(other, PolyLibrary)
                and (self.n, self.m, self.order) == (other.n, other.m, other.order))

    @property
    def size(self) -> int:
        return int(self.term_indices.shape[0])

    # ------------------------------------------------------------------ #
    def eval(self, y, u=None):
        """Evaluate Phi(Y, U) -> [..., L].

        y: [..., n], u: [..., m] or None (when m == 0).
        """
        parts = [jnp.ones_like(y[..., :1]), y]
        if self.m:
            if u is None:
                raise ValueError(f"library has m={self.m} inputs but u is None")
            parts.append(u)
        aug = jnp.concatenate(parts, axis=-1)                  # [..., 1+n+m]
        idx = jnp.asarray(self.term_indices)                   # [L, order]
        gathered = aug[..., idx]                               # [..., L, order]
        return jnp.prod(gathered, axis=-1)                     # [..., L]

    # ------------------------------------------------------------------ #
    def term_name(self, j: int) -> str:
        return self.names[j]

    def coeff_dict(self, theta, state_names=None, atol: float = 1e-8):
        """Render Theta [n, L] as {state: {term: coeff}} for interpretability."""
        theta = np.asarray(theta)
        state_names = state_names or [f"d{self._vname(i + 1)}/dt" for i in range(self.n)]
        out = {}
        for i in range(self.n):
            row = {
                self.names[j]: float(theta[i, j])
                for j in range(self.size)
                if abs(theta[i, j]) > atol
            }
            out[state_names[i]] = row
        return out

    def _vname(self, k: int) -> str:
        if k == 0:
            return "1"
        if k <= self.n:
            return f"y{k - 1}"
        return f"u{k - 1 - self.n}"

    # ------------------------------------------------------------------ #
    def theta_from_terms(self, rows: list[dict[str, float]]) -> np.ndarray:
        """Build a dense Theta [n, L] from per-state {term_name: coeff} dicts."""
        if len(rows) != self.n:
            raise ValueError(f"expected {self.n} rows, got {len(rows)}")
        name_to_j = {nm: j for j, nm in enumerate(self.names)}
        theta = np.zeros((self.n, self.size), dtype=np.float64)
        for i, row in enumerate(rows):
            for nm, c in row.items():
                key = _canonical_name(nm)
                if key not in name_to_j:
                    raise KeyError(f"term {nm!r} (canonical {key!r}) not in library "
                                   f"(n={self.n}, m={self.m}, order={self.order})")
                theta[i, name_to_j[key]] = c
        return theta


def _canonical_name(name: str) -> str:
    """Canonicalize 'y1*y0' -> 'y0*y1', '1' stays '1'."""
    if name in ("1", ""):
        return "1"
    return "*".join(sorted(name.split("*")))


def make_library(n: int, m: int = 0, order: int = 2) -> PolyLibrary:
    """Enumerate all monomials of total degree <= order over [Y(n), U(m)].

    Term j is the product of `order` entries of [1, Y, U]; lower-degree terms
    pad with index 0 (the constant 1).  L = C(order + n + m, n + m).
    """
    n_vars = n + m
    terms: list[tuple[int, ...]] = []
    names: list[str] = []
    # combinations_with_replacement over variable indices 0..n_vars-1 for each
    # degree d, padded with the constant slot.
    for d in range(order + 1):
        for combo in itertools.combinations_with_replacement(range(1, n_vars + 1), d):
            padded = combo + (0,) * (order - d)
            terms.append(padded)
            if d == 0:
                names.append("1")
            else:
                def vname(k: int) -> str:
                    return f"y{k - 1}" if k <= n else f"u{k - 1 - n}"
                names.append("*".join(sorted(vname(k) for k in combo)))
    term_indices = np.asarray(terms, dtype=np.int32)
    lib = PolyLibrary(n=n, m=m, order=order, term_indices=term_indices,
                      names=tuple(names))
    assert lib.size == n_library_terms(n_vars, order)
    return lib
