"""Fixed-step ODE integrators in jax.lax, used by every MR pipeline stage.

Three entry points:
  * rk4_step / euler_step     — single-step updates
  * integrate                 — scan a step fn over a precomputed input sequence
  * poly_ode_integrate        — integrate dY = Theta @ Phi(Y, U) (the MERINDA
                                decoder `SOLVE(Y(0), Theta, U)` block; the
                                fused Pallas kernel in kernels/rk4 implements
                                the same contract)

All integrators use zero-order-hold inputs: u[t] is held constant across the
step from t to t+1 (matching how the sampled input traces are generated).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["euler_step", "rk4_step", "integrate", "poly_ode_integrate"]


def euler_step(f: Callable, y, u, dt):
    return y + dt * f(y, u)


def rk4_step(f: Callable, y, u, dt):
    """Classic RK4 with zero-order-hold input."""
    k1 = f(y, u)
    k2 = f(y + 0.5 * dt * k1, u)
    k3 = f(y + 0.5 * dt * k2, u)
    k4 = f(y + dt * k3, u)
    return y + (dt / 6.0) * (k1 + 2.0 * k2 + 2.0 * k3 + k4)


_STEPPERS = {"rk4": rk4_step, "euler": euler_step}


def integrate(f: Callable, y0, us, dt, method: str = "rk4",
              substeps: int = 1):
    """Integrate dy/dt = f(y, u) over a sampled input sequence.

    Args:
      f: rhs, f(y [..., n], u [..., m]) -> [..., n].
      y0: [..., n] initial state.
      us: [T, ..., m] input samples (u[t] held over step t -> t+1).
      dt: sample interval.
      substeps: integrator substeps per sample interval (>=1) for accuracy.

    Returns:
      ys: [T+1, ..., n] including y0 at index 0.
    """
    step = _STEPPERS[method]
    h = dt / substeps

    def body(y, u):
        def sub(y, _):
            return step(f, y, u, h), None
        y, _ = jax.lax.scan(sub, y, None, length=substeps)
        return y, y

    yT, ys = jax.lax.scan(body, y0, us)
    del yT
    return jnp.concatenate([y0[None], ys], axis=0)


@partial(jax.jit, static_argnames=("library", "method", "substeps"))
def poly_ode_integrate(theta, y0, us, dt, *, library, method: str = "rk4",
                       substeps: int = 1):
    """Integrate the recovered polynomial model dY = Theta @ Phi(Y, U).

    theta: [..., n, L] per-instance coefficients (batched model recovery),
    y0: [..., n], us: [T, ..., m] (pass shape [T, ..., 0] when m == 0).
    Returns ys [T+1, ..., n].

    This is the reference semantics for kernels/rk4; see kernels/rk4/ref.py.
    """
    def rhs(y, u):
        phi = library.eval(y, u if library.m else None)        # [..., L]
        return jnp.einsum("...nl,...l->...n", theta, phi)

    return integrate(rhs, y0, us, dt, method=method, substeps=substeps)
