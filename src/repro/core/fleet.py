"""Fleet digital twinning: many independent MERINDA instances on one mesh.

The paper's deployment scenario is mission-critical online twinning (mid-air
collision avoidance): every tracked aircraft gets its own continuously-refit
digital twin.  At production scale that is thousands of CONCURRENT model
recoveries — an embarrassingly parallel, latency-critical workload.

`FleetMerinda` vmaps a Merinda instance over a fleet axis (separate params,
separate data per twin) and exposes:
  * fleet_init / fleet_step  — one fused training step for every twin
    (the latency-critical fused step; examples/fleet_twinning.py),
  * recover_all              — batched model extraction,
  * reset_slot               — re-initialize ONE fleet slot in place (the
    online-serving admission path: twin/scheduler.py admits a newly-tracked
    object into a refit slot without touching the other twins).

Online serving (twin/server.py) treats the fleet axis as a bounded pool of
REFIT SLOTS: twins are admitted/evicted dynamically, so per-slot training
progress must be tracked per slot — `state["steps"]` carries one step counter
per slot and the sparsify warmup (`FleetConfig.sparsify_after`) is applied
slot-wise, not globally.

Sharding: the fleet axis is sharded over ('pod','data') and the GRU/head
matmuls over 'model' via the rules in distributed/sharding.py, so one
train_step advances every twin on the pod simultaneously.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.merinda import Merinda, MerindaConfig
from repro.distributed.sharding import shard
from repro.train.optimizer import adamw, apply_updates, clip_by_global_norm

__all__ = ["FleetConfig", "FleetMerinda"]


@dataclass(frozen=True)
class FleetConfig:
    merinda: MerindaConfig
    fleet: int                  # number of concurrent twins (refit slots)
    windows_per_twin: int = 32  # S_B per twin per step
    lr: float = 3e-3
    sparsify_after: int = 200   # per-slot warmup steps before the hard top-k mask
    grad_clip: float = 1.0      # per-twin gradient clip


class FleetMerinda:
    def __init__(self, cfg: FleetConfig):
        self.cfg = cfg
        self.model = Merinda(cfg.merinda)
        # clipping happens PER TWIN inside _twin_grad: a global clip would
        # couple twins through the norm, and a single twin's non-finite
        # gradient would poison every slot in the fleet.
        self.opt = adamw(lr=cfg.lr, clip_norm=None)

    # ------------------------------------------------------------------ #
    def init(self, key):
        keys = jax.random.split(key, self.cfg.fleet)
        params = jax.vmap(self.model.init)(keys)
        opt_state = self.opt.init(params)   # leaves carry the fleet axis
        return {"params": params, "opt": opt_state,
                "step": jnp.zeros((), jnp.int32),
                "steps": jnp.zeros((self.cfg.fleet,), jnp.int32)}

    # ------------------------------------------------------------------ #
    def _twin_grad(self, params, y_win, u_win, sparsify):
        (loss, aux), grads = jax.value_and_grad(self.model.loss, has_aux=True)(
            params, (y_win, u_win), sparsify)
        grads, _ = clip_by_global_norm(grads, self.cfg.grad_clip)
        # Live telemetry can hand a twin a window its current theta integrates
        # to overflow; skip that twin's step (zero grads) instead of letting
        # NaNs reach its params — the slot stays recoverable.
        ok = jnp.isfinite(loss)
        for g in jax.tree.leaves(grads):
            ok = ok & jnp.all(jnp.isfinite(g))
        grads = jax.tree.map(
            lambda g: jnp.where(ok, g, jnp.zeros_like(g)), grads)
        return jnp.where(ok, loss, 0.0), ok, grads

    @partial(jax.jit, static_argnames=("self",))
    def train_step_per_slot(self, state, y_win, u_win):
        """One fused step for every twin, with per-slot diagnostics.

        y_win: [F, S_B, k+1, n], u_win: [F, S_B, k, m] — per-twin windows.
        The sparsify warmup is evaluated PER SLOT: twins admitted into a slot
        mid-stream (steps reset by `reset_slot`) train dense until their own
        counter passes `sparsify_after`, independent of their neighbours.
        Returns (state, loss [F], ok [F]) — per-slot losses (0 where the
        step was skipped as non-finite) so the serving layer can report
        losses for assigned slots without an extra forward pass.
        """
        # logical twin_* shardings (distributed/sharding.py): the fleet axis
        # is data-parallel over ('pod','data'); no-op outside axis_rules
        y_win = shard(y_win, "twin_windows")
        u_win = shard(u_win, "twin_windows")
        sparsify = state["steps"] > self.cfg.sparsify_after      # [F] bool
        loss, ok, grads = jax.vmap(self._twin_grad)(
            state["params"], y_win, u_win, sparsify)
        updates, opt = self.opt.update(grads, state["opt"], state["params"])
        params = apply_updates(state["params"], updates)
        return ({"params": params, "opt": opt, "step": state["step"] + 1,
                 "steps": state["steps"] + 1},
                shard(loss, "twin_fleet"), ok)

    def train_step(self, state, y_win, u_win):
        """One fused step for every twin; returns the mean loss over twins
        whose step was finite (thin host-side wrapper, same compiled core)."""
        state, loss, ok = self.train_step_per_slot(state, y_win, u_win)
        return state, jnp.sum(loss) / jnp.maximum(jnp.sum(ok), 1)

    # ------------------------------------------------------------------ #
    @partial(jax.jit, static_argnames=("self",))
    def reset_slot(self, state, slot, key, y_win=None, u_win=None):
        """Re-initialize fleet slot `slot` in place (admission of a new twin).

        slot may be a traced int32 scalar, so one compiled trace serves every
        slot.  When the admitted twin's windows are provided, the slot's norm
        stats (mu/sigma/phi_scale) are computed from them — the same
        conditioning `Merinda.init` gets in the offline path.  Optimizer
        moments for the slot are zeroed; the shared Adam bias-correction step
        is left global (a warm counter only slightly damps a fresh slot's
        first updates).
        """
        norm = None
        if y_win is not None:
            norm = self.model.norm_stats(y_win, u_win)
        fresh = self.model.init(key, norm)
        params = jax.tree.map(
            lambda a, f: a.at[slot].set(f.astype(a.dtype)),
            state["params"], fresh)
        opt = state["opt"]
        opt = opt._replace(
            mu=jax.tree.map(lambda a: a.at[slot].set(0.0), opt.mu),
            nu=jax.tree.map(lambda a: a.at[slot].set(0.0), opt.nu))
        return {"params": params, "opt": opt, "step": state["step"],
                "steps": state["steps"].at[slot].set(0)}

    # ------------------------------------------------------------------ #
    @partial(jax.jit, static_argnames=("self",))
    def recover_all(self, state, y_win, u_win):
        """Batched model extraction (no polish — pure in-network path, the
        latency-critical deployment call)."""
        def one(p, y, u):
            theta_dense, _ = self.model.encode(p, y, u)
            pooled = jnp.median(theta_dense, axis=0, keepdims=True)
            return self.model.sparsify(pooled, True,
                                       p["norm"]["phi_scale"])[0]
        return jax.vmap(one)(state["params"], y_win, u_win)
