"""Fleet digital twinning: many independent MERINDA instances on one mesh.

The paper's deployment scenario is mission-critical online twinning (mid-air
collision avoidance): every tracked aircraft gets its own continuously-refit
digital twin.  At production scale that is thousands of CONCURRENT model
recoveries — an embarrassingly parallel, latency-critical workload.

`FleetMerinda` vmaps a Merinda instance over a fleet axis (separate params,
separate data per twin) and exposes:
  * fleet_init / fleet_step  — one fused training step for every twin
    (the latency-critical fused step; examples/fleet_twinning.py),
  * recover_all              — batched model extraction.

Sharding: the fleet axis is sharded over ('pod','data') and the GRU/head
matmuls over 'model' via the rules in distributed/sharding.py, so one
train_step advances every twin on the pod simultaneously.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.merinda import Merinda, MerindaConfig
from repro.train.optimizer import adamw, apply_updates

__all__ = ["FleetConfig", "FleetMerinda"]


@dataclass(frozen=True)
class FleetConfig:
    merinda: MerindaConfig
    fleet: int                  # number of concurrent twins
    windows_per_twin: int = 32  # S_B per twin per step
    lr: float = 3e-3


class FleetMerinda:
    def __init__(self, cfg: FleetConfig):
        self.cfg = cfg
        self.model = Merinda(cfg.merinda)
        self.opt = adamw(lr=cfg.lr)

    # ------------------------------------------------------------------ #
    def init(self, key):
        keys = jax.random.split(key, self.cfg.fleet)
        params = jax.vmap(self.model.init)(keys)
        opt_state = self.opt.init(params)   # leaves carry the fleet axis
        return {"params": params, "opt": opt_state,
                "step": jnp.zeros((), jnp.int32)}

    # ------------------------------------------------------------------ #
    def _twin_grad(self, params, y_win, u_win, sparsify):
        (loss, aux), grads = jax.value_and_grad(self.model.loss, has_aux=True)(
            params, (y_win, u_win), sparsify)
        return loss, grads

    @partial(jax.jit, static_argnames=("self",))
    def train_step(self, state, y_win, u_win):
        """One fused step for every twin.

        y_win: [F, S_B, k+1, n], u_win: [F, S_B, k, m] — per-twin windows.
        """
        sparsify = state["step"] > 200
        loss, grads = jax.vmap(
            lambda p, y, u: self._twin_grad(p, y, u, sparsify)
        )(state["params"], y_win, u_win)
        updates, opt = self.opt.update(grads, state["opt"], state["params"])
        params = apply_updates(state["params"], updates)
        return ({"params": params, "opt": opt, "step": state["step"] + 1},
                jnp.mean(loss))

    # ------------------------------------------------------------------ #
    @partial(jax.jit, static_argnames=("self",))
    def recover_all(self, state, y_win, u_win):
        """Batched model extraction (no polish — pure in-network path, the
        latency-critical deployment call)."""
        def one(p, y, u):
            theta_dense, _ = self.model.encode(p, y, u)
            pooled = jnp.median(theta_dense, axis=0, keepdims=True)
            return self.model.sparsify(pooled, True,
                                       p["norm"]["phi_scale"])[0]
        return jax.vmap(one)(state["params"], y_win, u_win)
