"""MERINDA: Model REcovery IN Dynamic Architectures (the paper's contribution).

Architecture (paper Fig. 2):
  windows of (Y, U)  ->  GRU-NN (V hidden units; the neural-flow replacement
  of the NODE layer)  ->  pruned dense head (ReLU MLP mapping the V hidden
  states to C(M+n, n) library coefficients, sparsified so only |Theta| outputs
  stay active, plus q input-shift values)  ->  RK4 ODE solver
  SOLVE(Y(0), Theta_est, U)  ->  Y_est;  ODE loss = MSE(Y, Y_est).

Design notes:
  * The dense head's final layer is zero-initialized so Theta_est starts at 0
    and the RK4 integration starts on the data manifold (stable early
    training — standard flow/NODE practice).
  * Sparsification is magnitude top-|Theta| with a straight-through mask,
    enabled after a warmup ("the dropout rate of |Theta|" in the paper);
    an L1 penalty on the dense coefficients drives the survivors.
  * Both hot blocks run through the kernel wrappers (kernels/gru, kernels/rk4)
    with `use_pallas` selecting the TPU kernels or the jnp reference.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp

from repro.core.library import PolyLibrary, make_library
from repro.kernels.gru.ops import gru_scan
from repro.kernels.gru.ref import init_gru_params
from repro.kernels.rk4.ops import rk4_poly_solve

__all__ = ["MerindaConfig", "Merinda"]


@dataclass(frozen=True)
class MerindaConfig:
    n: int                      # state dim |Y|
    m: int                      # input dim
    order: int = 2              # library order M
    hidden: int = 64            # GRU width V (paper's "V nodes")
    head_hidden: int = 64       # dense-head hidden width
    n_active: int = 8           # |Theta|: surviving coefficients after pruning
    dt: float = 0.01
    l1: float = 1e-3            # sparsity penalty on dense coefficients
    theta_scale: float = 1.0    # output scale of the head (match coeff range)
    collocation_weight: float = 1.0   # "network loss" (derivative residual)
    # Backend selection for the GRU/RK4 hot blocks.  These flow unchanged to
    # the kernel wrappers (kernels/gru, kernels/rk4) and from there into every
    # serving module built on this config (fleet train_step, divergence guard,
    # TwinServer.predict) — docs/KERNELS.md traces the full path.
    use_pallas: bool = False    # False: jnp reference; True: Pallas kernels
    interpret: bool | None = None   # None = auto (compiled on TPU, Pallas
                                    # interpreter elsewhere); bool overrides
    learn_shift: bool = True    # the paper's q input-shift outputs

    @property
    def library(self) -> PolyLibrary:
        return make_library(self.n, self.m, self.order)

    def with_(self, **kw) -> "MerindaConfig":
        return replace(self, **kw)


class Merinda:
    """Functional model: params are a plain pytree; all methods are pure."""

    def __init__(self, cfg: MerindaConfig):
        self.cfg = cfg
        self.lib = cfg.library

    # ------------------------------------------------------------------ #
    def norm_stats(self, y_win, u_win):
        """Dataset statistics: per-channel (mu, sigma) for the GRU input and
        per-column library scales (phi_scale) for head-output conditioning.

        Column scaling is the classic SINDy conditioning trick: the head
        regresses coefficients of the UNIT-SCALE library, so its implicit
        least-squares problem is well conditioned; physical coefficients are
        theta_scaled / phi_scale.
        """
        xs = jnp.concatenate([y_win[:, :-1, :], u_win], axis=-1)
        mu = xs.mean(axis=(0, 1))
        sigma = xs.std(axis=(0, 1)) + 1e-6
        phi = self.lib.eval(y_win[:, :-1, :], u_win if self.cfg.m else None)
        phi_scale = jnp.sqrt(jnp.mean(jnp.square(phi), axis=(0, 1))) + 1e-6
        return {"mu": mu, "sigma": sigma, "phi_scale": phi_scale}

    def init(self, key, norm=None):
        cfg = self.cfg
        L = self.lib.size
        kg, k1, k2 = jax.random.split(key, 3)
        d_in = cfg.n + cfg.m
        q = cfg.m if cfg.learn_shift else 0
        # head input: [last hidden ; mean-pooled hidden] (richer summary of
        # the V hidden states than the final state alone).
        d_head = 2 * cfg.hidden
        s1 = 1.0 / jnp.sqrt(d_head)
        if norm is None:
            norm = {"mu": jnp.zeros((d_in,)), "sigma": jnp.ones((d_in,)),
                    "phi_scale": jnp.ones((L,))}
        return {
            "gru": init_gru_params(kg, d_in, cfg.hidden),
            "head": {
                "w1": jax.random.uniform(k1, (d_head, cfg.head_hidden),
                                         minval=-s1, maxval=s1),
                "b1": jnp.zeros((cfg.head_hidden,)),
                # zero init: Theta_est starts at 0 -> stable integration.
                "w2": jnp.zeros((cfg.head_hidden, cfg.n * L + q)),
                "b2": jnp.zeros((cfg.n * L + q,)),
            },
            "norm": norm,
        }

    # ------------------------------------------------------------------ #
    def encode(self, params, y_win, u_win):
        """GRU-NN forward: windows -> dense coefficients + input shift.

        y_win: [B, k+1, n] (k+1 samples; the extra sample is the target for
        the final integration step), u_win: [B, k, m].
        Returns (theta_dense [B, n, L], shift [B, m]).
        """
        cfg = self.cfg
        L = self.lib.size
        xs = jnp.concatenate([y_win[:, :-1, :], u_win], axis=-1)  # [B, k, n+m]
        norm = jax.lax.stop_gradient(params["norm"])
        xs = (xs - norm["mu"]) / norm["sigma"]
        B = xs.shape[0]
        h0 = jnp.zeros((B, cfg.hidden), xs.dtype)
        g = params["gru"]
        hs, hT = gru_scan(xs, h0, g["wx"], g["wh"], g["b"],
                          use_pallas=cfg.use_pallas, interpret=cfg.interpret)
        summary = jnp.concatenate([hT, hs.mean(axis=1)], axis=-1)
        hd = params["head"]
        h = jax.nn.relu(summary @ hd["w1"] + hd["b1"])
        raw = (h @ hd["w2"] + hd["b2"]) * cfg.theta_scale
        # head outputs unit-scale-library coefficients; rescale to physical.
        theta_dense = (raw[..., :cfg.n * L].reshape(B, cfg.n, L)
                       / norm["phi_scale"][None, None, :])
        if cfg.learn_shift and cfg.m:
            shift = raw[..., cfg.n * L:]
        else:
            shift = jnp.zeros((B, cfg.m), raw.dtype)
        return theta_dense, shift

    # ------------------------------------------------------------------ #
    def sparsify(self, theta_dense, enable, phi_scale=None):
        """Magnitude top-|Theta| mask with straight-through gradients.

        Magnitudes are measured on the unit-scale library (|theta| *
        phi_scale — each term's actual contribution), which is the
        identifiability-correct ranking.  `enable` may be a traced boolean.
        """
        cfg = self.cfg
        B, n, L = theta_dense.shape
        scale = jnp.ones((L,)) if phi_scale is None else phi_scale
        flat = theta_dense.reshape(B, n * L)
        k = min(cfg.n_active, n * L)
        # stop_gradient: the mask is a hard top-k selection (straight-through);
        # gradients flow only through the kept coefficient values.
        mag = jax.lax.stop_gradient(
            jnp.abs(flat * jnp.tile(scale, (n,))[None, :]))
        thresh = jnp.sort(mag, axis=-1)[:, -k][:, None]
        mask = (mag >= thresh).astype(flat.dtype)
        sparse = (flat * mask).reshape(B, n, L)
        return jnp.where(enable, sparse, theta_dense)

    # ------------------------------------------------------------------ #
    def decode(self, theta, y0, u_win):
        """SOLVE(Y(0), Theta, U): RK4-integrate the recovered model."""
        cfg = self.cfg
        return rk4_poly_solve(theta, y0, u_win, dt=cfg.dt, library=self.lib,
                              use_pallas=cfg.use_pallas,
                              interpret=cfg.interpret)

    # ------------------------------------------------------------------ #
    def forward(self, params, y_win, u_win, sparsify_enable=False):
        theta_dense, shift = self.encode(params, y_win, u_win)
        theta = self.sparsify(theta_dense, sparsify_enable,
                              params["norm"]["phi_scale"])
        u_eff = u_win + shift[:, None, :] if self.cfg.m else u_win
        y_est = self.decode(theta, y_win[:, 0, :], u_eff)
        return y_est, theta, theta_dense

    # ------------------------------------------------------------------ #
    def loss(self, params, batch, sparsify_enable=False):
        """ODE loss (paper: MSE(Y, Y_est)) + network (collocation) loss + L1.

        The collocation term matches Theta @ Phi(Y) against central-difference
        derivatives of the window — the "network loss" the ODE loss is
        appended to in the paper; it conditions the head long before the
        integrated trajectories carry useful gradient signal.
        """
        cfg = self.cfg
        y_win, u_win = batch
        y_est, theta, theta_dense = self.forward(params, y_win, u_win,
                                                 sparsify_enable)
        ode_loss = jnp.mean(jnp.square(y_est - y_win))
        # L1 on unit-scale-library coefficients (contribution magnitudes);
        # relaxed 10x once the hard mask is active (shrinkage no longer needed
        # for selection, only biases the survivors).
        phi_scale = jax.lax.stop_gradient(params["norm"]["phi_scale"])
        l1 = jnp.mean(jnp.abs(theta_dense * phi_scale[None, None, :]))
        l1_w = jnp.where(sparsify_enable, 0.1 * cfg.l1, cfg.l1)
        loss = ode_loss + l1_w * l1
        coll = jnp.zeros(())
        if cfg.collocation_weight:
            dy_fd = (y_win[:, 2:, :] - y_win[:, :-2, :]) / (2.0 * cfg.dt)
            y_mid = y_win[:, 1:-1, :]
            u_mid = u_win[:, 1:, :]
            phi = self.lib.eval(y_mid, u_mid if cfg.m else None)   # [B,k-1,L]
            pred = jnp.einsum("bnl,bkl->bkn", theta, phi)
            coll = jnp.mean(jnp.square(pred - dy_fd))
            loss = loss + cfg.collocation_weight * coll
        return loss, {"ode_loss": ode_loss, "l1": l1, "coll": coll,
                      "theta_mean_abs": jnp.mean(jnp.abs(theta))}

    # ------------------------------------------------------------------ #
    def recover(self, params, y_win, u_win, polish: bool = True):
        """Recover one global sparse model from all windows (median-pooled
        coefficients, re-sparsified) — the deployed digital-twin estimate.

        polish: refit coefficient VALUES on the network-identified support by
        masked ridge regression against finite-difference derivatives
        (standard in the MR literature; removes L1 shrinkage bias — the
        support selection itself stays entirely MERINDA's).
        """
        from repro.core.sparse_regression import masked_ridge

        theta_dense, _ = self.encode(params, y_win, u_win)
        pooled = jnp.median(theta_dense, axis=0, keepdims=True)
        theta = self.sparsify(pooled, True, params["norm"]["phi_scale"])[0]
        if not polish:
            return theta
        cfg = self.cfg
        dy = ((y_win[:, 2:, :] - y_win[:, :-2, :]) / (2.0 * cfg.dt)
              ).reshape(-1, cfg.n)
        y_mid = y_win[:, 1:-1, :].reshape(-1, cfg.n)
        u_mid = u_win[:, 1:, :].reshape(y_mid.shape[0], cfg.m)
        phi = self.lib.eval(y_mid, u_mid if cfg.m else None)
        mask = (jnp.abs(theta) > 0).astype(theta.dtype)
        return masked_ridge(phi, dy, mask)

    # ------------------------------------------------------------------ #
    def reconstruction_mse(self, theta, y_win, u_win):
        """Table-I metric: MSE of re-integrated trajectories vs ground truth."""
        B = y_win.shape[0]
        theta_b = jnp.broadcast_to(theta[None], (B,) + theta.shape)
        y_est = self.decode(theta_b, y_win[:, 0, :], u_win)
        return jnp.mean(jnp.square(y_est - y_win))
