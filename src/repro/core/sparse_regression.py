"""Sequentially-thresholded least squares (STLSQ) — the SINDy-style sparse
regression used by the EMILY and PINN+SR baselines to extract sparse models.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["stlsq", "masked_ridge"]


@jax.jit
def masked_ridge(phi, dy, mask, ridge: float = 1e-6):
    """Least-squares refit of dy ~= phi @ theta.T restricted to `mask` [n, L].

    Used to polish coefficient VALUES on a fixed support (removes L1
    shrinkage bias after the support has been identified).
    """
    L = phi.shape[-1]
    eye = jnp.eye(L)

    def row(mask_i, dy_i):
        phi_m = phi * mask_i[None, :]
        A = phi_m.T @ phi_m + ridge * eye
        b = phi_m.T @ dy_i
        return jnp.linalg.solve(A, b) * mask_i

    return jax.vmap(row)(mask, dy.T)


@partial(jax.jit, static_argnames=("n_iters",))
def stlsq(phi, dy, threshold: float = 0.05, ridge: float = 1e-6,
          n_iters: int = 10):
    """Solve dy ~= phi @ theta.T with sequential magnitude thresholding.

    phi: [N, L] library features at samples; dy: [N, n] derivative targets.
    Returns theta [n, L].
    """
    N, L = phi.shape
    n = dy.shape[-1]
    eye = jnp.eye(L)

    def ridge_solve(mask):
        # mask: [n, L]; solve each row's masked least squares via a masked
        # normal equation (keeps shapes static under jit).
        def row(mask_i, dy_i):
            phi_m = phi * mask_i[None, :]
            A = phi_m.T @ phi_m + ridge * eye
            b = phi_m.T @ dy_i
            w = jnp.linalg.solve(A, b)
            return w * mask_i

        return jax.vmap(row)(mask, dy.T)

    def body(_, theta_mask):
        theta, mask = theta_mask
        theta = ridge_solve(mask)
        mask = (jnp.abs(theta) > threshold).astype(phi.dtype)
        return theta * mask, mask

    mask0 = jnp.ones((n, L), phi.dtype)
    theta0 = ridge_solve(mask0)
    theta, _ = jax.lax.fori_loop(0, n_iters, body, (theta0, mask0))
    return theta
