"""PINN+SR baseline — physics-informed network + sparse regression.

Physics-informed neural networks with sparse regression for discovering
governing equations (the paper's second comparator).  A coordinate network
N(t) -> Y_hat(t) fits each trace; the physics residual ties its time
derivative (exact, via forward-mode AD) to a jointly-learned sparse library
model:

  loss = MSE(Y_hat(t_i), Y_i)
       + lam_phys * || dY_hat/dt(t_i) - Theta @ Phi(Y_hat(t_i), U(t_i)) ||^2
       + lam_l1 * |Theta|_1

with sequential thresholding rounds on Theta (the SR part).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.library import make_library

__all__ = ["PinnSRConfig", "PinnSR"]


@dataclass(frozen=True)
class PinnSRConfig:
    n: int
    m: int
    order: int = 2
    hidden: int = 64
    depth: int = 3
    n_fourier: int = 16         # Fourier features on t
    dt: float = 0.01
    horizon: int = 400          # samples per trace the net is fit to
    lam_phys: float = 0.1
    lam_l1: float = 1e-3
    threshold: float = 0.05

    @property
    def library(self):
        return make_library(self.n, self.m, self.order)


class PinnSR:
    def __init__(self, cfg: PinnSRConfig):
        self.cfg = cfg
        self.lib = cfg.library

    def init(self, key, ys=None):
        """ys: optional [T+1, n] trace for output normalization — the net
        predicts standardized Y (coordinate nets fit O(1) targets far
        faster); physics/theta stay in physical units via the chain rule."""
        cfg = self.cfg
        kf, *keys = jax.random.split(key, cfg.depth + 2)
        d_in = 2 * cfg.n_fourier + 1
        dims = [d_in] + [cfg.hidden] * cfg.depth + [cfg.n]
        layers = []
        for k, (a, b) in zip(keys, zip(dims[:-1], dims[1:])):
            s = 1.0 / jnp.sqrt(a)
            layers.append({
                "w": jax.random.uniform(k, (a, b), minval=-s, maxval=s),
                "b": jnp.zeros((b,)),
            })
        # harmonics of the trace period (bounded derivatives, fd-checkable)
        freqs = (jnp.arange(cfg.n_fourier) + 1.0) / (cfg.horizon * cfg.dt)
        y_mu = ys.mean(0) if ys is not None else jnp.zeros((cfg.n,))
        y_sigma = ys.std(0) + 1e-6 if ys is not None else jnp.ones((cfg.n,))
        return {
            "mlp": layers,
            "freqs": freqs,                       # fixed Fourier basis
            "y_mu": y_mu, "y_sigma": y_sigma,
            "theta": jnp.zeros((cfg.n, self.lib.size)),
            "mask": jnp.ones((cfg.n, self.lib.size)),   # SR threshold mask
        }

    # ------------------------------------------------------------------ #
    def net(self, params, t):
        """t: scalar (seconds) -> Y_hat [n]."""
        f = params["freqs"]
        x = jnp.concatenate([jnp.asarray([t]),
                             jnp.sin(2 * jnp.pi * f * t),
                             jnp.cos(2 * jnp.pi * f * t)])
        for layer in params["mlp"][:-1]:
            x = jnp.tanh(x @ layer["w"] + layer["b"])
        raw = x @ params["mlp"][-1]["w"] + params["mlp"][-1]["b"]
        stats = jax.lax.stop_gradient((params["y_mu"], params["y_sigma"]))
        return raw * stats[1] + stats[0]

    def net_and_dot(self, params, t):
        """(Y_hat, dY_hat/dt) via forward-mode AD in t."""
        return jax.jvp(lambda tt: self.net(params, tt), (t,), (jnp.ones(()),))

    # ------------------------------------------------------------------ #
    def loss(self, params, batch, sparsify_enable=False):
        """batch: (ys [T+1, n], us [T, m]) — one trace (vmap for more)."""
        del sparsify_enable
        cfg = self.cfg
        ys, us = batch
        T = us.shape[0]
        ts = jnp.arange(T) * cfg.dt
        y_hat, y_dot = jax.vmap(lambda t: self.net_and_dot(params, t))(ts)
        sigma = jax.lax.stop_gradient(params["y_sigma"])
        data = jnp.mean(jnp.square((y_hat - ys[:-1]) / sigma))
        theta = params["theta"] * params["mask"]
        phi = self.lib.eval(y_hat, us if cfg.m else None)
        resid = (y_dot - phi @ theta.T) / sigma
        phys = jnp.mean(jnp.square(resid))
        l1 = jnp.mean(jnp.abs(params["theta"]))
        loss = data + cfg.lam_phys * phys + cfg.lam_l1 * l1
        return loss, {"data": data, "phys": phys, "l1": l1, "ode_loss": data}

    # ------------------------------------------------------------------ #
    def apply_threshold(self, params):
        """One SR round: zero and freeze small coefficients."""
        theta = params["theta"] * params["mask"]
        mask = (jnp.abs(theta) > self.cfg.threshold).astype(theta.dtype)
        return {**params, "theta": theta * mask, "mask": mask}

    def recover(self, params, y_win=None, u_win=None):
        del y_win, u_win
        return params["theta"] * params["mask"]
