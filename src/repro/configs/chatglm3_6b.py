"""chatglm3-6b [dense; arXiv:2406.12793; hf]

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024 — 2d/partial RoPE
(rotary on half the head dims, interleaved pairing, GLM convention), GQA kv=2.
"""
import jax.numpy as jnp

from repro.configs import FULL_ATTN_SKIP, ArchSpec
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="chatglm3-6b",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
    d_ff=13696, vocab=65024,
    pattern=("attn",),
    rope="neox", rope_theta=1e4, rope_fraction=0.5, rope_interleaved=True,
    norm="rmsnorm", mlp_kind="swiglu",
    dtype=jnp.bfloat16,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, dtype=jnp.float32, remat=False,
)

SPEC = ArchSpec(
    name="chatglm3-6b", config=CONFIG, smoke=SMOKE,
    skip_shapes={"long_500k": FULL_ATTN_SKIP},
    notes="partial (2d) interleaved RoPE; extreme GQA kv=2",
)
