"""mixtral-8x22b [moe; arXiv:2401.04088; hf]

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8 experts top-2,
sliding-window attention (window 4096) per the assignment.  SWA gives the
decode path a ring cache, so `long_500k` RUNS (O(window) state per layer).
"""
import jax.numpy as jnp

from repro.configs import ArchSpec
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="mixtral-8x22b",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=32768,
    pattern=("swa",), window=4096,
    n_experts=8, top_k=2,
    moe_group_size=512, moe_capacity=1.25,
    rope="neox", rope_theta=1e6,
    norm="rmsnorm", mlp_kind="swiglu",
    dtype=jnp.bfloat16,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab=256, n_experts=4, window=16, moe_group_size=64,
    moe_capacity=8.0,  # no-drop capacity: see arctic smoke note
    dtype=jnp.float32, remat=False,
)

SPEC = ArchSpec(
    name="mixtral-8x22b", config=CONFIG, smoke=SMOKE,
    notes="8e top-2 MoE, SWA(4096) ring cache -> long_500k runnable",
)
