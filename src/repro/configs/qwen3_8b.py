"""qwen3-8b [dense; hf:Qwen/Qwen3-8B; hf]

36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936 — per-head q/k
RMSNorm, RoPE theta 1e6, SwiGLU.
"""
import jax.numpy as jnp

from repro.configs import FULL_ATTN_SKIP, ArchSpec
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="qwen3-8b",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=12288, vocab=151936,
    pattern=("attn",),
    rope="neox", rope_theta=1e6,
    qk_norm=True, qk_norm_kind="rmsnorm",
    norm="rmsnorm", mlp_kind="swiglu",
    dtype=jnp.bfloat16,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, dtype=jnp.float32, remat=False,
)

SPEC = ArchSpec(
    name="qwen3-8b", config=CONFIG, smoke=SMOKE,
    skip_shapes={"long_500k": FULL_ATTN_SKIP},
    notes="dense GQA + qk-norm",
)
