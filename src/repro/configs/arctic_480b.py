"""arctic-480b [moe; hf:Snowflake/snowflake-arctic-base; hf]

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128 experts top-2
PLUS a parallel dense-residual FFN per layer (Arctic's dense-MoE hybrid).
~476B total params; the optimizer defaults to adafactor + full ZeRO sharding
(launch/train.py) so optimizer state fits 16 GB/chip at 256 chips.
"""
import jax.numpy as jnp

from repro.configs import FULL_ATTN_SKIP, ArchSpec
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="arctic-480b",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=4864, vocab=32000,
    pattern=("attn",),
    n_experts=128, top_k=2, dense_ff=4864,
    moe_group_size=512, moe_capacity=1.25,
    rope="neox", rope_theta=1e4,
    norm="rmsnorm", mlp_kind="swiglu",
    dtype=jnp.bfloat16,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab=256, n_experts=4, dense_ff=96, moe_group_size=64,
    # smoke: capacity high enough that no token ever drops, so the
    # decode-vs-forward consistency test is exact (drop semantics are
    # exercised separately in tests/test_moe.py).
    moe_capacity=8.0,
    dtype=jnp.float32, remat=False,
)

SPEC = ArchSpec(
    name="arctic-480b", config=CONFIG, smoke=SMOKE,
    skip_shapes={"long_500k": FULL_ATTN_SKIP},
    notes="128e top-2 MoE + dense residual FFN; expert-parallel over 'model'",
)
