"""starcoder2-15b [dense; arXiv:2402.19173; hf]

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152 — GQA, RoPE,
LayerNorm + plain (non-gated) GELU MLP per StarCoder2.
"""
import jax.numpy as jnp

from repro.configs import FULL_ATTN_SKIP, ArchSpec
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="starcoder2-15b",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, head_dim=128,
    d_ff=24576, vocab=49152,
    pattern=("attn",),
    rope="neox", rope_theta=1e5,
    norm="layernorm", mlp_kind="gelu",
    dtype=jnp.bfloat16,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, dtype=jnp.float32, remat=False,
)

SPEC = ArchSpec(
    name="starcoder2-15b", config=CONFIG, smoke=SMOKE,
    skip_shapes={"long_500k": FULL_ATTN_SKIP},
    notes="dense GQA kv=4; non-gated GELU MLP",
)
