"""chameleon-34b [vlm; arXiv:2405.09818; unverified]

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536 — early-fusion VQ image
tokens.  The modality frontend is a STUB per the assignment: VQ image tokens
are ordinary vocabulary ids in an early-fusion model, so batch specs are plain
token ids.  Chameleon uses qk-norm (LayerNorm flavor) for training stability.
"""
import jax.numpy as jnp

from repro.configs import FULL_ATTN_SKIP, ArchSpec
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="chameleon-34b",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab=65536,
    pattern=("attn",),
    rope="neox", rope_theta=1e4,
    qk_norm=True, qk_norm_kind="layernorm",
    norm="rmsnorm", mlp_kind="swiglu",
    dtype=jnp.bfloat16,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=128, vocab=256, dtype=jnp.float32, remat=False,
)

SPEC = ArchSpec(
    name="chameleon-34b", config=CONFIG, smoke=SMOKE,
    skip_shapes={"long_500k": FULL_ATTN_SKIP},
    notes="early-fusion VLM backbone; image tokenizer stubbed (token ids)",
)
