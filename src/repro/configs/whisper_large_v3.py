"""whisper-large-v3 [audio; arXiv:2212.04356; unverified]

Enc-dec backbone: 32 encoder + 32 decoder layers, d_model=1280 20H (MHA
kv=20) d_ff=5120 vocab=51866.  The conv/mel frontend is a STUB per the
assignment — input_specs provide precomputed frame embeddings [B, T, d].
LayerNorm + plain GELU, no RoPE (sinusoidal encoder / learned decoder
positions).  Decoder decodes against self + cross caches; long_500k skipped
(full attention, and Whisper has no 500k-token decode semantics).
"""
import jax.numpy as jnp

from repro.configs import FULL_ATTN_SKIP, ArchSpec
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="whisper-large-v3",
    n_layers=32, enc_layers=32,
    d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
    d_ff=5120, vocab=51866,
    rope="none", norm="layernorm", mlp_kind="gelu",
    dtype=jnp.bfloat16,
)

SMOKE = CONFIG.with_(
    n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab=256, dtype=jnp.float32, remat=False,
)

SPEC = ArchSpec(
    name="whisper-large-v3", config=CONFIG, smoke=SMOKE,
    skip_shapes={"long_500k": FULL_ATTN_SKIP
                 + "; Whisper additionally has no 500k-decode semantics"},
    notes="enc-dec; frame frontend stubbed (precomputed embeddings)",
)
