"""zamba2-7b [hybrid; arXiv:2411.15242; unverified]

81L d_model=3584 Mamba2 backbone (ssm_state=64, headdim 64 -> d_inner=7168,
112 SSD heads) with a weight-SHARED attention+MLP block (32H, d_ff=14336)
applied over concat(hidden, embedding) at the top of every 6-layer cycle
(13 cycles + 3-layer tail = 14 invocations).  Per-invocation LoRA on the
shared block is omitted (recorded simplification, DESIGN.md).
long_500k RUNS: O(1) SSM state; the shared block's KV caches are
sequence-sharded over the whole mesh.
"""
import jax.numpy as jnp

from repro.configs import ArchSpec
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="zamba2-7b",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab=32000,
    pattern=("mamba2",) * 6, shared_every=6,
    shared_n_heads=32, shared_d_ff=14336,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, conv_width=4,
    rope="neox", rope_theta=1e4,
    norm="rmsnorm",
    dtype=jnp.bfloat16,
)

SMOKE = CONFIG.with_(
    n_layers=9, pattern=("mamba2",) * 3, shared_every=3,
    d_model=64, n_heads=4, n_kv_heads=4, head_dim=32,
    shared_n_heads=4, shared_d_ff=128, d_ff=128, vocab=256,
    ssm_state=16, ssm_head_dim=16,
    dtype=jnp.float32, remat=False,
)

SPEC = ArchSpec(
    name="zamba2-7b", config=CONFIG, smoke=SMOKE,
    notes="Mamba2 backbone + shared attn block every 6 layers; "
          "long_500k O(1) SSM state",
)
