"""rwkv6-3b [ssm; arXiv:2404.05892; hf]

"Finch": 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536 —
data-dependent decay + token-shift time-mix, squared-ReLU channel-mix.
Attention-free O(1)-state decode: every shape runs, including long_500k.
This family is the direct beneficiary of the paper's acceleration principle
(chunked VMEM-resident linear recurrence; DESIGN.md §Arch-applicability).
"""
import jax.numpy as jnp

from repro.configs import ArchSpec
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="rwkv6-3b",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
    d_ff=8960, vocab=65536,
    pattern=("rwkv6",), rwkv_head_dim=64,
    rope="none", norm="layernorm",
    dtype=jnp.bfloat16,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    rwkv_head_dim=16, d_ff=128, vocab=256, dtype=jnp.float32, remat=False,
)

SPEC = ArchSpec(
    name="rwkv6-3b", config=CONFIG, smoke=SMOKE,
    notes="attention-free linear recurrence; long_500k O(1) state",
)
