"""gemma3-12b [dense; hf:google/gemma-3-1b-pt; unverified]

48L d_model=3840 16H (GQA kv=8) head_dim=256 (attention dim 4096 != d_model)
d_ff=15360 vocab=262144 — 5:1 local(window 1024):global layer pattern, qk-norm,
GeGLU, tied + sqrt(d)-scaled embeddings, 128k-native context.  long_500k RUNS:
decode touches the 1024-token ring caches on 40/48 layers; the 8 global layers
use sequence-sharded flash-decode.
"""
import jax.numpy as jnp

from repro.configs import ArchSpec
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="gemma3-12b",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15360, vocab=262144,
    pattern=("local", "local", "local", "local", "local", "global"),
    window=1024,
    rope="neox", rope_theta=1e6, rope_theta_local=1e4,
    qk_norm=True, qk_norm_kind="rmsnorm",
    norm="rmsnorm", mlp_kind="geglu",
    embed_scale=True, tie_embeddings=True,
    dtype=jnp.bfloat16,
)

SMOKE = CONFIG.with_(
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, window=8, dtype=jnp.float32, remat=False,
)

SPEC = ArchSpec(
    name="gemma3-12b", config=CONFIG, smoke=SMOKE,
    notes="5:1 local:global; ring caches bound 40/48 layers at 500k decode",
)
