"""Architecture registry: ``--arch <id>`` -> full config + smoke config.

Every assigned architecture is transcribed exactly from the assignment block
(see each module's docstring for the source tier).  `SHAPES` defines the four
assigned input-shape cells; configs may skip shapes with a recorded reason
(DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field
from typing import Any

__all__ = ["SHAPES", "Shape", "ArchSpec", "get_arch", "list_archs",
           "FULL_ATTN_SKIP"]


@dataclass(frozen=True)
class Shape:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4_096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32_768, 128),
    "long_500k": Shape("long_500k", "decode", 524_288, 1),
}


@dataclass(frozen=True)
class ArchSpec:
    name: str
    config: Any                    # full LMConfig (dry-run only)
    smoke: Any                     # reduced LMConfig (CPU-runnable)
    skip_shapes: dict = field(default_factory=dict)   # name -> reason
    notes: str = ""

    def shapes(self):
        return [s for n, s in SHAPES.items() if n not in self.skip_shapes]


_ARCHS = {
    "chameleon-34b": "chameleon_34b",
    "arctic-480b": "arctic_480b",
    "mixtral-8x22b": "mixtral_8x22b",
    "rwkv6-3b": "rwkv6_3b",
    "whisper-large-v3": "whisper_large_v3",
    "zamba2-7b": "zamba2_7b",
    "qwen3-8b": "qwen3_8b",
    "starcoder2-15b": "starcoder2_15b",
    "chatglm3-6b": "chatglm3_6b",
    "gemma3-12b": "gemma3_12b",
}

FULL_ATTN_SKIP = ("pure full-attention arch: 500k-token decode has no "
                  "sub-quadratic/windowed/recurrent mode; skipped per the "
                  "assignment shape rules (recorded in DESIGN.md)")


def list_archs() -> list[str]:
    return sorted(_ARCHS)


def get_arch(name: str) -> ArchSpec:
    if name not in _ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{_ARCHS[name]}")
    return mod.SPEC
