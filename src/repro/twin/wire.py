"""Versioned wire format for the federation split (twin/federation.py).

Everything the `FederationCoordinator` and its `ShardWorker` subprocesses —
or a telemetry producer and the ingestion front door — say to each other is
one of the message dataclasses below, encoded as:

    u16 WIRE_VERSION | u32 header_len | JSON header | raw array blobs

The JSON header carries the message type, every scalar field, and a
manifest (name, dtype, shape) for each array field; the blobs follow in
manifest order as raw C-contiguous bytes, so telemetry arrays cross the
process boundary without a JSON detour.  A version bump is the upgrade
gate: decode refuses frames whose major version it does not speak, which
is what lets coordinator and workers be restarted independently.

Transports share the codec, they differ only in framing:

  * `multiprocessing.Connection` — `send_bytes(encode(msg))` /
    `decode(recv_bytes())`; the pipe frames for us.
  * TCP stream — `write_frame`/`read_frame` add a u32 big-endian length
    prefix.  `IngestFrontDoor` (the network ingestion door) and
    `FrontDoorClient` (what a telemetry producer embeds) live here too.

TRUST BOUNDARY: the front door accepts ONLY `IngestBatch` (pure arrays).
`SnapshotBlob` carries a pickled pytree and is valid ONLY on the
coordinator<->worker pipes, which never leave the machine; `decode`
enforces this with the `trusted` flag.
"""
from __future__ import annotations

import json
import pickle
import socket
import struct
import threading
from dataclasses import dataclass, field, fields

import numpy as np

__all__ = [
    "WIRE_VERSION", "WireError", "encode", "decode",
    "read_frame", "write_frame",
    "Hello", "IngestBatch", "TickCmd", "TickDone", "Deploy",
    "PredictCmd", "PredictResult", "Scenario", "ScenarioResult",
    "DrainCmd", "Ack", "StatsCmd", "Stats",
    "SnapshotCmd", "SnapshotBlob", "Shutdown", "ErrorMsg",
    "IngestFrontDoor", "FrontDoorClient",
]

WIRE_VERSION = 1          # bump MAJOR on any incompatible layout change
_MAX_FRAME = 1 << 28      # 256 MiB: corrupt length prefixes fail loudly
_HDR = struct.Struct(">HI")       # version, header_len
_LEN = struct.Struct(">I")        # stream length prefix


class WireError(RuntimeError):
    """Malformed, oversized, wrong-version, or untrusted frame."""


# --------------------------------------------------------------------------- #
# message registry
# --------------------------------------------------------------------------- #
_REGISTRY: dict[str, type] = {}


def _message(cls):
    """Register a message dataclass under its TYPE tag."""
    _REGISTRY[cls.TYPE] = cls
    return cls


@_message
@dataclass
class Hello:
    """Worker -> coordinator on (re)boot: what the worker already holds, so
    the supervisor can replay exactly the journal suffix after a restart
    (`samples[twin_id]` = samples the restored checkpoint had seen)."""
    TYPE = "hello"
    shard: int
    tick: int = 0                      # worker's restored tick counter
    ckpt_tick: int | None = None       # checkpoint tick it restored from
    samples: dict = field(default_factory=dict)   # twin_id(str) -> count


@_message
@dataclass
class IngestBatch:
    """A flush of telemetry chunks, columnar: `y[sum(counts), n]` holds the
    chunks back to back, `counts[i]` samples belonging to `twin_ids[i]`.
    The ONLY message the network front door accepts."""
    TYPE = "ingest"
    _ARRAY_FIELDS = ("twin_ids", "counts", "y", "u")
    twin_ids: np.ndarray               # int64 [k]
    counts: np.ndarray                 # int32 [k]
    y: np.ndarray                      # float32 [total, n]
    u: np.ndarray | None = None        # float32 [total, m] (None: no inputs)
    force: bool = False                # bypass staging backpressure (replay)

    @staticmethod
    def from_chunks(batch, *, force: bool = False) -> "IngestBatch":
        """Pack (twin_id, y[, u]) chunks into one columnar batch."""
        tids, counts, ys, us = [], [], [], []
        for chunk in batch:
            tid, y = chunk[0], chunk[1]
            u = chunk[2] if len(chunk) > 2 else None
            y = np.atleast_2d(np.asarray(y, np.float32))
            tids.append(int(tid))
            counts.append(y.shape[0])
            ys.append(y)
            if u is not None:
                u = np.asarray(u, np.float32)
                us.append(u.reshape(y.shape[0], -1))
        if us and len(us) != len(ys):
            raise WireError("mixed with/without-u chunks in one batch")
        return IngestBatch(
            twin_ids=np.asarray(tids, np.int64),
            counts=np.asarray(counts, np.int32),
            y=(np.concatenate(ys) if ys
               else np.zeros((0, 0), np.float32)),
            u=np.concatenate(us) if us else None,
            force=force)

    def chunks(self):
        """Iterate (twin_id, y, u|None) — the `ingest_many` batch shape."""
        off = 0
        for tid, c in zip(self.twin_ids, self.counts):
            c = int(c)
            u = self.u[off:off + c] if self.u is not None else None
            yield int(tid), self.y[off:off + c], u
            off += c

    @property
    def n_samples(self) -> int:
        return int(self.counts.sum())


@_message
@dataclass
class TickCmd:
    """Coordinator -> worker: run one serving tick under `grant` active
    slots.  `inject_delay_s` forwards the chaos straggler schedule so the
    sleep lands INSIDE the worker's timed tick, exactly like the in-process
    supervisor."""
    TYPE = "tick"
    tick: int
    grant: int = -1                    # -1: keep the current grant
    inject_delay_s: float = 0.0


@_message
@dataclass
class TickDone:
    """Worker -> coordinator: the per-tick report, flattened to scalars +
    the guard-event log — everything `ShardedTickReport` aggregates,
    nothing that would leak worker internals across the wire."""
    TYPE = "tick_done"
    tick: int
    latency_s: float
    deadline_met: bool
    n_active: int
    n_twins: int
    n_guarded: int
    degraded_level: int
    pressure: float                    # refit_pressure() for the federation
    loss: float | None = None
    ckpt_tick: int | None = None       # newest COMMITTED checkpoint tick
    events: list = field(default_factory=list)
                                       # [[twin_id, kind, score, tick], ...]


@_message
@dataclass
class Deploy:
    """Coordinator -> worker: warm-start thetas (`deploy_many` shape)."""
    TYPE = "deploy"
    _ARRAY_FIELDS = ("twin_ids", "thetas")
    twin_ids: np.ndarray               # int64 [k]
    thetas: np.ndarray                 # [k, ...] or broadcast [...]


@_message
@dataclass
class PredictCmd:
    TYPE = "predict"
    _ARRAY_FIELDS = ("us",)
    twin_id: int
    horizon: int
    us: np.ndarray | None = None


@_message
@dataclass
class PredictResult:
    TYPE = "predict_result"
    _ARRAY_FIELDS = ("ys",)
    ys: np.ndarray


@_message
@dataclass
class Scenario:
    """Coordinator -> worker: batched what-if query for one twin.

    `us` [K, horizon, m] counterfactual input sequences (None: zero
    inputs, K taken from `k`).  The worker's OWN degradation level decides
    shrink/refuse — the policy must live next to the ladder it reads."""
    TYPE = "scenario"
    _ARRAY_FIELDS = ("us",)
    twin_id: int
    horizon: int
    k: int | None = None
    us: np.ndarray | None = None


@_message
@dataclass
class ScenarioResult:
    """Worker -> coordinator: the flattened `twin.scenario.ScenarioResult`
    (center trajectories + ensemble envelope + per-scenario confidence)."""
    TYPE = "scenario_result"
    _ARRAY_FIELDS = ("ys", "lo", "hi", "confidence")
    twin_id: int
    horizon: int
    requested_k: int
    k: int
    degraded_level: int
    ys: np.ndarray                     # [K, H+1, n] live-theta center
    lo: np.ndarray                     # [K, H+1, n] ensemble lower envelope
    hi: np.ndarray                     # [K, H+1, n] ensemble upper envelope
    confidence: np.ndarray             # [K] in (0, 1]


@_message
@dataclass
class DrainCmd:
    """Ingest barrier; worker replies Ack when staged samples hit rings."""
    TYPE = "drain"


@_message
@dataclass
class Ack:
    TYPE = "ack"
    n: int = 0                         # e.g. samples staged by an ingest


@_message
@dataclass
class StatsCmd:
    TYPE = "stats"
    kind: str = "latency"              # latency | stage | reset


@_message
@dataclass
class Stats:
    TYPE = "stats_result"
    data: dict = field(default_factory=dict)


@_message
@dataclass
class SnapshotCmd:
    TYPE = "snapshot"


@_message
@dataclass
class SnapshotBlob:
    """Worker -> coordinator: pickled `snapshot_state()` pytree.  TRUSTED
    pipes only — `decode(trusted=False)` (the front door) refuses it."""
    TYPE = "snapshot_blob"
    _ARRAY_FIELDS = ("payload",)
    payload: np.ndarray                # uint8 pickle bytes

    @staticmethod
    def pack(state) -> "SnapshotBlob":
        return SnapshotBlob(payload=np.frombuffer(
            pickle.dumps(state, protocol=pickle.HIGHEST_PROTOCOL), np.uint8))

    def unpack(self):
        return pickle.loads(self.payload.tobytes())


@_message
@dataclass
class Shutdown:
    TYPE = "shutdown"


@_message
@dataclass
class ErrorMsg:
    """Worker -> coordinator: a tick/command raised.  The coordinator
    treats this like a process death (kill + supervised restart)."""
    TYPE = "error"
    where: str = ""
    error: str = ""


_UNTRUSTED_OK = frozenset({"ingest", "ack", "error"})


# --------------------------------------------------------------------------- #
# codec
# --------------------------------------------------------------------------- #
def encode(msg) -> bytes:
    """Message dataclass -> one wire payload (no outer length prefix)."""
    cls = type(msg)
    array_fields = getattr(cls, "_ARRAY_FIELDS", ())
    header: dict = {"t": cls.TYPE}
    manifest = []
    blobs = []
    for f in fields(cls):
        val = getattr(msg, f.name)
        if f.name in array_fields:
            if val is None:
                manifest.append([f.name, None, None])
            else:
                # record the shape BEFORE ascontiguousarray: it promotes
                # 0-d arrays to 1-d, which would corrupt the round trip
                val = np.asarray(val)
                shape = list(val.shape)
                arr = np.ascontiguousarray(val)
                manifest.append([f.name, str(arr.dtype), shape])
                blobs.append(arr.tobytes())
        else:
            header[f.name] = val
    if manifest:
        header["a"] = manifest
    hdr = json.dumps(header, separators=(",", ":")).encode()
    return b"".join([_HDR.pack(WIRE_VERSION, len(hdr)), hdr, *blobs])


def decode(payload: bytes, *, trusted: bool = True):
    """One wire payload -> message dataclass.  `trusted=False` is the
    network front door: only `_UNTRUSTED_OK` types are admitted (nothing
    that deserializes beyond JSON + raw arrays)."""
    if len(payload) < _HDR.size:
        raise WireError(f"short frame ({len(payload)} bytes)")
    version, hdr_len = _HDR.unpack_from(payload)
    if version != WIRE_VERSION:
        raise WireError(f"wire version {version} != {WIRE_VERSION} "
                        "(restart the older side)")
    end = _HDR.size + hdr_len
    if end > len(payload):
        raise WireError("header overruns frame")
    try:
        header = json.loads(payload[_HDR.size:end])
        tag = header.pop("t")
        cls = _REGISTRY[tag]
    except (ValueError, KeyError) as e:
        raise WireError(f"bad header: {e!r}") from e
    if not trusted and tag not in _UNTRUSTED_OK:
        raise WireError(f"message type {tag!r} not allowed on an "
                        "untrusted transport")
    kwargs = {}
    off = end
    manifest = header.pop("a", [])
    if not isinstance(manifest, list):
        raise WireError("bad header: array manifest is not a list")
    for entry in manifest:
        try:
            name, dtype, shape = entry
        except (TypeError, ValueError) as e:
            raise WireError(f"bad manifest entry: {entry!r}") from e
        if dtype is None:
            kwargs[name] = None
            continue
        # a flipped bit in the manifest must surface as WireError, not as
        # numpy's TypeError/ValueError/OverflowError zoo
        try:
            arr = np.dtype(dtype)
            n = int(np.prod(shape, dtype=np.int64)) * arr.itemsize
            if n < 0:
                raise WireError(f"blob {name!r} has negative size")
            if off + n > len(payload):
                raise WireError(f"blob {name!r} overruns frame")
            kwargs[name] = np.frombuffer(
                payload[off:off + n], arr).reshape(shape)
        except WireError:
            raise
        except Exception as e:
            raise WireError(f"bad blob {name!r}: {e!r}") from e
        off += n
    kwargs.update(header)
    try:
        return cls(**kwargs)
    except TypeError as e:
        raise WireError(f"bad fields for {tag!r}: {e}") from e


# --------------------------------------------------------------------------- #
# stream framing (TCP)
# --------------------------------------------------------------------------- #
def write_frame(sock: socket.socket, payload: bytes) -> None:
    if len(payload) > _MAX_FRAME:
        raise WireError(f"frame too large ({len(payload)} bytes)")
    sock.sendall(_LEN.pack(len(payload)) + payload)


def _read_exact(sock: socket.socket, n: int) -> bytes | None:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None                # peer closed
        buf += chunk
    return bytes(buf)


def read_frame(sock: socket.socket) -> bytes | None:
    """One length-prefixed payload, or None on clean EOF."""
    raw = _read_exact(sock, _LEN.size)
    if raw is None:
        return None
    (n,) = _LEN.unpack(raw)
    if n > _MAX_FRAME:
        raise WireError(f"frame length {n} exceeds {_MAX_FRAME}")
    payload = _read_exact(sock, n)
    if payload is None:
        raise WireError("EOF mid-frame")
    return payload


# --------------------------------------------------------------------------- #
# ingestion front door
# --------------------------------------------------------------------------- #
class IngestFrontDoor:
    """Length-prefixed TCP door decoupling telemetry producers from the
    serving loop.  Accepts ONLY `IngestBatch` frames (untrusted decode),
    hands each to `sink(chunks, force=...) -> samples`, replies `Ack(n)`
    — or `ErrorMsg`, keeping the connection alive, so one bad producer
    frame cannot take the door down.  `sink` is typically
    `FederationCoordinator.ingest_many` (journal-first, then routed), and
    must be thread-safe: each producer connection gets its own thread.
    """

    def __init__(self, sink, host: str = "127.0.0.1", port: int = 0):
        self._sink = sink
        self._srv = socket.create_server((host, port))
        self._srv.settimeout(0.2)
        self.address = self._srv.getsockname()     # (host, bound_port)
        self._stop = threading.Event()
        self._conns: list[socket.socket] = []
        self._lock = threading.Lock()
        self._threads: list[threading.Thread] = []
        self._accept = threading.Thread(target=self._accept_loop,
                                        name="frontdoor-accept", daemon=True)
        self._accept.start()

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._srv.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            with self._lock:
                self._conns.append(conn)
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 name="frontdoor-conn", daemon=True)
            t.start()
            self._threads.append(t)

    def _serve_conn(self, conn: socket.socket) -> None:
        try:
            while not self._stop.is_set():
                payload = read_frame(conn)
                if payload is None:
                    return
                try:
                    msg = decode(payload, trusted=False)
                    if not isinstance(msg, IngestBatch):
                        raise WireError(f"front door expects ingest, got "
                                        f"{type(msg).TYPE!r}")
                    n = self._sink(list(msg.chunks()), force=msg.force)
                    reply = Ack(n=int(n))
                except WireError as e:
                    reply = ErrorMsg(where="front_door", error=str(e))
                write_frame(conn, encode(reply))
        except (OSError, WireError):
            pass                        # connection torn down under us
        finally:
            conn.close()

    def close(self) -> None:
        self._stop.set()
        with self._lock:
            for c in self._conns:
                try:
                    c.shutdown(socket.SHUT_RDWR)
                except OSError:
                    pass
                c.close()
        self._srv.close()
        self._accept.join(timeout=2.0)
        for t in self._threads:
            t.join(timeout=2.0)


class FrontDoorClient:
    """What a telemetry producer embeds: pack chunks, send, await Ack.
    One socket, synchronous request/response; producers wanting pipelining
    open more clients."""

    def __init__(self, address):
        self._sock = socket.create_connection(address)

    def ingest_many(self, batch, *, force: bool = False) -> int:
        """Send (twin_id, y[, u]) chunks; returns samples staged server-side
        (the `TwinService.ingest_many` contract, across the network)."""
        write_frame(self._sock,
                    encode(IngestBatch.from_chunks(batch, force=force)))
        payload = read_frame(self._sock)
        if payload is None:
            raise WireError("front door closed the connection")
        reply = decode(payload, trusted=False)
        if isinstance(reply, ErrorMsg):
            raise WireError(f"front door rejected batch: {reply.error}")
        return reply.n

    def ingest(self, twin_id: int, y, u=None, *, force: bool = False) -> int:
        chunk = (twin_id, y) if u is None else (twin_id, y, u)
        return self.ingest_many([chunk], force=force)

    def close(self) -> None:
        self._sock.close()
