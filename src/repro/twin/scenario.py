"""Batched what-if rollouts with confidence bounds — the scenario engine.

The guard already rolls every deployed model forward against *observed*
telemetry; this module generalizes that machinery into the predictive
question the paper leads with: "what happens next, under inputs that have
not happened yet?"  A `ScenarioRunner` evaluates K counterfactual
action/disturbance sequences for one twin in a SINGLE fused
`rk4_poly_solve` call — the kernel folds arbitrary leading axes into its
batch axis, so an [ensemble, K] grid of rollouts costs one dispatch, not
E*K.

Confidence comes from an ENSEMBLE OVER RECENT THETAS: every deploy /
promote pushes the outgoing coefficients into a small per-twin ring
(`TwinServer._theta_hist`), and a scenario query rolls all of them forward
together.  Where the recent models agree, the envelope is tight and
confidence is ~1; where online refits have been thrashing, the envelope
widens and confidence decays toward 0.  The center trajectory is always
the LIVE theta's rollout — the bounds annotate it, they never replace it.

Deadline behavior rides the existing `DegradationPolicy` ladder: at
degradation level >= `shrink_level` the effective K deterministically
shrinks (`max(1, k // degraded_shrink)`); at >= `refuse_level` the query
is refused with `ScenarioRefused` before any device work is dispatched.
Deterministic shrink (not sampling) keeps the three server
implementations conformant under pressure — see
tests/test_service_conformance.py.

Threading: `ScenarioRunner` is stateless after construction (jit caches
aside) and safe to share across shards; `TwinServer.scenario()` must be
called from the serving thread, like `predict()`.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.rk4.ops import rk4_poly_solve

__all__ = [
    "ScenarioConfig", "ScenarioRefused", "ScenarioResult", "ScenarioRunner",
    "effective_k",
]

_BLOWUP = 1e6          # matches the guard's non-finite clamp (monitor.py)


@dataclass(frozen=True)
class ScenarioConfig:
    """Scenario-engine knobs (part of `TwinServerConfig`).

    max_k            hard per-query cap on counterfactual sequences
    ensemble         theta-history ring size per twin (confidence ensemble);
                     1 disables the envelope (lo == hi, confidence == 1)
    shrink_level     degradation level at which K shrinks deterministically
    degraded_shrink  divisor applied to K at shrink_level (floor 1)
    refuse_level     degradation level at which queries are refused outright
    """
    max_k: int = 32
    ensemble: int = 4
    shrink_level: int = 2
    degraded_shrink: int = 4
    refuse_level: int = 3

    def __post_init__(self):
        if self.max_k < 1 or self.ensemble < 1:
            raise ValueError("max_k and ensemble must be >= 1")
        if self.degraded_shrink < 2:
            raise ValueError("degraded_shrink must be >= 2")
        if not (0 < self.shrink_level <= self.refuse_level):
            raise ValueError("need 0 < shrink_level <= refuse_level")


class ScenarioRefused(RuntimeError):
    """Scenario query refused under deadline pressure (degradation ladder).

    Subclasses RuntimeError so callers that only handle the `predict()`
    error surface degrade gracefully; the message always starts with
    ``scenario refused`` so the federated coordinator can re-raise the
    precise type across the wire boundary.
    """


def effective_k(requested: int, level: int, cfg: ScenarioConfig) -> int:
    """Deterministic K under the degradation ladder; raises when refused."""
    if requested < 1:
        raise ValueError(f"k must be >= 1, got {requested}")
    if requested > cfg.max_k:
        raise ValueError(f"k {requested} exceeds max_k {cfg.max_k}")
    if level >= cfg.refuse_level:
        raise ScenarioRefused(
            f"scenario refused: degradation level {level} >= "
            f"refuse_level {cfg.refuse_level}")
    if level >= cfg.shrink_level:
        return max(1, requested // cfg.degraded_shrink)
    return requested


@dataclass(frozen=True)
class ScenarioResult:
    """One twin's what-if answer: K trajectories plus an uncertainty band.

    ys          [K, H+1, n] center trajectories (LIVE theta rollout)
    lo, hi      [K, H+1, n] ensemble envelope (min/max over recent thetas)
    confidence  [K] in (0, 1]: 1 / (1 + normalized ensemble spread)
    k           effective K served (may be < requested_k when degraded)
    degraded_level   degradation-ladder level at serve time
    """
    twin_id: int
    horizon: int
    requested_k: int
    k: int
    degraded_level: int
    ys: np.ndarray
    lo: np.ndarray
    hi: np.ndarray
    confidence: np.ndarray


class ScenarioRunner:
    """Fused ensemble x K rollout engine over a PolyLibrary model family.

    One runner per model configuration (library + dt + backend); shards
    with identical configs share a runner — and therefore a jit cache —
    via `share_modules_from`, exactly like the fleet model itself.
    """

    def __init__(self, library, dt: float, cfg: ScenarioConfig, *,
                 use_pallas: bool = False, interpret: bool | None = None):
        self.lib = library
        self.dt = float(dt)
        self.cfg = cfg
        self.use_pallas = bool(use_pallas)
        self.interpret = interpret
        self._roll = jax.jit(self._roll_impl)

    # ------------------------------------------------------------------ #
    def _roll_impl(self, theta_hist, count, y0, us):
        """theta_hist [E,n,L], count scalar, y0 [n], us [K,H,m] ->
        (center [K,H+1,n], lo, hi, confidence [K])."""
        E, n, L = theta_hist.shape
        K = us.shape[0]
        live_idx = jnp.maximum(count - 1, 0) % E
        live = theta_hist[live_idx]
        # unfilled ring slots fall back to the live theta: a twin with one
        # deploy still answers, with a degenerate (zero-width) envelope
        valid = jnp.arange(E) < count
        ens = jnp.where(valid[:, None, None], theta_hist, live[None])
        theta = jnp.broadcast_to(ens[:, None], (E, K, n, L))
        y0b = jnp.broadcast_to(y0[None, None], (E, K, n))
        usb = jnp.broadcast_to(us[None], (E,) + us.shape)
        ys = rk4_poly_solve(theta, y0b, usb, dt=self.dt, library=self.lib,
                            use_pallas=self.use_pallas,
                            interpret=self.interpret)
        ys = jnp.nan_to_num(ys, nan=_BLOWUP, posinf=_BLOWUP, neginf=-_BLOWUP)
        ys = jnp.clip(ys, -_BLOWUP, _BLOWUP)
        center = ys[live_idx]
        lo = ys.min(axis=0)
        hi = ys.max(axis=0)
        # normalized mean envelope width per scenario: spread measured in
        # units of the center trajectory's own scale, squashed to (0, 1]
        scale = jnp.std(center, axis=(1, 2)) + 1e-6
        spread = jnp.mean(hi - lo, axis=(1, 2)) / scale
        confidence = 1.0 / (1.0 + spread)
        return center, lo, hi, confidence

    # ------------------------------------------------------------------ #
    def rollout(self, theta_hist, count: int, y0, us) -> tuple:
        """Device entry point; shapes as `_roll_impl`. Blocks on the result
        (host arrays out — scenario answers leave the device anyway)."""
        us = jnp.asarray(us, jnp.float32)
        if us.ndim != 3:
            raise ValueError(f"us must be [K, H, m], got {us.shape}")
        center, lo, hi, conf = self._roll(
            jnp.asarray(theta_hist), jnp.int32(count),
            jnp.asarray(y0, jnp.float32), us)
        return (np.asarray(center), np.asarray(lo), np.asarray(hi),
                np.asarray(conf))
