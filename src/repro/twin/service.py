"""The stable serving API: the `TwinService` protocol + shared config bases.

Three servers implement the same serving surface at three scales:

    TwinServer            one process, one ring/fleet/theta store
    ShardedTwinServer     one process, N in-process shards + slot federation
    FederatedTwinServer   one coordinator process, N shard-worker SUBPROCESSES
                          (twin/federation.py) behind a versioned wire format
                          (twin/wire.py)

The process split is what forces the protocol: a coordinator cannot reach
into a worker's `TwinRecord` dict or theta store, so everything a caller may
depend on has to be a method on this surface — and once it is, telemetry
producers, front doors (`twin.wire.IngestFrontDoor`), benchmarks, and the
conformance suite (tests/test_service_conformance.py) run unchanged against
all three implementations.  `docs/API.md` documents the stable surface;
modules not named there (`packed`, `wire` framing internals) are
implementation detail and may change without deprecation.

Config consolidation (the other half of the redesign): the deadline lives in
ONE base (`DeadlineConfig`) instead of being re-declared per server config,
and the fleet-topology knobs a sharded and a federated deployment share —
global slot budget, per-shard grant floor, rebalance cadence, pressure
smoothing, recovery + chaos schedules — live in `FleetTopologyConfig`, which
both `ShardedTwinConfig` and `FederatedTwinConfig` extend.  The topology
base also owns the mapping onto the scheduler-level `FederationConfig`
(`make_federation`), so the two deployment shapes cannot drift.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Iterable, Protocol, runtime_checkable

from repro.twin.recovery import ChaosConfig, RecoveryConfig
from repro.twin.scheduler import FederationConfig

__all__ = ["TwinService", "DeadlineConfig", "FleetTopologyConfig",
           "IngestChunkLike", "conforms"]

# batch element accepted by `ingest_many`: (twin_id, y) or (twin_id, y, u)
IngestChunkLike = tuple


@runtime_checkable
class TwinService(Protocol):
    """What every twin server exposes, single-process or federated.

    Semantics every implementation must honor (the conformance suite pins
    them):

      * `ingest` stages telemetry host-side and never blocks on device work;
        `force=True` bypasses staging backpressure (crash-recovery replay).
      * `ingest_many` is the batched form — one call per producer flush, so
        a network front door is not forced into per-sample calls.  Returns
        the number of SAMPLES staged.
      * `tick` runs one full serving cycle and returns a report object with
        at least `.events` (guard transitions), `.latency_s`,
        `.deadline_met`, `.n_twins`, `.n_active`.
      * `drain` is the ingest barrier: every sample whose `ingest` returned
        before the call is visible to the next fused gather.
      * `predict` rolls the deployed model forward from the newest
        telemetry — the collision-avoidance lookahead.
      * `scenario` answers a batched what-if query: K counterfactual input
        sequences rolled forward from the newest telemetry with ensemble
        confidence bounds (`twin/scenario.py`).  Under deadline pressure
        the degradation ladder may deterministically shrink K or refuse
        with `ScenarioRefused`.
      * `snapshot_state` returns a host pytree sufficient to rebuild the
        serving state (per-shard sub-trees for multi-shard services).
      * `close` releases background threads/processes; idempotent.
    """

    def register(self, twin_id: int) -> Any: ...

    def ingest(self, twin_id: int, y, u=None, *,
               force: bool = False) -> None: ...

    def ingest_many(self, batch: Iterable[IngestChunkLike], *,
                    force: bool = False) -> int: ...

    def deploy(self, twin_id: int, theta) -> None: ...

    def deploy_many(self, twin_ids, thetas) -> None: ...

    def tick(self) -> Any: ...

    def drain(self) -> None: ...

    def predict(self, twin_id: int, horizon: int, us=None): ...

    def scenario(self, twin_id: int, horizon: int, us=None,
                 k: int | None = None): ...

    def snapshot_state(self) -> dict: ...

    def latency_summary(self) -> dict: ...

    def stage_summary(self) -> dict: ...

    def reset_latency_stats(self) -> None: ...

    def close(self) -> None: ...


_PROTOCOL_METHODS = tuple(
    name for name in vars(TwinService)
    if not name.startswith("_") and callable(getattr(TwinService, name)))


def conforms(obj) -> list[str]:
    """Names from the `TwinService` surface that `obj` is missing (empty
    list = structurally conformant).  Runtime `isinstance` checks only see
    attribute presence; tests use this for a readable diff."""
    return [name for name in _PROTOCOL_METHODS
            if not callable(getattr(obj, name, None))]


# --------------------------------------------------------------------------- #
# shared config bases
# --------------------------------------------------------------------------- #
@dataclass(frozen=True, kw_only=True)
class DeadlineConfig:
    """The mission refresh budget, declared once.

    `deadline_s` is SECONDS; the 1.0 s default is the paper's margin — 5x
    under the 5 s human-pilot reaction time.  `TwinServerConfig` inherits it
    directly; fleet configs (`ShardedTwinConfig`, `FederatedTwinConfig`)
    override the default to None, meaning "derive the tightest per-shard
    deadline" — set it explicitly to gate the WHOLE fleet tick instead.
    """
    deadline_s: float = 1.0


@dataclass(frozen=True, kw_only=True)
class FleetTopologyConfig(DeadlineConfig):
    """Fleet-shape knobs shared by in-process sharding and multi-process
    federation.  One definition, two deployment shapes — `ShardedTwinConfig`
    and `FederatedTwinConfig` both extend this, so the slot-budget /
    rebalance / recovery surface cannot drift between them."""
    deadline_s: float | None = field(default=None, kw_only=True)
    total_slots: int | None = None    # global active-refit budget
                                      # (None: sum of physical pools —
                                      # federation never constrains)
    min_shard_slots: int = 1          # per-shard grant floor
    rebalance_every: int = 4          # federation period (ticks)
    pressure_smooth: float = 0.5      # EMA on the pressure signal
    recovery: RecoveryConfig | None = None
                                      # per-shard checkpointing + journal +
                                      # supervised restart (twin/recovery.py)
    chaos: ChaosConfig | None = None  # injected failure schedule (tests/
                                      # benchmarks; None in production)

    def make_federation(self, pools: list[int]) -> "FederationConfig":
        """The scheduler-level federation for this topology's physical slot
        pools — the one place the config names map onto
        `FederationConfig`'s."""
        total = sum(pools) if self.total_slots is None else self.total_slots
        return FederationConfig(total_slots=total,
                                min_shard_slots=self.min_shard_slots,
                                pressure_smooth=self.pressure_smooth)
