"""Packed fleet-state arrays: the scheduler's device-scored data layout.

Up to PR 6 the dict of `TwinRecord`s was the source of truth for everything
the scheduler reads — samples, deploy watermark, divergence, residency — and
`RefitScheduler.plan()` re-derived priorities by iterating (and sorting) the
whole dict in Python every tick.  Fine at 10k twins, fatal at the ROADMAP's
100k-1M target.

This module flips the layout: **packed, row-indexed numpy arrays are the
truth** and the record dict is metadata (ids, slot assignments, tick stamps).
Every mutation point in the server (flush accounting, deploy, guard fold,
plan application) writes the packed arrays; the scheduler scores the WHOLE
fleet in one fused, jit-compiled device call (`fleet_scores`) that returns
only O(slots) winners, the waiting-queue depth, and the federation pressure
reduction — so per-tick host work is O(budget), not O(twins).

Rows are `TwinRecord.ring_slot` (the TelemetryRing row), so the guard's
by-row divergence array, the rotation's live set, and the scheduler's score
arrays all share one indexing scheme.

Precision contract: the device kernel scores in float32 (it only has to
RANK candidates — `jax.lax.top_k` ties break toward the lower row index);
the host re-scores the returned O(slots) candidates in float64 with exactly
the reference planner's arithmetic, so every admission/eviction COMPARISON
in `PackedRefitScheduler.plan` is bit-identical to `RefitScheduler.plan`.
The only divergence window is a float32 ranking swap across the top-k
cutoff between candidates whose float64 priorities differ by less than
float32 resolution — semantically a coin-flip tie.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["PackedFleet", "fleet_scores", "fleet_pressure"]


def _pad_capacity(n: int, floor: int = 64) -> int:
    """Round a row capacity up to a pow2 bucket (bounds jit recompiles when
    tests/tools build many small fleets; servers pass their exact, fixed
    `max_twins` and compile once per topology)."""
    cap = floor
    while cap < n:
        cap *= 2
    return cap


class PackedFleet:
    """Row-indexed scheduler-state arrays for one shard's tracked fleet.

    All arrays have length `capacity` (= the server's `max_twins`); a row is
    live once `registered[row]` is True.  Sample counters are int32 — the
    fused call's native dtype, exact in float64 host re-scoring, and good
    for 8 years of serving at 8 samples/s — so the per-tick device call
    reads the columns without a conversion pass.  `divergence` (float64) is
    the guard's exact truth for host re-scoring; `div32` is its float32
    shadow for the device kernel, written at the same mutation points
    (guard fold, promote) — `check_mirrors` asserts they never drift.

    Thread-safety matches the server's registry: `register` may be called
    from ingest threads (the server holds its registration lock and sets
    `registered` LAST, so a concurrently-planning tick sees either a fully
    initialized row or an unready one); every other field is written only by
    the serving thread.
    """

    __slots__ = ("capacity", "twin_id", "registered", "samples",
                 "samples_at_deploy", "deployed", "divergence", "div32",
                 "resident", "residency")

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.twin_id = np.full((capacity,), -1, np.int64)
        self.registered = np.zeros((capacity,), bool)
        self.samples = np.zeros((capacity,), np.int32)
        self.samples_at_deploy = np.zeros((capacity,), np.int32)
        self.deployed = np.zeros((capacity,), bool)
        self.divergence = np.zeros((capacity,), np.float64)
        self.div32 = np.zeros((capacity,), np.float32)
        self.resident = np.zeros((capacity,), bool)
        self.residency = np.zeros((capacity,), np.int64)

    def set_divergence(self, rows, values) -> None:
        """Write divergence truth + its float32 device shadow together —
        the only sanctioned way to move the divergence column."""
        self.divergence[rows] = values
        self.div32[rows] = self.divergence[rows]

    def check_mirrors(self) -> None:
        """Assert the float32 shadow matches the float64 truth (tests)."""
        if not np.array_equal(self.div32,
                              self.divergence.astype(np.float32)):
            raise AssertionError("div32 shadow drifted from divergence")

    # ------------------------------------------------------------------ #
    _COLUMNS = ("twin_id", "registered", "samples", "samples_at_deploy",
                "deployed", "divergence", "div32", "resident", "residency")

    def snapshot(self) -> dict:
        """Copy every column into a plain dict of numpy arrays — the
        checkpointable packed-fleet state (twin/recovery.py).  COPIES, not
        views: the async checkpoint writer must not race the serving
        thread's in-place column mutations."""
        return {c: getattr(self, c).copy() for c in self._COLUMNS}

    def load(self, state: dict) -> None:
        """Restore columns IN PLACE from a `snapshot()` dict.  In-place
        (`[:]`) because the server's `_div` aliases `divergence` — rebinding
        the array would silently sever the guard→scheduler data path."""
        for c in self._COLUMNS:
            col = getattr(self, c)
            src = np.asarray(state[c])
            if src.shape != col.shape:
                raise ValueError(f"packed column {c!r}: snapshot shape "
                                 f"{src.shape} != live shape {col.shape}")
            col[:] = src

    # ------------------------------------------------------------------ #
    def register(self, row: int, twin_id: int) -> None:
        """Bind a row to a twin id.  `registered` is set last — see class
        docstring for the concurrent-plan visibility argument."""
        self.twin_id[row] = twin_id
        self.registered[row] = True

    # ------------------------------------------------------------------ #
    @classmethod
    def from_records(cls, twins: dict, *, capacity: int | None = None
                     ) -> "PackedFleet":
        """Build packed arrays from a `TwinRecord` dict (rows =
        `ring_slot`).  The reference-planner interop path: equivalence
        tests feed the same record dict to both planners."""
        max_row = max((r.ring_slot for r in twins.values()), default=-1)
        cap = (_pad_capacity(max_row + 1) if capacity is None else capacity)
        if max_row >= cap:
            raise ValueError(f"ring_slot {max_row} exceeds capacity {cap}")
        fleet = cls(cap)
        seen_rows: set[int] = set()
        for rec in twins.values():
            if rec.ring_slot in seen_rows:
                raise ValueError(f"duplicate ring_slot {rec.ring_slot}")
            seen_rows.add(rec.ring_slot)
            row = rec.ring_slot
            fleet.twin_id[row] = rec.twin_id
            fleet.samples[row] = rec.samples
            fleet.samples_at_deploy[row] = rec.samples_at_deploy
            fleet.deployed[row] = rec.deployed
            fleet.divergence[row] = rec.divergence
            fleet.div32[row] = fleet.divergence[row]
            fleet.resident[row] = rec.refit_slot is not None
            fleet.residency[row] = rec.residency
            fleet.registered[row] = True
        return fleet

    def slot_rows_from_records(self, twins: dict, slots: int) -> np.ndarray:
        """[slots] array of resident ring rows (`capacity` marks an empty
        slot — the same scratch-row convention as the server's slot ring)."""
        slot_rows = np.full((slots,), self.capacity, np.int64)
        for rec in twins.values():
            if rec.refit_slot is None:
                continue
            if not 0 <= rec.refit_slot < slots:
                raise ValueError(f"refit_slot {rec.refit_slot} out of range")
            if slot_rows[rec.refit_slot] != self.capacity:
                raise ValueError(f"slot {rec.refit_slot} doubly occupied")
            slot_rows[rec.refit_slot] = rec.ring_slot
        return slot_rows


# --------------------------------------------------------------------------- #
# the fused scoring kernel: one jit-compiled call over the whole fleet
# --------------------------------------------------------------------------- #
@partial(jax.jit, static_argnames=("k",))
def _fleet_scores(samples, at_deploy, deployed, divergence, resident,
                  registered, min_samples, sw, dw, k: int):
    """Score every row and reduce to what the host actually needs.

        priority = sw * (staleness + never_deployed) + dw * divergence
        staleness = (samples - samples_at_deploy) / max(min_samples, 1)

    Returns (cand_rows [k], cand_prio [k], n_waiting [], pressure []):
    the top-k READY, UNSLOTTED rows by priority (ties toward the lower row
    index — `lax.top_k` is stable), the waiting-queue depth, and the summed
    priority over all ready rows (the federation pressure signal).  k =
    the slot-pool size is sufficient for exact planning: one tick can
    admit at most `slots` twins (fill + evict combined), so every waiting
    twin the reference planner could touch is inside the top-k.
    """
    stale = (samples - at_deploy).astype(jnp.float32) / jnp.maximum(
        min_samples, 1).astype(jnp.float32)
    stale = stale + jnp.where(deployed, 0.0, 1.0)
    prio = sw * stale + dw * divergence
    ready = registered & (samples >= min_samples)
    pressure = jnp.sum(jnp.where(ready, prio, 0.0))
    waiting = ready & ~resident
    n_waiting = jnp.sum(waiting)
    cand_prio, cand_rows = jax.lax.top_k(
        jnp.where(waiting, prio, -jnp.inf), k)
    return cand_rows, cand_prio, n_waiting, pressure


def _device_operands(fleet: PackedFleet):
    # zero-copy: every column is already in the kernel's dtype (int32
    # counters, float32 divergence shadow) — no O(n) conversion pass on the
    # serving tick's hot path
    return (fleet.samples, fleet.samples_at_deploy, fleet.deployed,
            fleet.div32, fleet.resident, fleet.registered)


def fleet_scores(fleet: PackedFleet, *, min_samples: int, sw: float,
                 dw: float, k: int):
    """Host wrapper: returns (cand_rows, cand_prio, n_waiting, pressure)
    as numpy/python values.  Rows whose cand_prio is -inf are padding
    (fewer than k twins waiting) — callers must drop them."""
    k = max(1, min(k, fleet.capacity))
    cand_rows, cand_prio, n_waiting, pressure = _fleet_scores(
        *_device_operands(fleet), np.int32(min_samples), np.float32(sw),
        np.float32(dw), k)
    return (np.asarray(cand_rows), np.asarray(cand_prio),
            int(n_waiting), float(pressure))


@jax.jit
def _fleet_pressure(samples, at_deploy, deployed, divergence, resident,
                    registered, min_samples, sw, dw):
    stale = (samples - at_deploy).astype(jnp.float32) / jnp.maximum(
        min_samples, 1).astype(jnp.float32)
    stale = stale + jnp.where(deployed, 0.0, 1.0)
    prio = sw * stale + dw * divergence
    ready = registered & (samples >= min_samples)
    return jnp.sum(jnp.where(ready, prio, 0.0))


def fleet_pressure(fleet: PackedFleet, *, min_samples: int, sw: float,
                   dw: float) -> float:
    """Aggregate refit demand as one fused device reduction — the number
    `SlotFederation.rebalance` consumes, without an O(twins) host scan."""
    return float(_fleet_pressure(
        *_device_operands(fleet), np.int32(min_samples), np.float32(sw),
        np.float32(dw)))
