"""Fault tolerance for the twin serving stack: checkpoint, failover, degrade.

The paper's setting is MISSION CRITICAL — collision-avoidance twins that must
keep answering inside a hard deadline.  Three failure classes are covered
here, each with its own mechanism and its own metric family:

  * **Crash** (a shard process dies): `TwinCheckpointer` snapshots each
    shard's full serving state — theta store, telemetry rings, fleet train
    state, packed scheduler columns, guard state — on a configurable cadence,
    reusing `train/checkpoint.py`'s atomic COMMIT directory layout (a torn
    write is invisible to `latest_step`).  The snapshot is taken on the tick
    thread (host copies, cheap); the `.npy` writes run on a background thread
    so checkpointing stays off the serving deadline (`twin_ckpt_*`).
    The supervisor (`ShardedTwinServer`) rebuilds a dead shard from its last
    committed checkpoint and REPLAYS the suffix of its `TelemetryJournal`,
    so every sample ingested inside the journal horizon survives the crash —
    guard events re-derived after replay match an uninterrupted run
    (tests/test_twin_recovery.py).

  * **Overload** (ticks approaching the deadline): `DegradationPolicy`
    watches tick wall time (EWMA via `StragglerDetector` + the instantaneous
    tick, so a SUSTAINED overload registers even though the detector's EWMA
    excludes outliers) and sheds work through a fixed ladder —
    level 1 shrinks the guard budget, level 2 defers refit train steps,
    level 3 skips shadow-eval promotion — restoring level by level once
    pressure clears (`twin_degraded_*`).  Ingest backpressure is the same
    story at the producer boundary: a bounded `StagingBuffer` raises
    `StagingOverflow`, and `TwinServer.ingest` retries with backoff then
    (non-strict mode) sheds the OLDEST staged samples instead of failing.

  * **Injected chaos** (tests/benchmarks): `ChaosConfig` extends
    `FailureInjector`/`SimulatedPreemption` into the knobs the sharded
    server accepts — kill-shard-at-tick, slow-shard straggler windows,
    torn-checkpoint, and staging-overflow storms — so every recovery path
    above is exercised deterministically in CI (`pytest -m chaos`,
    `benchmarks/run.py --chaos`).

Nothing here imports twin/server.py or twin/sharded.py — the servers import
THIS module and hand it callables/state, so the dependency points one way.
"""
from __future__ import annotations

import shutil
import threading
import time
from collections import deque
from dataclasses import dataclass
from pathlib import Path

import jax
import numpy as np

from repro.distributed.fault_tolerance import (FailureInjector,
                                               SimulatedPreemption,
                                               StragglerDetector)
from repro.obs import MetricRegistry
from repro.train import checkpoint

__all__ = ["RecoveryConfig", "TwinCheckpointer", "TelemetryJournal",
           "ChaosConfig", "ChaosInjector", "ShardFailure",
           "DegradationConfig", "DegradationPolicy", "DegradationEvent"]


# --------------------------------------------------------------------------- #
# per-shard checkpointing
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class RecoveryConfig:
    """Checkpoint + failover knobs for a sharded server.

    `ckpt_every` is in SHARD ticks (each shard checkpoints on its own tick
    counter, so a restarted shard resumes its own cadence).  `keep` commits
    are retained per shard — at least 2, so a torn newest write always has a
    committed predecessor to fall back to.  `journal_horizon` bounds the
    supervisor-side telemetry journal per twin (None: the shard's ring
    capacity — the ring horizon IS the replay guarantee boundary).
    """
    ckpt_dir: str
    ckpt_every: int = 16
    keep: int = 2
    async_write: bool = True
    restart_delay_ticks: int = 1      # supervisor ticks a shard stays down
    journal_horizon: int | None = None

    def __post_init__(self):
        if self.ckpt_every < 1:
            raise ValueError("ckpt_every must be >= 1")
        if self.keep < 2:
            raise ValueError("keep must be >= 2 (torn-write fallback needs "
                             "a committed predecessor)")


class TwinCheckpointer:
    """Atomic per-shard serving-state checkpoints, written off the tick loop.

    Layout: `ckpt_dir/shard_<i>/step_<tick>/{manifest.json, leaf_*.npy,
    COMMIT}` — `train/checkpoint.py`'s format verbatim, so atomicity
    (`latest_step` ignores torn dirs) and the bit-exact round-trip are the
    properties that module's tests already pin.

    `maybe_save` takes the snapshot SYNCHRONOUSLY on the caller's thread
    (the serving tick — the snapshot must not race in-place column writes;
    `TwinServer.snapshot_state` returns copies) and hands the host tree to a
    background writer thread.  One writer per shard at a time; a new save
    joins the previous one first (same discipline as `CheckpointManager`).
    """

    def __init__(self, cfg: RecoveryConfig,
                 metrics: MetricRegistry | None = None):
        self.cfg = cfg
        self.dir = Path(cfg.ckpt_dir)
        self.metrics = MetricRegistry() if metrics is None else metrics
        self._pending: dict[int, threading.Thread] = {}
        M = self.metrics
        self._m_saves = M.counter(
            "twin_ckpt_saves_total",
            help="shard serving-state checkpoints committed (or handed to "
                 "the background writer)")
        self._m_snapshot = M.histogram(
            "twin_ckpt_snapshot_seconds",
            help="on-tick host snapshot latency (the serving-path cost of a "
                 "checkpoint; the .npy write is off-path)", unit="seconds")
        self._m_write = M.histogram(
            "twin_ckpt_write_seconds",
            help="background checkpoint write+GC latency", unit="seconds")
        self._m_restores = M.counter(
            "twin_ckpt_restores_total",
            help="shard restores from a committed checkpoint")
        self._m_torn = M.counter(
            "twin_ckpt_torn_total",
            help="checkpoints torn by chaos injection (COMMIT removed)")
        self._m_last: dict[int, object] = {}       # shard -> Gauge

    def shard_dir(self, shard: int) -> Path:
        return self.dir / f"shard_{shard:03d}"

    def _last_gauge(self, shard: int):
        g = self._m_last.get(shard)
        if g is None:
            g = self.metrics.gauge(
                "twin_ckpt_last_tick",
                help="shard tick of the newest checkpoint handed to the "
                     "writer", labels={"shard": str(shard)})
            self._m_last[shard] = g
        return g

    # ------------------------------------------------------------------ #
    def maybe_save(self, shard: int, tick: int, snapshot_fn,
                   force: bool = False) -> bool:
        """Checkpoint shard `shard` if its tick hits the cadence.

        `snapshot_fn()` must return a host pytree of numpy arrays that the
        background writer may read without racing the serving thread (i.e.
        copies — `TwinServer.snapshot_state`)."""
        if not force and (tick % self.cfg.ckpt_every != 0 or tick == 0):
            return False
        prev = self._pending.get(shard)
        if prev is not None:
            prev.join()
        t0 = time.perf_counter()
        host_tree = jax.tree.map(np.asarray, jax.device_get(snapshot_fn()))
        self._m_snapshot.observe(time.perf_counter() - t0)
        d = self.shard_dir(shard)

        def write_then_gc():
            t1 = time.perf_counter()
            checkpoint._write(d, tick, host_tree)
            self._gc(shard)
            self._m_write.observe(time.perf_counter() - t1)

        if self.cfg.async_write:
            t = threading.Thread(target=write_then_gc, daemon=True)
            t.start()
            self._pending[shard] = t
        else:
            write_then_gc()
        self._m_saves.inc()
        self._last_gauge(shard).set(tick)
        return True

    def _gc(self, shard: int) -> None:
        steps = sorted(p for p in self.shard_dir(shard).glob("step_*")
                       if (p / "COMMIT").exists())
        for p in steps[:-self.cfg.keep]:
            shutil.rmtree(p, ignore_errors=True)

    # ------------------------------------------------------------------ #
    def wait(self, shard: int | None = None) -> None:
        """Join outstanding writer threads (all shards when `shard` is
        None) — the flush barrier before reading `latest`/restoring."""
        items = (list(self._pending.items()) if shard is None
                 else [(shard, self._pending.get(shard))])
        for s, t in items:
            if t is not None:
                t.join()
                self._pending.pop(s, None)

    def latest(self, shard: int) -> int | None:
        """Newest COMMITTED shard tick (torn checkpoints invisible)."""
        self.wait(shard)
        return checkpoint.latest_step(self.shard_dir(shard))

    def restore_latest(self, shard: int, like):
        """(tick, state) from the newest committed checkpoint, or
        (None, None) when the shard has never committed one.  `like` is a
        fresh server's `snapshot_state()` — fixed shapes by config, so a
        mismatched restore raises `ValueError` instead of corrupting."""
        step = self.latest(shard)
        if step is None:
            return None, None
        state = checkpoint.restore(self.shard_dir(shard), step, like)
        self._m_restores.inc()
        return step, state

    def tear_latest(self, shard: int) -> int | None:
        """Chaos: remove the COMMIT marker from the newest checkpoint —
        simulates a crash mid-write.  `latest`/`restore_latest` must then
        fall back to the previous committed step.  Returns the torn tick."""
        step = self.latest(shard)
        if step is None:
            return None
        (self.shard_dir(shard) / f"step_{step:08d}" / "COMMIT").unlink()
        self._m_torn.inc()
        return step


# --------------------------------------------------------------------------- #
# supervisor-side telemetry journal (the replay source)
# --------------------------------------------------------------------------- #
class TelemetryJournal:
    """Bounded per-twin journal of ingested telemetry chunks.

    Lives with the SUPERVISOR, not the shard: it must survive the shard's
    death.  Every `ShardedTwinServer.ingest` appends here before routing to
    the shard, so after a crash the journal holds the suffix of samples the
    restored checkpoint has not seen — `replay_since(twin, seen)` returns
    exactly those chunks (trimming the first chunk when `seen` falls inside
    it) plus a `lost` count for samples already evicted past the horizon.

    The horizon is per twin in SAMPLES (normally the shard's ring capacity):
    anything older would have been overwritten in the ring anyway, so the
    journal's memory bound matches the recovery guarantee — no sample inside
    the ring horizon is lost to a crash.

    Thread-safe: sensor threads append concurrently; replay runs on the
    serving thread.
    """

    def __init__(self, horizon: int):
        if horizon < 1:
            raise ValueError("journal horizon must be >= 1 sample")
        self.horizon = horizon
        self._lock = threading.Lock()
        # twin_id -> deque of (start_index, y [C,n], u [C,m] | None)
        self._chunks: dict[int, deque] = {}
        self._total: dict[int, int] = {}
        self.appended_samples = 0

    def append(self, twin_id: int, y, u=None) -> int:
        """Journal one chunk (same y/u shapes `TwinServer.ingest` takes).
        Copies — the caller may reuse its buffers.  Returns the chunk
        length in samples."""
        y = np.atleast_2d(np.asarray(y, np.float32)).copy()
        u = None if u is None else np.asarray(u, np.float32).copy()
        C = len(y)
        with self._lock:
            total = self._total.get(twin_id, 0)
            dq = self._chunks.setdefault(twin_id, deque())
            dq.append((total, y, u))
            total += C
            self._total[twin_id] = total
            # evict whole chunks that fell entirely past the horizon
            while dq and dq[0][0] + len(dq[0][1]) <= total - self.horizon:
                dq.popleft()
            self.appended_samples += C
        return C

    def twin_ids(self) -> list[int]:
        with self._lock:
            return list(self._total)

    def total(self, twin_id: int) -> int:
        with self._lock:
            return self._total.get(twin_id, 0)

    def replay_since(self, twin_id: int, seen: int):
        """Chunks covering samples [seen, total) for `twin_id`.

        Returns (chunks, lost): `chunks` is a list of (y, u) in
        chronological order (u may be None), `lost` counts samples in
        [seen, total) already evicted past the horizon — those are
        unrecoverable and the caller must surface them
        (`twin_replay_lost_samples_total`)."""
        out: list = []
        with self._lock:
            total = self._total.get(twin_id, 0)
            need = total - seen
            if need <= 0:
                return [], 0
            covered_from = None
            for start, y, u in self._chunks.get(twin_id, ()):
                if start + len(y) <= seen:
                    continue
                if covered_from is None:
                    covered_from = start
                skip = max(0, seen - start)
                out.append((y[skip:],
                            None if u is None else u[skip:]))
            if covered_from is None:
                return [], need
            lost = max(0, covered_from - seen)
        return out, lost


# --------------------------------------------------------------------------- #
# chaos injection (the deterministic failure schedule tests/benchmarks drive)
# --------------------------------------------------------------------------- #
class ShardFailure(SimulatedPreemption):
    """Injected death of one serving shard (supervisor catches + restarts)."""

    def __init__(self, shard: int, tick: int):
        super().__init__(f"injected shard {shard} failure at tick {tick}")
        self.shard = shard
        self.tick = tick


@dataclass(frozen=True)
class ChaosConfig:
    """Deterministic failure schedule a `ShardedTwinServer` accepts.

    Knobs (all independent; combine with care — a storm before a kill makes
    the journal and the shard's sample counts diverge by design):

      * kill_shard/kill_at_tick — shard dies instead of ticking once the
        supervisor tick reaches `kill_at_tick` (`>=` semantics via
        `FailureInjector`, so schedules survive skipped tick numbers).
      * torn_checkpoint — the killed shard's newest checkpoint loses its
        COMMIT marker (crash mid-write); restore must fall back.
      * slow_shard + slow_s over [slow_from_tick, slow_until_tick) — an
        injected straggler: the shard sleeps `slow_s` INSIDE its timed tick
        (`TwinServer.inject_delay_s`), so its own degradation policy sees
        the stall and climbs the shedding ladder.
      * storm_shard + storm_factor over [storm_from_tick, storm_until_tick)
        — every ingest routed to that shard is duplicated `storm_factor`x
        (journal and shard alike), a staging-overflow storm exercising the
        bounded-buffer retry/drop-oldest path.
    """
    kill_shard: int | None = None
    kill_at_tick: int = 1
    torn_checkpoint: bool = False
    slow_shard: int | None = None
    slow_s: float = 0.0
    slow_from_tick: int = 0
    slow_until_tick: int = 1 << 31
    storm_shard: int | None = None
    storm_factor: int = 1
    storm_from_tick: int = 0
    storm_until_tick: int = 1 << 31


class ChaosInjector:
    """Mutable driver for a `ChaosConfig` schedule (one-shot kill/tear)."""

    def __init__(self, cfg: ChaosConfig):
        self.cfg = cfg
        self._kill = FailureInjector(
            fail_at_step=(cfg.kill_at_tick if cfg.kill_shard is not None
                          else None))
        self._torn = False

    def should_kill(self, shard: int, tick: int) -> bool:
        """True exactly once, for the configured shard, at (or after —
        `FailureInjector`'s `>=` contract) the configured tick."""
        if self.cfg.kill_shard is None or shard != self.cfg.kill_shard:
            return False
        try:
            self._kill.maybe_fail(tick)
        except SimulatedPreemption:
            return True
        return False

    def should_tear(self) -> bool:
        """True once, at kill time, when torn_checkpoint is scheduled."""
        if not self.cfg.torn_checkpoint or self._torn:
            return False
        self._torn = True
        return True

    def slow_delay(self, shard: int, tick: int) -> float:
        c = self.cfg
        if (c.slow_shard == shard
                and c.slow_from_tick <= tick < c.slow_until_tick):
            return c.slow_s
        return 0.0

    def storm_extra(self, shard: int, tick: int) -> int:
        """Extra duplicate ingests for this shard at this tick (0 = none)."""
        c = self.cfg
        if (c.storm_shard == shard
                and c.storm_from_tick <= tick < c.storm_until_tick):
            return max(0, c.storm_factor - 1)
        return 0


# --------------------------------------------------------------------------- #
# deadline-aware graceful degradation
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class DegradationConfig:
    """Shed-work ladder for ticks approaching the deadline.

    Pressure = max(EWMA tick time, last tick time) / deadline — the max with
    the instantaneous tick matters because `StragglerDetector` EXCLUDES
    flagged outliers from its EWMA (so one straggler doesn't mask the next),
    which means a sustained overload would never move the EWMA alone.

    The ladder (each level includes the ones below, restored in reverse):
      level 1: shrink the guard budget by `guard_shrink`x (rotation mode)
               or score only every other tick (full-scan mode),
      level 2: defer refit train steps (slots hold; already-converged
               candidates may still promote),
      level 3: skip shadow-eval promotion too — the tick is down to flush +
               reduced guard + scheduling bookkeeping.

    Escalation needs pressure > `high_water`, de-escalation pressure <
    `low_water`, each at most once per `hold_ticks` (hysteresis — the
    ladder must not flap on one noisy tick).
    """
    enabled: bool = False
    high_water: float = 0.8
    low_water: float = 0.5
    alpha: float = 0.3               # EWMA weight of the newest tick
    hold_ticks: int = 2
    guard_shrink: int = 4
    max_level: int = 3


@dataclass(frozen=True)
class DegradationEvent:
    tick: int
    from_level: int
    to_level: int
    pressure: float


class DegradationPolicy:
    """Per-server degradation state machine; see `DegradationConfig`.

    `observe(tick, dt_s)` AFTER each tick updates pressure and moves the
    ladder at most one level; the `shed_guard`/`defer_refit`/`skip_promote`
    properties are what the NEXT tick consults.  Wraps a
    `StragglerDetector` so injected/organic stragglers are also counted
    (`straggler_events`)."""

    def __init__(self, cfg: DegradationConfig, deadline_s: float):
        self.cfg = cfg
        self.deadline_s = deadline_s
        self.detector = StragglerDetector(alpha=cfg.alpha)
        self.level = 0
        self.pressure = 0.0
        self._last_change = -(1 << 30)

    def reset(self) -> None:
        """Forget pressure history and restore full service — benchmarks
        call this (via `reset_latency_stats`) after jit warmup so compile
        stalls don't count as overload."""
        self.detector = StragglerDetector(alpha=self.cfg.alpha)
        self.level = 0
        self.pressure = 0.0
        self._last_change = -(1 << 30)

    @property
    def shed_guard(self) -> bool:
        return self.level >= 1

    @property
    def defer_refit(self) -> bool:
        return self.level >= 2

    @property
    def skip_promote(self) -> bool:
        return self.level >= 3

    @property
    def straggler_events(self) -> int:
        return len(self.detector.events)

    def observe(self, tick: int, dt_s: float) -> DegradationEvent | None:
        """Fold one tick's wall time; returns the ladder transition (if
        any).  Call even when disabled — pressure stays observable."""
        self.detector.observe(tick, dt_s)
        ewma = self.detector.ewma_s if self.detector.ewma_s is not None \
            else dt_s
        self.pressure = max(ewma, dt_s) / max(self.deadline_s, 1e-9)
        cfg = self.cfg
        if not cfg.enabled or tick - self._last_change < cfg.hold_ticks:
            return None
        if self.pressure > cfg.high_water and self.level < cfg.max_level:
            ev = DegradationEvent(tick, self.level, self.level + 1,
                                  self.pressure)
        elif self.pressure < cfg.low_water and self.level > 0:
            ev = DegradationEvent(tick, self.level, self.level - 1,
                                  self.pressure)
        else:
            return None
        self.level = ev.to_level
        self._last_change = tick
        return ev
