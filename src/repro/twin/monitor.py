"""Divergence guard: does the deployed twin still match reality?

The paper's safety case (mid-air collision avoidance) rests on the deployed
model staying faithful to the physical system it shadows.  The guard closes
that loop: every serving tick it RK4-rolls each deployed theta forward over
the NEWEST telemetry window (same integrator the twin was recovered with —
kernels/rk4) and scores the normalized rollout error against what the sensors
actually reported.

    score = mean((SOLVE(y_0, theta, U) - Y)^2) / (var(Y) + eps)

Variance normalization makes one threshold meaningful across systems with
wildly different state magnitudes (F-8 angle-of-attack radians vs Lorenz
tens).  A diverged model frequently goes unstable under rollout; non-finite
errors are clamped to a large finite score so the guard fires instead of
propagating NaNs.

Host-side hysteresis (`judge`) turns scores into events:
  * score > refit_threshold  -> REFIT  (scheduler priority boost: the twin's
    physics drifted — re-recover it)
  * score > alert_threshold  -> ALERT  (the model is too wrong to trust for
    prediction — the collision-avoidance abort signal)

Scores are EMA-smoothed so a single noisy window does not flap the scheduler.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.rk4.ops import rk4_poly_solve

__all__ = ["GuardConfig", "GuardEvent", "DivergenceGuard"]

_BLOWUP_SCORE = 1e6     # score assigned to non-finite (unstable) rollouts


@dataclass(frozen=True)
class GuardConfig:
    window: int = 32                 # telemetry steps rolled per check
    refit_threshold: float = 0.1
    alert_threshold: float = 1.0
    ema: float = 0.5                 # new-score weight in the EMA


@dataclass(frozen=True)
class GuardEvent:
    twin_id: int
    kind: str        # "REFIT" | "ALERT"
    score: float
    tick: int


class DivergenceGuard:
    def __init__(self, library, dt: float, cfg: GuardConfig = GuardConfig(),
                 *, use_pallas: bool = False, interpret: bool = True):
        self.lib = library
        self.dt = dt
        self.cfg = cfg
        self.use_pallas = use_pallas
        self.interpret = interpret

    # ------------------------------------------------------------------ #
    @partial(jax.jit, static_argnames=("self",))
    def score(self, theta, ys, us):
        """Normalized rollout error per twin (fused over the whole store).

        theta: [B, n, L]; ys: [B, k+1, n] newest telemetry; us: [B, k, m].
        Returns [B] float32 — finite even when the rollout diverges.
        """
        y_est = rk4_poly_solve(theta, ys[:, 0, :], us, dt=self.dt,
                               library=self.lib, use_pallas=self.use_pallas,
                               interpret=self.interpret)
        num = jnp.mean(jnp.square(y_est - ys), axis=(1, 2))
        den = jnp.mean(jnp.square(ys - jnp.mean(ys, axis=1, keepdims=True)),
                       axis=(1, 2)) + 1e-6
        return jnp.nan_to_num(num / den, nan=_BLOWUP_SCORE,
                              posinf=_BLOWUP_SCORE)

    # ------------------------------------------------------------------ #
    def smooth(self, prev: float, score: float) -> float:
        """EMA update used by the server when folding scores into records."""
        a = self.cfg.ema
        return a * min(float(score), _BLOWUP_SCORE) + (1.0 - a) * prev

    def judge(self, twin_id: int, score: float, tick: int) -> GuardEvent | None:
        """Threshold an (already smoothed) score into an event, or None."""
        if score > self.cfg.alert_threshold:
            return GuardEvent(twin_id, "ALERT", float(score), tick)
        if score > self.cfg.refit_threshold:
            return GuardEvent(twin_id, "REFIT", float(score), tick)
        return None
