"""Divergence guard: does the deployed twin still match reality?

The paper's safety case (mid-air collision avoidance) rests on the deployed
model staying faithful to the physical system it shadows.  The guard closes
that loop: every serving tick it RK4-rolls each deployed theta forward over
the NEWEST telemetry window (same integrator the twin was recovered with —
kernels/rk4) and scores the normalized rollout error against what the sensors
actually reported.

    score = mean((SOLVE(y_0, theta, U) - Y)^2) / (var(Y) + eps)

Variance normalization makes one threshold meaningful across systems with
wildly different state magnitudes (F-8 angle-of-attack radians vs Lorenz
tens).  A diverged model frequently goes unstable under rollout; non-finite
errors are clamped to a large finite score so the guard fires instead of
propagating NaNs.

Host-side hysteresis (`judge`) turns scores into events:
  * score > refit_threshold  -> REFIT  (scheduler priority boost: the twin's
    physics drifted — re-recover it)
  * score > alert_threshold  -> ALERT  (the model is too wrong to trust for
    prediction — the collision-avoidance abort signal)

Scores are EMA-smoothed so a single noisy window does not flap the scheduler.

At 10k+ tracked objects, rolling EVERY deployed theta per tick makes the
guard the serving bottleneck — `GuardRotation` bounds it: each tick scores a
fixed-size subset (budgeted round-robin over the store, plus a carry-over
quota that re-scores the currently most-diverged twins every tick), so guard
cost is O(budget) instead of O(twins) while every twin is still guaranteed a
score within ceil(twins / budget) ticks.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import shard
from repro.kernels.rk4.ops import rk4_poly_solve
from repro.obs.registry import DEFAULT_SCORE_BUCKETS

__all__ = ["GuardConfig", "GuardEvent", "GuardInstruments", "DivergenceGuard",
           "GuardRotation", "score_confidence"]

_BLOWUP_SCORE = 1e6     # score assigned to non-finite (unstable) rollouts


@dataclass(frozen=True)
class GuardConfig:
    window: int = 32                 # telemetry steps rolled per check
    refit_threshold: float = 0.1
    alert_threshold: float = 1.0
    ema: float = 0.5                 # new-score weight in the EMA


def score_confidence(score: float) -> float:
    """Map a normalized divergence score to a confidence in (0, 1].

    The guard's score is already a scale-free ratio (rollout error over
    telemetry variance), so `1 / (1 + score)` gives a dimensionless trust
    weight: ~1 while the model tracks, ~0 for a blown-up rollout.  The
    same squash the scenario engine applies to its ensemble spread
    (twin/scenario.py), so ALERT confidence and what-if confidence are
    directly comparable on one dashboard axis.
    """
    return 1.0 / (1.0 + max(float(score), 0.0))


@dataclass(frozen=True)
class GuardEvent:
    twin_id: int
    kind: str        # "REFIT" | "ALERT"
    score: float
    tick: int
    confidence: float = 1.0    # score_confidence(score); 1.0 = full trust


@dataclass
class GuardInstruments:
    """Guard/rotation instruments (obs registry children, one set per shard).

    Owned by the SERVER, not by `DivergenceGuard`: sharded serving shares
    one stateless guard instance across shards (`share_modules_from`), so
    per-shard attribution has to live with the per-shard caller.  The
    definitions live here so the guard's metric surface is catalogued next
    to the signals it measures.

    `events` counts REFIT/ALERT state TRANSITIONS (what an operator pages
    on), not the per-tick re-judgement of an already-flagged twin; `score`
    is the raw (pre-EMA) divergence-score distribution; `scored` counts
    fused guard evaluations (rotation throughput); `live` gauges the
    guard-eligible set the rotation cycles over.
    """
    events: dict            # kind -> Counter
    score: object           # Histogram of raw divergence scores
    scored: object          # Counter: twins scored by the fused guard call
    live: object            # Gauge: guard-eligible (deployed + sampled) twins

    @staticmethod
    def create(registry, labels: dict | None = None) -> "GuardInstruments":
        labels = labels or {}
        return GuardInstruments(
            events={kind: registry.counter(
                        "twin_guard_events_total",
                        help="guard state transitions by kind",
                        labels={**labels, "kind": kind})
                    for kind in ("REFIT", "ALERT")},
            score=registry.histogram(
                "twin_divergence_score",
                help="raw guard divergence scores (normalized rollout "
                     "error; 1e6 = non-finite blowup)",
                bounds=DEFAULT_SCORE_BUCKETS, labels=labels),
            scored=registry.counter(
                "twin_guard_scored_total",
                help="twin scorings performed by the fused guard rollout",
                labels=labels),
            live=registry.gauge(
                "twin_guard_live",
                help="guard-eligible twins (deployed with enough samples)",
                labels=labels))


class DivergenceGuard:
    """Scores deployed thetas against reality; see module docstring.

    Backend: `use_pallas`/`interpret` mirror `MerindaConfig` and flow into
    the fused `rk4_poly_solve` rollout — `TwinServer` always passes its
    MerindaConfig's values, so the guard rolls with the SAME backend the twin
    was trained/recovered with.  ``interpret=None`` is the auto default
    resolved in kernels/backend (compiled on TPU, interpreter elsewhere);
    the old local ``interpret=True`` default silently pinned interpreter
    mode regardless of the config.
    """

    def __init__(self, library, dt: float, cfg: GuardConfig = GuardConfig(),
                 *, use_pallas: bool = False, interpret: bool | None = None):
        self.lib = library
        self.dt = dt
        self.cfg = cfg
        self.use_pallas = use_pallas
        self.interpret = interpret

    # ------------------------------------------------------------------ #
    @partial(jax.jit, static_argnames=("self",))
    def score(self, theta, ys, us):
        """Normalized rollout error per twin (fused over the whole store).

        theta: [B, n, L]; ys: [B, k+1, n] newest telemetry; us: [B, k, m].
        Returns [B] float32 — finite even when the rollout diverges.
        """
        y_est = rk4_poly_solve(shard(theta, "twin_theta"), ys[:, 0, :], us,
                               dt=self.dt, library=self.lib,
                               use_pallas=self.use_pallas,
                               interpret=self.interpret)
        num = jnp.mean(jnp.square(y_est - ys), axis=(1, 2))
        den = jnp.mean(jnp.square(ys - jnp.mean(ys, axis=1, keepdims=True)),
                       axis=(1, 2)) + 1e-6
        return jnp.nan_to_num(num / den, nan=_BLOWUP_SCORE,
                              posinf=_BLOWUP_SCORE)

    # ------------------------------------------------------------------ #
    def smooth(self, prev: float, score: float) -> float:
        """EMA update used by the server when folding scores into records."""
        a = self.cfg.ema
        return a * min(float(score), _BLOWUP_SCORE) + (1.0 - a) * prev

    def fold_into(self, div_by_row: np.ndarray, rows: np.ndarray,
                  scores) -> np.ndarray:
        """Vectorized `smooth`: EMA-fold raw `scores` into the by-row
        divergence array IN PLACE at `rows`, returning the updated values.

        `div_by_row` is the packed fleet's divergence column
        (twin/packed.py), so this single numpy statement is how the guard
        publishes its view to the scheduler's fused scoring call.  Same
        float64 arithmetic order as the scalar `smooth`, so the record
        mirrors stay bit-identical.
        """
        a = self.cfg.ema
        rows = np.asarray(rows)
        clipped = np.minimum(np.asarray(scores, np.float64), _BLOWUP_SCORE)
        div_by_row[rows] = a * clipped + (1.0 - a) * div_by_row[rows]
        return div_by_row[rows]

    def judge(self, twin_id: int, score: float, tick: int) -> GuardEvent | None:
        """Threshold an (already smoothed) score into an event, or None."""
        if score > self.cfg.alert_threshold:
            return GuardEvent(twin_id, "ALERT", float(score), tick,
                              score_confidence(score))
        if score > self.cfg.refit_threshold:
            return GuardEvent(twin_id, "REFIT", float(score), tick,
                              score_confidence(score))
        return None


class GuardRotation:
    """Budgeted round-robin guard scheduling with divergence carry-over.

    Each tick `select()` picks which ring rows the guard scores:

      * `budget` rows advance a cyclic cursor over the eligible set, so every
        eligible twin is re-scored within ceil(eligible / budget) ticks — the
        freshness floor (host-tested in tests/test_twin_sharded.py);
      * up to `carry` EXTRA rows re-score the currently most-diverged twins
        (EMA score above the refit threshold) every tick, so a flagged twin's
        escalation to ALERT is never delayed by its place in the rotation.

    The carry quota rides ON TOP of the round-robin budget (fused guard call
    shape = budget + carry, scratch-padded), so priority twins never starve
    the rotation and the freshness bound survives any divergence pattern.

    Selection is pure numpy over a pre-sorted eligible-row array and a
    by-row divergence array (both maintained incrementally by the server):
    at 10k twins a per-tick python rescan of the store would reintroduce the
    O(twins) host cost this class exists to remove.

    Complexity contract: per tick, device work is one fused rollout of
    exactly `budget + carry` rows and host work is O(budget + carry + F)
    where F is the count of currently-flagged twins (vectorized numpy) —
    BOTH independent of the tracked-twin count.  The empirical gate: the
    scale benchmark (`benchmarks/run.py --only online_scale`) requires mean
    guard stage cost per tick to grow < 2x from 1k to 10k twins at a fixed
    budget (last recorded: 21 -> 39 ms, 1.84x — bench_out/online_scale.csv),
    and the freshness floor (every eligible twin re-scored within
    ceil(eligible / budget) ticks) is host-tested in
    tests/test_twin_sharded.py.
    """

    def __init__(self, budget: int, carry: int = 0):
        if budget < 1:
            raise ValueError("guard rotation budget must be >= 1")
        self.budget = budget
        self.carry = max(0, carry)
        self._cursor = 0       # next ring row served by the rotation (cyclic)

    @property
    def size(self) -> int:
        """Fixed fused-call width (rows beyond the pick are scratch-padded)."""
        return self.budget + self.carry

    def select(self, rows: np.ndarray, div_by_row: np.ndarray,
               threshold: float, *, budget: int | None = None,
               carry: int | None = None) -> np.ndarray:
        """Pick this tick's ring rows.

        rows: SORTED int array of eligible ring rows; div_by_row: full
        by-row EMA score array (indexed by ring row, not position).
        Returns at most `budget + carry` distinct rows.

        `budget`/`carry` override the configured quotas for ONE call — the
        deadline-degradation path (twin/recovery.py) shrinks the fused guard
        width under overload without touching the rotation's steady-state
        shape.  The cursor still advances by what was actually scored, so
        the freshness bound degrades proportionally instead of breaking.
        """
        eff_budget = self.budget if budget is None else max(1, budget)
        eff_carry = self.carry if carry is None else max(0, carry)
        rows = np.asarray(rows)
        if rows.size == 0:
            return rows
        i = int(np.searchsorted(rows, self._cursor))
        take = min(eff_budget, rows.size)
        pick = rows[(i + np.arange(take)) % rows.size]
        self._cursor = int(pick[-1]) + 1
        if eff_carry:
            flagged = rows[div_by_row[rows] > threshold]
            flagged = flagged[~np.isin(flagged, pick)]
            if flagged.size > eff_carry:
                part = np.argpartition(-div_by_row[flagged],
                                       eff_carry - 1)[:eff_carry]
                flagged = flagged[part]
            # deterministic order: most diverged first, row id breaks ties
            flagged = flagged[np.lexsort((flagged, -div_by_row[flagged]))]
            pick = np.concatenate([pick, flagged])
        return pick
