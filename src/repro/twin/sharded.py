"""ShardedTwinServer: the 10k-tracked-object serving architecture.

One `TwinServer` saturates at a few hundred twins: its guard scan, staging
flush, and single refit-slot pool all serialize on one tick loop.  This
module partitions the tracked fleet across N SHARDS — each shard owns its own
`TelemetryRing`, `FleetMerinda` refit-slot pool, theta store, and
`RefitScheduler` — with two cross-shard mechanisms on top:

  * **Slot federation** (`SlotFederation`, twin/scheduler.py): a GLOBAL
    active-refit budget is divided across shards in proportion to their
    aggregate staleness+divergence pressure (each shard's
    `refit_pressure()` — one fused device reduction over its packed fleet
    arrays, not an O(twins) host scan), re-evaluated every
    `rebalance_every` ticks.  A shard whose twins diverge (dynamics changed,
    models stale) is granted slots that quiet shards give back — refit
    compute follows the emergency.  Physical pools never change shape, so
    nothing recompiles; only each scheduler's fill cap moves.

  * **Shared compiled modules**: shards with identical configs share the
    stateless ring/fleet/guard module objects (`share_modules_from`), so the
    fused serving kernels compile once per topology instead of once per
    shard.

Shards may also be HETEROGENEOUS (different MerindaConfig per shard) — the
mixed-fleet deployment where F-8 airframes, Van der Pol oscillators, and
Lotka-Volterra populations are tracked by one server
(examples/sharded_fleet.py); federation grants still flow between them.

Placement is sticky: a twin's first `register`/`ingest` pins it to a shard
(`twin_id % shards` by default, or an explicit `shard=` for family-routed
fleets).  Combined with per-shard `async_ingest` (background staging flush)
and `guard_budget` (O(budget) rotating guard), one process tracks 10k+
objects — `benchmarks/online_scale.py` is the scaling evidence.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.obs import MetricRegistry, Tracer
from repro.twin.monitor import GuardEvent
from repro.twin.scheduler import FederationConfig, SlotFederation
from repro.twin.server import _HISTORY, TickReport, TwinServer, \
    TwinServerConfig

__all__ = ["ShardedTwinConfig", "ShardedTickReport", "ShardedTwinServer"]


@dataclass(frozen=True)
class ShardedTwinConfig:
    servers: tuple[TwinServerConfig, ...]   # one per shard (may differ)
    total_slots: int | None = None    # global active-refit budget
                                      # (None: sum of physical pools —
                                      # federation never constrains)
    min_shard_slots: int = 1          # per-shard grant floor
    rebalance_every: int = 4          # federation period (ticks)
    pressure_smooth: float = 0.5      # EMA on the pressure signal

    @staticmethod
    def uniform(server: TwinServerConfig, shards: int,
                **kw) -> "ShardedTwinConfig":
        """N identical shards (they will share compiled modules)."""
        return ShardedTwinConfig(servers=(server,) * shards, **kw)


@dataclass
class ShardedTickReport:
    tick: int
    latency_s: float
    deadline_met: bool
    reports: list[TickReport]             # per shard, in shard order
    grants: list[int]                     # active-slot grant per shard
    events: list[GuardEvent] = field(default_factory=list)
    n_active: int = 0
    n_twins: int = 0
    n_guarded: int = 0


class ShardedTwinServer:
    """N `TwinServer` shards + slot federation; see module docstring.

    API mirrors `TwinServer` (register/ingest/deploy/deploy_many/predict/
    tick/drain/close + latency/stage summaries) with twin_ids routed to
    their pinned shard.  Units: `ShardedTickReport.latency_s` is SECONDS
    for the WHOLE sharded tick (all shards, serial); `deadline_s` is the
    tightest per-shard deadline.  Threading matches `TwinServer`: `ingest`
    is safe from many sensor threads (each shard's staging buffer
    synchronizes its own producers), everything that touches device state —
    `tick`, `drain`, `deploy*`, `predict` — belongs to one serving thread.
    Guard cost per tick is O(sum of per-shard budgets), independent of the
    tracked-twin count (the 1k->10k scale benchmark checks <= 2x drift).
    """

    def __init__(self, cfg: ShardedTwinConfig, *,
                 metrics: MetricRegistry | None = None,
                 tracer: Tracer | None = None):
        """One `MetricRegistry` + `Tracer` is shared by the whole fleet:
        every shard resolves its instruments with a `shard="<i>"` label, so
        one `metrics.expose()` scrape carries per-shard stage histograms
        next to the fleet-level aggregates, and every shard's spans land in
        one Perfetto trace (nested under the `sharded_tick` root)."""
        if not cfg.servers:
            raise ValueError("need at least one shard")
        self.cfg = cfg
        self.metrics = MetricRegistry() if metrics is None else metrics
        self.tracer = Tracer(enabled=False) if tracer is None else tracer
        self.shards: list[TwinServer] = []
        first_with_cfg: dict[TwinServerConfig, TwinServer] = {}
        for i, scfg in enumerate(cfg.servers):
            srv = TwinServer(scfg,
                             share_modules_from=first_with_cfg.get(scfg),
                             seed=scfg.seed + i,
                             metrics=self.metrics, tracer=self.tracer,
                             shard=i)
            first_with_cfg.setdefault(scfg, srv)
            self.shards.append(srv)

        pools = [s.cfg.refit_slots for s in self.shards]
        total = sum(pools) if cfg.total_slots is None else cfg.total_slots
        self.federation = SlotFederation(
            FederationConfig(total_slots=total,
                             min_slots=cfg.min_shard_slots,
                             smooth=cfg.pressure_smooth), pools)
        self.grants = self.federation.rebalance([0.0] * len(pools))
        for srv, g in zip(self.shards, self.grants):
            srv.set_active_slots(g)

        self._placement: dict[int, int] = {}      # twin_id -> shard index
        self.tick_count = 0
        self.latencies: deque = deque(maxlen=_HISTORY)
        self.refresh_counts: deque = deque(maxlen=_HISTORY)
        self.deadline_s = min(s.cfg.deadline_s for s in self.shards)

        # fleet-level instruments: the whole sharded tick (all shards,
        # serial) — per-shard detail lives in each shard's labeled children
        M = self.metrics
        self._m_tick = M.histogram(
            "twin_fleet_tick_latency_seconds",
            help="full sharded serving-tick wall latency (all shards)",
            unit="seconds")
        self._m_violations = M.counter(
            "twin_fleet_deadline_violations_total",
            help="sharded ticks exceeding the tightest shard deadline")
        self._m_refreshes = M.counter(
            "twin_fleet_slot_refreshes_total",
            help="refit-slot train advances across all shards")
        self._m_grants = [
            M.gauge("twin_shard_slot_grant",
                    help="active refit-slot grant from the federation",
                    labels={"shard": str(i)})
            for i in range(len(self.shards))]
        for g, n in zip(self._m_grants, self.grants):
            g.set(n)

    # ------------------------------------------------------------------ #
    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def shard_of(self, twin_id: int) -> int:
        """The twin's pinned shard (pins it modulo-N if unplaced)."""
        s = self._placement.get(twin_id)
        if s is None:
            s = twin_id % self.n_shards
            self._placement[twin_id] = s
        return s

    def register(self, twin_id: int, shard: int | None = None):
        """Start tracking; `shard` pins placement explicitly (family routing
        for heterogeneous fleets) — conflicting re-pins raise."""
        if shard is not None:
            prev = self._placement.setdefault(twin_id, shard)
            if prev != shard:
                raise ValueError(f"twin {twin_id} already placed on shard "
                                 f"{prev}, cannot move to {shard}")
        return self.shards[self.shard_of(twin_id)].register(twin_id)

    # ------------------------------------------------------------------ #
    def ingest(self, twin_id: int, y, u=None):
        self.shards[self.shard_of(twin_id)].ingest(twin_id, y, u)

    def deploy(self, twin_id: int, theta) -> None:
        self.shards[self.shard_of(twin_id)].deploy(twin_id, theta)

    def deploy_many(self, twin_ids, thetas) -> None:
        """Warm-start across shards: one fused scatter per shard."""
        thetas = np.asarray(thetas)
        by_shard: dict[int, list[int]] = {}
        for k, tid in enumerate(twin_ids):
            by_shard.setdefault(self.shard_of(tid), []).append(k)
        for s, ks in by_shard.items():
            ids = [twin_ids[k] for k in ks]
            self.shards[s].deploy_many(
                ids, thetas if thetas.ndim == 2 else thetas[ks])

    def predict(self, twin_id: int, horizon: int, us=None):
        return self.shards[self.shard_of(twin_id)].predict(twin_id, horizon,
                                                           us)

    # ------------------------------------------------------------------ #
    def tick(self) -> ShardedTickReport:
        """One serving cycle: every shard ticks, then (periodically) the
        federation re-divides the global slot budget by shard pressure."""
        with self.tracer.span("sharded_tick", tick=self.tick_count + 1,
                              shards=len(self.shards)):
            t0 = time.perf_counter()
            self.tick_count += 1
            reports = [srv.tick() for srv in self.shards]
            if self.tick_count % self.cfg.rebalance_every == 0:
                with self.tracer.span("rebalance"):
                    self.grants = self.federation.rebalance(
                        [srv.refit_pressure() for srv in self.shards])
                    for srv, g, gauge in zip(self.shards, self.grants,
                                             self._m_grants):
                        srv.set_active_slots(g)
                        gauge.set(g)
            latency = time.perf_counter() - t0
        self.latencies.append(latency)
        self._m_tick.observe(latency)
        if latency > self.deadline_s:
            self._m_violations.inc()
        n_active = sum(r.n_active for r in reports)
        self.refresh_counts.append(n_active)
        if n_active:
            self._m_refreshes.inc(n_active)
        return ShardedTickReport(
            tick=self.tick_count, latency_s=latency,
            deadline_met=latency <= self.deadline_s,
            reports=reports, grants=list(self.grants),
            events=[e for r in reports for e in r.events],
            n_active=sum(r.n_active for r in reports),
            n_twins=sum(r.n_twins for r in reports),
            n_guarded=sum(r.n_guarded for r in reports))

    # ------------------------------------------------------------------ #
    def drain(self) -> None:
        """Barrier: every ingested sample reaches its shard's ring."""
        for srv in self.shards:
            srv.drain()

    def close(self) -> None:
        for srv in self.shards:
            srv.close()

    # ------------------------------------------------------------------ #
    def reset_latency_stats(self) -> None:
        self.latencies.clear()
        self.refresh_counts.clear()
        self._m_tick.reset()
        self._m_violations.reset()
        self._m_refreshes.reset()
        for srv in self.shards:
            srv.reset_latency_stats()

    def latency_summary(self) -> dict:
        """p50/p99 of the WHOLE sharded tick + aggregate twin throughput.

        Registry-backed like `TwinServer.latency_summary` (same histograms
        `metrics.expose()` scrapes); dropped/overflow totals aggregate the
        per-shard counters."""
        h = self._m_tick
        ticks = h.count
        if ticks == 0:
            return {"ticks": 0}
        return {
            "ticks": ticks,
            "p50_ms": h.quantile(0.5) * 1e3,
            "p99_ms": h.quantile(0.99) * 1e3,
            "max_ms": h.max * 1e3,
            "deadline_s": self.deadline_s,
            "violations": int(self._m_violations.value),
            "twin_refreshes_per_s":
                self._m_refreshes.value / max(h.sum, 1e-9),
            "dropped_samples": sum(int(s._m_dropped.value)
                                   for s in self.shards),
            "flush_overflows": sum(int(s._m_overflow.value)
                                   for s in self.shards),
        }

    def stage_summary(self) -> dict:
        """Aggregate per-tick stage cost across shards (ms): the guard
        column is the scale benchmark's O(budget) evidence."""
        out: dict[str, float] = {}
        for srv in self.shards:
            for k, v in srv.stage_summary().items():
                out[k] = out.get(k, 0.0) + v
        return out
