"""ShardedTwinServer: the 10k-tracked-object serving architecture.

One `TwinServer` saturates at a few hundred twins: its guard scan, staging
flush, and single refit-slot pool all serialize on one tick loop.  This
module partitions the tracked fleet across N SHARDS — each shard owns its own
`TelemetryRing`, `FleetMerinda` refit-slot pool, theta store, and
`RefitScheduler` — with two cross-shard mechanisms on top:

  * **Slot federation** (`SlotFederation`, twin/scheduler.py): a GLOBAL
    active-refit budget is divided across shards in proportion to their
    aggregate staleness+divergence pressure (each shard's
    `refit_pressure()` — one fused device reduction over its packed fleet
    arrays, not an O(twins) host scan), re-evaluated every
    `rebalance_every` ticks.  A shard whose twins diverge (dynamics changed,
    models stale) is granted slots that quiet shards give back — refit
    compute follows the emergency.  Physical pools never change shape, so
    nothing recompiles; only each scheduler's fill cap moves.

  * **Shared compiled modules**: shards with identical configs share the
    stateless ring/fleet/guard module objects (`share_modules_from`), so the
    fused serving kernels compile once per topology instead of once per
    shard.

Shards may also be HETEROGENEOUS (different MerindaConfig per shard) — the
mixed-fleet deployment where F-8 airframes, Van der Pol oscillators, and
Lotka-Volterra populations are tracked by one server
(examples/sharded_fleet.py); federation grants still flow between them.

Placement is sticky: a twin's first `register`/`ingest` pins it to a shard
(`twin_id % shards` by default, or an explicit `shard=` for family-routed
fleets).  Combined with per-shard `async_ingest` (background staging flush)
and `guard_budget` (O(budget) rotating guard), one process tracks 10k+
objects — `benchmarks/online_scale.py` is the scaling evidence.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.obs import MetricRegistry, Tracer
from repro.twin.monitor import GuardEvent
from repro.twin.recovery import (ChaosInjector, ShardFailure,
                                 TelemetryJournal, TwinCheckpointer)
from repro.twin.scheduler import SlotFederation
from repro.twin.server import _HISTORY, TickReport, TwinServer, \
    TwinServerConfig
from repro.twin.service import FleetTopologyConfig

__all__ = ["ShardedTwinConfig", "ShardedTickReport", "ShardedTwinServer"]


@dataclass(frozen=True)
class ShardedTwinConfig(FleetTopologyConfig):
    """In-process fleet: the topology knobs (slot budget, grant floor,
    rebalance cadence, smoothing, recovery, chaos) live in
    `FleetTopologyConfig` — shared verbatim with `FederatedTwinConfig`
    (twin/federation.py), the multi-process deployment of the same shape."""
    servers: tuple[TwinServerConfig, ...] = ()   # one per shard (may differ)

    @staticmethod
    def uniform(server: TwinServerConfig, shards: int,
                **kw) -> "ShardedTwinConfig":
        """N identical shards (they will share compiled modules)."""
        return ShardedTwinConfig(servers=(server,) * shards, **kw)


@dataclass
class ShardedTickReport:
    tick: int
    latency_s: float
    deadline_met: bool
    reports: list[TickReport | None]      # per shard, in shard order
                                          # (None: shard was dead this tick)
    grants: list[int]                     # active-slot grant per shard
    events: list[GuardEvent] = field(default_factory=list)
    n_active: int = 0
    n_twins: int = 0
    n_guarded: int = 0
    degraded_level: int = 0               # max shed-ladder level across shards
    dead_shards: int = 0                  # shards down at the end of the tick
    restarted: list = field(default_factory=list)
                                          # restart records this tick:
                                          # {shard, ckpt_tick, replayed, lost,
                                          #  down_ticks}
    replayed_samples: int = 0             # journal samples replayed this tick


class ShardedTwinServer:
    """N `TwinServer` shards + slot federation; see module docstring.

    API mirrors `TwinServer` (register/ingest/deploy/deploy_many/predict/
    tick/drain/close + latency/stage summaries) with twin_ids routed to
    their pinned shard.  Units: `ShardedTickReport.latency_s` is SECONDS
    for the WHOLE sharded tick (all shards, serial); `deadline_s` is the
    tightest per-shard deadline.  Threading matches `TwinServer`: `ingest`
    is safe from many sensor threads (each shard's staging buffer
    synchronizes its own producers), everything that touches device state —
    `tick`, `drain`, `deploy*`, `predict` — belongs to one serving thread.
    Guard cost per tick is O(sum of per-shard budgets), independent of the
    tracked-twin count (the 1k->10k scale benchmark checks <= 2x drift).
    """

    def __init__(self, cfg: ShardedTwinConfig, *,
                 metrics: MetricRegistry | None = None,
                 tracer: Tracer | None = None):
        """One `MetricRegistry` + `Tracer` is shared by the whole fleet:
        every shard resolves its instruments with a `shard="<i>"` label, so
        one `metrics.expose()` scrape carries per-shard stage histograms
        next to the fleet-level aggregates, and every shard's spans land in
        one Perfetto trace (nested under the `sharded_tick` root)."""
        if not cfg.servers:
            raise ValueError("need at least one shard")
        self.cfg = cfg
        self.metrics = MetricRegistry() if metrics is None else metrics
        self.tracer = Tracer(enabled=False) if tracer is None else tracer
        self.shards: list[TwinServer] = []
        first_with_cfg: dict[TwinServerConfig, TwinServer] = {}
        for i, scfg in enumerate(cfg.servers):
            srv = TwinServer(scfg,
                             share_modules_from=first_with_cfg.get(scfg),
                             seed=scfg.seed + i,
                             metrics=self.metrics, tracer=self.tracer,
                             shard=i)
            first_with_cfg.setdefault(scfg, srv)
            self.shards.append(srv)

        pools = [s.cfg.refit_slots for s in self.shards]
        self.federation = SlotFederation(cfg.make_federation(pools), pools)
        self.grants = self.federation.rebalance([0.0] * len(pools))
        for srv, g in zip(self.shards, self.grants):
            srv.set_active_slots(g)

        self._placement: dict[int, int] = {}      # twin_id -> shard index
        self.tick_count = 0
        self.latencies: deque = deque(maxlen=_HISTORY)
        self.refresh_counts: deque = deque(maxlen=_HISTORY)
        self.deadline_s = (cfg.deadline_s if cfg.deadline_s is not None
                           else min(s.cfg.deadline_s for s in self.shards))

        # fault-tolerance layer (twin/recovery.py): checkpointer + journals
        # live with the SUPERVISOR so they survive any shard's death
        self.checkpointer = (TwinCheckpointer(cfg.recovery,
                                              metrics=self.metrics)
                             if cfg.recovery is not None else None)
        self.journals = ([TelemetryJournal(cfg.recovery.journal_horizon
                                           or s.capacity)
                          for s in cfg.servers]
                         if cfg.recovery is not None else None)
        self.chaos = (ChaosInjector(cfg.chaos)
                      if cfg.chaos is not None else None)
        self._dead: dict[int, int] = {}           # shard -> supervisor tick
                                                  # it died on

        # fleet-level instruments: the whole sharded tick (all shards,
        # serial) — per-shard detail lives in each shard's labeled children
        M = self.metrics
        self._m_tick = M.histogram(
            "twin_fleet_tick_latency_seconds",
            help="full sharded serving-tick wall latency (all shards)",
            unit="seconds")
        self._m_violations = M.counter(
            "twin_fleet_deadline_violations_total",
            help="sharded ticks exceeding the tightest shard deadline")
        self._m_refreshes = M.counter(
            "twin_fleet_slot_refreshes_total",
            help="refit-slot train advances across all shards")
        self._m_grants = [
            M.gauge("twin_shard_slot_grant",
                    help="active refit-slot grant from the federation",
                    labels={"shard": str(i)})
            for i in range(len(self.shards))]
        for g, n in zip(self._m_grants, self.grants):
            g.set(n)
        self._m_deaths = M.counter(
            "twin_shard_deaths_total",
            help="shard failures (injected or organic) the supervisor "
                 "handled")
        self._m_restarts = M.counter(
            "twin_shard_restarts_total",
            help="supervised shard restarts (checkpoint restore + journal "
                 "replay)")
        self._m_dead = M.gauge(
            "twin_dead_shards", help="shards currently down")
        self._m_recovery = M.histogram(
            "twin_recovery_ticks",
            help="supervisor ticks a shard spent down before its restart "
                 "completed", unit="ticks")
        self._m_replayed = M.counter(
            "twin_replay_samples_total",
            help="journal samples replayed into restarted shards")
        self._m_replay_lost = M.counter(
            "twin_replay_lost_samples_total",
            help="samples past the journal horizon at restart "
                 "(unrecoverable by design; ring would have dropped them)")
        self._m_slow_inj = M.counter(
            "twin_chaos_slow_injections_total",
            help="injected straggler sleeps before shard ticks")

    # ------------------------------------------------------------------ #
    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def shard_of(self, twin_id: int) -> int:
        """The twin's pinned shard (pins it modulo-N if unplaced)."""
        s = self._placement.get(twin_id)
        if s is None:
            s = twin_id % self.n_shards
            self._placement[twin_id] = s
        return s

    def _shard_srv(self, i: int) -> TwinServer:
        srv = self.shards[i]
        if srv is None:
            raise RuntimeError(f"shard {i} is down (died at supervisor tick "
                               f"{self._dead.get(i)}; restart pending)")
        return srv

    def register(self, twin_id: int, shard: int | None = None):
        """Start tracking; `shard` pins placement explicitly (family routing
        for heterogeneous fleets) — conflicting re-pins raise."""
        if shard is not None:
            prev = self._placement.setdefault(twin_id, shard)
            if prev != shard:
                raise ValueError(f"twin {twin_id} already placed on shard "
                                 f"{prev}, cannot move to {shard}")
        return self._shard_srv(self.shard_of(twin_id)).register(twin_id)

    # ------------------------------------------------------------------ #
    def ingest(self, twin_id: int, y, u=None, *, force: bool = False):
        """Route telemetry to the twin's shard, journaling first (recovery
        enabled): the journal must already hold a sample when the shard that
        received it dies.  Ingest into a DEAD shard is journal-only — the
        sample is replayed at restart, so producers never block on a crash.
        A chaos storm duplicates the chunk (journal and shard alike), so
        replay stays consistent with what the shard actually saw.
        `force=True` bypasses shard staging backpressure (crash-recovery
        replay) — same contract as `TwinServer.ingest`."""
        s = self.shard_of(twin_id)
        copies = 1 + (self.chaos.storm_extra(s, self.tick_count)
                      if self.chaos is not None else 0)
        srv = self.shards[s]
        for _ in range(copies):
            if self.journals is not None:
                self.journals[s].append(twin_id, y, u)
            if srv is not None:
                srv.ingest(twin_id, y, u, force=force)

    def ingest_many(self, batch, *, force: bool = False) -> int:
        """Batched `ingest` over (twin_id, y[, u]) chunks; returns the
        number of SAMPLES staged (journal-only samples for dead shards
        count — they WILL be served after replay)."""
        staged = 0
        for chunk in batch:
            tid, y = chunk[0], chunk[1]
            u = chunk[2] if len(chunk) > 2 else None
            self.ingest(tid, y, u, force=force)
            staged += np.atleast_2d(np.asarray(y)).shape[0]
        return staged

    def deploy(self, twin_id: int, theta) -> None:
        self._shard_srv(self.shard_of(twin_id)).deploy(twin_id, theta)

    def deploy_many(self, twin_ids, thetas) -> None:
        """Warm-start across shards: one fused scatter per shard."""
        thetas = np.asarray(thetas)
        by_shard: dict[int, list[int]] = {}
        for k, tid in enumerate(twin_ids):
            by_shard.setdefault(self.shard_of(tid), []).append(k)
        for s, ks in by_shard.items():
            ids = [twin_ids[k] for k in ks]
            self._shard_srv(s).deploy_many(
                ids, thetas if thetas.ndim == 2 else thetas[ks])

    def predict(self, twin_id: int, horizon: int, us=None):
        return self._shard_srv(self.shard_of(twin_id)).predict(twin_id,
                                                               horizon, us)

    def scenario(self, twin_id: int, horizon: int, us=None,
                 k: int | None = None):
        """What-if fan-out: route to the owning shard; degradation shrink /
        refuse happens at THAT shard's ladder level (a straggling shard
        sheds its own scenario load without dimming the healthy shards)."""
        return self._shard_srv(self.shard_of(twin_id)).scenario(
            twin_id, horizon, us, k=k)

    # ------------------------------------------------------------------ #
    def _alive(self) -> list[bool]:
        return [srv is not None for srv in self.shards]

    def _rebalance(self) -> None:
        """Re-divide the global slot budget; dead shards pressure 0 / no
        floor (their share flows to survivors until restart)."""
        pressures = [srv.refit_pressure() if srv is not None else 0.0
                     for srv in self.shards]
        self.grants = self.federation.rebalance(pressures,
                                                alive=self._alive())
        for srv, g, gauge in zip(self.shards, self.grants, self._m_grants):
            if srv is not None:
                srv.set_active_slots(g)
            gauge.set(g)

    def tick(self) -> ShardedTickReport:
        """One serving cycle: restart any dead shard whose delay elapsed,
        tick every live shard (applying the chaos schedule: straggler
        sleeps, kills), checkpoint shards on their cadence, then
        (periodically) rebalance the global slot budget by shard pressure.

        A shard death never fails the supervisor tick: the dead shard's
        report slot is None, its grant flows to the survivors, and ingest
        for its twins is journaled until the restart replays it."""
        with self.tracer.span("sharded_tick", tick=self.tick_count + 1,
                              shards=len(self.shards)):
            t0 = time.perf_counter()
            self.tick_count += 1
            restarted: list[dict] = []
            if self._dead and self.cfg.recovery is not None:
                for i, died_at in sorted(self._dead.items()):
                    if (self.tick_count - died_at
                            >= self.cfg.recovery.restart_delay_ticks):
                        with self.tracer.span("restart_shard", shard=i):
                            restarted.append(self._restart_shard(i))
            reports: list[TickReport | None] = []
            for i, srv in enumerate(self.shards):
                if srv is None:
                    reports.append(None)
                    continue
                if self.chaos is not None:
                    if self.chaos.should_kill(i, self.tick_count):
                        try:
                            raise ShardFailure(i, self.tick_count)
                        except ShardFailure:
                            self._kill_shard(i)
                        reports.append(None)
                        continue
                    delay = self.chaos.slow_delay(i, self.tick_count)
                    if delay > 0:
                        self._m_slow_inj.inc()
                    srv.inject_delay_s = delay
                reports.append(srv.tick())
                if self.checkpointer is not None:
                    self.checkpointer.maybe_save(i, srv.tick_count,
                                                 srv.snapshot_state)
            if restarted or self.tick_count % self.cfg.rebalance_every == 0:
                with self.tracer.span("rebalance"):
                    self._rebalance()
            latency = time.perf_counter() - t0
        self.latencies.append(latency)
        self._m_tick.observe(latency)
        if latency > self.deadline_s:
            self._m_violations.inc()
        live = [r for r in reports if r is not None]
        n_active = sum(r.n_active for r in live)
        self.refresh_counts.append(n_active)
        if n_active:
            self._m_refreshes.inc(n_active)
        self._m_dead.set(len(self._dead))
        return ShardedTickReport(
            tick=self.tick_count, latency_s=latency,
            deadline_met=latency <= self.deadline_s,
            reports=reports, grants=list(self.grants),
            events=[e for r in live for e in r.events],
            n_active=n_active,
            n_twins=sum(r.n_twins for r in live),
            n_guarded=sum(r.n_guarded for r in live),
            degraded_level=max((r.degraded_level for r in live), default=0),
            dead_shards=len(self._dead),
            restarted=restarted,
            replayed_samples=sum(r["replayed"] for r in restarted))

    # -- failover: kill (chaos/organic) + supervised restart ------------ #
    def _kill_shard(self, i: int) -> None:
        """Take shard `i` down: stop its pump, drop the server object, hand
        its slot grant to the survivors.  Its rings/thetas die with it —
        recovery is checkpoint + journal replay at restart."""
        srv = self.shards[i]
        if srv is not None:
            srv.close()
        self.shards[i] = None
        self._dead[i] = self.tick_count
        self._m_deaths.inc()
        self._m_dead.set(len(self._dead))
        if (self.chaos is not None and self.checkpointer is not None
                and self.chaos.should_tear()):
            self.checkpointer.tear_latest(i)
        self._rebalance()

    def _restart_shard(self, i: int) -> dict:
        """Supervised restart: fresh server (sharing a surviving donor's
        compiled modules when configs match), restore from the last
        COMMITTED checkpoint, replay the journal suffix, rejoin the
        federation.  Returns the restart record for the tick report."""
        scfg = self.cfg.servers[i]
        donor = next((s for s in self.shards
                      if s is not None and s.cfg == scfg), None)
        srv = TwinServer(scfg, share_modules_from=donor, seed=scfg.seed + i,
                         metrics=self.metrics, tracer=self.tracer, shard=i)
        ckpt_tick = None
        if self.checkpointer is not None:
            ckpt_tick, state = self.checkpointer.restore_latest(
                i, srv.snapshot_state())
            if state is not None:
                srv.restore_state(state)
        self.shards[i] = srv
        died_at = self._dead.pop(i)
        replayed = lost = 0
        if self.journals is not None:
            journal = self.journals[i]
            for tid in journal.twin_ids():
                rec = srv.twins.get(tid)
                seen = rec.samples if rec is not None else 0
                chunks, lost_t = journal.replay_since(tid, seen)
                lost += lost_t
                for y, u in chunks:
                    # force: replay must not be shed by ingest backpressure
                    srv.ingest(tid, y, u, force=True)
                    replayed += len(y)
            srv.drain()      # every replayed sample reaches the ring NOW
        srv.set_active_slots(self.grants[i])
        down = self.tick_count - died_at
        self._m_restarts.inc()
        self._m_recovery.observe(down)
        self._m_replayed.inc(replayed)
        if lost:
            self._m_replay_lost.inc(lost)
        self._m_dead.set(len(self._dead))
        return {"shard": i, "ckpt_tick": ckpt_tick, "replayed": replayed,
                "lost": lost, "down_ticks": down}

    # ------------------------------------------------------------------ #
    def snapshot_state(self) -> dict:
        """Host pytree of the whole fleet: one `TwinServer.snapshot_state`
        sub-tree per LIVE shard, keyed `"shard<i>"` (dead shards omitted —
        their truth is the checkpoint + journal)."""
        return {f"shard{i}": srv.snapshot_state()
                for i, srv in enumerate(self.shards) if srv is not None}

    def drain(self) -> None:
        """Barrier: every ingested sample reaches its shard's ring."""
        for srv in self.shards:
            if srv is not None:
                srv.drain()

    def close(self) -> None:
        if self.checkpointer is not None:
            self.checkpointer.wait()
        for srv in self.shards:
            if srv is not None:
                srv.close()

    # ------------------------------------------------------------------ #
    def reset_latency_stats(self) -> None:
        self.latencies.clear()
        self.refresh_counts.clear()
        self._m_tick.reset()
        self._m_violations.reset()
        self._m_refreshes.reset()
        for srv in self.shards:
            if srv is not None:
                srv.reset_latency_stats()

    def latency_summary(self) -> dict:
        """p50/p99 of the WHOLE sharded tick + aggregate twin throughput.

        Registry-backed like `TwinServer.latency_summary` (same histograms
        `metrics.expose()` scrapes); dropped/overflow totals aggregate the
        per-shard counters."""
        h = self._m_tick
        ticks = h.count
        if ticks == 0:
            return {"ticks": 0}
        return {
            "ticks": ticks,
            "p50_ms": h.quantile(0.5) * 1e3,
            "p99_ms": h.quantile(0.99) * 1e3,
            "max_ms": h.max * 1e3,
            "deadline_s": self.deadline_s,
            "violations": int(self._m_violations.value),
            "twin_refreshes_per_s":
                self._m_refreshes.value / max(h.sum, 1e-9),
            "dropped_samples": sum(int(s._m_dropped.value)
                                   for s in self.shards if s is not None),
            "flush_overflows": sum(int(s._m_overflow.value)
                                   for s in self.shards if s is not None),
        }

    def stage_summary(self) -> dict:
        """Aggregate per-tick stage cost across shards (ms): the guard
        column is the scale benchmark's O(budget) evidence."""
        out: dict[str, float] = {}
        for srv in self.shards:
            if srv is None:
                continue
            for k, v in srv.stage_summary().items():
                out[k] = out.get(k, 0.0) + v
        return out
