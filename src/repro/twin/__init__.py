"""Online digital-twin serving: the paper's deployment loop as a subsystem.

The paper's contribution is *online* twinning — refitting a recovered model
from live telemetry fast enough to beat human reaction time in mid-air
collision avoidance.  The offline path (core/trainer.py, train/loop.py)
recovers one model from one recorded trace; this package is the serving-scale
loop around it: **sense -> recover -> predict -> guard**, continuously, for a
whole tracked fleet on a bounded compute budget.

Modules
-------
stream.py     `TelemetryRing` — per-twin fixed-capacity telemetry rings
              stored as device arrays.  One jitted scatter ingests a chunk
              for every twin (`ingest`); one jitted gather turns the newest
              samples into the sliding-window batches the trainer consumes
              (`windows`, parity-tested against data/pipeline.make_windows).

scheduler.py  Slot-based refit scheduling mirroring serve/engine.ServeEngine's
              admission pattern: a fixed pool of FleetMerinda slots, twins
              admitted / preempted / released by a priority score of
              staleness + divergence, so thousands of tracked objects share
              `refit_slots` concurrent recoveries.  `PackedRefitScheduler`
              (the serving default) scores the whole fleet in one fused
              device call over packed arrays (packed.py) and pops only the
              O(slots) winners through a `PriorityBuckets` queue;
              `RefitScheduler` is the O(n log n) dict-sorting reference the
              equivalence tests hold it to.  `SlotFederation` divides a
              global active-slot budget across per-shard schedulers by
              aggregate pressure (sharded serving).

packed.py     `PackedFleet` — the packed, row-indexed scheduler-state arrays
              (samples, deploy watermark, divergence, residency) that the
              server maintains incrementally and the fused scoring /
              pressure kernels reduce on device.

sharded.py    `ShardedTwinServer` — N shards, each its own ring + slot pool
              + theta store + scheduler, under one federation: the 10k+
              tracked-object architecture (async ingest per shard, budgeted
              guard rotation, slot grants following divergence pressure).

server.py     `TwinServer` — ties the loop together.  `ingest(twin_id, y, u)`
              stages telemetry; each `tick()` flushes to the rings, scores
              divergence, turns over slots, runs `steps_per_tick` fused
              incremental train steps, and deploys recovered thetas — with
              per-tick latency accounted against the 1 s refresh deadline
              (5x under the paper's 5 s human-reaction budget).
              `predict(twin_id, horizon)` is the collision-avoidance
              lookahead on the deployed model.

monitor.py    `DivergenceGuard` — RK4-rolls every deployed theta over the
              newest telemetry window and compares against what the sensors
              reported; emits REFIT (physics drifted, re-recover) and ALERT
              (model untrustworthy — the safety abort signal) events.

recovery.py   Crash-safety layer: `TwinCheckpointer` (per-shard atomic theta
              store checkpoints, async off the tick loop), `TelemetryJournal`
              (supervisor-side replay log bounded by the ring horizon),
              `ChaosConfig`/`ChaosInjector` (fault injection: shard kills,
              stragglers, torn checkpoints, ingest storms) and
              `DegradationPolicy` (deadline-aware shedding ladder:
              shrink guard -> defer refits -> skip promotion).  See
              docs/ROBUSTNESS.md.

Quick start
-----------
    from repro.core.merinda import MerindaConfig
    from repro.twin import TwinServer, TwinServerConfig

    cfg = TwinServerConfig(merinda=MerindaConfig(n=3, m=1, order=3, dt=0.01),
                           max_twins=64, refit_slots=8)
    server = TwinServer(cfg)
    for t in range(1000):
        for twin_id, (y, u) in telemetry_at(t):
            server.ingest(twin_id, y, u)
        report = server.tick()          # fused refit of every active slot
        for ev in report.events:        # REFIT / ALERT
            handle(ev)
    ys = server.predict(twin_id, horizon=50)

End-to-end scenarios: examples/online_twinning.py (64 F-8 twins, mid-stream
dynamics switch -> guard fires, scheduler re-recovers) and
examples/sharded_fleet.py (1k+ heterogeneous twins across federated shards).
Sustained latency/throughput tables: benchmarks/online_serving.py
(`--only online`) and benchmarks/online_scale.py (`--only online_scale`,
64 -> 10k twins).
"""
from repro.twin.monitor import (DivergenceGuard, GuardConfig, GuardEvent,
                                GuardInstruments, GuardRotation)
from repro.twin.packed import PackedFleet, fleet_pressure, fleet_scores
from repro.twin.recovery import (ChaosConfig, ChaosInjector,
                                 DegradationConfig, DegradationEvent,
                                 DegradationPolicy, RecoveryConfig,
                                 ShardFailure, TelemetryJournal,
                                 TwinCheckpointer)
from repro.twin.scheduler import (FederationConfig, PackedRefitScheduler,
                                  PriorityBuckets, RefitScheduler,
                                  SchedulerConfig, SchedulePlan,
                                  SchedulerMetrics, SlotFederation,
                                  TwinRecord)
from repro.twin.server import TickReport, TwinServer, TwinServerConfig
from repro.twin.sharded import (ShardedTickReport, ShardedTwinConfig,
                                ShardedTwinServer)
from repro.twin.stream import (RingConfig, StagingBuffer, StagingOverflow,
                               TelemetryRing, prepare_flush)

__all__ = [
    "DivergenceGuard", "GuardConfig", "GuardEvent", "GuardInstruments",
    "GuardRotation",
    "FederationConfig", "PackedFleet", "PackedRefitScheduler",
    "PriorityBuckets", "RefitScheduler", "SchedulerConfig", "SchedulePlan",
    "SchedulerMetrics", "SlotFederation", "TwinRecord",
    "fleet_pressure", "fleet_scores",
    "ChaosConfig", "ChaosInjector", "DegradationConfig", "DegradationEvent",
    "DegradationPolicy", "RecoveryConfig", "ShardFailure", "TelemetryJournal",
    "TwinCheckpointer",
    "TickReport", "TwinServer", "TwinServerConfig",
    "ShardedTickReport", "ShardedTwinConfig", "ShardedTwinServer",
    "RingConfig", "StagingBuffer", "StagingOverflow", "TelemetryRing",
    "prepare_flush",
]
