"""Online digital-twin serving: the paper's deployment loop as a subsystem.

The paper's contribution is *online* twinning — refitting a recovered model
from live telemetry fast enough to beat human reaction time in mid-air
collision avoidance.  The offline path (core/trainer.py, train/loop.py)
recovers one model from one recorded trace; this package is the serving-scale
loop around it: **sense -> recover -> predict -> guard**, continuously, for a
whole tracked fleet on a bounded compute budget.

The STABLE surface is the `TwinService` protocol (service.py) and the three
servers that implement it at three scales — see docs/API.md for the
contract, and the "stable vs internal" split at the bottom of this
docstring.

Modules
-------
service.py    `TwinService` — the protocol every server implements
              (ingest/ingest_many/tick/drain/predict/snapshot_state/...),
              plus the shared config bases `DeadlineConfig` and
              `FleetTopologyConfig`.  The conformance suite
              (tests/test_service_conformance.py) pins the semantics.

stream.py     `TelemetryRing` — per-twin fixed-capacity telemetry rings
              stored as device arrays.  One jitted scatter ingests a chunk
              for every twin (`ingest`); one jitted gather turns the newest
              samples into the sliding-window batches the trainer consumes
              (`windows`, parity-tested against data/pipeline.make_windows).

scheduler.py  Slot-based refit scheduling mirroring serve/engine.ServeEngine's
              admission pattern: a fixed pool of FleetMerinda slots, twins
              admitted / preempted / released by a priority score of
              staleness + divergence, so thousands of tracked objects share
              `refit_slots` concurrent recoveries.  `PackedRefitScheduler`
              (the serving default) scores the whole fleet in one fused
              device call over packed arrays (packed.py) and pops only the
              O(slots) winners through a `PriorityBuckets` queue;
              `RefitScheduler` is the O(n log n) dict-sorting reference the
              equivalence tests hold it to.  `SlotFederation` divides a
              global active-slot budget across per-shard schedulers by
              aggregate pressure (sharded + federated serving).

packed.py     `PackedFleet` — the packed, row-indexed scheduler-state arrays
              (samples, deploy watermark, divergence, residency) that the
              server maintains incrementally and the fused scoring /
              pressure kernels reduce on device.

sharded.py    `ShardedTwinServer` — N shards IN ONE PROCESS, each its own
              ring + slot pool + theta store + scheduler, under one
              federation: the 10k+ tracked-object architecture (async
              ingest per shard, budgeted guard rotation, slot grants
              following divergence pressure).

federation.py `FederatedTwinServer` — the same architecture across REAL
              process boundaries: a `FederationCoordinator` owning N
              `ShardWorker` subprocesses (each a `TwinServer` + its
              checkpointer), supervisor-side telemetry journals, failure
              detection + supervised restart with journal-tail replay, and
              an optional TCP ingestion front door for remote telemetry
              producers.

wire.py       The versioned wire format federation speaks: message
              dataclasses, the JSON-header + raw-array-blob codec, stream
              framing, `IngestFrontDoor`/`FrontDoorClient`.  Framing
              internals are NOT a stable API (docs/API.md).

scenario.py   `ScenarioRunner` — batched what-if rollouts: K counterfactual
              input sequences per twin evaluated in ONE fused ensemble x K
              device call against the recent-theta history, returning
              center trajectories plus lo/hi confidence bounds.
              `TwinServer.scenario()` serves it under the degradation
              ladder (shrink K, then refuse) on all three servers.

server.py     `TwinServer` — ties the loop together.  `ingest(twin_id, y, u)`
              stages telemetry; each `tick()` flushes to the rings, scores
              divergence, turns over slots, runs `steps_per_tick` fused
              incremental train steps, and deploys recovered thetas — with
              per-tick latency accounted against the 1 s refresh deadline
              (5x under the paper's 5 s human-reaction budget).
              `predict(twin_id, horizon)` is the collision-avoidance
              lookahead on the deployed model.

monitor.py    `DivergenceGuard` — RK4-rolls every deployed theta over the
              newest telemetry window and compares against what the sensors
              reported; emits REFIT (physics drifted, re-recover) and ALERT
              (model untrustworthy — the safety abort signal) events.

recovery.py   Crash-safety layer: `TwinCheckpointer` (per-shard atomic theta
              store checkpoints, async off the tick loop), `TelemetryJournal`
              (supervisor-side replay log bounded by the ring horizon),
              `ChaosConfig`/`ChaosInjector` (fault injection: shard kills,
              stragglers, torn checkpoints, ingest storms) and
              `DegradationPolicy` (deadline-aware shedding ladder:
              shrink guard -> defer refits -> skip promotion).  See
              docs/ROBUSTNESS.md.

Quick start
-----------
    from repro.core.merinda import MerindaConfig
    from repro.twin import TwinServer, TwinServerConfig

    cfg = TwinServerConfig(merinda=MerindaConfig(n=3, m=1, order=3, dt=0.01),
                           max_twins=64, refit_slots=8)
    server = TwinServer(cfg)
    for t in range(1000):
        server.ingest_many(telemetry_at(t))      # [(twin_id, y[, u]), ...]
        report = server.tick()          # fused refit of every active slot
        for ev in report.events:        # REFIT / ALERT
            handle(ev)
    ys = server.predict(twin_id, horizon=50)

Scale out by swapping the config, not the call sites (`TwinService`):
`ShardedTwinConfig.uniform(cfg, shards)` -> `ShardedTwinServer`, or
`FederatedTwinConfig.uniform(cfg, workers, front_door=True)` ->
`FederatedTwinServer`.

End-to-end scenarios: examples/online_twinning.py (64 F-8 twins, mid-stream
dynamics switch -> guard fires, scheduler re-recovers) and
examples/sharded_fleet.py (1k+ heterogeneous twins across federated shards).
Sustained latency/throughput tables: benchmarks/online_serving.py
(`--only online`), benchmarks/online_scale.py (`--only online_scale`,
64 -> 10k twins) and benchmarks/online_federated.py
(`--only online_federated`, multi-process).
"""
from repro.twin.federation import (FederatedTwinConfig, FederatedTwinServer,
                                   FederationCoordinator, ShardWorker)
from repro.twin.monitor import (DivergenceGuard, GuardConfig, GuardEvent,
                                GuardInstruments, GuardRotation)
from repro.twin.packed import PackedFleet, fleet_pressure, fleet_scores
from repro.twin.recovery import (ChaosConfig, ChaosInjector,
                                 DegradationConfig, DegradationEvent,
                                 DegradationPolicy, RecoveryConfig,
                                 ShardFailure, TelemetryJournal,
                                 TwinCheckpointer)
from repro.twin.scenario import (ScenarioConfig, ScenarioRefused,
                                 ScenarioResult, ScenarioRunner, effective_k)
from repro.twin.scheduler import (FederationConfig, PackedRefitScheduler,
                                  PriorityBuckets, RefitScheduler,
                                  SchedulerConfig, SchedulePlan,
                                  SchedulerMetrics, SlotFederation,
                                  TwinRecord)
from repro.twin.server import TickReport, TwinServer, TwinServerConfig
from repro.twin.service import (DeadlineConfig, FleetTopologyConfig,
                                TwinService, conforms)
from repro.twin.sharded import (ShardedTickReport, ShardedTwinConfig,
                                ShardedTwinServer)
from repro.twin.stream import (RingConfig, StagingBuffer, StagingOverflow,
                               TelemetryRing, prepare_flush)
from repro.twin.wire import FrontDoorClient, IngestFrontDoor

# --------------------------------------------------------------------------- #
# STABLE serving surface (docs/API.md): the protocol, the three servers that
# implement it, their configs, and the report/event types callers consume.
# Everything callers need to serve a fleet at any scale.
# --------------------------------------------------------------------------- #
_STABLE = [
    "TwinService", "conforms",
    "DeadlineConfig", "FleetTopologyConfig",
    "TwinServer", "TwinServerConfig", "TickReport",
    "ShardedTwinServer", "ShardedTwinConfig", "ShardedTickReport",
    "FederatedTwinServer", "FederatedTwinConfig",
    "FrontDoorClient", "IngestFrontDoor",
    "GuardConfig", "GuardEvent",
    "ScenarioConfig", "ScenarioResult", "ScenarioRefused",
    "RecoveryConfig", "ChaosConfig",
    "DegradationConfig", "DegradationEvent",
]

# --------------------------------------------------------------------------- #
# INTERNAL building blocks, exported for tests/benchmarks/extension authors.
# Subject to change without deprecation (packed layouts, wire framing,
# scheduler internals) — depend on the stable surface instead where possible.
# --------------------------------------------------------------------------- #
_INTERNAL = [
    "FederationCoordinator", "ShardWorker",
    "DivergenceGuard", "GuardInstruments", "GuardRotation",
    "ScenarioRunner", "effective_k",
    "FederationConfig", "PackedFleet", "PackedRefitScheduler",
    "PriorityBuckets", "RefitScheduler", "SchedulerConfig", "SchedulePlan",
    "SchedulerMetrics", "SlotFederation", "TwinRecord",
    "fleet_pressure", "fleet_scores",
    "ChaosInjector", "DegradationPolicy", "ShardFailure",
    "TelemetryJournal", "TwinCheckpointer",
    "RingConfig", "StagingBuffer", "StagingOverflow", "TelemetryRing",
    "prepare_flush",
]

__all__ = _STABLE + _INTERNAL
