"""Multi-process federation: the fleet served as a real service.

`ShardedTwinServer` (twin/sharded.py) proved the architecture — N shards,
a global slot budget following pressure, a supervisor that restarts dead
shards from checkpoint + journal replay — but every shard shares one
Python process, one GIL, one device context.  This module runs the SAME
architecture across real process boundaries:

    telemetry producers                      FederationCoordinator
    (FrontDoorClient) ──IngestBatch──▶ IngestFrontDoor ─▶ journal ─▶ route
                                               │ per-worker pipes (wire.py)
                          ┌────────────────────┼────────────────────┐
                    TickCmd/grants       TickCmd/grants       TickCmd/grants
                    TickDone/pressure    TickDone/pressure    TickDone/pressure
                          │                    │                    │
                     ShardWorker          ShardWorker          ShardWorker
                     (subprocess:         (subprocess:         (subprocess:
                      TwinServer +         TwinServer +         TwinServer +
                      TwinCheckpointer)    TwinCheckpointer)    TwinCheckpointer)

Division of state, dictated by what must survive a worker death:

  * WORKER-side: the serving state (rings, fleet slots, theta store) and
    its `TwinCheckpointer` — checkpoints are the worker's durable truth,
    written to the shared `RecoveryConfig.ckpt_dir`.
  * COORDINATOR-side: the `TelemetryJournal` (one per worker — a sample is
    journaled BEFORE it is routed, so the coordinator can replay the
    suffix a dead worker never checkpointed), the `SlotFederation`, the
    chaos schedule, and twin placement.

Failure protocol (mirrors the in-process supervisor tick for tick):
a worker that times out, EOFs, or replies `ErrorMsg` is killed and marked
dead; its grant flows to survivors at the immediate rebalance; ingest for
its twins is journal-only until restart.  After `restart_delay_ticks`
supervisor ticks, a fresh process boots, restores the newest COMMITTED
checkpoint, and reports per-twin sample counts in `Hello`; the
coordinator replays exactly the journal suffix past those counts
(`force=True` ingest — replay must not be shed), drains, and the worker
rejoins the federation with its pre-crash pressure EMA intact.

The coordinator only ever speaks `twin/wire.py` messages — it never
reaches into worker internals — which is what lets workers and
coordinator restart independently (the wire version is the compatibility
gate) and is why the whole thing fits behind the `TwinService` protocol:
`FederatedTwinServer` here, `ShardedTwinServer`, and `TwinServer` are
interchangeable to every caller in this repo (benchmarks, examples, the
conformance suite).

Worker boot is NOT cheap (a fresh JAX import + module compile, seconds,
plus `restart_delay_ticks`); size `RecoveryConfig.journal_horizon` to
cover the boot window at your ingest rate or replay will report lost
samples.
"""
from __future__ import annotations

import multiprocessing as mp
import threading
import time
import traceback
from collections import deque
from dataclasses import dataclass

import numpy as np

from repro.obs import MetricRegistry, Tracer
from repro.twin.monitor import GuardEvent
from repro.twin.recovery import TelemetryJournal, TwinCheckpointer, \
    ChaosInjector
from repro.twin.scenario import ScenarioRefused, ScenarioResult
from repro.twin.scheduler import SlotFederation
from repro.twin.server import _HISTORY, TwinServer, TwinServerConfig
from repro.twin.sharded import ShardedTickReport
from repro.twin.service import FleetTopologyConfig
from repro.twin import wire as W

__all__ = ["FederatedTwinConfig", "ShardWorker", "FederationCoordinator",
           "FederatedTwinServer"]


@dataclass(frozen=True)
class FederatedTwinConfig(FleetTopologyConfig):
    """Multi-process fleet: same topology surface as `ShardedTwinConfig`
    (one `FleetTopologyConfig` base — the configs cannot drift), plus the
    process-boundary knobs."""
    servers: tuple[TwinServerConfig, ...] = ()   # one per worker process
    tick_timeout_s: float = 60.0      # reply deadline before a worker is
                                      # declared dead (generous: first tick
                                      # compiles the serving kernels)
    boot_timeout_s: float = 300.0     # spawn -> Hello deadline
    front_door: bool = False          # open the TCP ingestion door
    front_host: str = "127.0.0.1"
    front_port: int = 0               # 0: ephemeral (read .front_address)
    start_method: str = "spawn"       # fork is unsafe under JAX threads

    @staticmethod
    def uniform(server: TwinServerConfig, workers: int,
                **kw) -> "FederatedTwinConfig":
        """N identical worker processes."""
        return FederatedTwinConfig(servers=(server,) * workers, **kw)


# --------------------------------------------------------------------------- #
# worker process entry (module-level: spawn must import it by name)
# --------------------------------------------------------------------------- #
def _worker_main(conn, scfg: TwinServerConfig, shard: int, recovery) -> None:
    """One `ShardWorker` subprocess: TwinServer + its checkpointer behind a
    wire-message loop.  Boot: build, restore the newest committed
    checkpoint, announce holdings in `Hello`.  Any command that raises
    sends `ErrorMsg` and exits — the coordinator treats that as a death
    and runs the restart protocol."""
    srv = TwinServer(scfg, seed=scfg.seed + shard)
    ckpt = TwinCheckpointer(recovery, metrics=srv.metrics) \
        if recovery is not None else None
    ckpt_tick = None
    if ckpt is not None:
        ckpt_tick, state = ckpt.restore_latest(shard, srv.snapshot_state())
        if state is not None:
            srv.restore_state(state)
    samples = {int(tid): int(rec.samples)
               for tid, rec in srv.twin_snapshot().items()}
    conn.send_bytes(W.encode(W.Hello(
        shard=shard, tick=int(srv.tick_count), ckpt_tick=ckpt_tick,
        samples=samples)))
    last_saved = ckpt_tick
    try:
        while True:
            try:
                msg = W.decode(conn.recv_bytes())
            except EOFError:
                break                       # coordinator went away
            if isinstance(msg, W.Shutdown):
                break
            if isinstance(msg, W.IngestBatch):        # fire-and-forget
                srv.ingest_many(msg.chunks(), force=msg.force)
            elif isinstance(msg, W.Deploy):           # fire-and-forget
                srv.deploy_many([int(t) for t in msg.twin_ids], msg.thetas)
            elif isinstance(msg, W.TickCmd):
                if msg.grant >= 0:
                    srv.set_active_slots(msg.grant)
                srv.inject_delay_s = msg.inject_delay_s
                rep = srv.tick()
                if ckpt is not None and ckpt.maybe_save(
                        shard, srv.tick_count, srv.snapshot_state):
                    last_saved = srv.tick_count
                conn.send_bytes(W.encode(W.TickDone(
                    tick=int(srv.tick_count),
                    latency_s=float(rep.latency_s),
                    deadline_met=bool(rep.deadline_met),
                    n_active=int(rep.n_active),
                    n_twins=int(rep.n_twins),
                    n_guarded=int(rep.n_guarded),
                    degraded_level=int(rep.degraded_level),
                    pressure=float(srv.refit_pressure()),
                    loss=None if rep.loss is None else float(rep.loss),
                    ckpt_tick=last_saved,
                    events=[[int(e.twin_id), e.kind, float(e.score),
                             int(e.tick), float(e.confidence)]
                            for e in rep.events])))
            elif isinstance(msg, W.DrainCmd):
                srv.drain()
                conn.send_bytes(W.encode(W.Ack()))
            elif isinstance(msg, W.PredictCmd):
                # a bad request (unknown twin, nothing deployed) is the
                # CALLER's error — reply it, don't take the worker down
                try:
                    ys = srv.predict(msg.twin_id, msg.horizon, msg.us)
                except (KeyError, ValueError, RuntimeError) as e:
                    conn.send_bytes(W.encode(W.ErrorMsg(
                        where="predict", error=str(e))))
                else:
                    conn.send_bytes(W.encode(W.PredictResult(
                        ys=np.asarray(ys))))
            elif isinstance(msg, W.Scenario):
                # ScenarioRefused is a RuntimeError: a refusal under
                # deadline pressure rides the same error reply, and the
                # coordinator re-raises the precise type from its message
                try:
                    res = srv.scenario(msg.twin_id, msg.horizon, msg.us,
                                       k=msg.k)
                except (KeyError, ValueError, RuntimeError) as e:
                    conn.send_bytes(W.encode(W.ErrorMsg(
                        where="scenario", error=str(e))))
                else:
                    conn.send_bytes(W.encode(W.ScenarioResult(
                        twin_id=int(res.twin_id), horizon=int(res.horizon),
                        requested_k=int(res.requested_k), k=int(res.k),
                        degraded_level=int(res.degraded_level),
                        ys=np.asarray(res.ys), lo=np.asarray(res.lo),
                        hi=np.asarray(res.hi),
                        confidence=np.asarray(res.confidence))))
            elif isinstance(msg, W.StatsCmd):
                if msg.kind == "reset":
                    srv.reset_latency_stats()
                    conn.send_bytes(W.encode(W.Ack()))
                else:
                    data = (srv.latency_summary() if msg.kind == "latency"
                            else srv.stage_summary())
                    conn.send_bytes(W.encode(W.Stats(
                        data={k: (None if v is None else
                                  float(v) if isinstance(v, (int, float))
                                  else v)
                              for k, v in data.items()})))
            elif isinstance(msg, W.SnapshotCmd):
                conn.send_bytes(W.encode(
                    W.SnapshotBlob.pack(srv.snapshot_state())))
            else:
                raise W.WireError(
                    f"worker cannot handle {type(msg).TYPE!r}")
    except Exception:                       # noqa: BLE001 — report, then die
        try:
            conn.send_bytes(W.encode(W.ErrorMsg(
                where=f"shard{shard}", error=traceback.format_exc())))
        except OSError:
            pass
    finally:
        try:
            if ckpt is not None:
                ckpt.wait()
            srv.close()
        finally:
            conn.close()


# --------------------------------------------------------------------------- #
# coordinator-side worker handle
# --------------------------------------------------------------------------- #
class ShardWorker:
    """Coordinator-side proxy for one worker subprocess: the process, its
    pipe, and the last federation-relevant facts it reported.  All sends
    hold `_send_lock` (front-door threads ingest concurrently with the
    serving thread); only the serving thread ever receives."""

    def __init__(self, ctx, scfg: TwinServerConfig, shard: int, recovery):
        self.shard = shard
        self.conn, child = ctx.Pipe(duplex=True)
        self.proc = ctx.Process(
            target=_worker_main, args=(child, scfg, shard, recovery),
            name=f"twin-worker-{shard}", daemon=True)
        self.proc.start()
        child.close()                       # the worker owns its end now
        self._send_lock = threading.Lock()
        self.alive = True
        self.pressure = 0.0                 # last reported refit pressure
        self.n_twins = 0
        self.hello: W.Hello | None = None

    def wait_hello(self, timeout: float) -> W.Hello:
        msg = self.request_raw(timeout)
        if not isinstance(msg, W.Hello):
            raise W.WireError(f"worker {self.shard}: expected hello, got "
                              f"{type(msg).TYPE!r}")
        self.hello = msg
        return msg

    def send(self, msg) -> bool:
        """Fire-and-forget; False (and dead-marking is the caller's job)
        when the pipe is already broken."""
        if not self.alive:
            return False
        payload = W.encode(msg)
        try:
            with self._send_lock:
                self.conn.send_bytes(payload)
            return True
        except (BrokenPipeError, OSError):
            return False

    def request_raw(self, timeout: float):
        """One reply off the pipe (serving thread only).  Raises
        `TimeoutError`/`EOFError`/`WireError` — callers translate any of
        those into a death."""
        if not self.conn.poll(timeout):
            raise TimeoutError(f"worker {self.shard}: no reply in "
                               f"{timeout:.1f}s")
        msg = W.decode(self.conn.recv_bytes())
        if isinstance(msg, W.ErrorMsg):
            raise W.WireError(
                f"worker {self.shard} failed in {msg.where}:\n{msg.error}")
        return msg

    def request(self, msg, want: type, timeout: float):
        if not self.send(msg):
            raise EOFError(f"worker {self.shard}: pipe closed")
        reply = self.request_raw(timeout)
        if not isinstance(reply, want):
            raise W.WireError(f"worker {self.shard}: expected "
                              f"{want.TYPE!r}, got {type(reply).TYPE!r}")
        return reply

    def kill(self) -> None:
        self.alive = False
        if self.proc.is_alive():
            self.proc.kill()
        self.proc.join(timeout=5.0)
        self.conn.close()


# --------------------------------------------------------------------------- #
# the coordinator / federated server
# --------------------------------------------------------------------------- #
class FederationCoordinator:
    """Owns N `ShardWorker` subprocesses; implements the `TwinService`
    surface by routing over the wire.  See the module docstring for the
    state split and failure protocol.  Threading: `ingest`/`ingest_many`
    are safe from many producer threads (per-worker send locks + a
    journal lock); `tick`, `drain`, `deploy*`, `predict`,
    `snapshot_state` belong to ONE serving thread, exactly like the
    in-process servers."""

    def __init__(self, cfg: FederatedTwinConfig, *,
                 metrics: MetricRegistry | None = None,
                 tracer: Tracer | None = None):
        if not cfg.servers:
            raise ValueError("need at least one worker")
        self.cfg = cfg
        self.metrics = MetricRegistry() if metrics is None else metrics
        self.tracer = Tracer(enabled=False) if tracer is None else tracer
        self._ctx = mp.get_context(cfg.start_method)

        self.journals = ([TelemetryJournal(cfg.recovery.journal_horizon
                                           or s.capacity)
                          for s in cfg.servers]
                         if cfg.recovery is not None else None)
        self.chaos = (ChaosInjector(cfg.chaos)
                      if cfg.chaos is not None else None)
        # coordinator-side checkpointer handle: NEVER saves (workers own
        # that); exists so chaos can tear a dead worker's newest commit
        self._ckpt_view = (TwinCheckpointer(cfg.recovery,
                                            metrics=self.metrics)
                           if cfg.recovery is not None else None)

        self._instruments()
        t0 = time.perf_counter()
        self.workers: list[ShardWorker] = [
            ShardWorker(self._ctx, scfg, i, cfg.recovery)
            for i, scfg in enumerate(cfg.servers)]
        for w in self.workers:
            w.wait_hello(cfg.boot_timeout_s)
            self._m_boot.observe(time.perf_counter() - t0)

        pools = [s.refit_slots for s in cfg.servers]
        self.federation = SlotFederation(cfg.make_federation(pools), pools)
        self.grants = self.federation.rebalance([0.0] * len(pools))
        for g, gauge in zip(self.grants, self._m_grants):
            gauge.set(g)

        self._placement: dict[int, int] = {}
        self._dead: dict[int, int] = {}       # shard -> tick it died on
        self.tick_count = 0
        self.latencies: deque = deque(maxlen=_HISTORY)
        self.refresh_counts: deque = deque(maxlen=_HISTORY)
        self.deadline_s = (cfg.deadline_s if cfg.deadline_s is not None
                           else min(s.deadline_s for s in cfg.servers))

    def _instruments(self) -> None:
        """Same families the in-process supervisor exports (dashboards work
        unchanged) + the process-boundary extras."""
        M, n = self.metrics, len(self.cfg.servers)
        self._m_tick = M.histogram(
            "twin_fleet_tick_latency_seconds",
            help="full federated serving-tick wall latency (all workers)",
            unit="seconds")
        self._m_violations = M.counter(
            "twin_fleet_deadline_violations_total",
            help="federated ticks exceeding the fleet deadline")
        self._m_refreshes = M.counter(
            "twin_fleet_slot_refreshes_total",
            help="refit-slot train advances across all workers")
        self._m_grants = [
            M.gauge("twin_shard_slot_grant",
                    help="active refit-slot grant from the federation",
                    labels={"shard": str(i)}) for i in range(n)]
        self._m_deaths = M.counter(
            "twin_shard_deaths_total",
            help="worker-process deaths the coordinator handled")
        self._m_restarts = M.counter(
            "twin_shard_restarts_total",
            help="supervised worker restarts (checkpoint + journal replay)")
        self._m_dead = M.gauge(
            "twin_dead_shards", help="worker processes currently down")
        self._m_recovery = M.histogram(
            "twin_recovery_ticks",
            help="coordinator ticks a worker spent down before its restart "
                 "completed", unit="ticks")
        self._m_replayed = M.counter(
            "twin_replay_samples_total",
            help="journal samples replayed into restarted workers")
        self._m_replay_lost = M.counter(
            "twin_replay_lost_samples_total",
            help="samples past the journal horizon at restart")
        self._m_slow_inj = M.counter(
            "twin_chaos_slow_injections_total",
            help="injected straggler sleeps forwarded to worker ticks")
        self._m_boot = M.histogram(
            "twin_worker_boot_seconds",
            help="spawn -> Hello latency of a worker process (includes "
                 "JAX import and checkpoint restore)", unit="seconds")
        self._m_ingest_sent = M.counter(
            "twin_coord_ingest_batches_total",
            help="ingest batches routed to workers over the wire")

    # -- placement + TwinService surface -------------------------------- #
    @property
    def n_workers(self) -> int:
        return len(self.workers)

    def shard_of(self, twin_id: int) -> int:
        s = self._placement.get(twin_id)
        if s is None:
            s = twin_id % self.n_workers
            self._placement[twin_id] = s
        return s

    def register(self, twin_id: int, shard: int | None = None) -> int:
        """Pin placement (workers register lazily on first ingest);
        returns the worker index.  Conflicting re-pins raise, matching
        `ShardedTwinServer.register`."""
        if shard is not None:
            prev = self._placement.setdefault(twin_id, shard)
            if prev != shard:
                raise ValueError(f"twin {twin_id} already placed on worker "
                                 f"{prev}, cannot move to {shard}")
        return self.shard_of(twin_id)

    def _live_worker(self, i: int) -> ShardWorker:
        w = self.workers[i]
        if not w.alive:
            raise RuntimeError(f"worker {i} is down (died at tick "
                               f"{self._dead.get(i)}; restart pending)")
        return w

    def ingest(self, twin_id: int, y, u=None, *, force: bool = False):
        """Journal-first routed ingest; dead-worker samples are journal-only
        until replay (producers never block on a crash)."""
        self.ingest_many([(twin_id, y, u)], force=force)

    def ingest_many(self, batch, *, force: bool = False) -> int:
        """One wire batch per worker — this is the front door's sink, so a
        producer flush of any size costs at most `n_workers` pipe writes."""
        staged = 0
        by_worker: dict[int, list] = {}
        for chunk in batch:
            tid, y = chunk[0], chunk[1]
            u = chunk[2] if len(chunk) > 2 else None
            s = self.shard_of(tid)
            copies = 1 + (self.chaos.storm_extra(s, self.tick_count)
                          if self.chaos is not None else 0)
            for _ in range(copies):
                if self.journals is not None:
                    self.journals[s].append(tid, y, u)
                by_worker.setdefault(s, []).append((tid, y, u))
            staged += np.atleast_2d(np.asarray(y)).shape[0]
        for s, chunks in by_worker.items():
            w = self.workers[s]
            if w.alive:
                w.send(W.IngestBatch.from_chunks(chunks, force=force))
                self._m_ingest_sent.inc()
        return staged

    def deploy(self, twin_id: int, theta) -> None:
        self.deploy_many([twin_id], np.asarray(theta)[None])

    def deploy_many(self, twin_ids, thetas) -> None:
        """Warm-start across workers: one Deploy frame per worker.  Raises
        on a dead target — a warm start cannot be journaled (thetas are not
        telemetry), so refusing beats silently dropping."""
        thetas = np.asarray(thetas)
        by_worker: dict[int, list[int]] = {}
        for k, tid in enumerate(twin_ids):
            by_worker.setdefault(self.shard_of(tid), []).append(k)
        for s, ks in by_worker.items():
            ids = np.asarray([int(twin_ids[k]) for k in ks], np.int64)
            block = thetas if thetas.ndim == 2 else thetas[ks]
            if not self._live_worker(s).send(W.Deploy(twin_ids=ids,
                                                      thetas=block)):
                raise RuntimeError(f"worker {s} died mid-deploy")

    def predict(self, twin_id: int, horizon: int, us=None):
        w = self._live_worker(self.shard_of(twin_id))
        try:
            return w.request(
                W.PredictCmd(twin_id=int(twin_id), horizon=int(horizon),
                             us=None if us is None else np.asarray(us)),
                W.PredictResult, self.cfg.tick_timeout_s).ys
        except W.WireError as e:
            # logical refusal (unknown twin, nothing deployed): the worker
            # is fine — surface the same error shape TwinServer raises
            raise RuntimeError(str(e)) from e
        except (TimeoutError, EOFError):
            self._mark_dead(w.shard)
            raise

    def scenario(self, twin_id: int, horizon: int, us=None,
                 k: int | None = None):
        """What-if fan-out across the process boundary: the owning worker
        answers from its live theta store at its OWN degradation level."""
        w = self._live_worker(self.shard_of(twin_id))
        try:
            r = w.request(
                W.Scenario(twin_id=int(twin_id), horizon=int(horizon),
                           k=None if k is None else int(k),
                           us=None if us is None
                           else np.asarray(us, np.float32)),
                W.ScenarioResult, self.cfg.tick_timeout_s)
        except W.WireError as e:
            msg = str(e)
            if "scenario refused" in msg:
                raise ScenarioRefused(msg) from e
            raise RuntimeError(msg) from e
        except (TimeoutError, EOFError):
            self._mark_dead(w.shard)
            raise
        return ScenarioResult(twin_id=int(r.twin_id), horizon=int(r.horizon),
                              requested_k=int(r.requested_k), k=int(r.k),
                              degraded_level=int(r.degraded_level),
                              ys=r.ys, lo=r.lo, hi=r.hi,
                              confidence=r.confidence)

    # -- the supervisor tick -------------------------------------------- #
    def _alive(self) -> list[bool]:
        return [w.alive for w in self.workers]

    def _rebalance(self) -> None:
        """Re-divide the global budget from the last REPORTED pressures —
        the post-tick values, exactly what the in-process supervisor reads
        live (no train work happens between a tick and its rebalance)."""
        pressures = [w.pressure if w.alive else 0.0 for w in self.workers]
        self.grants = self.federation.rebalance(pressures,
                                                alive=self._alive())
        for g, gauge in zip(self.grants, self._m_grants):
            gauge.set(g)

    def _mark_dead(self, i: int) -> None:
        w = self.workers[i]
        if not w.alive:
            return
        w.kill()
        self._dead[i] = self.tick_count
        self._m_deaths.inc()
        self._m_dead.set(len(self._dead))
        if (self.chaos is not None and self._ckpt_view is not None
                and self.chaos.should_tear()):
            self._ckpt_view.tear_latest(i)
        self._rebalance()

    def kill_worker(self, i: int) -> None:
        """Operational/chaos hook: SIGKILL worker `i` now.  The journal
        already holds everything it was sent; the supervised restart
        replays the un-checkpointed suffix."""
        self._mark_dead(i)

    def tick(self) -> ShardedTickReport:
        """One federated cycle, same shape as the in-process supervisor:
        restart due workers, fan `TickCmd` out to every live worker, then
        collect every `TickDone` — send-all-then-collect, so workers tick
        CONCURRENTLY (this is the multi-core speedup the process split
        exists for).  A worker death never fails the supervisor tick."""
        with self.tracer.span("federated_tick", tick=self.tick_count + 1,
                              workers=self.n_workers):
            t0 = time.perf_counter()
            self.tick_count += 1
            restarted: list[dict] = []
            if self._dead and self.cfg.recovery is not None:
                for i, died_at in sorted(self._dead.items()):
                    if (self.tick_count - died_at
                            >= self.cfg.recovery.restart_delay_ticks):
                        with self.tracer.span("restart_worker", shard=i):
                            restarted.append(self._restart_worker(i))
            ticked: list[int] = []
            for i, w in enumerate(self.workers):
                if not w.alive:
                    continue
                if self.chaos is not None:
                    if self.chaos.should_kill(i, self.tick_count):
                        self._mark_dead(i)
                        continue
                    delay = self.chaos.slow_delay(i, self.tick_count)
                    if delay > 0:
                        self._m_slow_inj.inc()
                else:
                    delay = 0.0
                if w.send(W.TickCmd(tick=self.tick_count,
                                    grant=self.grants[i],
                                    inject_delay_s=delay)):
                    ticked.append(i)
                else:
                    self._mark_dead(i)
            reports: list = [None] * self.n_workers
            deadline = time.monotonic() + self.cfg.tick_timeout_s
            for i in ticked:
                w = self.workers[i]
                try:
                    done = w.request_raw(
                        max(0.05, deadline - time.monotonic()))
                    if not isinstance(done, W.TickDone):
                        raise W.WireError(
                            f"worker {i}: expected tick_done, got "
                            f"{type(done).TYPE!r}")
                except (TimeoutError, EOFError, OSError, W.WireError):
                    self._mark_dead(i)
                    continue
                w.pressure = done.pressure
                w.n_twins = done.n_twins
                reports[i] = done
            if restarted or self.tick_count % self.cfg.rebalance_every == 0:
                with self.tracer.span("rebalance"):
                    self._rebalance()
            latency = time.perf_counter() - t0
        self.latencies.append(latency)
        self._m_tick.observe(latency)
        if latency > self.deadline_s:
            self._m_violations.inc()
        live = [r for r in reports if r is not None]
        n_active = sum(r.n_active for r in live)
        self.refresh_counts.append(n_active)
        if n_active:
            self._m_refreshes.inc(n_active)
        self._m_dead.set(len(self._dead))
        return ShardedTickReport(
            tick=self.tick_count, latency_s=latency,
            deadline_met=latency <= self.deadline_s,
            reports=reports, grants=list(self.grants),
            events=[GuardEvent(twin_id=e[0], kind=e[1], score=e[2],
                               tick=e[3],
                               # tolerate 4-tuple events from pre-confidence
                               # workers (rolling upgrade across versions)
                               confidence=e[4] if len(e) > 4 else 1.0)
                    for r in live for e in r.events],
            n_active=n_active,
            n_twins=sum(r.n_twins for r in live),
            n_guarded=sum(r.n_guarded for r in live),
            degraded_level=max((r.degraded_level for r in live), default=0),
            dead_shards=len(self._dead),
            restarted=restarted,
            replayed_samples=sum(r["replayed"] for r in restarted))

    def _restart_worker(self, i: int) -> dict:
        """Supervised restart across the process boundary: spawn, let the
        worker restore its own newest committed checkpoint, read its
        `Hello` sample counts, replay exactly the journal suffix past
        them, drain.  Returns the restart record for the tick report."""
        t0 = time.perf_counter()
        w = ShardWorker(self._ctx, self.cfg.servers[i], i,
                        self.cfg.recovery)
        hello = w.wait_hello(self.cfg.boot_timeout_s)
        self._m_boot.observe(time.perf_counter() - t0)
        self.workers[i] = w
        died_at = self._dead.pop(i)
        replayed = lost = 0
        if self.journals is not None:
            journal = self.journals[i]
            seen = {int(k): int(v) for k, v in hello.samples.items()}
            chunks: list = []
            for tid in journal.twin_ids():
                tail, lost_t = journal.replay_since(tid, seen.get(tid, 0))
                lost += lost_t
                for y, u in tail:
                    chunks.append((tid, y, u))
                    replayed += len(y)
            if chunks:
                # force: replay must not be shed by staging backpressure
                w.send(W.IngestBatch.from_chunks(chunks, force=True))
            w.request(W.DrainCmd(), W.Ack, self.cfg.tick_timeout_s)
        down = self.tick_count - died_at
        self._m_restarts.inc()
        self._m_recovery.observe(down)
        self._m_replayed.inc(replayed)
        if lost:
            self._m_replay_lost.inc(lost)
        self._m_dead.set(len(self._dead))
        return {"shard": i, "ckpt_tick": hello.ckpt_tick,
                "replayed": replayed, "lost": lost, "down_ticks": down}

    # -- barriers, stats, shutdown -------------------------------------- #
    def drain(self) -> None:
        """Barrier: every routed sample reaches its worker's ring."""
        for w in self.workers:
            if not w.alive:
                continue
            try:
                w.request(W.DrainCmd(), W.Ack, self.cfg.tick_timeout_s)
            except (TimeoutError, EOFError, W.WireError):
                self._mark_dead(w.shard)

    def snapshot_state(self) -> dict:
        """Host pytree: one worker `snapshot_state` sub-tree per LIVE
        worker, keyed `"shard<i>"` — the `ShardedTwinServer` shape, so
        fleet snapshots are interchangeable across deployments."""
        out = {}
        for i, w in enumerate(self.workers):
            if not w.alive:
                continue
            blob = w.request(W.SnapshotCmd(), W.SnapshotBlob,
                             self.cfg.tick_timeout_s)
            out[f"shard{i}"] = blob.unpack()
        return out

    def _worker_stats(self, kind: str) -> list[dict]:
        out = []
        for w in self.workers:
            if not w.alive:
                continue
            out.append(w.request(W.StatsCmd(kind=kind), W.Stats,
                                 self.cfg.tick_timeout_s).data)
        return out

    def latency_summary(self) -> dict:
        """p50/p99 of the WHOLE federated tick + aggregate throughput
        (the `ShardedTwinServer.latency_summary` shape)."""
        h = self._m_tick
        ticks = h.count
        if ticks == 0:
            return {"ticks": 0}
        worker = self._worker_stats("latency")
        return {
            "ticks": ticks,
            "p50_ms": h.quantile(0.5) * 1e3,
            "p99_ms": h.quantile(0.99) * 1e3,
            "max_ms": h.max * 1e3,
            "deadline_s": self.deadline_s,
            "violations": int(self._m_violations.value),
            "twin_refreshes_per_s":
                self._m_refreshes.value / max(h.sum, 1e-9),
            "dropped_samples": sum(int(s.get("dropped_samples", 0))
                                   for s in worker),
            "flush_overflows": sum(int(s.get("flush_overflows", 0))
                                   for s in worker),
        }

    def stage_summary(self) -> dict:
        """Aggregate per-tick stage cost across workers (ms)."""
        out: dict[str, float] = {}
        for data in self._worker_stats("stage"):
            for k, v in data.items():
                out[k] = out.get(k, 0.0) + v
        return out

    def reset_latency_stats(self) -> None:
        self.latencies.clear()
        self.refresh_counts.clear()
        self._m_tick.reset()
        self._m_violations.reset()
        self._m_refreshes.reset()
        for w in self.workers:
            if not w.alive:
                continue
            try:
                w.request(W.StatsCmd(kind="reset"), W.Ack,
                          self.cfg.tick_timeout_s)
            except (TimeoutError, EOFError, W.WireError):
                self._mark_dead(w.shard)

    def close(self) -> None:
        """Shut every worker down (idempotent); stragglers are killed."""
        for w in self.workers:
            if w.alive:
                w.send(W.Shutdown())
        for w in self.workers:
            if w.alive:
                w.proc.join(timeout=10.0)
                w.alive = False
                if w.proc.is_alive():
                    w.proc.kill()
                    w.proc.join(timeout=5.0)
                w.conn.close()


class FederatedTwinServer(FederationCoordinator):
    """`FederationCoordinator` + the network ingestion front door: the
    third `TwinService` implementation (see twin/service.py).  With
    `cfg.front_door=True`, telemetry producers connect a
    `FrontDoorClient` to `.front_address` and their batches land in the
    coordinator journal (durability first) before being routed — the
    full production shape of the paper's online-twinning loop."""

    def __init__(self, cfg: FederatedTwinConfig, *,
                 metrics: MetricRegistry | None = None,
                 tracer: Tracer | None = None):
        super().__init__(cfg, metrics=metrics, tracer=tracer)
        self.front_door = (W.IngestFrontDoor(self.ingest_many,
                                             host=cfg.front_host,
                                             port=cfg.front_port)
                           if cfg.front_door else None)

    @property
    def front_address(self):
        """(host, port) producers dial, or None without a front door."""
        return None if self.front_door is None else self.front_door.address

    def close(self) -> None:
        if self.front_door is not None:
            self.front_door.close()
            self.front_door = None
        super().close()
