"""Slot-based refit scheduling: thousands of twins, a bounded compute budget.

Mirrors serve/engine.ServeEngine's admission pattern: a FIXED number of refit
slots (the FleetMerinda fleet axis — one fused train_step advances all of
them), with twins admitted into and evicted from slots dynamically.  The
device-side math stays static-shape; all policy runs here on the host over a
small registry of `TwinRecord`s.

Priority model (computed per twin, higher = refit sooner):

    priority = staleness_weight * staleness + divergence_weight * divergence

  * staleness   — samples ingested since the twin's model was last deployed,
    normalized by the refit window span; a never-deployed twin gets a +1
    bonus (it has NO model, the worst kind of stale).
  * divergence  — the guard score from twin/monitor.py (normalized rollout
    error of the deployed model on the newest telemetry).  This is the
    collision-avoidance signal: a twin whose physics changed outranks every
    merely-stale twin.

Slot turnover:
  * free slots are filled by the highest-priority READY twins (enough samples
    for a full window batch);
  * a resident twin can be PREEMPTED by a waiting twin whose priority exceeds
    the resident's by `evict_margin`, but only after `min_residency` ticks
    (refits must get enough steps to converge before the slot churns);
  * a resident twin that has both converged (>= `max_residency` ticks) and
    gone quiet (divergence below `release_divergence`) RELEASES its slot
    voluntarily — the mechanism that lets a big fleet rotate through a small
    slot pool.
"""
from __future__ import annotations

from dataclasses import dataclass, field

__all__ = ["TwinRecord", "SchedulerConfig", "SchedulePlan", "RefitScheduler"]


@dataclass
class TwinRecord:
    """Host-side registry entry for one tracked object."""
    twin_id: int
    ring_slot: int                    # row in TelemetryRing
    refit_slot: int | None = None     # FleetMerinda slot, None if waiting
    samples: int = 0                  # total telemetry ingested
    samples_at_deploy: int = 0
    deployed: bool = False            # has a theta in the serving store
    deploy_tick: int = -1
    admitted_tick: int = -1
    residency: int = 0                # ticks spent in current slot
    steps_in_slot: int = 0            # train steps in current slot
    divergence: float = 0.0           # EMA guard score


@dataclass(frozen=True)
class SchedulerConfig:
    slots: int
    min_samples: int                  # readiness: samples for one window batch
    staleness_weight: float = 1.0
    divergence_weight: float = 4.0
    evict_margin: float = 0.5         # challenger must beat resident by this
    min_residency: int = 8            # ticks before preemption allowed
    max_residency: int = 64           # ticks before voluntary release allowed
    release_divergence: float = 0.05  # ...and only if the twin tracks reality


@dataclass
class SchedulePlan:
    admit: list = field(default_factory=list)    # [(slot, twin_id)]
    evict: list = field(default_factory=list)    # [twin_id] preempted
    release: list = field(default_factory=list)  # [twin_id] converged


class RefitScheduler:
    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ #
    def priority(self, rec: TwinRecord) -> float:
        cfg = self.cfg
        staleness = (rec.samples - rec.samples_at_deploy) / max(cfg.min_samples, 1)
        if not rec.deployed:
            staleness += 1.0
        return (cfg.staleness_weight * staleness
                + cfg.divergence_weight * rec.divergence)

    def ready(self, rec: TwinRecord) -> bool:
        return rec.samples >= self.cfg.min_samples

    # ------------------------------------------------------------------ #
    def plan(self, twins: dict[int, TwinRecord]) -> SchedulePlan:
        """Decide this tick's slot turnover.  Pure: mutates nothing; the
        server applies the plan (device-side slot resets + record updates).

        Iteration is in twin_id order so equal-priority decisions are
        deterministic across runs.
        """
        cfg = self.cfg
        plan = SchedulePlan()
        residents = sorted((r for r in twins.values()
                            if r.refit_slot is not None),
                           key=lambda r: r.twin_id)
        waiting = sorted((r for r in twins.values()
                          if r.refit_slot is None and self.ready(r)),
                         key=lambda r: (-self.priority(r), r.twin_id))

        # voluntary release: converged, healthy residents hand back slots.
        # A resident stuck far past max_residency without converging is
        # released too (its divergence priority would otherwise let it starve
        # the waiting queue indefinitely).
        free: list[int] = sorted(set(range(cfg.slots))
                                 - {r.refit_slot for r in residents})
        kept: list[TwinRecord] = []
        # release only for waiting twins the already-free slots cannot
        # absorb — releasing more would idle slots and throw away converged
        # training state
        releasable = len(waiting) - len(free)
        for r in residents:
            healthy = r.deployed and r.divergence < cfg.release_divergence
            stuck = r.residency >= 2 * cfg.max_residency
            if (len(plan.release) < releasable
                    and ((r.residency >= cfg.max_residency and healthy)
                         or stuck)):
                plan.release.append(r.twin_id)
                free.append(r.refit_slot)
            else:
                kept.append(r)

        # fill free slots with the best waiting twins
        free.sort()
        for slot in free:
            if not waiting:
                break
            plan.admit.append((slot, waiting.pop(0).twin_id))

        # preemption: strongest challengers vs weakest eligible residents
        evictable = sorted((r for r in kept
                            if r.residency >= cfg.min_residency),
                           key=lambda r: (self.priority(r), r.twin_id))
        for r in evictable:
            if not waiting:
                break
            challenger = waiting[0]
            if self.priority(challenger) > self.priority(r) + cfg.evict_margin:
                waiting.pop(0)
                plan.evict.append(r.twin_id)
                plan.admit.append((r.refit_slot, challenger.twin_id))
            else:
                break   # residents below this one are even harder to beat
        return plan
