"""Slot-based refit scheduling: thousands of twins, a bounded compute budget.

Mirrors serve/engine.ServeEngine's admission pattern: a FIXED number of refit
slots (the FleetMerinda fleet axis — one fused train_step advances all of
them), with twins admitted into and evicted from slots dynamically.  The
device-side math stays static-shape; all policy runs here on the host over a
small registry of `TwinRecord`s.

Priority model (computed per twin, higher = refit sooner):

    priority = staleness_weight * staleness + divergence_weight * divergence

  * staleness   — samples ingested since the twin's model was last deployed,
    normalized by the refit window span; a never-deployed twin gets a +1
    bonus (it has NO model, the worst kind of stale).
  * divergence  — the guard score from twin/monitor.py (normalized rollout
    error of the deployed model on the newest telemetry).  This is the
    collision-avoidance signal: a twin whose physics changed outranks every
    merely-stale twin.

Slot turnover:
  * free slots are filled by the highest-priority READY twins (enough samples
    for a full window batch);
  * a resident twin can be PREEMPTED by a waiting twin whose priority exceeds
    the resident's by `evict_margin`, but only after `min_residency` ticks
    (refits must get enough steps to converge before the slot churns);
  * a resident twin that has both converged (>= `max_residency` ticks) and
    gone quiet (divergence below `release_divergence`) RELEASES its slot
    voluntarily — the mechanism that lets a big fleet rotate through a small
    slot pool.

Federation (sharded serving, twin/sharded.py): each shard runs its own
scheduler over its own twins; `SlotFederation` divides a GLOBAL active-slot
budget across shards in proportion to their aggregate staleness+divergence
`pressure`, and each shard's `plan(..., max_active=k)` honors its grant —
shedding surplus residents (lowest priority first) when the grant shrinks.
Physical slot pools stay fixed-shape (no recompiles); only the number of
slots a shard may FILL moves.

Two planners implement the SAME admission semantics:

  * `RefitScheduler` — the reference: iterates and sorts the whole
    `TwinRecord` dict per tick, O(n log n) host cost.  Retained as the
    equivalence oracle (tests/test_scheduler_equivalence.py) and for tiny
    fleets.
  * `PackedRefitScheduler` — the default (twin/server.py): scores the whole
    fleet in ONE fused jit-compiled device call over packed staleness /
    divergence arrays (twin/packed.py), pops the O(slots) winners through a
    `PriorityBuckets` queue, and leaves the host O(budget + log n) work per
    tick.  The 100k-twin planner.
"""
from __future__ import annotations

import heapq
import math
import time
import warnings
from dataclasses import dataclass, field

import numpy as np

from repro.twin.packed import PackedFleet, fleet_pressure, fleet_scores

__all__ = ["TwinRecord", "SchedulerConfig", "SchedulePlan", "SchedulerMetrics",
           "PriorityBuckets", "RefitScheduler", "PackedRefitScheduler",
           "FederationConfig", "SlotFederation"]


@dataclass
class TwinRecord:
    """Host-side registry entry for one tracked object."""
    twin_id: int
    ring_slot: int                    # row in TelemetryRing
    refit_slot: int | None = None     # FleetMerinda slot, None if waiting
    samples: int = 0                  # total telemetry ingested
    samples_at_deploy: int = 0
    deployed: bool = False            # has a theta in the serving store
    deploy_tick: int = -1
    admitted_tick: int = -1
    residency: int = 0                # ticks spent in current slot
    steps_in_slot: int = 0            # train steps in current slot
    divergence: float = 0.0           # EMA guard score


@dataclass(frozen=True)
class SchedulerConfig:
    slots: int
    min_samples: int                  # readiness: samples for one window batch
    staleness_weight: float = 1.0
    divergence_weight: float = 4.0
    evict_margin: float = 0.5         # challenger must beat resident by this
    min_residency: int = 8            # ticks before preemption allowed
    max_residency: int = 64           # ticks before voluntary release allowed
    release_divergence: float = 0.05  # ...and only if the twin tracks reality


@dataclass
class SchedulePlan:
    admit: list = field(default_factory=list)    # [(slot, twin_id)]
    evict: list = field(default_factory=list)    # [twin_id] preempted
    release: list = field(default_factory=list)  # [twin_id] converged


@dataclass
class SchedulerMetrics:
    """Slot-turnover instruments (obs registry children, one set per shard).

    `admitted`/`evicted`/`released` count slot transitions cumulatively;
    `pressure` is the latest aggregate staleness+divergence demand — the
    same number the federation rebalances on, so a fleet dashboard shows
    WHY grants moved.  `plan_seconds` is the pure planning cost (scoring +
    winner pops, excluding the server's slot-reset applies) — the scale
    benchmark's flatness evidence; `waiting` gauges the ready-but-unslotted
    backlog the planner draws from; `queue_entries` the candidate entries
    retained in the bucketed queue after a plan.
    """
    admitted: object            # Counter-like: .inc(n)
    evicted: object
    released: object
    pressure: object            # Gauge-like: .set(v)
    plan_seconds: object        # Histogram-like: .observe(s)
    waiting: object             # Gauge: ready twins without a slot
    queue_entries: object       # Gauge: live bucket-queue entries

    @staticmethod
    def create(registry, labels: dict | None = None) -> "SchedulerMetrics":
        """Resolve the scheduler's instruments from a `MetricRegistry`."""
        return SchedulerMetrics(
            admitted=registry.counter(
                "twin_sched_admitted_total",
                help="twins admitted into refit slots", labels=labels),
            evicted=registry.counter(
                "twin_sched_evicted_total",
                help="twins preempted out of refit slots", labels=labels),
            released=registry.counter(
                "twin_sched_released_total",
                help="twins that released their refit slot (converged, "
                     "stuck, or federation revoke)", labels=labels),
            pressure=registry.gauge(
                "twin_sched_pressure",
                help="aggregate staleness+divergence refit demand "
                     "(federation rebalance signal)", labels=labels),
            plan_seconds=registry.histogram(
                "twin_sched_plan_seconds",
                help="schedule-planning wall latency per tick (scoring + "
                     "winner selection, excluding slot-reset application)",
                unit="seconds", labels=labels),
            waiting=registry.gauge(
                "twin_sched_waiting",
                help="ready twins waiting for a refit slot (planner queue "
                     "depth)", labels=labels),
            queue_entries=registry.gauge(
                "twin_sched_queue_entries",
                help="live candidate entries held by the bucketed priority "
                     "queue after planning", labels=labels))


# --------------------------------------------------------------------------- #
# PriorityBuckets: quantized-priority queue with lazy deletion
# --------------------------------------------------------------------------- #
class PriorityBuckets:
    """Bucketed max-priority queue: O(1) push/discard, cheap ordered pops.

    Priorities are quantized to `quantum`-wide buckets (level =
    floor(priority / quantum)); a lazy max-heap tracks non-empty levels, and
    entries are lazily deleted — `discard`/re-`push` just version-bumps the
    key, and stale bucket entries are skipped (and pruned) when a pop
    reaches their bucket.  The same live-set discipline as
    `GuardRotation`'s eligible-row array: mutation points pay O(1) and the
    consumer pays for exactly what it touches.

    Ordering contract: pops come out in EXACT (-priority, key) order, not
    merely bucket order — quantization is monotone, so cross-bucket order is
    exact for free, and within the one bucket a pop touches, live entries
    are compared exactly.  Cost per pop is O(touched-bucket size +
    log #levels); with `quantum` sized so a bucket holds O(budget) entries,
    a planning pass of B pops costs O(B + log n) — the bound that replaces
    the reference planner's O(n log n) full sorts.
    """

    __slots__ = ("quantum", "_buckets", "_levels", "_live", "_version")

    def __init__(self, quantum: float = 0.25):
        if not quantum > 0:
            raise ValueError("bucket quantum must be > 0")
        self.quantum = quantum
        self._buckets: dict[int, list] = {}   # level -> [(key, prio, payload, ver)]
        self._levels: list[int] = []          # negated levels (max-heap)
        self._live: dict = {}                 # key -> (prio, level, ver)
        self._version = 0

    def _level(self, prio: float) -> int:
        if not math.isfinite(prio):
            raise ValueError(f"priority must be finite, got {prio}")
        return int(math.floor(prio / self.quantum))

    def __len__(self) -> int:
        return len(self._live)

    @property
    def stale_entries(self) -> int:
        """Lazily-deleted entries still occupying buckets (pruned on pop)."""
        return sum(len(b) for b in self._buckets.values()) - len(self._live)

    def clear(self) -> None:
        self._buckets.clear()
        self._levels.clear()
        self._live.clear()

    def push(self, key, prio: float, payload=None) -> None:
        """Insert or reprioritize `key` (old entry is lazily deleted)."""
        level = self._level(prio)
        self._version += 1
        self._live[key] = (prio, level, self._version)
        bucket = self._buckets.get(level)
        if bucket is None:
            bucket = self._buckets[level] = []
            heapq.heappush(self._levels, -level)
        bucket.append((key, prio, payload, self._version))

    def discard(self, key) -> None:
        """Lazily delete `key` (no-op if absent)."""
        self._live.pop(key, None)

    def _top_bucket(self):
        """Highest level with a live entry, with its bucket pruned to live
        entries only; None when empty."""
        while self._levels:
            level = -self._levels[0]
            bucket = self._buckets.get(level, ())
            live = [e for e in bucket
                    if self._live.get(e[0], (None, None, -1))[2] == e[3]]
            if live:
                self._buckets[level] = live
                return live
            heapq.heappop(self._levels)
            self._buckets.pop(level, None)
        return None

    def peek(self):
        """Best live (key, prio, payload) by (-prio, key), or None."""
        bucket = self._top_bucket()
        if bucket is None:
            return None
        key, prio, payload, _ = min(bucket, key=lambda e: (-e[1], e[0]))
        return key, prio, payload

    def pop(self):
        """Remove and return the best live (key, prio, payload), or None."""
        bucket = self._top_bucket()
        if bucket is None:
            return None
        best = min(bucket, key=lambda e: (-e[1], e[0]))
        bucket.remove(best)
        del self._live[best[0]]
        return best[0], best[1], best[2]


class RefitScheduler:
    def __init__(self, cfg: SchedulerConfig,
                 metrics: SchedulerMetrics | None = None):
        self.cfg = cfg
        self.metrics = metrics

    # ------------------------------------------------------------------ #
    def priority(self, rec: TwinRecord) -> float:
        cfg = self.cfg
        staleness = (rec.samples - rec.samples_at_deploy) / max(cfg.min_samples, 1)
        if not rec.deployed:
            staleness += 1.0
        return (cfg.staleness_weight * staleness
                + cfg.divergence_weight * rec.divergence)

    def ready(self, rec: TwinRecord) -> bool:
        return rec.samples >= self.cfg.min_samples

    def pressure(self, twins: dict[int, TwinRecord]) -> float:
        """Aggregate refit demand: summed priority over READY twins (waiting
        AND resident — a shard actively refitting diverged twins is still
        under pressure).  The federation's rebalancing signal."""
        p = sum(self.priority(r) for r in twins.values() if self.ready(r))
        if self.metrics is not None:
            self.metrics.pressure.set(p)
        return p

    # ------------------------------------------------------------------ #
    def plan(self, twins: dict[int, TwinRecord],
             max_active: int | None = None) -> SchedulePlan:
        """Decide this tick's slot turnover.  Pure: mutates nothing; the
        server applies the plan (device-side slot resets + record updates).

        `max_active` caps how many physical slots may be FILLED (the
        federation grant); None means the whole pool.  When the grant drops
        below current occupancy, the lowest-priority residents are shed.

        Units: residency thresholds (`min_residency`, `max_residency`) are
        serving TICKS, not seconds or train steps; `min_samples` is ring
        telemetry samples.  Host cost is O(n log n) in the number of
        tracked twins (two sorts per tick) — the reason
        `PackedRefitScheduler` is the serving default; this planner is the
        semantics oracle.  Not thread-safe by itself; the server passes
        a `twin_snapshot()` registry copy so concurrent `ingest`
        registrations cannot race the iteration.

        Iteration is in twin_id order so equal-priority decisions are
        deterministic across runs.
        """
        t0 = time.perf_counter()
        cfg = self.cfg
        cap = (cfg.slots if max_active is None
               else max(0, min(cfg.slots, max_active)))
        plan = SchedulePlan()
        residents = sorted((r for r in twins.values()
                            if r.refit_slot is not None),
                           key=lambda r: r.twin_id)
        waiting = sorted((r for r in twins.values()
                          if r.refit_slot is None and self.ready(r)),
                         key=lambda r: (-self.priority(r), r.twin_id))
        n_waiting = len(waiting)

        # federation revoke: the grant shrank below occupancy — shed the
        # lowest-priority residents until the shard fits its grant
        if len(residents) > cap:
            shed = sorted(residents,
                          key=lambda r: (self.priority(r), r.twin_id))
            shed = shed[:len(residents) - cap]
            shed_ids = {r.twin_id for r in shed}
            plan.release.extend(sorted(shed_ids))
            residents = [r for r in residents if r.twin_id not in shed_ids]

        # voluntary release: converged, healthy residents hand back slots.
        # A resident stuck far past max_residency without converging is
        # released too (its divergence priority would otherwise let it starve
        # the waiting queue indefinitely).
        free: list[int] = sorted(set(range(cfg.slots))
                                 - {r.refit_slot for r in residents})
        kept: list[TwinRecord] = []
        # release only for waiting twins the free slots USABLE under the
        # grant cannot absorb — releasing more would idle slots and throw
        # away converged training state
        usable_free = min(len(free), cap - len(residents))
        releasable = len(waiting) - usable_free
        voluntary = 0
        for r in residents:
            healthy = r.deployed and r.divergence < cfg.release_divergence
            stuck = r.residency >= 2 * cfg.max_residency
            if (voluntary < releasable
                    and ((r.residency >= cfg.max_residency and healthy)
                         or stuck)):
                plan.release.append(r.twin_id)
                voluntary += 1
                free.append(r.refit_slot)
            else:
                kept.append(r)

        # fill free slots with the best waiting twins, up to the grant
        free.sort()
        budget = cap - len(kept)
        for slot in free:
            if not waiting or budget <= 0:
                break
            plan.admit.append((slot, waiting.pop(0).twin_id))
            budget -= 1

        # preemption: strongest challengers vs weakest eligible residents
        evictable = sorted((r for r in kept
                            if r.residency >= cfg.min_residency),
                           key=lambda r: (self.priority(r), r.twin_id))
        for r in evictable:
            if not waiting:
                break
            challenger = waiting[0]
            if self.priority(challenger) > self.priority(r) + cfg.evict_margin:
                waiting.pop(0)
                plan.evict.append(r.twin_id)
                plan.admit.append((r.refit_slot, challenger.twin_id))
            else:
                break   # residents below this one are even harder to beat
        if self.metrics is not None:
            if plan.admit:
                self.metrics.admitted.inc(len(plan.admit))
            if plan.evict:
                self.metrics.evicted.inc(len(plan.evict))
            if plan.release:
                self.metrics.released.inc(len(plan.release))
            self.metrics.waiting.set(n_waiting)
            self.metrics.plan_seconds.observe(time.perf_counter() - t0)
        return plan


# --------------------------------------------------------------------------- #
# PackedRefitScheduler: device-fused scoring + O(budget + log n) host pops
# --------------------------------------------------------------------------- #
class PackedRefitScheduler:
    """The 100k-twin planner: same admission semantics as `RefitScheduler`,
    different cost model.

    Per tick it makes ONE fused jit call over the shard's `PackedFleet`
    arrays (`packed.fleet_scores`) which returns the top-`slots` waiting
    candidates, the waiting-queue depth, and the pressure reduction.  That
    top-k is provably sufficient for exact planning: a tick can consume at
    most `cap - len(kept)` waiting twins in the fill phase plus `len(kept)`
    in the eviction phase, and their sum is bounded by `cap <= slots`.  The
    host then re-scores the O(slots) candidates and residents in float64
    with the reference planner's exact arithmetic (see twin/packed.py's
    precision contract), orders candidates through a `PriorityBuckets`
    queue keyed by twin_id, and replays the reference algorithm
    step-for-step — so `plan()` returns byte-identical
    admit/evict/release sets (tests/test_scheduler_equivalence.py holds the
    two planners to that on random fleets).

    Host cost per tick: O(slots log slots + log n) plus the O(n) work that
    runs VECTORIZED on the device — vs the reference's O(n log n) in
    Python.  State: stateless between ticks (staleness drifts every tick
    for every waiting twin, so any incrementally-maintained host ordering
    would need Omega(n) updates per tick anyway — the fused device pass IS
    the incremental structure).
    """

    def __init__(self, cfg: SchedulerConfig,
                 metrics: SchedulerMetrics | None = None, *,
                 quantum: float = 0.25):
        self.cfg = cfg
        self.metrics = metrics
        self.queue = PriorityBuckets(quantum)
        self.last_pressure = 0.0
        self.last_waiting = 0

    # ------------------------------------------------------------------ #
    def _priority_rows(self, fleet: PackedFleet, rows: np.ndarray
                       ) -> np.ndarray:
        """Exact float64 re-score of `rows` — the same IEEE operation order
        as `RefitScheduler.priority`, so comparisons are bit-identical."""
        cfg = self.cfg
        rows = np.asarray(rows, np.int64)
        stale = ((fleet.samples[rows] - fleet.samples_at_deploy[rows])
                 / max(cfg.min_samples, 1))
        stale = stale + np.where(fleet.deployed[rows], 0.0, 1.0)
        return (cfg.staleness_weight * stale
                + cfg.divergence_weight * fleet.divergence[rows])

    def pressure(self, fleet: PackedFleet) -> float:
        """Aggregate refit demand via the fused device reduction (see
        `RefitScheduler.pressure` for the definition)."""
        cfg = self.cfg
        p = fleet_pressure(fleet, min_samples=cfg.min_samples,
                           sw=cfg.staleness_weight,
                           dw=cfg.divergence_weight)
        self.last_pressure = p
        if self.metrics is not None:
            self.metrics.pressure.set(p)
        return p

    # ------------------------------------------------------------------ #
    def plan_records(self, twins: dict[int, TwinRecord],
                     max_active: int | None = None) -> SchedulePlan:
        """Reference-interop entry: plan from a `TwinRecord` dict by packing
        it first.  Used by the equivalence tests and tools; the server calls
        `plan()` directly on its incrementally-maintained fleet."""
        fleet = PackedFleet.from_records(twins)
        slot_rows = fleet.slot_rows_from_records(twins, self.cfg.slots)
        return self.plan(fleet, slot_rows, max_active=max_active)

    def plan(self, fleet: PackedFleet, slot_rows: np.ndarray,
             max_active: int | None = None) -> SchedulePlan:
        """Decide this tick's slot turnover from packed fleet state.

        `slot_rows[slot]` is the resident ring row, with values outside
        [0, fleet.capacity) marking an empty slot (the server's scratch-row
        convention).  Pure: mutates neither the fleet nor `slot_rows`; the
        server applies the plan.  Same `max_active` grant semantics as the
        reference planner.
        """
        t0 = time.perf_counter()
        cfg = self.cfg
        cap = (cfg.slots if max_active is None
               else max(0, min(cfg.slots, max_active)))
        plan = SchedulePlan()

        slot_rows = np.asarray(slot_rows)
        occupied = ((slot_rows >= 0) & (slot_rows < fleet.capacity))

        # ONE device pass: top-k waiting candidates + queue depth + pressure
        cand_rows, cand_prio32, n_waiting, pressure = fleet_scores(
            fleet, min_samples=cfg.min_samples, sw=cfg.staleness_weight,
            dw=cfg.divergence_weight, k=cfg.slots)
        self.last_pressure = pressure
        self.last_waiting = n_waiting
        keep = np.isfinite(cand_prio32)
        cand_rows = cand_rows[keep]

        # exact float64 re-score of the O(slots) rows the plan can touch
        queue = self.queue
        queue.clear()
        if cand_rows.size:
            cand_prio = self._priority_rows(fleet, cand_rows)
            cand_ids = fleet.twin_id[cand_rows]
            for tid, prio in zip(cand_ids.tolist(), cand_prio.tolist()):
                queue.push(int(tid), prio)

        # residents as (twin_id, slot, priority, residency, healthy, stuck),
        # iterated in twin_id order like the reference
        residents = []
        res_rows = slot_rows[occupied]
        if res_rows.size:
            res_slots = np.nonzero(occupied)[0]
            res_prio = self._priority_rows(fleet, res_rows)
            res_ids = fleet.twin_id[res_rows]
            healthy = (fleet.deployed[res_rows]
                       & (fleet.divergence[res_rows]
                          < cfg.release_divergence))
            res_cnt = fleet.residency[res_rows]
            residents = sorted(
                zip(res_ids.tolist(), res_slots.tolist(), res_prio.tolist(),
                    res_cnt.tolist(), healthy.tolist()))

        # federation revoke: shed lowest-priority residents to fit the grant
        if len(residents) > cap:
            shed = sorted(residents, key=lambda r: (r[2], r[0]))
            shed_ids = {r[0] for r in shed[:len(residents) - cap]}
            plan.release.extend(sorted(shed_ids))
            residents = [r for r in residents if r[0] not in shed_ids]

        # voluntary release (converged+healthy, or stuck) — but only for
        # waiting twins the grant-usable free slots cannot absorb
        free = sorted(set(range(cfg.slots))
                      - {slot for _, slot, *_ in residents})
        kept = []
        usable_free = min(len(free), cap - len(residents))
        releasable = n_waiting - usable_free
        voluntary = 0
        for tid, slot, prio, residency, healthy in residents:
            stuck = residency >= 2 * cfg.max_residency
            if (voluntary < releasable
                    and ((residency >= cfg.max_residency and healthy)
                         or stuck)):
                plan.release.append(tid)
                voluntary += 1
                free.append(slot)
            else:
                kept.append((tid, slot, prio, residency))

        # fill free slots with the best waiting twins, up to the grant
        free.sort()
        budget = cap - len(kept)
        for slot in free:
            if budget <= 0 or not len(queue):
                break
            tid, _, _ = queue.pop()
            plan.admit.append((slot, tid))
            budget -= 1

        # preemption: strongest challengers vs weakest eligible residents
        evictable = sorted((r for r in kept
                            if r[3] >= cfg.min_residency),
                           key=lambda r: (r[2], r[0]))
        for tid, slot, prio, _ in evictable:
            top = queue.peek()
            if top is None:
                break
            if top[1] > prio + cfg.evict_margin:
                queue.pop()
                plan.evict.append(tid)
                plan.admit.append((slot, top[0]))
            else:
                break   # residents below this one are even harder to beat

        if self.metrics is not None:
            if plan.admit:
                self.metrics.admitted.inc(len(plan.admit))
            if plan.evict:
                self.metrics.evicted.inc(len(plan.evict))
            if plan.release:
                self.metrics.released.inc(len(plan.release))
            self.metrics.pressure.set(pressure)
            self.metrics.waiting.set(n_waiting)
            self.metrics.queue_entries.set(len(queue))
            self.metrics.plan_seconds.observe(time.perf_counter() - t0)
        return plan


# --------------------------------------------------------------------------- #
# Federation: divide a global active-slot budget across per-shard schedulers
# --------------------------------------------------------------------------- #
@dataclass(frozen=True, init=False)
class FederationConfig:
    """Slot-federation knobs; field names match `FleetTopologyConfig`
    (twin/service.py), the config base both deployment shapes extend.

    The pre-federation names (`min_slots=`, `smooth=`) are accepted as
    deprecated keyword aliases for one release — they warn and route to the
    canonical fields."""
    total_slots: int                # global active-refit budget, all shards
    min_shard_slots: int = 1        # per-shard grant floor (keeps shards live)
    pressure_smooth: float = 0.5    # EMA weight of the newest pressure reading

    def __init__(self, total_slots: int, min_shard_slots: int | None = None,
                 pressure_smooth: float | None = None, *,
                 min_slots: int | None = None, smooth: float | None = None):
        for old, new, val in (("min_slots", "min_shard_slots", min_slots),
                              ("smooth", "pressure_smooth", smooth)):
            if val is not None:
                warnings.warn(
                    f"FederationConfig({old}=...) is deprecated; use "
                    f"{new}=... (one-release shim, twin/service.py "
                    "consolidation)", DeprecationWarning, stacklevel=2)
        if min_slots is not None:
            if min_shard_slots is not None:
                raise TypeError("pass min_shard_slots OR min_slots, not both")
            min_shard_slots = min_slots
        if smooth is not None:
            if pressure_smooth is not None:
                raise TypeError("pass pressure_smooth OR smooth, not both")
            pressure_smooth = smooth
        object.__setattr__(self, "total_slots", total_slots)
        object.__setattr__(self, "min_shard_slots",
                           1 if min_shard_slots is None else min_shard_slots)
        object.__setattr__(self, "pressure_smooth",
                           0.5 if pressure_smooth is None else pressure_smooth)

    @property
    def min_slots(self) -> int:
        """Deprecated alias of `min_shard_slots` (one-release shim)."""
        warnings.warn("FederationConfig.min_slots is deprecated; read "
                      "min_shard_slots", DeprecationWarning, stacklevel=2)
        return self.min_shard_slots

    @property
    def smooth(self) -> float:
        """Deprecated alias of `pressure_smooth` (one-release shim)."""
        warnings.warn("FederationConfig.smooth is deprecated; read "
                      "pressure_smooth", DeprecationWarning, stacklevel=2)
        return self.pressure_smooth


class SlotFederation:
    """Rebalance refit-slot grants across shards by aggregate pressure.

    Each shard reports `RefitScheduler.pressure` (summed staleness+divergence
    priority over its ready twins); grants are allocated proportionally —
    floor first, then one slot at a time to the shard with the lowest
    grant-to-pressure ratio, clamped at each shard's physical pool.  Pressure
    is EMA-smoothed so a single noisy tick does not thrash slots between
    shards (slot moves cost a `reset_slot` warmup on the receiving side).
    """

    def __init__(self, cfg: FederationConfig, shard_slots: list[int]):
        if cfg.total_slots > sum(shard_slots):
            raise ValueError("federation budget exceeds the physical pools")
        self.cfg = cfg
        self.shard_slots = list(shard_slots)
        self._ema = [0.0] * len(shard_slots)

    @property
    def pressures(self) -> list[float]:
        return list(self._ema)

    def rebalance(self, pressures: list[float],
                  alive: list[bool] | None = None) -> list[int]:
        """pressures[i] = shard i's current aggregate demand; returns the
        per-shard active-slot grants (sums to total_slots when the physical
        pools allow it).

        `alive` (default: all True) masks out DEAD shards: a dead shard gets
        grant 0 and no floor — its share flows to the survivors until the
        supervisor restarts it (twin/recovery.py failover).  Its pressure
        EMA is held, not decayed, so the restarted shard re-enters the next
        rebalance with its pre-crash demand instead of starting from zero.
        """
        cfg = self.cfg
        n = len(self.shard_slots)
        if alive is None:
            alive = [True] * n
        a = cfg.pressure_smooth
        self._ema = [a * p + (1 - a) * e if up else e
                     for p, e, up in zip(pressures, self._ema, alive)]
        grants = [min(cfg.min_shard_slots, cap) if up else 0
                  for cap, up in zip(self.shard_slots, alive)]
        budget = cfg.total_slots - sum(grants)
        while budget < 0:      # degenerate: floors exceed the global budget
            i = max(range(n), key=lambda j: grants[j])
            grants[i] -= 1
            budget += 1
        weights = [max(e, 0.0) if up else 0.0
                   for e, up in zip(self._ema, alive)]
        if sum(weights) <= 0:
            weights = [1.0 if up else 0.0 for up in alive]
            if sum(weights) <= 0:      # every shard dead: park the budget
                return grants
        # proportional-fair greedy: next slot to the shard whose grant is
        # smallest relative to its demand (deterministic, O(total_slots))
        while budget > 0:
            cand = [i for i in range(n)
                    if alive[i] and grants[i] < self.shard_slots[i]]
            if not cand:
                break
            i = min(cand, key=lambda j: (grants[j] / (weights[j] + 1e-9),
                                         -weights[j], j))
            grants[i] += 1
            budget -= 1
        return grants
