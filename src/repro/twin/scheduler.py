"""Slot-based refit scheduling: thousands of twins, a bounded compute budget.

Mirrors serve/engine.ServeEngine's admission pattern: a FIXED number of refit
slots (the FleetMerinda fleet axis — one fused train_step advances all of
them), with twins admitted into and evicted from slots dynamically.  The
device-side math stays static-shape; all policy runs here on the host over a
small registry of `TwinRecord`s.

Priority model (computed per twin, higher = refit sooner):

    priority = staleness_weight * staleness + divergence_weight * divergence

  * staleness   — samples ingested since the twin's model was last deployed,
    normalized by the refit window span; a never-deployed twin gets a +1
    bonus (it has NO model, the worst kind of stale).
  * divergence  — the guard score from twin/monitor.py (normalized rollout
    error of the deployed model on the newest telemetry).  This is the
    collision-avoidance signal: a twin whose physics changed outranks every
    merely-stale twin.

Slot turnover:
  * free slots are filled by the highest-priority READY twins (enough samples
    for a full window batch);
  * a resident twin can be PREEMPTED by a waiting twin whose priority exceeds
    the resident's by `evict_margin`, but only after `min_residency` ticks
    (refits must get enough steps to converge before the slot churns);
  * a resident twin that has both converged (>= `max_residency` ticks) and
    gone quiet (divergence below `release_divergence`) RELEASES its slot
    voluntarily — the mechanism that lets a big fleet rotate through a small
    slot pool.

Federation (sharded serving, twin/sharded.py): each shard runs its own
scheduler over its own twins; `SlotFederation` divides a GLOBAL active-slot
budget across shards in proportion to their aggregate staleness+divergence
`pressure`, and each shard's `plan(..., max_active=k)` honors its grant —
shedding surplus residents (lowest priority first) when the grant shrinks.
Physical slot pools stay fixed-shape (no recompiles); only the number of
slots a shard may FILL moves.
"""
from __future__ import annotations

from dataclasses import dataclass, field

__all__ = ["TwinRecord", "SchedulerConfig", "SchedulePlan", "SchedulerMetrics",
           "RefitScheduler", "FederationConfig", "SlotFederation"]


@dataclass
class TwinRecord:
    """Host-side registry entry for one tracked object."""
    twin_id: int
    ring_slot: int                    # row in TelemetryRing
    refit_slot: int | None = None     # FleetMerinda slot, None if waiting
    samples: int = 0                  # total telemetry ingested
    samples_at_deploy: int = 0
    deployed: bool = False            # has a theta in the serving store
    deploy_tick: int = -1
    admitted_tick: int = -1
    residency: int = 0                # ticks spent in current slot
    steps_in_slot: int = 0            # train steps in current slot
    divergence: float = 0.0           # EMA guard score


@dataclass(frozen=True)
class SchedulerConfig:
    slots: int
    min_samples: int                  # readiness: samples for one window batch
    staleness_weight: float = 1.0
    divergence_weight: float = 4.0
    evict_margin: float = 0.5         # challenger must beat resident by this
    min_residency: int = 8            # ticks before preemption allowed
    max_residency: int = 64           # ticks before voluntary release allowed
    release_divergence: float = 0.05  # ...and only if the twin tracks reality


@dataclass
class SchedulePlan:
    admit: list = field(default_factory=list)    # [(slot, twin_id)]
    evict: list = field(default_factory=list)    # [twin_id] preempted
    release: list = field(default_factory=list)  # [twin_id] converged


@dataclass
class SchedulerMetrics:
    """Slot-turnover instruments (obs registry children, one set per shard).

    `admitted`/`evicted`/`released` count slot transitions cumulatively;
    `pressure` is the latest aggregate staleness+divergence demand — the
    same number the federation rebalances on, so a fleet dashboard shows
    WHY grants moved.
    """
    admitted: object            # Counter-like: .inc(n)
    evicted: object
    released: object
    pressure: object            # Gauge-like: .set(v)

    @staticmethod
    def create(registry, labels: dict | None = None) -> "SchedulerMetrics":
        """Resolve the scheduler's instruments from a `MetricRegistry`."""
        return SchedulerMetrics(
            admitted=registry.counter(
                "twin_sched_admitted_total",
                help="twins admitted into refit slots", labels=labels),
            evicted=registry.counter(
                "twin_sched_evicted_total",
                help="twins preempted out of refit slots", labels=labels),
            released=registry.counter(
                "twin_sched_released_total",
                help="twins that released their refit slot (converged, "
                     "stuck, or federation revoke)", labels=labels),
            pressure=registry.gauge(
                "twin_sched_pressure",
                help="aggregate staleness+divergence refit demand "
                     "(federation rebalance signal)", labels=labels))


class RefitScheduler:
    def __init__(self, cfg: SchedulerConfig,
                 metrics: SchedulerMetrics | None = None):
        self.cfg = cfg
        self.metrics = metrics

    # ------------------------------------------------------------------ #
    def priority(self, rec: TwinRecord) -> float:
        cfg = self.cfg
        staleness = (rec.samples - rec.samples_at_deploy) / max(cfg.min_samples, 1)
        if not rec.deployed:
            staleness += 1.0
        return (cfg.staleness_weight * staleness
                + cfg.divergence_weight * rec.divergence)

    def ready(self, rec: TwinRecord) -> bool:
        return rec.samples >= self.cfg.min_samples

    def pressure(self, twins: dict[int, TwinRecord]) -> float:
        """Aggregate refit demand: summed priority over READY twins (waiting
        AND resident — a shard actively refitting diverged twins is still
        under pressure).  The federation's rebalancing signal."""
        p = sum(self.priority(r) for r in twins.values() if self.ready(r))
        if self.metrics is not None:
            self.metrics.pressure.set(p)
        return p

    # ------------------------------------------------------------------ #
    def plan(self, twins: dict[int, TwinRecord],
             max_active: int | None = None) -> SchedulePlan:
        """Decide this tick's slot turnover.  Pure: mutates nothing; the
        server applies the plan (device-side slot resets + record updates).

        `max_active` caps how many physical slots may be FILLED (the
        federation grant); None means the whole pool.  When the grant drops
        below current occupancy, the lowest-priority residents are shed.

        Units: residency thresholds (`min_residency`, `max_residency`) are
        serving TICKS, not seconds or train steps; `min_samples` is ring
        telemetry samples.  Host cost is O(n log n) in the number of
        tracked twins (two sorts per tick — the known 100k-twin scaling
        limit, see ROADMAP).  Not thread-safe by itself; the server passes
        a `twin_snapshot()` registry copy so concurrent `ingest`
        registrations cannot race the iteration.

        Iteration is in twin_id order so equal-priority decisions are
        deterministic across runs.
        """
        cfg = self.cfg
        cap = (cfg.slots if max_active is None
               else max(0, min(cfg.slots, max_active)))
        plan = SchedulePlan()
        residents = sorted((r for r in twins.values()
                            if r.refit_slot is not None),
                           key=lambda r: r.twin_id)
        waiting = sorted((r for r in twins.values()
                          if r.refit_slot is None and self.ready(r)),
                         key=lambda r: (-self.priority(r), r.twin_id))

        # federation revoke: the grant shrank below occupancy — shed the
        # lowest-priority residents until the shard fits its grant
        if len(residents) > cap:
            shed = sorted(residents,
                          key=lambda r: (self.priority(r), r.twin_id))
            shed = shed[:len(residents) - cap]
            shed_ids = {r.twin_id for r in shed}
            plan.release.extend(sorted(shed_ids))
            residents = [r for r in residents if r.twin_id not in shed_ids]

        # voluntary release: converged, healthy residents hand back slots.
        # A resident stuck far past max_residency without converging is
        # released too (its divergence priority would otherwise let it starve
        # the waiting queue indefinitely).
        free: list[int] = sorted(set(range(cfg.slots))
                                 - {r.refit_slot for r in residents})
        kept: list[TwinRecord] = []
        # release only for waiting twins the free slots USABLE under the
        # grant cannot absorb — releasing more would idle slots and throw
        # away converged training state
        usable_free = min(len(free), cap - len(residents))
        releasable = len(waiting) - usable_free
        voluntary = 0
        for r in residents:
            healthy = r.deployed and r.divergence < cfg.release_divergence
            stuck = r.residency >= 2 * cfg.max_residency
            if (voluntary < releasable
                    and ((r.residency >= cfg.max_residency and healthy)
                         or stuck)):
                plan.release.append(r.twin_id)
                voluntary += 1
                free.append(r.refit_slot)
            else:
                kept.append(r)

        # fill free slots with the best waiting twins, up to the grant
        free.sort()
        budget = cap - len(kept)
        for slot in free:
            if not waiting or budget <= 0:
                break
            plan.admit.append((slot, waiting.pop(0).twin_id))
            budget -= 1

        # preemption: strongest challengers vs weakest eligible residents
        evictable = sorted((r for r in kept
                            if r.residency >= cfg.min_residency),
                           key=lambda r: (self.priority(r), r.twin_id))
        for r in evictable:
            if not waiting:
                break
            challenger = waiting[0]
            if self.priority(challenger) > self.priority(r) + cfg.evict_margin:
                waiting.pop(0)
                plan.evict.append(r.twin_id)
                plan.admit.append((r.refit_slot, challenger.twin_id))
            else:
                break   # residents below this one are even harder to beat
        if self.metrics is not None:
            if plan.admit:
                self.metrics.admitted.inc(len(plan.admit))
            if plan.evict:
                self.metrics.evicted.inc(len(plan.evict))
            if plan.release:
                self.metrics.released.inc(len(plan.release))
        return plan


# --------------------------------------------------------------------------- #
# Federation: divide a global active-slot budget across per-shard schedulers
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class FederationConfig:
    total_slots: int        # global active-refit budget across all shards
    min_slots: int = 1      # per-shard grant floor (keeps every shard live)
    smooth: float = 0.5     # EMA weight of the newest pressure reading


class SlotFederation:
    """Rebalance refit-slot grants across shards by aggregate pressure.

    Each shard reports `RefitScheduler.pressure` (summed staleness+divergence
    priority over its ready twins); grants are allocated proportionally —
    floor first, then one slot at a time to the shard with the lowest
    grant-to-pressure ratio, clamped at each shard's physical pool.  Pressure
    is EMA-smoothed so a single noisy tick does not thrash slots between
    shards (slot moves cost a `reset_slot` warmup on the receiving side).
    """

    def __init__(self, cfg: FederationConfig, shard_slots: list[int]):
        if cfg.total_slots > sum(shard_slots):
            raise ValueError("federation budget exceeds the physical pools")
        self.cfg = cfg
        self.shard_slots = list(shard_slots)
        self._ema = [0.0] * len(shard_slots)

    @property
    def pressures(self) -> list[float]:
        return list(self._ema)

    def rebalance(self, pressures: list[float]) -> list[int]:
        """pressures[i] = shard i's current aggregate demand; returns the
        per-shard active-slot grants (sums to total_slots when the physical
        pools allow it)."""
        cfg = self.cfg
        n = len(self.shard_slots)
        a = cfg.smooth
        self._ema = [a * p + (1 - a) * e
                     for p, e in zip(pressures, self._ema)]
        grants = [min(cfg.min_slots, cap) for cap in self.shard_slots]
        budget = cfg.total_slots - sum(grants)
        while budget < 0:      # degenerate: floors exceed the global budget
            i = max(range(n), key=lambda j: grants[j])
            grants[i] -= 1
            budget += 1
        weights = [max(e, 0.0) for e in self._ema]
        if sum(weights) <= 0:
            weights = [1.0] * n        # no demand anywhere: split evenly
        # proportional-fair greedy: next slot to the shard whose grant is
        # smallest relative to its demand (deterministic, O(total_slots))
        while budget > 0:
            cand = [i for i in range(n) if grants[i] < self.shard_slots[i]]
            if not cand:
                break
            i = min(cand, key=lambda j: (grants[j] / (weights[j] + 1e-9),
                                         -weights[j], j))
            grants[i] += 1
            budget -= 1
        return grants
