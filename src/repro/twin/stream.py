"""Per-twin telemetry ring buffers as device arrays with a fused ingest.

Online twinning is a streaming workload: every tracked object produces a
(y_t, u_t) sample per sensor tick, and the refit path consumes the NEWEST
sliding windows.  `TelemetryRing` keeps one fixed-capacity ring per twin as a
single set of device arrays, so a full serving tick does exactly one jitted
scatter (`ingest`) and one jitted gather (`windows` / `latest`) for the whole
fleet — no per-twin host round-trips, no reallocation, bounded memory.

State layout (a plain pytree, shardable over the slot axis like every other
fleet-axis array in this repo):
    y     [S, cap, n]   state telemetry
    u     [S, cap, m]   input telemetry (u_t held during y_t -> y_{t+1})
    count [S] int32     total samples ever written per slot (write head
                        = count % cap; monotonically increasing)

Row `S-1` is conventionally reserved by twin/server.py as a scratch row so
fixed-shape fused calls can park unassigned refit slots on it; the ring
itself has no special-casing.

Window extraction reuses data/pipeline.ring_latest / make_ring_windows, so
windows taken from the ring are bitwise identical to `make_windows` on the
equivalent chronological trace (tested in tests/test_twin_stream.py).
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import make_ring_windows, ring_latest
from repro.distributed.sharding import shard

__all__ = ["RingConfig", "TelemetryRing", "StagingBuffer", "StagingOverflow",
           "FlushBatch", "prepare_flush"]


@dataclass(frozen=True)
class RingConfig:
    slots: int       # number of per-twin rings (tracked-object capacity)
    capacity: int    # samples per ring; windows must fit inside it
    n: int           # state dim
    m: int           # input dim


class TelemetryRing:
    def __init__(self, cfg: RingConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ #
    def init(self):
        cfg = self.cfg
        return {
            "y": jnp.zeros((cfg.slots, cfg.capacity, cfg.n)),
            "u": jnp.zeros((cfg.slots, cfg.capacity, cfg.m)),
            "count": jnp.zeros((cfg.slots,), jnp.int32),
        }

    # ------------------------------------------------------------------ #
    @partial(jax.jit, static_argnames=("self",))
    def ingest(self, state, slots, ys, us, counts):
        """Fused scatter of one telemetry chunk per slot.

        slots:  [B] int32, DISTINCT ring rows (one chunk per twin per call).
        ys:     [B, C, n], us: [B, C, m] — chunk buffers, possibly padded.
        counts: [B] int32 — valid prefix length of each chunk (<= C); padded
                tail positions are written back with their current values, so
                callers can batch twins with unequal chunk sizes into one
                fixed-shape call (the retrace-free flush in twin/server.py).

        Requires C <= capacity (one call never laps its own ring).
        """
        cfg = self.cfg
        C = ys.shape[1]
        if C > cfg.capacity:     # trace-time shape check; survives python -O
            raise ValueError(f"chunk of {C} samples would lap the "
                             f"{cfg.capacity}-sample ring")
        offs = jnp.arange(C)[None, :]                        # [1, C]
        cols = (state["count"][slots][:, None] + offs) % cfg.capacity
        valid = offs < counts[:, None]                       # [B, C]
        rows = jnp.broadcast_to(slots[:, None], cols.shape)
        old_y = state["y"][rows, cols]
        old_u = state["u"][rows, cols]
        y = state["y"].at[rows, cols].set(
            jnp.where(valid[..., None], ys, old_y))
        u = state["u"].at[rows, cols].set(
            jnp.where(valid[..., None], us, old_u))
        count = state["count"].at[slots].add(counts)
        # logical twin_* shardings (distributed/sharding.py): the ring's slot
        # axis partitions over ('pod','data') exactly like the fleet axis —
        # a no-op outside an axis_rules context (CPU tests, single device)
        return {"y": shard(y, "twin_ring"), "u": shard(u, "twin_ring"),
                "count": shard(count, "twin_count")}

    # ------------------------------------------------------------------ #
    @partial(jax.jit, static_argnames=("self", "length"))
    def latest(self, state, slots, length: int):
        """Newest `length+1` samples per slot, chronological.

        Returns (ys [B, length+1, n], us [B, length, m]); requires
        count[slots] >= length+1 (host-checked by the server's readiness
        gate — stale columns come back otherwise).
        """
        return ring_latest(state["y"], state["u"], state["count"], slots,
                           length)

    # ------------------------------------------------------------------ #
    @partial(jax.jit, static_argnames=("self", "window", "stride", "length"))
    def windows(self, state, slots, *, window: int, stride: int | None = None,
                length: int):
        """Sliding windows over the newest `length` steps of each slot.

        Returns (y_win [B, N, k+1, n], u_win [B, N, k, m]) — the per-twin
        window batches FleetMerinda.train_step consumes; parity with
        data/pipeline.make_windows on the chronological trace.
        """
        return make_ring_windows(state["y"], state["u"], state["count"],
                                 slots, window=window, stride=stride,
                                 length=length)

    # ------------------------------------------------------------------ #
    @staticmethod
    def span(window: int, stride: int, n_windows: int) -> int:
        """Ring steps needed so `windows(..., length=span)` yields exactly
        `n_windows` windows (the server's per-slot batch shape)."""
        return stride * (n_windows - 1) + window

    @partial(jax.jit, static_argnames=("self",))
    def clear(self, state, slot):
        """Logically empty one ring (eviction of a tracked object)."""
        return {"y": state["y"], "u": state["u"],
                "count": state["count"].at[slot].set(0)}


# --------------------------------------------------------------------------- #
# Host-side staging: thread-safe chunk accumulation + fused-flush preparation
# --------------------------------------------------------------------------- #
class StagingOverflow(RuntimeError):
    """A bounded `StagingBuffer` cannot accept a chunk without exceeding its
    capacity.  Raised from `append` so the caller decides the policy —
    `TwinServer.ingest` retries with backoff and, in non-strict mode, sheds
    the oldest staged samples instead of failing the producer."""


class StagingBuffer:
    """Thread-safe host-side staging of telemetry chunks, keyed by ring row.

    The seed server staged chunks in a bare dict and assumed single-threaded
    callers; with async ingestion the producer (sensor threads calling
    `TwinServer.ingest`) and the flusher (a `BackgroundPump` worker) race on
    that dict.  This buffer makes the handoff explicit:

      * `append()` — producers push chunks under the lock (cheap: list append),
      * `swap()`   — the flusher atomically takes the filled buffer and
        installs an empty one (the double-buffer handoff), so producers never
        wait on the numpy merge/pad work that follows.

    Chronological order per row is preserved across swaps: chunks appended
    before a swap land in an earlier `FlushBatch`, and batches are applied in
    FIFO order by the consumer.

    With `capacity` set the buffer is bounded: `append` raises
    `StagingOverflow` once the pending backlog would exceed it (a stalled
    flusher must surface as backpressure, not unbounded host memory), and
    `drop_oldest` sheds the globally oldest staged chunks to make room —
    the degradation path for non-strict producers.
    """

    def __init__(self, capacity: int | None = None):
        self._lock = threading.Lock()
        self._buf: dict[int, list] = {}
        self._order: deque[int] = deque()   # rows in chunk-append order
        self.capacity = capacity
        self.staged_samples = 0      # samples appended, monotonic
        self.swapped_samples = 0     # samples handed off via swap(), monotonic
        self.dropped_samples = 0     # samples shed by drop_oldest, monotonic

    def append(self, row: int, y: np.ndarray, u: np.ndarray, *,
               force: bool = False) -> None:
        """Stage one chunk.  Raises `StagingOverflow` when bounded and full;
        `force=True` bypasses the bound (used after an explicit
        `drop_oldest` so the shed-then-stage sequence cannot starve)."""
        with self._lock:
            if (self.capacity is not None and not force
                    and self._pending_locked() + len(y) > self.capacity):
                raise StagingOverflow(
                    f"staging buffer full: {self._pending_locked()} pending "
                    f"+ {len(y)} new > capacity {self.capacity}")
            self._buf.setdefault(row, []).append((y, u))
            self._order.append(row)
            self.staged_samples += len(y)

    def drop_oldest(self, need: int) -> int:
        """Shed the globally oldest staged chunks until at least `need`
        samples are freed (or the buffer is empty).  Returns samples
        dropped.  Whole chunks are shed — per-row chronology is preserved
        because only each row's HEAD chunk is ever removed."""
        dropped = 0
        with self._lock:
            while dropped < need and self._order:
                row = self._order.popleft()
                chunks = self._buf.get(row)
                if not chunks:       # row already consumed by a swap
                    continue
                y, _ = chunks.pop(0)
                dropped += len(y)
                if not chunks:
                    del self._buf[row]
            self.dropped_samples += dropped
        return dropped

    def swap(self) -> dict[int, list]:
        """Atomically take everything staged so far (may be empty)."""
        with self._lock:
            buf, self._buf = self._buf, {}
            self._order.clear()
            self.swapped_samples += sum(len(c[0]) for cs in buf.values()
                                        for c in cs)
            return buf

    def empty(self) -> bool:
        with self._lock:
            return not self._buf

    def _pending_locked(self) -> int:
        return (self.staged_samples - self.swapped_samples
                - self.dropped_samples)

    def pending_samples(self) -> int:
        """Samples staged but not yet handed to a flush — the ingestion
        backlog gauge (`twin_staging_pending_samples`): a producer outrunning
        the tick rate shows up here before it shows up as drops."""
        with self._lock:
            return self._pending_locked()


@dataclass
class FlushBatch:
    """One prepared fused-ingest call: fixed-quanta padded device operands
    plus the per-row raw sample counts (pre-truncation) for host accounting."""
    slots: np.ndarray        # [B] int32 ring rows (scratch-padded)
    ys: np.ndarray           # [B, C, n]
    us: np.ndarray           # [B, C, m]
    counts: np.ndarray       # [B] int32 valid prefix per row
    received: dict[int, int] # ring row -> raw samples staged (incl. truncated)
    dropped: int = 0         # backlog samples truncated (ring would have
                             # overwritten them anyway — but loudly counted)


def prepare_flush(staged: dict[int, list], *, capacity: int, pad: int,
                  scratch: int, n: int, m: int) -> FlushBatch | None:
    """Merge staged chunks into one fixed-quanta fused-ingest batch.

    Pads BOTH axes to `pad` quanta (rows with scratch/zero-count entries,
    columns per chunk-length quantum) so the fused ingest does not recompile
    when the set of reporting twins varies tick to tick.  A BACKLOG (many
    chunks whose total exceeds the ring) keeps only the newest
    capacity-worth of samples — the ring would have overwritten the rest
    anyway — and reports the loss in `dropped`; `received` still carries the
    raw counts so twin sample accounting stays exact.

    A SINGLE chunk longer than the ring is different: the fused scatter
    would lap itself within one call and corrupt the ring silently.  That
    raises RuntimeError — an explicit overflow assert instead of silent
    mid-flush wraparound (`TwinServer.ingest` validates chunks up front;
    this guards direct/async callers).
    """
    if not staged:
        return None
    merged = []
    received: dict[int, int] = {}
    dropped = 0
    for row, chunks in sorted(staged.items()):
        longest = max(len(c[0]) for c in chunks)
        if longest > capacity:
            raise RuntimeError(
                f"staged chunk of {longest} samples would lap the "
                f"{capacity}-sample ring mid-flush (row {row})")
        y = np.concatenate([c[0] for c in chunks], 0)
        u = np.concatenate([c[1] for c in chunks], 0)
        received[row] = len(y)
        if len(y) > capacity:
            dropped += len(y) - capacity
            y, u = y[-capacity:], u[-capacity:]
        merged.append((row, y, u))
    # row axis: pad quanta bucketed to powers of two — async flushes swap at
    # arbitrary moments, so the reporting-row count varies freely; pow2
    # bucketing caps the number of distinct fused-ingest shapes at
    # log2(max_twins) instead of max_twins/pad (each shape is a retrace)
    q = -(-len(merged) // pad)
    B = int(pad * (1 << (q - 1).bit_length()))
    # cap the padded length at ring capacity: every chunk is already
    # truncated to <= cap, but rounding up could lap a non-multiple ring
    C = min(int(-(-max(len(y) for _, y, _ in merged) // pad) * pad), capacity)
    ys = np.zeros((B, C, n), np.float32)
    us = np.zeros((B, C, m), np.float32)
    slots = np.full((B,), scratch, np.int32)
    counts = np.zeros((B,), np.int32)
    for i, (row, y, u) in enumerate(merged):
        ys[i, :len(y)] = y
        us[i, :len(y)] = u
        slots[i] = row
        counts[i] = len(y)
    return FlushBatch(slots=slots, ys=ys, us=us, counts=counts,
                      received=received, dropped=dropped)
