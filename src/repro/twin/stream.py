"""Per-twin telemetry ring buffers as device arrays with a fused ingest.

Online twinning is a streaming workload: every tracked object produces a
(y_t, u_t) sample per sensor tick, and the refit path consumes the NEWEST
sliding windows.  `TelemetryRing` keeps one fixed-capacity ring per twin as a
single set of device arrays, so a full serving tick does exactly one jitted
scatter (`ingest`) and one jitted gather (`windows` / `latest`) for the whole
fleet — no per-twin host round-trips, no reallocation, bounded memory.

State layout (a plain pytree, shardable over the slot axis like every other
fleet-axis array in this repo):
    y     [S, cap, n]   state telemetry
    u     [S, cap, m]   input telemetry (u_t held during y_t -> y_{t+1})
    count [S] int32     total samples ever written per slot (write head
                        = count % cap; monotonically increasing)

Row `S-1` is conventionally reserved by twin/server.py as a scratch row so
fixed-shape fused calls can park unassigned refit slots on it; the ring
itself has no special-casing.

Window extraction reuses data/pipeline.ring_latest / make_ring_windows, so
windows taken from the ring are bitwise identical to `make_windows` on the
equivalent chronological trace (tested in tests/test_twin_stream.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.data.pipeline import make_ring_windows, ring_latest

__all__ = ["RingConfig", "TelemetryRing"]


@dataclass(frozen=True)
class RingConfig:
    slots: int       # number of per-twin rings (tracked-object capacity)
    capacity: int    # samples per ring; windows must fit inside it
    n: int           # state dim
    m: int           # input dim


class TelemetryRing:
    def __init__(self, cfg: RingConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ #
    def init(self):
        cfg = self.cfg
        return {
            "y": jnp.zeros((cfg.slots, cfg.capacity, cfg.n)),
            "u": jnp.zeros((cfg.slots, cfg.capacity, cfg.m)),
            "count": jnp.zeros((cfg.slots,), jnp.int32),
        }

    # ------------------------------------------------------------------ #
    @partial(jax.jit, static_argnames=("self",))
    def ingest(self, state, slots, ys, us, counts):
        """Fused scatter of one telemetry chunk per slot.

        slots:  [B] int32, DISTINCT ring rows (one chunk per twin per call).
        ys:     [B, C, n], us: [B, C, m] — chunk buffers, possibly padded.
        counts: [B] int32 — valid prefix length of each chunk (<= C); padded
                tail positions are written back with their current values, so
                callers can batch twins with unequal chunk sizes into one
                fixed-shape call (the retrace-free flush in twin/server.py).

        Requires C <= capacity (one call never laps its own ring).
        """
        cfg = self.cfg
        C = ys.shape[1]
        assert C <= cfg.capacity, "chunk may not lap the ring"
        offs = jnp.arange(C)[None, :]                        # [1, C]
        cols = (state["count"][slots][:, None] + offs) % cfg.capacity
        valid = offs < counts[:, None]                       # [B, C]
        rows = jnp.broadcast_to(slots[:, None], cols.shape)
        old_y = state["y"][rows, cols]
        old_u = state["u"][rows, cols]
        y = state["y"].at[rows, cols].set(
            jnp.where(valid[..., None], ys, old_y))
        u = state["u"].at[rows, cols].set(
            jnp.where(valid[..., None], us, old_u))
        count = state["count"].at[slots].add(counts)
        return {"y": y, "u": u, "count": count}

    # ------------------------------------------------------------------ #
    @partial(jax.jit, static_argnames=("self", "length"))
    def latest(self, state, slots, length: int):
        """Newest `length+1` samples per slot, chronological.

        Returns (ys [B, length+1, n], us [B, length, m]); requires
        count[slots] >= length+1 (host-checked by the server's readiness
        gate — stale columns come back otherwise).
        """
        return ring_latest(state["y"], state["u"], state["count"], slots,
                           length)

    # ------------------------------------------------------------------ #
    @partial(jax.jit, static_argnames=("self", "window", "stride", "length"))
    def windows(self, state, slots, *, window: int, stride: int | None = None,
                length: int):
        """Sliding windows over the newest `length` steps of each slot.

        Returns (y_win [B, N, k+1, n], u_win [B, N, k, m]) — the per-twin
        window batches FleetMerinda.train_step consumes; parity with
        data/pipeline.make_windows on the chronological trace.
        """
        return make_ring_windows(state["y"], state["u"], state["count"],
                                 slots, window=window, stride=stride,
                                 length=length)

    # ------------------------------------------------------------------ #
    @staticmethod
    def span(window: int, stride: int, n_windows: int) -> int:
        """Ring steps needed so `windows(..., length=span)` yields exactly
        `n_windows` windows (the server's per-slot batch shape)."""
        return stride * (n_windows - 1) + window

    @partial(jax.jit, static_argnames=("self",))
    def clear(self, state, slot):
        """Logically empty one ring (eviction of a tracked object)."""
        return {"y": state["y"], "u": state["u"],
                "count": state["count"].at[slot].set(0)}
