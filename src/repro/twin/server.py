"""TwinServer: the online serving loop — ingest, refit, deploy, guard.

One `tick()` is a full serving cycle over the whole tracked fleet:

    1. FLUSH    staged telemetry into the device ring buffers (one fused
                scatter for every twin that produced samples this tick).
                With `async_ingest` the host-side merge/pad work runs on a
                background `BackgroundPump` thread (double-buffered handoff,
                data/pipeline.py); the tick only applies prepared batches,
    2. GUARD    RK4-roll deployed thetas over their newest window and
                EMA-fold the normalized rollout error into each twin's
                divergence score; emit REFIT/ALERT events on transitions.
                With `guard_budget` set, a `GuardRotation` scores a fixed-size
                rotating subset per tick (round-robin + divergence carry-over)
                so guard cost is O(budget), not O(twins),
    3. SCHEDULE admit/evict/release twins over the bounded refit-slot pool
                by staleness + divergence priority (twin/scheduler.py).
                The default `PackedRefitScheduler` scores the WHOLE fleet in
                one fused device call over packed arrays (twin/packed.py)
                and pops only the O(slots) winners on the host; a federation
                layer (twin/sharded.py) can cap the active pool via
                `set_active_slots`,
    4. REFIT    `steps_per_tick` fused FleetMerinda.train_step calls over all
                slots at once (the bounded compute budget),
    5. DEPLOY   recover_all on slots whose twin has trained past
                `deploy_after`, scattered into the serving theta store.

Every fused call has a FIXED shape (refit_slots / max_twins / guard budget),
so steady-state serving compiles exactly once; unassigned refit slots are
parked on a scratch ring row (`max_twins`) and unused recoveries land on a
scratch theta row.  Shards of a `ShardedTwinServer` with identical configs
share the stateless module objects (`share_modules_from`), so the jit cache
is hit once per topology, not once per shard.

Per-tick wall latency is recorded against `deadline_s`, and each stage's cost
is tracked separately (`stage_summary`) — the scale benchmark's evidence that
guard cost stays flat as the tracked fleet grows.  All serving stats flow
through a bounded `repro.obs` metrics registry (scrape via
`server.metrics.expose()`; catalog in docs/OBSERVABILITY.md), and an optional
`Tracer` wraps every stage in spans exportable as a Perfetto-loadable trace.
The paper's mission budget: beat the 5 s human-pilot reaction time 5x —
refresh every deployed twin in <= 1 s.

`predict(twin_id, horizon)` rolls the deployed model forward from the
twin's newest telemetry — the collision-avoidance lookahead.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fleet import FleetConfig, FleetMerinda
from repro.core.merinda import MerindaConfig
from repro.data.pipeline import BackgroundPump
from repro.kernels.rk4.ops import rk4_poly_solve
from repro.obs import MetricRegistry, Tracer
from repro.twin.monitor import (DivergenceGuard, GuardConfig, GuardEvent,
                                GuardInstruments, GuardRotation)
from repro.twin.packed import PackedFleet
from repro.twin.recovery import (DegradationConfig, DegradationEvent,
                                 DegradationPolicy)
from repro.twin.scenario import (ScenarioConfig, ScenarioRefused,
                                 ScenarioResult, ScenarioRunner, effective_k)
from repro.twin.service import DeadlineConfig
from repro.twin.scheduler import (PackedRefitScheduler, RefitScheduler,
                                  SchedulerConfig, SchedulePlan,
                                  SchedulerMetrics, TwinRecord)
from repro.twin.stream import (FlushBatch, RingConfig, StagingBuffer,
                               StagingOverflow, TelemetryRing, prepare_flush)

__all__ = ["TwinServerConfig", "TickReport", "TwinServer"]

_STAGES = ("flush", "guard", "schedule", "refit")

# recent-tick window kept for debugging/back-compat (`srv.latencies` et al.).
# Authoritative latency stats come from the bounded metrics-registry
# histograms; these deques exist so short interactive runs can still inspect
# raw per-tick numbers without the registry — and, unlike the seed's bare
# lists, they cannot grow without bound in a long-running service.
_HISTORY = 4096


@dataclass(frozen=True)
class TwinServerConfig(DeadlineConfig):
    """Single-server knobs; `deadline_s` (1.0 s default — 5x under the 5 s
    human-reaction budget) comes from the shared `DeadlineConfig` base
    (twin/service.py) so every server config agrees on its meaning."""
    merinda: MerindaConfig
    max_twins: int                    # tracked-object capacity
    refit_slots: int = 8              # concurrent refits (compute budget)
    capacity: int = 512               # ring samples per twin
    window: int = 24                  # refit window k
    stride: int = 8
    windows_per_twin: int = 16        # S_B per slot per train step
    steps_per_tick: int = 2           # incremental train steps per tick
    lr: float = 3e-3
    sparsify_after: int = 60          # per-slot warmup (FleetConfig)
    deploy_after: int = 24            # train steps before a slot's theta ships
    promote_margin: float = 0.7       # candidate must score < margin * incumbent
    guard: GuardConfig = GuardConfig()
    guard_budget: int | None = None   # None: score the whole store per tick;
                                      # int: rotating subset of this size
    guard_carry: int | None = None    # extra per-tick re-scores of flagged
                                      # twins (default: guard_budget // 4)
    async_ingest: bool = False        # background staging flush thread
    ingest_depth: int = 2             # prepared-batch queue depth (double buf)
    staleness_weight: float = 1.0
    divergence_weight: float = 4.0
    evict_margin: float = 0.5
    min_residency: int = 8
    max_residency: int = 64
    release_divergence: float = 0.05
    scheduler: str = "bucketed"       # "bucketed": PackedRefitScheduler
                                      # (device-fused scoring); "reference":
                                      # the O(n log n) dict-sorting oracle
    flush_pad: int = 8                # chunk-length quantum (bounds retraces)
    degradation: DegradationConfig = DegradationConfig()
                                      # deadline-aware shed ladder
                                      # (twin/recovery.py; disabled default)
    scenario: ScenarioConfig = ScenarioConfig()
                                      # what-if engine knobs
                                      # (twin/scenario.py)
    staging_capacity: int | None = None
                                      # staging-buffer sample bound (None:
                                      # unbounded — the seed behaviour)
    ingest_strict: bool = True        # overflow after retries: raise (True)
                                      # or shed oldest staged samples
    ingest_retries: int = 3           # bounded backoff attempts on overflow
    ingest_backoff_s: float = 2e-3    # first retry sleep (doubles per try)
    seed: int = 0


@dataclass
class TickReport:
    tick: int
    latency_s: float
    deadline_met: bool
    loss: float | None                # mean refit loss (None: no active slot)
    events: list[GuardEvent] = field(default_factory=list)
    admitted: list = field(default_factory=list)   # [(slot, twin_id)]
    evicted: list = field(default_factory=list)
    released: list = field(default_factory=list)
    n_active: int = 0                 # twins resident in refit slots
    n_twins: int = 0                  # twins tracked
    n_guarded: int = 0                # twins scored by the guard this tick
    degraded_level: int = 0           # shed ladder after this tick (0 = full)
    degradation_events: list = field(default_factory=list)
                                      # DegradationEvent transitions this tick


class TwinServer:
    def __init__(self, cfg: TwinServerConfig, *,
                 share_modules_from: "TwinServer | None" = None,
                 seed: int | None = None,
                 metrics: MetricRegistry | None = None,
                 tracer: Tracer | None = None,
                 shard: int | str | None = None):
        """`metrics`/`tracer` attach shared observability (a sharded server
        passes one registry + tracer to every shard with a distinct `shard`
        label); standalone servers get a private registry and a disabled
        tracer, so instrumentation is always live and always bounded."""
        m = cfg.merinda
        self.cfg = cfg
        self.metrics = MetricRegistry() if metrics is None else metrics
        self.tracer = Tracer(enabled=False) if tracer is None else tracer
        self._labels = {} if shard is None else {"shard": str(shard)}
        self.span = TelemetryRing.span(cfg.window, cfg.stride,
                                       cfg.windows_per_twin)
        self.min_samples = self.span + 1
        if cfg.capacity < max(self.min_samples, cfg.guard.window + 1):
            raise ValueError("ring capacity smaller than the refit/guard span")

        self._scratch = cfg.max_twins     # scratch ring row + theta row
        src = share_modules_from
        if src is not None:
            if src.cfg.merinda != m or src.cfg.max_twins != cfg.max_twins \
                    or src.cfg.refit_slots != cfg.refit_slots \
                    or src.cfg.capacity != cfg.capacity \
                    or src.cfg.windows_per_twin != cfg.windows_per_twin \
                    or src.cfg.lr != cfg.lr \
                    or src.cfg.sparsify_after != cfg.sparsify_after \
                    or src.cfg.guard != cfg.guard \
                    or src.cfg.scenario != cfg.scenario:
                raise ValueError("share_modules_from requires identical "
                                 "fused-call shapes and guard/scenario "
                                 "config (merinda/ring/fleet cfg)")
            # ring / fleet / guard / scenario runner are stateless (state
            # passed explicitly); sharing the instances shares their jit
            # caches across shards
            self.ring, self.fleet, self.guard = src.ring, src.fleet, src.guard
            self.scenario_runner = src.scenario_runner
        else:
            self.ring = TelemetryRing(RingConfig(
                slots=cfg.max_twins + 1, capacity=cfg.capacity, n=m.n, m=m.m))
            self.fleet = FleetMerinda(FleetConfig(
                merinda=m, fleet=cfg.refit_slots,
                windows_per_twin=cfg.windows_per_twin, lr=cfg.lr,
                sparsify_after=cfg.sparsify_after))
            self.guard = DivergenceGuard(self.fleet.model.lib, m.dt,
                                         cfg.guard, use_pallas=m.use_pallas,
                                         interpret=m.interpret)
            self.scenario_runner = ScenarioRunner(
                self.fleet.model.lib, m.dt, cfg.scenario,
                use_pallas=m.use_pallas, interpret=m.interpret)
        self._rstate = self.ring.init()
        self._key = jax.random.PRNGKey(cfg.seed if seed is None else seed)
        self._fstate = self.fleet.init(self._split())

        sched_cfg = SchedulerConfig(
            slots=cfg.refit_slots, min_samples=self.min_samples,
            staleness_weight=cfg.staleness_weight,
            divergence_weight=cfg.divergence_weight,
            evict_margin=cfg.evict_margin, min_residency=cfg.min_residency,
            max_residency=cfg.max_residency,
            release_divergence=cfg.release_divergence)
        sched_metrics = SchedulerMetrics.create(self.metrics, self._labels)
        if cfg.scheduler == "bucketed":
            self.scheduler = PackedRefitScheduler(sched_cfg,
                                                  metrics=sched_metrics)
        elif cfg.scheduler == "reference":
            self.scheduler = RefitScheduler(sched_cfg, metrics=sched_metrics)
        else:
            raise ValueError(f"unknown scheduler {cfg.scheduler!r} "
                             "(expected 'bucketed' or 'reference')")
        # packed-arrays-as-truth scheduler state (twin/packed.py): every
        # mutation point below (flush accounting, deploy, guard fold, plan
        # apply, refit residency) writes BOTH the record and its packed row,
        # so the fused scoring call never rebuilds from the dict.  The
        # record dict stays the metadata mirror (ids, slots, tick stamps)
        # that tests/examples and the reference planner read.
        self.packed = PackedFleet(cfg.max_twins)
        self._max_active: int | None = None   # federation cap (None: all)

        self._rotation = (None if cfg.guard_budget is None else
                          GuardRotation(cfg.guard_budget,
                                        cfg.guard_budget // 4
                                        if cfg.guard_carry is None
                                        else cfg.guard_carry))

        self.twins: dict[int, TwinRecord] = {}
        self._row2rec: dict[int, TwinRecord] = {}     # ring row -> record
        # guard-eligible set (deployed + enough samples), maintained
        # INCREMENTALLY at deploy/flush time: the guard must not rescan all
        # 10k records per tick, or its cost is O(twins) again on the host
        # side no matter how small the fused budget is.  _div mirrors each
        # record's EMA score by ring row (the rotation's vectorized
        # carry-over scan reads it); since the packed-fleet refactor _div IS
        # the fleet's divergence column (same array object), so guard folds
        # feed the scheduler's fused scoring with no extra copy.  _live_rows
        # caches the sorted row array, rebuilt only when membership changes.
        self._guard_live: dict[int, TwinRecord] = {}  # ring row -> record
        self._guard_min = cfg.guard.window + 1
        self._div = self.packed.divergence
        self._live_rows = np.empty((0,), np.int64)
        self._live_dirty = False
        self._reg_lock = threading.Lock()             # async ingest registers
        self._guard_state: dict[int, str] = {}        # twin_id -> last kind
        self._slot_ring = np.full((cfg.refit_slots,), self._scratch,
                                  dtype=np.int32)     # refit slot -> ring row
        self._slot_twin: dict[int, int] = {}          # refit slot -> twin_id
        L = self.fleet.model.lib.size
        self._theta = jnp.zeros((cfg.max_twins + 1, m.n, L))
        # per-twin ring of recently served thetas (scenario confidence
        # ensemble); _hist_count tracks fills so unfilled slots fall back
        # to the live model inside the fused rollout
        self._theta_hist = jnp.zeros(
            (cfg.max_twins + 1, cfg.scenario.ensemble, m.n, L))
        self._hist_count = np.zeros((cfg.max_twins + 1,), np.int64)
        self._staging = StagingBuffer(capacity=cfg.staging_capacity)
        self._degradation = DegradationPolicy(cfg.degradation, cfg.deadline_s)
        self._pump = (BackgroundPump(self._prepare_timed,
                                     depth=cfg.ingest_depth)
                      if cfg.async_ingest else None)
        self.tick_count = 0
        self._n_deployed = 0
        self.inject_delay_s = 0.0     # chaos straggler (twin/recovery.py):
                                      # slept INSIDE the timed tick region so
                                      # the degradation policy sees the stall
        # recent-tick raw numbers (bounded; registry histograms are the
        # authoritative, never-growing stats — see _HISTORY note above)
        self.latencies: deque[float] = deque(maxlen=_HISTORY)
        self.stage_times: dict[str, deque] = {s: deque(maxlen=_HISTORY)
                                              for s in _STAGES}
        self.refresh_counts: deque[int] = deque(maxlen=_HISTORY)
        self.events: list[GuardEvent] = []
        self._init_instruments()

    def _init_instruments(self) -> None:
        """Resolve this server's metric children (per-shard labels)."""
        M, lab = self.metrics, self._labels
        self._m_tick = M.histogram(
            "twin_tick_latency_seconds",
            help="full serving-tick wall latency", unit="seconds",
            labels=lab)
        self._m_stage = {
            s: M.histogram("twin_stage_latency_seconds",
                           help="per-stage serving-tick wall latency",
                           unit="seconds", labels={**lab, "stage": s})
            for s in _STAGES}
        self._m_violations = M.counter(
            "twin_deadline_violations_total",
            help="ticks whose wall latency exceeded deadline_s", labels=lab)
        self._m_refreshes = M.counter(
            "twin_slot_refreshes_total",
            help="refit-slot train advances (active slots summed per tick)",
            labels=lab)
        self._m_dropped = M.counter(
            "twin_dropped_samples_total",
            help="telemetry samples truncated by flush backlog (ring would "
                 "have overwritten them)", labels=lab)
        self._m_overflow = M.counter(
            "twin_flush_overflows_total",
            help="flush batches that truncated a backlog", labels=lab)
        self._m_prepare = M.histogram(
            "twin_flush_prepare_seconds",
            help="host-side staging merge/pad latency (pump thread when "
                 "async)", unit="seconds", labels=lab)
        self._m_tracked = M.gauge(
            "twin_tracked_twins", help="registered tracked objects",
            labels=lab)
        self._m_deployed = M.gauge(
            "twin_deployed_twins", help="twins with a serving theta",
            labels=lab)
        self._m_active = M.gauge(
            "twin_active_slots", help="refit slots currently assigned",
            labels=lab)
        self._m_staging = M.gauge(
            "twin_staging_pending_samples",
            help="samples staged but not yet flushed", labels=lab)
        self._m_queue = M.gauge(
            "twin_pump_queue_depth",
            help="prepared flush batches awaiting the serving tick",
            labels=lab)
        self._m_degraded = M.gauge(
            "twin_degraded_level",
            help="deadline-degradation ladder level (0 = full service)",
            labels=lab)
        self._m_deg_trans = {
            d: M.counter("twin_degraded_transitions_total",
                         help="degradation ladder moves by direction",
                         labels={**lab, "direction": d})
            for d in ("up", "down")}
        self._m_shed = {
            a: M.counter("twin_degraded_shed_total",
                         help="ticks that shed a stage under degradation",
                         labels={**lab, "action": a})
            for a in ("guard", "refit", "promote")}
        self._m_ingest_retries = M.counter(
            "twin_ingest_retries_total",
            help="ingest backoff retries after a staging overflow",
            labels=lab)
        self._m_ingest_dropped = M.counter(
            "twin_ingest_dropped_total",
            help="staged samples shed (drop-oldest) by non-strict ingest "
                 "backpressure", labels=lab)
        self._guard_obs = GuardInstruments.create(M, lab)
        self._m_scn_latency = M.histogram(
            "twin_scenario_latency_seconds",
            help="what-if query wall latency (ensemble x K fused rollout)",
            unit="seconds", labels=lab)
        self._m_scn_requests = M.counter(
            "twin_scenario_requests_total",
            help="scenario queries answered", labels=lab)
        self._m_scn_rollouts = M.counter(
            "twin_scenario_rollouts_total",
            help="individual trajectories integrated for scenario queries "
                 "(effective K x ensemble)", labels=lab)
        self._m_scn_shrunk = M.counter(
            "twin_scenario_shrunk_total",
            help="scenario queries served with K shrunk by the degradation "
                 "ladder", labels=lab)
        self._m_scn_refused = M.counter(
            "twin_scenario_refused_total",
            help="scenario queries refused under deadline pressure",
            labels=lab)
        self._m_scn_confidence = M.histogram(
            "twin_scenario_confidence",
            help="per-scenario ensemble confidence (1 = recent thetas "
                 "agree)", bounds=(0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0),
            labels=lab)

    # ------------------------------------------------------------------ #
    def _split(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    # ------------------------------------------------------------------ #
    def register(self, twin_id: int) -> TwinRecord:
        """Start tracking an object; assigns its telemetry ring row."""
        rec = self.twins.get(twin_id)
        if rec is not None:
            return rec
        with self._reg_lock:
            rec = self.twins.get(twin_id)
            if rec is not None:
                return rec
            row = len(self.twins)
            if row >= self.cfg.max_twins:
                raise RuntimeError(f"server full ({self.cfg.max_twins} twins)")
            rec = TwinRecord(twin_id=twin_id, ring_slot=row)
            self.twins[twin_id] = rec
            self._row2rec[row] = rec
            self._guard_state[twin_id] = "OK"
            self.packed.register(row, twin_id)
            return rec

    def twin_snapshot(self) -> dict[int, TwinRecord]:
        """Registry copy safe to iterate while ingest threads register."""
        with self._reg_lock:
            return dict(self.twins)

    def _guard_add(self, rec: TwinRecord) -> None:
        """Admit a record to the guard-eligible set (idempotent)."""
        if rec.ring_slot not in self._guard_live:
            self._guard_live[rec.ring_slot] = rec
            self.packed.set_divergence(rec.ring_slot, rec.divergence)
            self._live_dirty = True

    # ------------------------------------------------------------------ #
    def ingest(self, twin_id: int, y, u=None, *, force: bool = False):
        """Stage telemetry for `twin_id`: y [n] or [C, n], u [m] or [C, m].

        Host-side staging only — the device scatter happens once per tick in
        the fused flush, so per-sample ingest stays cheap.  Thread-safe:
        with `async_ingest` many sensor threads may call this concurrently
        with `tick()` (the staging buffer is the synchronized handoff).

        Backpressure (bounded staging, `cfg.staging_capacity`): an overflow
        retries up to `ingest_retries` times with doubling backoff (kicking
        the pump each try so a stalled flush can clear); if still full,
        strict mode re-raises `StagingOverflow` to the producer, non-strict
        mode sheds the OLDEST staged samples (counted in
        `twin_ingest_dropped_total`) and stages the new chunk — fresh
        telemetry outranks stale backlog for a guard that scores NEWEST
        windows.  `force=True` bypasses the bound entirely (crash-recovery
        replay, twin/recovery.py).
        """
        rec = self.register(twin_id)
        y = np.atleast_2d(np.asarray(y, np.float32))
        C = y.shape[0]
        m = self.cfg.merinda.m
        u = (np.zeros((C, m), np.float32) if u is None
             else np.asarray(u, np.float32).reshape(C, m))
        if C > self.cfg.capacity:
            raise ValueError("chunk larger than ring capacity")
        try:
            self._staging.append(rec.ring_slot, y, u, force=force)
        except StagingOverflow:
            self._ingest_backpressure(rec.ring_slot, y, u)
        if self._pump is not None:
            self._pump.kick()

    def ingest_many(self, batch, *, force: bool = False) -> int:
        """Batched `ingest`: `batch` iterates (twin_id, y) or (twin_id, y, u)
        chunks — one call per producer flush instead of one per sample, the
        shape the network front door (twin/wire.py IngestBatch) arrives in.
        Returns the number of SAMPLES staged.  Same thread-safety and
        backpressure contract as `ingest`."""
        staged = 0
        for chunk in batch:
            tid, y = chunk[0], chunk[1]
            u = chunk[2] if len(chunk) > 2 else None
            self.ingest(tid, y, u, force=force)
            staged += np.atleast_2d(np.asarray(y)).shape[0]
        return staged

    def _ingest_backpressure(self, row: int, y, u) -> None:
        """Bounded retry-with-backoff, then strict-raise or drop-oldest."""
        delay = self.cfg.ingest_backoff_s
        for _ in range(max(0, self.cfg.ingest_retries)):
            self._m_ingest_retries.inc()
            if self._pump is not None:
                self._pump.kick()      # give the flusher a chance to drain
            time.sleep(delay)
            delay *= 2
            try:
                self._staging.append(row, y, u)
                return
            except StagingOverflow:
                continue
        if self.cfg.ingest_strict:
            raise StagingOverflow(
                f"staging buffer still full after "
                f"{self.cfg.ingest_retries} retries "
                f"(capacity {self.cfg.staging_capacity} samples)")
        dropped = self._staging.drop_oldest(len(y))
        self._m_ingest_dropped.inc(dropped)
        self._staging.append(row, y, u, force=True)

    # -- staging flush: prepare (host, possibly background) + apply ----- #
    def _prepare(self) -> FlushBatch | None:
        m = self.cfg.merinda
        return prepare_flush(self._staging.swap(),
                             capacity=self.cfg.capacity,
                             pad=self.cfg.flush_pad, scratch=self._scratch,
                             n=m.n, m=m.m)

    def _prepare_timed(self) -> FlushBatch | None:
        """`_prepare` under a span + latency histogram — with async ingest
        this runs on the pump thread, so the span lands on the pump's own
        Perfetto track and the histogram shows how much host merge/pad work
        the tick was spared."""
        with self.tracer.span("pump_flush", cat="ingest", **self._labels):
            t0 = time.perf_counter()
            batch = self._prepare()
            self._m_prepare.observe(time.perf_counter() - t0)
        return batch

    @property
    def dropped_samples(self) -> int:
        """Backlog samples truncated by the flush (loud; counter-backed)."""
        return int(self._m_dropped.value)

    def _apply(self, batch: FlushBatch) -> int:
        if batch.dropped:
            self._m_dropped.inc(batch.dropped)
            self._m_overflow.inc()
        for row, raw in batch.received.items():
            rec = self._row2rec[row]
            rec.samples += raw
            self.packed.samples[row] = rec.samples
            if rec.deployed and rec.samples >= self._guard_min:
                self._guard_add(rec)
        self._rstate = self.ring.ingest(
            self._rstate, jnp.asarray(batch.slots), jnp.asarray(batch.ys),
            jnp.asarray(batch.us), jnp.asarray(batch.counts))
        return sum(batch.received.values())

    def _flush(self) -> int:
        if self._pump is not None:
            return sum(self._apply(b) for b in self._pump.drain())
        batch = self._prepare_timed()
        return self._apply(batch) if batch is not None else 0

    def drain(self) -> None:
        """Barrier: every sample ingested before this call reaches the ring.

        With async ingest, waits for the pump to go idle, applies every
        prepared batch, then flushes anything still staged inline.  Must be
        called from the serving (tick) thread — device state is
        single-threaded by design.

        Guarantee: on return, all samples whose `ingest()` call returned
        BEFORE `drain()` started are visible to the next fused gather.
        Samples ingested concurrently with the drain may or may not be
        included (they are never lost — at worst they wait for the next
        flush).  Busy-waits in 0.1 ms sleeps while the pump finishes its
        in-flight batch; does not block producers.
        """
        if self._pump is not None:
            while not self._pump.idle():
                for b in self._pump.drain():
                    self._apply(b)
                time.sleep(1e-4)
            for b in self._pump.drain():
                self._apply(b)
        batch = self._prepare_timed()
        if batch is not None:
            self._apply(batch)

    def close(self) -> None:
        """Stop the async flush worker (no-op for synchronous servers)."""
        if self._pump is not None:
            self._pump.close()

    # ------------------------------------------------------------------ #
    def set_active_slots(self, n: int | None) -> None:
        """Cap the refit slots the scheduler may fill (federation rebalance;
        twin/sharded.py).  None restores the full physical pool."""
        self._max_active = n

    @property
    def active_slot_cap(self) -> int:
        return (self.cfg.refit_slots if self._max_active is None
                else max(0, min(self.cfg.refit_slots, self._max_active)))

    def refit_pressure(self) -> float:
        """Aggregate staleness+divergence refit demand — the federation's
        rebalance signal.  Bucketed scheduler: one fused device reduction
        over the packed arrays; reference scheduler: the O(twins) host scan
        over a registry snapshot."""
        if isinstance(self.scheduler, PackedRefitScheduler):
            return self.scheduler.pressure(self.packed)
        return self.scheduler.pressure(self.twin_snapshot())

    # ------------------------------------------------------------------ #
    def _hist_push(self, rows: np.ndarray, thetas) -> None:
        """Append served thetas to the per-twin history rings (one scatter).

        rows [B] ring rows, thetas [B, n, L].  Every deploy/promote lands
        here so the scenario ensemble always holds the `ensemble` most
        recently SERVED models per twin — a cheap, always-fresh proxy for
        model uncertainty (thrashing refits -> wide envelope).
        """
        pos = (self._hist_count[rows] % self.cfg.scenario.ensemble)
        self._theta_hist = self._theta_hist.at[
            jnp.asarray(rows), jnp.asarray(pos.astype(np.int32))].set(thetas)
        self._hist_count[rows] += 1

    def deploy(self, twin_id: int, theta) -> None:
        """Install a theta for `twin_id` directly (warm start from an offline
        recovery — lets a fleet come up serving while online refits rotate)."""
        rec = self.register(twin_id)
        theta = jnp.asarray(theta)
        self._theta = self._theta.at[rec.ring_slot].set(theta)
        self._hist_push(np.asarray([rec.ring_slot], np.int64), theta[None])
        self._mark_deployed(rec)
        rec.samples_at_deploy = rec.samples
        self.packed.samples_at_deploy[rec.ring_slot] = rec.samples
        rec.deploy_tick = self.tick_count
        if rec.samples >= self._guard_min:
            self._guard_add(rec)

    def deploy_many(self, twin_ids, thetas) -> None:
        """Warm-start a whole fleet in one scatter: thetas [B, n, L] (or a
        single [n, L] broadcast to every twin).  The 10k-twin startup path —
        per-twin `deploy` would issue 10k device ops.

        Registers unknown twin_ids, marks every target deployed, and admits
        twins with >= guard.window+1 ring samples to the guard-eligible set.
        Serving-thread only (mutates the device theta store); not safe to
        call concurrently with `tick()`.
        """
        recs = [self.register(t) for t in twin_ids]
        rows = np.asarray([r.ring_slot for r in recs], np.int32)
        thetas = jnp.asarray(thetas)
        if thetas.ndim == 2:
            thetas = jnp.broadcast_to(thetas, (len(recs),) + thetas.shape)
        self._theta = self._theta.at[jnp.asarray(rows)].set(thetas)
        self._hist_push(rows.astype(np.int64), thetas)
        for rec in recs:
            self._mark_deployed(rec)
            rec.samples_at_deploy = rec.samples
            self.packed.samples_at_deploy[rec.ring_slot] = rec.samples
            rec.deploy_tick = self.tick_count
            if rec.samples >= self._guard_min:
                self._guard_add(rec)

    def _mark_deployed(self, rec: TwinRecord) -> None:
        if not rec.deployed:
            rec.deployed = True
            self.packed.deployed[rec.ring_slot] = True
            self._n_deployed += 1

    # ------------------------------------------------------------------ #
    def _update_divergence(self, shed: bool = False
                           ) -> tuple[list[GuardEvent], int]:
        gw = self.cfg.guard.window
        live = self._guard_live       # maintained incrementally, O(1)/tick
        if not live:
            return [], 0
        if self._rotation is None:
            # full scan: one fused call over the whole store (O(twins)).
            # Degraded: the scan has ONE fused shape, so shedding means
            # scoring every other tick — half the device work, freshness
            # halves instead of breaking.
            if shed and self.tick_count % 2 == 0:
                return [], 0
            rows = jnp.arange(self.cfg.max_twins)
            ys, us = self.ring.latest(self._rstate, rows, gw)
            scores = np.asarray(self.guard.score(self._theta[:-1], ys, us))
            recs = list(live.values())
            srows = np.fromiter((r.ring_slot for r in recs), np.int64,
                                count=len(recs))
            raw = scores[srows]
        else:
            # budgeted rotation: fixed-size fused call (O(budget)).
            # Degraded: a SMALLER fixed width (budget // guard_shrink, no
            # carry) — one extra compile the first time the ladder engages,
            # then a genuinely cheaper rollout until pressure clears.
            if self._live_dirty:
                self._live_rows = np.fromiter(sorted(live), np.int64,
                                              count=len(live))
                self._live_dirty = False
            if shed:
                width = max(1, self._rotation.budget
                            // max(1, self.cfg.degradation.guard_shrink))
                pick = self._rotation.select(self._live_rows, self._div,
                                             self.cfg.guard.refit_threshold,
                                             budget=width, carry=0)
            else:
                width = self._rotation.size
                pick = self._rotation.select(self._live_rows, self._div,
                                             self.cfg.guard.refit_threshold)
            rows_np = np.full((width,), self._scratch, np.int32)
            rows_np[:len(pick)] = pick
            rows = jnp.asarray(rows_np)
            ys, us = self.ring.latest(self._rstate, rows, gw)
            scores = np.asarray(self.guard.score(self._theta[rows], ys, us))
            recs = [live[int(row)] for row in pick]
            srows = np.asarray(pick, np.int64)
            raw = scores[:len(recs)]
        # one vectorized EMA fold publishes the smoothed scores into the
        # packed divergence column (_div IS packed.divergence); the record
        # fields are mirrors of the same values
        smoothed = self.guard.fold_into(self._div, srows, raw)
        self.packed.div32[srows] = smoothed   # float32 shadow for the kernel
        events: list[GuardEvent] = []
        score_hist = self._guard_obs.score
        for rec, score, div in zip(recs, raw, smoothed):
            score_hist.observe(float(score))
            rec.divergence = float(div)
            ev = self.guard.judge(rec.twin_id, rec.divergence, self.tick_count)
            kind = ev.kind if ev else "OK"
            if kind != self._guard_state[rec.twin_id]:
                self._guard_state[rec.twin_id] = kind
                if ev:
                    events.append(ev)
                    self._guard_obs.events[ev.kind].inc()
        self.events.extend(events)
        self._guard_obs.scored.inc(len(recs))
        return events, len(recs)

    # ------------------------------------------------------------------ #
    def _slot_windows(self):
        rows = jnp.asarray(self._slot_ring)
        return self.ring.windows(self._rstate, rows, window=self.cfg.window,
                                 stride=self.cfg.stride, length=self.span)

    def _apply_plan(self, plan: SchedulePlan) -> None:
        packed = self.packed
        for tid in plan.evict + plan.release:
            rec = self.twins[tid]
            self._slot_ring[rec.refit_slot] = self._scratch
            self._slot_twin.pop(rec.refit_slot, None)
            rec.refit_slot = None
            rec.residency = rec.steps_in_slot = 0
            packed.resident[rec.ring_slot] = False
            packed.residency[rec.ring_slot] = 0
        for slot, tid in plan.admit:
            rec = self.twins[tid]
            y_w, u_w = self.ring.windows(
                self._rstate, jnp.asarray([rec.ring_slot]),
                window=self.cfg.window, stride=self.cfg.stride,
                length=self.span)
            self._fstate = self.fleet.reset_slot(
                self._fstate, jnp.int32(slot), self._split(), y_w[0], u_w[0])
            rec.refit_slot = slot
            rec.admitted_tick = self.tick_count
            rec.residency = rec.steps_in_slot = 0
            packed.resident[rec.ring_slot] = True
            packed.residency[rec.ring_slot] = 0
            self._slot_ring[slot] = rec.ring_slot
            self._slot_twin[slot] = tid

    def _refit(self, defer: bool = False, skip_promote: bool = False
               ) -> float | None:
        if not self._slot_twin:
            return None
        if defer:
            # degraded (level >= 2): slots hold — no train steps, residency
            # frozen.  Candidates that already converged may still ship
            # (level < 3): promotion is one shadow-eval rollout, far cheaper
            # than steps_per_tick train steps, and a finished model serving
            # beats a finished model waiting out an overload.
            if not skip_promote:
                deployable = [
                    slot for slot, tid in self._slot_twin.items()
                    if self.twins[tid].steps_in_slot >= self.cfg.deploy_after]
                if deployable:
                    y_win, u_win = self._slot_windows()
                    self._promote(deployable, y_win, u_win)
            return None
        y_win, u_win = self._slot_windows()
        loss_vec = None
        for _ in range(self.cfg.steps_per_tick):
            self._fstate, loss_vec, _ = self.fleet.train_step_per_slot(
                self._fstate, y_win, u_win)
        # report loss over ASSIGNED slots only — scratch-parked slots train
        # on zero windows and would dilute the mean toward zero
        loss = float(np.mean(np.asarray(loss_vec)[sorted(self._slot_twin)]))
        deployable = []
        for slot, tid in self._slot_twin.items():
            rec = self.twins[tid]
            rec.steps_in_slot += self.cfg.steps_per_tick
            rec.residency += 1
            self.packed.residency[rec.ring_slot] = rec.residency
            if rec.steps_in_slot >= self.cfg.deploy_after:
                deployable.append(slot)
        if deployable and not skip_promote:
            self._promote(deployable, y_win, u_win)
        return loss

    def _promote(self, deployable, y_win, u_win) -> None:
        """Shadow-evaluate slot recoveries and deploy only improvements.

        Both the candidate theta and the incumbent are rolled over the same
        newest telemetry (one fused guard call each).  Against a HEALTHY
        incumbent (score < refit_threshold) the candidate must beat it by
        `promote_margin` — "good enough" is not enough to replace a model
        that tracks reality better.  Against a missing/diverged incumbent the
        candidate ships if it is outright good or a margin improvement.
        """
        thresh = self.cfg.guard.refit_threshold
        rows = jnp.asarray(self._slot_ring)
        thetas = self.fleet.recover_all(self._fstate, y_win, u_win)
        ys_g, us_g = self.ring.latest(self._rstate, rows,
                                      self.cfg.guard.window)
        cand = np.asarray(self.guard.score(thetas, ys_g, us_g))
        inc = np.asarray(self.guard.score(self._theta[rows], ys_g, us_g))
        targets = np.full((self.cfg.refit_slots,), self._scratch,
                          dtype=np.int32)
        promoted = set()
        for slot in deployable:
            rec = self.twins[self._slot_twin[slot]]
            healthy_inc = rec.deployed and inc[slot] < thresh
            better = cand[slot] < self.cfg.promote_margin * inc[slot]
            if better or (not healthy_inc and cand[slot] < thresh):
                targets[slot] = rec.ring_slot
                promoted.add(slot)
            elif healthy_inc:
                # candidate lost, but the serving model is still healthy:
                # count this as a completed review so the twin's staleness
                # resets and it stops hogging a refit slot.
                rec.samples_at_deploy = rec.samples
                self.packed.samples_at_deploy[rec.ring_slot] = rec.samples
        if promoted:
            self._theta = self._theta.at[jnp.asarray(targets)].set(thetas)
            slots = sorted(promoted)
            prows = np.asarray(
                [self.twins[self._slot_twin[s]].ring_slot for s in slots],
                np.int64)
            self._hist_push(prows, thetas[jnp.asarray(slots)])
        for slot in promoted:
            rec = self.twins[self._slot_twin[slot]]
            self._mark_deployed(rec)
            rec.samples_at_deploy = rec.samples
            self.packed.samples_at_deploy[rec.ring_slot] = rec.samples
            rec.deploy_tick = self.tick_count
            rec.divergence = float(min(cand[slot], 1e6))
            self.packed.set_divergence(rec.ring_slot, rec.divergence)
            if rec.samples >= self._guard_min:
                self._guard_add(rec)

    # ------------------------------------------------------------------ #
    def tick(self) -> TickReport:
        """One full serving cycle; see module docstring for the five stages.

        Units: `TickReport.latency_s` and `cfg.deadline_s` are SECONDS
        (`latency_summary`/`stage_summary` report milliseconds); the default
        deadline of 1.0 s is the paper's mission budget — 5x under the 5 s
        human-pilot reaction time.  `deadline_met` compares this tick's wall
        latency against `cfg.deadline_s`.

        Threading: must be called from the single serving thread (device
        state — ring, fleet, theta store — is single-threaded by design).
        `ingest()` MAY run concurrently on sensor threads; the staging
        buffer's lock is the only synchronization point between them, and a
        registry snapshot is taken before scheduling so concurrent
        registrations cannot race dict iteration.

        Fused-call costs per tick: flush is one scatter over the reporting
        twins (pow2-bucketed shapes), guard is O(guard_budget + carry)
        device work and O(budget) host work (`GuardRotation`), refit is
        `steps_per_tick` fixed-shape train steps over `refit_slots` slots.
        """
        span = self.tracer.span
        # degradation ladder: consult the level set by the PREVIOUS tick's
        # observe() — shedding decisions are made before the work they shed
        deg = self._degradation
        shed_guard, defer_refit = deg.shed_guard, deg.defer_refit
        skip_promote = deg.skip_promote
        with span("tick", tick=self.tick_count + 1, **self._labels):
            t0 = time.perf_counter()
            self.tick_count += 1
            if self.inject_delay_s > 0.0:
                time.sleep(self.inject_delay_s)
            with span("flush"):
                self._flush()
            t1 = time.perf_counter()
            with span("guard"):
                if shed_guard:
                    self._m_shed["guard"].inc()
                events, n_guarded = self._update_divergence(shed=shed_guard)
            t2 = time.perf_counter()
            # bucketed path: plan straight off the packed arrays (a twin
            # registered mid-plan is visible only once `registered` flips,
            # and with 0 samples it cannot be ready — no snapshot needed).
            # reference path: snapshot the registry, since async ingest
            # threads may register new twins mid-tick and dict iteration
            # must not race those inserts.
            with span("schedule"):
                if isinstance(self.scheduler, PackedRefitScheduler):
                    plan = self.scheduler.plan(self.packed, self._slot_ring,
                                               max_active=self._max_active)
                else:
                    plan = self.scheduler.plan(self.twin_snapshot(),
                                               max_active=self._max_active)
                self._apply_plan(plan)
            t3 = time.perf_counter()
            with span("refit"):
                if defer_refit:
                    self._m_shed["refit"].inc()
                if skip_promote:
                    self._m_shed["promote"].inc()
                loss = self._refit(defer=defer_refit,
                                   skip_promote=skip_promote)
                jax.block_until_ready(self._theta)
            t4 = time.perf_counter()
        latency = t4 - t0
        self.latencies.append(latency)
        self._m_tick.observe(latency)
        for stage, dt in zip(_STAGES, (t1 - t0, t2 - t1, t3 - t2, t4 - t3)):
            self.stage_times[stage].append(dt)
            self._m_stage[stage].observe(dt)
        if latency > self.cfg.deadline_s:
            self._m_violations.inc()
        deg_ev = deg.observe(self.tick_count, latency)
        self._m_degraded.set(deg.level)
        if deg_ev is not None:
            self._m_deg_trans[
                "up" if deg_ev.to_level > deg_ev.from_level else "down"].inc()
        n_active = len(self._slot_twin)
        self.refresh_counts.append(n_active)
        if n_active:
            self._m_refreshes.inc(n_active)
        self._m_tracked.set(len(self.twins))
        self._m_deployed.set(self._n_deployed)
        self._m_active.set(n_active)
        self._m_staging.set(self._staging.pending_samples())
        if self._pump is not None:
            self._m_queue.set(self._pump.queue_depth())
        self._guard_obs.live.set(len(self._guard_live))
        return TickReport(
            tick=self.tick_count, latency_s=latency,
            deadline_met=latency <= self.cfg.deadline_s, loss=loss,
            events=events, admitted=plan.admit, evicted=plan.evict,
            released=plan.release, n_active=n_active,
            n_twins=len(self.twins), n_guarded=n_guarded,
            degraded_level=deg.level,
            degradation_events=[deg_ev] if deg_ev is not None else [])

    # ------------------------------------------------------------------ #
    def predict(self, twin_id: int, horizon: int, us=None):
        """Roll the deployed model `horizon` steps from the newest telemetry.

        Returns ys [horizon+1, n] (index 0 = the newest observed state).
        """
        rec = self.twins[twin_id]
        if not rec.deployed:
            raise RuntimeError(f"twin {twin_id} has no deployed model")
        if rec.samples < 1:
            # the ring is still all zeros — a rollout would silently start
            # from the origin instead of the twin's actual state
            raise RuntimeError(f"twin {twin_id} has no telemetry to "
                               "predict from")
        ys, _ = self.ring.latest(self._rstate,
                                 jnp.asarray([rec.ring_slot]), 0)
        y0 = ys[:, -1, :]                                    # [1, n]
        m = self.cfg.merinda.m
        us = (jnp.zeros((1, horizon, m)) if us is None
              else jnp.asarray(us, jnp.float32).reshape(1, horizon, m))
        out = rk4_poly_solve(self._theta[rec.ring_slot][None], y0, us,
                             dt=self.cfg.merinda.dt, library=self.fleet.model.lib,
                             use_pallas=self.cfg.merinda.use_pallas,
                             interpret=self.cfg.merinda.interpret)
        return out[0]

    def scenario(self, twin_id: int, horizon: int, us=None,
                 k: int | None = None) -> ScenarioResult:
        """Answer a batched what-if query for one twin (twin/scenario.py).

        `us` is [K, horizon, m] counterfactual input sequences (or
        [horizon, m] for K=1; None = zero inputs, K from `k`).  Returns a
        `ScenarioResult` whose center trajectories come from the LIVE theta
        and whose lo/hi/confidence come from the recent-theta ensemble.
        Under deadline pressure the degradation ladder deterministically
        shrinks K (level >= shrink_level) or raises `ScenarioRefused`
        (level >= refuse_level) before any device work is dispatched.

        Serving-thread only, like `predict` (reads device ring state).
        """
        rec = self.twins[twin_id]
        if not rec.deployed:
            raise RuntimeError(f"twin {twin_id} has no deployed model")
        if rec.samples < 1:
            raise RuntimeError(f"twin {twin_id} has no telemetry to "
                               "roll scenarios from")
        if horizon < 1:
            raise ValueError(f"horizon must be >= 1, got {horizon}")
        scfg = self.cfg.scenario
        m = self.cfg.merinda.m
        if us is not None:
            us = np.asarray(us, np.float32)
            if us.ndim == 2:
                us = us[None]
            if us.ndim != 3 or us.shape[1] != horizon or us.shape[2] != m:
                raise ValueError(f"us must be [K, {horizon}, {m}], "
                                 f"got {us.shape}")
            requested = us.shape[0] if k is None else int(k)
            if requested > us.shape[0]:
                raise ValueError(f"k {requested} exceeds provided "
                                 f"sequences {us.shape[0]}")
        else:
            requested = 1 if k is None else int(k)
        level = self._degradation.level
        with self.tracer.span("scenario", twin=int(twin_id), k=requested,
                              horizon=int(horizon), level=level):
            t0 = time.perf_counter()
            try:
                eff = effective_k(requested, level, scfg)
            except ScenarioRefused:
                self._m_scn_refused.inc()
                raise
            if eff < requested:
                self._m_scn_shrunk.inc()
            us_eff = (np.zeros((eff, horizon, m), np.float32)
                      if us is None else np.ascontiguousarray(us[:eff]))
            ys, _ = self.ring.latest(self._rstate,
                                     jnp.asarray([rec.ring_slot]), 0)
            center, lo, hi, conf = self.scenario_runner.rollout(
                self._theta_hist[rec.ring_slot],
                int(self._hist_count[rec.ring_slot]),
                ys[0, -1, :], us_eff)
            self._m_scn_requests.inc()
            self._m_scn_rollouts.inc(eff * scfg.ensemble)
            for c in conf:
                self._m_scn_confidence.observe(float(c))
            self._m_scn_latency.observe(time.perf_counter() - t0)
        return ScenarioResult(twin_id=int(twin_id), horizon=int(horizon),
                              requested_k=requested, k=eff,
                              degraded_level=level, ys=center, lo=lo, hi=hi,
                              confidence=conf)

    # ------------------------------------------------------------------ #
    def reset_latency_stats(self) -> None:
        """Reset the measured-window stats (benchmarks call this after jit
        warmup).  Resets the tick/stage histograms and the violation/refresh
        counters; LEAVES the monotone accounting counters (dropped samples,
        overflows, guard events) alone — those are lifetime totals."""
        self.latencies.clear()
        self.refresh_counts.clear()
        for times in self.stage_times.values():
            times.clear()
        self._m_tick.reset()
        for h in self._m_stage.values():
            h.reset()
        self._m_violations.reset()
        self._m_refreshes.reset()
        self._degradation.reset()     # compile stalls are not overload
        self._m_degraded.set(0)

    def latency_summary(self) -> dict:
        """p50/p99 refresh latency vs the deadline + serving throughput.

        Registry-backed: the same bounded histograms/counters an operator
        scrapes via `metrics.expose()` produce these numbers, so benchmarks
        and production dashboards cannot disagree.  p50/p99 are log-bucket
        estimates (< 4% relative quantization); max/violations are exact.
        """
        h = self._m_tick
        ticks = h.count
        if ticks == 0:
            return {"ticks": 0}
        return {
            "ticks": ticks,
            "p50_ms": h.quantile(0.5) * 1e3,
            "p99_ms": h.quantile(0.99) * 1e3,
            "max_ms": h.max * 1e3,
            "deadline_s": self.cfg.deadline_s,
            "violations": int(self._m_violations.value),
            # actual slot-refreshes performed, not pool capacity: idle slots
            # don't count toward serving throughput
            "twin_refreshes_per_s":
                self._m_refreshes.value / max(h.sum, 1e-9),
            "dropped_samples": int(self._m_dropped.value),
            "flush_overflows": int(self._m_overflow.value),
        }

    def stage_summary(self) -> dict:
        """Mean per-tick cost of each serving stage (ms) — the guard column
        is the scale benchmark's O(budget)-flatness evidence.  Registry-
        backed (histogram sum/count), same source the exporters scrape."""
        out = {}
        for stage, hist in self._m_stage.items():
            n = hist.count
            out[f"{stage}_ms"] = (hist.sum / n * 1e3) if n else 0.0
        return out

    # -- crash-safe serving state (twin/recovery.py checkpoints) -------- #
    @property
    def degraded_level(self) -> int:
        """Current deadline-degradation ladder level (0 = full service)."""
        return self._degradation.level

    _GUARD_KINDS = ("OK", "REFIT", "ALERT")

    def snapshot_state(self) -> dict:
        """Full serving state as a fixed-shape host pytree — what a
        `TwinCheckpointer` writes and `restore_state` consumes.

        Every leaf's shape is a function of the CONFIG alone (max_twins,
        refit_slots, ring capacity, model dims), never of runtime
        occupancy — so a fresh server's snapshot is a valid restore `like`
        and `checkpoint.restore`'s shape checks catch config drift.  All
        host arrays are COPIES (the async checkpoint writer must not race
        the serving thread's in-place mutations); device leaves are
        device_get by the checkpointer.

        Serving-thread only (reads device state mid-mutation otherwise).
        Excludes the staging buffer/pump (in-flight samples are the
        telemetry journal's job) and the bounded debug/metric windows
        (registry children are restart-safe monotone counters).
        """
        cap = self.cfg.max_twins
        refit_slot = np.full((cap,), -1, np.int32)
        deploy_tick = np.full((cap,), -1, np.int64)
        admitted_tick = np.full((cap,), -1, np.int64)
        steps_in_slot = np.zeros((cap,), np.int64)
        guard_code = np.zeros((cap,), np.int8)
        guard_live = np.zeros((cap,), bool)
        kind_code = {k: i for i, k in enumerate(self._GUARD_KINDS)}
        for rec in self.twin_snapshot().values():
            row = rec.ring_slot
            refit_slot[row] = -1 if rec.refit_slot is None else rec.refit_slot
            deploy_tick[row] = rec.deploy_tick
            admitted_tick[row] = rec.admitted_tick
            steps_in_slot[row] = rec.steps_in_slot
            guard_code[row] = kind_code[
                self._guard_state.get(rec.twin_id, "OK")]
        for row in self._guard_live:
            guard_live[row] = True
        slot_twin_ids = np.full((self.cfg.refit_slots,), -1, np.int64)
        for slot, tid in self._slot_twin.items():
            slot_twin_ids[slot] = tid
        return {
            "theta": self._theta,
            "theta_hist": self._theta_hist,
            "hist_count": self._hist_count.copy(),
            "rstate": self._rstate,
            "fstate": self._fstate,
            "key": self._key,
            "packed": self.packed.snapshot(),
            "rows": {"refit_slot": refit_slot, "deploy_tick": deploy_tick,
                     "admitted_tick": admitted_tick,
                     "steps_in_slot": steps_in_slot,
                     "guard_code": guard_code, "guard_live": guard_live},
            "slot_ring": self._slot_ring.copy(),
            "slot_twin_ids": slot_twin_ids,
            "scalars": np.asarray(
                [self.tick_count, self._n_deployed,
                 0 if self._rotation is None else self._rotation._cursor,
                 -1 if self._max_active is None else self._max_active],
                np.int64),
        }

    def restore_state(self, state: dict) -> None:
        """Rebuild this server's serving state from a `snapshot_state`
        tree (typically `checkpoint.restore`d into a fresh server's own
        snapshot as `like`).  In-place where aliasing matters: the packed
        columns are loaded with `[:]` so `_div` keeps aliasing
        `packed.divergence`.  The registry (TwinRecord dict, row maps,
        guard-live set) is rebuilt from the packed columns + per-row extras.
        Serving-thread only; call before any post-restart ingest/tick."""
        self._theta = jnp.asarray(state["theta"])
        self._theta_hist = jnp.asarray(state["theta_hist"])
        self._hist_count[:] = np.asarray(state["hist_count"])
        self._rstate = jax.tree.map(jnp.asarray, state["rstate"])
        self._fstate = jax.tree.map(jnp.asarray, state["fstate"])
        self._key = jnp.asarray(state["key"])
        self.packed.load(state["packed"])
        self._slot_ring[:] = np.asarray(state["slot_ring"], np.int32)
        scalars = np.asarray(state["scalars"])
        self.tick_count = int(scalars[0])
        self._n_deployed = int(scalars[1])
        if self._rotation is not None:
            self._rotation._cursor = int(scalars[2])
        ma = int(scalars[3])
        self._max_active = None if ma < 0 else ma
        rows = state["rows"]
        refit_slot = np.asarray(rows["refit_slot"])
        deploy_tick = np.asarray(rows["deploy_tick"])
        admitted_tick = np.asarray(rows["admitted_tick"])
        steps_in_slot = np.asarray(rows["steps_in_slot"])
        guard_code = np.asarray(rows["guard_code"])
        guard_live = np.asarray(rows["guard_live"])
        p = self.packed
        with self._reg_lock:
            self.twins.clear()
            self._row2rec.clear()
            self._guard_state.clear()
            self._guard_live.clear()
            self._slot_twin.clear()
            for row in np.flatnonzero(p.registered):
                row = int(row)
                rec = TwinRecord(
                    twin_id=int(p.twin_id[row]), ring_slot=row,
                    refit_slot=(None if refit_slot[row] < 0
                                else int(refit_slot[row])),
                    samples=int(p.samples[row]),
                    samples_at_deploy=int(p.samples_at_deploy[row]),
                    deployed=bool(p.deployed[row]),
                    deploy_tick=int(deploy_tick[row]),
                    admitted_tick=int(admitted_tick[row]),
                    residency=int(p.residency[row]),
                    steps_in_slot=int(steps_in_slot[row]),
                    divergence=float(p.divergence[row]))
                self.twins[rec.twin_id] = rec
                self._row2rec[row] = rec
                self._guard_state[rec.twin_id] = \
                    self._GUARD_KINDS[int(guard_code[row])]
                if guard_live[row]:
                    self._guard_live[row] = rec
            for slot, tid in enumerate(np.asarray(state["slot_twin_ids"])):
                if tid >= 0:
                    self._slot_twin[slot] = int(tid)
        self._live_dirty = True
