"""TwinServer: the online serving loop — ingest, refit, deploy, guard.

One `tick()` is a full serving cycle over the whole tracked fleet:

    1. FLUSH    staged telemetry into the device ring buffers (one fused
                scatter for every twin that produced samples this tick),
    2. GUARD    RK4-roll every deployed theta over its newest window and
                EMA-fold the normalized rollout error into each twin's
                divergence score; emit REFIT/ALERT events on transitions,
    3. SCHEDULE admit/evict/release twins over the bounded refit-slot pool
                by staleness + divergence priority (twin/scheduler.py),
    4. REFIT    `steps_per_tick` fused FleetMerinda.train_step calls over all
                slots at once (the bounded compute budget),
    5. DEPLOY   recover_all on slots whose twin has trained past
                `deploy_after`, scattered into the serving theta store.

Every fused call has a FIXED shape (refit_slots / max_twins), so steady-state
serving compiles exactly once; unassigned refit slots are parked on a scratch
ring row (`max_twins`) and unused recoveries land on a scratch theta row.

Per-tick wall latency is recorded against `deadline_s`.  The paper's
mission budget: beat the 5 s human-pilot reaction time 5x — refresh every
deployed twin in <= 1 s.

`predict(twin_id, horizon)` rolls the deployed model forward from the
twin's newest telemetry — the collision-avoidance lookahead.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fleet import FleetConfig, FleetMerinda
from repro.core.merinda import MerindaConfig
from repro.kernels.rk4.ops import rk4_poly_solve
from repro.twin.monitor import DivergenceGuard, GuardConfig, GuardEvent
from repro.twin.scheduler import (RefitScheduler, SchedulerConfig,
                                  SchedulePlan, TwinRecord)
from repro.twin.stream import RingConfig, TelemetryRing

__all__ = ["TwinServerConfig", "TickReport", "TwinServer"]


@dataclass(frozen=True)
class TwinServerConfig:
    merinda: MerindaConfig
    max_twins: int                    # tracked-object capacity
    refit_slots: int = 8              # concurrent refits (compute budget)
    capacity: int = 512               # ring samples per twin
    window: int = 24                  # refit window k
    stride: int = 8
    windows_per_twin: int = 16        # S_B per slot per train step
    steps_per_tick: int = 2           # incremental train steps per tick
    lr: float = 3e-3
    sparsify_after: int = 60          # per-slot warmup (FleetConfig)
    deploy_after: int = 24            # train steps before a slot's theta ships
    promote_margin: float = 0.7       # candidate must score < margin * incumbent
    deadline_s: float = 1.0           # 5x under the 5 s human-reaction budget
    guard: GuardConfig = GuardConfig()
    staleness_weight: float = 1.0
    divergence_weight: float = 4.0
    evict_margin: float = 0.5
    min_residency: int = 8
    max_residency: int = 64
    release_divergence: float = 0.05
    flush_pad: int = 8                # chunk-length quantum (bounds retraces)
    seed: int = 0


@dataclass
class TickReport:
    tick: int
    latency_s: float
    deadline_met: bool
    loss: float | None                # mean refit loss (None: no active slot)
    events: list[GuardEvent] = field(default_factory=list)
    admitted: list = field(default_factory=list)   # [(slot, twin_id)]
    evicted: list = field(default_factory=list)
    released: list = field(default_factory=list)
    n_active: int = 0                 # twins resident in refit slots
    n_twins: int = 0                  # twins tracked


class TwinServer:
    def __init__(self, cfg: TwinServerConfig):
        m = cfg.merinda
        self.cfg = cfg
        self.span = TelemetryRing.span(cfg.window, cfg.stride,
                                       cfg.windows_per_twin)
        self.min_samples = self.span + 1
        if cfg.capacity < max(self.min_samples, cfg.guard.window + 1):
            raise ValueError("ring capacity smaller than the refit/guard span")

        self._scratch = cfg.max_twins     # scratch ring row + theta row
        self.ring = TelemetryRing(RingConfig(
            slots=cfg.max_twins + 1, capacity=cfg.capacity, n=m.n, m=m.m))
        self._rstate = self.ring.init()

        self.fleet = FleetMerinda(FleetConfig(
            merinda=m, fleet=cfg.refit_slots,
            windows_per_twin=cfg.windows_per_twin, lr=cfg.lr,
            sparsify_after=cfg.sparsify_after))
        self._key = jax.random.PRNGKey(cfg.seed)
        self._fstate = self.fleet.init(self._split())

        self.guard = DivergenceGuard(self.fleet.model.lib, m.dt, cfg.guard,
                                     use_pallas=m.use_pallas,
                                     interpret=m.interpret)
        self.scheduler = RefitScheduler(SchedulerConfig(
            slots=cfg.refit_slots, min_samples=self.min_samples,
            staleness_weight=cfg.staleness_weight,
            divergence_weight=cfg.divergence_weight,
            evict_margin=cfg.evict_margin, min_residency=cfg.min_residency,
            max_residency=cfg.max_residency,
            release_divergence=cfg.release_divergence))

        self.twins: dict[int, TwinRecord] = {}
        self._guard_state: dict[int, str] = {}        # twin_id -> last kind
        self._slot_ring = np.full((cfg.refit_slots,), self._scratch,
                                  dtype=np.int32)     # refit slot -> ring row
        self._slot_twin: dict[int, int] = {}          # refit slot -> twin_id
        L = self.fleet.model.lib.size
        self._theta = jnp.zeros((cfg.max_twins + 1, m.n, L))
        self._staged: dict[int, list] = {}
        self.tick_count = 0
        self.latencies: list[float] = []
        self.refresh_counts: list[int] = []   # active slots per recorded tick
        self.events: list[GuardEvent] = []

    # ------------------------------------------------------------------ #
    def _split(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    # ------------------------------------------------------------------ #
    def register(self, twin_id: int) -> TwinRecord:
        """Start tracking an object; assigns its telemetry ring row."""
        if twin_id in self.twins:
            return self.twins[twin_id]
        row = len(self.twins)
        if row >= self.cfg.max_twins:
            raise RuntimeError(f"server full ({self.cfg.max_twins} twins)")
        rec = TwinRecord(twin_id=twin_id, ring_slot=row)
        self.twins[twin_id] = rec
        self._guard_state[twin_id] = "OK"
        return rec

    # ------------------------------------------------------------------ #
    def ingest(self, twin_id: int, y, u=None):
        """Stage telemetry for `twin_id`: y [n] or [C, n], u [m] or [C, m].

        Host-side staging only — the device scatter happens once per tick in
        the fused flush, so per-sample ingest stays cheap.
        """
        rec = self.register(twin_id)
        y = np.atleast_2d(np.asarray(y, np.float32))
        C = y.shape[0]
        m = self.cfg.merinda.m
        u = (np.zeros((C, m), np.float32) if u is None
             else np.asarray(u, np.float32).reshape(C, m))
        if C > self.cfg.capacity:
            raise ValueError("chunk larger than ring capacity")
        self._staged.setdefault(rec.twin_id, []).append((y, u))

    def _flush(self) -> int:
        if not self._staged:
            return 0
        cap, pad = self.cfg.capacity, self.cfg.flush_pad
        merged = []
        received = 0
        for tid, chunks in sorted(self._staged.items()):
            rec = self.twins[tid]
            y = np.concatenate([c[0] for c in chunks], 0)
            u = np.concatenate([c[1] for c in chunks], 0)
            rec.samples += len(y)
            received += len(y)
            if len(y) > cap:
                # a backlog longer than the ring would overwrite itself
                # anyway; keep only the newest capacity-worth of samples
                y, u = y[-cap:], u[-cap:]
            merged.append((rec.ring_slot, y, u))
        # pad BOTH axes to fixed quanta (rows with scratch/zero-count
        # entries, columns per flush_pad) so the fused ingest does not
        # recompile when the set of reporting twins varies tick to tick
        B = int(-(-len(merged) // pad) * pad)
        # cap the padded length at ring capacity: every chunk is already
        # truncated to <= cap, but rounding up could lap a non-multiple ring
        C = min(int(-(-max(len(y) for _, y, _ in merged) // pad) * pad), cap)
        n, m = self.cfg.merinda.n, self.cfg.merinda.m
        ys = np.zeros((B, C, n), np.float32)
        us = np.zeros((B, C, m), np.float32)
        slots = np.full((B,), self._scratch, np.int32)
        counts = np.zeros((B,), np.int32)
        for i, (row, y, u) in enumerate(merged):
            ys[i, :len(y)] = y
            us[i, :len(y)] = u
            slots[i] = row
            counts[i] = len(y)
        self._rstate = self.ring.ingest(
            self._rstate, jnp.asarray(slots), jnp.asarray(ys),
            jnp.asarray(us), jnp.asarray(counts))
        self._staged.clear()
        return received

    # ------------------------------------------------------------------ #
    def deploy(self, twin_id: int, theta) -> None:
        """Install a theta for `twin_id` directly (warm start from an offline
        recovery — lets a fleet come up serving while online refits rotate)."""
        rec = self.register(twin_id)
        self._theta = self._theta.at[rec.ring_slot].set(jnp.asarray(theta))
        rec.deployed = True
        rec.samples_at_deploy = rec.samples
        rec.deploy_tick = self.tick_count

    # ------------------------------------------------------------------ #
    def _update_divergence(self) -> list[GuardEvent]:
        gw = self.cfg.guard.window
        live = [r for r in self.twins.values()
                if r.deployed and r.samples >= gw + 1]
        if not live:
            return []
        rows = jnp.arange(self.cfg.max_twins)
        ys, us = self.ring.latest(self._rstate, rows, gw)
        scores = np.asarray(self.guard.score(self._theta[:-1], ys, us))
        events: list[GuardEvent] = []
        for rec in live:
            rec.divergence = self.guard.smooth(rec.divergence,
                                               scores[rec.ring_slot])
            ev = self.guard.judge(rec.twin_id, rec.divergence, self.tick_count)
            kind = ev.kind if ev else "OK"
            if kind != self._guard_state[rec.twin_id]:
                self._guard_state[rec.twin_id] = kind
                if ev:
                    events.append(ev)
        self.events.extend(events)
        return events

    # ------------------------------------------------------------------ #
    def _slot_windows(self):
        rows = jnp.asarray(self._slot_ring)
        return self.ring.windows(self._rstate, rows, window=self.cfg.window,
                                 stride=self.cfg.stride, length=self.span)

    def _apply_plan(self, plan: SchedulePlan) -> None:
        for tid in plan.evict + plan.release:
            rec = self.twins[tid]
            self._slot_ring[rec.refit_slot] = self._scratch
            self._slot_twin.pop(rec.refit_slot, None)
            rec.refit_slot = None
            rec.residency = rec.steps_in_slot = 0
        for slot, tid in plan.admit:
            rec = self.twins[tid]
            y_w, u_w = self.ring.windows(
                self._rstate, jnp.asarray([rec.ring_slot]),
                window=self.cfg.window, stride=self.cfg.stride,
                length=self.span)
            self._fstate = self.fleet.reset_slot(
                self._fstate, jnp.int32(slot), self._split(), y_w[0], u_w[0])
            rec.refit_slot = slot
            rec.admitted_tick = self.tick_count
            rec.residency = rec.steps_in_slot = 0
            self._slot_ring[slot] = rec.ring_slot
            self._slot_twin[slot] = tid

    def _refit(self) -> float | None:
        if not self._slot_twin:
            return None
        y_win, u_win = self._slot_windows()
        loss_vec = None
        for _ in range(self.cfg.steps_per_tick):
            self._fstate, loss_vec, _ = self.fleet.train_step_per_slot(
                self._fstate, y_win, u_win)
        # report loss over ASSIGNED slots only — scratch-parked slots train
        # on zero windows and would dilute the mean toward zero
        loss = float(np.mean(np.asarray(loss_vec)[sorted(self._slot_twin)]))
        deployable = []
        for slot, tid in self._slot_twin.items():
            rec = self.twins[tid]
            rec.steps_in_slot += self.cfg.steps_per_tick
            rec.residency += 1
            if rec.steps_in_slot >= self.cfg.deploy_after:
                deployable.append(slot)
        if deployable:
            self._promote(deployable, y_win, u_win)
        return loss

    def _promote(self, deployable, y_win, u_win) -> None:
        """Shadow-evaluate slot recoveries and deploy only improvements.

        Both the candidate theta and the incumbent are rolled over the same
        newest telemetry (one fused guard call each).  Against a HEALTHY
        incumbent (score < refit_threshold) the candidate must beat it by
        `promote_margin` — "good enough" is not enough to replace a model
        that tracks reality better.  Against a missing/diverged incumbent the
        candidate ships if it is outright good or a margin improvement.
        """
        thresh = self.cfg.guard.refit_threshold
        rows = jnp.asarray(self._slot_ring)
        thetas = self.fleet.recover_all(self._fstate, y_win, u_win)
        ys_g, us_g = self.ring.latest(self._rstate, rows,
                                      self.cfg.guard.window)
        cand = np.asarray(self.guard.score(thetas, ys_g, us_g))
        inc = np.asarray(self.guard.score(self._theta[rows], ys_g, us_g))
        targets = np.full((self.cfg.refit_slots,), self._scratch,
                          dtype=np.int32)
        promoted = set()
        for slot in deployable:
            rec = self.twins[self._slot_twin[slot]]
            healthy_inc = rec.deployed and inc[slot] < thresh
            better = cand[slot] < self.cfg.promote_margin * inc[slot]
            if better or (not healthy_inc and cand[slot] < thresh):
                targets[slot] = rec.ring_slot
                promoted.add(slot)
            elif healthy_inc:
                # candidate lost, but the serving model is still healthy:
                # count this as a completed review so the twin's staleness
                # resets and it stops hogging a refit slot.
                rec.samples_at_deploy = rec.samples
        if promoted:
            self._theta = self._theta.at[jnp.asarray(targets)].set(thetas)
        for slot in promoted:
            rec = self.twins[self._slot_twin[slot]]
            rec.deployed = True
            rec.samples_at_deploy = rec.samples
            rec.deploy_tick = self.tick_count
            rec.divergence = float(min(cand[slot], 1e6))

    # ------------------------------------------------------------------ #
    def tick(self) -> TickReport:
        """One full serving cycle; see module docstring for the five stages."""
        t0 = time.perf_counter()
        self.tick_count += 1
        self._flush()
        events = self._update_divergence()
        plan = self.scheduler.plan(self.twins)
        self._apply_plan(plan)
        loss = self._refit()
        jax.block_until_ready(self._theta)
        latency = time.perf_counter() - t0
        self.latencies.append(latency)
        self.refresh_counts.append(len(self._slot_twin))
        return TickReport(
            tick=self.tick_count, latency_s=latency,
            deadline_met=latency <= self.cfg.deadline_s, loss=loss,
            events=events, admitted=plan.admit, evicted=plan.evict,
            released=plan.release, n_active=len(self._slot_twin),
            n_twins=len(self.twins))

    # ------------------------------------------------------------------ #
    def predict(self, twin_id: int, horizon: int, us=None):
        """Roll the deployed model `horizon` steps from the newest telemetry.

        Returns ys [horizon+1, n] (index 0 = the newest observed state).
        """
        rec = self.twins[twin_id]
        if not rec.deployed:
            raise RuntimeError(f"twin {twin_id} has no deployed model")
        if rec.samples < 1:
            # the ring is still all zeros — a rollout would silently start
            # from the origin instead of the twin's actual state
            raise RuntimeError(f"twin {twin_id} has no telemetry to "
                               "predict from")
        ys, _ = self.ring.latest(self._rstate,
                                 jnp.asarray([rec.ring_slot]), 0)
        y0 = ys[:, -1, :]                                    # [1, n]
        m = self.cfg.merinda.m
        us = (jnp.zeros((1, horizon, m)) if us is None
              else jnp.asarray(us, jnp.float32).reshape(1, horizon, m))
        out = rk4_poly_solve(self._theta[rec.ring_slot][None], y0, us,
                             dt=self.cfg.merinda.dt, library=self.fleet.model.lib,
                             use_pallas=self.cfg.merinda.use_pallas,
                             interpret=self.cfg.merinda.interpret)
        return out[0]

    # ------------------------------------------------------------------ #
    def reset_latency_stats(self) -> None:
        """Drop recorded latencies (benchmarks call this after jit warmup)."""
        self.latencies.clear()
        self.refresh_counts.clear()

    def latency_summary(self) -> dict:
        """p50/p99 refresh latency vs the deadline + serving throughput."""
        lat = np.asarray(self.latencies)
        if lat.size == 0:
            return {"ticks": 0}
        total = float(lat.sum())
        return {
            "ticks": int(lat.size),
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
            "max_ms": float(lat.max() * 1e3),
            "deadline_s": self.cfg.deadline_s,
            "violations": int((lat > self.cfg.deadline_s).sum()),
            # actual slot-refreshes performed, not pool capacity: idle slots
            # don't count toward serving throughput
            "twin_refreshes_per_s":
                sum(self.refresh_counts) / max(total, 1e-9),
        }
