"""Unified model API: every assigned architecture behind one interface.

`build(cfg)` returns a ModelApi whose five callables are what the launchers
(train / serve / dryrun) lower:
    init(key)                      -> params
    param_specs()                  -> ShapeDtypeStruct tree (no allocation)
    loss(params, batch)            -> (loss, metrics)       [train_* shapes]
    prefill(params, batch, max_len)-> (cache, logits)       [prefill_*]
    decode(params, cache, tokens1) -> (cache, logits)       [decode_* / long_*]
    cache_specs(B, max_len)        -> cache ShapeDtypeStructs
    batch_specs(B, T)              -> input ShapeDtypeStructs (stub frontends
                                      provide precomputed embeddings here)
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import encdec
from repro.models import transformer as tfm
from repro.models.kv_cache import cache_init, cache_specs
from repro.models.transformer import LMConfig

__all__ = ["ModelApi", "build"]


@dataclass(frozen=True)
class ModelApi:
    cfg: LMConfig
    init: Callable
    param_specs: Callable
    loss: Callable
    prefill: Callable
    decode: Callable
    cache_specs: Callable
    cache_init: Callable
    batch_specs: Callable
    is_encdec: bool = False


def _lm_batch_specs(cfg: LMConfig, B: int, T: int):
    return {"tokens": jax.ShapeDtypeStruct((B, T), jnp.int32)}


def _whisper_batch_specs(cfg: LMConfig, B: int, T: int):
    return {"enc_x": jax.ShapeDtypeStruct((B, T, cfg.d_model), cfg.dtype),
            "tokens": jax.ShapeDtypeStruct((B, T), jnp.int32)}


def build(cfg: LMConfig, max_position: int = 4096) -> ModelApi:
    if cfg.enc_layers:
        return ModelApi(
            cfg=cfg,
            init=lambda key: encdec.whisper_init(cfg, key, max_position),
            param_specs=lambda: encdec.whisper_param_specs(cfg, max_position),
            loss=partial(encdec.whisper_loss, cfg),
            prefill=partial(encdec.whisper_prefill, cfg),
            decode=partial(encdec.whisper_decode_step, cfg),
            cache_specs=lambda B, S, T_enc=None: encdec.whisper_cache_specs(
                cfg, B, S, T_enc if T_enc is not None else S),
            cache_init=lambda B, S, T_enc=None: encdec.whisper_cache_init(
                cfg, B, S, T_enc if T_enc is not None else S),
            batch_specs=partial(_whisper_batch_specs, cfg),
            is_encdec=True,
        )

    def lm_prefill(params, batch, max_len):
        return tfm.prefill(cfg, params, batch["tokens"], max_len)

    return ModelApi(
        cfg=cfg,
        init=partial(tfm.init_params, cfg),
        param_specs=lambda: tfm.param_specs(cfg),
        loss=partial(tfm.loss_fn, cfg),
        prefill=lm_prefill,
        decode=partial(tfm.decode_step, cfg),
        cache_specs=lambda B, S, T_enc=None: cache_specs(cfg, B, S),
        cache_init=lambda B, S, T_enc=None: cache_init(cfg, B, S),
        batch_specs=partial(_lm_batch_specs, cfg),
    )
