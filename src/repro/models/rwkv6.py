"""RWKV-6 "Finch" block: data-dependent token-shift + decay time-mix, and
squared-ReLU channel-mix.

Sequence execution uses the chunked linear recurrence (kernels/linear_scan,
mode "rwkv6" — read-before-update with bonus u), i.e. the MXU-shaped
formulation; decode is the exact O(1)-state per-step update.  This family is
the direct beneficiary of the paper's acceleration principle (DESIGN.md
§Arch-applicability).

Simplification vs reference RWKV-6 (recorded): the five ddlerp token-shift
mixes (w,k,v,r,g) share one two-layer LoRA producing all five deltas, matching
the official parameter count and dataflow shape.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.kernels.linear_scan.ops import linear_scan
from repro.models.layers import apply_norm, dense, dense_init, norm_init

__all__ = ["rwkv6_init", "rwkv6_time_mix", "rwkv6_channel_mix",
           "rwkv6_time_mix_decode", "rwkv6_channel_mix_decode",
           "rwkv6_state_init"]

_TM_LORA = 32
_DECAY_LORA = 64


def rwkv6_init(key, d_model: int, head_dim: int = 64, d_ff: int = 0,
               dtype=jnp.float32):
    H = d_model // head_dim
    K = head_dim
    ks = jax.random.split(key, 12)
    s = 1.0 / math.sqrt(d_model)
    p = {
        # --- time mix ---------------------------------------------------- #
        "time_maa_x": jnp.zeros((d_model,), dtype),
        "time_maa_5": jnp.zeros((5, d_model), dtype),      # w,k,v,r,g base mix
        "tm_lora_a": (jax.random.normal(ks[0], (d_model, 5 * _TM_LORA))
                      * s).astype(dtype),
        "tm_lora_b": jnp.zeros((5, _TM_LORA, d_model), dtype),
        "time_decay": jnp.tile(
            jnp.linspace(-6.0, -1.0, K, dtype=jnp.float32), (H,)
        ).astype(dtype),                                   # [d] log-log decay
        "decay_lora_a": (jax.random.normal(ks[1], (d_model, _DECAY_LORA))
                         * s).astype(dtype),
        "decay_lora_b": jnp.zeros((_DECAY_LORA, d_model), dtype),
        "time_faaaa": jnp.full((H, K), 0.5, dtype),        # bonus u
        "wr": dense_init(ks[2], d_model, d_model, dtype),
        "wk": dense_init(ks[3], d_model, d_model, dtype),
        "wv": dense_init(ks[4], d_model, d_model, dtype),
        "wg": dense_init(ks[5], d_model, d_model, dtype),
        "wo": dense_init(ks[6], d_model, d_model, dtype),
        "ln_x": norm_init(d_model, "layernorm", dtype),
        # --- channel mix -------------------------------------------------- #
        "cm_maa_k": jnp.zeros((d_model,), dtype),
        "cm_maa_r": jnp.zeros((d_model,), dtype),
        "cm_wk": dense_init(ks[7], d_model, d_ff, dtype),
        "cm_wv": dense_init(ks[8], d_ff, d_model, dtype),
        "cm_wr": dense_init(ks[9], d_model, d_model, dtype),
    }
    return p


def _ddlerp(p, x, sx):
    """Data-dependent lerp producing the 5 mixed inputs (w,k,v,r,g).

    x: [B, T, d]; sx = shifted(x) - x.  Returns [5, B, T, d]."""
    xxx = x + sx * p["time_maa_x"]
    lora = jnp.tanh(jnp.einsum("btd,dr->btr", xxx, p["tm_lora_a"]))
    lora = lora.reshape(*lora.shape[:-1], 5, _TM_LORA)
    delta = jnp.einsum("btfr,frd->fbtd", lora, p["tm_lora_b"])
    base = p["time_maa_5"][:, None, None, :]
    return x[None] + sx[None] * (base + delta)


def _token_shift(x, last):
    """shift(x)[t] = x[t-1], with `last` ([B, d]) as x[-1]."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def rwkv6_time_mix(p, x, *, head_dim: int, last_x=None, state=None,
                   chunk: int = 64, use_pallas=False, interpret=None):
    """x: [B, T, d] -> (y, (new_last_x, new_state)).  state: [B,H,K,V]."""
    B, T, d = x.shape
    H, K = d // head_dim, head_dim
    if last_x is None:
        last_x = jnp.zeros((B, d), x.dtype)
    sx = _token_shift(x, last_x) - x
    xw, xk, xv, xr, xg = _ddlerp(p, x, sx)

    # data-dependent decay (log-space, <= 0 after -exp).
    dl = jnp.tanh(jnp.einsum("btd,dr->btr", xw, p["decay_lora_a"]))
    w_log = -jnp.exp((p["time_decay"].astype(jnp.float32)
                      + jnp.einsum("btr,rd->btd", dl,
                                   p["decay_lora_b"]).astype(jnp.float32)))

    heads = lambda z: z.reshape(B, T, H, K).transpose(0, 2, 1, 3)
    r = heads(dense(p["wr"], xr))
    k = heads(dense(p["wk"], xk))
    v = heads(dense(p["wv"], xv))
    g = jax.nn.silu(dense(p["wg"], xg))
    w = heads(w_log)
    r, k, v = (shard(z, "act_bhtd") for z in (r, k, v))

    o, new_state = linear_scan(r, k, v, w, u=p["time_faaaa"], mode="rwkv6",
                               chunk=chunk, initial_state=state,
                               use_pallas=use_pallas, interpret=interpret)
    o = o.transpose(0, 2, 1, 3).reshape(B, T, d).astype(x.dtype)
    o = apply_norm(p["ln_x"], o, "layernorm") * g
    y = dense(p["wo"], o)
    return y, (x[:, -1, :], new_state)


def rwkv6_channel_mix(p, x, *, last_x=None):
    B, T, d = x.shape
    if last_x is None:
        last_x = jnp.zeros((B, d), x.dtype)
    sx = _token_shift(x, last_x) - x
    xk = x + sx * p["cm_maa_k"]
    xr = x + sx * p["cm_maa_r"]
    k = jnp.square(jax.nn.relu(dense(p["cm_wk"], xk)))
    k = shard(k, "act_ffn")
    kv = dense(p["cm_wv"], k)
    return jax.nn.sigmoid(dense(p["cm_wr"], xr)) * kv, x[:, -1, :]


# --------------------------------------------------------------------------- #
# Decode (single token, exact recurrence)
# --------------------------------------------------------------------------- #
def rwkv6_state_init(batch: int, d_model: int, head_dim: int,
                     dtype=jnp.float32):
    H, K = d_model // head_dim, head_dim
    return {
        "tm_last": jnp.zeros((batch, d_model), dtype),
        "cm_last": jnp.zeros((batch, d_model), dtype),
        "wkv": jnp.zeros((batch, H, K, head_dim), jnp.float32),
    }


def rwkv6_time_mix_decode(p, x1, last_x, state, *, head_dim: int):
    """x1: [B, d] single token.  Returns (y [B, d], new_last, new_state)."""
    B, d = x1.shape
    H, K = d // head_dim, head_dim
    x = x1[:, None, :]
    sx = (last_x - x1)[:, None, :]
    xw, xk, xv, xr, xg = (z[:, 0] for z in _ddlerp(p, x, sx))

    dl = jnp.tanh(xw @ p["decay_lora_a"])
    w_log = -jnp.exp(p["time_decay"].astype(jnp.float32)
                     + (dl @ p["decay_lora_b"]).astype(jnp.float32))
    heads = lambda z: z.reshape(B, H, K)
    r = heads(dense(p["wr"], xr)).astype(jnp.float32)
    k = heads(dense(p["wk"], xk)).astype(jnp.float32)
    v = heads(dense(p["wv"], xv)).astype(jnp.float32)
    g = jax.nn.silu(dense(p["wg"], xg))
    w = jnp.exp(heads(w_log))
    u = p["time_faaaa"].astype(jnp.float32)

    kv = k[..., :, None] * v[..., None, :]                 # [B, H, K, V]
    o = jnp.einsum("bhk,bhkv->bhv", r, state + u[None, :, :, None] * kv)
    new_state = w[..., None] * state + kv
    o = o.reshape(B, d).astype(x1.dtype)
    o = apply_norm(p["ln_x"], o, "layernorm") * g
    return dense(p["wo"], o), x1, new_state


def rwkv6_channel_mix_decode(p, x1, last_x):
    sx = last_x - x1
    xk = x1 + sx * p["cm_maa_k"]
    xr = x1 + sx * p["cm_maa_r"]
    k = jnp.square(jax.nn.relu(dense(p["cm_wk"], xk)))
    kv = dense(p["cm_wv"], k)
    return jax.nn.sigmoid(dense(p["cm_wr"], xr)) * kv, x1
