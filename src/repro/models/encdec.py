"""Whisper-style encoder-decoder backbone.

Per the assignment, the conv/mel frontend is a STUB: the encoder consumes
precomputed frame embeddings [B, T_enc, d] (input_specs provides them).  The
encoder is `cfg.enc_layers` bidirectional attention blocks over sinusoidal
positions; the decoder is `cfg.n_layers` blocks of (causal self-attention +
cross-attention + MLP) over a learned position table, with tied
embed/unembed (Whisper convention).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import attention as attn
from repro.models.kv_cache import _attn_entry
from repro.models.layers import (apply_norm, embed_init, embed_lookup, mlp,
                                 mlp_init, norm_init, sinusoidal_positions,
                                 unembed)
from repro.models.transformer import LMConfig, _fill_attn_cache

__all__ = ["whisper_init", "whisper_param_specs", "whisper_encode",
           "whisper_loss", "whisper_prefill", "whisper_decode_step",
           "whisper_cache_init", "whisper_cache_specs"]


def _enc_block_init(key, cfg: LMConfig):
    ks = jax.random.split(key, 2)
    dt = cfg.dtype
    return {
        "norm1": norm_init(cfg.d_model, cfg.norm, dt),
        "attn": attn.attention_init(ks[0], cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.head_dim, False,
                                    cfg.norm, dt),
        "norm2": norm_init(cfg.d_model, cfg.norm, dt),
        "ffn": mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_kind, dt),
    }


def _dec_block_init(key, cfg: LMConfig):
    ks = jax.random.split(key, 3)
    dt = cfg.dtype
    a = lambda k: attn.attention_init(k, cfg.d_model, cfg.n_heads,
                                      cfg.n_kv_heads, cfg.head_dim, False,
                                      cfg.norm, dt)
    return {
        "norm1": norm_init(cfg.d_model, cfg.norm, dt),
        "self": a(ks[0]),
        "normx": norm_init(cfg.d_model, cfg.norm, dt),
        "cross": a(ks[1]),
        "norm2": norm_init(cfg.d_model, cfg.norm, dt),
        "ffn": mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_kind, dt),
    }


def whisper_init(cfg: LMConfig, key, max_position: int = 4096):
    ks = jax.random.split(key, 5)
    stack = lambda k, n, f: jax.vmap(f)(jax.random.split(k, n))
    return {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, cfg.dtype),
        "dec_pos": {"w": (jax.random.normal(ks[1],
                                            (max_position, cfg.d_model),
                                            jnp.float32) * 0.01
                          ).astype(cfg.dtype)},
        "enc_layers": stack(ks[2], cfg.enc_layers,
                            lambda k: _enc_block_init(k, cfg)),
        "enc_norm": norm_init(cfg.d_model, cfg.norm, cfg.dtype),
        "dec_layers": stack(ks[3], cfg.n_layers,
                            lambda k: _dec_block_init(k, cfg)),
        "dec_norm": norm_init(cfg.d_model, cfg.norm, cfg.dtype),
    }


def whisper_param_specs(cfg: LMConfig, max_position: int = 4096):
    return jax.eval_shape(
        lambda: whisper_init(cfg, jax.random.PRNGKey(0), max_position))


# --------------------------------------------------------------------------- #
def _kw(cfg):
    return dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=cfg.head_dim, rope="none", norm_kind=cfg.norm,
                kv_block=cfg.kv_block)


def whisper_encode(cfg: LMConfig, params, enc_x):
    """enc_x: [B, T_enc, d] stub frame embeddings -> [B, T_enc, d]."""
    B, T, _ = enc_x.shape
    x = (enc_x.astype(cfg.dtype)
         + sinusoidal_positions(T, cfg.d_model, cfg.dtype)[None])
    x = shard(x, "act_btd")

    def block(carry, p):
        h = carry
        a = attn.attention_apply(p["attn"],
                                 apply_norm(p["norm1"], h, cfg.norm),
                                 causal=False, **_kw(cfg))
        h = h + a
        h = h + mlp(p["ffn"], apply_norm(p["norm2"], h, cfg.norm),
                    cfg.mlp_kind)
        return shard(h, "act_btd"), None

    body = jax.checkpoint(block) if cfg.remat else block
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(params["enc_norm"], x, cfg.norm)


def _dec_block(cfg, p, x, enc_out, positions):
    h = apply_norm(p["norm1"], x, cfg.norm)
    x = x + attn.attention_apply(p["self"], h, positions=positions,
                                 causal=True, **_kw(cfg))
    h = apply_norm(p["normx"], x, cfg.norm)
    x = x + attn.attention_apply(p["cross"], h, x_kv=enc_out, **_kw(cfg))
    h = apply_norm(p["norm2"], x, cfg.norm)
    x = x + mlp(p["ffn"], h, cfg.mlp_kind)
    return shard(x, "act_btd")


def whisper_decode_forward(cfg: LMConfig, params, tokens, enc_out):
    B, T = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    x = embed_lookup(params["embed"], tokens).astype(cfg.dtype)
    x = x + params["dec_pos"]["w"][:T][None].astype(cfg.dtype)

    def block(carry, p):
        return _dec_block(cfg, p, carry, enc_out, positions), None

    body = jax.checkpoint(block) if cfg.remat else block
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = apply_norm(params["dec_norm"], x, cfg.norm)
    return unembed(params["embed"], x)


def whisper_loss(cfg: LMConfig, params, batch):
    """batch: {"enc_x": [B, T_enc, d], "tokens": [B, T_dec]}."""
    enc_out = whisper_encode(cfg, params, batch["enc_x"])
    logits = whisper_decode_forward(cfg, params, batch["tokens"], enc_out)
    logits, targets = logits[:, :-1], batch["tokens"][:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    return nll, {"nll": nll, "aux": jnp.zeros(())}


# --------------------------------------------------------------------------- #
# Serving
# --------------------------------------------------------------------------- #
def whisper_cache_init(cfg: LMConfig, B: int, max_len: int, T_enc: int):
    """Self-attn caches + precomputed cross K/V (filled by prefill)."""
    L = cfg.n_layers
    bc = lambda x: jnp.broadcast_to(x, (L,) + x.shape)
    self_e = _attn_entry(cfg, B, max_len)
    cross = {
        "k": jnp.zeros((B, T_enc, cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
        "v": jnp.zeros((B, T_enc, cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
    }
    return {"self": jax.tree.map(bc, self_e),
            "cross": jax.tree.map(bc, cross),
            "pos": jnp.zeros((B,), jnp.int32)}


def whisper_cache_specs(cfg, B, max_len, T_enc):
    return jax.eval_shape(lambda: whisper_cache_init(cfg, B, max_len, T_enc))


def whisper_prefill(cfg: LMConfig, params, batch, max_len: int):
    """Encoder pass + decoder-prompt pass emitting self + cross caches."""
    enc_out = whisper_encode(cfg, params, batch["enc_x"])
    tokens = batch["tokens"]
    B, T = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    cache = whisper_cache_init(cfg, B, max_len, enc_out.shape[1])
    x = embed_lookup(params["embed"], tokens).astype(cfg.dtype)
    x = x + params["dec_pos"]["w"][:T][None].astype(cfg.dtype)

    def block(carry, inp):
        h = carry
        p, self_e = inp
        a, (k, v) = attn.attention_apply(
            p["self"], apply_norm(p["norm1"], h, cfg.norm),
            positions=positions, causal=True, return_kv=True, **_kw(cfg))
        h = h + a
        self_e = _fill_attn_cache(self_e, k, v, positions)
        # cross K/V are position-independent: computed once, stored.
        hq = apply_norm(p["normx"], h, cfg.norm)
        a, (xk, xv) = attn.attention_apply(p["cross"], hq, x_kv=enc_out,
                                           return_kv=True, **_kw(cfg))
        h = h + a
        h = h + mlp(p["ffn"], apply_norm(p["norm2"], h, cfg.norm),
                    cfg.mlp_kind)
        cross_e = {"k": xk.astype(cfg.dtype), "v": xv.astype(cfg.dtype)}
        return shard(h, "act_btd"), (self_e, cross_e)

    body = jax.checkpoint(block) if cfg.remat else block
    x, (self_c, cross_c) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["self"]))
    cache["self"], cache["cross"] = self_c, cross_c
    cache["pos"] = jnp.full((B,), T, jnp.int32)
    x = apply_norm(params["dec_norm"], x, cfg.norm)
    logits = unembed(params["embed"], x[:, -1:, :])[:, 0]
    return cache, logits


def whisper_decode_step(cfg: LMConfig, params, cache, tokens1):
    B = tokens1.shape[0]
    position = cache["pos"]
    x = embed_lookup(params["embed"], tokens1[:, None]).astype(cfg.dtype)
    pos_emb = jnp.take(params["dec_pos"]["w"], position, axis=0)
    x = x + pos_emb[:, None, :].astype(cfg.dtype)

    def block(carry, inp):
        # self-cache rides in the carry, updated in place at layer i
        # (xs/ys cache threading doubles the cache footprint — §Dry-run
        # iter 4).
        h, self_c = carry
        i, p, cross_e = inp
        self_e = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            self_c)
        a, self_e = attn.attention_decode(
            p["self"], apply_norm(p["norm1"], h, cfg.norm), self_e,
            position=position, rope="none", norm_kind=cfg.norm,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim)
        h = h + a
        a, _ = attn.attention_decode(
            p["cross"], apply_norm(p["normx"], h, cfg.norm), None,
            position=position, rope="none", norm_kind=cfg.norm,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
            cross_kv=(cross_e["k"], cross_e["v"]))
        h = h + a
        h = h + mlp(p["ffn"], apply_norm(p["norm2"], h, cfg.norm),
                    cfg.mlp_kind)
        self_c = jax.tree.map(
            lambda a2, u: jax.lax.dynamic_update_index_in_dim(
                a2, u.astype(a2.dtype), i, 0),
            self_c, self_e)
        return (h, self_c), None

    (x, self_c), _ = jax.lax.scan(
        block, (x, cache["self"]),
        (jnp.arange(cfg.n_layers), params["dec_layers"], cache["cross"]))
    cache["self"] = self_c
    cache["pos"] = position + 1
    x = apply_norm(params["dec_norm"], x, cfg.norm)
    return cache, unembed(params["embed"], x)[:, 0]
