"""Decode caches for every block family.

Shapes:
  * "attn"/"global":  full cache   {"k","v": [B, S, kv, dh], "pos": [B, S]}
  * "swa"/"local":    ring cache   same layout, S = window (slot = pos % S)
  * "rwkv6":          {"tm_last","cm_last": [B, d], "wkv": [B, H, K, V]}
  * "mamba2":         {"conv": [B, W-1, conv_dim], "ssm": [B, H, K, V]}
  * shared block:     full cache at 2*d_model geometry, one per invocation.

`pos` is initialized to INT32_MAX so empty slots are masked by the decode
attention (kv_pos <= q_pos test).  Layout mirrors the param stacking: leaves
under cache["layers"] carry a leading n_cycles axis so one lax.scan walks
params and cache together.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["cache_init", "cache_specs"]

INT_MAX = jnp.iinfo(jnp.int32).max


def _attn_entry(cfg, B, S, *, n_kv=None, head_dim=None, dtype=None):
    n_kv = n_kv if n_kv is not None else cfg.n_kv_heads
    head_dim = head_dim if head_dim is not None else cfg.head_dim
    dtype = dtype or cfg.dtype
    return {
        "k": jnp.zeros((B, S, n_kv, head_dim), dtype),
        "v": jnp.zeros((B, S, n_kv, head_dim), dtype),
        "pos": jnp.full((B, S), INT_MAX, jnp.int32),
    }


def _entry(cfg, kind: str, B: int, max_len: int):
    if kind in ("attn", "global"):
        return _attn_entry(cfg, B, max_len)
    if kind in ("swa", "local"):
        return _attn_entry(cfg, B, min(cfg.window, max_len))
    if kind == "rwkv6":
        H = cfg.d_model // cfg.rwkv_head_dim
        return {
            "tm_last": jnp.zeros((B, cfg.d_model), cfg.dtype),
            "cm_last": jnp.zeros((B, cfg.d_model), cfg.dtype),
            "wkv": jnp.zeros((B, H, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                             jnp.float32),
        }
    if kind == "mamba2":
        d_inner = cfg.ssm_expand * cfg.d_model
        H = d_inner // cfg.ssm_head_dim
        conv_dim = d_inner + 2 * cfg.ssm_state
        return {
            "conv": jnp.zeros((B, cfg.conv_width - 1, conv_dim), cfg.dtype),
            "ssm": jnp.zeros((B, H, cfg.ssm_state, cfg.ssm_head_dim),
                             jnp.float32),
        }
    raise ValueError(kind)


def _shared_entry(cfg, B, max_len):
    d_in = 2 * cfg.d_model
    return _attn_entry(cfg, B, max_len, n_kv=cfg.shared_n_heads,
                       head_dim=d_in // cfg.shared_n_heads)


def cache_init(cfg, B: int, max_len: int):
    """Build the zeroed cache pytree for `decode_step`."""
    p = len(cfg.pattern)
    n_cyc, tail = cfg.cycles, cfg.tail

    def group(n_blocks):
        blocks = [_entry(cfg, cfg.pattern[i], B, max_len)
                  for i in range(n_blocks)]
        if cfg.shared_every:
            return {"shared": _shared_entry(cfg, B, max_len),
                    "blocks": blocks}
        return blocks

    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_cyc,) + x.shape), group(p))
    cache = {"layers": stacked, "pos": jnp.zeros((B,), jnp.int32)}
    if tail:
        cache["tail"] = group(tail)
    return cache


def cache_specs(cfg, B: int, max_len: int):
    """ShapeDtypeStruct tree (dry-run input spec)."""
    return jax.eval_shape(lambda: cache_init(cfg, B, max_len))
