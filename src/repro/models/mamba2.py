"""Mamba-2 block (SSD — state-space duality) for the Zamba2 hybrid.

Sequence execution maps the SSD recurrence
    S_t = a_t * S_{t-1} + dt_t * B_t (x) x_t ,   y_t = C_t . S_t + D * x_t
onto the shared chunked linear recurrence (kernels/linear_scan, mode "ssd"):
    k_t = B_t (broadcast over heads), v_t = dt_t * x_t, w_t = log a_t,
    q_t = C_t.
Decode is the exact O(1)-state step with a rolling causal-conv window.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.kernels.linear_scan.ops import linear_scan
from repro.models.layers import apply_norm, dense, dense_init, norm_init

__all__ = ["mamba2_init", "mamba2_apply", "mamba2_decode", "mamba2_state_init"]


def _dims(d_model: int, expand: int, head_dim: int, state: int):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * state
    return d_inner, n_heads, conv_dim


def mamba2_init(key, d_model: int, *, state: int = 64, head_dim: int = 64,
                expand: int = 2, conv_width: int = 4, dtype=jnp.float32):
    d_inner, n_heads, conv_dim = _dims(d_model, expand, head_dim, state)
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * d_inner + 2 * state + n_heads     # z, x, B, C, dt
    return {
        "in_proj": dense_init(ks[0], d_model, d_in_proj, dtype),
        "conv": {  # depthwise causal conv over (x, B, C)
            "w": (jax.random.normal(ks[1], (conv_width, conv_dim), jnp.float32)
                  / math.sqrt(conv_width)).astype(dtype),
            "b": jnp.zeros((conv_dim,), dtype),
        },
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(dtype),
        "D": jnp.ones((n_heads,), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(
                ks[2], (n_heads,), jnp.float32,
                math.log(1e-3), math.log(1e-1))))).astype(dtype),
        "norm": norm_init(d_inner, "rmsnorm", dtype),
        "out_proj": dense_init(ks[3], d_inner, d_model, dtype),
    }


def _causal_conv(w, b, x, init=None):
    """Depthwise causal conv: x [B, T, C], w [W, C].  init: [B, W-1, C] tail
    of the previous segment (zeros at sequence start)."""
    W = w.shape[0]
    B, T, C = x.shape
    if init is None:
        init = jnp.zeros((B, W - 1, C), x.dtype)
    xp = jnp.concatenate([init, x], axis=1)
    out = sum(xp[:, i:i + T, :] * w[i] for i in range(W)) + b
    return jax.nn.silu(out), xp[:, T:, :]                 # new conv tail


def _split_proj(zxbcdt, d_inner, state, n_heads):
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + d_inner + 2 * state]
    dt = zxbcdt[..., -n_heads:]
    return z, xbc, dt


def mamba2_apply(p, x, *, state: int = 64, head_dim: int = 64,
                 expand: int = 2, conv_width: int = 4, ssm_state=None,
                 conv_state=None, chunk: int = 64, use_pallas=False,
                 interpret=None):
    """x: [B, T, d] -> (y, (new_conv_state, new_ssm_state))."""
    B, T, d = x.shape
    d_inner, n_heads, conv_dim = _dims(d, expand, head_dim, state)
    zxbcdt = dense(p["in_proj"], x)
    zxbcdt = shard(zxbcdt, "act_ffn")
    z, xbc, dt = _split_proj(zxbcdt, d_inner, state, n_heads)

    xbc, new_conv = _causal_conv(p["conv"]["w"], p["conv"]["b"], xbc,
                                 conv_state)
    xs = xbc[..., :d_inner]
    Bt = xbc[..., d_inner:d_inner + state]
    Ct = xbc[..., d_inner + state:]

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # [B, T, H]
    a_log = -jnp.exp(p["A_log"].astype(jnp.float32)) * dt      # log decay

    # map to the unified recurrence: [B, H, T, K/V]
    q = jnp.broadcast_to(Ct[:, None], (B, n_heads, T, state))
    k = jnp.broadcast_to(Bt[:, None], (B, n_heads, T, state))
    v = (xs.reshape(B, T, n_heads, head_dim)
         * dt[..., None].astype(xs.dtype)).transpose(0, 2, 1, 3)
    w = jnp.broadcast_to(a_log.transpose(0, 2, 1)[..., None],
                         (B, n_heads, T, state))
    v = shard(v, "act_bhtd")

    o, new_ssm = linear_scan(q, k, v, w, mode="ssd", chunk=chunk,
                             initial_state=ssm_state,
                             use_pallas=use_pallas, interpret=interpret)
    y = o.transpose(0, 2, 1, 3).reshape(B, T, d_inner).astype(x.dtype)
    y = y + xs * jnp.repeat(p["D"], head_dim)[None, None, :]
    y = apply_norm(p["norm"], y * jax.nn.silu(z), "rmsnorm")
    return dense(p["out_proj"], y), (new_conv, new_ssm)


# --------------------------------------------------------------------------- #
# Decode
# --------------------------------------------------------------------------- #
def mamba2_state_init(batch: int, d_model: int, *, state: int = 64,
                      head_dim: int = 64, expand: int = 2,
                      conv_width: int = 4, dtype=jnp.float32):
    d_inner, n_heads, conv_dim = _dims(d_model, expand, head_dim, state)
    return {
        "conv": jnp.zeros((batch, conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, n_heads, state, head_dim), jnp.float32),
    }


def mamba2_decode(p, x1, mstate, *, state: int = 64, head_dim: int = 64,
                  expand: int = 2, conv_width: int = 4):
    """x1: [B, d] -> (y [B, d], new_state)."""
    B, d = x1.shape
    d_inner, n_heads, conv_dim = _dims(d, expand, head_dim, state)
    zxbcdt = dense(p["in_proj"], x1)
    z, xbc, dt = _split_proj(zxbcdt, d_inner, state, n_heads)

    conv_in = jnp.concatenate([mstate["conv"], xbc[:, None, :]], axis=1)
    w = p["conv"]["w"]
    xbc = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", conv_in, w) + p["conv"]["b"])
    new_conv = conv_in[:, 1:, :]

    xs = xbc[..., :d_inner]
    Bt = xbc[..., d_inner:d_inner + state].astype(jnp.float32)
    Ct = xbc[..., d_inner + state:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])    # [B, H]
    a = jnp.exp(-jnp.exp(p["A_log"].astype(jnp.float32)) * dt)     # [B, H]

    xh = xs.reshape(B, n_heads, head_dim).astype(jnp.float32)
    dBx = (dt[..., None, None] * Bt[:, None, :, None]
           * xh[:, :, None, :])                                    # [B,H,K,V]
    new_ssm = a[..., None, None] * mstate["ssm"] + dBx
    y = jnp.einsum("bk,bhkv->bhv", Ct, new_ssm)
    y = y.reshape(B, d_inner).astype(x1.dtype)
    y = y + xs * jnp.repeat(p["D"], head_dim)[None, :]
    y = apply_norm(p["norm"], y * jax.nn.silu(z), "rmsnorm")
    return dense(p["out_proj"], y), {"conv": new_conv, "ssm": new_ssm}
