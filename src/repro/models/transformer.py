"""Decoder-only LM assembly for the assigned architectures.

Layer-pattern machinery: `cfg.pattern` is a tuple of block kinds cycled over
the depth ("attn", "swa", "local", "global", "rwkv6", "mamba2").  All kinds in
one pattern must share param SHAPES (they do: local/global differ only in
masking), so per-layer params are stacked [n_cycles, p, ...] and executed with
one `lax.scan` over cycles whose body unrolls the p pattern positions — the
HLO stays O(pattern) regardless of depth (compile-time critical for the
512-device dry-run).

Zamba2's weight-shared attention block (`cfg.shared_every > 0`) is applied at
the top of every cycle from a SINGLE param copy (a scan-body closure
constant); its KV caches are per-invocation.

Paths:
  * `forward`  — logits for teacher-forced training (no cache).
  * `loss_fn`  — next-token cross-entropy (+ MoE aux loss).
  * `prefill`  — forward + emitted per-layer caches.
  * `decode_step` — one token against the cache (what decode_* cells lower).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models import rwkv6 as rw
from repro.models.layers import (apply_norm, embed_init, embed_lookup,
                                 mlp, mlp_init, norm_init, unembed)
from repro.models.moe import moe_apply, moe_init

__all__ = ["LMConfig", "init_params", "param_specs", "forward", "loss_fn",
           "prefill", "decode_step", "ATTN_KINDS"]

ATTN_KINDS = ("attn", "swa", "local", "global")


# --------------------------------------------------------------------------- #
# Config
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    # layer pattern
    pattern: tuple = ("attn",)
    shared_every: int = 0            # zamba2: shared attn block per cycle
    # attention
    rope: str = "neox"               # "neox" | "none"
    rope_theta: float = 1e4
    rope_theta_local: float = 1e4    # gemma3 local layers
    rope_fraction: float = 1.0       # chatglm3: 0.5
    rope_interleaved: bool = False
    qk_norm: bool = False
    qk_norm_kind: str = "rmsnorm"
    window: int = 0                  # swa / local window
    norm: str = "rmsnorm"
    mlp_kind: str = "swiglu"
    embed_scale: bool = False        # gemma: x *= sqrt(d)
    tie_embeddings: bool = False
    logit_softcap: float = 0.0       # gemma-style tanh soft capping
    # MoE
    n_experts: int = 0
    top_k: int = 2
    dense_ff: int = 0                # arctic parallel dense-residual FFN
    moe_group_size: int = 512
    moe_capacity: float = 1.25
    aux_loss_weight: float = 0.01
    # SSM / RWKV
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    rwkv_head_dim: int = 64
    # shared block (zamba2) geometry
    shared_n_heads: int = 0
    shared_d_ff: int = 0
    # enc-dec (whisper; assembled in encdec.py)
    enc_layers: int = 0
    # execution
    dtype: Any = jnp.float32
    remat: bool = True
    scan_layers: bool = True
    kv_block: int = 1024
    scan_chunk: int = 64
    use_pallas: bool = False
    interpret: bool | None = None   # None = auto (kernels/backend)

    def with_(self, **kw) -> "LMConfig":
        return dataclasses.replace(self, **kw)

    @property
    def cycles(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def tail(self) -> int:
        return self.n_layers % len(self.pattern)

    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.head_dim


# --------------------------------------------------------------------------- #
# Per-block init
# --------------------------------------------------------------------------- #
def _block_init(key, cfg: LMConfig, kind: str):
    ks = jax.random.split(key, 4)
    dt = cfg.dtype
    p: dict = {"norm1": norm_init(cfg.d_model, cfg.norm, dt)}
    if kind in ATTN_KINDS:
        p["attn"] = attn.attention_init(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            cfg.qk_norm, cfg.qk_norm_kind, dt)
        p["norm2"] = norm_init(cfg.d_model, cfg.norm, dt)
        if cfg.n_experts:
            p["moe"] = moe_init(ks[1], cfg.d_model, cfg.d_ff, cfg.n_experts,
                                cfg.mlp_kind, dt)
            if cfg.dense_ff:
                p["ffn"] = mlp_init(ks[2], cfg.d_model, cfg.dense_ff,
                                    cfg.mlp_kind, dt)
        else:
            p["ffn"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_kind, dt)
    elif kind == "rwkv6":
        p["rwkv"] = rw.rwkv6_init(ks[0], cfg.d_model, cfg.rwkv_head_dim,
                                  cfg.d_ff, dt)
        p["norm2"] = norm_init(cfg.d_model, cfg.norm, dt)
    elif kind == "mamba2":
        p["mamba"] = m2.mamba2_init(
            ks[0], cfg.d_model, state=cfg.ssm_state,
            head_dim=cfg.ssm_head_dim, expand=cfg.ssm_expand,
            conv_width=cfg.conv_width, dtype=dt)
    else:
        raise ValueError(kind)
    return p


def _shared_block_init(key, cfg: LMConfig):
    """Zamba2 shared block: full-attention + MLP over concat(x, x0)."""
    ks = jax.random.split(key, 3)
    dt = cfg.dtype
    d_in = 2 * cfg.d_model
    hd = d_in // cfg.shared_n_heads
    from repro.models.layers import dense_init
    return {
        "norm1": norm_init(d_in, cfg.norm, dt),
        "attn": attn.attention_init(ks[0], d_in, cfg.shared_n_heads,
                                    cfg.shared_n_heads, hd, False,
                                    cfg.norm, dt),
        "norm2": norm_init(d_in, cfg.norm, dt),
        "ffn": mlp_init(ks[1], d_in, cfg.shared_d_ff, "gelu", dt),
        "out": {"down": dense_init(ks[2], d_in, cfg.d_model, dt)},
    }


def init_params(cfg: LMConfig, key):
    ks = jax.random.split(key, 6)
    p = len(cfg.pattern)
    n_cyc, tail = cfg.cycles, cfg.tail

    def stack_init(key, n, kinds):
        keys = jax.random.split(key, n * len(kinds)).reshape(n, len(kinds), 2)

        def one_cycle(cyc_keys):
            return [_block_init(cyc_keys[i], cfg, kinds[i])
                    for i in range(len(kinds))]

        stacked = jax.vmap(one_cycle)(keys)
        return stacked  # list over pattern positions, leaves [n, ...]

    params = {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, cfg.dtype),
        "layers": stack_init(ks[1], n_cyc, cfg.pattern),
        "final_norm": norm_init(cfg.d_model, cfg.norm, cfg.dtype),
    }
    if tail:
        params["tail"] = [_block_init(k, cfg, cfg.pattern[i])
                          for i, k in enumerate(jax.random.split(ks[2], tail))]
    if cfg.shared_every:
        params["shared"] = _shared_block_init(ks[3], cfg)
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(ks[4], cfg.vocab, cfg.d_model,
                                       cfg.dtype)
    return params


def param_specs(cfg: LMConfig):
    """Allocation-free ShapeDtypeStruct tree (dry-run)."""
    return jax.eval_shape(partial(init_params, cfg),
                          jax.random.PRNGKey(0))


# --------------------------------------------------------------------------- #
# Block forward (train / prefill)
# --------------------------------------------------------------------------- #
def _attn_kwargs(cfg: LMConfig, kind: str):
    theta = cfg.rope_theta_local if kind == "local" else cfg.rope_theta
    window = None
    if kind == "swa" or kind == "local":
        window = cfg.window
    return dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=cfg.head_dim, rope=cfg.rope, rope_theta=theta,
                rope_fraction=cfg.rope_fraction,
                rope_interleaved=cfg.rope_interleaved,
                norm_kind=cfg.qk_norm_kind, window=window,
                kv_block=cfg.kv_block)


def _ffn_apply(cfg: LMConfig, p, h):
    """Dense MLP / MoE / arctic MoE+dense-residual."""
    if cfg.n_experts:
        y, aux = moe_apply(p["moe"], h, n_experts=cfg.n_experts,
                           top_k=cfg.top_k, group_size=cfg.moe_group_size,
                           capacity_factor=cfg.moe_capacity,
                           mlp_kind=cfg.mlp_kind)
        if cfg.dense_ff:
            y = y + mlp(p["ffn"], h, cfg.mlp_kind)
        return y, aux
    return mlp(p["ffn"], h, cfg.mlp_kind), 0.0


def _block_forward(cfg: LMConfig, kind: str, p, x, positions):
    """x: [B, T, d] -> (x, aux_loss)."""
    aux = 0.0
    if kind in ATTN_KINDS:
        h = apply_norm(p["norm1"], x, cfg.norm)
        x = x + attn.attention_apply(p["attn"], h, positions=positions,
                                     causal=True, **_attn_kwargs(cfg, kind))
        h = apply_norm(p["norm2"], x, cfg.norm)
        y, aux = _ffn_apply(cfg, p, h)
        x = x + y
    elif kind == "rwkv6":
        h = apply_norm(p["norm1"], x, cfg.norm)
        y, _ = rw.rwkv6_time_mix(p["rwkv"], h, head_dim=cfg.rwkv_head_dim,
                                 chunk=cfg.scan_chunk,
                                 use_pallas=cfg.use_pallas,
                                 interpret=cfg.interpret)
        x = x + y
        h = apply_norm(p["norm2"], x, cfg.norm)
        y, _ = rw.rwkv6_channel_mix(p["rwkv"], h)
        x = x + y
    elif kind == "mamba2":
        h = apply_norm(p["norm1"], x, cfg.norm)
        y, _ = m2.mamba2_apply(p["mamba"], h, state=cfg.ssm_state,
                               head_dim=cfg.ssm_head_dim,
                               expand=cfg.ssm_expand,
                               conv_width=cfg.conv_width,
                               chunk=cfg.scan_chunk,
                               use_pallas=cfg.use_pallas,
                               interpret=cfg.interpret)
        x = x + y
    else:
        raise ValueError(kind)
    return shard(x, "act_btd"), aux


def _fill_attn_cache(entry, k, v, positions):
    """Write prefill K/V [B, T, ...] into a cache entry sized S.

    For ring caches (S < T) the last S tokens are kept and ROLLED so token at
    position p lands on ring slot p % S (matching the decode-time update)."""
    T = k.shape[1]
    S = entry["k"].shape[1]
    if T >= S:
        k, v, positions = k[:, T - S:], v[:, T - S:], positions[:, T - S:]
        if T % S:
            roll = lambda x: jnp.roll(x, T % S, axis=1)
            k, v, positions = roll(k), roll(v), roll(positions)
        return {"k": k.astype(entry["k"].dtype),
                "v": v.astype(entry["v"].dtype),
                "pos": positions.astype(jnp.int32)}
    z = jax.lax.dynamic_update_slice
    return {"k": z(entry["k"], k.astype(entry["k"].dtype), (0, 0, 0, 0)),
            "v": z(entry["v"], v.astype(entry["v"].dtype), (0, 0, 0, 0)),
            "pos": z(entry["pos"], positions.astype(jnp.int32), (0, 0))}


def _shared_forward(cfg: LMConfig, p, x, x0, positions, cache=None,
                    position=None, prefill_entry=None):
    """Zamba2 shared block over concat(x, x0); returns (delta, cache_entry)."""
    h_in = jnp.concatenate([x, x0], axis=-1)
    h = apply_norm(p["norm1"], h_in, cfg.norm)
    d_in = h.shape[-1]
    hd = d_in // cfg.shared_n_heads
    kw = dict(n_heads=cfg.shared_n_heads, n_kv=cfg.shared_n_heads,
              head_dim=hd, rope="neox", rope_theta=cfg.rope_theta,
              norm_kind=cfg.norm)
    new_cache = None
    if cache is not None:                              # decode
        a, new_cache = attn.attention_decode(p["attn"], h, cache,
                                             position=position, **kw)
    elif prefill_entry is not None:                    # prefill
        a, (k, v) = attn.attention_apply(p["attn"], h, positions=positions,
                                         causal=True, kv_block=cfg.kv_block,
                                         return_kv=True, **kw)
        new_cache = _fill_attn_cache(prefill_entry, k, v, positions)
    else:                                              # train
        a = attn.attention_apply(p["attn"], h, positions=positions,
                                 causal=True, kv_block=cfg.kv_block, **kw)
    h_in = h_in + a
    h = apply_norm(p["norm2"], h_in, cfg.norm)
    h_in = h_in + mlp(p["ffn"], h, "gelu")
    from repro.models.layers import dense
    return dense(p["out"]["down"], h_in), new_cache


# --------------------------------------------------------------------------- #
# Stacked-layer execution
# --------------------------------------------------------------------------- #
def _tree_slice(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _run_layers(cfg: LMConfig, params, x, positions):
    """Scan over cycles; body unrolls pattern positions.  Returns (x, aux)."""
    pat = cfg.pattern
    shared = params.get("shared")
    x0 = x

    def cycle(carry, cyc_params):
        h, aux = carry
        if shared is not None:
            delta, _ = _shared_forward(cfg, shared, h, x0, positions)
            h = h + delta
        for i, kind in enumerate(pat):
            h, a = _block_forward(cfg, kind, cyc_params[i], h, positions)
            aux = aux + a
        return (h, aux), None

    body = jax.checkpoint(cycle, policy=None) if cfg.remat else cycle
    if cfg.scan_layers and cfg.cycles > 1:
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros(())), params["layers"])
    else:
        carry = (x, jnp.zeros(()))
        for c in range(cfg.cycles):
            carry, _ = body(carry, _tree_slice(params["layers"], c))
        x, aux = carry
    for i in range(cfg.tail):
        if shared is not None and i == 0:
            delta, _ = _shared_forward(cfg, shared, x, x0, positions)
            x = x + delta
        x, a = _block_forward(cfg, cfg.pattern[i], params["tail"][i], x,
                              positions)
        aux = aux + a
    return x, aux


def forward(cfg: LMConfig, params, tokens):
    """tokens [B, T] -> logits [B, T, V] (f32)."""
    B, T = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    x = embed_lookup(params["embed"], tokens).astype(cfg.dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
    x, aux = _run_layers(cfg, params, x, positions)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(table, x)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits, aux


def loss_fn(cfg: LMConfig, params, batch):
    """Next-token cross-entropy.  batch: {"tokens": [B, T] int32}."""
    tokens = batch["tokens"]
    logits, aux = forward(cfg, params, tokens)
    logits = logits[:, :-1]
    targets = tokens[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None],
                               axis=-1)[..., 0]
    nll = (logz - gold).mean()
    loss = nll + cfg.aux_loss_weight * aux
    return loss, {"nll": nll, "aux": aux}


# --------------------------------------------------------------------------- #
# Prefill / decode
# --------------------------------------------------------------------------- #
from repro.models.kv_cache import cache_init  # noqa: E402  (cycle-free)


def prefill(cfg: LMConfig, params, tokens, max_len: int):
    """Run the prompt, emitting caches sized max_len.  Returns
    (cache, last_logits [B, V])."""
    # Forward pass reusing _run_layers is cheap to maintain but recomputes
    # K/V; for the assigned shapes prefill is lowered as its own program, so
    # we simply run block-by-block emitting caches.
    B, T = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    x = embed_lookup(params["embed"], tokens).astype(cfg.dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
    cache = cache_init(cfg, B, max_len)
    x0 = x

    def fill_entry(kind, p, x, entry):
        if kind in ATTN_KINDS:
            h = apply_norm(p["norm1"], x, cfg.norm)
            y, (k, v) = attn.attention_apply(
                p["attn"], h, positions=positions, causal=True,
                return_kv=True, **_attn_kwargs(cfg, kind))
            x = x + y
            entry = _fill_attn_cache(entry, k, v, positions)
            h = apply_norm(p["norm2"], x, cfg.norm)
            y, _ = _ffn_apply(cfg, p, h)
            x = x + y
        elif kind == "rwkv6":
            h = apply_norm(p["norm1"], x, cfg.norm)
            y, (tm_last, wkv) = rw.rwkv6_time_mix(
                p["rwkv"], h, head_dim=cfg.rwkv_head_dim,
                chunk=cfg.scan_chunk, use_pallas=cfg.use_pallas,
                interpret=cfg.interpret)
            x = x + y
            h = apply_norm(p["norm2"], x, cfg.norm)
            y, cm_last = rw.rwkv6_channel_mix(p["rwkv"], h)
            x = x + y
            entry = {"tm_last": tm_last, "cm_last": cm_last, "wkv": wkv}
        elif kind == "mamba2":
            h = apply_norm(p["norm1"], x, cfg.norm)
            y, (conv, ssm) = m2.mamba2_apply(
                p["mamba"], h, state=cfg.ssm_state,
                head_dim=cfg.ssm_head_dim, expand=cfg.ssm_expand,
                conv_width=cfg.conv_width, chunk=cfg.scan_chunk,
                use_pallas=cfg.use_pallas, interpret=cfg.interpret)
            x = x + y
            entry = {"conv": conv, "ssm": ssm}
        return shard(x, "act_btd"), entry

    shared = params.get("shared")

    def cycle(carry, inp):
        h, = carry
        cyc_params, cyc_cache = inp
        blocks = cyc_cache["blocks"] if shared is not None else cyc_cache
        new_entries = []
        if shared is not None:
            delta, sc = _shared_forward(cfg, shared, h, x0, positions,
                                        prefill_entry=cyc_cache["shared"])
            h = h + delta
        for i, kind in enumerate(cfg.pattern):
            h, e = fill_entry(kind, cyc_params[i], h, blocks[i])
            new_entries.append(e)
        out = (new_entries if shared is None
               else {"shared": sc, "blocks": new_entries})
        return (h,), out

    if cfg.scan_layers and cfg.cycles > 1:
        (x,), new_cache = jax.lax.scan(cycle, (x,),
                                       (params["layers"], cache["layers"]))
    else:
        entries = []
        h = x
        for c in range(cfg.cycles):
            (h,), e = cycle((h,), (_tree_slice(params["layers"], c),
                                   _tree_slice(cache["layers"], c)))
            entries.append(e)
        x = h
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *entries) \
            if entries else cache["layers"]
    cache["layers"] = new_cache
    if cfg.tail:
        tg = cache["tail"]
        blocks = tg["blocks"] if shared is not None else tg
        new_entries = []
        if shared is not None:
            delta, sc = _shared_forward(cfg, shared, x, x0, positions,
                                        prefill_entry=tg["shared"])
            x = x + delta
        for i in range(cfg.tail):
            x, e = fill_entry(cfg.pattern[i], params["tail"][i], x, blocks[i])
            new_entries.append(e)
        cache["tail"] = (new_entries if shared is None
                         else {"shared": sc, "blocks": new_entries})
    cache["pos"] = jnp.full((B,), T, jnp.int32)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(table, x[:, -1:, :])[:, 0]
    return cache, logits


def _block_decode(cfg: LMConfig, kind: str, p, x1, entry, position):
    """x1: [B, 1, d].  Returns (x1, entry)."""
    if kind in ATTN_KINDS:
        h = apply_norm(p["norm1"], x1, cfg.norm)
        kw = _attn_kwargs(cfg, kind)
        window = kw.pop("window")
        kw.pop("kv_block")
        cache_kind = "ring" if window else "full"
        y, entry = attn.attention_decode(p["attn"], h, entry,
                                         position=position,
                                         cache_kind=cache_kind, **kw)
        x1 = x1 + y
        h = apply_norm(p["norm2"], x1, cfg.norm)
        y, _ = _ffn_apply(cfg, p, h)
        x1 = x1 + y
    elif kind == "rwkv6":
        h = apply_norm(p["norm1"], x1, cfg.norm)[:, 0]
        y, tm_last, wkv = rw.rwkv6_time_mix_decode(
            p["rwkv"], h, entry["tm_last"], entry["wkv"],
            head_dim=cfg.rwkv_head_dim)
        x1 = x1 + y[:, None, :]
        h = apply_norm(p["norm2"], x1, cfg.norm)[:, 0]
        y, cm_last = rw.rwkv6_channel_mix_decode(p["rwkv"], h,
                                                 entry["cm_last"])
        x1 = x1 + y[:, None, :]
        entry = {"tm_last": tm_last, "cm_last": cm_last, "wkv": wkv}
    elif kind == "mamba2":
        h = apply_norm(p["norm1"], x1, cfg.norm)[:, 0]
        y, new = m2.mamba2_decode(p["mamba"], h, entry, state=cfg.ssm_state,
                                  head_dim=cfg.ssm_head_dim,
                                  expand=cfg.ssm_expand,
                                  conv_width=cfg.conv_width)
        x1 = x1 + y[:, None, :]
        entry = new
    return x1, entry


def decode_step(cfg: LMConfig, params, cache, tokens1):
    """One decode step.  tokens1: [B] int32.  Returns (cache, logits [B,V])."""
    B = tokens1.shape[0]
    position = cache["pos"]                                    # [B]
    x1 = embed_lookup(params["embed"], tokens1[:, None]).astype(cfg.dtype)
    if cfg.embed_scale:
        x1 = x1 * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
    shared = params.get("shared")
    x0 = x1

    def one_cycle(h, cyc_params, cyc_cache):
        new_entries = []
        if shared is not None:
            delta, sc = _shared_forward(cfg, shared, h, x0, None,
                                        cache=cyc_cache["shared"],
                                        position=position)
            h = h + delta
        for i, kind in enumerate(cfg.pattern):
            h, e = _block_decode(cfg, kind, cyc_params[i],
                                 h, cyc_cache[i] if shared is None
                                 else cyc_cache["blocks"][i], position)
            new_entries.append(e)
        out = new_entries if shared is None else {"shared": sc,
                                                  "blocks": new_entries}
        return h, out

    if cfg.scan_layers and cfg.cycles > 1:
        # The cache rides in the CARRY and is updated in place at cycle
        # index i: passing it through xs/ys instead makes XLA hold two full
        # cache copies (scan input + stacked output) — +1x total cache size
        # in temps, which alone broke the decode_32k cells (§Dry-run iter 4).
        def cycle(carry, inp):
            h, layers_cache = carry
            i, cyc_params = inp
            cyc_cache = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0,
                                                       keepdims=False),
                layers_cache)
            h, out = one_cycle(h, cyc_params, cyc_cache)
            layers_cache = jax.tree.map(
                lambda a, u: jax.lax.dynamic_update_index_in_dim(
                    a, u.astype(a.dtype), i, 0),
                layers_cache, out)
            return (h, layers_cache), None

        (x1, new_layers), _ = jax.lax.scan(
            cycle, (x1, cache["layers"]),
            (jnp.arange(cfg.cycles), params["layers"]))
    else:
        entries = []
        h = x1
        for c in range(cfg.cycles):
            h, e = one_cycle(h, _tree_slice(params["layers"], c),
                             _tree_slice(cache["layers"], c))
            entries.append(e)
        x1 = h
        new_layers = (jax.tree.map(lambda *xs: jnp.stack(xs), *entries)
                      if entries else cache["layers"])
    cache["layers"] = new_layers
    if cfg.tail:
        tg = cache["tail"]
        blocks = tg["blocks"] if shared is not None else tg
        new_entries = []
        if shared is not None:
            delta, sc = _shared_forward(cfg, shared, x1, x0, None,
                                        cache=tg["shared"],
                                        position=position)
            x1 = x1 + delta
        for i in range(cfg.tail):
            x1, e = _block_decode(cfg, cfg.pattern[i], params["tail"][i],
                                  x1, blocks[i], position)
            new_entries.append(e)
        cache["tail"] = (new_entries if shared is None
                         else {"shared": sc, "blocks": new_entries})
    cache["pos"] = position + 1
    x1 = apply_norm(params["final_norm"], x1, cfg.norm)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(table, x1)[:, 0]
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return cache, logits
