"""Mixture-of-Experts: group-wise top-k routing with capacity, GShard-style
einsum dispatch/combine.

Formulation (why there is no all-to-all in the baseline):
  tokens are reshaped to [G, n, d] groups (G sharded over ('pod','data'), d
  replicated over 'model'); the dispatch one-hot [G, n, E, C] carries the
  expert axis, E-sharded over 'model'.  Dispatch and the expert FFNs are then
  LOCAL on every model shard (each shard computes its E/ep experts on the
  capacity buffers of all its local groups); the only collective is the
  all-reduce over 'model' completing the combine contraction (plus the FSDP
  weight all-gathers).  An all-to-all dispatch variant (lower bandwidth per
  token) is a recorded §Perf hillclimb candidate.

Capacity: C = ceil(top_k * n * capacity_factor / E) per group; overflowing
tokens are dropped (standard GShard/Switch semantics), so expert FLOPs are
exactly capacity_factor * active-FLOPs.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.layers import _GATED, _PLAIN, dense_init

__all__ = ["moe_init", "moe_apply", "router_topk"]


def moe_init(key, d_model: int, d_ff: int, n_experts: int,
             mlp_kind: str = "swiglu", dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)

    def expert_mat(k, d_in, d_out, s):
        w = (jax.random.truncated_normal(
            k, -2.0, 2.0, (n_experts, d_in, d_out), jnp.float32) * s
        ).astype(dtype)
        return {"w": w}

    p = {
        "router": dense_init(ks[0], d_model, n_experts, jnp.float32),
        "experts": {
            "up": expert_mat(ks[1], d_model, d_ff, s_in),
            "down": expert_mat(ks[2], d_ff, d_model, s_out),
        },
    }
    if mlp_kind in _GATED:
        p["experts"]["gate"] = expert_mat(ks[3], d_model, d_ff, s_in)
    return p


def router_topk(logits, top_k: int, capacity: int):
    """logits [G, n, E] -> (combine [G, n, E, C] f32, aux_loss scalar).

    Slot-sequential position assignment (mesh-tf style): slot j of token t
    takes the next free capacity slot of its expert; tokens beyond capacity
    are dropped.  Combine weights are softmax probs renormalized over the
    top-k (mixtral convention).
    """
    G, n, E = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topv, topi = jax.lax.top_k(probs, top_k)                   # [G, n, k]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch eq. 4): E * sum_e f_e * p_e.
    me = probs.mean(axis=1)                                    # [G, E]
    ce = jax.nn.one_hot(topi[..., 0], E, dtype=jnp.float32).mean(axis=1)
    aux = E * jnp.mean(jnp.sum(me * ce, axis=-1))

    counts = jnp.zeros((G, 1, E), jnp.float32)
    combine = jnp.zeros((G, n, E, capacity), jnp.float32)
    for j in range(top_k):
        ohj = jax.nn.one_hot(topi[..., j], E, dtype=jnp.float32)   # [G, n, E]
        pos = jnp.cumsum(ohj, axis=1) - 1.0 + counts               # [G, n, E]
        keep = ohj * (pos < capacity)
        pc = jax.nn.one_hot(pos.astype(jnp.int32), capacity,
                            dtype=jnp.float32)                     # [G,n,E,C]
        combine = combine + (topv[..., j][..., None, None]
                             * pc * keep[..., None])
        counts = counts + ohj.sum(axis=1, keepdims=True)
    return combine, aux


def moe_apply(params, x, *, n_experts: int, top_k: int = 2,
              capacity_factor: float = 1.25, group_size: int = 512,
              mlp_kind: str = "swiglu"):
    """x: [B, T, d] -> (y [B, T, d], aux_loss)."""
    B, T, d = x.shape
    N = B * T
    gs = min(group_size, N)
    G = max(N // gs, 1)
    n = N // G
    E = n_experts
    capacity = max(int(math.ceil(top_k * n * capacity_factor / E)), 1)

    xg = shard(x.reshape(G, n, d), "act_gnd")
    # router dot in the activation dtype (upcasting xg materialized a full
    # f32 copy of every token's activations); routing probabilities are
    # computed in f32 from the small [G, n, E] logits.
    logits = jnp.matmul(xg, params["router"]["w"].astype(x.dtype)
                        ).astype(jnp.float32)
    combine, aux = router_topk(logits, top_k, capacity)        # [G, n, E, C]
    combine = shard(combine, "act_gnec")
    dispatch = (combine > 0).astype(x.dtype)

    # All expert dots run in the input dtype — forcing f32 outputs makes the
    # CPU legalizer hoist f32 copies of the [E, d, f] expert stacks out of
    # the layer scan (+4.5 GiB/device on arctic, §Dry-run iter 3); the TPU
    # MXU accumulates f32 internally regardless.
    xd = jnp.einsum("gnd,gnec->gecd", xg, dispatch)
    xd = shard(xd, "act_gecd")

    we = params["experts"]
    up = jnp.einsum("gecd,edf->gecf", xd, we["up"]["w"])
    if mlp_kind in _GATED:
        gate = jnp.einsum("gecd,edf->gecf", xd, we["gate"]["w"])
        h = _GATED[mlp_kind](gate) * up
    else:
        h = _PLAIN[mlp_kind](up)
    h = shard(h, "act_gecf")
    yd = jnp.einsum("gecf,efd->gecd", h, we["down"]["w"])
    yd = shard(yd, "act_gecd")

    # combine: contraction over (e, c); e is model-sharded -> the all-reduce
    # runs in the input dtype (bf16 at scale — half the MoE wire bytes).
    y = jnp.einsum("gecd,gnec->gnd", yd, combine.astype(x.dtype))
    y = shard(y, "act_gnd")
    return y.reshape(B, T, d), aux
