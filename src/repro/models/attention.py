"""Grouped-query attention: train/prefill (chunked-flash) and decode paths.

Three execution strategies, chosen by static shape/window arguments:

  * `flash_attention` — online-softmax scan over KV blocks (bounded memory,
    the pure-JAX flash formulation).  Used for full/causal attention at any
    sequence length; causal masking wastes <= 2x score FLOPs, negligible next
    to the projection matmuls at the assigned shapes.
  * `local_attention` — block-local sliding-window attention: each query
    block of `window` tokens attends exactly its own + previous block
    (compute O(T * window), the honest cost of SWA/local layers — no masked
    full-T^2 waste).  Used by mixtral (window 4096) and gemma3 local layers
    (window 1024).
  * `decode_attention` — single-query attention against a KV cache, written
    reduction-friendly so GSPMD turns sequence-sharded caches into
    flash-decode (partial max/sum + all-reduce over the sequence shards).

All paths are GQA-aware: KV heads are repeated logically via reshape of Q to
[B, T, kv, group, dh] and einsums over the group axis (no materialized
repeat_kv).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard

__all__ = ["flash_attention", "local_attention", "decode_attention",
           "attention_init", "attention_apply", "attention_decode"]

NEG_INF = -1e30


def _group_q(q, n_kv: int):
    """[B, T, H, dh] -> [B, T, kv, G, dh] with G = H // kv."""
    B, T, H, dh = q.shape
    return q.reshape(B, T, n_kv, H // n_kv, dh)


# --------------------------------------------------------------------------- #
# Flash attention: scan over KV blocks with online softmax.
# --------------------------------------------------------------------------- #
def flash_attention(q, k, v, *, causal: bool = True, kv_block: int = 1024,
                    q_block: int = 1024, q_positions=None,
                    kv_positions=None):
    """q: [B, Tq, H, dh]; k, v: [B, Tk, kv, dh] -> [B, Tq, H, dh].

    Double-blocked online softmax: outer scan over QUERY blocks, inner scan
    over KV blocks.  Peak score memory is one [B, qb, kv, G, kb] tile, and
    the residuals saved for backward are O(nq * nkv * qb * dh) carries
    instead of O(Tq * Tk) — the formulation that keeps the 32k-prefill and
    4k-train cells inside HBM (EXPERIMENTS.md §Dry-run iteration 2).
    For causal attention, KV blocks strictly above a query block's diagonal
    are skipped by masking-to-zero; the <=2x score-FLOP overshoot is
    negligible next to the projection matmuls at the assigned shapes.
    """
    B, Tq, H, dh = q.shape
    Tk, n_kv = k.shape[1], k.shape[2]
    G = H // n_kv
    scale = dh ** -0.5
    kb_sz = min(kv_block, Tk)
    qb_sz = min(q_block, Tq)
    pad_k = (-Tk) % kb_sz
    pad_q = (-Tq) % qb_sz
    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(Tq), (B, Tq))
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(Tk), (B, Tk))
    INT_MAX = jnp.iinfo(jnp.int32).max
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad_k)),
                               constant_values=INT_MAX)
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pad_q)),
                              constant_values=0)
    nk = (Tk + pad_k) // kb_sz
    nq = (Tq + pad_q) // qb_sz

    # Big dots stay in the input dtype (bf16 at the assigned shapes): the
    # TPU MXU accumulates f32 internally; forcing f32 HLO outputs makes the
    # CPU legalizer hoist f32 copies of K/V out of the scan (§Dry-run iter 3).
    # Softmax math happens in f32 on the per-tile score tensor only.
    qg = _group_q(q, n_kv) * jnp.asarray(scale, q.dtype)
    qb = qg.reshape(B, nq, qb_sz, n_kv, G, dh)
    qpb = q_positions.reshape(B, nq, qb_sz)
    kb = k.reshape(B, nk, kb_sz, n_kv, dh)
    vb = v.reshape(B, nk, kb_sz, n_kv, dh)
    pb = kv_positions.reshape(B, nk, kb_sz)

    def q_step(_, q_in):
        q_i, qp_i = q_in                                   # [B,qb,kv,G,dh]

        def kv_step(carry, kv_in):
            m, l, acc = carry
            k_j, v_j, p_j = kv_in
            s = jnp.einsum("btkgd,bjkd->btkgj", q_i, k_j
                           ).astype(jnp.float32)
            mask = (p_j[:, None, :] <= qp_i[:, :, None] if causal
                    else p_j[:, None, :] < INT_MAX)
            s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "btkgj,bjkd->btkgd", p.astype(v_j.dtype), v_j
            ).astype(jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((B, qb_sz, n_kv, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, qb_sz, n_kv, G), jnp.float32)
        a0 = jnp.zeros((B, qb_sz, n_kv, G, dh), jnp.float32)
        # checkpoint: backward recomputes each tile's scores instead of
        # saving every [B, qb, kv, G, kb] probability tile.
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (m0, l0, a0),
            (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0),
             jnp.moveaxis(pb, 1, 0)))
        out_i = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out_i

    _, out = jax.lax.scan(q_step, None,
                          (jnp.moveaxis(qb, 1, 0), jnp.moveaxis(qpb, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, Tq + pad_q, H, dh)
    return out[:, :Tq].astype(q.dtype)


# --------------------------------------------------------------------------- #
# Block-local sliding-window attention (O(T * window) compute).
# --------------------------------------------------------------------------- #
def local_attention(q, k, v, *, window: int, q_positions=None):
    """Causal sliding-window attention; token t attends (t-window, t].

    Blocked at `window`: query block i attends key blocks i-1 and i, which
    covers the window exactly; positions outside are masked.  Compute is
    2 * T * window scores — the true cost of SWA.
    """
    B, T, H, dh = q.shape
    n_kv = k.shape[2]
    G = H // n_kv
    scale = dh ** -0.5
    w = min(window, T)
    pad = (-T) % w
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Tp = T + pad
    N = Tp // w

    qb = _group_q(q, n_kv).reshape(B, N, w, n_kv, G, dh)
    kb = k.reshape(B, N, w, n_kv, dh)
    vb = v.reshape(B, N, w, n_kv, dh)
    # context = [previous block ; own block]  -> [B, N, 2w, kv, dh]
    prev = lambda x: jnp.pad(x[:, :-1], ((0, 0), (1, 0)) + ((0, 0),) * 3)
    kc = jnp.concatenate([prev(kb), kb], axis=2)
    vc = jnp.concatenate([prev(vb), vb], axis=2)

    qpos = jnp.arange(Tp).reshape(N, w)                       # [N, w]
    kpos = jnp.concatenate([qpos - w, qpos], axis=1)          # [N, 2w]
    mask = ((kpos[:, None, :] <= qpos[:, :, None])
            & (kpos[:, None, :] > qpos[:, :, None] - w)
            & (kpos[:, None, :] >= 0))                        # [N, w, 2w]

    def blk(qi, ki, vi, mi):
        s = jnp.einsum("btkgd,bjkd->btkgj",
                       qi * jnp.asarray(scale, qi.dtype), ki
                       ).astype(jnp.float32)
        s = jnp.where(mi[None, :, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("btkgj,bjkd->btkgd", p.astype(vi.dtype), vi)

    # scan over query blocks: bounds peak memory at one [B, w, kv, G, 2w] score
    out = jax.lax.scan(
        lambda _, x: (None, blk(*x)), None,
        (jnp.moveaxis(qb, 1, 0), jnp.moveaxis(kc, 1, 0),
         jnp.moveaxis(vc, 1, 0), mask))[1]
    out = jnp.moveaxis(out, 0, 1).reshape(B, Tp, H, dh)[:, :T]
    return out.astype(q.dtype)


# --------------------------------------------------------------------------- #
# Decode: single query step against a cache.
# --------------------------------------------------------------------------- #
def decode_attention(q, k_cache, v_cache, kv_positions, q_position):
    """q: [B, 1, H, dh]; caches [B, S, kv, dh]; kv_positions [B, S] (absolute,
    MAX_INT for empty slots); q_position [B].

    Written as separate max / exp / sum reductions over S so GSPMD lowers a
    sequence-sharded cache to flash-decode (partial reductions + all-reduce).
    The caches are NEVER upcast: the q*K and p*V dots run in the cache dtype
    (an .astype(f32) here materialized a full f32 copy of every cache —
    +10 GiB/device on whisper decode, §Dry-run iter 3); softmax runs in f32
    on the [B, kv, G, S] score tensor.
    """
    B, _, H, dh = q.shape
    n_kv = k_cache.shape[2]
    G = H // n_kv
    qg = (_group_q(q, n_kv)[:, 0]
          * jnp.asarray(dh ** -0.5, q.dtype))                      # [B,kv,G,dh]
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(k_cache.dtype),
                   k_cache).astype(jnp.float32)
    valid = kv_positions <= q_position[:, None]                    # [B, S]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jax.lax.stop_gradient(s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m)
    l = p.sum(axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache
                     ).astype(jnp.float32)
    out = out / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, 1, H, dh).astype(q.dtype)


# --------------------------------------------------------------------------- #
# Full attention block (projections + rope + qk-norm + core + out proj)
# --------------------------------------------------------------------------- #
from repro.models.layers import (apply_qk_norm, apply_rope, dense, dense_init,
                                 qk_norm_init)


def attention_init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   qk_norm: bool = False, norm_kind: str = "rmsnorm",
                   dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, n_kv * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, n_kv * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype),
    }
    if qk_norm:
        p["qk_norm"] = qk_norm_init(head_dim, norm_kind, dtype)
    return p


def _project_qkv(params, x, n_heads, n_kv, head_dim, *, positions, rope,
                 rope_theta, rope_fraction, rope_interleaved, norm_kind):
    B, T, _ = x.shape
    q = dense(params["wq"], x).reshape(B, T, n_heads, head_dim)
    k = dense(params["wk"], x).reshape(B, T, n_kv, head_dim)
    v = dense(params["wv"], x).reshape(B, T, n_kv, head_dim)
    if "qk_norm" in params:
        q, k = apply_qk_norm(params["qk_norm"], q, k, norm_kind)
    if rope != "none":
        q = apply_rope(q, positions, theta=rope_theta, fraction=rope_fraction,
                       interleaved=rope_interleaved)
        k = apply_rope(k, positions, theta=rope_theta, fraction=rope_fraction,
                       interleaved=rope_interleaved)
    return shard(q, "act_bthd"), shard(k, "kv_bt"), shard(v, "kv_bt")


def attention_apply(params, x, *, n_heads, n_kv, head_dim, positions=None,
                    causal=True, window=None, rope="neox", rope_theta=1e4,
                    rope_fraction=1.0, rope_interleaved=False,
                    norm_kind="rmsnorm", kv_block=1024, x_kv=None,
                    return_kv=False):
    """Train/prefill attention.  x_kv (cross-attention source) overrides the
    KV input; window selects the block-local path."""
    B, T, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    if x_kv is None:
        q, k, v = _project_qkv(params, x, n_heads, n_kv, head_dim,
                               positions=positions, rope=rope,
                               rope_theta=rope_theta,
                               rope_fraction=rope_fraction,
                               rope_interleaved=rope_interleaved,
                               norm_kind=norm_kind)
    else:  # cross-attention: queries from x, keys/values from x_kv, no rope.
        Tk = x_kv.shape[1]
        q = dense(params["wq"], x).reshape(B, T, n_heads, head_dim)
        k = dense(params["wk"], x_kv).reshape(B, Tk, n_kv, head_dim)
        v = dense(params["wv"], x_kv).reshape(B, Tk, n_kv, head_dim)
        q, k, v = shard(q, "act_bthd"), shard(k, "kv_bt"), shard(v, "kv_bt")
    if window is not None and x_kv is None and causal:
        out = local_attention(q, k, v, window=window)
    else:
        out = flash_attention(q, k, v, causal=causal and x_kv is None,
                              kv_block=kv_block)
    out = shard(out, "act_bthd")
    y = dense(params["wo"], out.reshape(B, T, n_heads * head_dim))
    if return_kv:
        return y, (k, v)
    return y


def attention_decode(params, x, cache, *, n_heads, n_kv, head_dim, position,
                     rope="neox", rope_theta=1e4, rope_fraction=1.0,
                     rope_interleaved=False, norm_kind="rmsnorm",
                     cache_kind="full", cross_kv=None):
    """One-token decode.  cache = {"k","v","pos"}; position [B] absolute.

    cache_kind "full": slot = position; "ring": slot = position % S (window
    ring buffer — SWA/local layers keep only the last S tokens).
    cross_kv: precomputed (k, v) encoder projections for cross-attention
    (cache is not updated).
    """
    B = x.shape[0]
    if cross_kv is not None:
        q = dense(params["wq"], x).reshape(B, 1, n_heads, head_dim)
        k_all, v_all = cross_kv
        Tk = k_all.shape[1]
        kv_pos = jnp.broadcast_to(jnp.arange(Tk), (B, Tk))
        out = decode_attention(q, k_all, v_all, kv_pos,
                               jnp.full((B,), Tk, jnp.int32))
        y = dense(params["wo"], out.reshape(B, 1, n_heads * head_dim))
        return y, cache

    pos_b = jnp.broadcast_to(position[:, None], (B, 1))
    q, k, v = _project_qkv(params, x, n_heads, n_kv, head_dim,
                           positions=pos_b, rope=rope, rope_theta=rope_theta,
                           rope_fraction=rope_fraction,
                           rope_interleaved=rope_interleaved,
                           norm_kind=norm_kind)
    S = cache["k"].shape[1]
    slot = position % S if cache_kind == "ring" else position
    # per-sample dynamic_update_slice via vmap (slot differs across batch).
    upd = jax.vmap(lambda c, u, s: jax.lax.dynamic_update_slice(
        c, u.astype(c.dtype), (s, 0, 0)))
    k_cache = upd(cache["k"], k, slot)
    v_cache = upd(cache["v"], v, slot)
    kv_pos = jax.vmap(lambda c, p, s: jax.lax.dynamic_update_slice(
        c, p[None].astype(c.dtype), (s,)))(cache["pos"], position, slot)
    out = decode_attention(q, k_cache, v_cache, kv_pos, position)
    y = dense(params["wo"], out.reshape(B, 1, n_heads * head_dim))
    return y, {"k": k_cache, "v": v_cache, "pos": kv_pos}
