"""Shared neural-net layers for the assigned LM architectures.

Pure-functional style matching repro.core: params are plain dict pytrees,
every function is `f(params, x, ...) -> y`.  Initializers return the param
tree; `jax.eval_shape` over them gives the allocation-free specs used by the
multi-pod dry-run.

Activation sharding is requested through `repro.distributed.sharding.shard`,
which is a no-op outside an `axis_rules` context (so smoke tests and the
MERINDA path never touch device state).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard

__all__ = [
    "dense_init", "dense", "norm_init", "apply_norm", "mlp_init", "mlp",
    "embed_init", "embed_lookup", "unembed", "rope_frequencies", "apply_rope",
    "sinusoidal_positions", "qk_norm_init", "apply_qk_norm",
]


# --------------------------------------------------------------------------- #
# Dense / projections
# --------------------------------------------------------------------------- #
def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale=None):
    """Truncated-normal fan-in init (MaxText/T5 style)."""
    s = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out), jnp.float32)
         * s).astype(dtype)
    return {"w": w}


def dense(params, x):
    """x: [..., d_in] @ w [d_in, d_out] in the input dtype.

    No preferred_element_type=f32 here: on the TPU target the MXU
    accumulates in f32 regardless; forcing an f32 HLO output makes the CPU
    legalizer hoist f32 CONVERTS of entire stacked weight arrays out of the
    layer scan (measured +2-15 GiB/device in the dry-run — §Dry-run iter 3).
    f32 math is applied explicitly where it matters (norms, softmax, logits).
    """
    return jnp.matmul(x, params["w"])


# --------------------------------------------------------------------------- #
# Normalization
# --------------------------------------------------------------------------- #
def norm_init(d: int, kind: str = "rmsnorm", dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    raise ValueError(kind)


def apply_norm(params, x, kind: str = "rmsnorm", eps: float = 1e-6):
    """RMSNorm / LayerNorm in f32 (numerics) cast back to input dtype."""
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = ((xf - mu) * jax.lax.rsqrt(var + eps)
             * params["scale"].astype(jnp.float32)
             + params["bias"].astype(jnp.float32))
    return y.astype(x.dtype)


def qk_norm_init(head_dim: int, kind: str = "rmsnorm", dtype=jnp.float32):
    """Per-head q/k norms (qwen3 / gemma3 RMS, chameleon LayerNorm)."""
    return {"q": norm_init(head_dim, kind, dtype),
            "k": norm_init(head_dim, kind, dtype)}


def apply_qk_norm(params, q, k, kind: str = "rmsnorm"):
    return (apply_norm(params["q"], q, kind), apply_norm(params["k"], k, kind))


# --------------------------------------------------------------------------- #
# MLP (swiglu / geglu / gelu / relu2)
# --------------------------------------------------------------------------- #
_GATED = {"swiglu": jax.nn.silu, "geglu": lambda x: jax.nn.gelu(x, approximate=True)}
_PLAIN = {"gelu": lambda x: jax.nn.gelu(x, approximate=True),
          "relu2": lambda x: jnp.square(jax.nn.relu(x))}


def mlp_init(key, d_model: int, d_ff: int, kind: str = "swiglu",
             dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], d_model, d_ff, dtype),
         "down": dense_init(ks[1], d_ff, d_model, dtype)}
    if kind in _GATED:
        p["gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def mlp(params, x, kind: str = "swiglu"):
    """Position-wise FFN.  Hidden activation sharded over the model axis."""
    if kind in _GATED:
        h = _GATED[kind](dense(params["gate"], x)) * dense(params["up"], x)
    else:
        h = _PLAIN[kind](dense(params["up"], x))
    h = shard(h, "act_ffn")
    return dense(params["down"], h)


# --------------------------------------------------------------------------- #
# Embeddings
# --------------------------------------------------------------------------- #
def embed_init(key, vocab: int, d_model: int, dtype=jnp.float32):
    w = (jax.random.normal(key, (vocab, d_model), jnp.float32)
         * (1.0 / math.sqrt(d_model))).astype(dtype)
    return {"w": w}


def embed_lookup(params, tokens):
    """tokens [..] int32 -> [.., d].

    Under sharding rules the lookup is a one-hot MATMUL: a gather from the
    vocab-sharded table makes GSPMD replicate the whole table ("involuntary
    full rematerialization", 2-4 GiB/device for the 262k vocabs); the
    one-hot contraction keeps the table sharded and reduces with one psum
    (and its transpose is the exact embedding-gradient scatter).  On a
    single device the plain gather is used.
    """
    from repro.distributed.sharding import active_rules
    w = params["w"]
    rules = active_rules()
    model_size = (rules.mesh.shape.get("model", 1)
                  if rules is not None else 1)
    if rules is None or w.shape[0] % model_size != 0:
        # non-divisible vocab (whisper 51866): the table is replicated by
        # the param rules, so a plain gather is local; the one-hot path
        # would materialize a full [B, T, V] one-hot before resharding.
        return shard(jnp.take(w, tokens, axis=0), "act_btd")
    oh = jax.nn.one_hot(tokens, w.shape[0], dtype=w.dtype)
    oh = shard(oh, "act_btv")
    out = jnp.matmul(oh, w)        # exact: one-hot selects, no accumulation
    return shard(out, "act_btd")


def unembed(params, x, scale: float | None = None):
    """x [.., d] -> logits [.., V] (f32).  V sharded over 'model'."""
    logits = jnp.matmul(x, params["w"].T, preferred_element_type=jnp.float32)
    if scale is not None:
        logits = logits * scale
    return shard(logits, "act_btv")


# --------------------------------------------------------------------------- #
# Rotary position embeddings (neox, partial/interleaved, none)
# --------------------------------------------------------------------------- #
def rope_frequencies(head_dim: int, theta: float, fraction: float = 1.0):
    """Inverse frequencies for the rotated sub-dimension."""
    rot = int(head_dim * fraction)
    rot -= rot % 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x, positions, *, theta: float = 1e4, fraction: float = 1.0,
               interleaved: bool = False):
    """x: [B, T, H, dh], positions: [B, T] (absolute token positions).

    fraction < 1 rotates only the first `fraction * dh` dims (chatglm3's 2d
    RoPE applies rotary to half the head dims); `interleaved` pairs (0,1),
    (2,3), ... (GLM/GPT-J style) instead of neox half-splitting.
    """
    dh = x.shape[-1]
    inv, rot = rope_frequencies(dh, theta, fraction)
    ang = positions[..., None].astype(jnp.float32) * inv        # [B, T, rot/2]
    # angles/trig in f32 (small [B, T, rot/2] tables); the rotation itself
    # in the input dtype — full-width f32 rotation materialized 2 GiB/layer
    # of transient q/k copies at 32k prefill (§Dry-run iter 3).
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    xr, xp = x[..., :rot], x[..., rot:]
    if interleaved:
        x1, x2 = xr[..., 0::2], xr[..., 1::2]
        r1 = x1 * cos - x2 * sin
        r2 = x2 * cos + x1 * sin
        rotated = jnp.stack([r1, r2], axis=-1).reshape(xr.shape)
    else:
        half = rot // 2
        x1, x2 = xr[..., :half], xr[..., half:]
        rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                                  axis=-1)
    return jnp.concatenate([rotated.astype(x.dtype), xp], axis=-1)


def sinusoidal_positions(T: int, d: int, dtype=jnp.float32):
    """Whisper-encoder style fixed sinusoidal position table [T, d]."""
    half = d // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                   / max(half - 1, 1))
    ang = jnp.arange(T, dtype=jnp.float32)[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)
