"""Single-area grid frequency dynamics (scenario-zoo system).

The textbook swing-equation + governor model for one control area:
frequency deviation f (Hz from nominal) and mechanical power deviation p,
driven by a net load disturbance u (lost generation, demand steps):

    M*df/dt  = p - D*f - u               (inertia vs damping vs imbalance)
    tau*dp/dt = -p - f/R                 (governor droop response)

Linear — deliberately: it pins the zoo's "easy identification, hard
mission" corner.  The serving question is pure what-if: "if this feeder
trips (u steps 0.2 pu), does frequency stay inside the load-shed band
over the next 10 s?" — a grid operator's scenario query, answered with
confidence bounds from the online-refit ensemble.
"""
from __future__ import annotations

from repro.systems.base import DynamicalSystem, SystemSpec


class GridFrequency(DynamicalSystem):
    def __init__(self, M=8.0, D=1.0, R=0.08, tau=0.5):
        self.p = (M, D, R, tau)
        self.spec = SystemSpec(
            name="grid_frequency", n=2, m=1, order=2,
            dt=0.02, horizon=500,
            y0_low=(-0.5, -0.5), y0_high=(0.5, 0.5),
            input_kind="prbs", input_scale=0.3,
        )

    def rows(self):
        M, D, R, tau = self.p
        return [
            {"y1": 1.0 / M, "y0": -D / M, "u0": -1.0 / M},
            {"y1": -1.0 / tau, "y0": -1.0 / (R * tau)},
        ]
