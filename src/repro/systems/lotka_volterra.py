"""Lotka-Volterra predator-prey system (paper Table I, row 1).

dy0/dt =  a*y0 - b*y0*y1
dy1/dt = -c*y1 + d*y0*y1

Coefficients follow the SINDy-MPC benchmark suite (Kaiser, Kutz & Brunton).
"""
from __future__ import annotations

from repro.systems.base import DynamicalSystem, SystemSpec


class LotkaVolterra(DynamicalSystem):
    def __init__(self, a=1.0, b=0.1, c=1.5, d=0.075):
        self.a, self.b, self.c, self.d = a, b, c, d
        self.spec = SystemSpec(
            name="lotka_volterra", n=2, m=0, order=2,
            dt=0.02, horizon=400,
            y0_low=(5.0, 2.0), y0_high=(20.0, 10.0),
            input_kind="none",
        )

    def rows(self):
        return [
            {"y0": self.a, "y0*y1": -self.b},
            {"y1": -self.c, "y0*y1": self.d},
        ]
