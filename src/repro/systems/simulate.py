"""Ground-truth trace generation for the MR benchmarks.

Traces are integrated at `substeps` RK4 sub-intervals per sample so the sampled
trajectory is accurate well past the Nyquist requirement, then optionally
corrupted with measurement noise (the "human-induced noise" regime the paper
mentions for MR).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.odeint import integrate
from repro.systems.base import DynamicalSystem

__all__ = ["Trace", "simulate", "simulate_batch"]


@dataclass
class Trace:
    """A sampled trajectory. ys: [T+1, n] clean, ys_noisy likewise, us: [T, m]."""
    ys: jnp.ndarray
    ys_noisy: jnp.ndarray
    us: jnp.ndarray
    dt: float


@partial(jax.jit, static_argnames=("system", "horizon", "substeps"))
def _simulate(system: DynamicalSystem, key, horizon: int, substeps: int,
              noise_std: float):
    k0, k1, k2 = jax.random.split(key, 3)
    y0 = system.sample_y0(k0)
    us = system.sample_inputs(k1, horizon)
    ys = integrate(system.rhs, y0, us, system.spec.dt, substeps=substeps)
    noise = noise_std * jax.random.normal(k2, ys.shape) * jnp.std(ys, 0, keepdims=True)
    return ys, ys + noise, us


def simulate(system: DynamicalSystem, key, horizon: int | None = None,
             substeps: int = 10, noise_std: float = 0.0) -> Trace:
    horizon = horizon or system.spec.horizon
    ys, ys_noisy, us = _simulate(system, key, horizon, substeps, noise_std)
    return Trace(ys=ys, ys_noisy=ys_noisy, us=us, dt=system.spec.dt)


def simulate_batch(system: DynamicalSystem, key, batch: int,
                   horizon: int | None = None, substeps: int = 10,
                   noise_std: float = 0.0) -> Trace:
    """Batch of independent traces: ys [B, T+1, n], us [B, T, m]."""
    horizon = horizon or system.spec.horizon
    keys = jax.random.split(key, batch)
    sim = jax.vmap(lambda k: _simulate(system, k, horizon, substeps, noise_std))
    ys, ys_noisy, us = sim(keys)
    return Trace(ys=ys, ys_noisy=ys_noisy, us=us, dt=system.spec.dt)


REGISTRY = {}


def register_systems():
    """Populate the name -> constructor registry (import-cycle-free)."""
    from repro.systems.f8_crusader import F8Crusader
    from repro.systems.grid_frequency import GridFrequency
    from repro.systems.lorenz import Lorenz
    from repro.systems.lotka_volterra import LotkaVolterra
    from repro.systems.pathogen import PathogenicAttack
    from repro.systems.quadrotor import Quadrotor
    from repro.systems.thermal_battery import ThermalBattery
    from repro.systems.van_der_pol import VanDerPol

    REGISTRY.update({
        "lotka_volterra": LotkaVolterra,
        "lorenz": Lorenz,
        "f8_crusader": F8Crusader,
        "pathogenic_attack": PathogenicAttack,
        "van_der_pol": VanDerPol,
        "quadrotor": Quadrotor,
        "thermal_battery": ThermalBattery,
        "grid_frequency": GridFrequency,
    })
    return REGISTRY
