"""F8 Crusader aircraft longitudinal dynamics (paper's primary benchmark).

The classic Garrard & Jordan polynomial model (order 3, n=3 states, m=1 input):
  y0 = angle of attack, y1 = pitch angle, y2 = pitch rate, u = elevator.

dy0/dt = -0.877 y0 + y2 - 0.088 y0*y2 + 0.47 y0^2 - 0.019 y1^2 - y0^2*y2
         + 3.846 y0^3 - 0.215 u + 0.28 y0^2*u + 0.47 y0*u^2 + 0.63 u^3
dy1/dt = y2
dy2/dt = -4.208 y0 - 0.396 y2 - 0.47 y0^2 - 3.564 y0^3
         - 20.967 u + 6.265 y0^2*u + 46 y0*u^2 + 61.4 u^3

The paper sweeps "model dimension" 20..150 on this system (Fig. 4 / Table II).
We reproduce that sweep with `F8Crusader(n_aircraft=k)`: a fleet of k
independent F8 airframes stacked into one 3k-dimensional system — the digital-
twinning deployment scenario (one twin per tracked aircraft), which scales the
state dimension exactly as the paper's x-axis does while keeping the true
dynamics sparse and identifiable.
"""
from __future__ import annotations

from repro.systems.base import DynamicalSystem, SystemSpec


def _f8_rows(base: int, n: int, u_name: str) -> list[dict[str, float]]:
    """Rows for one airframe whose states are y{base}..y{base+2}."""
    a, b, q = f"y{base}", f"y{base + 1}", f"y{base + 2}"
    u = u_name

    def nm(*parts):
        return "*".join(sorted(parts))

    row0 = {
        a: -0.877, q: 1.0, nm(a, q): -0.088, nm(a, a): 0.47,
        nm(b, b): -0.019, nm(a, a, q): -1.0, nm(a, a, a): 3.846,
        u: -0.215, nm(a, a, u): 0.28, nm(a, u, u): 0.47, nm(u, u, u): 0.63,
    }
    row1 = {q: 1.0}
    row2 = {
        a: -4.208, q: -0.396, nm(a, a): -0.47, nm(a, a, a): -3.564,
        u: -20.967, nm(a, a, u): 6.265, nm(a, u, u): 46.0, nm(u, u, u): 61.4,
    }
    return [row0, row1, row2]


class F8Crusader(DynamicalSystem):
    """F8 longitudinal dynamics; `n_aircraft` stacks independent airframes.

    State dim n = 3 * n_aircraft, one shared elevator input (m=1) — the
    collision-avoidance scenario drives the fleet with a common commanded
    maneuver while each airframe's response is recovered independently.
    """

    def __init__(self, n_aircraft: int = 1):
        self.n_aircraft = n_aircraft
        n = 3 * n_aircraft
        self.spec = SystemSpec(
            name=f"f8_crusader_{n}d" if n_aircraft > 1 else "f8_crusader",
            n=n, m=1, order=3,
            dt=0.01, horizon=600,
            # the open-loop F8 cubic terms (3.846 y0^3) destabilize large
            # angle-of-attack excursions; ranges per the verification
            # literature's trim-neighbourhood studies.
            y0_low=tuple([-0.15, -0.05, -0.05] * n_aircraft),
            y0_high=tuple([0.30, 0.05, 0.05] * n_aircraft),
            input_kind="sum_of_sines", input_scale=0.05,
        )

    def rows(self):
        rows: list[dict[str, float]] = []
        u_name = f"u0"
        # note: in the library naming, inputs come after ALL states, so the
        # input name is independent of n_aircraft.
        for k in range(self.n_aircraft):
            rows.extend(_f8_rows(3 * k, self.spec.n, u_name))
        return rows
