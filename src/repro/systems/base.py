"""Benchmark nonlinear dynamical systems (the paper's four evaluation systems).

Every system is a sparse polynomial ODE  dY/dt = Theta_true @ Phi(Y, U)  plus
metadata needed by the data pipeline (sane initial-condition ranges, input
excitation, integration step).  `true_theta(library)` places the ground-truth
coefficients into an arbitrary-order library so recovered models can be scored
both on trajectory reconstruction MSE (the paper's Table I metric) and on
coefficient error.
"""
from __future__ import annotations

import abc
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.library import PolyLibrary, make_library


@dataclass(frozen=True)
class SystemSpec:
    name: str
    n: int              # state dimension
    m: int              # input dimension
    order: int          # polynomial order of the true dynamics
    dt: float           # sampling interval (at or above Nyquist for the system)
    horizon: int        # default number of samples per trace
    y0_low: tuple
    y0_high: tuple
    input_kind: str     # "none" | "sum_of_sines" | "prbs"
    input_scale: float = 1.0


class DynamicalSystem(abc.ABC):
    spec: SystemSpec

    @abc.abstractmethod
    def rows(self) -> list[dict[str, float]]:
        """Ground-truth coefficients as per-state {term_name: coeff} dicts."""

    # ------------------------------------------------------------------ #
    def library(self, order: int | None = None) -> PolyLibrary:
        return make_library(self.spec.n, self.spec.m,
                            order if order is not None else self.spec.order)

    def true_theta(self, library: PolyLibrary | None = None) -> np.ndarray:
        lib = library or self.library()
        return lib.theta_from_terms(self.rows())

    def rhs(self, y, u=None):
        """Polynomial rhs evaluated through the library (single source of truth)."""
        lib = self.library()
        theta = jnp.asarray(self.true_theta(lib), dtype=y.dtype)
        phi = lib.eval(y, u if self.spec.m else None)
        return phi @ theta.T

    # ------------------------------------------------------------------ #
    def sample_y0(self, key, batch: tuple[int, ...] = ()):
        lo = jnp.asarray(self.spec.y0_low)
        hi = jnp.asarray(self.spec.y0_high)
        return jax.random.uniform(key, batch + (self.spec.n,), minval=lo, maxval=hi)

    def sample_inputs(self, key, horizon: int, batch: tuple[int, ...] = ()):
        """Excitation inputs [T, *batch, m]."""
        m, dt, scale = self.spec.m, self.spec.dt, self.spec.input_scale
        if m == 0:
            return jnp.zeros((horizon,) + batch + (0,))
        t = jnp.arange(horizon) * dt
        if self.spec.input_kind == "sum_of_sines":
            kf, ka, kp = jax.random.split(key, 3)
            n_tones = 4
            freqs = jax.random.uniform(kf, batch + (m, n_tones), minval=0.1, maxval=1.5)
            phases = jax.random.uniform(kp, batch + (m, n_tones), maxval=2 * jnp.pi)
            amps = jax.random.uniform(ka, batch + (m, n_tones), minval=0.2, maxval=1.0)
            # [T, *batch, m]
            wave = jnp.sin(2 * jnp.pi * freqs[None] * t.reshape((-1,) + (1,) * (len(batch) + 2))
                           + phases[None])
            u = (amps[None] * wave).sum(-1) * scale
            return u
        if self.spec.input_kind == "prbs":
            # multi-level PRBS: two-level sequences make u^2 collinear with
            # {1, u} in the polynomial library (unidentifiable); four levels
            # keep every monomial of u linearly independent.
            hold = 20
            n_seg = horizon // hold + 1
            levels = jax.random.choice(
                key, jnp.asarray([0.0, 1.0 / 3.0, 2.0 / 3.0, 1.0]),
                (n_seg,) + batch + (m,))
            u = jnp.repeat(levels, hold, axis=0)[:horizon] * scale
            return u
        return jnp.zeros((horizon,) + batch + (m,))
