"""Near-hover quadrotor roll axis with lateral drift (scenario-zoo system).

A planar reduction of the standard quadrotor attitude model around hover:
roll angle phi, roll rate p, and the lateral velocity the tilt induces.
Differential thrust is the single input; rotor drag gives the linear rate
damping and blade flapping the cubic term that caps aggressive maneuvers:

    dphi/dt = p
    dp/dt   = tau*u - d1*p - d3*p^3      (actuation, drag, flapping)
    dvy/dt  = g*phi - c*vy               (tilt accelerates, drag bleeds)

Order-3 polynomial and the same (n=3, m=1) shape as the F-8, so a mixed
F-8/quadrotor fleet shares fused-call shapes shard to shard.  Near hover
the model is identifiable from a sum-of-sines excitation; the documented
domain (spec.y0_low/high) keeps |p| small enough that the cubic term
stabilizes rather than departs.
"""
from __future__ import annotations

from repro.systems.base import DynamicalSystem, SystemSpec


class Quadrotor(DynamicalSystem):
    def __init__(self, tau=8.0, d1=0.6, d3=0.4, g=9.81, c=0.35):
        self.p = (tau, d1, d3, g, c)
        self.spec = SystemSpec(
            name="quadrotor", n=3, m=1, order=3,
            dt=0.01, horizon=500,
            y0_low=(-0.3, -0.5, -0.5), y0_high=(0.3, 0.5, 0.5),
            input_kind="sum_of_sines", input_scale=0.4,
        )

    def rows(self):
        tau, d1, d3, g, c = self.p
        return [
            {"y1": 1.0},
            {"u0": tau, "y1": -d1, "y1*y1*y1": -d3},
            {"y0": g, "y2": -c},
        ]
