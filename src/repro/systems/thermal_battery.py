"""Two-lump battery thermal model (scenario-zoo system).

The classic core/surface lumped-capacitance model for a cylindrical cell,
with temperatures expressed as DEVIATIONS from ambient (so the origin is
the thermal equilibrium and the polynomial library needs no constant
term).  Joule heating scales with current squared — the one nonlinearity:

    dTc/dt = q*u^2 - k1*(Tc - Ts)        (I^2*R heating, core->surface)
    dTs/dt = k1*(Tc - Ts) - k2*Ts        (conduction in, convection out)

Order-2 polynomial with a pure-input quadratic term (`u0*u0`) — the only
zoo system exercising that library column, which is exactly why it earns
its slot: a twin fleet mixing flight dynamics with thermal management is
the paper's "mission critical" setting (battery runaway is an ALERT).
The what-if question writes itself: "what if this cell pulls 2x current
for the next minute?"
"""
from __future__ import annotations

from repro.systems.base import DynamicalSystem, SystemSpec


class ThermalBattery(DynamicalSystem):
    def __init__(self, q=1.8, k1=0.9, k2=0.5):
        self.p = (q, k1, k2)
        self.spec = SystemSpec(
            name="thermal_battery", n=2, m=1, order=2,
            dt=0.05, horizon=500,
            y0_low=(0.0, 0.0), y0_high=(8.0, 4.0),
            input_kind="prbs", input_scale=1.0,
        )

    def rows(self):
        q, k1, k2 = self.p
        return [
            {"u0*u0": q, "y0": -k1, "y1": k1},
            {"y0": k1, "y1": -(k1 + k2)},
        ]
