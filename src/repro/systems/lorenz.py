"""Chaotic Lorenz system (paper Table I, row 2).

dy0/dt = sigma*(y1 - y0)
dy1/dt = y0*(rho - y2) - y1
dy2/dt = y0*y1 - beta*y2
"""
from __future__ import annotations

from repro.systems.base import DynamicalSystem, SystemSpec


class Lorenz(DynamicalSystem):
    def __init__(self, sigma=10.0, rho=28.0, beta=8.0 / 3.0):
        self.sigma, self.rho, self.beta = sigma, rho, beta
        self.spec = SystemSpec(
            name="lorenz", n=3, m=0, order=2,
            dt=0.005, horizon=800,
            y0_low=(-10.0, -10.0, 15.0), y0_high=(10.0, 10.0, 35.0),
            input_kind="none",
        )

    def rows(self):
        return [
            {"y0": -self.sigma, "y1": self.sigma},
            {"y0": self.rho, "y0*y2": -1.0, "y1": -1.0},
            {"y0*y1": 1.0, "y2": -self.beta},
        ]
