"""Pathogenic attack system (paper Table I, row 4).

The paper cites the SINDy-MPC benchmark suite; its infection-dynamics example
is a pathogen/immune-response model under treatment input.  The paper prints
no equations, so we use a sparse polynomial pathogen-immune-treatment model
(documented adaptation, DESIGN.md §10):

dP/dt = r*P - c*P*I - g*P*u     (pathogen: growth, immune kill, drug kill)
dI/dt = a*P*I - d*I + s*u       (immune cells: stimulated by pathogen load,
                                 natural death, boosted by treatment)

Order-2 polynomial, identifiable, stiff enough to be a meaningful 4th
benchmark (its Table I errors are an order of magnitude above Lotka-Volterra,
consistent with a fast-growth system).
"""
from __future__ import annotations

from repro.systems.base import DynamicalSystem, SystemSpec


class PathogenicAttack(DynamicalSystem):
    def __init__(self, r=1.2, c=0.45, g=0.6, a=0.25, d=0.35, s=0.4):
        self.p = (r, c, g, a, d, s)
        self.spec = SystemSpec(
            name="pathogenic_attack", n=2, m=1, order=2,
            dt=0.02, horizon=500,
            y0_low=(1.0, 0.5), y0_high=(6.0, 3.0),
            input_kind="prbs", input_scale=0.8,
        )

    def rows(self):
        r, c, g, a, d, s = self.p
        return [
            {"y0": r, "y0*y1": -c, "u0*y0": -g},
            {"y0*y1": a, "y1": -d, "u0": s},
        ]
