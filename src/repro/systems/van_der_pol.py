"""Forced Van der Pol oscillator (scenario diversity: limit-cycle dynamics).

The classic self-excited oscillator with nonlinear damping, plus an external
forcing input — a regime none of the other benchmarks cover (Lotka-Volterra
is conservative-cyclic, Lorenz chaotic, F-8 a stabilized aircraft, pathogen
monotone).  The limit cycle makes it a good online-twinning stress case: the
state revisits the same orbit, so telemetry windows are highly correlated and
identifiability leans on the forcing input.

  dy0/dt = y1
  dy1/dt = mu*(1 - y0^2)*y1 - y0 + u
         = mu*y1 - mu*y0^2*y1 - y0 + u

Order 3 (the y0^2*y1 damping term), n=2 states, m=1 forcing input
(`sum_of_sines`, the paper's excitation for the F-8).
"""
from __future__ import annotations

from repro.systems.base import DynamicalSystem, SystemSpec


class VanDerPol(DynamicalSystem):
    def __init__(self, mu: float = 1.5):
        self.mu = mu
        self.spec = SystemSpec(
            name="van_der_pol", n=2, m=1, order=3,
            dt=0.02, horizon=600,
            y0_low=(-2.0, -2.0), y0_high=(2.0, 2.0),
            input_kind="sum_of_sines", input_scale=0.8,
        )

    def rows(self):
        return [
            {"y1": 1.0},
            {"y1": self.mu, "y0*y0*y1": -self.mu, "y0": -1.0, "u0": 1.0},
        ]
