"""Data pipeline: windowing sampled traces into MR training batches.

The paper forms batches of size S_B from temporal traces of (Y, U), yielding a
3D tensor of size S_B x (|Y|+m) x k (we store it window-major as
[S_B, k, |Y|+m] — the layout the GRU scan consumes; the content is identical).

Includes a host-side prefetching iterator with a deadline — the straggler-
mitigation hook used by the distributed trainer (a late batch is replaced by
the next ready one rather than stalling the step; see
distributed/fault_tolerance.py).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["WindowDataset", "make_windows", "PrefetchIterator",
           "BackgroundPump", "ring_latest", "make_ring_windows"]


def make_windows(ys: jnp.ndarray, us: jnp.ndarray, window: int,
                 stride: int | None = None):
    """Slice a trace (or batch of traces) into overlapping windows.

    ys: [T+1, n] or [B, T+1, n]; us: [T, m] or [B, T, m].
    Returns (y_win [N, k, n], u_win [N, k, m]) with k = window; each window's
    u_win[t] is the input held during ys step t -> t+1, so integrating the
    recovered model from y_win[:, 0] with u_win reproduces y_win.
    """
    if ys.ndim == 2:
        ys, us = ys[None], us[None]
    stride = stride or max(1, window // 2)
    B, Tp1, n = ys.shape
    m = us.shape[-1]
    T = Tp1 - 1
    starts = np.arange(0, T - window + 1, stride)
    N = len(starts)
    y_win = jnp.stack([ys[:, s:s + window + 1] for s in starts], 1)   # [B,N,k+1,n]
    u_win = jnp.stack([us[:, s:s + window] for s in starts], 1)       # [B,N,k,m]
    y_win = y_win.reshape(B * N, window + 1, n)
    u_win = u_win.reshape(B * N, window, m)
    return y_win, u_win


def ring_latest(ring_y: jnp.ndarray, ring_u: jnp.ndarray, count: jnp.ndarray,
                slots: jnp.ndarray, length: int):
    """Gather the newest `length+1` samples per ring slot, in time order.

    The online path (twin/stream.py) stores telemetry in fixed-capacity ring
    buffers; this unrolls the ring back into the chronological layout
    `make_windows` consumes, entirely with gathers (jit-safe, no host sync).

    ring_y: [S, cap, n], ring_u: [S, cap, m] — per-slot rings where sample i
      of slot s lives at column i % cap; count: [S] total samples written.
    slots: [B] int32 rows to extract.  Requires count[slots] >= length+1
      (caller-checked; earlier columns are stale/zero otherwise).
    Returns (ys [B, length+1, n], us [B, length, m]) where us[t] is the input
    held during ys step t -> t+1 (the `make_windows` alignment).
    """
    cap = ring_y.shape[1]
    end = count[slots]                                           # [B]
    idx = (end[:, None] + jnp.arange(length + 1)[None, :]
           - (length + 1)) % cap                                 # [B, length+1]
    rows = jnp.broadcast_to(slots[:, None], idx.shape)
    ys = ring_y[rows, idx]
    us = ring_u[rows[:, :-1], idx[:, :-1]]
    return ys, us


def make_ring_windows(ring_y, ring_u, count, slots, *, window: int,
                      stride: int | None = None, length: int):
    """Sliding windows over the newest `length` ring steps, grouped per slot.

    Returns (y_win [B, N, k+1, n], u_win [B, N, k, m]) with k = window and
    N = (length - window)//stride + 1 — bitwise identical to running
    `make_windows` on the chronological trace of each slot.
    """
    ys, us = ring_latest(ring_y, ring_u, count, slots, length)
    y_win, u_win = make_windows(ys, us, window, stride)
    B = ys.shape[0]
    N = y_win.shape[0] // B
    return (y_win.reshape(B, N, window + 1, ys.shape[-1]),
            u_win.reshape(B, N, window, us.shape[-1]))


@dataclass
class WindowDataset:
    """In-memory windowed dataset with shuffled minibatch iteration."""
    y_win: jnp.ndarray   # [N, k+1, n]  (k+1 so targets include the full window)
    u_win: jnp.ndarray   # [N, k, m]
    dt: float

    @property
    def n_windows(self) -> int:
        return int(self.y_win.shape[0])

    def norm_stats(self):
        """Per-channel (mu, sigma) over [Y ; U] — feeds Merinda.init."""
        xs = jnp.concatenate([self.y_win[:, :-1, :], self.u_win], axis=-1)
        mu = xs.mean(axis=(0, 1))
        sigma = xs.std(axis=(0, 1)) + 1e-6
        return mu, sigma

    def batches(self, key, batch_size: int, *, epochs: int = 1,
                drop_remainder: bool = True) -> Iterator[tuple]:
        n = self.n_windows
        steps = n // batch_size if drop_remainder else -(-n // batch_size)
        for _ in range(epochs):
            key, sub = jax.random.split(key)
            perm = jax.random.permutation(sub, n)
            for s in range(steps):
                idx = perm[s * batch_size:(s + 1) * batch_size]
                yield self.y_win[idx], self.u_win[idx]

    @staticmethod
    def from_trace(ys, us, dt, window: int, stride: int | None = None,
                   normalize: bool = False):
        y_win, u_win = make_windows(ys, us, window, stride)
        return WindowDataset(y_win=y_win, u_win=u_win, dt=dt)


class BackgroundPump:
    """Event-driven background producer feeding a bounded handoff queue.

    The PrefetchIterator pattern generalized from iterators to swap-based
    producers: a consumer `kick()`s the pump whenever new source material
    exists; the worker thread calls `produce()` (which should atomically take
    the source's current contents — a double-buffer swap) and parks the result
    in a depth-bounded queue.  `queue.put` on a full queue is the
    backpressure: with depth=2 the worker prepares one batch while the
    consumer applies another, and coalesces further kicks until a slot frees.

    Used by twin/server.py to move the host-side telemetry staging flush off
    the serving tick: `produce` swaps the staging buffer and does the numpy
    merge/pad work; the tick thread `drain()`s prepared batches and issues
    the (single-threaded) device scatters.

    `produce` returning None (nothing staged) enqueues nothing.  `idle()` is
    True once every kick issued so far has been fully processed — the drain
    barrier used to guarantee no sample is left in flight.

    A `produce()` exception does NOT kill the worker silently: the error is
    captured in `self.error`, the kick is marked served (so `idle()` and the
    drain barrier cannot deadlock on a dead producer), and the next `drain()`
    re-raises it on the consumer thread where it can be handled.
    """

    def __init__(self, produce, depth: int = 2):
        self._produce = produce
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._event = threading.Event()
        self._lock = threading.Lock()
        self._kicks = 0          # kicks issued
        self._served = 0         # kicks whose produce() has fully completed
        self._stop = False
        self.error: BaseException | None = None   # first produce() failure
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def kick(self) -> None:
        with self._lock:
            self._kicks += 1
        self._event.set()

    def _run(self) -> None:
        while True:
            self._event.wait()
            if self._stop:
                return
            # clear BEFORE reading the kick counter: a kick landing after the
            # clear re-sets the event (extra wakeup, harmless); the reverse
            # order would clear a fresh kick's wakeup and strand idle()
            self._event.clear()
            with self._lock:
                target = self._kicks
            try:
                item = self._produce()
            except BaseException as e:    # noqa: BLE001 — surfaced via drain
                with self._lock:
                    if self.error is None:
                        self.error = e
                    self._served = target    # keep idle()/drain barrier live
                continue
            if item is not None:
                self._q.put(item)     # blocks when full: backpressure
            with self._lock:
                self._served = target
            if self._stop:
                return

    def drain(self) -> list:
        """Non-blocking: every batch the worker has parked so far.  Re-raises
        a captured `produce()` failure (after handing over any batches that
        completed before it) so producer errors surface on the consumer."""
        out = []
        while True:
            try:
                out.append(self._q.get_nowait())
            except queue.Empty:
                break
        with self._lock:
            err, self.error = self.error, None
        if err is not None:
            raise err
        return out

    def idle(self) -> bool:
        """True when no kick is pending or mid-produce (queued batches may
        still await drain())."""
        with self._lock:
            return self._served >= self._kicks

    def queue_depth(self) -> int:
        """Prepared batches parked and awaiting drain() — the handoff-queue
        gauge (`twin_pump_queue_depth`): pinned at `depth` means the consumer
        (serving tick) is the bottleneck, 0 means the producer is."""
        return self._q.qsize()

    def close(self) -> None:
        self._stop = True
        self._event.set()
        try:
            self.drain()          # unblock a worker parked on a full queue
        except BaseException:     # noqa: BLE001 — shutdown must not raise
            pass
        self._thread.join(timeout=5.0)


class PrefetchIterator:
    """Background-thread prefetcher with a per-batch deadline.

    If the producer misses `deadline_s` for a batch, the consumer records a
    straggler event and keeps waiting only until the next batch is ready —
    production behaviour is to surface the count so the trainer can switch to
    stale-gradient mode (distributed/fault_tolerance.py).
    """

    def __init__(self, it: Iterator, depth: int = 2, deadline_s: float = 5.0):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._deadline = deadline_s
        self.straggler_events = 0
        self._done = object()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        try:
            item = self._q.get(timeout=self._deadline)
        except queue.Empty:
            self.straggler_events += 1
            item = self._q.get()   # block until ready
        if item is self._done:
            raise StopIteration
        return item
