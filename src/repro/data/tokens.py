"""Synthetic LM data pipeline: deterministic, seekable token streams.

Sampling is Zipf-distributed over the vocab with a deterministic
order-2 Markov mix so the LM loss actually decreases (pure uniform tokens
have no learnable structure).  `TokenStream.batches(step)` is addressable by
step — a resumed run re-produces the exact batch sequence (required for the
bit-exact restart test).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["TokenStream"]


@dataclass
class TokenStream:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    d_frontend: int | None = None     # whisper: also emit frame embeddings

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng((self.seed, step))

    def batch_at(self, step: int) -> dict:
        rng = self._rng(step)
        # zipf over a capped vocab for realistic token frequencies
        z = rng.zipf(1.3, size=(self.batch, self.seq_len)).astype(np.int64)
        tokens = (z - 1) % self.vocab
        # inject learnable structure: token[t] ~ (token[t-1] * 31 + 7) for a
        # third of positions.
        follow = (tokens[:, :-1] * 31 + 7) % self.vocab
        mask = rng.random((self.batch, self.seq_len - 1)) < 0.33
        tokens[:, 1:] = np.where(mask, follow, tokens[:, 1:])
        out = {"tokens": tokens.astype(np.int32)}
        if self.d_frontend:
            out["enc_x"] = rng.standard_normal(
                (self.batch, self.seq_len, self.d_frontend)
            ).astype(np.float32) * 0.1
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def iter_from(self, step: int):
        while True:
            yield self.batch_at(step)
            step += 1
