import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first init, and the multi-pod dry-run needs 512 host devices.
# (Everything else in the repo sees the real single CPU device.)

import argparse      # noqa: E402
import gzip          # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402

from repro.configs import SHAPES, get_arch, list_archs            # noqa: E402
from repro.distributed.sharding import ShardingRules              # noqa: E402
from repro.launch.cells import build_cell, lower_cell             # noqa: E402
from repro.launch.hlo_analysis import roofline_terms              # noqa: E402
from repro.launch.hlo_walk import walk_hlo                        # noqa: E402
from repro.launch.mesh import HW, make_production_mesh            # noqa: E402

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) cell this lowers + compiles the exact
production program on the 16x16 single-pod mesh AND the 2x16x16 multi-pod
mesh, prints memory_analysis() (proves it fits 16 GB/chip) and
cost_analysis() (FLOPs/bytes for the roofline), parses the partitioned HLO
for collective wire bytes, and writes one JSON per cell under
artifacts/dryrun/.  launch/roofline.py renders the EXPERIMENTS.md tables
from those JSONs.
"""


def _model_flops(cell, shape) -> float:
    """MODEL_FLOPS convention: 6*N*D train, 2*N*D inference (N = active
    params for MoE); attention flops excluded (recorded convention)."""
    n = cell.n_active_params
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # decode: one token/sample


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: Path, save_hlo: bool = False,
             grad_accum: int | None = None,
             cfg_overrides: dict | None = None, tag_suffix: str = "") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = ShardingRules(mesh=mesh)
    n_dev = mesh.size
    t0 = time.time()
    cell = build_cell(arch, shape_name, rules, grad_accum=grad_accum,
                      cfg_overrides=cfg_overrides)
    lowered, compiled = lower_cell(cell, rules)
    compile_s = time.time() - t0

    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
    }
    mem["total_bytes"] = (mem["argument_bytes"] + mem["temp_bytes"]
                          + mem["output_bytes"] - mem["alias_bytes"])
    mem["fits_hbm"] = bool(mem["total_bytes"] <= HW.HBM_BYTES)

    cost = compiled.cost_analysis()

    # Trip-count-aware walk: XLA's cost_analysis counts while bodies once,
    # which undercounts scanned layers/microbatches ~100x (see hlo_walk.py).
    hlo = compiled.as_text()
    walk = walk_hlo(hlo)
    shape = SHAPES[shape_name]
    terms = roofline_terms(
        flops=walk.flops, bytes_accessed=walk.bytes,
        wire_bytes=walk.wire_bytes,
        model_flops_per_device=_model_flops(cell, shape) / n_dev,
        peak_flops=HW.PEAK_BF16_FLOPS, hbm_bw=HW.HBM_BW, ici_bw=HW.ICI_BW)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "n_devices": n_dev,
        "compile_s": round(compile_s, 1),
        "n_params": cell.n_params, "n_active_params": cell.n_active_params,
        "memory": mem,
        "cost": {"flops": walk.flops, "bytes_accessed": walk.bytes,
                 # raw XLA numbers kept for cross-checking (count while
                 # bodies once):
                 "xla_flops_once": float(cost.get("flops", 0.0)),
                 "xla_bytes_once": float(cost.get("bytes accessed", 0.0))},
        "collectives": {"per_op": walk.coll_per_op,
                        "total_wire_bytes": walk.wire_bytes},
        "whiles": sorted(walk.while_breakdown,
                         key=lambda w: -w["flops"])[:12],
        "warnings": walk.warnings[:10],
        "roofline": terms,
        "status": "ok",
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"{arch}_{shape_name}_{rec['mesh']}{tag_suffix}"
    (out_dir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
    if save_hlo:
        with gzip.open(out_dir / f"{tag}.hlo.gz", "wt") as f:
            f.write(hlo)
    print(f"[dryrun] {tag}: compile {compile_s:.0f}s, "
          f"mem/dev {mem['total_bytes'] / 2**30:.2f} GiB "
          f"(fits={mem['fits_hbm']}), flops/dev {walk.flops:.3e}, "
          f"wire {walk.wire_bytes / 2**20:.1f} MiB, "
          f"dominant={terms['dominant']}, "
          f"roofline_frac={terms['roofline_fraction']:.3f}", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES), help="shape (default: all)")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--fail-fast", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=None,
                    help="override grad accumulation (perf experiments)")
    ap.add_argument("--cfg", action="append", default=[],
                    help="config override key=value (perf experiments)")
    ap.add_argument("--tag", default="",
                    help="suffix for the output JSON (perf experiments)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.cfg:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v

    assert len(jax.devices()) == 512, "dry-run needs 512 host devices"
    out_dir = Path(args.out)
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        spec = get_arch(arch)
        for shape_name in shapes:
            if shape_name in spec.skip_shapes:
                print(f"[dryrun] SKIP {arch} x {shape_name}: "
                      f"{spec.skip_shapes[shape_name][:80]}...", flush=True)
                continue
            for mp in meshes:
                try:
                    run_cell(arch, shape_name, multi_pod=mp,
                             out_dir=out_dir, save_hlo=args.save_hlo,
                             grad_accum=args.grad_accum,
                             cfg_overrides=overrides or None,
                             tag_suffix=args.tag)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape_name, mp, repr(e)))
                    print(f"[dryrun] FAIL {arch} x {shape_name} "
                          f"(multi_pod={mp}): {e}", flush=True)
                    traceback.print_exc()
                    if args.fail_fast:
                        raise
    if failures:
        print(f"[dryrun] {len(failures)} failures:")
        for f in failures:
            print("   ", *f)
        raise SystemExit(1)
    print("[dryrun] all requested cells passed.")


if __name__ == "__main__":
    main()
