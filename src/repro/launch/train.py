"""End-to-end training driver.

Two modes:
  * --merinda <system>: the paper's pipeline — train a MERINDA digital twin
    (or a fleet) on simulated traces of lotka_volterra / lorenz /
    f8_crusader / pathogen, with checkpoint/restart.
  * --arch <id> [--smoke]: LM training on the synthetic token stream.
    --smoke uses the reduced config on CPU (the runnable path in this
    container); the full config is exercised through launch/dryrun.py.

Examples:
  PYTHONPATH=src python -m repro.launch.train --merinda f8_crusader --steps 300
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke --steps 30
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_arch
from repro.data.tokens import TokenStream
from repro.distributed.compression import topk_compressor
from repro.distributed.fault_tolerance import FailureInjector
from repro.models.zoo import build
from repro.train.loop import LoopConfig, run_loop
from repro.train.optimizer import adamw, cosine_schedule
from repro.train.train_state import init_state, make_train_step


def train_lm(args) -> None:
    spec = get_arch(args.arch)
    cfg = spec.smoke if args.smoke else spec.config
    api = build(cfg, max_position=args.seq_len)
    key = jax.random.PRNGKey(args.seed)
    params = api.init(key)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"[train] {cfg.name}{' (smoke)' if args.smoke else ''}: "
          f"{n_params:,} params")

    opt = adamw(lr=cosine_schedule(args.lr, 10, args.steps), weight_decay=0.1)
    compressor = (topk_compressor(args.compress) if args.compress else None)
    step_fn = jax.jit(make_train_step(api.loss, opt,
                                      grad_accum=args.grad_accum,
                                      compressor=compressor))
    state = init_state(params, opt)
    if compressor is not None:
        state["comp"] = compressor.init(params)

    stream = TokenStream(vocab=cfg.vocab, batch=args.batch,
                         seq_len=args.seq_len, seed=args.seed,
                         d_frontend=cfg.d_model if api.is_encdec else None)
    injector = (FailureInjector(fail_at_step=args.fail_at)
                if args.fail_at is not None else None)
    loop_cfg = LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                          ckpt_every=args.ckpt_every, injector=injector)
    state, history = run_loop(step_fn, state, iter(stream), loop_cfg)
    print(f"[train] done: loss {history[0]['loss']:.4f} -> "
          f"{history[-1]['loss']:.4f} over {len(history)} steps")


def train_merinda(args) -> None:
    from repro.core.merinda import Merinda, MerindaConfig
    from repro.core.trainer import fit
    from repro.data.pipeline import WindowDataset
    from repro.systems.simulate import simulate_batch
    from repro.systems.simulate import register_systems

    system = register_systems()[args.merinda]()
    key = jax.random.PRNGKey(args.seed)
    trace = simulate_batch(system, key, batch=8, noise_std=0.01)
    ds = WindowDataset.from_trace(trace.ys_noisy, trace.us,
                                  system.spec.dt, window=args.window)
    true_theta = system.true_theta()
    n_active = int((abs(true_theta) > 0).sum())
    mcfg = MerindaConfig(n=system.spec.n, m=system.spec.m,
                         order=system.spec.order, dt=system.spec.dt,
                         hidden=args.hidden, n_active=n_active)
    model = Merinda(mcfg)
    params = model.init(key, model.norm_stats(ds.y_win, ds.u_win))
    result = fit(model, params,
                 ds.batches(key, args.batch, epochs=10_000),
                 steps=args.steps, lr=args.lr, log_every=50)
    theta = model.recover(result.params, ds.y_win, ds.u_win)
    mse = float(model.reconstruction_mse(theta, ds.y_win, ds.u_win))
    print(f"[train] {args.merinda}: reconstruction MSE {mse:.4f}, "
          f"nan_restarts={result.nan_restarts}")
    print(model.lib.coeff_dict(theta))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--merinda", default=None,
                    help="system id: lotka_volterra|lorenz|f8_crusader|pathogen")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--window", type=int, default=16)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress", type=float, default=None,
                    help="top-k gradient compression keep fraction")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a simulated preemption at this step")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.merinda:
        train_merinda(args)
    elif args.arch:
        train_lm(args)
    else:
        raise SystemExit("pass --arch or --merinda")


if __name__ == "__main__":
    main()
