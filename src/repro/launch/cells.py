"""Cell builders: one lowered program per (arch x shape x mesh).

A *cell* is the unit of the multi-pod dry-run: for a given architecture,
input shape, and mesh this module produces (fn, arg_specs, jit_kwargs) such
that

    jax.jit(fn, **jit_kwargs).lower(*arg_specs).compile()

is the exact program the production launcher would execute:
  * train_*   -> make_train_step(loss, opt, grad_accum) over sharded state
  * prefill_* -> prefill emitting sequence-sharded caches
  * decode_*  -> one-token decode_step against a donated, filled cache

This module is import-safe on one device (no XLA_FLAGS hack; tests lower
cells on small meshes); launch/dryrun.py is the 512-device CLI around it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, ArchSpec, Shape, get_arch
from repro.distributed.sharding import (ShardingRules, axis_rules,
                                        cache_shardings, logical_to_sharding,
                                        param_shardings)
from repro.models.zoo import ModelApi, build
from repro.train.optimizer import adafactor, adamw
from repro.train.train_state import make_train_step, state_specs

__all__ = ["Cell", "build_cell", "lower_cell", "GRAD_ACCUM"]

# Microbatching per arch for train_4k: keeps the live logits microbatch
# ([B/ga, T, V/tp] f32) and MoE dispatch buffers inside HBM (see
# EXPERIMENTS.md §Dry-run for the measured per-device bytes).
GRAD_ACCUM = {
    "gemma3-12b": 16,       # 262k vocab
    "qwen3-8b": 8,          # 152k vocab
    "chameleon-34b": 16,    # d_model 8192: layer-scan residual stack
    "arctic-480b": 16,      # 1.9B params/chip at 256 chips: see EXPERIMENTS
                            # (32 was tried: -2 GiB memory but 3.8x wire —
                            # refuted; §Perf)
    "mixtral-8x22b": 16,    # 56 layers x d 6144 residual stack
    "starcoder2-15b": 8,    # d 6144 residual stack (40L)
    "whisper-large-v3": 4,
    "default": 4,
}

# Adafactor where AdamW's 8 bytes/param of moments cannot fit 16 GB/chip.
ADAFACTOR_ARCHS = {"arctic-480b"}

# Sequence-shard K/V during training (ring-attention-style): K/V heads (8)
# cannot split over model=16, and the flash tiles + expert buffers leave no
# headroom for replicated KV at 56 layers.  Costs ~10% wire; measured in
# §Perf (mixtral hillclimb).
SEQ_KV_ARCHS = {"mixtral-8x22b"}


@dataclass
class Cell:
    arch: str
    shape: Shape
    fn: Callable
    arg_specs: tuple
    in_shardings: Any
    out_shardings: Any
    donate_argnums: tuple
    api: ModelApi
    n_params: int
    n_active_params: int
    rules: ShardingRules | None = None   # per-cell act-rule overrides


def _count_params(specs) -> tuple[int, int]:
    """(total, active) param counts; MoE experts count top_k/E as active."""
    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(specs)[0]:
        names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        n = int(np.prod(leaf.shape))
        total += n
        if "experts" in names:
            continue  # added below at active ratio
        active += n
    return total, active


def _moe_active(api: ModelApi, total: int, dense_active: int) -> int:
    cfg = api.cfg
    if not cfg.n_experts:
        return dense_active
    expert_total = total - dense_active
    return dense_active + expert_total * cfg.top_k // cfg.n_experts


def _batch_shardings(rules, batch_specs):
    def spec_of(leaf):
        nd = leaf.ndim
        return logical_to_sharding(P(("pod", "data"), *([None] * (nd - 1))),
                                   rules.mesh, leaf.shape)
    return jax.tree.map(spec_of, batch_specs)


def _opt_for(arch: str, lr: float = 1e-4):
    if arch in ADAFACTOR_ARCHS:
        return adafactor(lr=lr)
    return adamw(lr=lr, weight_decay=0.1)


def build_cell(arch: str, shape_name: str, rules: ShardingRules,
               *, grad_accum: int | None = None,
               cfg_overrides: dict | None = None) -> Cell:
    spec: ArchSpec = get_arch(arch)
    shape = SHAPES[shape_name]
    if shape_name in spec.skip_shapes:
        raise ValueError(f"{arch} skips {shape_name}: "
                         f"{spec.skip_shapes[shape_name]}")
    cfg = spec.config
    if cfg_overrides:
        cfg = cfg.with_(**cfg_overrides)
    if arch in SEQ_KV_ARCHS:
        act = dict(rules.act)
        act["kv_bt"] = P(("pod", "data"), "model", None, None)
        rules = ShardingRules(mesh=rules.mesh, act=act, params=rules.params)
    B, T = shape.global_batch, shape.seq_len
    api = build(cfg, max_position=T)
    p_specs = api.param_specs()
    p_shard = param_shardings(rules, p_specs)
    total, dense_active = _count_params(p_specs)
    active = _moe_active(api, total, dense_active)

    if shape.kind == "train":
        ga = grad_accum or GRAD_ACCUM.get(arch, GRAD_ACCUM["default"])
        opt = _opt_for(arch)
        # arctic: 1.9B params/chip — the f32 accumulation tree alone is
        # 7.4 GiB/device; accumulate in bf16 (EXPERIMENTS.md §Dry-run it. 7).
        accum_dtype = (jnp.bfloat16 if arch in ADAFACTOR_ARCHS
                       else jnp.float32)
        fn = make_train_step(api.loss, opt, grad_accum=ga,
                             accum_dtype=accum_dtype)
        s_specs = state_specs(p_specs, opt)
        s_shard = param_shardings(rules, s_specs)
        b_specs = api.batch_specs(B, T)
        b_shard = _batch_shardings(rules, b_specs)
        return Cell(arch, shape, fn, (s_specs, b_specs),
                    (s_shard, b_shard), (s_shard, None), (0,), api,
                    total, active, rules)

    if shape.kind == "prefill":
        def fn(params, batch):
            return api.prefill(params, batch, T)

        b_specs = api.batch_specs(B, T)
        b_shard = _batch_shardings(rules, b_specs)
        c_specs = api.cache_specs(B, T)
        c_shard = cache_shardings(rules, c_specs, batch=B)
        logits_shard = logical_to_sharding(
            P(("pod", "data"), "model"), rules.mesh, (B, cfg.vocab))
        return Cell(arch, shape, fn, (p_specs, b_specs),
                    (p_shard, b_shard), (c_shard, logits_shard), (), api,
                    total, active, rules)

    # decode: one new token against a cache of seq_len.
    def fn(params, cache, tokens1):
        return api.decode(params, cache, tokens1)

    c_specs = api.cache_specs(B, T)
    c_shard = cache_shardings(rules, c_specs, batch=B)
    t_specs = jax.ShapeDtypeStruct((B,), np.int32)
    t_shard = logical_to_sharding(P(("pod", "data")), rules.mesh, (B,))
    logits_shard = logical_to_sharding(
        P(("pod", "data"), "model"), rules.mesh, (B, cfg.vocab))
    return Cell(arch, shape, fn, (p_specs, c_specs, t_specs),
                (p_shard, c_shard, t_shard), (c_shard, logits_shard), (1,),
                api, total, active, rules)


def lower_cell(cell: Cell, rules: ShardingRules):
    """Lower + compile under the mesh; returns (lowered, compiled)."""
    rules = cell.rules or rules
    with rules.mesh, axis_rules(rules):
        jitted = jax.jit(cell.fn,
                         in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate_argnums)
        lowered = jitted.lower(*cell.arg_specs)
        compiled = lowered.compile()
    return lowered, compiled
