"""Roofline report (deliverable g): renders EXPERIMENTS.md tables from the
dry-run JSONs in artifacts/dryrun/.

Usage:  PYTHONPATH=src python -m repro.launch.roofline [--dir artifacts/dryrun]
Writes artifacts/roofline.md (single-pod table per the assignment; multi-pod
cells are listed in the dry-run pass/fail summary).
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

__all__ = ["load_records", "render_table", "main"]


def load_records(d: Path) -> list[dict]:
    recs = [json.loads(p.read_text()) for p in sorted(d.glob("*.json"))]
    return sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"]))


def _fmt_t(s: float) -> str:
    if s >= 1.0:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.2f}ms"
    return f"{s * 1e6:.1f}us"


def render_table(recs: list[dict], mesh: str = "16x16") -> str:
    rows = [r for r in recs if r["mesh"] == mesh]
    out = [
        f"| arch | shape | compute | memory | collective | dominant | "
        f"GiB/dev | fits | MODEL/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        t = r["roofline"]
        m = r["memory"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_t(t['compute_s'])} | "
            f"{_fmt_t(t['memory_s'])} | {_fmt_t(t['collective_s'])} | "
            f"{t['dominant'].replace('_s', '')} | "
            f"{m['total_bytes'] / 2**30:.2f} | "
            f"{'yes' if m['fits_hbm'] else 'NO'} | "
            f"{t['useful_flop_ratio']:.3f} | "
            f"{t['roofline_fraction']:.3f} |")
    return "\n".join(out)


def render_summary(recs: list[dict]) -> str:
    """Pass/fail matrix over meshes (the multi-pod proof)."""
    cells: dict[tuple, set] = {}
    for r in recs:
        cells.setdefault((r["arch"], r["shape"]), set()).add(r["mesh"])
    out = ["| arch | shape | 16x16 | 2x16x16 |", "|---|---|---|---|"]
    for (a, s), meshes in sorted(cells.items()):
        out.append(f"| {a} | {s} | "
                   f"{'pass' if '16x16' in meshes else '—'} | "
                   f"{'pass' if '2x16x16' in meshes else '—'} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--out", default="artifacts/roofline.md")
    args = ap.parse_args()
    recs = load_records(Path(args.dir))
    doc = ["# Roofline table (single-pod 16x16, per-device terms)", "",
           render_table(recs, "16x16"), "",
           "# Multi-pod pass matrix", "", render_summary(recs), ""]
    Path(args.out).write_text("\n".join(doc))
    print("\n".join(doc))


if __name__ == "__main__":
    main()
