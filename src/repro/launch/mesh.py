"""Production mesh builders.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — smoke tests and benches must keep seeing 1 CPU
device; only launch/dryrun.py forces 512 host devices.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: 16x16 (256 chips) per pod; the multi-pod
    variant prepends a 2-pod axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))


class HW:
    """TPU v5e roofline constants (per chip)."""
    PEAK_BF16_FLOPS = 197e12        # 197 TFLOP/s bf16
    HBM_BW = 819e9                  # 819 GB/s
    ICI_BW = 50e9                   # ~50 GB/s per link
    HBM_BYTES = 16 * 1024 ** 3      # 16 GB
    VMEM_BYTES = 128 * 1024 ** 2    # ~128 MB VMEM
