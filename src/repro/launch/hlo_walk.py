"""Trip-count-aware HLO cost walker.

XLA's `compiled.cost_analysis()` counts every `while` body ONCE — with
scan-over-layers + grad-accumulation scans that undercounts FLOPs,
bytes, and collective traffic by the product of trip counts (~100x at the
assigned shapes).  This walker re-derives the three roofline inputs from the
partitioned HLO text, multiplying every while body by its
`known_trip_count` annotation (present on all jax-emitted scans; fallback:
the loop-condition compare constant, else 1 with a warning).

Accounting conventions (recorded in EXPERIMENTS.md):
  * flops: dots = 2*M*N*K from real operand shapes; elementwise /
    transcendental ops = 1 flop per output element (inside fusions too).
  * bytes: HBM traffic = operand+output bytes of every instruction at
    "traffic level" (ENTRY, while/conditional bodies) — fusions count their
    call-site I/O only, internal ops are register traffic.
  * collectives: ring-algorithm wire bytes per device (see hlo_analysis).
  * per-while breakdown kept for §Perf drill-downs.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.launch.hlo_analysis import _DTYPE_BYTES, _WIRE, _group_size

__all__ = ["walk_hlo", "HloCost"]

_HDR_RE = re.compile(r"^(ENTRY\s+)?%?(?P<name>[\w.\-]+)\s*\(")
_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?%(?P<name>[\w.\-]+)\s*=\s*"
    r"(?P<shape>\([^)]*\)|[a-zA-Z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(?P<op>[\w\-]+)\((?P<operands>[^)]*)\)(?P<rest>.*)$")
_TRIP_RE = re.compile(r'known_trip_count[^{]*\{\s*"n"\s*:\s*"(\d+)"')
_CALLED_RE = {
    "calls": re.compile(r"calls=%?([\w.\-]+)"),
    "body": re.compile(r"body=%?([\w.\-]+)"),
    "condition": re.compile(r"condition=%?([\w.\-]+)"),
    "branches": re.compile(r"branch_computations=\{([^}]*)\}"),
    "true": re.compile(r"true_computation=%?([\w.\-]+)"),
    "false": re.compile(r"false_computation=%?([\w.\-]+)"),
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "logistic", "rsqrt", "sqrt", "cbrt", "power", "compare",
    "select", "and", "or", "xor", "not", "sign", "floor", "ceil", "round",
    "clamp", "atan2", "sine", "cosine", "remainder", "convert", "erf",
}
_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
    # control flow: carried buffers are aliased in place; the real traffic
    # is counted inside the bodies (slices/updates at trip multiplicity).
    "while", "conditional", "call", "optimization-barrier",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "ragged-all-to-all", "collective-permute")


def _shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    elems = total = 0
    for m in re.finditer(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dtype]
    return elems, total


def _shape_dims(shape_str: str) -> list[int]:
    m = re.search(r"[a-z0-9]+\[([0-9,]*)\]", shape_str)
    if not m or not m.group(1):
        return []
    return [int(d) for d in m.group(1).split(",")]


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    wire_bytes: float = 0.0
    coll_per_op: dict = field(default_factory=dict)
    while_breakdown: list = field(default_factory=list)
    warnings: list = field(default_factory=list)

    def add(self, other: "HloCost", mult: float = 1.0, with_bytes=True):
        self.flops += mult * other.flops
        if with_bytes:
            self.bytes += mult * other.bytes
        self.wire_bytes += mult * other.wire_bytes
        for k, v in other.coll_per_op.items():
            rec = self.coll_per_op.setdefault(
                k, {"count": 0.0, "result_bytes": 0.0, "wire_bytes": 0.0})
            for f in rec:
                rec[f] += mult * v.get(f, 0.0)


@dataclass
class _Instr:
    name: str
    shape: str
    op: str
    operands: list
    rest: str


def _operand_bytes(ins: _Instr, symtab: dict, i: int) -> int:
    if i >= len(ins.operands):
        return 0
    return _shape_elems_bytes(symtab.get(ins.operands[i], ""))[1]


def _traffic_bytes(ins: _Instr, symtab: dict, comps: dict,
                   out_bytes: int) -> float:
    """Approximate HBM traffic of one traffic-level instruction.

    In-place/slicing ops touch only the slice region, not the whole buffer
    (XLA aliases the carried buffer): counting whole operands would inflate
    the memory term by the stacked-layer/cache factor (~50x measured).
    """
    op = ins.op
    if op == "dynamic-slice" or op == "slice" or op == "gather":
        return 2.0 * out_bytes                       # read slice + write
    if op == "dynamic-update-slice":
        return 2.0 * _operand_bytes(ins, symtab, 1)  # r/m/w of the region
    if op == "scatter":
        return 2.0 * _operand_bytes(ins, symtab, 2)
    if op == "broadcast":
        return float(out_bytes)
    if op == "fusion":
        total = float(out_bytes) + sum(
            _operand_bytes(ins, symtab, i) for i in range(len(ins.operands)))
        # correct for big aliased buffers sliced/updated INSIDE the fusion.
        called = _CALLED_RE["calls"].search(ins.rest)
        if called:
            fsym = None
            for fins in comps.get(called.group(1), []):
                if fins.op in ("dynamic-update-slice", "dynamic-slice"):
                    if fsym is None:
                        fsym = {i2.name: i2.shape
                                for i2 in comps[called.group(1)]}
                    if fins.op == "dynamic-update-slice":
                        full = _shape_elems_bytes(fins.shape)[1]
                        upd = _operand_bytes(fins, fsym, 1)
                        total -= max(0.0, 2.0 * (full - upd))
                    else:
                        buf = _operand_bytes(fins, fsym, 0)
                        sl = _shape_elems_bytes(fins.shape)[1]
                        total -= max(0.0, buf - sl)
        return max(total, 0.0)
    opd = sum(_operand_bytes(ins, symtab, i)
              for i in range(len(ins.operands)))
    return float(out_bytes + opd)


def _parse_computations(text: str) -> dict[str, list[_Instr]]:
    comps: dict[str, list[_Instr]] = {}
    cur: list[_Instr] | None = None
    entry_name = None
    for line in text.splitlines():
        s = line.rstrip()
        if not s:
            continue
        if not s.startswith(" ") and s.endswith("{"):
            m = _HDR_RE.match(s)
            if m:
                cur = []
                comps[m.group("name")] = cur
                if s.startswith("ENTRY"):
                    entry_name = m.group("name")
            continue
        if s == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(s)
        if m:
            ops = [o.strip().lstrip("%") for o in
                   m.group("operands").split(",") if o.strip()]
            cur.append(_Instr(m.group("name"), m.group("shape"),
                              m.group("op"), ops, m.group("rest")))
    comps["__entry__"] = comps.get(entry_name, [])
    return comps


def _dot_flops(ins: _Instr, symtab: dict[str, str]) -> float:
    out_elems, _ = _shape_elems_bytes(ins.shape)
    k = 1
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
    if mc and ins.operands:
        lhs_shape = symtab.get(ins.operands[0])
        if lhs_shape is not None:
            dims = _shape_dims(lhs_shape)
            for ci in mc.group(1).split(","):
                if ci != "" and int(ci) < len(dims):
                    k *= dims[int(ci)]
    return 2.0 * out_elems * k


def walk_hlo(text: str) -> HloCost:
    comps = _parse_computations(text)
    memo: dict[str, HloCost] = {}
    top_warnings: list = []

    def comp_cost(name: str) -> HloCost:
        if name in memo:
            return memo[name]
        memo[name] = HloCost()          # cycle guard (shouldn't happen)
        cost = HloCost()
        instrs = comps.get(name, [])
        symtab = {i.name: i.shape for i in instrs}
        for ins in instrs:
            op = ins.op
            out_elems, out_bytes = _shape_elems_bytes(ins.shape)
            # --- flops ------------------------------------------------- #
            if op == "dot":
                cost.flops += _dot_flops(ins, symtab)
            elif op in _ELEMENTWISE:
                cost.flops += out_elems
            elif op == "reduce" or op == "reduce-window":
                # approx: one op per input element
                in_elems = sum(_shape_elems_bytes(symtab.get(o, ""))[0]
                               for o in ins.operands[:1])
                cost.flops += in_elems
            # --- control flow ------------------------------------------ #
            if op == "while":
                body = _CALLED_RE["body"].search(ins.rest)
                cond = _CALLED_RE["condition"].search(ins.rest)
                trip_m = _TRIP_RE.search(ins.rest)
                trip = int(trip_m.group(1)) if trip_m else None
                if trip is None:
                    trip = 1
                    cost.warnings.append(f"while {ins.name}: no trip count")
                sub = HloCost()
                if body:
                    sub.add(comp_cost(body.group(1)))
                if cond:
                    sub.add(comp_cost(cond.group(1)))
                cost.add(sub, mult=trip)
                cost.while_breakdown.append(
                    {"name": ins.name, "trip": trip,
                     "body": body.group(1) if body else None,
                     "flops": trip * sub.flops,
                     "wire_bytes": trip * sub.wire_bytes})
                cost.while_breakdown.extend(
                    [dict(w) for w in sub.while_breakdown])
            elif op == "fusion":
                called = _CALLED_RE["calls"].search(ins.rest)
                if called:
                    # flops/collectives from inside; bytes = call-site I/O.
                    cost.add(comp_cost(called.group(1)), with_bytes=False)
            elif op == "conditional":
                branches: list[str] = []
                mb = _CALLED_RE["branches"].search(ins.rest)
                if mb:
                    branches = [b.strip().lstrip("%")
                                for b in mb.group(1).split(",")]
                else:
                    for key in ("true", "false"):
                        mm = _CALLED_RE[key].search(ins.rest)
                        if mm:
                            branches.append(mm.group(1))
                if branches:
                    worst = max((comp_cost(b) for b in branches),
                                key=lambda c: c.flops)
                    cost.add(worst)
            elif op == "call":
                called = _CALLED_RE["calls"].search(ins.rest)
                if called:
                    cost.add(comp_cost(called.group(1)))
            # --- collectives -------------------------------------------- #
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES and not op.endswith("-done"):
                g = _group_size(ins.rest)
                if g > 1:
                    key = "all-to-all" if base == "ragged-all-to-all" else base
                    wire = _WIRE[key](out_bytes, g)
                    cost.wire_bytes += wire
                    rec = cost.coll_per_op.setdefault(
                        base, {"count": 0.0, "result_bytes": 0.0,
                               "wire_bytes": 0.0})
                    rec["count"] += 1
                    rec["result_bytes"] += out_bytes
                    rec["wire_bytes"] += wire
            # --- bytes (traffic level only; fusion internals excluded by
            #     the with_bytes=False above) ----------------------------- #
            if op not in _NO_TRAFFIC:
                cost.bytes += _traffic_bytes(ins, symtab, comps, out_bytes)
        memo[name] = cost
        return cost

    total = HloCost()
    total.add(comp_cost("__entry__"))
    entry = memo.get("__entry__")
    if entry:
        total.while_breakdown = entry.while_breakdown
        total.warnings = entry.warnings + top_warnings
    return total
