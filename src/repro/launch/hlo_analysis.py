"""Post-SPMD HLO analysis: collective bytes + roofline terms.

`compiled.as_text()` is the per-device (partitioned) module: shapes are LOCAL
shards and cost_analysis()['flops'] is per-device work.  Collective wire
bytes use ring-algorithm conventions per participating device:

    all-reduce         2 * (g-1)/g * result_bytes
    all-gather         (g-1)/g * result_bytes        (result = gathered)
    reduce-scatter     (g-1)   * result_bytes        (result = one shard)
    all-to-all         (g-1)/g * result_bytes
    collective-permute result_bytes

Group size g is parsed from replica_groups (explicit {{...}} or iota
[n_groups, g]<=[N] form).  The collective roofline term divides total wire
bytes by the per-chip ICI bandwidth — a deliberate single-link convention
(recorded in EXPERIMENTS.md) so terms are comparable across cells.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["parse_collectives", "CollectiveStats", "roofline_terms"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(")

_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


def _shape_bytes(shape_str: str) -> int:
    """bytes of 'bf16[2,16]{1,0}' or '(f32[8]{0}, f32[8]{0})'."""
    total = 0
    for m in re.finditer(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        entries = [e for e in m.group(1).split(",") if e.strip() != ""]
        return max(len(entries), 1)
    return 1


_WIRE = {
    "all-reduce": lambda b, g: 2.0 * (g - 1) / g * b,
    "all-gather": lambda b, g: (g - 1) / g * b,
    "reduce-scatter": lambda b, g: float(g - 1) * b,
    "all-to-all": lambda b, g: (g - 1) / g * b,
    "collective-permute": lambda b, g: float(b),
}


@dataclass
class CollectiveStats:
    per_op: dict = field(default_factory=dict)   # op -> {count, bytes, wire}
    total_wire_bytes: float = 0.0
    total_result_bytes: float = 0.0

    def as_dict(self):
        return {"per_op": self.per_op,
                "total_wire_bytes": self.total_wire_bytes,
                "total_result_bytes": self.total_result_bytes}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        b = _shape_bytes(m.group("shape"))
        g = _group_size(line)
        if g <= 1:
            continue  # degenerate group: no wire traffic
        wire = _WIRE[op](b, g)
        rec = stats.per_op.setdefault(
            op, {"count": 0, "result_bytes": 0.0, "wire_bytes": 0.0,
                 "max_group": 0})
        rec["count"] += 1
        rec["result_bytes"] += b
        rec["wire_bytes"] += wire
        rec["max_group"] = max(rec["max_group"], g)
        stats.total_wire_bytes += wire
        stats.total_result_bytes += b
    return stats


def roofline_terms(*, flops: float, bytes_accessed: float,
                   wire_bytes: float, model_flops_per_device: float,
                   peak_flops: float, hbm_bw: float, ici_bw: float) -> dict:
    """The three roofline terms (seconds, per device) + derived metrics."""
    compute_t = flops / peak_flops
    memory_t = bytes_accessed / hbm_bw
    collective_t = wire_bytes / ici_bw
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": collective_t}
    dominant = max(terms, key=terms.get)
    step_t = max(compute_t, memory_t, collective_t)
    useful_t = model_flops_per_device / peak_flops
    return {
        **terms,
        "dominant": dominant,
        "step_time_s": step_t,
        "model_flops_per_device": model_flops_per_device,
        "useful_flop_ratio": (model_flops_per_device / flops
                              if flops else 0.0),
        "roofline_fraction": useful_t / step_t if step_t else 0.0,
    }
