"""Sharded, async, elastic checkpointing (dependency-free).

Layout (one directory per step):
    ckpt_dir/step_000100/
        manifest.json      — tree structure, shapes, dtypes, logical specs
        <leaf-id>.npy      — one array per leaf (np.save, mmap-restorable)
        COMMIT             — written LAST; a checkpoint without it is torn
                             and ignored by `latest_step` (crash safety)

Properties the tests assert:
  * atomic: kill mid-save -> restore picks the previous committed step
  * bit-exact: save/restore round-trips params+opt+step exactly
  * elastic: restore re-device_puts onto ANY mesh via the sharding rules
    (arrays are stored unsharded; resharding happens at device_put), so a
    512-chip checkpoint restores onto 256 chips or 1 CPU
  * async: `save_async` snapshots to host (device_get) synchronously, then
    writes in a background thread — training continues during the write.
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "CheckpointManager"]

PyTree = Any


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _tree_paths(tree) -> list[str]:
    out = []
    for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        parts = []
        for p in path:
            parts.append(str(getattr(p, "key", getattr(p, "idx",
                         getattr(p, "name", p)))))
        out.append("/".join(parts) or "root")
    return out


def save(ckpt_dir: str | Path, step: int, tree: PyTree) -> Path:
    """Synchronous atomic save."""
    host_tree = jax.tree.map(np.asarray, jax.device_get(tree))
    return _write(Path(ckpt_dir), step, host_tree)


def _write(ckpt_dir: Path, step: int, host_tree: PyTree) -> Path:
    d = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(host_tree)
    paths = _tree_paths(host_tree)
    manifest = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, (leaf, p) in enumerate(zip(leaves, paths)):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        # np.save handles bfloat16 via view trick
        if arr.dtype.name == "bfloat16":
            np.save(tmp / fname, arr.view(np.uint16))
            dtype = "bfloat16"
        else:
            np.save(tmp / fname, arr)
            dtype = arr.dtype.name
        manifest["leaves"].append(
            {"file": fname, "path": p, "shape": list(arr.shape),
             "dtype": dtype})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    (tmp / "COMMIT").write_text("ok")
    if d.exists():
        shutil.rmtree(d)
    tmp.rename(d)
    return d


def save_async(ckpt_dir: str | Path, step: int, tree: PyTree
               ) -> threading.Thread:
    """Snapshot to host now; write in the background.  Returns the writer
    thread (join() to block; the trainer keeps a handle and joins before the
    next save)."""
    host_tree = jax.tree.map(np.asarray, jax.device_get(tree))
    t = threading.Thread(target=_write, args=(Path(ckpt_dir), step,
                                              host_tree), daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str | Path) -> int | None:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = []
    for p in d.glob("step_*"):
        if (p / "COMMIT").exists():      # torn checkpoints are ignored
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, step: int, like: PyTree,
            shardings: PyTree | None = None) -> PyTree:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs).  With `shardings`, leaves are device_put with the
    given (possibly different-mesh) shardings — elastic resharding."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves_like, treedef = _flatten(like)
    # Real exceptions, not asserts: these guard against restoring a
    # checkpoint into a mismatched model and must survive `python -O`.
    if len(manifest["leaves"]) != len(leaves_like):
        raise ValueError(
            f"checkpoint {d} has {len(manifest['leaves'])} leaves but "
            f"`like` has {len(leaves_like)} — structure mismatch")
    out = []
    for rec, ref in zip(manifest["leaves"], leaves_like):
        arr = np.load(d / rec["file"])
        if rec["dtype"] == "bfloat16":
            arr = arr.view(jax.numpy.bfloat16)
        if list(arr.shape) != list(ref.shape):
            raise ValueError(
                f"checkpoint leaf {rec['path']!r} has shape "
                f"{tuple(arr.shape)} but `like` expects "
                f"{tuple(ref.shape)} — shape mismatch")
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree


class CheckpointManager:
    """Keeps N checkpoints, drives async saves, joins before overlap."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3,
                 save_every: int = 100):
        self.dir = Path(ckpt_dir)
        self.keep = keep
        self.save_every = save_every
        self._pending: threading.Thread | None = None

    def maybe_save(self, step: int, tree: PyTree, force: bool = False):
        if not force and (step % self.save_every != 0 or step == 0):
            return False
        if self._pending is not None:
            self._pending.join()
        host_tree = jax.tree.map(np.asarray, jax.device_get(tree))

        def write_then_gc():
            _write(self.dir, step, host_tree)
            self._gc()          # GC only after this step is committed

        self._pending = threading.Thread(target=write_then_gc, daemon=True)
        self._pending.start()
        return True

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = sorted(p for p in self.dir.glob("step_*")
                       if (p / "COMMIT").exists())
        for p in steps[:-self.keep]:
            shutil.rmtree(p, ignore_errors=True)

    def latest(self) -> int | None:
        self.wait()
        return latest_step(self.dir)

    def restore_latest(self, like: PyTree, shardings=None):
        step = self.latest()
        if step is None:
            return None, None
        return step, restore(self.dir, step, like, shardings)
