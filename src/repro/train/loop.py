"""Generic fault-tolerant training loop.

Wires together: jitted train step, data iterator, async checkpointing,
heartbeat/straggler monitors, failure injection (tests), and resume.  Used
by launch/train.py, the examples, and tests/test_fault_tolerance.py.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator

import jax

from repro.distributed.fault_tolerance import (FailureInjector, Heartbeat,
                                               StragglerDetector)
from repro.train.checkpoint import CheckpointManager

__all__ = ["LoopConfig", "run_loop"]


@dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    log_every: int = 10
    straggler_threshold: float = 3.0
    injector: FailureInjector | None = None
    log_fn: Callable[[str], None] = print
    metrics_hook: Callable[[int, dict], None] | None = None


def run_loop(train_step: Callable, state: Any, data: Iterator,
             cfg: LoopConfig) -> tuple[Any, list[dict]]:
    """Runs to cfg.total_steps, resuming from the latest checkpoint if one
    exists.  Returns (final state, metrics history)."""
    mgr = (CheckpointManager(cfg.ckpt_dir, keep=cfg.ckpt_keep,
                             save_every=cfg.ckpt_every)
           if cfg.ckpt_dir else None)
    hb = Heartbeat()
    straggler = StragglerDetector(threshold=cfg.straggler_threshold)
    history: list[dict] = []

    start = 0
    if mgr is not None:
        step0, restored = mgr.restore_latest(state)
        if restored is not None:
            state = restored
            start = int(step0)
            cfg.log_fn(f"[loop] resumed from checkpoint step {start}")

    step = start
    for step in range(start, cfg.total_steps):
        if cfg.injector is not None:
            cfg.injector.maybe_fail(step)
        batch = next(data)
        t0 = time.monotonic()
        state, metrics = train_step(state, batch)
        jax.block_until_ready(metrics)
        dt = time.monotonic() - t0
        hb.beat(step)
        if straggler.observe(step, dt):
            cfg.log_fn(f"[loop] straggler at step {step}: {dt:.2f}s "
                       f"(ewma {straggler.ewma_s:.2f}s) — early checkpoint")
            if mgr is not None:
                mgr.maybe_save(step + 1, state, force=True)
        m = {k: float(v) for k, v in metrics.items()
             if getattr(v, "ndim", 0) == 0}
        m["step"], m["dt_s"] = step, dt
        history.append(m)
        if cfg.metrics_hook:
            cfg.metrics_hook(step, m)
        if step % cfg.log_every == 0:
            loss = m.get("loss", m.get("nll", float("nan")))
            cfg.log_fn(f"[loop] step {step}: loss {loss:.4f} ({dt:.2f}s)")
        if mgr is not None:
            mgr.maybe_save(step + 1, state)
    if mgr is not None:
        mgr.maybe_save(cfg.total_steps, state, force=True)
        mgr.wait()
    return state, history
