"""Train state + the generic (microbatched, compressible) train step.

`make_train_step(loss, opt, grad_accum)` builds the function every launcher
lowers: grad-accumulation is a `lax.scan` over microbatches (the standard
fit-HBM-at-scale lever: peak activation/logit memory divides by
`grad_accum`), gradients are optionally compressed before the data-parallel
reduction (distributed/compression.py), and the optimizer update runs on the
FSDP-sharded state.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.train.optimizer import Optimizer, apply_updates

__all__ = ["init_state", "make_train_step", "state_specs"]

PyTree = Any


def init_state(params: PyTree, opt: Optimizer):
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def state_specs(param_specs: PyTree, opt: Optimizer):
    """Allocation-free state tree for the dry-run."""
    return jax.eval_shape(lambda p: init_state(p, opt), param_specs)


def _split_microbatches(batch: PyTree, n: int):
    def rs(x):
        assert x.shape[0] % n == 0, (x.shape, n)
        return x.reshape(n, x.shape[0] // n, *x.shape[1:])
    return jax.tree.map(rs, batch)


def make_train_step(loss_fn: Callable, opt: Optimizer, *, grad_accum: int = 1,
                    compressor=None, accum_dtype=jnp.float32) -> Callable:
    """loss_fn(params, batch) -> (loss, metrics dict).

    Returns train_step(state, batch) -> (state, metrics).  When
    `grad_accum > 1` the global batch is split along axis 0 and gradients are
    averaged with a scan (remat of the fwd happens inside loss_fn's layer
    scan).  `compressor` (optional) maps grads -> grads with persistent error
    state under state["comp"].  `accum_dtype`: the accumulation buffer dtype;
    f32 default, bf16 for params-per-chip-bound runs (arctic-480b: the f32
    tree alone is 7.4 GiB/device at 256 chips — production pairing would be
    stochastic rounding; recorded in EXPERIMENTS.md).
    """
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if grad_accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = _split_microbatches(batch, grad_accum)

            def acc(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + l), m

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            (grads, loss), ms = jax.lax.scan(acc, (g0, jnp.zeros(())), micro)
            inv = 1.0 / grad_accum
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss = loss * inv
            metrics = jax.tree.map(lambda m: m[-1], ms)

        new_state = dict(state)
        if compressor is not None:
            grads, comp_state = compressor.apply(
                grads, state.get("comp"))
            new_state["comp"] = comp_state
        updates, opt_state = opt.update(grads, state["opt"], params)
        new_state["params"] = apply_updates(params, updates)
        new_state["opt"] = opt_state
        new_state["step"] = state["step"] + 1
        metrics = dict(metrics)
        metrics["loss"] = loss
        return new_state, metrics

    return train_step
