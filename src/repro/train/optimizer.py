"""Minimal optax-style optimizers in pure JAX pytrees.

Kept dependency-free so optimizer states inherit parameter shardings directly
under pjit (state is a pytree of arrays shaped like params — the sharding
rules in distributed/sharding.py map over it unchanged, giving ZeRO-style
sharded optimizer state for free).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["adamw", "sgd", "adafactor", "apply_updates", "global_norm",
           "clip_by_global_norm", "cosine_schedule", "Optimizer"]

PyTree = Any


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: PyTree
    nu: PyTree


class SgdState(NamedTuple):
    step: jnp.ndarray
    mom: PyTree


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], Any]
    update: Callable[..., tuple[PyTree, Any]]


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, tree), norm


def cosine_schedule(base_lr: float, warmup_steps: int, total_steps: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(step < warmup_steps, warm, cos)
    return lr


def adamw(lr: float | Callable = 1e-3, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.0,
          clip_norm: float | None = 1.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamState(step=jnp.zeros((), jnp.int32),
                         mu=jax.tree.map(zeros, params),
                         nu=jax.tree.map(zeros, params))

    def update(grads, state: AdamState, params=None):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        t = step.astype(jnp.float32)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v
                          + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                          state.nu, grads)
        lr_t = lr_fn(step)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(m, v, p):
            u = -lr_t * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p is not None:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u.astype(p.dtype if p is not None else u.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def sgd(lr: float | Callable = 1e-2, momentum: float = 0.9,
        clip_norm: float | None = None) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return SgdState(step=jnp.zeros((), jnp.int32),
                        mom=jax.tree.map(lambda p: jnp.zeros_like(p), params))

    def update(grads, state: SgdState, params=None):
        del params
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        mom = jax.tree.map(lambda m, g: momentum * m + g, state.mom, grads)
        updates = jax.tree.map(lambda m: -lr_fn(step) * m, mom)
        return updates, SgdState(step=step, mom=mom)

    return Optimizer(init=init, update=update)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


# --------------------------------------------------------------------------- #
# Adafactor (Shazeer & Stern 2018): factored second moments, no momentum.
# O(d_in + d_out) state per matrix instead of O(d_in * d_out) — the optimizer
# that lets a 476B-param MoE train inside 16 GB/chip at 256 chips (see
# configs/arctic_480b.py).  Factoring is over the LAST TWO axes; leading axes
# (stacked layers, experts) are treated as batch.
# --------------------------------------------------------------------------- #
class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: PyTree      # row second-moment (rank>=2 leaves) or full v (rank<2)
    vc: PyTree      # col second-moment (rank>=2) or None-placeholder


def adafactor(lr: float | Callable = 1e-2, decay: float = 0.8,
              eps: float = 1e-30, clip_threshold: float = 1.0,
              min_dim_size_to_factor: int = 128) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def factored(p) -> bool:
        return (p.ndim >= 2 and p.shape[-1] >= min_dim_size_to_factor
                and p.shape[-2] >= min_dim_size_to_factor)

    def init(params):
        def vr0(p):
            if factored(p):
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def vc0(p):
            if factored(p):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((1,), jnp.float32)

        return AdafactorState(step=jnp.zeros((), jnp.int32),
                              vr=jax.tree.map(vr0, params),
                              vc=jax.tree.map(vc0, params))

    def update(grads, state: AdafactorState, params=None):
        step = state.step + 1
        t = step.astype(jnp.float32)
        # time-dependent decay (t^-0.8 schedule from the paper)
        beta = 1.0 - t ** (-decay)
        lr_t = lr_fn(step)

        def upd(g, vr, vc, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if factored(p):
                vr_n = beta * vr + (1 - beta) * g2.mean(axis=-1)
                vc_n = beta * vc + (1 - beta) * g2.mean(axis=-2)
                denom = vr_n.mean(axis=-1, keepdims=True)
                vhat = (vr_n / jnp.maximum(denom, eps))[..., None] \
                    * vc_n[..., None, :]
                u = g * jax.lax.rsqrt(jnp.maximum(vhat, eps))
            else:
                vr_n = beta * vr + (1 - beta) * g2
                vc_n = vc
                u = g * jax.lax.rsqrt(jnp.maximum(vr_n, eps))
            # RMS clipping (paper eq. 6)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (-lr_t * u).astype(p.dtype), vr_n, vc_n

        out = jax.tree.map(upd, grads, state.vr, state.vc, params)
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        vr = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        vc = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        return updates, AdafactorState(step=step, vr=vr, vc=vc)

    return Optimizer(init=init, update=update)
