"""Batched serving engine: prefill + decode with continuous slot management.

`ServeEngine` keeps a fixed decode batch of `slots`; requests are admitted
into free slots (prefill), stepped together (one fused decode_step for the
whole batch — the production serving pattern the decode_* dry-run cells
lower), and retired on EOS/length.  Greedy or temperature sampling.

Single-sequence decode state is carved out of / merged into the batched
cache purely with tree ops, so the engine works unchanged for attention
caches, ring caches, SSM states, and whisper self+cross caches.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.zoo import ModelApi

__all__ = ["Request", "ServeEngine"]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # [T] int32
    enc_x: np.ndarray | None = None     # whisper frame embeddings
    max_new_tokens: int = 32
    eos_id: int | None = None
    temperature: float = 0.0
    generated: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, api: ModelApi, *, slots: int = 4, max_len: int = 256,
                 seed: int = 0):
        self.api = api
        self.slots = slots
        self.max_len = max_len
        self.params = None
        self.cache = None
        self.active: dict[int, Request] = {}     # slot -> request
        self._key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(api.decode)

    # ------------------------------------------------------------------ #
    def load(self, params):
        self.params = params
        self.cache = self.api.cache_init(self.slots, self.max_len)

    def _write_slot(self, slot: int, src_cache):
        """Copy a batch-1 prefill cache into batched-cache slot `slot`."""
        def merge(dst, src):
            # batch axis location: find the axis where dst == slots and
            # src == 1 (the batch axis survives stacking at the same index).
            for ax in range(src.ndim):
                if src.shape[ax] == 1 and dst.shape[ax] == self.slots:
                    idx = [slice(None)] * dst.ndim
                    idx[ax] = slice(slot, slot + 1)
                    return dst.at[tuple(idx)].set(src.astype(dst.dtype))
            return dst  # scalar/shared leaves
        self.cache = jax.tree.map(merge, self.cache, src_cache)

    def admit(self, req: Request) -> bool:
        """Prefill `req` into a free slot; False if engine is full."""
        free = [s for s in range(self.slots) if s not in self.active]
        if not free or self.params is None:
            return False
        slot = free[0]
        batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
        if req.enc_x is not None:
            batch["enc_x"] = jnp.asarray(req.enc_x[None])
        src_cache, logits = self.api.prefill(self.params, batch, self.max_len)
        self._write_slot(slot, src_cache)
        self.active[slot] = req
        req.generated.append(int(self._sample(logits[0], req)))
        return True

    def _sample(self, logits, req: Request) -> int:
        if req.temperature <= 0.0:
            return int(jnp.argmax(logits))
        self._key, sub = jax.random.split(self._key)
        return int(jax.random.categorical(sub, logits / req.temperature))

    # ------------------------------------------------------------------ #
    def step(self) -> list[Request]:
        """One fused decode step for every active slot; returns finished."""
        if not self.active:
            return []
        tokens = np.zeros((self.slots,), np.int32)
        for slot, req in self.active.items():
            tokens[slot] = req.generated[-1]
        self.cache, logits = self._decode(self.params, self.cache,
                                          jnp.asarray(tokens))
        finished = []
        for slot, req in list(self.active.items()):
            tok = self._sample(logits[slot], req)
            req.generated.append(tok)
            if ((req.eos_id is not None and tok == req.eos_id)
                    or len(req.generated) >= req.max_new_tokens):
                req.done = True
                finished.append(req)
                del self.active[slot]
        return finished

    # ------------------------------------------------------------------ #
    def generate(self, reqs: list[Request]) -> list[Request]:
        """Run a request list to completion with continuous admission."""
        pending = list(reqs)
        done: list[Request] = []
        while pending or self.active:
            while pending and self.admit(pending[0]):
                pending.pop(0)
            done.extend(self.step())
        return done
