"""Fault tolerance for 1000+-node runs: heartbeats, straggler detection,
failure injection, and elastic re-meshing policy.

What runs where:
  * `Heartbeat` / `StragglerDetector` — host-side monitors around the train
    loop (per-step walltime EWMA; a step exceeding `threshold x` the EWMA is
    flagged; at production scale the runner re-dispatches the step to the
    backup pod and fences the slow host).
  * `FailureInjector` — deterministic chaos hook used by the tests: raises a
    simulated preemption at a chosen step; the loop must restart from the
    last committed checkpoint bit-exactly (tests/test_fault_tolerance.py).
  * `elastic_plan` — given a checkpoint taken on mesh A and a surviving
    device count, picks the largest valid production mesh and the resharding
    is performed by checkpoint.restore(..., shardings=new) (arrays are
    stored unsharded, so any target mesh works).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

__all__ = ["Heartbeat", "StragglerDetector", "FailureInjector",
           "elastic_plan"]


@dataclass
class Heartbeat:
    """Step-progress monitor.  `beat()` each step; `stalled()` reports if no
    beat arrived within `timeout_s` (host hang / lost worker)."""
    timeout_s: float = 300.0
    last_beat: float = field(default_factory=time.monotonic)
    step: int = -1

    def beat(self, step: int):
        self.step = step
        self.last_beat = time.monotonic()

    def stalled(self) -> bool:
        return (time.monotonic() - self.last_beat) > self.timeout_s


@dataclass
class StragglerDetector:
    """EWMA step-time monitor; flags steps slower than threshold x EWMA.

    At scale the mitigation is re-dispatch + fence; in this repo the loop
    logs the event and (optionally) triggers an early checkpoint so a kill
    of the slow host loses no progress.
    """
    alpha: float = 0.1
    threshold: float = 3.0
    ewma_s: float | None = None
    events: list = field(default_factory=list)

    def observe(self, step: int, dt_s: float) -> bool:
        if self.ewma_s is None:
            self.ewma_s = dt_s
            return False
        slow = dt_s > self.threshold * self.ewma_s
        if slow:
            self.events.append({"step": step, "dt_s": dt_s,
                                "ewma_s": self.ewma_s})
        # EWMA excludes flagged outliers so one straggler doesn't mask the
        # next.
        if not slow:
            self.ewma_s = (1 - self.alpha) * self.ewma_s + self.alpha * dt_s
        return slow


class SimulatedPreemption(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Raise SimulatedPreemption once the loop reaches `fail_at_step`.

    Fires on `step >= fail_at_step` (once), not exact equality: loops that
    skip step numbers (resume from a checkpoint, stride by accumulation,
    tick counters that jump after a drain) must still hit the injected
    failure instead of silently sailing past it.
    """
    fail_at_step: int | None = None
    fired: bool = False

    def maybe_fail(self, step: int):
        if (self.fail_at_step is not None and not self.fired
                and step >= self.fail_at_step):
            self.fired = True
            raise SimulatedPreemption(f"injected failure at step {step}")


def elastic_plan(n_devices: int, *, model_axis: int = 16) -> dict:
    """Pick the largest (data, model) mesh for the surviving device count.

    Keeps the model axis fixed (TP degree is a property of the program) and
    shrinks data parallelism; global batch is preserved by raising
    grad_accum, so restarts are loss-curve-identical regardless of node
    loss.
    """
    if n_devices < model_axis:
        # degenerate: shrink TP too (single-host debugging)
        model_axis = max(1, n_devices)
    data = max(1, n_devices // model_axis)
    return {"mesh_shape": (data, model_axis),
            "axes": ("data", "model"),
            "grad_accum_scale": 16 // min(data, 16) if data < 16 else 1,
            "dropped_devices": n_devices - data * model_axis}
