"""Sharding rules: logical activation/param names -> mesh PartitionSpecs.

MaxText-style separation: model code annotates activations with LOGICAL names
(`shard(x, "act_btd")`) and builds params under descriptive dict paths; this
module owns the mapping of both onto the physical mesh axes
('pod', 'data', 'model').

Outside an `axis_rules(mesh, ...)` context every annotation is a no-op, so
single-device smoke tests and the MERINDA CPU path never touch device state.

Parallelism encoded here:
  * DP / FSDP  — batch over ('pod', 'data'); params + optimizer state sharded
    over 'data' on their largest non-tensor axis (ZeRO-3 style: GSPMD
    all-gathers weights per layer inside the scan and reduce-scatters grads).
  * TP         — attention heads / FFN hidden / vocab over 'model'.
  * EP         — MoE expert axis over 'model' (expert-parallel groups);
    dispatch/combine lower to all-to-alls.
  * SP         — decode KV caches sequence-sharded over 'model'
    (flash-decode: partial softmax + all-reduce), long-context over
    ('data', 'model') when batch=1.
"""
from __future__ import annotations

import re
import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["axis_rules", "shard", "ShardingRules", "param_shardings",
           "cache_shardings", "logical_to_sharding", "DEFAULT_ACT_RULES",
           "DEFAULT_PARAM_RULES", "active_rules"]

_LOCAL = threading.local()


# --------------------------------------------------------------------------- #
# Activation rules: logical name -> PartitionSpec (tuple entries = multi-axis)
# --------------------------------------------------------------------------- #
DEFAULT_ACT_RULES: dict[str, P] = {
    # [B, T, d_model] residual stream: batch over pod+data, d replicated.
    # (Megatron-style sequence parallelism — T over 'model' — was measured
    # and REJECTED as the default: qwen3 train memory 8.1 -> 2.6 GiB but
    # wire bytes 3.2x and roofline fraction 0.072 -> 0.023; see §Perf.)
    "act_btd": P(("pod", "data"), None, None),
    # [B, T, d_ff] / moe hidden: hidden over model (TP).
    "act_ffn": P(("pod", "data"), None, "model"),
    # [B, T, V] logits: vocab over model.
    "act_btv": P(("pod", "data"), None, "model"),
    # [B, T, H, dh] attention heads over model.
    "act_bthd": P(("pod", "data"), None, "model", None),
    # [B, H, T, dh]
    "act_bhtd": P(("pod", "data"), "model", None, None),
    # KV cache (prefill/train): [B, T, kv, dh] heads over model when divisible.
    "kv_bt": P(("pod", "data"), None, "model", None),
    # decode KV cache: sequence-sharded over model (flash-decode).
    "kv_seq": P(("pod", "data"), "model", None, None),
    # long-context (B=1) decode cache: sequence over every axis.
    "kv_seq_all": P(None, ("pod", "data", "model"), None, None),
    # MoE grouped tokens [G, n, d]: groups over pod+data+model.
    "act_gnd": P(("pod", "data"), None, None),
    # MoE dispatched [G, E, C, d] / hidden [G, E, C, f]: E over model.
    "act_gecd": P(("pod", "data"), "model", None, None),
    "act_gecf": P(("pod", "data"), "model", None, None),
    # MoE combine/dispatch one-hots [G, n, E, C].
    "act_gnec": P(("pod", "data"), None, "model", None),
    # recurrent state [B, H, K, V(head)] (rwkv6 / mamba2): heads over model.
    "state_bhkv": P(("pod", "data"), "model", None, None),
    # ---- online twin serving (twin/*): every per-twin / per-slot axis is
    # data-parallel over ('pod','data'), mirroring the FleetMerinda fleet
    # axis, so one sharded TwinServer tick advances every shard's slots. ----
    # telemetry rings [S, cap, n|m] and their write heads [S].
    "twin_ring": P(("pod", "data"), None, None),
    "twin_count": P(("pod", "data")),
    # serving theta store [S, n, L].
    "twin_theta": P(("pod", "data"), None, None),
    # refit window batches [F, S_B, k(+1), n|m] (fleet axis leading).
    "twin_windows": P(("pod", "data"), None, None, None),
    # per-slot scalars [F]: step counters, losses.
    "twin_fleet": P(("pod", "data")),
}

# --------------------------------------------------------------------------- #
# Param rules: path regex -> PartitionSpec.  First match wins; matched against
# "/"-joined tree paths like "layers/attn/wq/w".
# --------------------------------------------------------------------------- #
DEFAULT_PARAM_RULES: list[tuple[str, P]] = [
    # adafactor factored stats: expert stats sharded, the rest replicated
    # (they are O(d_in + d_out) — tiny except for the expert stack).
    (r".*opt/v[rc]/.*experts/(gate|up|down)/w$", P(None, "model", "data")),
    (r".*opt/v[rc]/.*", P()),
    # embeddings / unembed: vocab over model, d over data (FSDP).
    (r".*(embed|unembed|lm_head|dec_pos)/w$", P("model", "data")),
    # attention projections: qkv column-parallel, out row-parallel.
    (r".*(wq|wk|wv|wr|wg|wqkv|in_proj)/w$", P("data", "model")),
    (r".*(wo|out_proj)/w$", P("model", "data")),
    # MoE experts: [E, d_in, d_out] expert axis over model, d_in over data.
    (r".*experts/(gate|up)/w$", P("model", "data", None)),
    (r".*experts/down/w$", P("model", None, "data")),
    (r".*router/w$", P("data", None)),
    # MLP: column-parallel up/gate, row-parallel down.
    (r".*(gate|up)/w$", P("data", "model")),
    (r".*down/w$", P("model", "data")),
    # mamba2 / rwkv6 fused projections.
    (r".*(xproj|zproj|dt_proj|abc_proj)/w$", P("data", "model")),
    (r".*(time_mix|decay|bonus).*", P()),
    (r".*conv/.*", P()),
    # norms / scalars / biases: replicated.
    (r".*", P()),
]


@dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    act: dict[str, P] = field(default_factory=lambda: dict(DEFAULT_ACT_RULES))
    params: tuple = tuple(DEFAULT_PARAM_RULES)

    def act_spec(self, name: str) -> P:
        return self.act[name]


def _strip_missing_axes(spec: P, mesh: Mesh) -> P:
    """Drop mesh axes the current mesh does not define (e.g. 'pod' on the
    single-pod mesh) so one rule set serves every mesh."""
    names = set(mesh.axis_names)
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
        elif isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            out.append(kept if kept else None)
        else:
            out.append(entry if entry in names else None)
    return P(*out)


def _shardable(dim: int, entry, mesh: Mesh) -> bool:
    axes = (entry,) if isinstance(entry, str) else tuple(entry or ())
    size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    return size <= 1 or dim % size == 0


def logical_to_sharding(spec: P, mesh: Mesh, shape=None,
                        repair: bool = False,
                        pad_ok: bool = False) -> NamedSharding:
    """pad_ok: keep a non-dividing axis when dim >= axis size (GSPMD pads,
    <=2x waste on that dim — used for ACTIVATIONS, where the alternative is
    full replication: whisper's 20 heads / 51866 vocab over model=16).
    Weights/caches (pad_ok=False) prefer replication or repair."""
    spec = _strip_missing_axes(spec, mesh)
    if shape is not None:
        entries = list(spec) + [None] * (len(shape) - len(spec))
        dropped: list = []
        for i, (d, e) in enumerate(zip(shape, entries)):
            if _shardable(d, e, mesh):
                continue
            axes = (e,) if isinstance(e, str) else tuple(e)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            if pad_ok and d >= size:
                continue
            dropped.append(e)
            entries[i] = None
        if repair and dropped:
            # Sharding repair: relocate a dropped mesh axis onto the largest
            # free dim it divides (e.g. mixtral's 8 experts cannot split
            # over model=16 -> shard the expert FFN dim instead; dropping
            # silently would replicate 338 GB of experts 16-way).
            for e in dropped:
                axes = (e,) if isinstance(e, str) else tuple(e)
                size = int(np.prod([mesh.shape[a] for a in axes]))
                cands = [i for i, (d, cur) in enumerate(zip(shape, entries))
                         if cur is None and d % size == 0 and d >= size]
                if cands:
                    target = max(cands, key=lambda i: shape[i])
                    entries[target] = e
        spec = P(*entries)
    return NamedSharding(mesh, spec)


# --------------------------------------------------------------------------- #
# Context + activation annotation
# --------------------------------------------------------------------------- #
@contextmanager
def axis_rules(rules: ShardingRules | None):
    prev = getattr(_LOCAL, "rules", None)
    _LOCAL.rules = rules
    try:
        yield rules
    finally:
        _LOCAL.rules = prev


def active_rules() -> ShardingRules | None:
    return getattr(_LOCAL, "rules", None)


def shard(x, name: str):
    """Constrain activation `x` to the logical sharding `name` (no-op when no
    rules are active or the spec does not divide the shape)."""
    rules = active_rules()
    if rules is None:
        return x
    spec = rules.act.get(name)
    if spec is None:
        return x
    # pad_ok: shard non-dividing head/vocab dims with GSPMD padding rather
    # than replicate.  repair (relocating a fully-undividable axis to a
    # divisible dim) applies ONLY to MoE group tensors — mixtral's E=8 over
    # model=16 moves to the expert-FFN dim; on attention K/V it would
    # silently sequence-shard the cache and 3x the training wire bytes
    # (measured; §Perf).
    sharding = logical_to_sharding(spec, rules.mesh, x.shape, pad_ok=True,
                                   repair=name.startswith("act_g"))
    return jax.lax.with_sharding_constraint(x, sharding)


# --------------------------------------------------------------------------- #
# Param tree -> sharding tree
# --------------------------------------------------------------------------- #
def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def cache_shardings(rules: ShardingRules, cache: Any, *, batch: int) -> Any:
    """Decode/prefill cache tree -> NamedShardings.

    KV caches are sequence-sharded over 'model' (flash-decode: GSPMD lowers
    the softmax reductions over the sharded axis to partial reductions +
    all-reduce); at batch==1 (long-context) the sequence is sharded over the
    ENTIRE mesh.  Recurrent states shard batch over ('pod','data') and heads
    over 'model' where divisible.  Stacked-layer leading axes are inferred
    from rank (base ranks are fixed per leaf name).
    """
    mesh = rules.mesh
    bd = ("pod", "data")
    seq = ("pod", "data", "model") if batch == 1 else "model"
    BASE = {
        "k": (4, P(bd, seq, None, None)),
        "v": (4, P(bd, seq, None, None)),
        "pos": (2, P(bd, seq)),
        "wkv": (4, P(bd, "model", None, None)),
        "ssm": (4, P(bd, "model", None, None)),
        "conv": (3, P(bd, None, None)),
        "tm_last": (2, P(bd, None)),
        "cm_last": (2, P(bd, None)),
    }

    def assign(path, leaf):
        last = _path_str(path[-1:])
        shape = tuple(leaf.shape)
        if last == "pos" and leaf.ndim == 1:          # top-level position
            return logical_to_sharding(P(bd), mesh, shape)
        if last not in BASE:
            raise AssertionError(f"no cache rule for {_path_str(path)}")
        base_rank, spec = BASE[last]
        missing = len(shape) - base_rank
        spec = P(*([None] * missing), *spec)
        return logical_to_sharding(spec, mesh, shape)

    return jax.tree_util.tree_map_with_path(assign, cache)


def param_shardings(rules: ShardingRules, params: Any) -> Any:
    """Map a params(-shaped) pytree to NamedShardings via the path rules.

    Works on concrete arrays or ShapeDtypeStructs (dry-run).  Stacked-layer
    leading axes (scan-over-layers) are detected by rank mismatch: rules are
    written for the per-layer rank; extra leading dims get None.
    """
    compiled = [(re.compile(pat), spec) for pat, spec in rules.params]

    def assign(path, leaf):
        name = _path_str(path)
        shape = tuple(leaf.shape)
        for pat, spec in compiled:
            if pat.match(name):
                # pad spec on the LEFT for stacked-layer leading axes.
                missing = len(shape) - len(spec)
                if missing > 0:
                    spec = P(*([None] * missing), *spec)
                elif missing < 0:
                    spec = P(*list(spec)[-len(shape):] if shape else ())
                return logical_to_sharding(spec, rules.mesh, shape,
                                           repair=True)
        raise AssertionError(f"no param rule matched {name}")

    return jax.tree_util.tree_map_with_path(assign, params)
