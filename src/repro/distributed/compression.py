"""Gradient compression for the data-parallel reduction.

Two composable schemes (used before the DP all-reduce at 1000+-node scale,
where the cross-pod DCN hop is ~10x slower than ICI):

  * top-k sparsification with ERROR FEEDBACK (memory): each step sends only
    the largest-|g| fraction per leaf; the residual is carried and added to
    the next step's gradient, preserving convergence (Stich et al. 2018).
  * int8 quantization: per-leaf symmetric scale, quantize -> dequantize.

`Compressor.apply` is pure (error state threads through the train state
under state["comp"]), so it lives inside the jitted train step; leaves are
compressed elementwise which means the pattern shards trivially under pjit.
The wire saving is realized when the launcher runs the DP reduction over the
compressed representation (launch/train.py --compress; the dry-run §Perf log
quantifies the collective-term delta).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["Compressor", "topk_compressor", "int8_compressor",
           "quantize_int8", "dequantize_int8"]

PyTree = Any


def quantize_int8(x):
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def _topk_mask(x, keep_frac: float):
    """Mask keeping the top `keep_frac` fraction of |x| entries."""
    flat = jnp.abs(x.reshape(-1))
    k = max(int(flat.size * keep_frac), 1)
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


@dataclass(frozen=True)
class Compressor:
    keep_frac: float | None = None      # top-k sparsification fraction
    int8: bool = False

    def init(self, grads: PyTree) -> PyTree:
        if self.keep_frac is None:
            return None
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def apply(self, grads: PyTree, err: PyTree | None):
        """grads -> (compressed grads, new error state)."""
        if self.keep_frac is not None:
            if err is None:
                err = self.init(grads)

            def one(g, e):
                corrected = g.astype(jnp.float32) + e
                mask = _topk_mask(corrected, self.keep_frac)
                sent = corrected * mask
                return sent.astype(g.dtype), corrected - sent

            out = jax.tree.map(one, grads, err)
            grads = jax.tree.map(lambda o: o[0], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
            err = jax.tree.map(lambda o: o[1], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        if self.int8:
            def q(g):
                qq, s = quantize_int8(g)
                return dequantize_int8(qq, s).astype(g.dtype)
            grads = jax.tree.map(q, grads)
        return grads, err

    def wire_bytes_per_param(self) -> float:
        """Modeled bytes/param on the DP reduction (for §Perf napkin math):
        top-k sends (value+index) per kept entry; int8 sends 1 byte."""
        value = 1.0 if self.int8 else 4.0
        if self.keep_frac is not None:
            return self.keep_frac * (value + 4.0)
        return value


def topk_compressor(keep_frac: float = 0.1, int8: bool = False) -> Compressor:
    return Compressor(keep_frac=keep_frac, int8=int8)


def int8_compressor() -> Compressor:
    return Compressor(int8=True)
