"""Per-tick span tracing exportable as Chrome trace-event JSON (Perfetto).

A metric histogram tells you the p99 got worse; a trace tells you WHICH tick
and WHICH stage.  `Tracer.span()` wraps the serving stages in nested spans —

    sharded_tick
    └─ tick (shard=0)
       ├─ flush            (+ pump_flush spans on the BackgroundPump thread)
       ├─ guard
       ├─ schedule
       └─ refit

— recorded as Chrome trace-event "complete" events (`ph: "X"`) that load
directly in Perfetto (https://ui.perfetto.dev) or `chrome://tracing`.

Designed for an always-on service:

  * **ring-bounded buffer** — events live in a `deque(maxlen=capacity)`;
    a long-running server overwrites its oldest spans instead of growing
    (`dropped_events` counts the overwritten ones, loudly);
  * **sampling knob** — `sample_every=N` records every Nth ROOT span and its
    whole subtree, so steady-state tracing cost scales down linearly while
    sampled ticks stay internally complete (a half-recorded tick is useless);
  * **near-free when off** — `enabled=False` makes `span()` return a shared
    no-op context manager: no clock reads, no allocation, one attribute
    check.  The 64-twin tracing-on-vs-off parity test and the 10k-twin
    overhead column in bench_out/online_scale.csv hold the cost honest.

Spans may begin on any thread (the pump flush records from its worker
thread); each thread renders as its own Perfetto track via `tid`, with
thread-name metadata events emitted on first sight.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque

__all__ = ["Tracer", "NULL_SPAN"]


class _NullSpan:
    """Shared no-op context manager (tracing disabled)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class _SkipSpan:
    """Depth bookkeeping for an UNSAMPLED subtree — records nothing, but the
    root/child distinction must survive so the next root re-rolls the
    sampling decision."""

    __slots__ = ("_tls",)

    def __init__(self, tls):
        self._tls = tls

    def __enter__(self):
        self._tls.depth += 1
        return self

    def __exit__(self, *exc):
        self._tls.depth -= 1
        return False


class _Span:
    """One recorded span: clock on enter, event emission on exit."""

    __slots__ = ("_tr", "_tls", "name", "cat", "args", "_t0")

    def __init__(self, tracer, tls, name, cat, args):
        self._tr = tracer
        self._tls = tls
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self):
        self._tls.depth += 1
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        self._tls.depth -= 1
        self._tr._record(self.name, self.cat, self._t0, t1, self.args)
        return False


class Tracer:
    """Span recorder with a bounded ring buffer; see module docstring.

    Thread-safe: spans may be opened concurrently from the serving thread
    and the ingest/pump threads.  Sampling is decided at ROOT spans only
    (depth 0 on the calling thread) and inherited by the whole subtree.
    """

    def __init__(self, *, capacity: int = 65536, sample_every: int = 1,
                 enabled: bool = True):
        if capacity < 1:
            raise ValueError("trace capacity must be >= 1")
        if sample_every < 1:
            raise ValueError("sample_every must be >= 1")
        self.enabled = enabled
        self.capacity = capacity
        self.sample_every = sample_every
        self.dropped_events = 0       # overwritten by the ring (monotonic)
        self._events: deque = deque(maxlen=capacity)
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()
        self._roots = 0
        self._tids: dict[int, int] = {}      # thread ident -> compact tid
        self._thread_meta: list[dict] = []   # Perfetto thread_name events
        self._local = threading.local()

    # ------------------------------------------------------------------ #
    def _tls(self):
        tls = self._local
        if not hasattr(tls, "depth"):
            tls.depth = 0
            tls.skip = False
        return tls

    def span(self, name: str, cat: str = "twin", **args):
        """Context manager timing one span; `args` land in the trace event.

        Usage: `with tracer.span("guard", shard="2"): ...` — nesting follows
        the runtime call structure per thread.
        """
        if not self.enabled:
            return NULL_SPAN
        tls = self._tls()
        if tls.depth == 0:
            with self._lock:
                n = self._roots
                self._roots += 1
            tls.skip = (n % self.sample_every) != 0
        if tls.skip:
            return _SkipSpan(tls)
        return _Span(self, tls, name, cat, args)

    # ------------------------------------------------------------------ #
    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids))
                if tid == len(self._tids) - 1:
                    self._thread_meta.append({
                        "name": "thread_name", "ph": "M", "pid": 0,
                        "tid": tid,
                        "args": {"name": threading.current_thread().name}})
        return tid

    def _record(self, name, cat, t0, t1, args) -> None:
        ev = {"name": name, "cat": cat, "ph": "X",
              "ts": (t0 - self._t0) * 1e6,          # microseconds
              "dur": (t1 - t0) * 1e6,
              "pid": 0, "tid": self._tid()}
        if args:
            ev["args"] = {k: (v if isinstance(v, (int, float, str, bool))
                              else str(v)) for k, v in args.items()}
        with self._lock:
            if len(self._events) == self.capacity:
                self.dropped_events += 1
            self._events.append(ev)

    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped_events = 0

    def to_chrome_trace(self) -> dict:
        """The Chrome trace-event JSON object Perfetto loads directly."""
        with self._lock:
            events = self._thread_meta + list(self._events)
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"producer": "repro.obs.tracing",
                              "dropped_events": self.dropped_events}}

    def write(self, path) -> None:
        """Dump the trace to `path` as Perfetto-loadable JSON."""
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
