"""Exporters: periodic JSON metric snapshots + trace file emission.

The Prometheus text exposition itself lives on the registry
(`MetricRegistry.expose()` — transport-free; serve it from any HTTP
handler).  This module covers the file-based paths an edge deployment
actually has available when there is no scrape infrastructure:

  * `SnapshotWriter` — writes `registry.snapshot()` (plus optional tracer
    health) to a JSON file at most once per `every_s` seconds.  Call
    `maybe_write()` opportunistically from the serving loop (cheap no-op
    between periods) or `write()` to force one — e.g. at benchmark end.
    Writes are atomic (tmp file + rename) so a scraping sidecar never
    reads a torn snapshot.
"""
from __future__ import annotations

import json
import os
import time

__all__ = ["SnapshotWriter"]


class SnapshotWriter:
    """Periodic JSON snapshot of a `MetricRegistry` (+ tracer health)."""

    def __init__(self, registry, path, *, every_s: float = 10.0,
                 tracer=None):
        self.registry = registry
        self.path = str(path)
        self.every_s = float(every_s)
        self.tracer = tracer
        self.writes = 0
        self._last = -float("inf")

    def maybe_write(self) -> bool:
        """Write if a full period elapsed since the last write."""
        now = time.monotonic()
        if now - self._last < self.every_s:
            return False
        self._last = now
        self.write()
        return True

    def write(self) -> None:
        snap = {"unix_time": time.time(),
                "metrics": self.registry.snapshot()}
        if self.tracer is not None:
            snap["trace"] = {"events": len(self.tracer),
                             "dropped_events": self.tracer.dropped_events,
                             "sample_every": self.tracer.sample_every,
                             "enabled": self.tracer.enabled}
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(snap, f, indent=1)
        os.replace(tmp, self.path)
        self.writes += 1
