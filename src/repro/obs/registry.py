"""Thread-safe, dependency-free metrics registry for the twin-serving stack.

The serving loop used to prove its latency SLO with ad-hoc `perf_counter()`
pairs appended to unbounded Python lists — a memory leak in a long-running
service and invisible to operators.  This module is the replacement: a small
Prometheus-shaped registry with three instrument types, all bounded-memory
and safe to update from sensor/pump threads concurrently with the serving
tick:

  * `Counter`   — monotone float (events, samples, violations),
  * `Gauge`     — last-write-wins float (queue depth, tracked twins, grants),
  * `Histogram` — FIXED log-spaced buckets with p50/p90/p99/max queries.
    Memory is O(buckets) regardless of how many samples are observed; the
    per-bucket geometric spacing bounds the relative quantile error at one
    bucket ratio (`tests/test_obs.py` checks it against exact quantiles).

Instruments are grouped into FAMILIES by metric name (one help/type/unit per
name) with label-keyed children — `registry.counter("x_total",
labels={"shard": "3"})` returns the same child on every call, so layers can
re-resolve instruments cheaply instead of threading objects around.

Exposition: `registry.expose()` renders the standard Prometheus text format
(histograms as cumulative `_bucket{le=...}` series plus `_sum`/`_count`);
`registry.snapshot()` returns a JSON-able dict for the periodic snapshot
writer (obs/exporters.py).  Naming follows Prometheus conventions: counters
end in `_total`, units are in the name (`_seconds`), labels are flat strings.
"""
from __future__ import annotations

import bisect
import math
import threading

__all__ = ["Counter", "Gauge", "Histogram", "MetricRegistry",
           "log_buckets", "DEFAULT_LATENCY_BUCKETS", "DEFAULT_SCORE_BUCKETS"]


def log_buckets(lo: float, hi: float, per_decade: int = 30) -> tuple:
    """Geometric bucket upper edges from `lo` to >= `hi`.

    `per_decade` edges per power of ten; the relative width of each bucket
    (and so the worst-case relative quantile error) is 10**(1/per_decade)-1
    (~8% at the default 30).  An implicit +inf overflow bucket rides on top.
    """
    if not (0 < lo < hi):
        raise ValueError("need 0 < lo < hi")
    n = int(math.ceil(math.log10(hi / lo) * per_decade))
    return tuple(lo * 10.0 ** (i / per_decade) for i in range(n + 1))


# serving latencies: 10 us .. 100 s covers a fused kernel dispatch through a
# badly-stalled sharded tick; 60/decade keeps the worst-case quantile
# quantization under 4% — tight enough that the tracing-overhead gate
# (p50 within 5%, bench_out/online_scale.csv) measures the tracer, not the
# histogram.  421 buckets = a few KB per child.
DEFAULT_LATENCY_BUCKETS = log_buckets(1e-5, 100.0, 60)
# guard divergence scores: 1e-6 (tracking perfectly) .. 1e6 (_BLOWUP_SCORE)
DEFAULT_SCORE_BUCKETS = log_buckets(1e-6, 1e6, 6)


class _Metric:
    """Common identity: family name + sorted label pairs."""

    __slots__ = ("name", "labels", "_lock")

    def __init__(self, name: str, labels: tuple):
        self.name = name
        self.labels = labels          # sorted ((key, value), ...) strings
        self._lock = threading.Lock()

    def label_str(self) -> str:
        if not self.labels:
            return ""
        inner = ",".join(f'{k}="{v}"' for k, v in self.labels)
        return "{" + inner + "}"


class Counter(_Metric):
    """Monotone event/sample counter.  `inc()` is thread-safe."""

    __slots__ = ("_value",)

    def __init__(self, name: str, labels: tuple = ()):
        super().__init__(name, labels)
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def reset(self) -> None:
        """Zero the counter (benchmark warmup resets, not production)."""
        with self._lock:
            self._value = 0.0


class Gauge(_Metric):
    """Last-write-wins instantaneous value.  Thread-safe."""

    __slots__ = ("_value",)

    def __init__(self, name: str, labels: tuple = ()):
        super().__init__(name, labels)
        self._value = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value -= amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0.0


class Histogram(_Metric):
    """Fixed-bucket histogram with quantile queries; memory is O(buckets).

    `bounds` are ascending upper edges (log-spaced for latency/score use);
    observations above the last edge land in an implicit +inf bucket whose
    quantile estimate is the tracked exact max.  `observe()` is thread-safe
    and O(log buckets) (bisect).  `quantile(q)` interpolates geometrically
    inside the winning bucket, so with `log_buckets(per_decade=k)` the
    relative error vs the exact quantile is bounded by one bucket ratio
    (10**(1/k) - 1).
    """

    __slots__ = ("bounds", "_counts", "_count", "_sum", "_max", "_min")

    def __init__(self, name: str, labels: tuple = (),
                 bounds: tuple = DEFAULT_LATENCY_BUCKETS):
        super().__init__(name, labels)
        b = tuple(float(x) for x in bounds)
        if list(b) != sorted(set(b)):
            raise ValueError("histogram bounds must be strictly ascending")
        self.bounds = b
        self._counts = [0] * (len(b) + 1)      # last = +inf overflow
        self._count = 0
        self._sum = 0.0
        self._max = 0.0
        self._min = math.inf

    # ------------------------------------------------------------------ #
    def observe(self, value: float) -> None:
        v = float(value)
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += v
            if v > self._max:
                self._max = v
            if v < self._min:
                self._min = v

    # ------------------------------------------------------------------ #
    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    @property
    def max(self) -> float:
        with self._lock:
            return self._max

    def quantile(self, q: float) -> float:
        """Approximate q-quantile (0 <= q <= 1) from the bucket counts."""
        if not 0.0 <= q <= 1.0:
            raise ValueError("quantile must be in [0, 1]")
        with self._lock:
            if self._count == 0:
                return 0.0
            rank = q * self._count
            cum = 0
            for i, c in enumerate(self._counts):
                if c == 0:
                    continue
                if cum + c >= rank:
                    # geometric interpolation inside bucket i; the bucket's
                    # lower edge is clamped to the observed min, its upper
                    # edge to the observed max (exact endpoints beat edges)
                    lo = self.bounds[i - 1] if i > 0 else self._min
                    hi = self.bounds[i] if i < len(self.bounds) else self._max
                    lo = max(min(lo, self._max), min(self._min, hi), 1e-300)
                    hi = min(max(hi, lo), self._max)
                    frac = (rank - cum) / c
                    return lo * (hi / lo) ** frac if hi > lo > 0 else hi
                cum += c
            return self._max

    def reset(self) -> None:
        with self._lock:
            self._counts = [0] * (len(self.bounds) + 1)
            self._count = 0
            self._sum = 0.0
            self._max = 0.0
            self._min = math.inf


class _Family:
    """One metric name: shared help/type/unit + label-keyed children."""

    __slots__ = ("name", "kind", "help", "unit", "bounds", "children")

    def __init__(self, name, kind, help, unit, bounds):
        self.name = name
        self.kind = kind            # "counter" | "gauge" | "histogram"
        self.help = help
        self.unit = unit
        self.bounds = bounds
        self.children: dict[tuple, _Metric] = {}


_CLS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricRegistry:
    """Families of counters/gauges/histograms; see module docstring.

    All three accessors are GET-OR-CREATE on (name, labels): layers resolve
    their instruments at construction time and hold the child references on
    the hot path (dict lookups stay off the tick).  Re-registering a name
    with a different type raises — one name, one meaning.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._families: dict[str, _Family] = {}

    # ------------------------------------------------------------------ #
    def _get(self, kind: str, name: str, help: str, unit: str,
             labels: dict | None, bounds: tuple | None):
        key = tuple(sorted((str(k), str(v))
                           for k, v in (labels or {}).items()))
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = _Family(name, kind, help, unit,
                              bounds or DEFAULT_LATENCY_BUCKETS)
                self._families[name] = fam
            elif fam.kind != kind:
                raise ValueError(f"metric {name!r} already registered as "
                                 f"{fam.kind}, not {kind}")
            child = fam.children.get(key)
            if child is None:
                if kind == "histogram":
                    child = Histogram(name, key, bounds=fam.bounds)
                else:
                    child = _CLS[kind](name, key)
                fam.children[key] = child
            return child

    def counter(self, name: str, help: str = "", unit: str = "",
                labels: dict | None = None) -> Counter:
        return self._get("counter", name, help, unit, labels, None)

    def gauge(self, name: str, help: str = "", unit: str = "",
              labels: dict | None = None) -> Gauge:
        return self._get("gauge", name, help, unit, labels, None)

    def histogram(self, name: str, help: str = "", unit: str = "",
                  labels: dict | None = None,
                  bounds: tuple | None = None) -> Histogram:
        return self._get("histogram", name, help, unit, labels, bounds)

    # ------------------------------------------------------------------ #
    def families(self) -> list[_Family]:
        with self._lock:
            return list(self._families.values())

    def expose(self) -> str:
        """Prometheus text exposition of every family, labels included.

        Histograms render as cumulative `_bucket{le="..."}` series plus
        `_sum` and `_count` (the standard scrape shape; a Grafana
        `histogram_quantile()` works unmodified).  Scrape it from whatever
        HTTP handler the deployment runs — the registry itself is
        transport-free.
        """
        out: list[str] = []
        for fam in self.families():
            if fam.help:
                out.append(f"# HELP {fam.name} {fam.help}")
            out.append(f"# TYPE {fam.name} {fam.kind}")
            for child in fam.children.values():
                if fam.kind == "histogram":
                    out.extend(_expose_histogram(child))
                else:
                    out.append(f"{fam.name}{child.label_str()} "
                               f"{_fmt(child.value)}")
        return "\n".join(out) + "\n"

    def snapshot(self) -> dict:
        """JSON-able state dump: {name: {kind, help, unit, series: [...]}}.

        Histogram series carry count/sum/max and the derived p50/p90/p99 so
        a snapshot is directly plottable without re-deriving quantiles.
        """
        snap: dict = {}
        for fam in self.families():
            series = []
            for child in fam.children.values():
                entry: dict = {"labels": dict(child.labels)}
                if fam.kind == "histogram":
                    entry.update(count=child.count, sum=child.sum,
                                 max=child.max,
                                 p50=child.quantile(0.5),
                                 p90=child.quantile(0.9),
                                 p99=child.quantile(0.99))
                else:
                    entry["value"] = child.value
                series.append(entry)
            snap[fam.name] = {"kind": fam.kind, "help": fam.help,
                              "unit": fam.unit, "series": series}
        return snap


def _fmt(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    return repr(v) if v != int(v) else str(int(v))


def _expose_histogram(h: Histogram) -> list[str]:
    base = dict(h.labels)
    lines = []
    with h._lock:
        counts, bounds = list(h._counts), h.bounds
        total, s = h._count, h._sum
    cum = 0
    for edge, c in zip(tuple(bounds) + (math.inf,), counts):
        cum += c
        lab = dict(base)
        lab["le"] = "+Inf" if edge == math.inf else repr(edge)
        inner = ",".join(f'{k}="{v}"' for k, v in sorted(lab.items()))
        lines.append(f"{h.name}_bucket{{{inner}}} {cum}")
    lines.append(f"{h.name}_sum{h.label_str()} {_fmt(s)}")
    lines.append(f"{h.name}_count{h.label_str()} {total}")
    return lines
