"""Observability for the twin-serving stack: metrics, tracing, exporters.

Dependency-free (stdlib only — no JAX, no numpy) so it can be imported from
any layer, including host-side threads that must never touch device state.

Modules
-------
registry.py   `MetricRegistry` — thread-safe counters / gauges / fixed-bucket
              log-spaced histograms with p50/p90/p99/max queries, grouped
              into label-keyed families.  `expose()` renders Prometheus text
              exposition; `snapshot()` a JSON-able dump.  Bounded memory:
              histograms are O(buckets) no matter how long the server runs.

tracing.py    `Tracer` — nested spans around the serving stages
              (tick -> flush/guard/schedule/refit, pump flushes, per-shard
              ticks), recorded into a ring-bounded buffer and exported as
              Chrome trace-event JSON loadable in Perfetto.  `sample_every`
              records every Nth root span's subtree; `enabled=False` makes
              spans no-op context managers (near-free).

exporters.py  `SnapshotWriter` — periodic (atomic) JSON snapshot file of the
              registry, for deployments without scrape infrastructure.

The serving integration (which metric names exist, the span hierarchy, how
to scrape) is catalogued in docs/OBSERVABILITY.md.
"""
from repro.obs.exporters import SnapshotWriter
from repro.obs.registry import (Counter, Gauge, Histogram, MetricRegistry,
                                DEFAULT_LATENCY_BUCKETS,
                                DEFAULT_SCORE_BUCKETS, log_buckets)
from repro.obs.tracing import NULL_SPAN, Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricRegistry", "log_buckets",
    "DEFAULT_LATENCY_BUCKETS", "DEFAULT_SCORE_BUCKETS",
    "Tracer", "NULL_SPAN", "SnapshotWriter",
]
