"""Pure-jnp oracle for the fused RK4 polynomial-ODE integrator.

Contract (shared with the Pallas kernel):
  rk4_poly_solve(theta [B, n, L], y0 [B, n], us [B, T, m], dt,
                 term_indices [L, O]) -> ys [B, T+1, n]

integrating  dY/dt = theta @ Phi(Y, u)  with zero-order-hold inputs, where
Phi_l = prod_o Xaug[term_indices[l, o]] and Xaug = [1, Y, U].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rk4_poly_solve_ref", "poly_features_ref"]


def poly_features_ref(y, u, term_indices):
    """y: [..., n], u: [..., m], term_indices: [L, O] -> Phi [..., L]."""
    aug = jnp.concatenate([jnp.ones_like(y[..., :1]), y, u], axis=-1)
    return jnp.prod(aug[..., jnp.asarray(term_indices)], axis=-1)


def rk4_poly_solve_ref(theta, y0, us, dt, term_indices):
    def rhs(y, u):
        phi = poly_features_ref(y, u, term_indices)          # [B, L]
        return jnp.einsum("bnl,bl->bn", theta, phi)

    def step(y, u):
        k1 = rhs(y, u)
        k2 = rhs(y + 0.5 * dt * k1, u)
        k3 = rhs(y + 0.5 * dt * k2, u)
        k4 = rhs(y + dt * k3, u)
        y = y + (dt / 6.0) * (k1 + 2 * k2 + 2 * k3 + k4)
        return y, y

    _, ys = jax.lax.scan(step, y0, jnp.swapaxes(us, 0, 1))
    return jnp.concatenate([y0[:, None], jnp.swapaxes(ys, 0, 1)], axis=1)
