"""Fused RK4 polynomial-ODE integrator — Pallas TPU kernel.

This is the `SOLVE(Y(0), Theta, U)` block of MERINDA: the part of the MR
pipeline prior FPGA ODE-solver work could NOT accelerate because the model
coefficients are input-dependent (they arrive per-instance from the dense
head).  On TPU we make it MXU-shaped:

  * Library evaluation uses GATHER-AS-MATMUL: Phi = prod_o (Xaug @ S_o) with
    precomputed one-hot selection matrices S_o [1+n+m, L].  TPU has no cheap
    lane gather; a small matmul against a one-hot matrix runs on the MXU and
    pipelines perfectly (the CORDIC-analogue trick of DESIGN.md §2).
  * Theta stays pinned in VMEM across all T steps / 4 stages (ARRAY_PARTITION
    analogue) — per-instance coefficients are loaded exactly once.
  * The batch grid double-buffers tiles (PIPELINE II=1 analogue).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["rk4_poly_solve_pallas", "selection_matrices"]


def selection_matrices(term_indices: np.ndarray, n_aug: int) -> np.ndarray:
    """term_indices [L, O] -> one-hot S [O, n_aug, L] with S[o, i, l] = 1 iff
    term l's o-th factor is Xaug[i]."""
    L, O = term_indices.shape
    sel = np.zeros((O, n_aug, L), dtype=np.float32)
    for o in range(O):
        sel[o, term_indices[:, o], np.arange(L)] = 1.0
    return sel


def _rk4_kernel(theta_ref, y0_ref, us_ref, sel_ref, ys_ref,
                *, dt: float, seq_len: int, order: int):
    theta = theta_ref[...].astype(jnp.float32)        # [Bt, n, L]
    sel = sel_ref[...].astype(jnp.float32)            # [O, n_aug, L]
    bt, n, L = theta.shape

    def rhs(y, u):
        ones = jnp.ones((bt, 1), jnp.float32)
        xaug = jnp.concatenate([ones, y, u], axis=-1)    # [Bt, 1+n+m]
        phi = jnp.ones((bt, L), jnp.float32)
        for o in range(order):                           # static unroll
            phi = phi * jnp.dot(xaug, sel[o],
                                preferred_element_type=jnp.float32)
        return jnp.sum(phi[:, None, :] * theta, axis=-1)  # [Bt, n]

    def step(t, y):
        u = us_ref[:, t, :].astype(jnp.float32)
        k1 = rhs(y, u)
        k2 = rhs(y + 0.5 * dt * k1, u)
        k3 = rhs(y + 0.5 * dt * k2, u)
        k4 = rhs(y + dt * k3, u)
        y = y + (dt / 6.0) * (k1 + 2.0 * k2 + 2.0 * k3 + k4)
        ys_ref[:, t + 1, :] = y.astype(ys_ref.dtype)
        return y

    y0 = y0_ref[...].astype(jnp.float32)
    ys_ref[:, 0, :] = y0.astype(ys_ref.dtype)
    jax.lax.fori_loop(0, seq_len, step, y0)


def rk4_poly_solve_pallas(theta, y0, us, dt, sel, *, block_b: int = 8,
                          interpret: bool = False):
    """theta: [B, n, L], y0: [B, n], us: [B, T, m], sel: [O, n_aug, L]
    -> ys [B, T+1, n].  B must be a multiple of block_b (ops.py pads)."""
    B, n, L = theta.shape
    T = us.shape[1]
    m = us.shape[2]
    O, n_aug, _ = sel.shape
    assert n_aug == 1 + n + m, (n_aug, n, m)
    assert B % block_b == 0

    kernel = functools.partial(_rk4_kernel, dt=float(dt), seq_len=T, order=O)
    ys = pl.pallas_call(
        kernel,
        grid=(B // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, n, L), lambda i: (i, 0, 0)),    # theta tile
            pl.BlockSpec((block_b, n), lambda i: (i, 0)),          # y0 tile
            pl.BlockSpec((block_b, T, m), lambda i: (i, 0, 0)),    # us tile
            pl.BlockSpec((O, n_aug, L), lambda i: (0, 0, 0)),      # sel (pinned)
        ],
        out_specs=pl.BlockSpec((block_b, T + 1, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T + 1, n), theta.dtype),
        interpret=interpret,
    )(theta, y0, us, sel)
    return ys
