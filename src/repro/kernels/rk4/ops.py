"""Jit'd public wrapper for the fused RK4 polynomial-ODE integrator.

Same serving-hot-path contract as kernels/gru/ops.py: the Pallas forward is
paired with a custom-VJP backward that replays the pure-jnp reference, so the
fleet train step (``jax.vmap(jax.value_and_grad)`` over refit slots) and the
divergence guard's fused rollouts both run the kernel with
``use_pallas=True``.  Batch padding is pow2-bucketed (kernels/backend) so
varying caller batch widths produce a log-bounded set of kernel shapes, and
extra leading axes on theta/y0/us are folded into the batch axis (the
fleet-shaped batched entry — RK4 coefficients are per-instance operands, so
folding is exact).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.backend import bucket_pow2, pad_batch, resolve_interpret
from repro.kernels.rk4.ref import rk4_poly_solve_ref
from repro.kernels.rk4.rk4 import rk4_poly_solve_pallas, selection_matrices


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _rk4_pallas(dt, library, block_b, interpret, theta, y0, us):
    """Pallas forward with reference backward; see `_rk4_pallas_bwd`."""
    # Pallas BlockSpecs cannot carry zero-width dims: for autonomous systems
    # (m == 0) pad a dummy zero input channel; its selection row stays cold.
    if library.m == 0:
        us = jnp.zeros(us.shape[:2] + (1,), us.dtype)
    sel = jnp.asarray(selection_matrices(np.asarray(library.term_indices),
                                         1 + library.n + max(library.m, 1)))
    B = theta.shape[0]
    Bp = bucket_pow2(B, block_b)
    ys = rk4_poly_solve_pallas(pad_batch(theta, Bp), pad_batch(y0, Bp),
                               pad_batch(us, Bp), dt, sel,
                               block_b=block_b, interpret=interpret)
    return ys[:B]


def _rk4_pallas_fwd(dt, library, block_b, interpret, theta, y0, us):
    return (_rk4_pallas(dt, library, block_b, interpret, theta, y0, us),
            (theta, y0, us))


def _rk4_pallas_bwd(dt, library, block_b, interpret, residuals, ct):
    # Backward replays the jnp reference: pallas_call is not differentiable,
    # and the reference IS the kernel's semantic contract (parity-tested).
    theta, y0, us = residuals
    ref = partial(rk4_poly_solve_ref, dt=dt,
                  term_indices=np.asarray(library.term_indices))
    _, vjp = jax.vjp(lambda th, y, u: ref(th, y, u), theta, y0, us)
    return vjp(ct)


_rk4_pallas.defvjp(_rk4_pallas_fwd, _rk4_pallas_bwd)


@partial(jax.jit, static_argnames=("dt", "library", "use_pallas", "interpret",
                                   "block_b"))
def rk4_poly_solve(theta, y0, us, *, dt: float, library,
                   use_pallas: bool = False, interpret: bool | None = None,
                   block_b: int = 8):
    """Integrate dY = theta @ Phi(Y, u) for T steps.

    theta: [B, n, L], y0: [B, n], us: [B, T, m] -> ys [B, T+1, n].
    `library` is a repro.core.library.PolyLibrary (hashable static).

    Extra leading axes ([..., B, n, L] etc.) are folded into the batch axis.
    ``interpret=None`` resolves via kernels/backend (compiled on TPU,
    interpreter elsewhere).
    """
    n, L = theta.shape[-2:]
    if n != library.n or L != library.size:
        raise ValueError(f"theta {theta.shape} inconsistent with library "
                         f"(n={library.n}, L={library.size})")
    if y0.shape[-1] != n or us.shape[-1] != library.m \
            or theta.shape[:-2] != y0.shape[:-1] \
            or theta.shape[:-2] != us.shape[:-2]:
        raise ValueError(f"theta {theta.shape} / y0 {y0.shape} / us "
                         f"{us.shape} batch or channel axes disagree "
                         f"(library n={library.n}, m={library.m})")
    term_indices = np.asarray(library.term_indices)
    lead = theta.shape[:-2]
    if theta.ndim > 3:        # fleet-shaped batched entry: fold leading axes
        T = us.shape[-2]
        # explicit flat batch size: reshape(-1) cannot infer it for
        # autonomous systems (m == 0 makes us a zero-size array)
        Bf = int(np.prod(lead))
        theta = theta.reshape((Bf, n, L))
        y0 = y0.reshape((Bf, n))
        us = us.reshape((Bf, T, library.m))
    if use_pallas:
        ys = _rk4_pallas(dt, library, block_b, resolve_interpret(interpret),
                         theta, y0, us)
    else:
        ys = rk4_poly_solve_ref(theta, y0, us, dt, term_indices)
    return ys.reshape(lead + ys.shape[1:]) if len(lead) > 1 else ys
