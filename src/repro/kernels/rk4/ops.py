"""Jit'd public wrapper for the fused RK4 polynomial-ODE integrator."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.rk4.ref import rk4_poly_solve_ref
from repro.kernels.rk4.rk4 import rk4_poly_solve_pallas, selection_matrices


@partial(jax.jit, static_argnames=("dt", "library", "use_pallas", "interpret",
                                   "block_b"))
def rk4_poly_solve(theta, y0, us, *, dt: float, library,
                   use_pallas: bool = False, interpret: bool = True,
                   block_b: int = 8):
    """Integrate dY = theta @ Phi(Y, u) for T steps.

    theta: [B, n, L], y0: [B, n], us: [B, T, m] -> ys [B, T+1, n].
    `library` is a repro.core.library.PolyLibrary (hashable static).
    """
    term_indices = np.asarray(library.term_indices)
    if not use_pallas:
        return rk4_poly_solve_ref(theta, y0, us, dt, term_indices)

    # Pallas BlockSpecs cannot carry zero-width dims: for autonomous systems
    # (m == 0) pad a dummy zero input channel; its selection row stays cold.
    if library.m == 0:
        us = jnp.zeros(us.shape[:2] + (1,), us.dtype)
    sel = jnp.asarray(selection_matrices(term_indices,
                                         1 + library.n + max(library.m, 1)))
    B = theta.shape[0]
    pad = (-B) % block_b
    if pad:
        theta = jnp.pad(theta, ((0, pad), (0, 0), (0, 0)))
        y0 = jnp.pad(y0, ((0, pad), (0, 0)))
        us = jnp.pad(us, ((0, pad), (0, 0), (0, 0)))
    ys = rk4_poly_solve_pallas(theta, y0, us, dt, sel, block_b=block_b,
                               interpret=interpret)
    return ys[:B] if pad else ys
