"""Fused GRU sequence-scan Pallas TPU kernel — the paper's accelerated core.

FPGA -> TPU mapping (DESIGN.md §2):
  * ARRAY_PARTITION complete  -> Wx/Wh/b pinned in VMEM for the whole scan
    (BlockSpec index_map broadcasts the full weight block to every grid step),
    and the per-timestep input projections hoisted into ONE MXU matmul.
  * PIPELINE II=1             -> the pallas grid pipelines batch tiles:
    while tile i computes, tile i+1's activations are DMA'd HBM->VMEM.
  * Operations 1-3 fusion     -> z/r share a single [H, 2H] matmul; the
    candidate is a second [H, H] matmul; all gate elementwise math stays in
    registers (VPU) — no HBM round-trips between timesteps.

Block shapes are padded to (8, 128) multiples by the wrapper (ops.py) so MXU
matmul dims are hardware-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gru_scan_pallas"]


def _gru_kernel(xs_ref, h0_ref, wx_ref, wh_ref, b_ref, hs_ref, hT_ref,
                *, hidden: int, seq_len: int):
    """One batch tile: hoisted input matmul + fused recurrent scan."""
    H = hidden
    xs = xs_ref[...]                                  # [Bt, T, Din]
    bt, T, d_in = xs.shape
    wx = wx_ref[...]                                  # [Din, 3H]
    wh = wh_ref[...]                                  # [H, 3H]
    b = b_ref[...]                                    # [1, 3H]

    # --- Stage 1: hoist all T input projections into one MXU matmul. ------
    xp = jnp.dot(xs.reshape(bt * T, d_in), wx,
                 preferred_element_type=jnp.float32)
    xp = (xp + b).reshape(bt, T, 3 * H)

    wh_zr = wh[:, :2 * H]
    wh_c = wh[:, 2 * H:]

    # --- Stage 2: recurrent scan, weights resident in VMEM. ---------------
    def step(t, h):
        xp_t = xp[:, t, :]                            # [Bt, 3H]
        hp = jnp.dot(h, wh_zr, preferred_element_type=jnp.float32)
        z = jax.nn.sigmoid(xp_t[:, :H] + hp[:, :H])
        r = jax.nn.sigmoid(xp_t[:, H:2 * H] + hp[:, H:])
        c = jnp.tanh(xp_t[:, 2 * H:]
                     + jnp.dot(r * h, wh_c, preferred_element_type=jnp.float32))
        h = (1.0 - z) * h + z * c
        hs_ref[:, t, :] = h.astype(hs_ref.dtype)
        return h

    h = h0_ref[...].astype(jnp.float32)
    h = jax.lax.fori_loop(0, seq_len, step, h)
    hT_ref[...] = h.astype(hT_ref.dtype)


def gru_scan_pallas(xs, h0, wx, wh, b, *, block_b: int = 8,
                    interpret: bool = False):
    """xs: [B, T, Din], h0: [B, H] -> (hs [B, T, H], hT [B, H]).

    B must be a multiple of block_b (ops.py pads).  Weights are mapped fully
    into VMEM (index_map -> block 0) for every batch-tile grid step.
    """
    B, T, d_in = xs.shape
    H = h0.shape[-1]
    assert B % block_b == 0, (B, block_b)
    b2 = b.reshape(1, -1)

    grid = (B // block_b,)
    kernel = functools.partial(_gru_kernel, hidden=H, seq_len=T)
    hs, hT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, T, d_in), lambda i: (i, 0, 0)),   # xs tile
            pl.BlockSpec((block_b, H), lambda i: (i, 0)),            # h0 tile
            pl.BlockSpec((d_in, 3 * H), lambda i: (0, 0)),           # Wx (pinned)
            pl.BlockSpec((H, 3 * H), lambda i: (0, 0)),              # Wh (pinned)
            pl.BlockSpec((1, 3 * H), lambda i: (0, 0)),              # b  (pinned)
        ],
        out_specs=[
            pl.BlockSpec((block_b, T, H), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, H), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H), xs.dtype),
            jax.ShapeDtypeStruct((B, H), h0.dtype),
        ],
        interpret=interpret,
    )(xs, h0, wx, wh, b2)
    return hs, hT
