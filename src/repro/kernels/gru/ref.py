"""Pure-jnp oracle for the fused GRU sequence scan.

Single source of truth for the GRU math used everywhere (MERINDA encoder,
kernel tests, LM smoke paths).  Gate layout in the fused weight matrices is
[z | r | c] along the last axis.

    z_t = sigmoid(x_t Wx[:, :H]   + h Wh[:, :H]   + b[:H])
    r_t = sigmoid(x_t Wx[:, H:2H] + h Wh[:, H:2H] + b[H:2H])
    c_t = tanh   (x_t Wx[:, 2H:]  + (r_t * h) Wh[:, 2H:] + b[2H:])
    h_t = (1 - z_t) * h + z_t * c_t

matching the paper's Operations 1-3 (gates, reset-apply, candidate).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["gru_cell_ref", "gru_scan_ref", "init_gru_params"]


def init_gru_params(key, d_in: int, hidden: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    sx = 1.0 / jnp.sqrt(d_in)
    sh = 1.0 / jnp.sqrt(hidden)
    return {
        "wx": (jax.random.uniform(k1, (d_in, 3 * hidden), minval=-sx, maxval=sx)
               .astype(dtype)),
        "wh": (jax.random.uniform(k2, (hidden, 3 * hidden), minval=-sh, maxval=sh)
               .astype(dtype)),
        "b": jnp.zeros((3 * hidden,), dtype),
    }


def gru_cell_ref(h, x, wx, wh, b):
    """One GRU step. h: [..., H], x: [..., Din] -> new h."""
    H = h.shape[-1]
    xp = x @ wx + b                                   # [..., 3H]
    hp2 = h @ wh[:, :2 * H]                           # z/r hidden contribution
    z = jax.nn.sigmoid(xp[..., :H] + hp2[..., :H])
    r = jax.nn.sigmoid(xp[..., H:2 * H] + hp2[..., H:])
    c = jnp.tanh(xp[..., 2 * H:] + (r * h) @ wh[:, 2 * H:])
    return (1.0 - z) * h + z * c


def gru_scan_ref(xs, h0, wx, wh, b):
    """Scan the GRU over time.

    xs: [B, T, Din], h0: [B, H] -> (hs [B, T, H], hT [B, H]).
    """
    # Hoisted input projection: one large MXU matmul for every timestep
    # (the TPU analogue of ARRAY_PARTITION; see DESIGN.md §2).
    H = h0.shape[-1]
    xp = xs @ wx + b                                   # [B, T, 3H]

    def step(h, xp_t):
        hp2 = h @ wh[:, :2 * H]
        z = jax.nn.sigmoid(xp_t[..., :H] + hp2[..., :H])
        r = jax.nn.sigmoid(xp_t[..., H:2 * H] + hp2[..., H:])
        c = jnp.tanh(xp_t[..., 2 * H:] + (r * h) @ wh[:, 2 * H:])
        h = (1.0 - z) * h + z * c
        return h, h

    hT, hs = jax.lax.scan(step, h0, jnp.swapaxes(xp, 0, 1))
    return jnp.swapaxes(hs, 0, 1), hT
