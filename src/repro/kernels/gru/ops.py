"""Jit'd public wrapper for the GRU scan: backend dispatch, batch padding,
and the custom-VJP rule that makes the Pallas path trainable.

The serving hot path calls this from inside ``jax.vmap(jax.value_and_grad)``
(FleetMerinda.train_step: one fused step over every refit slot, per-twin
weights).  Two things make that work with ``use_pallas=True``:

  * **custom_vjp** — `pallas_call` has no autodiff rule, so the Pallas
    forward is paired with a backward that replays the pure-jnp reference
    (kernels/gru/ref.py) under ``jax.vjp``.  Forward math and backward math
    agree to kernel-parity tolerance (CI-gated in tests/test_hotpath_parity),
    so gradients are exact w.r.t. the reference semantics at the cost of one
    extra reference forward in the backward pass.
  * **vmap batching** — `pallas_call` carries a batching rule that turns a
    vmapped invocation into an extra grid axis, so fleet-shaped calls
    (per-twin weights) run as one kernel launch over a (fleet, batch-tile)
    grid.  Wrappers also accept extra leading batch axes directly when the
    weights are shared (xs [..., B, T, Din] flattened into the batch axis).

Batch padding is pow2-bucketed (kernels/backend.bucket_pow2): the padded
batch is ``block_b * 2**k``, matching the pow2 flush quanta the ingestion
path already produces, so a varying caller batch axis can only generate a
log-bounded set of kernel shapes.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.backend import bucket_pow2, pad_batch, resolve_interpret
from repro.kernels.gru.gru import gru_scan_pallas
from repro.kernels.gru.ref import gru_scan_ref


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _gru_pallas(block_b, interpret, xs, h0, wx, wh, b):
    """Pallas forward with reference backward; see `_gru_pallas_bwd`."""
    B = xs.shape[0]
    Bp = bucket_pow2(B, block_b)
    hs, hT = gru_scan_pallas(pad_batch(xs, Bp), pad_batch(h0, Bp),
                             wx, wh, b, block_b=block_b, interpret=interpret)
    return hs[:B], hT[:B]


def _gru_pallas_fwd(block_b, interpret, xs, h0, wx, wh, b):
    return (_gru_pallas(block_b, interpret, xs, h0, wx, wh, b),
            (xs, h0, wx, wh, b))


def _gru_pallas_bwd(block_b, interpret, residuals, cts):
    # Backward replays the jnp reference: pallas_call is not differentiable,
    # and the reference IS the kernel's semantic contract (parity-tested).
    _, vjp = jax.vjp(gru_scan_ref, *residuals)
    return vjp(cts)


_gru_pallas.defvjp(_gru_pallas_fwd, _gru_pallas_bwd)


@partial(jax.jit, static_argnames=("use_pallas", "interpret", "block_b"))
def gru_scan(xs, h0, wx, wh, b, *, use_pallas: bool = False,
             interpret: bool | None = None, block_b: int = 8):
    """Fused GRU scan; see kernels/gru/ref.py for the math.

    xs: [B, T, Din], h0: [B, H], wx: [Din, 3H], wh: [H, 3H], b: [3H]
    -> (hs [B, T, H], hT [B, H]).

    Extra leading axes on xs/h0 (shared weights) are flattened into the
    batch axis for the kernel and restored on return.  ``interpret=None``
    resolves via kernels/backend (compiled on TPU, interpreter elsewhere).
    """
    H = h0.shape[-1]
    if wx.shape[-1] != 3 * H or wh.shape != (H, 3 * H) or b.shape[-1] != 3 * H:
        raise ValueError(f"GRU weight shapes {wx.shape}/{wh.shape}/{b.shape} "
                         f"inconsistent with hidden={H} (expect [*, 3H])")
    if xs.shape[:-2] != h0.shape[:-1] or xs.shape[-1] != wx.shape[0]:
        raise ValueError(f"xs {xs.shape} inconsistent with h0 {h0.shape} / "
                         f"wx {wx.shape}")
    lead = xs.shape[:-2]
    if xs.ndim > 3:           # shared-weight batched entry: fold leading axes
        T, d_in = xs.shape[-2:]
        xs = xs.reshape((-1, T, d_in))
        h0 = h0.reshape((-1, H))
    if use_pallas:
        hs, hT = _gru_pallas(block_b, resolve_interpret(interpret),
                             xs, h0, wx, wh, b)
    else:
        hs, hT = gru_scan_ref(xs, h0, wx, wh, b)
    if len(lead) > 1:
        hs, hT = hs.reshape(lead + hs.shape[1:]), hT.reshape(lead + (H,))
    return hs, hT
