"""Jit'd public wrapper for the GRU scan: pads to hardware-aligned tiles and
dispatches to the Pallas kernel (TPU) or the pure-jnp reference (CPU/dry-run).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.gru.gru import gru_scan_pallas
from repro.kernels.gru.ref import gru_scan_ref


def _pad_to(x, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


@partial(jax.jit, static_argnames=("use_pallas", "interpret", "block_b"))
def gru_scan(xs, h0, wx, wh, b, *, use_pallas: bool = False,
             interpret: bool = True, block_b: int = 8):
    """Fused GRU scan; see kernels/gru/ref.py for the math.

    xs: [B, T, Din], h0: [B, H], wx: [Din, 3H], wh: [H, 3H], b: [3H]
    -> (hs [B, T, H], hT [B, H])
    """
    if not use_pallas:
        return gru_scan_ref(xs, h0, wx, wh, b)
    xs_p, B = _pad_to(xs, 0, block_b)
    h0_p, _ = _pad_to(h0, 0, block_b)
    hs, hT = gru_scan_pallas(xs_p, h0_p, wx, wh, b,
                             block_b=block_b, interpret=interpret)
    return hs[:B], hT[:B]
