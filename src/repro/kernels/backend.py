"""Backend selection for the Pallas kernel wrappers — decided in ONE place.

Every kernel wrapper in this package (kernels/gru, kernels/rk4,
kernels/linear_scan) takes the same pair of knobs:

  * ``use_pallas`` — False runs the pure-jnp reference (always available,
    fully differentiable); True dispatches the Pallas kernel.
  * ``interpret``  — how the Pallas kernel executes.  ``None`` (the default
    everywhere) means AUTO: compiled on a TPU backend, interpreter mode on
    everything else (CPU CI, dry-runs).  Passing an explicit bool overrides
    auto — e.g. ``interpret=True`` on TPU to debug a kernel.

Historically each call site carried its own ``interpret: bool = True``
default, which silently pinned interpreter mode even on real hardware and
let the defaults drift apart between the training and guard paths (the
server's guard said ``interpret=True`` while its config said otherwise).
`resolve_interpret` is now the single source of truth; call sites pass
``None`` through and the decision happens here, once per process.

`bucket_pow2` is the companion shape policy: Pallas batch padding rounds the
tile count up to a power of two, so the number of DISTINCT kernel shapes a
varying batch axis can produce is log2-bounded — the same trade the
ingestion path makes for its flush shapes (see data/pipeline.prepare_flush).
The cost is bounded 2x scratch work on padded rows; the payoff is a compile
cache that cannot grow linearly with fleet size.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["resolve_interpret", "bucket_pow2", "pad_batch"]


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve the ``interpret`` knob: None = auto (compiled only on TPU)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def bucket_pow2(size: int, quantum: int) -> int:
    """Round ``size`` up to ``quantum * 2**k`` (the padded batch size).

    Static-shape helper (called at trace time on python ints): kernels see
    at most log2(max_batch / quantum) distinct batch widths.
    """
    if size <= 0:
        return quantum
    tiles = -(-size // quantum)
    return quantum * (1 << (tiles - 1).bit_length())


def pad_batch(x, target: int):
    """Zero-pad axis 0 of ``x`` to ``target`` rows (no-op when already there).

    The Pallas wrappers pad with zeros and slice the scratch rows off after
    the kernel; zero rows are safe for both kernels (GRU zero inputs, RK4
    zero coefficients) and never feed gradients (padding happens inside the
    custom-VJP forward, backward replays the unpadded reference).
    """
    if x.shape[0] == target:
        return x
    widths = [(0, 0)] * x.ndim
    widths[0] = (0, target - x.shape[0])
    return jnp.pad(x, widths)
