"""Pure-jnp oracles for the data-dependent-decay linear recurrence.

Unified recurrence (covers RWKV-6 time-mix and Mamba-2 SSD):

    S_t = diag(exp(w_t)) @ S_{t-1} + k_t^T v_t          S: [K, V]
    mode "ssd"  :  o_t = q_t @ S_t                       (read after update)
    mode "rwkv6":  o_t = q_t @ (S_{t-1} + diag(u) k_t^T v_t)
                                                         (read before update,
                                                          bonus u for current)

Shapes: q, k, w: [B, H, T, K]; v: [B, H, T, V]; u (bonus): [H, K] or None.
w is the LOG decay (<= 0).  initial_state: [B, H, K, V] or None (zeros).
Both functions return (o [B, H, T, V] f32, final_state [B, H, K, V] f32).

Two references:
  * linear_scan_seq   — exact per-step lax.scan (the oracle)
  * linear_scan_chunked — chunk-parallel formulation (intra-chunk masked
    matmul + inter-chunk state carry).  This is the formulation the Pallas
    kernel implements and the formulation the LM models run on the XLA path
    (it is MXU-shaped: the paper's "make the recurrence matmul-sized" insight
    applied to the assigned recurrent architectures).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["linear_scan_seq", "linear_scan_chunked"]


def _seq_one(q, k, v, w, u, S0, mode: str):
    """Single (b, h): q,k,w [T,K], v [T,V], u [K] or None, S0 [K,V]."""

    def step(S, inp):
        q_t, k_t, v_t, w_t = inp
        kv = jnp.outer(k_t, v_t)
        if mode == "rwkv6":
            bonus = kv * u[:, None] if u is not None else kv
            o_t = q_t @ (S + bonus)
            S = jnp.exp(w_t)[:, None] * S + kv
        else:  # ssd
            S = jnp.exp(w_t)[:, None] * S + kv
            o_t = q_t @ S
        return S, o_t

    S, os = jax.lax.scan(step, S0.astype(jnp.float32),
                         (q.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32), w.astype(jnp.float32)))
    return os, S


@partial(jax.jit, static_argnames=("mode",))
def linear_scan_seq(q, k, v, w, u=None, mode: str = "ssd",
                    initial_state=None):
    """Exact sequential oracle. Returns (o [B,H,T,V], S_final [B,H,K,V])."""
    B, H, _, K = q.shape
    V = v.shape[-1]
    if initial_state is None:
        initial_state = jnp.zeros((B, H, K, V), jnp.float32)

    def per_head(h, args):
        qq, kk, vv, ww, ss = args
        uu = None if u is None else u[h]
        return _seq_one(qq, kk, vv, ww, uu, ss, mode)

    def per_batch(qb, kb, vb, wb, sb):
        return jax.vmap(per_head)(jnp.arange(H), (qb, kb, vb, wb, sb))

    return jax.vmap(per_batch)(q, k, v, w, initial_state)


@partial(jax.jit, static_argnames=("mode", "chunk"))
def linear_scan_chunked(q, k, v, w, u=None, mode: str = "ssd",
                        chunk: int = 64, initial_state=None):
    """Chunk-parallel formulation; numerically stable (all decay factors are
    exp of non-positive differences).  Matches linear_scan_seq to fp32
    tolerance for any chunk size."""
    B, H, T, K = q.shape
    V = v.shape[-1]
    C = min(chunk, T)
    pad = (-T) % C
    if pad:
        zq = lambda x: jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        q, k, v, w = zq(q), zq(k), zq(v), zq(w)
    Tp = T + pad
    N = Tp // C

    f32 = jnp.float32
    # One sequential scan over chunks: per-step working set is a single
    # [B, H, C, C, K] decay tile (materializing all N chunks at once costs
    # N x more and blew the 81-layer Mamba2 cells out of HBM — §Dry-run).
    # Inputs keep their dtype; the f32 upcast happens on per-chunk tiles
    # inside the (rematerialized) step.
    qc = jnp.moveaxis(q.reshape(B, H, N, C, K), 2, 0)
    kc = jnp.moveaxis(k.reshape(B, H, N, C, K), 2, 0)
    vc = jnp.moveaxis(v.reshape(B, H, N, C, V), 2, 0)
    wc = jnp.moveaxis(w.reshape(B, H, N, C, K), 2, 0).astype(f32)

    if mode == "rwkv6":
        mask = jnp.tril(jnp.ones((C, C), bool), k=-1)  # strict causal
    else:
        mask = jnp.tril(jnp.ones((C, C), bool))
    uf = None if u is None else u.astype(f32)

    def chunk_step(S, inp):
        qn, kn, vn, wn = inp                           # [B,H,C,K/V]
        qn = qn.astype(f32)
        kn = kn.astype(f32)
        vn = vn.astype(f32)
        cw = jnp.cumsum(wn, axis=-2)                   # inclusive log-decay
        cw_read = cw - wn if mode == "rwkv6" else cw
        # intra-chunk pair decays D[t,s,k] = exp(cw_read[t] - cw[s]), masked
        diff = cw_read[..., :, None, :] - cw[..., None, :, :]  # [B,H,C,C,K]
        D = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
        P = jnp.einsum("bhtk,bhsk,bhtsk->bhts", qn, kn, D)
        o = P @ vn                                     # [B,H,C,V]
        if mode == "rwkv6":
            if uf is not None:
                diag = jnp.einsum("bhtk,hk,bhtk->bht", qn, uf, kn)
            else:
                diag = jnp.einsum("bhtk,bhtk->bht", qn, kn)
            o = o + diag[..., None] * vn
        # inter-chunk: read carried state with decay since chunk start
        q_read = qn * jnp.exp(cw_read)
        o = o + jnp.einsum("bhck,bhkv->bhcv", q_read, S)
        # state update
        A_end = jnp.exp(cw[:, :, -1, :])               # [B,H,K]
        kd = kn * jnp.exp(cw[:, :, -1:, :] - cw)
        dS = jnp.einsum("bhck,bhcv->bhkv", kd, vn)
        return A_end[..., None] * S + dS, o

    if initial_state is None:
        S0 = jnp.zeros((B, H, K, V), f32)
    else:
        S0 = initial_state.astype(f32)
    # remat the step: without it, backward saves every chunk's [B,H,C,C,K]
    # decay tile simultaneously (1.75 GiB/layer on zamba2 — §Dry-run iter 3).
    S_final, os = jax.lax.scan(jax.checkpoint(chunk_step), S0,
                               (qc, kc, vc, wc))
    o = jnp.moveaxis(os, 0, 2).reshape(B, H, Tp, V)
    return o[:, :, :T], S_final
