"""Jit'd public wrapper for the chunked linear recurrence (RWKV-6 / SSD).

Dispatches to the Pallas TPU kernel or the pure-jnp chunked reference; both
implement the identical chunk-parallel math (see ref.py docstring).
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.backend import resolve_interpret
from repro.kernels.linear_scan.ref import linear_scan_chunked

__all__ = ["linear_scan"]


@partial(jax.jit, static_argnames=("mode", "chunk", "use_pallas", "interpret"))
def linear_scan(q, k, v, w, u=None, *, mode: str = "ssd", chunk: int = 64,
                initial_state=None, use_pallas: bool = False,
                interpret: bool | None = None):
    """q, k, w: [B, H, T, K]; v: [B, H, T, V]; u: [H, K] or None.

    Returns (o [B, H, T, V] f32, final_state [B, H, K, V] f32).
    ``interpret=None`` resolves via kernels/backend (compiled on TPU only).
    """
    if not use_pallas:
        return linear_scan_chunked(q, k, v, w, u, mode=mode, chunk=chunk,
                                   initial_state=initial_state)
    from repro.kernels.linear_scan.linear_scan import linear_scan_pallas
    return linear_scan_pallas(q, k, v, w, u, mode=mode, chunk=chunk,
                              initial_state=initial_state,
                              interpret=resolve_interpret(interpret))
