"""Chunked data-dependent-decay linear recurrence — Pallas TPU kernel.

The recurrent-LM analogue of the paper's GRU strategy (DESIGN.md §5):
  * ARRAY_PARTITION  -> the [K, V] state lives in a VMEM scratch for the
    whole sequence; chunk inputs stream HBM->VMEM via the grid pipeline.
  * PIPELINE II=1    -> grid = (B*H, N_chunks): while chunk n computes,
    chunk n+1 DMAs in (Pallas double-buffering), and the B*H axis gives
    embarrassing parallelism across cores.
  * "make it MXU-shaped" -> intra-chunk work is two [C,K]x[K,C]-class
    matmuls + one [C,C]x[C,V] matmul instead of T sequential rank-1 updates.

Math identical to kernels/linear_scan/ref.py::linear_scan_chunked (the
oracle); modes "ssd" (read-after-update) and "rwkv6" (read-before-update
with bonus u).  All internal math f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["linear_scan_pallas"]


def _ls_kernel(q_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sf_ref,
               state, *, chunk: int, mode: str):
    n = pl.program_id(1)
    C = chunk

    @pl.when(n == 0)
    def _init():
        state[...] = s0_ref[0].astype(jnp.float32)

    q = q_ref[0].astype(jnp.float32)                    # [C, K]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)                    # [C, V]
    w = w_ref[0].astype(jnp.float32)                    # [C, K] log decay

    cw = jnp.cumsum(w, axis=0)                          # inclusive
    row = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    if mode == "rwkv6":
        cw_read = cw - w                                # exclusive
        mask = col < row                                # strict causal
    else:
        cw_read = cw
        mask = col <= row

    # intra-chunk: P[t,s] = sum_k q[t,k] k[s,k] exp(cw_read[t,k] - cw[s,k])
    diff = cw_read[:, None, :] - cw[None, :, :]         # [C, C, K]
    D = jnp.where(mask[:, :, None], jnp.exp(diff), 0.0)
    P = jnp.einsum("tk,sk,tsk->ts", q, k, D)            # [C, C]
    o = jnp.dot(P, v, preferred_element_type=jnp.float32)

    if mode == "rwkv6":
        u = u_ref[0].astype(jnp.float32)                # [K]
        diag = jnp.sum(q * u[None, :] * k, axis=-1)     # [C]
        o = o + diag[:, None] * v

    # inter-chunk: read the carried state.
    S_in = state[...]                                   # [K, V]
    q_read = q * jnp.exp(cw_read)
    o = o + jnp.dot(q_read, S_in, preferred_element_type=jnp.float32)

    # state update: S_out = diag(A_end) S_in + sum_s diag(A_end/A_s) k_s v_s^T
    A_end = jnp.exp(cw[-1, :])                          # [K]
    kd = k * jnp.exp(cw[-1:, :] - cw)                   # [C, K]
    dS = jnp.dot(kd.T, v, preferred_element_type=jnp.float32)
    S_out = A_end[:, None] * S_in + dS
    state[...] = S_out

    o_ref[0] = o.astype(o_ref.dtype)
    sf_ref[0] = S_out.astype(sf_ref.dtype)


def linear_scan_pallas(q, k, v, w, u=None, *, mode: str = "ssd",
                       chunk: int = 64, initial_state=None,
                       interpret: bool = True):
    """q, k, w: [B, H, T, K]; v: [B, H, T, V]; u: [H, K] or None.

    Returns (o [B, H, T, V] f32, final_state [B, H, K, V] f32).
    """
    B, H, T, K = q.shape
    V = v.shape[-1]
    C = min(chunk, T)
    pad = (-T) % C
    if pad:
        # zero-pad: w=0 (decay 1) and k=0 leave the carried state unchanged.
        zp = lambda x: jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        q, k, v, w = zp(q), zp(k), zp(v), zp(w)
    Tp = T + pad
    N = Tp // C
    BH = B * H

    flat = lambda x: x.reshape(BH, Tp, x.shape[-1])
    qf, kf, vf, wf = flat(q), flat(k), flat(v), flat(w)
    if u is None:
        uf = jnp.zeros((BH, K), jnp.float32)
    else:
        uf = jnp.broadcast_to(u[None, :, :], (B, H, K)).reshape(BH, K)
    if initial_state is None:
        s0 = jnp.zeros((BH, K, V), jnp.float32)
    else:
        s0 = initial_state.astype(jnp.float32).reshape(BH, K, V)

    grid = (BH, N)
    kernel = functools.partial(_ls_kernel, chunk=C, mode=mode)
    o, sf = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, C, K), lambda i, n: (i, n, 0)),   # q
            pl.BlockSpec((1, C, K), lambda i, n: (i, n, 0)),   # k
            pl.BlockSpec((1, C, V), lambda i, n: (i, n, 0)),   # v
            pl.BlockSpec((1, C, K), lambda i, n: (i, n, 0)),   # w
            pl.BlockSpec((1, K), lambda i, n: (i, 0)),         # u (pinned)
            pl.BlockSpec((1, K, V), lambda i, n: (i, 0, 0)),   # s0
        ],
        out_specs=[
            pl.BlockSpec((1, C, V), lambda i, n: (i, n, 0)),   # o
            pl.BlockSpec((1, K, V), lambda i, n: (i, 0, 0)),   # final state
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Tp, V), jnp.float32),
            jax.ShapeDtypeStruct((BH, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf, wf, uf, s0)
    o = o.reshape(B, H, Tp, V)[:, :, :T]
    return o, sf.reshape(B, H, K, V)
