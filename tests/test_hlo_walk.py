"""Trip-count-aware HLO walker unit tests on hand-written HLO snippets."""
from __future__ import annotations

from repro.launch.hlo_analysis import parse_collectives
from repro.launch.hlo_walk import walk_hlo

HLO = """
HloModule test

%adder (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %dot = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%dot), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%adder
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ip, %ar)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[8,16]) -> (s32[], f32[8,16]) {
  %x = f32[8,16]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,16]) tuple(%z, %x)
  ROOT %w0 = (s32[], f32[8,16]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
}
"""


def test_while_trip_multiplication():
    cost = walk_hlo(HLO)
    # dot: 2*8*16*16 = 4096 flops, x12 trips
    assert cost.flops >= 12 * 4096
    assert cost.flops < 12 * 4096 * 1.2     # small elementwise slack
    # all-reduce: 8*16*4 bytes, group 4 -> wire 2*(3/4)*512 = 768, x12
    assert abs(cost.wire_bytes - 12 * 768.0) < 1e-6
    assert cost.while_breakdown[0]["trip"] == 12


def test_collective_parse_direct():
    stats = parse_collectives(HLO)
    assert stats.per_op["all-reduce"]["count"] == 1
    assert stats.per_op["all-reduce"]["max_group"] == 4


def test_bytes_exclude_tuple_plumbing():
    cost = walk_hlo(HLO)
    # traffic: dot (operands+out) + all-reduce (operand+out) per trip, plus
    # entry tuple ops are free.  Rough bound: < 10 KB * 12 trips.
    assert cost.bytes < 12 * 10_000
    assert cost.bytes > 12 * (8 * 16 * 4 * 2)     # at least dot in/out
