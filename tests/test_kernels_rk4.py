"""Per-kernel allclose tests: fused RK4 poly-ODE integrator vs oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.library import make_library
from repro.kernels.rk4.ops import rk4_poly_solve
from repro.kernels.rk4.ref import poly_features_ref, rk4_poly_solve_ref
from repro.kernels.rk4.rk4 import selection_matrices

jax.config.update("jax_platform_name", "cpu")


def _mk(seed, B, n, m, order, T):
    lib = make_library(n, m, order)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    theta = 0.1 * jax.random.normal(k1, (B, n, lib.size))
    y0 = 0.3 * jax.random.normal(k2, (B, n))
    us = 0.2 * jax.random.normal(k3, (B, T, m))
    return lib, theta, y0, us


@pytest.mark.parametrize("B,n,m,order,T", [
    (1, 1, 0, 1, 5), (4, 2, 0, 2, 10), (5, 3, 1, 3, 20), (8, 2, 1, 2, 7),
    (9, 4, 2, 2, 12),
])
def test_rk4_pallas_matches_ref(B, n, m, order, T):
    lib, theta, y0, us = _mk(0, B, n, m, order, T)
    ys_r = rk4_poly_solve_ref(theta, y0, us, 0.02, lib.term_indices)
    ys_p = rk4_poly_solve(theta, y0, us, dt=0.02, library=lib,
                          use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(ys_r), np.asarray(ys_p), atol=1e-5)


def test_selection_matrices_match_gather():
    """Gather-as-matmul library eval == direct gather eval."""
    lib = make_library(3, 1, 3)
    sel = selection_matrices(np.asarray(lib.term_indices), 1 + 3 + 1)
    key = jax.random.PRNGKey(1)
    y = jax.random.normal(key, (6, 3))
    u = jax.random.normal(jax.random.PRNGKey(2), (6, 1))
    aug = jnp.concatenate([jnp.ones((6, 1)), y, u], -1)
    phi_mm = jnp.ones((6, lib.size))
    for o in range(3):
        phi_mm = phi_mm * (aug @ sel[o])
    phi_g = poly_features_ref(y, u, lib.term_indices)
    np.testing.assert_allclose(np.asarray(phi_mm), np.asarray(phi_g),
                               rtol=1e-5)


def test_rk4_matches_library_semantics():
    """Kernel contract == core poly_ode_integrate (library API)."""
    from repro.core.odeint import poly_ode_integrate
    lib, theta, y0, us = _mk(3, 4, 2, 1, 2, 15)
    ys_k = rk4_poly_solve(theta, y0, us, dt=0.05, library=lib)
    ys_c = poly_ode_integrate(theta, y0, jnp.swapaxes(us, 0, 1), 0.05,
                              library=lib)
    np.testing.assert_allclose(np.asarray(ys_k),
                               np.asarray(jnp.swapaxes(ys_c, 0, 1)),
                               atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(B=st.integers(1, 7), n=st.integers(1, 3), m=st.integers(0, 2),
       order=st.integers(1, 3), T=st.integers(1, 10),
       seed=st.integers(0, 999))
def test_rk4_pallas_property(B, n, m, order, T, seed):
    lib, theta, y0, us = _mk(seed, B, n, m, order, T)
    ys_r = rk4_poly_solve_ref(theta, y0, us, 0.02, lib.term_indices)
    ys_p = rk4_poly_solve(theta, y0, us, dt=0.02, library=lib,
                          use_pallas=True, interpret=True)
    assert ys_p.shape == (B, T + 1, n)
    np.testing.assert_allclose(np.asarray(ys_r), np.asarray(ys_p), atol=1e-4)


def test_rk4_grad_through_solver():
    """The ODE loss backpropagates through the reference solver."""
    lib, theta, y0, us = _mk(5, 3, 2, 1, 2, 8)

    def loss(theta):
        ys = rk4_poly_solve(theta, y0, us, dt=0.02, library=lib)
        return jnp.mean(ys ** 2)

    g = jax.grad(loss)(theta)
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.abs(g).max()) > 0
