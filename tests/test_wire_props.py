"""Wire-format property tests: codec round trips and hostile frames.

The federation's availability story rests on two codec properties:

  1. ROUND TRIP — `decode(encode(msg))` reproduces every registered
     message exactly (scalars, None-able arrays, dtypes, shapes, 0-sized
     blobs included), so anything a worker says survives the pipe.
  2. TOTALITY OVER GARBAGE — `decode` of ANY byte string either returns a
     message or raises `WireError`; no other exception type ever escapes.
     The front door leans on this: a hostile producer must get an
     `ErrorMsg` reply, never take the door (or the serving loop) down.

Both are checked with a seeded-RNG fuzzer (hundreds of cases, always the
same cases — CI-stable).  When the `hypothesis` plugin is available the
same properties additionally run under its shrinking search; those
variants are import-gated so the default environment (no hypothesis) still
exercises the seeded pass.
"""
import socket
import struct

import numpy as np
import pytest

import repro.twin.wire as W
from repro.twin.wire import (WIRE_VERSION, FrontDoorClient, IngestFrontDoor,
                             WireError, decode, encode, read_frame,
                             write_frame)

SEED = 20260807
_DTYPES = [np.float32, np.float64, np.int32, np.int64, np.uint8, np.bool_]


def _rand_array(rng, *, max_rank=3, max_dim=6):
    dt = _DTYPES[rng.integers(len(_DTYPES))]
    shape = tuple(int(rng.integers(0, max_dim + 1))
                  for _ in range(int(rng.integers(0, max_rank + 1))))
    if np.issubdtype(dt, np.floating):
        a = rng.standard_normal(shape).astype(dt)
    elif dt is np.bool_:
        a = rng.integers(0, 2, shape).astype(bool)
    else:
        a = rng.integers(-1000, 1000, shape).astype(dt)
    return a


def _rand_msg(rng):
    """One random instance of a random registered message type."""
    builders = [
        lambda: W.Hello(shard=int(rng.integers(0, 64)),
                        tick=int(rng.integers(0, 1 << 20)),
                        ckpt_tick=(None if rng.random() < 0.3
                                   else int(rng.integers(0, 1 << 20))),
                        samples={str(int(rng.integers(0, 99))):
                                 int(rng.integers(0, 1 << 16))
                                 for _ in range(int(rng.integers(0, 4)))}),
        lambda: W.IngestBatch(
            twin_ids=rng.integers(0, 1 << 20, int(rng.integers(0, 5)))
            .astype(np.int64),
            counts=rng.integers(0, 64, int(rng.integers(0, 5)))
            .astype(np.int32),
            y=rng.standard_normal((int(rng.integers(0, 9)),
                                   int(rng.integers(1, 5))))
            .astype(np.float32),
            u=(None if rng.random() < 0.5 else
               rng.standard_normal((int(rng.integers(0, 9)), 1))
               .astype(np.float32)),
            force=bool(rng.integers(0, 2))),
        lambda: W.TickCmd(tick=int(rng.integers(0, 1 << 30)),
                          grant=int(rng.integers(-1, 16)),
                          inject_delay_s=float(rng.random())),
        lambda: W.TickDone(tick=int(rng.integers(0, 1 << 30)),
                           latency_s=float(rng.random()),
                           deadline_met=bool(rng.integers(0, 2)),
                           n_active=int(rng.integers(0, 64)),
                           n_twins=int(rng.integers(0, 1 << 16)),
                           n_guarded=int(rng.integers(0, 64)),
                           degraded_level=int(rng.integers(0, 4)),
                           pressure=float(rng.random()),
                           loss=(None if rng.random() < 0.5
                                 else float(rng.random())),
                           events=[[int(rng.integers(0, 99)), "diverged",
                                    float(rng.random()),
                                    int(rng.integers(0, 99)),
                                    float(rng.random())]
                                   for _ in range(int(rng.integers(0, 3)))]),
        lambda: W.Deploy(twin_ids=rng.integers(0, 99, 3).astype(np.int64),
                         thetas=_rand_array(rng)),
        lambda: W.PredictCmd(twin_id=int(rng.integers(0, 99)),
                             horizon=int(rng.integers(1, 64)),
                             us=(None if rng.random() < 0.5
                                 else _rand_array(rng))),
        lambda: W.PredictResult(ys=_rand_array(rng)),
        lambda: W.Scenario(twin_id=int(rng.integers(0, 99)),
                           horizon=int(rng.integers(1, 64)),
                           k=(None if rng.random() < 0.5
                              else int(rng.integers(1, 9))),
                           us=(None if rng.random() < 0.5
                               else rng.standard_normal((2, 4, 1))
                               .astype(np.float32))),
        lambda: W.ScenarioResult(
            twin_id=int(rng.integers(0, 99)),
            horizon=int(rng.integers(1, 64)),
            requested_k=int(rng.integers(1, 9)),
            k=int(rng.integers(1, 9)),
            degraded_level=int(rng.integers(0, 4)),
            ys=rng.standard_normal((2, 5, 3)).astype(np.float32),
            lo=rng.standard_normal((2, 5, 3)).astype(np.float32),
            hi=rng.standard_normal((2, 5, 3)).astype(np.float32),
            confidence=rng.random(2).astype(np.float32)),
        lambda: W.DrainCmd(),
        lambda: W.Ack(n=int(rng.integers(0, 1 << 20))),
        lambda: W.StatsCmd(kind=["latency", "stage", "reset"]
                           [rng.integers(3)]),
        lambda: W.Stats(data={"p50_ms": float(rng.random())}),
        lambda: W.SnapshotCmd(),
        lambda: W.SnapshotBlob.pack({"tick": int(rng.integers(0, 99)),
                                     "arr": _rand_array(rng)}),
        lambda: W.Shutdown(),
        lambda: W.ErrorMsg(where="tick", error="boom"),
    ]
    return builders[rng.integers(len(builders))]()


def _assert_same(a, b):
    assert type(a) is type(b)
    import dataclasses
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, np.ndarray):
            assert va.dtype == vb.dtype and va.shape == vb.shape
            np.testing.assert_array_equal(va, vb)
        elif va is None or vb is None:
            assert va is vb
        else:
            assert va == vb


# --------------------------------------------------------------------- #
# property 1: round trip
# --------------------------------------------------------------------- #
def test_roundtrip_fuzz_all_message_types():
    rng = np.random.default_rng(SEED)
    seen = set()
    for _ in range(400):
        msg = _rand_msg(rng)
        seen.add(type(msg).TYPE)
        out = decode(encode(msg))
        if isinstance(msg, W.SnapshotBlob):
            a, b = msg.unpack(), out.unpack()
            assert a["tick"] == b["tick"]
            np.testing.assert_array_equal(a["arr"], b["arr"])
        else:
            _assert_same(msg, out)
    # the fuzzer must actually cover the registry (new messages included)
    assert seen == set(W._REGISTRY), f"uncovered types: {set(W._REGISTRY) - seen}"


def test_roundtrip_preserves_noncontiguous_and_views():
    base = np.arange(48, dtype=np.float32).reshape(6, 8)
    msg = W.PredictResult(ys=base[::2, ::2])      # strided view
    out = decode(encode(msg))
    np.testing.assert_array_equal(out.ys, base[::2, ::2])
    assert out.ys.flags["C_CONTIGUOUS"]


def test_ingest_chunks_roundtrip():
    rng = np.random.default_rng(SEED + 1)
    batch = [(int(i), rng.standard_normal((4, 2)).astype(np.float32),
              rng.standard_normal((4, 1)).astype(np.float32))
             for i in range(5)]
    msg = decode(encode(W.IngestBatch.from_chunks(batch)))
    for (tid, y, u), (tid2, y2, u2) in zip(batch, msg.chunks()):
        assert tid == tid2
        np.testing.assert_array_equal(y, y2)
        np.testing.assert_array_equal(u, u2)
    assert msg.n_samples == 20


# --------------------------------------------------------------------- #
# property 2: totality over garbage
# --------------------------------------------------------------------- #
def test_decode_garbage_raises_wireerror_only():
    rng = np.random.default_rng(SEED + 2)
    for _ in range(300):
        n = int(rng.integers(0, 200))
        payload = rng.integers(0, 256, n).astype(np.uint8).tobytes()
        try:
            decode(payload)
        except WireError:
            pass                                   # the only allowed failure


def test_decode_mutated_valid_frames_never_crash():
    """Bit-flipped REAL frames: decode returns a message or WireError —
    never IndexError/KeyError/json errors/segfault-shaped surprises."""
    rng = np.random.default_rng(SEED + 3)
    for _ in range(300):
        buf = bytearray(encode(_rand_msg(rng)))
        for _ in range(int(rng.integers(1, 4))):
            buf[rng.integers(len(buf))] = int(rng.integers(0, 256))
        try:
            decode(bytes(buf))
        except WireError:
            pass


def test_decode_rejects_wrong_version():
    buf = bytearray(encode(W.Ack(n=1)))
    struct.pack_into(">H", buf, 0, WIRE_VERSION + 1)
    with pytest.raises(WireError, match="wire version"):
        decode(bytes(buf))


def test_decode_rejects_overrunning_header_and_blob():
    buf = bytearray(encode(W.Ack(n=1)))
    struct.pack_into(">I", buf, 2, 1 << 20)        # header_len overrun
    with pytest.raises(WireError, match="overruns"):
        decode(bytes(buf))
    frame = encode(W.PredictResult(ys=np.ones((4, 4), np.float32)))
    with pytest.raises(WireError, match="overruns"):
        decode(frame[:-8])                          # truncated blob


def test_decode_rejects_unknown_type_and_bad_fields():
    hdr = b'{"t":"no_such_message"}'
    frame = struct.pack(">HI", WIRE_VERSION, len(hdr)) + hdr
    with pytest.raises(WireError, match="bad header"):
        decode(frame)
    hdr = b'{"t":"ack","bogus_field":1}'
    frame = struct.pack(">HI", WIRE_VERSION, len(hdr)) + hdr
    with pytest.raises(WireError, match="bad fields"):
        decode(frame)


def test_untrusted_decode_enforces_allowlist():
    for msg, ok in [(W.IngestBatch.from_chunks([(0, np.ones((2, 2)))]), True),
                    (W.Ack(n=1), True),
                    (W.ErrorMsg(error="x"), True),
                    (W.Scenario(twin_id=0, horizon=4), False),
                    (W.Deploy(twin_ids=np.zeros(1, np.int64),
                              thetas=np.ones((1, 2, 3))), False),
                    (W.SnapshotBlob.pack({"x": 1}), False),
                    (W.Shutdown(), False)]:
        if ok:
            decode(encode(msg), trusted=False)
        else:
            with pytest.raises(WireError, match="untrusted"):
                decode(encode(msg), trusted=False)


# --------------------------------------------------------------------- #
# stream framing + front door under hostile bytes
# --------------------------------------------------------------------- #
def _sock_pair():
    a, b = socket.socketpair()
    return a, b


def test_read_frame_rejects_oversized_length():
    a, b = _sock_pair()
    try:
        a.sendall(struct.pack(">I", W._MAX_FRAME + 1))
        with pytest.raises(WireError, match="exceeds"):
            read_frame(b)
    finally:
        a.close(), b.close()


def test_read_frame_eof_semantics():
    a, b = _sock_pair()
    try:
        a.close()
        assert read_frame(b) is None               # clean EOF
    finally:
        b.close()
    a, b = _sock_pair()
    try:
        a.sendall(struct.pack(">I", 100) + b"short")
        a.close()
        with pytest.raises(WireError, match="EOF mid-frame"):
            read_frame(b)
    finally:
        b.close()


def test_write_read_frame_roundtrip_fuzz():
    rng = np.random.default_rng(SEED + 4)
    a, b = _sock_pair()
    try:
        for _ in range(50):
            payload = rng.integers(0, 256, int(rng.integers(0, 4096))) \
                .astype(np.uint8).tobytes()
            write_frame(a, payload)
            assert read_frame(b) == payload
    finally:
        a.close(), b.close()


def test_front_door_survives_hostile_producer():
    """Garbage frames, forbidden types, then a valid batch — the door must
    answer ErrorMsg / ErrorMsg / Ack on the SAME connection, and the sink
    must see only the valid chunks."""
    staged = []

    def sink(chunks, *, force=False):
        staged.extend(chunks)
        return sum(c[1].shape[0] for c in chunks)

    door = IngestFrontDoor(sink)
    rng = np.random.default_rng(SEED + 5)
    try:
        raw = socket.create_connection(door.address)
        try:
            # 1) random garbage payload
            write_frame(raw, rng.integers(0, 256, 64).astype(np.uint8)
                        .tobytes())
            reply = decode(read_frame(raw), trusted=False)
            assert isinstance(reply, W.ErrorMsg)
            # 2) well-formed but forbidden type
            write_frame(raw, encode(W.Shutdown()))
            reply = decode(read_frame(raw), trusted=False)
            assert isinstance(reply, W.ErrorMsg)
            # 3) valid batch still lands
            write_frame(raw, encode(W.IngestBatch.from_chunks(
                [(7, np.ones((3, 2), np.float32))])))
            reply = decode(read_frame(raw), trusted=False)
            assert isinstance(reply, W.Ack) and reply.n == 3
        finally:
            raw.close()
        assert len(staged) == 1 and staged[0][0] == 7
        # the client helper sees the same contract
        cl = FrontDoorClient(door.address)
        try:
            assert cl.ingest(8, np.ones((2, 2), np.float32)) == 2
        finally:
            cl.close()
    finally:
        door.close()


# --------------------------------------------------------------------- #
# hypothesis variants (shrinking search) — import-gated: the environment
# without the plugin still runs everything above
# --------------------------------------------------------------------- #
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:

    @pytest.mark.hypothesis
    @settings(max_examples=200, deadline=None)
    @given(st.binary(max_size=256))
    def test_hyp_decode_total(payload):
        try:
            decode(payload)
        except WireError:
            pass

    @pytest.mark.hypothesis
    @settings(max_examples=100, deadline=None)
    @given(st.integers(0, 1 << 30), st.integers(-1, 64),
           st.floats(0, 10, allow_nan=False))
    def test_hyp_tickcmd_roundtrip(tick, grant, delay):
        msg = W.TickCmd(tick=tick, grant=grant, inject_delay_s=delay)
        _assert_same(msg, decode(encode(msg)))
