"""Checkpointing: atomicity, bit-exact round-trip, async, GC, elasticity."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {
        "params": {"w": jax.random.normal(k1, (8, 16), jnp.float32),
                   "b16": jax.random.normal(k2, (4,), jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
        "nested": [jnp.arange(5), {"x": jnp.ones((2, 2))}],
    }


def test_roundtrip_bit_exact(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    ckpt.save(tmp_path, 7, tree)
    assert ckpt.latest_step(tmp_path) == 7
    restored = ckpt.restore(tmp_path, 7, jax.eval_shape(lambda: tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_restore_structure_mismatch_raises_value_error(tmp_path):
    """Config drift between writer and restorer must be a catchable error
    (a failover supervisor decides fallback vs rebuild), not an assert."""
    tree = _tree(jax.random.PRNGKey(3))
    ckpt.save(tmp_path, 1, tree)
    with pytest.raises(ValueError, match="structure mismatch"):
        ckpt.restore(tmp_path, 1, {"only": jnp.zeros((2,))})
    wrong_shape = jax.tree.map(lambda a: jnp.zeros((3,) + a.shape, a.dtype),
                               tree)
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore(tmp_path, 1, jax.eval_shape(lambda: wrong_shape))


def test_torn_checkpoint_ignored(tmp_path):
    tree = _tree(jax.random.PRNGKey(1))
    ckpt.save(tmp_path, 10, tree)
    # simulate a crash mid-write of step 20: directory without COMMIT
    torn = tmp_path / "step_00000020"
    torn.mkdir()
    (torn / "manifest.json").write_text("{}")
    assert ckpt.latest_step(tmp_path) == 10


def test_async_save_and_gc(tmp_path):
    mgr = ckpt.CheckpointManager(tmp_path, keep=2, save_every=5)
    tree = _tree(jax.random.PRNGKey(2))
    for step in [5, 10, 15]:
        assert mgr.maybe_save(step, tree)
    assert not mgr.maybe_save(16, tree)      # not on the cadence
    mgr.wait()
    assert ckpt.latest_step(tmp_path) == 15
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(kept) <= 2                     # GC keeps the last 2


def test_elastic_restore_new_sharding(tmp_path):
    """A checkpoint restores onto a different mesh (here: 1-device mesh with
    explicit shardings) — the elastic-rescale path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
    ckpt.save(tmp_path, 1, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored = ckpt.restore(tmp_path, 1, jax.eval_shape(lambda: tree), sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
