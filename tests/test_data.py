"""Data pipeline tests: windowing semantics, stats, prefetch/straggler."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import PrefetchIterator, WindowDataset, make_windows

jax.config.update("jax_platform_name", "cpu")


def test_window_content_alignment():
    """Window t's inputs must be the ones held during its transitions."""
    T, n, m = 20, 2, 1
    ys = jnp.arange((T + 1) * n, dtype=jnp.float32).reshape(T + 1, n)
    us = jnp.arange(T * m, dtype=jnp.float32).reshape(T, m)
    y_win, u_win = make_windows(ys, us, window=5, stride=3)
    # first window starts at 0: ys[0..5], us[0..4]
    np.testing.assert_array_equal(np.asarray(y_win[0]), np.asarray(ys[:6]))
    np.testing.assert_array_equal(np.asarray(u_win[0]), np.asarray(us[:5]))
    # second window starts at 3
    np.testing.assert_array_equal(np.asarray(y_win[1]), np.asarray(ys[3:9]))
    np.testing.assert_array_equal(np.asarray(u_win[1]), np.asarray(us[3:8]))


def test_batched_traces_windowing():
    ys = jnp.zeros((3, 21, 2))
    us = jnp.zeros((3, 20, 1))
    y_win, u_win = make_windows(ys, us, window=10, stride=5)
    assert y_win.shape[0] == 3 * u_win.shape[0] // 3
    assert y_win.shape[1:] == (11, 2)
    assert u_win.shape[1:] == (10, 1)


def test_batches_iterator_shapes_and_count():
    ds = WindowDataset(y_win=jnp.zeros((50, 11, 2)),
                       u_win=jnp.zeros((50, 10, 1)), dt=0.01)
    batches = list(ds.batches(jax.random.PRNGKey(0), 16, epochs=2))
    assert len(batches) == 6      # 3 per epoch, drop remainder
    assert batches[0][0].shape == (16, 11, 2)


def test_norm_stats():
    y = jnp.stack([jnp.full((11, 2), 3.0), jnp.full((11, 2), 5.0)])
    u = jnp.zeros((2, 10, 1))
    ds = WindowDataset(y_win=y, u_win=u, dt=0.01)
    mu, sigma = ds.norm_stats()
    np.testing.assert_allclose(np.asarray(mu), [4.0, 4.0, 0.0], atol=1e-6)


def test_prefetch_iterator_order_and_completion():
    it = PrefetchIterator(iter(range(10)), depth=2)
    assert list(it) == list(range(10))


def test_prefetch_straggler_counted():
    def slow_gen():
        yield 1
        time.sleep(0.3)
        yield 2

    it = PrefetchIterator(slow_gen(), depth=1, deadline_s=0.05)
    out = list(it)
    assert out == [1, 2]
    assert it.straggler_events >= 1
