"""Per-kernel allclose tests: fused GRU scan (Pallas, interpret mode) vs the
pure-jnp oracle, sweeping shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.gru.ops import gru_scan
from repro.kernels.gru.ref import gru_cell_ref, gru_scan_ref, init_gru_params

jax.config.update("jax_platform_name", "cpu")


def _mk(key, B, T, D, H, dtype):
    kp, kx = jax.random.split(jax.random.PRNGKey(key))
    p = init_gru_params(kp, D, H, dtype)
    xs = jax.random.normal(kx, (B, T, D), dtype)
    h0 = jnp.zeros((B, H), dtype)
    return xs, h0, p


@pytest.mark.parametrize("B,T,D,H", [
    (1, 1, 1, 1), (2, 3, 4, 5), (8, 16, 8, 16), (5, 40, 3, 32),
    (16, 7, 151, 64), (3, 100, 2, 8),
])
def test_gru_pallas_matches_ref_shapes(B, T, D, H):
    xs, h0, p = _mk(0, B, T, D, H, jnp.float32)
    hs_r, hT_r = gru_scan_ref(xs, h0, p["wx"], p["wh"], p["b"])
    hs_p, hT_p = gru_scan(xs, h0, p["wx"], p["wh"], p["b"],
                          use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(hs_r), np.asarray(hs_p), atol=1e-5)
    np.testing.assert_allclose(np.asarray(hT_r), np.asarray(hT_p), atol=1e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 1e-5),
                                        (jnp.bfloat16, 3e-2)])
def test_gru_pallas_dtypes(dtype, atol):
    xs, h0, p = _mk(1, 4, 12, 6, 16, dtype)
    hs_r, _ = gru_scan_ref(xs, h0, p["wx"], p["wh"], p["b"])
    hs_p, _ = gru_scan(xs, h0, p["wx"], p["wh"], p["b"],
                       use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(hs_r, np.float32),
                               np.asarray(hs_p, np.float32), atol=atol)


def test_gru_scan_equals_unrolled_cell():
    """The scan (with hoisted input projection) == step-by-step cell calls."""
    xs, h0, p = _mk(2, 3, 10, 4, 8, jnp.float32)
    hs, hT = gru_scan_ref(xs, h0, p["wx"], p["wh"], p["b"])
    h = h0
    for t in range(10):
        h = gru_cell_ref(h, xs[:, t, :], p["wx"], p["wh"], p["b"])
        np.testing.assert_allclose(np.asarray(hs[:, t]), np.asarray(h),
                                   atol=1e-5)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(h), atol=1e-5)


def test_gru_grad_flows():
    xs, h0, p = _mk(3, 2, 5, 3, 4, jnp.float32)

    def loss(p):
        hs, hT = gru_scan_ref(xs, h0, p["wx"], p["wh"], p["b"])
        return jnp.sum(hT ** 2)

    g = jax.grad(loss)(p)
    assert all(bool(jnp.all(jnp.isfinite(v))) for v in jax.tree.leaves(g))
    assert float(jnp.abs(g["wh"]).max()) > 0


@settings(max_examples=12, deadline=None)
@given(B=st.integers(1, 9), T=st.integers(1, 24), D=st.integers(1, 12),
       H=st.integers(1, 24), seed=st.integers(0, 1000))
def test_gru_pallas_matches_ref_property(B, T, D, H, seed):
    xs, h0, p = _mk(seed, B, T, D, H, jnp.float32)
    hs_r, hT_r = gru_scan_ref(xs, h0, p["wx"], p["wh"], p["b"])
    hs_p, hT_p = gru_scan(xs, h0, p["wx"], p["wh"], p["b"],
                          use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(hs_r), np.asarray(hs_p), atol=1e-5)


def test_gru_hidden_bounded():
    """GRU hidden state is a convex combination of tanh outputs: |h| <= 1."""
    xs, h0, p = _mk(4, 4, 50, 3, 8, jnp.float32)
    hs, _ = gru_scan_ref(100.0 * xs, h0, p["wx"], p["wh"], p["b"])
    assert float(jnp.abs(hs).max()) <= 1.0 + 1e-6
