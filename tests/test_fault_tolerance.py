"""Fault tolerance: failure-injected restart is bit-exact; stragglers are
detected; elastic re-mesh plans are sane."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data.tokens import TokenStream
from repro.distributed.fault_tolerance import (FailureInjector,
                                               SimulatedPreemption,
                                               StragglerDetector,
                                               elastic_plan)
from repro.models.zoo import build
from repro.train.loop import LoopConfig, run_loop
from repro.train.optimizer import adamw
from repro.train.train_state import init_state, make_train_step


def _setup(tmp_path):
    api = build(get_arch("qwen3-8b").smoke)
    opt = adamw(lr=1e-3)
    params = api.init(jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(api.loss, opt))
    state = init_state(params, opt)
    stream = TokenStream(vocab=api.cfg.vocab, batch=2, seq_len=16)
    return api, step_fn, state, stream


@pytest.mark.slow
def test_failure_injection_bit_exact_resume(tmp_path):
    api, step_fn, state0, stream = _setup(tmp_path)

    # uninterrupted run: 8 steps
    cfg = LoopConfig(total_steps=8, ckpt_dir=None, log_every=100)
    ref_state, _ = run_loop(step_fn, state0, iter(stream), cfg)

    # interrupted run: checkpoint every 2 steps, die at step 5, restart.
    ckpt_dir = str(tmp_path / "ckpt")
    inj = FailureInjector(fail_at_step=5)
    cfg2 = LoopConfig(total_steps=8, ckpt_dir=ckpt_dir, ckpt_every=2,
                      injector=inj, log_every=100)
    with pytest.raises(SimulatedPreemption):
        run_loop(step_fn, state0, iter(stream), cfg2)

    # restart: run_loop resumes from step 4's checkpoint and replays the
    # deterministic data stream from there.
    def data_from(step):
        return stream.iter_from(step)

    cfg3 = LoopConfig(total_steps=8, ckpt_dir=ckpt_dir, ckpt_every=2,
                      log_every=100)
    # resume-aware data: run_loop reads latest checkpoint first, so feed a
    # stream seeked to it.
    from repro.train.checkpoint import latest_step
    start = latest_step(ckpt_dir)
    assert start is not None and 0 < start < 8
    resumed, _ = run_loop(step_fn, state0, data_from(start), cfg3)

    for a, b in zip(jax.tree.leaves(ref_state["params"]),
                    jax.tree.leaves(resumed["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_failure_injector_fires_on_skipped_step():
    """`>=` semantics: a schedule whose exact step number never occurs
    (checkpoint cadence skips it, a tick loop restarts past it) still
    fires — once — at the first step at or beyond the target."""
    inj = FailureInjector(fail_at_step=5)
    inj.maybe_fail(3)
    with pytest.raises(SimulatedPreemption):
        inj.maybe_fail(7)                   # 5 and 6 never happened
    inj.maybe_fail(8)                       # one-shot: no refire
    assert FailureInjector(fail_at_step=None).maybe_fail(10 ** 9) is None


def test_straggler_detector():
    det = StragglerDetector(threshold=3.0)
    assert not det.observe(0, 1.0)
    for s in range(1, 5):
        assert not det.observe(s, 1.0)
    assert det.observe(5, 10.0)           # 10x the EWMA -> straggler
    assert det.events and det.events[0]["step"] == 5
    assert abs(det.ewma_s - 1.0) < 0.1    # outlier excluded from EWMA


def test_elastic_plan():
    p = elastic_plan(512)
    assert p["mesh_shape"] == (32, 16) and p["dropped_devices"] == 0
    p = elastic_plan(240)                 # lost a host: 240 devices survive
    assert p["mesh_shape"] == (15, 16)
    assert p["dropped_devices"] == 0
    p = elastic_plan(250)                 # ragged: drop the remainder
    assert p["mesh_shape"] == (15, 16) and p["dropped_devices"] == 10
    p = elastic_plan(8)                   # degenerate single-host debug
    assert p["mesh_shape"][0] * p["mesh_shape"][1] <= 8
