"""Property-based tests (hypothesis) on model-stack invariants."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.attention import (decode_attention, flash_attention,
                                    local_attention)
from repro.models.layers import apply_rope


def _naive_attention(q, k, v, causal=True, window=None):
    B, T, H, dh = q.shape
    n_kv = k.shape[2]
    G = H // n_kv
    qg = q.reshape(B, T, n_kv, G, dh).astype(jnp.float32) * dh ** -0.5
    s = jnp.einsum("btkgd,bjkd->btkgj", qg, k.astype(jnp.float32))
    i = jnp.arange(T)
    mask = jnp.ones((T, T), bool)
    if causal:
        mask &= i[None, :] <= i[:, None]
    if window:
        mask &= i[None, :] > i[:, None] - window
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("btkgj,bjkd->btkgd", p, v.astype(jnp.float32))
    return o.reshape(B, T, H, dh)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([1, 2, 4]),
       st.sampled_from([8, 17, 32]))
def test_flash_matches_naive(seed, g, t):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    B, n_kv, dh = 2, 2, 8
    q = jax.random.normal(ks[0], (B, t, n_kv * g, dh))
    k = jax.random.normal(ks[1], (B, t, n_kv, dh))
    v = jax.random.normal(ks[2], (B, t, n_kv, dh))
    out = flash_attention(q, k, v, causal=True, kv_block=8, q_block=8)
    ref = _naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([4, 8]))
def test_local_matches_naive_windowed(seed, w):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    B, T, n_kv, g, dh = 1, 24, 2, 2, 8
    q = jax.random.normal(ks[0], (B, T, n_kv * g, dh))
    k = jax.random.normal(ks[1], (B, T, n_kv, dh))
    v = jax.random.normal(ks[2], (B, T, n_kv, dh))
    out = local_attention(q, k, v, window=w)
    ref = _naive_attention(q, k, v, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_causality_future_independence():
    """Changing future tokens must not change past attention outputs."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    B, T, H, dh = 1, 16, 4, 8
    q = jax.random.normal(ks[0], (B, T, H, dh))
    k = jax.random.normal(ks[1], (B, T, H, dh))
    v = jax.random.normal(ks[2], (B, T, H, dh))
    out1 = flash_attention(q, k, v, causal=True, kv_block=8, q_block=8)
    k2 = k.at[:, T // 2:].add(jax.random.normal(ks[3], (B, T // 2, H, dh)))
    v2 = v.at[:, T // 2:].add(1.0)
    out2 = flash_attention(q, k2, v2, causal=True, kv_block=8, q_block=8)
    np.testing.assert_allclose(np.asarray(out1[:, :T // 2]),
                               np.asarray(out2[:, :T // 2]),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([1.0, 0.5]),
       st.booleans())
def test_rope_relative_shift_invariance(seed, fraction, interleaved):
    """RoPE: q.k inner products depend only on relative positions."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    B, T, H, dh = 1, 8, 1, 16
    q = jax.random.normal(k1, (B, T, H, dh))
    k = jax.random.normal(k2, (B, T, H, dh))
    pos = jnp.broadcast_to(jnp.arange(T), (B, T))

    def scores(shift):
        qr = apply_rope(q, pos + shift, fraction=fraction,
                        interleaved=interleaved)
        kr = apply_rope(k, pos + shift, fraction=fraction,
                        interleaved=interleaved)
        return jnp.einsum("bthd,bshd->bhts", qr, kr)

    np.testing.assert_allclose(np.asarray(scores(0)), np.asarray(scores(13)),
                               rtol=2e-4, atol=2e-4)


def test_decode_attention_matches_full():
    """decode of position t == row t of full causal attention."""
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    B, S, n_kv, g, dh = 2, 12, 2, 2, 8
    H = n_kv * g
    q_all = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, n_kv, dh))
    v = jax.random.normal(ks[2], (B, S, n_kv, dh))
    ref = _naive_attention(q_all, k, v, causal=True)
    t = S - 1
    kv_pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    out = decode_attention(q_all[:, t:t + 1], k, v, kv_pos,
                           jnp.full((B,), t, jnp.int32))
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(ref[:, t]),
                               rtol=2e-3, atol=2e-3)
