"""MERINDA model tests: shapes, sparsification invariants, and a short
end-to-end recovery (integration test — the paper's core claim)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.merinda import Merinda, MerindaConfig
from repro.core.trainer import fit
from repro.data.pipeline import WindowDataset
from repro.systems.lotka_volterra import LotkaVolterra
from repro.systems.simulate import simulate_batch

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def lv_data():
    sys_ = LotkaVolterra()
    tr = simulate_batch(sys_, jax.random.PRNGKey(0), batch=6, horizon=300)
    ds = WindowDataset.from_trace(tr.ys_noisy, tr.us, tr.dt, window=40,
                                  stride=10)
    return sys_, ds


def _model(sys_, **kw):
    cfg = MerindaConfig(n=sys_.spec.n, m=sys_.spec.m, order=2, hidden=32,
                        head_hidden=32, n_active=4, dt=sys_.spec.dt,
                        l1=2e-3, **kw)
    return Merinda(cfg)


def test_forward_shapes(lv_data):
    sys_, ds = lv_data
    model = _model(sys_)
    params = model.init(jax.random.PRNGKey(1),
                        model.norm_stats(ds.y_win, ds.u_win))
    y, u = ds.y_win[:8], ds.u_win[:8]
    y_est, theta, theta_dense = model.forward(params, y, u)
    assert y_est.shape == y.shape
    assert theta.shape == (8, 2, model.lib.size)
    assert theta_dense.shape == theta.shape


def test_zero_init_starts_on_manifold(lv_data):
    """theta starts at 0 -> first forward integrates a constant trajectory."""
    sys_, ds = lv_data
    model = _model(sys_)
    params = model.init(jax.random.PRNGKey(1))
    y, u = ds.y_win[:4], ds.u_win[:4]
    y_est, theta, _ = model.forward(params, y, u)
    assert float(jnp.abs(theta).max()) == 0.0
    np.testing.assert_allclose(
        np.asarray(y_est), np.broadcast_to(np.asarray(y[:, :1]), y.shape))


def test_sparsify_keeps_exactly_k(lv_data):
    sys_, ds = lv_data
    model = _model(sys_)
    B, n, L = 7, 2, model.lib.size
    theta = jax.random.normal(jax.random.PRNGKey(2), (B, n, L))
    sp = model.sparsify(theta, True)
    nz = np.asarray((jnp.abs(sp) > 0).sum(axis=(1, 2)))
    np.testing.assert_array_equal(nz, model.cfg.n_active * np.ones(B))


def test_sparsify_disabled_is_identity(lv_data):
    sys_, ds = lv_data
    model = _model(sys_)
    theta = jax.random.normal(jax.random.PRNGKey(3), (4, 2, model.lib.size))
    np.testing.assert_array_equal(np.asarray(model.sparsify(theta, False)),
                                  np.asarray(theta))


def test_loss_finite_and_differentiable(lv_data):
    sys_, ds = lv_data
    model = _model(sys_)
    params = model.init(jax.random.PRNGKey(4),
                        model.norm_stats(ds.y_win, ds.u_win))
    batch = (ds.y_win[:16], ds.u_win[:16])
    (loss, aux), grads = jax.value_and_grad(model.loss, has_aux=True)(
        params, batch, False)
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads))


@pytest.mark.slow
@pytest.mark.xfail(
    strict=False,
    reason="pre-existing seed defect: at these training hyperparameters the "
           "recovery picks one wrong support term (y1*y1 instead of y1); "
           "needs a trainer/identifiability fix, not serving work — see "
           "ROADMAP.md 'Known-failing seed test'")
def test_recovers_lotka_volterra(lv_data):
    """Integration test for the paper's core claim: MERINDA recovers the
    sparse dynamics with low reconstruction error."""
    sys_, ds = lv_data
    model = _model(sys_)
    params = model.init(jax.random.PRNGKey(1),
                        model.norm_stats(ds.y_win, ds.u_win))
    res = fit(model, params,
              ds.batches(jax.random.PRNGKey(2), 64, epochs=400),
              steps=700, lr=5e-3, sparsify_after=0.6)
    assert res.history[-1] < res.history[0] * 0.05
    theta = model.recover(res.params, ds.y_win[:200], ds.u_win[:200])
    true = sys_.true_theta(model.lib)
    # identical sparsity structure
    np.testing.assert_array_equal(np.asarray(theta) != 0, true != 0)
    # coefficients within 5%
    nz = true != 0
    np.testing.assert_allclose(np.asarray(theta)[nz], true[nz], rtol=0.05)
    mse = float(model.reconstruction_mse(theta, ds.y_win[:200],
                                         ds.u_win[:200]))
    assert mse < 0.03        # paper Table I: 0.03 for Lotka-Volterra
