"""System correctness: hand-coded rhs vs library form + identifiability."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparse_regression import stlsq
from repro.data.pipeline import make_windows
from repro.systems.f8_crusader import F8Crusader
from repro.systems.lorenz import Lorenz
from repro.systems.lotka_volterra import LotkaVolterra
from repro.systems.pathogen import PathogenicAttack
from repro.systems.simulate import register_systems, simulate, simulate_batch
from repro.systems.van_der_pol import VanDerPol

jax.config.update("jax_platform_name", "cpu")

SYSTEMS = [LotkaVolterra(), Lorenz(), F8Crusader(), PathogenicAttack(),
           VanDerPol()]


def test_lorenz_rhs_matches_handcoded():
    s = Lorenz()
    y = jnp.asarray([[1.0, 2.0, 3.0]])
    got = np.asarray(s.rhs(y))
    expect = np.asarray([[10.0 * (2 - 1), 1 * (28 - 3) - 2, 1 * 2 - (8 / 3) * 3]])
    np.testing.assert_allclose(got, expect, rtol=1e-6)


def test_f8_rhs_matches_handcoded():
    s = F8Crusader()
    y = jnp.asarray([[0.1, 0.05, -0.02]])
    u = jnp.asarray([[0.03]])
    a, b, q, uu = 0.1, 0.05, -0.02, 0.03
    e0 = (-0.877 * a + q - 0.088 * a * q + 0.47 * a * a - 0.019 * b * b
          - a * a * q + 3.846 * a ** 3 - 0.215 * uu + 0.28 * a * a * uu
          + 0.47 * a * uu * uu + 0.63 * uu ** 3)
    e2 = (-4.208 * a - 0.396 * q - 0.47 * a * a - 3.564 * a ** 3
          - 20.967 * uu + 6.265 * a * a * uu + 46.0 * a * uu * uu
          + 61.4 * uu ** 3)
    got = np.asarray(s.rhs(y, u))[0]
    np.testing.assert_allclose(got, [e0, q, e2], rtol=1e-5)


def test_f8_dimension_scaling():
    s = F8Crusader(n_aircraft=5)
    assert s.spec.n == 15
    tr = simulate(s, jax.random.PRNGKey(0), horizon=50)
    assert tr.ys.shape == (51, 15)
    assert bool(jnp.all(jnp.isfinite(tr.ys)))


def test_van_der_pol_rhs_matches_handcoded():
    s = VanDerPol(mu=1.5)
    y = jnp.asarray([[0.7, -0.4]])
    u = jnp.asarray([[0.25]])
    y0, y1, uu = 0.7, -0.4, 0.25
    expect = [y1, 1.5 * (1 - y0 * y0) * y1 - y0 + uu]
    np.testing.assert_allclose(np.asarray(s.rhs(y, u))[0], expect, rtol=1e-6)


def test_van_der_pol_registered():
    reg = register_systems()
    assert reg["van_der_pol"] is VanDerPol
    assert VanDerPol().spec.order == 3


@pytest.mark.parametrize("system", SYSTEMS, ids=lambda s: s.spec.name)
def test_traces_finite(system):
    tr = simulate_batch(system, jax.random.PRNGKey(1), batch=3, horizon=150)
    assert bool(jnp.all(jnp.isfinite(tr.ys)))
    assert tr.ys.shape[0] == 3 and tr.ys.shape[-1] == system.spec.n
    assert tr.us.shape == (3, 150, system.spec.m)


@pytest.mark.parametrize("system", [LotkaVolterra(), Lorenz(),
                                    PathogenicAttack(), VanDerPol()],
                         ids=lambda s: s.spec.name)
def test_identifiable_via_stlsq(system):
    """Clean traces + STLSQ must recover the true coefficients — the
    identifiability assumption (paper Eq. 2) holds for every benchmark."""
    tr = simulate_batch(system, jax.random.PRNGKey(2), batch=6,
                        horizon=system.spec.horizon)
    y_win, u_win = make_windows(tr.ys, tr.us, window=40, stride=11)
    n, m = system.spec.n, system.spec.m
    dt = system.spec.dt
    dy = ((y_win[:, 2:, :] - y_win[:, :-2, :]) / (2 * dt)).reshape(-1, n)
    y = y_win[:, 1:-1, :].reshape(-1, n)
    u = u_win[:, 1:, :].reshape(y.shape[0], m)
    lib = system.library()
    phi = lib.eval(y, u if m else None)
    theta = np.asarray(stlsq(phi, dy, threshold=0.02))
    true = system.true_theta(lib)
    big = np.abs(true) > 0.05
    np.testing.assert_allclose(theta[big], true[big], rtol=0.1)


def test_noise_injection_scales():
    s = LotkaVolterra()
    tr = simulate(s, jax.random.PRNGKey(3), horizon=200, noise_std=0.05)
    resid = np.asarray(tr.ys_noisy - tr.ys)
    assert 0.0 < resid.std() < 1.0
