"""System correctness: hand-coded rhs vs library form, identifiability,
and the registry-wide invariant suite (every REGISTERED system must pass
finiteness / equilibrium / simulate-contract checks — and must DECLARE its
invariants below, so adding a system without them fails collection)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparse_regression import stlsq
from repro.data.pipeline import make_windows
from repro.systems.f8_crusader import F8Crusader
from repro.systems.grid_frequency import GridFrequency
from repro.systems.lorenz import Lorenz
from repro.systems.lotka_volterra import LotkaVolterra
from repro.systems.pathogen import PathogenicAttack
from repro.systems.quadrotor import Quadrotor
from repro.systems.simulate import register_systems, simulate, simulate_batch
from repro.systems.thermal_battery import ThermalBattery
from repro.systems.van_der_pol import VanDerPol

jax.config.update("jax_platform_name", "cpu")

SYSTEMS = [LotkaVolterra(), Lorenz(), F8Crusader(), PathogenicAttack(),
           VanDerPol()]

REGISTRY = register_systems()
ALL_NAMES = sorted(REGISTRY)

# ------------------------------------------------------------------------- #
# Per-system invariant declarations.  EVERY registered system must appear:
# a known equilibrium (y*, u*) with rhs(y*, u*) == 0, and a bound on |y|
# over the documented initial-condition domain under default excitation.
# Registering a system without declaring its invariants fails the suite
# (test_every_registered_system_declares_invariants).
# ------------------------------------------------------------------------- #
EQUILIBRIA = {
    # name: (y_star, u_star) — all zoo systems have no constant library
    # term, so the origin is an equilibrium under zero input; systems with
    # a second analytic fixed point declare it too.
    "lotka_volterra": [(np.zeros(2), None)],
    "lorenz": [(np.zeros(3), None)],
    "f8_crusader": [(np.zeros(3), np.zeros(1))],
    "van_der_pol": [(np.zeros(2), np.zeros(1))],
    "pathogenic_attack": [(np.zeros(2), np.zeros(1))],
    "quadrotor": [(np.zeros(3), np.zeros(1))],
    "thermal_battery": [(np.zeros(2), np.zeros(1))],
    "grid_frequency": [(np.zeros(2), np.zeros(1))],
}
TRACE_BOUND = {
    # max |y| over a default-excitation batch from the documented domain —
    # loose (2-5x observed) but finite: catches silent blowups
    "lotka_volterra": 100.0,
    "lorenz": 80.0,
    "f8_crusader": 10.0,
    "van_der_pol": 20.0,
    "pathogenic_attack": 20.0,
    "quadrotor": 80.0,
    "thermal_battery": 20.0,
    "grid_frequency": 10.0,
}


def test_lorenz_rhs_matches_handcoded():
    s = Lorenz()
    y = jnp.asarray([[1.0, 2.0, 3.0]])
    got = np.asarray(s.rhs(y))
    expect = np.asarray([[10.0 * (2 - 1), 1 * (28 - 3) - 2, 1 * 2 - (8 / 3) * 3]])
    np.testing.assert_allclose(got, expect, rtol=1e-6)


def test_f8_rhs_matches_handcoded():
    s = F8Crusader()
    y = jnp.asarray([[0.1, 0.05, -0.02]])
    u = jnp.asarray([[0.03]])
    a, b, q, uu = 0.1, 0.05, -0.02, 0.03
    e0 = (-0.877 * a + q - 0.088 * a * q + 0.47 * a * a - 0.019 * b * b
          - a * a * q + 3.846 * a ** 3 - 0.215 * uu + 0.28 * a * a * uu
          + 0.47 * a * uu * uu + 0.63 * uu ** 3)
    e2 = (-4.208 * a - 0.396 * q - 0.47 * a * a - 3.564 * a ** 3
          - 20.967 * uu + 6.265 * a * a * uu + 46.0 * a * uu * uu
          + 61.4 * uu ** 3)
    got = np.asarray(s.rhs(y, u))[0]
    np.testing.assert_allclose(got, [e0, q, e2], rtol=1e-5)


def test_f8_dimension_scaling():
    s = F8Crusader(n_aircraft=5)
    assert s.spec.n == 15
    tr = simulate(s, jax.random.PRNGKey(0), horizon=50)
    assert tr.ys.shape == (51, 15)
    assert bool(jnp.all(jnp.isfinite(tr.ys)))


def test_van_der_pol_rhs_matches_handcoded():
    s = VanDerPol(mu=1.5)
    y = jnp.asarray([[0.7, -0.4]])
    u = jnp.asarray([[0.25]])
    y0, y1, uu = 0.7, -0.4, 0.25
    expect = [y1, 1.5 * (1 - y0 * y0) * y1 - y0 + uu]
    np.testing.assert_allclose(np.asarray(s.rhs(y, u))[0], expect, rtol=1e-6)


def test_van_der_pol_registered():
    reg = register_systems()
    assert reg["van_der_pol"] is VanDerPol
    assert VanDerPol().spec.order == 3


@pytest.mark.parametrize("system", SYSTEMS, ids=lambda s: s.spec.name)
def test_traces_finite(system):
    tr = simulate_batch(system, jax.random.PRNGKey(1), batch=3, horizon=150)
    assert bool(jnp.all(jnp.isfinite(tr.ys)))
    assert tr.ys.shape[0] == 3 and tr.ys.shape[-1] == system.spec.n
    assert tr.us.shape == (3, 150, system.spec.m)


@pytest.mark.parametrize("system", [LotkaVolterra(), Lorenz(),
                                    PathogenicAttack(), VanDerPol()],
                         ids=lambda s: s.spec.name)
def test_identifiable_via_stlsq(system):
    """Clean traces + STLSQ must recover the true coefficients — the
    identifiability assumption (paper Eq. 2) holds for every benchmark."""
    tr = simulate_batch(system, jax.random.PRNGKey(2), batch=6,
                        horizon=system.spec.horizon)
    y_win, u_win = make_windows(tr.ys, tr.us, window=40, stride=11)
    n, m = system.spec.n, system.spec.m
    dt = system.spec.dt
    dy = ((y_win[:, 2:, :] - y_win[:, :-2, :]) / (2 * dt)).reshape(-1, n)
    y = y_win[:, 1:-1, :].reshape(-1, n)
    u = u_win[:, 1:, :].reshape(y.shape[0], m)
    lib = system.library()
    phi = lib.eval(y, u if m else None)
    theta = np.asarray(stlsq(phi, dy, threshold=0.02))
    true = system.true_theta(lib)
    big = np.abs(true) > 0.05
    np.testing.assert_allclose(theta[big], true[big], rtol=0.1)


def test_noise_injection_scales():
    s = LotkaVolterra()
    tr = simulate(s, jax.random.PRNGKey(3), horizon=200, noise_std=0.05)
    resid = np.asarray(tr.ys_noisy - tr.ys)
    assert 0.0 < resid.std() < 1.0


# ------------------------------------------------------------------------- #
# New-zoo hand-derived rhs checks (rows() vs physics, like Lorenz/F-8/VdP)
# ------------------------------------------------------------------------- #
def test_quadrotor_rhs_matches_handcoded():
    s = Quadrotor(tau=8.0, d1=0.6, d3=0.4, g=9.81, c=0.35)
    y = jnp.asarray([[0.2, -0.3, 0.1]])
    u = jnp.asarray([[0.15]])
    phi, p, vy, uu = 0.2, -0.3, 0.1, 0.15
    expect = [p,
              8.0 * uu - 0.6 * p - 0.4 * p ** 3,
              9.81 * phi - 0.35 * vy]
    np.testing.assert_allclose(np.asarray(s.rhs(y, u))[0], expect, rtol=1e-5)


def test_thermal_battery_rhs_matches_handcoded():
    s = ThermalBattery(q=1.8, k1=0.9, k2=0.5)
    y = jnp.asarray([[3.0, 1.5]])
    u = jnp.asarray([[0.8]])
    tc, ts, uu = 3.0, 1.5, 0.8
    expect = [1.8 * uu * uu - 0.9 * (tc - ts),
              0.9 * (tc - ts) - 0.5 * ts]
    np.testing.assert_allclose(np.asarray(s.rhs(y, u))[0], expect, rtol=1e-5)


def test_grid_frequency_rhs_matches_handcoded():
    M, D, R, tau = 8.0, 1.0, 0.08, 0.5
    s = GridFrequency(M=M, D=D, R=R, tau=tau)
    y = jnp.asarray([[0.2, -0.1]])
    u = jnp.asarray([[0.3]])
    f, p, uu = 0.2, -0.1, 0.3
    expect = [(p - D * f - uu) / M, (-p - f / R) / tau]
    np.testing.assert_allclose(np.asarray(s.rhs(y, u))[0], expect, rtol=1e-5)


def test_grid_frequency_droop_steady_state():
    """Physics invariant: a constant load step settles at the analytic
    droop frequency f* = -u*R / (D*R + 1) — the number a grid operator's
    what-if query is really asking for."""
    M, D, R, tau = 8.0, 1.0, 0.08, 0.5
    s = GridFrequency(M=M, D=D, R=R, tau=tau)
    u_step = 0.2
    dt, steps = s.spec.dt, 2000
    y = jnp.zeros((1, 2))
    u = jnp.asarray([[u_step]])
    for _ in range(steps):        # forward Euler is fine for a settling test
        y = y + dt * s.rhs(y, u)
    f_star = -u_step * R / (D * R + 1.0)
    np.testing.assert_allclose(float(y[0, 0]), f_star, rtol=1e-2)


def test_thermal_battery_steady_state():
    """Constant current settles at the analytic two-lump equilibrium."""
    q, k1, k2 = 1.8, 0.9, 0.5
    s = ThermalBattery(q=q, k1=k1, k2=k2)
    i_const = 0.7
    dt = s.spec.dt
    y = jnp.zeros((1, 2))
    u = jnp.asarray([[i_const]])
    for _ in range(1500):
        y = y + dt * s.rhs(y, u)
    heat = q * i_const ** 2
    ts_star = heat / k2                       # all heat leaves by convection
    tc_star = ts_star + heat / k1
    np.testing.assert_allclose(np.asarray(y)[0], [tc_star, ts_star],
                               rtol=1e-2)


# ------------------------------------------------------------------------- #
# Registry-wide invariant suite: parametrized over ALL registered systems,
# so a new system is covered the moment it is registered — and fails the
# declaration check until its invariants are written down above.
# ------------------------------------------------------------------------- #
def test_every_registered_system_declares_invariants():
    missing = [n for n in ALL_NAMES
               if n not in EQUILIBRIA or n not in TRACE_BOUND]
    assert not missing, (
        f"systems registered without declared invariants: {missing} — add "
        "EQUILIBRIA and TRACE_BOUND entries in tests/test_systems.py")


@pytest.mark.parametrize("name", ALL_NAMES)
def test_registry_rhs_finite_on_domain(name):
    """rhs stays finite over a dense sample of the DOCUMENTED domain
    (spec.y0_low/high x input_scale) — the domain the scenario engine
    rolls from."""
    s = REGISTRY[name]()
    key = jax.random.PRNGKey(7)
    ky, ku = jax.random.split(key)
    y = s.sample_y0(ky, (256,))
    u = (jax.random.uniform(ku, (256, s.spec.m), minval=-1.0, maxval=1.0)
         * s.spec.input_scale) if s.spec.m else None
    dy = np.asarray(s.rhs(y, u))
    assert dy.shape == (256, s.spec.n)
    assert np.isfinite(dy).all(), f"{name}: non-finite rhs on its domain"


@pytest.mark.parametrize("name", ALL_NAMES)
def test_registry_equilibria(name):
    """Declared fixed points are actual fixed points of rows()."""
    s = REGISTRY[name]()
    for y_star, u_star in EQUILIBRIA[name]:
        y = jnp.asarray(y_star, jnp.float32)[None]
        u = None if u_star is None else jnp.asarray(u_star,
                                                    jnp.float32)[None]
        dy = np.asarray(s.rhs(y, u))
        np.testing.assert_allclose(dy, 0.0, atol=1e-6,
                                   err_msg=f"{name}: rhs != 0 at declared "
                                           f"equilibrium {y_star}")


@pytest.mark.parametrize("name", ALL_NAMES)
def test_registry_simulate_contract(name):
    """simulate_batch round trip: shapes, dtypes, finiteness, and the
    declared trajectory bound under default excitation."""
    s = REGISTRY[name]()
    tr = simulate_batch(s, jax.random.PRNGKey(11), batch=4, horizon=200,
                        noise_std=0.01)
    assert tr.ys.shape == (4, 201, s.spec.n)
    assert tr.ys_noisy.shape == tr.ys.shape
    assert tr.us.shape == (4, 200, s.spec.m)
    assert tr.ys.dtype == jnp.float32 and tr.us.dtype == jnp.float32
    assert tr.dt == s.spec.dt > 0
    ys = np.asarray(tr.ys)
    assert np.isfinite(ys).all(), f"{name}: non-finite trace"
    assert np.abs(ys).max() <= TRACE_BOUND[name], (
        f"{name}: |y| max {np.abs(ys).max():.1f} exceeds declared bound "
        f"{TRACE_BOUND[name]}")
    assert len(s.spec.y0_low) == len(s.spec.y0_high) == s.spec.n


@pytest.mark.parametrize("name", ALL_NAMES)
def test_registry_true_theta_consistent(name):
    """true_theta embeds rows() exactly: evaluating the library form
    reproduces rhs on random domain points (the single-source-of-truth
    contract the serving stack's fused rollouts rely on)."""
    s = REGISTRY[name]()
    lib = s.library()
    theta = jnp.asarray(s.true_theta(lib), jnp.float32)
    key = jax.random.PRNGKey(13)
    ky, ku = jax.random.split(key)
    y = s.sample_y0(ky, (32,))
    u = (jax.random.uniform(ku, (32, s.spec.m), minval=-1.0, maxval=1.0)
         * s.spec.input_scale) if s.spec.m else None
    phi = lib.eval(y, u)
    np.testing.assert_allclose(np.asarray(phi @ theta.T),
                               np.asarray(s.rhs(y, u)), rtol=1e-5,
                               atol=1e-6)
