"""Sharding rules unit tests (no multi-device mesh needed: a 1x1 mesh
exercises rule selection; spec CONTENT is asserted on a fake mesh object)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as sh


class FakeMesh:
    """Duck-typed mesh: just axis names/sizes (enough for spec logic)."""
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)

    @property
    def size(self):
        return int(np.prod(list(self.shape.values())))


def _spec(leaf_shape, rule_spec, mesh, **kw):
    # reuse internals: strip + divisibility + repair
    ns = sh.logical_to_sharding.__wrapped__ if hasattr(
        sh.logical_to_sharding, "__wrapped__") else sh.logical_to_sharding
    try:
        return ns(rule_spec, mesh, leaf_shape, **kw).spec
    except Exception:
        pytest.skip("NamedSharding requires a real mesh")


MESH = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_strip_missing_axes():
    m = FakeMesh({"data": 4, "model": 2})
    spec = sh._strip_missing_axes(P(("pod", "data"), "model"), m)
    assert spec == P(("data",), "model")


def test_shardable():
    m = FakeMesh({"data": 4, "model": 2})
    assert sh._shardable(8, "data", m)
    assert not sh._shardable(6, "data", m)
    assert sh._shardable(6, "model", m)
    assert sh._shardable(5, None, m)
    assert not sh._shardable(4, ("data", "model"), m)   # 4 % 8


def test_param_rules_order():
    """Expert rules must match before generic gate/up rules."""
    import re
    rules = sh.DEFAULT_PARAM_RULES
    path = "layers/0/moe/experts/up/w"
    for pat, spec in rules:
        if re.compile(pat).match(path):
            assert spec == P("model", "data", None)
            break
    path2 = "layers/0/ffn/up/w"
    for pat, spec in rules:
        if re.compile(pat).match(path2):
            assert spec == P("data", "model")
            break


def test_param_shardings_on_real_mesh():
    """End-to-end on a 1-device mesh: every param leaf gets a sharding and
    stacked leading axes are padded with None."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = sh.ShardingRules(mesh=mesh)
    params = {
        "layers": {"attn": {"wq": {"w": jnp.zeros((4, 8, 16))}}},  # stacked
        "embed": {"w": jnp.zeros((32, 8))},
        "norm": {"scale": jnp.zeros((8,))},
    }
    out = sh.param_shardings(rules, params)
    assert out["layers"]["attn"]["wq"]["w"].spec == P(None, "data", "model")
    assert out["embed"]["w"].spec == P("model", "data")
    assert out["norm"]["scale"].spec in (P(), P(None))  # both = replicated


def test_repair_relocates_model_axis():
    """mixtral case: 8 experts cannot split over model=16 -> the model axis
    must land on a divisible dim instead of silently replicating."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # simulate divisibility logic with the production mesh sizes via a
    # private check: use logical_to_sharding on the real (1,1) mesh but
    # verify the repair branch through _shardable on the fake mesh.
    assert not sh._shardable(8, "model", MESH)
    assert sh._shardable(16384, "model", MESH)
    # full-path check on the real production mesh requires 512 devices and
    # is exercised by launch/dryrun.py (mixtral cells fit post-repair).


def test_cache_shardings_rank_dispatch():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = sh.ShardingRules(mesh=mesh)
    cache = {
        "layers": [{"k": jnp.zeros((3, 2, 8, 2, 4)),     # stacked attn
                    "v": jnp.zeros((3, 2, 8, 2, 4)),
                    "pos": jnp.zeros((3, 2, 8), jnp.int32)}],
        "pos": jnp.zeros((2,), jnp.int32),
    }
    out = sh.cache_shardings(rules, cache, batch=2)
    assert out["layers"][0]["k"].spec == P(None, ("data",), "model", None, None)
    assert out["pos"].spec == P(("data",))


def test_shard_noop_outside_rules():
    x = jnp.ones((4, 4))
    assert sh.shard(x, "act_btd") is x
