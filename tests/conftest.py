import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
    config.addinivalue_line("markers",
                            "dryrun: multi-device compile-only test")
