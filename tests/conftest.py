# Markers are registered in pyproject.toml ([tool.pytest.ini_options]);
# this hook stays so the suite also collects cleanly when pytest is invoked
# with an explicit -c pointing elsewhere.
def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
    config.addinivalue_line("markers",
                            "dryrun: multi-device compile-only test")
    config.addinivalue_line("markers", "hypothesis: property-based test")
    config.addinivalue_line("markers", "chaos: fault-injection recovery test")
    config.addinivalue_line("markers",
                            "scenario: what-if scenario-engine test")
