"""MoE routing semantics: capacity drops, combine-weight normalization,
aux loss, and property-based invariants."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.moe import moe_apply, moe_init, router_topk


def test_combine_weights_normalized_when_kept():
    G, n, E, k, C = 1, 16, 4, 2, 16   # capacity ample: nothing drops
    logits = jax.random.normal(jax.random.PRNGKey(0), (G, n, E))
    combine, aux = router_topk(logits, k, C)
    w_sum = np.asarray(combine.sum(axis=(2, 3)))
    np.testing.assert_allclose(w_sum, 1.0, rtol=1e-5)
    assert float(aux) > 0.0


def test_capacity_drops_tokens():
    """All tokens pick expert 0 first; capacity 2 keeps exactly 2."""
    G, n, E, k = 1, 8, 4, 1
    logits = jnp.zeros((G, n, E)).at[..., 0].set(10.0)
    combine, _ = router_topk(logits, k, capacity=2)
    kept = float((combine.sum(axis=(2, 3)) > 0).sum())
    assert kept == 2.0


def test_slot_assignment_no_collisions():
    """Two tokens on the same expert occupy different capacity slots."""
    G, n, E = 1, 4, 2
    logits = jnp.zeros((G, n, E)).at[..., 0].set(5.0)
    combine, _ = router_topk(logits, 1, capacity=4)
    occupancy = np.asarray((combine[0, :, 0, :] > 0))     # [n, C]
    # each kept token sits in its own slot
    assert occupancy.sum(axis=0).max() <= 1


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_moe_apply_finite_and_shaped(seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    d, E, ff = 8, 4, 16
    params = moe_init(k1, d, ff, E, "swiglu", jnp.float32)
    x = jax.random.normal(k2, (2, 8, d))
    y, aux = moe_apply(params, x, n_experts=E, top_k=2, group_size=16,
                       capacity_factor=8.0)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert np.isfinite(float(aux))


def test_token_permutation_equivariance():
    """With no drops, permuting tokens permutes outputs identically (the
    dispatch/combine einsums must not leak across positions)."""
    key = jax.random.PRNGKey(3)
    k1, k2 = jax.random.split(key)
    d, E, ff = 8, 4, 16
    params = moe_init(k1, d, ff, E, "swiglu", jnp.float32)
    x = jax.random.normal(k2, (1, 16, d))
    y, _ = moe_apply(params, x, n_experts=E, top_k=2, group_size=16,
                     capacity_factor=8.0)
    perm = jax.random.permutation(jax.random.PRNGKey(4), 16)
    y_p, _ = moe_apply(params, x[:, perm], n_experts=E, top_k=2,
                       group_size=16, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(y[:, perm]), np.asarray(y_p),
                               rtol=2e-5, atol=2e-5)


def test_expert_flops_scale_with_capacity_factor():
    """Capacity bounds compute: dispatch buffer second dim == C."""
    G, n, E, k = 1, 64, 8, 2
    logits = jax.random.normal(jax.random.PRNGKey(5), (G, n, E))
    for cf in (1.0, 2.0):
        C = max(int(np.ceil(k * n * cf / E)), 1)
        combine, _ = router_topk(logits, k, C)
        assert combine.shape == (G, n, E, C)
