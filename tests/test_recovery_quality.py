"""Recovery quality as a TRACKED number: term-selection F1 per system.

The suite's correctness tests are binary; this module makes recovery
accuracy a trajectory.  For every registered system it fits the noisy-data
STLSQ path (the serving stack's warm-start estimator) and scores the
recovered support against the ground-truth library coefficients:

    precision  |predicted ∩ true| / |predicted|
    recall     |predicted ∩ true| / |true|
    f1         harmonic mean — the gated column
    mse        coefficient MSE on the true support (reported)

Rows land in `bench_out/recovery_quality.csv` and are compared to the
checked-in baseline by tools/check_bench.py (WARN-ONLY by design: this
file exists to make the number visible, promoting it to a hard gate is
the ROADMAP's recovery-quality item).  One additional `slow` row runs the
full MERINDA trainer on Lotka-Volterra — the tracked number behind the
known-failing seed xfail in tests/test_merinda.py — so the defect shows
up as an F1 deficit in a CSV instead of only as an xfail marker.

Each test also asserts a LOOSE floor (F1 >= 0.5 on clean-ish data) so a
total identifiability collapse fails the default lane outright even
without baselines.
"""
import csv
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core.sparse_regression import stlsq
from repro.data.pipeline import make_windows
from repro.systems.simulate import register_systems, simulate_batch

jax.config.update("jax_platform_name", "cpu")

REGISTRY = register_systems()
ALL_NAMES = sorted(REGISTRY)
OUT = Path(__file__).resolve().parent.parent / "bench_out" \
    / "recovery_quality.csv"

NOISE = 0.002           # serving-bench telemetry noise level
SUPPORT_ATOL = 0.02     # |coeff| above this counts as a selected term

_ROWS: list[dict] = []


def _score(theta, true):
    pred = np.abs(np.asarray(theta)) > SUPPORT_ATOL
    actual = np.abs(np.asarray(true)) > SUPPORT_ATOL
    tp = int((pred & actual).sum())
    precision = tp / max(int(pred.sum()), 1)
    recall = tp / max(int(actual.sum()), 1)
    f1 = (2 * precision * recall / (precision + recall)
          if precision + recall else 0.0)
    mse = float(np.mean((np.asarray(theta)[actual] - true[actual]) ** 2))
    return precision, recall, f1, mse


def _record(name, method, precision, recall, f1, mse):
    _ROWS.append({"system": name, "method": method, "noise": NOISE,
                  "precision": round(precision, 3),
                  "recall": round(recall, 3),
                  "f1": round(f1, 3), "mse": round(mse, 5)})


@pytest.fixture(scope="module", autouse=True)
def _write_csv_at_teardown():
    """Rows accumulate across the module; one CSV lands at the end (only
    the rows that actually ran — check_bench skips absent identities)."""
    yield
    if _ROWS:
        OUT.parent.mkdir(parents=True, exist_ok=True)
        rows = sorted(_ROWS, key=lambda r: (r["system"], r["method"]))
        with open(OUT, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)


@pytest.mark.parametrize("name", ALL_NAMES)
def test_stlsq_recovery_f1(name):
    """STLSQ on NOISY windows: the estimator the online warm-start path
    actually runs.  Loose floor; the tracked number is the CSV."""
    system = REGISTRY[name]()
    tr = simulate_batch(system, jax.random.PRNGKey(2), batch=6,
                        horizon=system.spec.horizon, noise_std=NOISE)
    y_win, u_win = make_windows(tr.ys_noisy, tr.us, window=40, stride=11)
    n, m, dt = system.spec.n, system.spec.m, system.spec.dt
    dy = ((y_win[:, 2:, :] - y_win[:, :-2, :]) / (2 * dt)).reshape(-1, n)
    y = y_win[:, 1:-1, :].reshape(-1, n)
    u = u_win[:, 1:, :].reshape(y.shape[0], m)
    lib = system.library()
    phi = lib.eval(y, u if m else None)
    theta = np.asarray(stlsq(phi, dy, threshold=0.02))
    true = system.true_theta(lib)
    precision, recall, f1, mse = _score(theta, true)
    _record(name, "stlsq", precision, recall, f1, mse)
    assert f1 >= 0.5, (
        f"{name}: term-selection F1 {f1:.2f} collapsed (precision "
        f"{precision:.2f}, recall {recall:.2f})")


@pytest.mark.slow
def test_merinda_recovery_f1_lotka_volterra():
    """Full-trainer recovery on Lotka-Volterra — the number behind the
    known-failing seed xfail (tests/test_merinda.py).  RECORDED, with only
    a does-it-learn-anything floor: the CSV baseline is what tracks it."""
    from repro.core.merinda import Merinda, MerindaConfig
    from repro.core.trainer import fit
    from repro.data.pipeline import WindowDataset
    from repro.systems.lotka_volterra import LotkaVolterra

    sys_ = LotkaVolterra()
    tr = simulate_batch(sys_, jax.random.PRNGKey(0), batch=6, horizon=300)
    ds = WindowDataset.from_trace(tr.ys_noisy, tr.us, tr.dt, window=40,
                                  stride=10)
    model = Merinda(MerindaConfig(n=sys_.spec.n, m=sys_.spec.m, order=2,
                                  hidden=32, head_hidden=32, n_active=4,
                                  dt=sys_.spec.dt, l1=2e-3))
    params = model.init(jax.random.PRNGKey(1),
                        model.norm_stats(ds.y_win, ds.u_win))
    res = fit(model, params,
              ds.batches(jax.random.PRNGKey(2), 64, epochs=400),
              steps=700, lr=5e-3, sparsify_after=0.6)
    theta = model.recover(res.params, ds.y_win[:200], ds.u_win[:200])
    true = sys_.true_theta(model.lib)
    precision, recall, f1, mse = _score(theta, true)
    _record("lotka_volterra", "merinda", precision, recall, f1, mse)
    # one wrong support term (the tracked defect) still scores ~0.75;
    # anything below half means the trainer stopped learning, which is a
    # different (new) failure
    assert f1 >= 0.5 and np.isfinite(mse)
