"""Gradient compression: quantization error bounds, top-k + error feedback
convergence property, and wire-byte model."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.distributed.compression import (dequantize_int8, int8_compressor,
                                           quantize_int8, topk_compressor)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(1e-3, 1e3))
def test_int8_quantization_error_bound(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * scale
    q, s = quantize_int8(x)
    x_hat = dequantize_int8(q, s)
    # error bounded by half a quantization step
    assert float(jnp.max(jnp.abs(x - x_hat))) <= float(s) * 0.5 + 1e-6


def test_topk_error_feedback_sums_to_identity():
    """Over many steps, sum(sent) == sum(grads): error feedback loses
    nothing in expectation (telescoping residual)."""
    comp = topk_compressor(keep_frac=0.25)
    key = jax.random.PRNGKey(0)
    g_total = jnp.zeros((32,))
    sent_total = jnp.zeros((32,))
    err = None
    for i in range(20):
        key, sub = jax.random.split(key)
        g = {"w": jax.random.normal(sub, (32,))}
        g_total = g_total + g["w"]
        sent, err = comp.apply(g, err)
        sent_total = sent_total + sent["w"]
    # residual is whatever is still in the error buffer
    np.testing.assert_allclose(np.asarray(sent_total + err["w"]),
                               np.asarray(g_total), rtol=1e-5, atol=1e-5)


def test_topk_sparsity():
    comp = topk_compressor(keep_frac=0.1)
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (1000,))}
    sent, err = comp.apply(g, None)
    nnz = int(jnp.sum(sent["w"] != 0.0))
    assert nnz <= 110                      # ~10% kept
    assert comp.wire_bytes_per_param() < 4.0  # beats raw f32


def test_int8_compressor_pytree():
    comp = int8_compressor()
    g = {"a": jnp.ones((4, 4)) * 3.0, "b": jnp.linspace(-1, 1, 16)}
    out, err = comp.apply(g, None)
    assert err is None
    np.testing.assert_allclose(np.asarray(out["a"]), 3.0, rtol=1e-2)
