"""Crash-safe serving: checkpoints, failover + journal replay, degradation.

The contract under test (twin/recovery.py + server/sharded wiring): a shard
crash loses NO telemetry inside the ring horizon — the supervisor restores
the last committed checkpoint, replays the journal suffix, and the guard
re-derives the same ALERT set an uninterrupted run produces.  Overload never
breaks the deadline silently: the degradation ladder sheds work in a fixed
order BEFORE the deadline is violated and restores when pressure clears.
"""
import threading

import jax
import numpy as np
import pytest

from repro.core.merinda import MerindaConfig
from repro.systems.lotka_volterra import LotkaVolterra
from repro.systems.simulate import simulate_batch
from repro.twin.monitor import GuardConfig
from repro.twin.recovery import (ChaosConfig, ChaosInjector,
                                 DegradationConfig, DegradationPolicy,
                                 RecoveryConfig, TelemetryJournal,
                                 TwinCheckpointer)
from repro.twin.scheduler import FederationConfig, SlotFederation
from repro.twin.server import TwinServer, TwinServerConfig
from repro.twin.sharded import ShardedTwinConfig, ShardedTwinServer
from repro.twin.stream import StagingBuffer, StagingOverflow


# --------------------------------------------------------------------- #
# telemetry journal: the replay source
# --------------------------------------------------------------------- #
def _chunk(rng, c, n=2, m=1):
    return (rng.normal(size=(c, n)).astype(np.float32),
            rng.normal(size=(c, m)).astype(np.float32))


def test_journal_replays_exact_suffix():
    rng = np.random.default_rng(0)
    j = TelemetryJournal(horizon=100)
    sent_y, sent_u = [], []
    for c in (3, 5, 4):
        y, u = _chunk(rng, c)
        j.append(7, y, u)
        sent_y.append(y)
        sent_u.append(u)
    all_y = np.concatenate(sent_y)
    all_u = np.concatenate(sent_u)
    # seen=4 falls INSIDE the second chunk: the first replayed chunk must be
    # trimmed, and the concatenation must equal the true suffix exactly
    chunks, lost = j.replay_since(7, seen=4)
    assert lost == 0
    got_y = np.concatenate([y for y, _ in chunks])
    got_u = np.concatenate([u for _, u in chunks])
    np.testing.assert_array_equal(got_y, all_y[4:])
    np.testing.assert_array_equal(got_u, all_u[4:])
    # fully caught up -> nothing to replay
    assert j.replay_since(7, seen=12) == ([], 0)
    assert j.total(7) == 12 and j.twin_ids() == [7]


def test_journal_horizon_eviction_counts_lost():
    rng = np.random.default_rng(1)
    j = TelemetryJournal(horizon=6)
    for _ in range(5):                      # 20 samples, horizon keeps <= ~8
        j.append(1, *_chunk(rng, 4))
    chunks, lost = j.replay_since(1, seen=0)
    got = sum(len(y) for y, _ in chunks)
    assert lost > 0 and lost + got == 20    # every sample accounted for
    assert got >= 6                         # horizon worth is recoverable
    # the tail inside the horizon is never lost
    _, lost_tail = j.replay_since(1, seen=20 - 6)
    assert lost_tail == 0


def test_journal_concurrent_appends_keep_per_twin_order():
    j = TelemetryJournal(horizon=10_000)

    def pump(tid):
        for i in range(50):
            j.append(tid, np.full((2, 2), i, np.float32))

    threads = [threading.Thread(target=pump, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for tid in range(4):
        chunks, lost = j.replay_since(tid, seen=0)
        assert lost == 0
        vals = np.concatenate([y for y, _ in chunks])[:, 0]
        assert list(vals) == sorted(vals)   # chronological per twin


# --------------------------------------------------------------------- #
# checkpointer: atomic commits, GC, torn-write fallback
# --------------------------------------------------------------------- #
def _snap(v):
    return lambda: {"w": np.full((4, 3), v, np.float32),
                    "step": np.asarray([v], np.int64)}


def test_checkpointer_roundtrip_and_gc(tmp_path):
    ck = TwinCheckpointer(RecoveryConfig(ckpt_dir=str(tmp_path),
                                         ckpt_every=4, keep=2))
    assert not ck.maybe_save(0, 3, _snap(3))        # off cadence
    for tick in (4, 8, 12):
        assert ck.maybe_save(0, tick, _snap(tick))
    ck.wait()
    assert ck.latest(0) == 12
    tick, state = ck.restore_latest(0, _snap(0)())
    assert tick == 12
    np.testing.assert_array_equal(np.asarray(state["w"]),
                                  np.full((4, 3), 12, np.float32))
    kept = sorted(p.name for p in ck.shard_dir(0).glob("step_*"))
    assert len(kept) <= 2                           # GC keeps the last `keep`


def test_checkpointer_torn_commit_falls_back(tmp_path):
    ck = TwinCheckpointer(RecoveryConfig(ckpt_dir=str(tmp_path),
                                         ckpt_every=1, keep=2))
    ck.maybe_save(0, 1, _snap(1))
    ck.maybe_save(0, 2, _snap(2))
    assert ck.tear_latest(0) == 2                   # crash mid-write of #2
    tick, state = ck.restore_latest(0, _snap(0)())
    assert tick == 1                                # fell back, didn't corrupt
    np.testing.assert_array_equal(np.asarray(state["step"]), [1])


def test_checkpointer_keep_must_cover_torn_fallback(tmp_path):
    with pytest.raises(ValueError, match="keep"):
        RecoveryConfig(ckpt_dir=str(tmp_path), keep=1)


def test_checkpointer_restore_nothing_committed(tmp_path):
    ck = TwinCheckpointer(RecoveryConfig(ckpt_dir=str(tmp_path)))
    assert ck.restore_latest(3, _snap(0)()) == (None, None)


# --------------------------------------------------------------------- #
# chaos injector: deterministic one-shot schedule
# --------------------------------------------------------------------- #
def test_chaos_kill_fires_once_even_past_the_tick():
    inj = ChaosInjector(ChaosConfig(kill_shard=1, kill_at_tick=5))
    assert not inj.should_kill(0, 5)                # wrong shard
    assert not inj.should_kill(1, 4)
    assert inj.should_kill(1, 7)                    # >= semantics, skipped 5/6
    assert not inj.should_kill(1, 8)                # one-shot


def test_chaos_windows():
    inj = ChaosInjector(ChaosConfig(slow_shard=0, slow_s=0.5,
                                    slow_from_tick=3, slow_until_tick=5,
                                    storm_shard=1, storm_factor=3,
                                    storm_from_tick=2, storm_until_tick=4))
    assert inj.slow_delay(0, 2) == 0.0
    assert inj.slow_delay(0, 4) == 0.5
    assert inj.slow_delay(1, 4) == 0.0
    assert inj.storm_extra(1, 3) == 2
    assert inj.storm_extra(1, 4) == 0
    assert not inj.should_tear()                    # not scheduled


# --------------------------------------------------------------------- #
# degradation ladder (policy unit; server wiring below)
# --------------------------------------------------------------------- #
def test_degradation_ladder_up_then_down_with_hysteresis():
    pol = DegradationPolicy(DegradationConfig(enabled=True, hold_ticks=2,
                                              alpha=0.9), deadline_s=1.0)
    levels = []
    for t in range(1, 9):
        pol.observe(t, 0.95)                        # sustained overload
        levels.append(pol.level)
    # one level per hold_ticks, capped at max_level
    assert levels == [1, 1, 2, 2, 3, 3, 3, 3]
    assert pol.shed_guard and pol.defer_refit and pol.skip_promote
    for t in range(9, 30):
        pol.observe(t, 0.01)                        # pressure clears
        if pol.level == 0:
            break
    assert pol.level == 0
    assert not (pol.shed_guard or pol.defer_refit or pol.skip_promote)


def test_degradation_disabled_observes_but_never_sheds():
    pol = DegradationPolicy(DegradationConfig(enabled=False), deadline_s=1.0)
    for t in range(1, 10):
        assert pol.observe(t, 5.0) is None
    assert pol.level == 0 and pol.pressure > 1.0    # pressure still visible


# --------------------------------------------------------------------- #
# federation: dead shards give their slots to the survivors
# --------------------------------------------------------------------- #
def test_federation_dead_shard_grant_flows_to_survivors():
    fed = SlotFederation(FederationConfig(total_slots=8, min_slots=1,
                                          smooth=1.0), [4, 4, 4])
    base = fed.rebalance([1.0, 1.0, 1.0])
    assert sum(base) == 8 and all(g >= 1 for g in base)
    dead = fed.rebalance([1.0, 0.0, 1.0], alive=[True, False, True])
    assert dead[1] == 0                             # no floor for the dead
    assert sum(dead) <= 8 and dead[0] + dead[2] == sum(dead)
    assert dead[0] >= base[0] and dead[2] >= base[2]
    back = fed.rebalance([1.0, 1.0, 1.0], alive=[True, True, True])
    assert back[1] >= 1                             # restart rejoins the floor


def test_federation_all_dead_parks_the_budget():
    fed = SlotFederation(FederationConfig(total_slots=6, min_slots=1,
                                          smooth=1.0), [3, 3])
    assert fed.rebalance([1.0, 1.0], alive=[False, False]) == [0, 0]


# --------------------------------------------------------------------- #
# bounded staging: retry/backoff then strict-raise or drop-oldest
# --------------------------------------------------------------------- #
def test_staging_overflow_strict_and_force():
    buf = StagingBuffer(capacity=8)
    y = np.zeros((4, 2), np.float32)
    u = np.zeros((4, 1), np.float32)
    buf.append(0, y, u)
    buf.append(1, y, u)
    with pytest.raises(StagingOverflow):
        buf.append(2, y, u)
    buf.append(2, y, u, force=True)                 # replay bypass
    assert buf.pending_samples() == 12


def test_staging_drop_oldest_preserves_chronology():
    buf = StagingBuffer(capacity=100)
    for i in range(4):
        buf.append(0, np.full((2, 1), i, np.float32),
                   np.zeros((2, 1), np.float32))
    dropped = buf.drop_oldest(3)
    assert dropped >= 3
    staged = buf.swap()
    ys = np.concatenate([y for y, _ in staged[0]])[:, 0]
    # the OLDEST chunks went first; what survives is still in order
    assert list(ys) == sorted(ys) and ys[0] >= 2


def _world():
    sys_ = LotkaVolterra()
    tr = simulate_batch(sys_, jax.random.PRNGKey(0), batch=8, horizon=400,
                       noise_std=0.002)
    return sys_, np.asarray(tr.ys_noisy), np.asarray(tr.us)


@pytest.fixture(scope="module")
def lv_world():
    return _world()


def _server_cfg(sys_, **kw):
    d = dict(
        merinda=MerindaConfig(n=2, m=0, order=2, hidden=8, head_hidden=8,
                              n_active=4, dt=sys_.spec.dt),
        max_twins=6, refit_slots=2, capacity=128, window=16, stride=8,
        windows_per_twin=4, steps_per_tick=1, deploy_after=2,
        min_residency=1, max_residency=4,
        guard=GuardConfig(window=16))
    d.update(kw)
    return TwinServerConfig(**d)


def test_server_ingest_backpressure_sheds_oldest(lv_world):
    """Non-strict bounded staging: overload drops the OLDEST staged samples
    (counted) and keeps serving; strict mode raises to the producer."""
    sys_, ys, us = lv_world
    srv = TwinServer(_server_cfg(sys_, staging_capacity=16,
                                 ingest_strict=False, ingest_retries=1,
                                 ingest_backoff_s=1e-4))
    try:
        for k in range(5):                          # 40 > 16 staged samples
            srv.ingest(k % 2, ys[0, k * 8:(k + 1) * 8])
        assert int(srv._m_ingest_dropped.value) > 0
        assert int(srv._m_ingest_retries.value) > 0
        srv.tick()                                  # still serves
        assert srv.twins[0].samples + srv.twins[1].samples <= 16
    finally:
        srv.close()
    strict = TwinServer(_server_cfg(sys_, staging_capacity=8,
                                    ingest_retries=0))
    try:
        strict.ingest(0, ys[0, :8])
        with pytest.raises(StagingOverflow):
            strict.ingest(1, ys[1, :8])
        strict.ingest(1, ys[1, :8], force=True)     # replay path bypasses
    finally:
        strict.close()


# --------------------------------------------------------------------- #
# serving-state snapshot/restore round trip
# --------------------------------------------------------------------- #
def test_server_snapshot_restore_roundtrip(lv_world):
    """A fresh server restored from a snapshot serves indistinguishably:
    same registry, same thetas/predictions, same guard + scheduler state."""
    sys_, ys, us = lv_world
    cfg = _server_cfg(sys_)
    srv = TwinServer(cfg)
    try:
        lib = srv.fleet.model.lib
        true = sys_.true_theta(lib)
        for t in range(6):
            for i in range(4):
                srv.ingest(i, ys[i, t * 20:(t + 1) * 20])
            if t == 1:
                srv.deploy(0, true)
                srv.deploy(1, -true)
            srv.tick()
        snap = jax.tree.map(np.asarray, jax.device_get(srv.snapshot_state()))

        twin = TwinServer(cfg, share_modules_from=srv)
        twin.restore_state(snap)
        assert twin.tick_count == srv.tick_count
        assert sorted(twin.twins) == sorted(srv.twins)
        for tid, rec in srv.twins.items():
            r2 = twin.twins[tid]
            assert (r2.samples, r2.deployed, r2.refit_slot, r2.residency) \
                == (rec.samples, rec.deployed, rec.refit_slot, rec.residency)
            assert r2.divergence == pytest.approx(rec.divergence)
        assert twin._guard_state == srv._guard_state
        assert twin._slot_twin == srv._slot_twin
        np.testing.assert_array_equal(np.asarray(twin._theta),
                                      np.asarray(srv._theta))
        np.testing.assert_array_equal(
            np.asarray(twin.predict(0, 10)), np.asarray(srv.predict(0, 10)))
        # both continue ticking identically on identical telemetry
        for i in range(4):
            chunk = ys[i, 120:140]
            srv.ingest(i, chunk)
            twin.ingest(i, chunk)
        r1, r2 = srv.tick(), twin.tick()
        assert r1.n_guarded == r2.n_guarded
        assert [e.kind for e in r1.events] == [e.kind for e in r2.events]
    finally:
        srv.close()


def test_restore_rejects_mismatched_shapes(lv_world):
    sys_, _, _ = lv_world
    srv = TwinServer(_server_cfg(sys_))
    other = TwinServer(_server_cfg(sys_, max_twins=8))
    try:
        snap = jax.tree.map(np.asarray, jax.device_get(srv.snapshot_state()))
        with pytest.raises((ValueError, KeyError)):
            other.packed.load(snap["packed"])
    finally:
        srv.close()
        other.close()


# --------------------------------------------------------------------- #
# chaos lane: fault-injected sharded serving
# --------------------------------------------------------------------- #
def _fleet_cfg(sys_, shards, twins_per_shard, **kw):
    scfg = TwinServerConfig(
        merinda=MerindaConfig(n=2, m=0, order=2, hidden=8, head_hidden=8,
                              n_active=4, dt=sys_.spec.dt),
        max_twins=twins_per_shard, refit_slots=4, capacity=64,
        window=16, stride=8, windows_per_twin=4, steps_per_tick=1,
        deploy_after=10 ** 6,                  # guard-only serving: samples
        min_residency=1,                       # stay under the refit span so
        guard=GuardConfig(window=16))          # no slot ever trains
    return ShardedTwinConfig.uniform(scfg, shards, **kw)


def _alert_sets(fleet):
    state = {tid for s in fleet.shards if s is not None
             for tid, k in s._guard_state.items() if k == "ALERT"}
    events = {e.twin_id for s in fleet.shards if s is not None
              for e in s.events if e.kind == "ALERT"}
    return state, events


def _run_fleet(fleet, sys_, ys, us, n_twins, damaged, ticks, per_tick=2):
    lib = fleet.shards[0].fleet.model.lib
    true = np.asarray(sys_.true_theta(lib))
    rng = np.random.default_rng(7)
    for tid in range(n_twins):
        fleet.register(tid)
    fleet.deploy_many(list(range(n_twins)),
                      np.stack([-true if tid in damaged else true
                                for tid in range(n_twins)]))
    reports = []
    for t in range(ticks):
        for tid in range(n_twins):
            s = t * per_tick
            fleet.ingest(tid, ys[tid % ys.shape[0], s:s + per_tick])
        reports.append(fleet.tick())
    fleet.drain()
    return reports


@pytest.mark.chaos
def test_kill_shard_at_1k_twins_recovers_all_alerts(lv_world, tmp_path):
    """THE crash contract: kill 1 of 4 shards mid-serving at 1024 twins;
    the supervisor restores the last committed checkpoint + replays the
    journal, and the re-derived guard ALERT set EQUALS an uninterrupted
    run's — zero lost alerts inside the ring horizon — within a bounded
    number of recovery ticks."""
    sys_, ys, us = lv_world
    n_twins, shards, ticks = 1024, 4, 16
    damaged = {tid for tid in range(n_twins) if tid % 7 == 3}

    control = ShardedTwinServer(_fleet_cfg(sys_, shards, n_twins // shards))
    try:
        _run_fleet(control, sys_, ys, us, n_twins, damaged, ticks)
        control_state, control_events = _alert_sets(control)
        control_samples = {tid: s.twins[tid].samples
                          for s in control.shards for tid in s.twins}
    finally:
        control.close()
    assert control_state == damaged                 # the guard works at all

    chaos = ShardedTwinServer(_fleet_cfg(
        sys_, shards, n_twins // shards,
        recovery=RecoveryConfig(ckpt_dir=str(tmp_path), ckpt_every=4,
                                restart_delay_ticks=1),
        chaos=ChaosConfig(kill_shard=2, kill_at_tick=12)))
    try:
        reports = _run_fleet(chaos, sys_, ys, us, n_twins, damaged, ticks)
        died = [r for r in reports if r.dead_shards > 0]
        restarts = [rec for r in reports for rec in r.restarted]
        assert died and restarts, "chaos schedule never fired"
        rec = restarts[0]
        assert rec["shard"] == 2
        assert rec["ckpt_tick"] is not None         # restored, not rebuilt
        assert rec["lost"] == 0                     # inside the ring horizon
        assert rec["replayed"] > 0
        # bounded recovery: down for restart_delay (+ the kill tick itself)
        assert rec["down_ticks"] <= 2
        assert int(chaos._m_replay_lost.value) == 0

        chaos_state, chaos_events = _alert_sets(chaos)
        assert chaos_state == control_state         # same final ALERT set
        assert chaos_events == control_events       # same twins ever alerted
        # replay restored every sample the crash interrupted
        chaos_samples = {tid: s.twins[tid].samples
                         for s in chaos.shards for tid in s.twins}
        assert chaos_samples == control_samples
        assert reports[-1].dead_shards == 0
    finally:
        chaos.close()


@pytest.mark.chaos
def test_torn_checkpoint_falls_back_to_previous_commit(lv_world, tmp_path):
    """A crash mid-checkpoint-write (COMMIT torn off) must not poison
    recovery: restore falls back to the previous committed tick and the
    journal covers the longer gap."""
    sys_, ys, us = lv_world
    n_twins = 32
    damaged = {3, 10, 17}
    fleet = ShardedTwinServer(_fleet_cfg(
        sys_, 2, n_twins // 2,
        recovery=RecoveryConfig(ckpt_dir=str(tmp_path), ckpt_every=3,
                                restart_delay_ticks=1),
        chaos=ChaosConfig(kill_shard=1, kill_at_tick=8,
                          torn_checkpoint=True)))
    try:
        reports = _run_fleet(fleet, sys_, ys, us, n_twins, damaged, 14)
        rec = [r for rep in reports for r in rep.restarted][0]
        # newest commit before the kill was tick 6; chaos tore it -> tick 3
        assert rec["ckpt_tick"] == 3
        assert rec["lost"] == 0 and rec["replayed"] > 0
        assert int(fleet.checkpointer._m_torn.value) == 1
        state, _ = _alert_sets(fleet)
        assert state == damaged                     # served through it all
    finally:
        fleet.close()


@pytest.mark.chaos
def test_degradation_sheds_before_deadline_breaks(lv_world, tmp_path):
    """Injected straggler drives pressure ABOVE high_water while staying
    UNDER the deadline: the ladder climbs through guard->refit->promote
    shedding with ZERO deadline violations, then returns to level 0 once
    the stall clears."""
    sys_, ys, us = lv_world
    srv = TwinServer(_server_cfg(
        sys_, deadline_s=0.5,
        degradation=DegradationConfig(enabled=True, high_water=0.8,
                                      low_water=0.5, alpha=0.9,
                                      hold_ticks=1)))
    try:
        for t in range(4):                          # warm up + compile
            for i in range(4):
                srv.ingest(i, ys[i, t * 20:(t + 1) * 20])
            srv.tick()
        srv.reset_latency_stats()                   # compile != overload
        assert srv.degraded_level == 0
        ups0 = int(srv._m_deg_trans["up"].value)
        downs0 = int(srv._m_deg_trans["down"].value)
        srv.inject_delay_s = 0.45                   # 90% of deadline
        seen_levels = []
        for t in range(5):
            rep = srv.tick()
            seen_levels.append(rep.degraded_level)
        assert max(seen_levels) == 3                # full ladder engaged
        assert seen_levels == sorted(seen_levels)   # one level at a time
        assert int(srv._m_shed["guard"].value) > 0
        assert int(srv._m_shed["refit"].value) > 0
        assert int(srv._m_shed["promote"].value) > 0
        assert int(srv._m_violations.value) == 0    # shed BEFORE breaking
        srv.inject_delay_s = 0.0                    # pressure clears
        for t in range(30):
            rep = srv.tick()
            if rep.degraded_level == 0:
                break
        assert rep.degraded_level == 0              # restored, full service
        assert srv._degradation.pressure < 0.5
        assert int(srv._m_violations.value) == 0
        ups = int(srv._m_deg_trans["up"].value) - ups0
        downs = int(srv._m_deg_trans["down"].value) - downs0
        assert ups == downs == 3                    # clean round trip
    finally:
        srv.close()


@pytest.mark.chaos
def test_chaos_slow_shard_degrades_only_that_shard(lv_world):
    """The sharded slow-shard knob lands INSIDE the victim's timed tick:
    its own ladder climbs (visible in the sharded report) while the
    healthy shard keeps full service."""
    sys_, ys, us = lv_world
    base = _server_cfg(
        sys_, deadline_s=0.5,
        degradation=DegradationConfig(enabled=True, high_water=0.8,
                                      low_water=0.5, alpha=0.9,
                                      hold_ticks=1))
    fleet = ShardedTwinServer(ShardedTwinConfig(
        servers=(base, base),
        chaos=ChaosConfig(slow_shard=1, slow_s=0.45,
                          slow_from_tick=3, slow_until_tick=7)))
    try:
        levels = []
        for t in range(8):
            for i in range(6):
                fleet.ingest(i, ys[i, t * 10:(t + 1) * 10])
            rep = fleet.tick()
            levels.append(rep.degraded_level)
        assert max(levels) >= 1                     # victim shed
        assert fleet.shards[0].degraded_level == 0  # healthy shard untouched
        assert int(fleet._m_slow_inj.value) == 4    # ticks 3..6
    finally:
        fleet.close()


@pytest.mark.chaos
def test_storm_duplicates_journal_and_shard_alike(lv_world, tmp_path):
    """An ingest storm (x3 duplication) must hit the journal and the shard
    identically, or replay after a later crash would diverge from what the
    shard actually saw."""
    sys_, ys, us = lv_world
    fleet = ShardedTwinServer(_fleet_cfg(
        sys_, 2, 8,
        recovery=RecoveryConfig(ckpt_dir=str(tmp_path), ckpt_every=2),
        chaos=ChaosConfig(storm_shard=0, storm_factor=3,
                          storm_from_tick=2, storm_until_tick=4)))
    try:
        for t in range(5):
            for tid in (0, 1):                      # shard 0 and shard 1
                fleet.ingest(tid, ys[tid, t * 4:(t + 1) * 4])
            fleet.tick()
        fleet.drain()
        # shard 0's twin saw the duplicated samples; shard 1's did not
        assert fleet.journals[0].total(0) == fleet.shards[0].twins[0].samples
        assert fleet.journals[1].total(1) == fleet.shards[1].twins[1].samples
        assert fleet.journals[0].total(0) > fleet.journals[1].total(1)
    finally:
        fleet.close()
