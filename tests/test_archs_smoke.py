"""Per-architecture smoke tests (deliverable f).

Each assigned arch instantiates its REDUCED config and runs, on CPU:
  * one train-style loss+grad step  (shape + finiteness asserted)
  * prefill over a short prompt + 2 decode steps
  * decode-vs-forward consistency: the logits from step-by-step decode match
    a teacher-forced forward pass (the strongest cheap correctness check the
    cache machinery can get).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.models.zoo import build

# the two heaviest reduced configs (~20 s each on CPU) run outside the
# -m "not slow" CI lane; the remaining archs keep the zoo covered there
_HEAVY = {"arctic-480b", "gemma3-12b"}
ARCHS = [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY else a
         for a in list_archs()]
B, T = 2, 16


def _batch(api, key):
    cfg = api.cfg
    kt, ke = jax.random.split(key)
    batch = {"tokens": jax.random.randint(kt, (B, T), 0, cfg.vocab)}
    if api.is_encdec:
        batch["enc_x"] = jax.random.normal(ke, (B, T, cfg.d_model),
                                           jnp.float32) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    api = build(get_arch(arch).smoke)
    key = jax.random.PRNGKey(0)
    params = api.init(key)
    batch = _batch(api, jax.random.PRNGKey(1))

    (loss, metrics), grads = jax.value_and_grad(api.loss, has_aux=True)(
        params, batch)
    assert np.isfinite(float(loss)), (arch, float(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0.0, (arch, gnorm)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    api = build(get_arch(arch).smoke)
    cfg = api.cfg
    params = api.init(jax.random.PRNGKey(0))
    batch = _batch(api, jax.random.PRNGKey(1))
    tokens = batch["tokens"]

    # teacher-forced forward logits
    if api.is_encdec:
        from repro.models.encdec import whisper_decode_forward, whisper_encode
        enc_out = whisper_encode(cfg, params, batch["enc_x"])
        ref_logits = whisper_decode_forward(cfg, params, tokens, enc_out)
    else:
        from repro.models.transformer import forward
        ref_logits, _ = forward(cfg, params, tokens)

    # prefill on the first T-2 tokens, then decode 2 steps.
    t0 = T - 2
    pre = dict(batch)
    pre["tokens"] = tokens[:, :t0]
    if api.is_encdec:
        cache, logits = api.prefill(params, pre, T)
    else:
        cache, logits = api.prefill(params, pre, T)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(ref_logits[:, t0 - 1]),
                               rtol=2e-2, atol=2e-2)
    for t in range(t0, T):
        cache, logits = api.decode(params, cache, tokens[:, t])
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(ref_logits[:, t]),
                                   rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_match_init(arch):
    api = build(get_arch(arch).smoke)
    specs = api.param_specs()
    params = api.init(jax.random.PRNGKey(0))
    s_tree = jax.tree.map(lambda s: (tuple(s.shape), str(s.dtype)), specs)
    p_tree = jax.tree.map(lambda p: (tuple(p.shape), str(p.dtype)), params)
    assert s_tree == p_tree
