"""Fleet digital-twinning layer: batched concurrent model recovery."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fleet import FleetConfig, FleetMerinda
from repro.core.merinda import MerindaConfig
from repro.data.pipeline import make_windows
from repro.systems.lotka_volterra import LotkaVolterra
from repro.systems.simulate import simulate_batch

jax.config.update("jax_platform_name", "cpu")


def _fleet_batch(fleet=3, windows=8):
    sys_ = LotkaVolterra()
    tr = simulate_batch(sys_, jax.random.PRNGKey(0), batch=fleet, horizon=120)
    ys, us = [], []
    for f in range(fleet):
        y_win, u_win = make_windows(tr.ys[f], tr.us[f], window=30, stride=10)
        ys.append(y_win[:windows])
        us.append(u_win[:windows])
    return sys_, jnp.stack(ys), jnp.stack(us)


def test_fleet_init_and_step():
    sys_, y, u = _fleet_batch()
    cfg = FleetConfig(
        merinda=MerindaConfig(n=2, m=0, order=2, hidden=16, head_hidden=16,
                              n_active=4, dt=sys_.spec.dt),
        fleet=3)
    fm = FleetMerinda(cfg)
    state = fm.init(jax.random.PRNGKey(1))
    # per-twin params are independent (fleet axis on every leaf)
    assert state["params"]["gru"]["wx"].shape[0] == 3
    losses = []
    for _ in range(5):
        state, loss = fm.train_step(state, y, u)
        losses.append(float(loss))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]
    assert int(state["step"]) == 5


def test_fleet_recover_shapes():
    sys_, y, u = _fleet_batch()
    cfg = FleetConfig(
        merinda=MerindaConfig(n=2, m=0, order=2, hidden=16, head_hidden=16,
                              n_active=4, dt=sys_.spec.dt),
        fleet=3)
    fm = FleetMerinda(cfg)
    state = fm.init(jax.random.PRNGKey(2))
    theta = fm.recover_all(state, y, u)
    assert theta.shape == (3, 2, fm.model.lib.size)
    # every twin's theta respects the sparsity budget
    nz = np.asarray((jnp.abs(theta) > 0).sum(axis=(1, 2)))
    assert (nz <= cfg.merinda.n_active).all()


def test_fleet_twins_are_independent():
    """Different data -> different recovered params per twin."""
    sys_, y, u = _fleet_batch()
    cfg = FleetConfig(
        merinda=MerindaConfig(n=2, m=0, order=2, hidden=16, head_hidden=16,
                              n_active=4, dt=sys_.spec.dt),
        fleet=3)
    fm = FleetMerinda(cfg)
    state = fm.init(jax.random.PRNGKey(3))
    for _ in range(3):
        state, _ = fm.train_step(state, y, u)
    p = state["params"]["head"]["b2"]
    assert not np.allclose(np.asarray(p[0]), np.asarray(p[1]))
