"""EMILY and PINN+SR baseline API/behaviour tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.emily import Emily, EmilyConfig
from repro.core.pinn_sr import PinnSR, PinnSRConfig
from repro.core.trainer import fit
from repro.data.pipeline import WindowDataset
from repro.systems.lotka_volterra import LotkaVolterra
from repro.systems.simulate import simulate_batch

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def lv_data():
    sys_ = LotkaVolterra()
    tr = simulate_batch(sys_, jax.random.PRNGKey(0), batch=4, horizon=250)
    ds = WindowDataset.from_trace(tr.ys_noisy, tr.us, tr.dt, window=40,
                                  stride=12)
    return sys_, tr, ds


def test_emily_node_forward_is_integration(lv_data):
    """With a zero-init output layer the NODE forward returns constants."""
    sys_, tr, ds = lv_data
    em = Emily(EmilyConfig(n=2, m=0, dt=sys_.spec.dt))
    p = em.init(jax.random.PRNGKey(1))
    y = ds.y_win[:4]
    ys = em.node_forward(p, y[:, 0, :], ds.u_win[:4])
    np.testing.assert_allclose(
        np.asarray(ys), np.broadcast_to(np.asarray(y[:, :1]), y.shape))


def test_emily_loss_decreases(lv_data):
    sys_, tr, ds = lv_data
    em = Emily(EmilyConfig(n=2, m=0, hidden=32, dt=sys_.spec.dt))
    p = em.init(jax.random.PRNGKey(2))
    res = fit(em, p, ds.batches(jax.random.PRNGKey(3), 32, epochs=100),
              steps=120, lr=3e-3)
    assert res.history[-1] < res.history[0]


def test_emily_recover_shape(lv_data):
    sys_, tr, ds = lv_data
    em = Emily(EmilyConfig(n=2, m=0, dt=sys_.spec.dt))
    p = em.init(jax.random.PRNGKey(4))
    theta = em.recover(p, ds.y_win, ds.u_win)
    assert theta.shape == (2, em.lib.size)


def test_pinnsr_net_and_derivative(lv_data):
    sys_, tr, ds = lv_data
    pm = PinnSR(PinnSRConfig(n=2, m=0, dt=sys_.spec.dt, horizon=250))
    p = pm.init(jax.random.PRNGKey(5), tr.ys[0])
    y, ydot = pm.net_and_dot(p, jnp.asarray(0.3))
    assert y.shape == (2,) and ydot.shape == (2,)
    # finite-difference check of the jvp derivative
    eps = 1e-4
    fd = (pm.net(p, jnp.asarray(0.3 + eps)) - pm.net(p, jnp.asarray(0.3 - eps))) / (2 * eps)
    np.testing.assert_allclose(np.asarray(ydot), np.asarray(fd), atol=1e-2,
                               rtol=1e-2)


def test_pinnsr_threshold_freezes(lv_data):
    sys_, tr, ds = lv_data
    pm = PinnSR(PinnSRConfig(n=2, m=0, dt=sys_.spec.dt, horizon=250,
                             threshold=0.5))
    p = pm.init(jax.random.PRNGKey(6), tr.ys[0])
    p = {**p, "theta": p["theta"].at[0, 1].set(1.0).at[1, 2].set(0.1)}
    p2 = pm.apply_threshold(p)
    assert float(p2["theta"][0, 1]) == 1.0
    assert float(p2["theta"][1, 2]) == 0.0
    assert float(p2["mask"][1, 2]) == 0.0


def test_pinnsr_loss_decreases(lv_data):
    sys_, tr, ds = lv_data
    pm = PinnSR(PinnSRConfig(n=2, m=0, hidden=32, depth=2, dt=sys_.spec.dt,
                             horizon=250))
    p = pm.init(jax.random.PRNGKey(7), tr.ys[0])
    batch = (tr.ys_noisy[0], tr.us[0])

    def batches():
        while True:
            yield batch

    res = fit(pm, p, batches(), steps=100, lr=2e-3)
    assert res.history[-1] < res.history[0]
