"""Online twin server: scheduling order, admit/evict, guard, predict."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.merinda import MerindaConfig
from repro.systems.lotka_volterra import LotkaVolterra
from repro.systems.simulate import simulate_batch
from repro.twin.monitor import DivergenceGuard, GuardConfig
from repro.twin.scheduler import (PackedRefitScheduler, RefitScheduler,
                                  SchedulerConfig, TwinRecord)
from repro.twin.server import TwinServer, TwinServerConfig

jax.config.update("jax_platform_name", "cpu")


# --------------------------------------------------------------------- #
# scheduler policy — every test runs against BOTH planners (the reference
# dict-sorting oracle and the packed device-scored default), since they
# promise identical admission semantics
# --------------------------------------------------------------------- #
class _PackedPlanAdapter:
    """Give `PackedRefitScheduler` the reference's dict-based plan() shape."""

    def __init__(self, cfg):
        self._s = PackedRefitScheduler(cfg)

    def plan(self, twins, max_active=None):
        return self._s.plan_records(twins, max_active=max_active)


@pytest.fixture(params=["reference", "bucketed"])
def _sched(request):
    def build(**kw):
        d = dict(slots=2, min_samples=10, min_residency=2, max_residency=8,
                 evict_margin=0.5)
        d.update(kw)
        cfg = SchedulerConfig(**d)
        return (RefitScheduler(cfg) if request.param == "reference"
                else _PackedPlanAdapter(cfg))
    return build


def test_scheduler_fills_free_slots_by_priority(_sched):
    s = _sched()
    twins = {i: TwinRecord(twin_id=i, ring_slot=i, samples=10 + i)
             for i in range(4)}
    twins[1].divergence = 5.0            # highest priority
    plan = s.plan(twins)
    assert plan.admit[0] == (0, 1)       # diverged twin wins slot 0
    assert len(plan.admit) == 2 and not plan.evict


def test_scheduler_respects_readiness(_sched):
    s = _sched()
    twins = {0: TwinRecord(twin_id=0, ring_slot=0, samples=3)}   # < min
    assert s.plan(twins).admit == []


def test_scheduler_preempts_only_after_min_residency(_sched):
    s = _sched()
    resident = TwinRecord(twin_id=0, ring_slot=0, refit_slot=0, samples=50,
                          deployed=True, samples_at_deploy=50, residency=1)
    challenger = TwinRecord(twin_id=1, ring_slot=1, samples=50,
                            divergence=9.0, deployed=True)
    other = TwinRecord(twin_id=2, ring_slot=2, refit_slot=1, samples=50,
                       deployed=True, samples_at_deploy=50, residency=1)
    twins = {0: resident, 1: challenger, 2: other}
    assert s.plan(twins).evict == []             # too fresh to preempt
    resident.residency = other.residency = 5
    plan = s.plan(twins)
    assert plan.evict == [0]                     # weakest resident goes
    assert (0, 1) in plan.admit


def _resident(tid, slot, **kw):
    d = dict(twin_id=tid, ring_slot=tid, refit_slot=slot, samples=50,
             deployed=True, samples_at_deploy=50, residency=4)
    d.update(kw)
    return TwinRecord(**d)


def test_scheduler_releases_converged_resident(_sched):
    s = _sched()
    resident = _resident(0, 0, residency=9, divergence=0.01)
    other = _resident(2, 1)                    # keeps the pool full
    waiting = TwinRecord(twin_id=1, ring_slot=1, samples=50)
    plan = s.plan({0: resident, 1: waiting, 2: other})
    assert plan.release == [0]
    assert (0, 1) in plan.admit


def test_scheduler_releases_stuck_resident(_sched):
    """A non-converging resident cannot hold its slot forever."""
    s = _sched()
    resident = _resident(0, 0, residency=16, divergence=50.0)  # 2*max_res
    other = _resident(2, 1)
    waiting = TwinRecord(twin_id=1, ring_slot=1, samples=50)
    plan = s.plan({0: resident, 1: waiting, 2: other})
    assert plan.release == [0]


def test_scheduler_free_slots_absorb_waiting_without_release(_sched):
    """When idle slots can take every waiting twin, converged residents
    keep their slots (and their training state)."""
    s = _sched()
    resident = _resident(0, 0, residency=9, divergence=0.01)
    waiting = TwinRecord(twin_id=1, ring_slot=1, samples=50)
    plan = s.plan({0: resident, 1: waiting})   # slot 1 is free
    assert plan.release == [] and plan.evict == []
    assert plan.admit == [(1, 1)]


# --------------------------------------------------------------------- #
# server end-to-end (tiny model so CI stays fast)
# --------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def lv_world():
    sys_ = LotkaVolterra()
    tr = simulate_batch(sys_, jax.random.PRNGKey(0), batch=4, horizon=400,
                        noise_std=0.002)
    return sys_, np.asarray(tr.ys_noisy), np.asarray(tr.us)


def _server(sys_, **kw):
    d = dict(
        merinda=MerindaConfig(n=2, m=0, order=2, hidden=8, head_hidden=8,
                              n_active=4, dt=sys_.spec.dt),
        max_twins=6, refit_slots=2, capacity=128, window=16, stride=8,
        windows_per_twin=4, steps_per_tick=1, deploy_after=2,
        min_residency=2, max_residency=6,
        guard=GuardConfig(window=16))
    d.update(kw)
    return TwinServer(TwinServerConfig(**d))


def test_server_admits_and_refits(lv_world):
    sys_, ys, us = lv_world
    srv = _server(sys_)
    chunk = 10
    reports = []
    for t in range(12):
        for i in range(4):
            srv.ingest(i, ys[i, t * chunk:(t + 1) * chunk],
                       us[i, t * chunk:(t + 1) * chunk])
        reports.append(srv.tick())
    # both slots busy once twins are ready; min_samples = 8*3+16+1 = 41
    assert reports[-1].n_active == 2
    assert reports[-1].n_twins == 4
    admitted = [a for r in reports for a in r.admitted]
    assert len(admitted) >= 2
    # refit losses are finite once slots are active
    assert all(np.isfinite(r.loss) for r in reports if r.loss is not None)
    # every tick's latency was recorded
    assert len(srv.latencies) == 12
    # per-slot step counters advanced (incremental stepping)
    assert int(srv._fstate["steps"].max()) > 0


def test_server_slot_turnover_rotates_fleet(lv_world):
    """With 4 ready twins and 2 slots, releases/evictions must rotate the
    pool: every twin gets slot time eventually."""
    sys_, ys, us = lv_world
    srv = _server(sys_, max_residency=3, min_residency=1)
    chunk = 10
    slotted = set()
    for t in range(30):
        for i in range(4):
            lo = (t * chunk) % 300
            srv.ingest(i, ys[i, lo:lo + chunk], us[i, lo:lo + chunk])
        rep = srv.tick()
        slotted |= {tid for _, tid in rep.admitted}
    assert slotted == {0, 1, 2, 3}


def test_packed_mirrors_track_records_through_serving(lv_world):
    """The packed arrays are the scheduler's truth; every server mutation
    point must keep them consistent with the record metadata AND keep the
    float32 divergence shadow in lockstep with the float64 column — a
    stale mirror silently mis-ranks candidates, which the from_records
    equivalence tests can never see."""
    sys_, ys, us = lv_world
    srv = _server(sys_, max_residency=3, min_residency=1)
    chunk = 10
    for t in range(30):
        for i in range(4):
            lo = (t * chunk) % 300
            srv.ingest(i, ys[i, lo:lo + chunk], us[i, lo:lo + chunk])
        srv.tick()
        p = srv.packed
        p.check_mirrors()
        for rec in srv.twins.values():
            row = rec.ring_slot
            assert p.registered[row] and p.twin_id[row] == rec.twin_id
            assert p.samples[row] == rec.samples
            assert p.samples_at_deploy[row] == rec.samples_at_deploy
            assert p.deployed[row] == rec.deployed
            assert p.divergence[row] == rec.divergence
            assert p.resident[row] == (rec.refit_slot is not None)
            assert p.residency[row] == rec.residency


def test_guard_fires_on_perturbed_dynamics(lv_world):
    """Deploy the TRUE model, then the truth with flipped signs: the guard
    must stay quiet on the former and fire REFIT/ALERT on the latter."""
    sys_, ys, us = lv_world
    srv = _server(sys_, refit_slots=2, deploy_after=10 ** 6)  # no auto-deploy
    lib = srv.fleet.model.lib
    true = sys_.true_theta(lib)
    chunk = 10
    for t in range(6):    # enough samples for the guard window
        for i in range(2):
            srv.ingest(i, ys[i, t * chunk:(t + 1) * chunk],
                       us[i, t * chunk:(t + 1) * chunk])
        srv.tick()
    srv.deploy(0, true)
    srv.deploy(1, -true)           # wrong physics
    events = []
    for t in range(6, 10):
        for i in range(2):
            srv.ingest(i, ys[i, t * chunk:(t + 1) * chunk],
                       us[i, t * chunk:(t + 1) * chunk])
        events += srv.tick().events
    assert srv.twins[0].divergence < 0.05          # true model tracks
    assert srv.twins[1].divergence > 0.1           # wrong model diverges
    kinds = {(e.twin_id, e.kind) for e in events}
    assert any(tid == 1 for tid, _ in kinds)       # guard fired for twin 1
    assert all(tid != 0 for tid, _ in kinds)       # ...and only for twin 1


def test_flush_handles_backlog_beyond_capacity(lv_world):
    """Telemetry staged faster than ticks must not crash the fused flush;
    only the newest capacity-worth of samples survives."""
    sys_, ys, us = lv_world
    srv = _server(sys_, capacity=128)
    srv.ingest(0, ys[0, :100], us[0, :100])
    srv.ingest(0, ys[0, 100:200], us[0, 100:200])   # backlog: 200 > 128
    srv.tick()
    assert srv.twins[0].samples == 200              # telemetry accounting
    assert int(srv._rstate["count"][0]) == 128      # ring kept the newest
    yl, _ = srv.ring.latest(srv._rstate, jnp.asarray([0]), 10)
    np.testing.assert_allclose(np.asarray(yl[0]), ys[0, 189:200], rtol=1e-6)


def test_flush_capacity_not_multiple_of_pad(lv_world):
    """flush_pad rounding of the chunk axis must not lap a ring whose
    capacity is not a multiple of the pad quantum."""
    sys_, ys, us = lv_world
    srv = _server(sys_, capacity=100)           # 100 % 8 != 0
    srv.ingest(0, ys[0, :97], us[0, :97])       # rounds to 104 without cap
    srv.tick()
    assert int(srv._rstate["count"][0]) == 97
    yl, _ = srv.ring.latest(srv._rstate, jnp.asarray([0]), 5)
    np.testing.assert_allclose(np.asarray(yl[0]), ys[0, 91:97], rtol=1e-6)


def test_predict_shapes_and_rollout(lv_world):
    sys_, ys, us = lv_world
    srv = _server(sys_)
    lib = srv.fleet.model.lib
    srv.register(0)
    for t in range(5):
        srv.ingest(0, ys[0, t * 10:(t + 1) * 10], us[0, t * 10:(t + 1) * 10])
    srv.tick()
    with pytest.raises(RuntimeError):
        srv.predict(0, 10)                         # nothing deployed yet
    srv.register(5)
    srv.deploy(5, sys_.true_theta(lib))
    with pytest.raises(RuntimeError):
        srv.predict(5, 10)                         # deployed, no telemetry
    srv.deploy(0, sys_.true_theta(lib))
    out = srv.predict(0, 12)
    assert out.shape == (13, 2)
    assert bool(jnp.all(jnp.isfinite(out)))
    # rollout starts from the newest observed state
    np.testing.assert_allclose(np.asarray(out[0]), ys[0, 49], rtol=1e-5)


def test_latency_summary_tracks_deadline(lv_world):
    sys_, ys, us = lv_world
    srv = _server(sys_)
    for t in range(3):
        srv.ingest(0, ys[0, t * 10:(t + 1) * 10], us[0, t * 10:(t + 1) * 10])
        srv.tick()
    s = srv.latency_summary()
    assert s["ticks"] == 3 and s["p50_ms"] > 0 and s["deadline_s"] == 1.0
    srv.reset_latency_stats()
    assert srv.latency_summary() == {"ticks": 0}
