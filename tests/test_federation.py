"""Federation layer: wire codec, coordinator serving, kill + restart.

Three tiers, cheapest first:

  * wire codec unit tests — pure host-side roundtrips of the versioned
    JSON-header + array-blob format, the version gate, and the front-door
    trust boundary (no subprocesses);
  * one live `FederatedTwinServer` (2 workers) exercised through the
    `TwinService` surface: routed batched ingest, tick fan-out, predict
    across the pipe (including the worker-survives-refusal contract), the
    TCP front door, fleet snapshots;
  * the crash contract (`chaos` marker): SIGKILL a worker mid-serve and
    assert 0 lost samples after journal-tail replay, slot grants migrating
    to the survivor while the worker is down, and the grant shape restored
    after the supervised restart — the ISSUE 9 acceptance semantics.

Cross-implementation guard-event equality lives in
tests/test_service_conformance.py; this file owns the federation-only
behavior.
"""
import socket
import struct

import jax
import numpy as np
import pytest

from repro.core.merinda import MerindaConfig
from repro.systems.lotka_volterra import LotkaVolterra
from repro.systems.simulate import simulate_batch
from repro.twin import (FederatedTwinConfig, FederatedTwinServer,
                        FrontDoorClient, GuardConfig, RecoveryConfig,
                        TwinServerConfig, conforms)
from repro.twin import wire as W

N_TWINS = 8
WORKERS = 2
PER_TICK = 8


# --------------------------------------------------------------------------- #
# wire codec (no subprocesses)
# --------------------------------------------------------------------------- #
def _chunks(with_u: bool = True):
    rng = np.random.default_rng(0)
    return [(tid,
             rng.standard_normal((3, 2)).astype(np.float32),
             rng.standard_normal((3, 1)).astype(np.float32) if with_u
             else None)
            for tid in (4, 9, 4)]


@pytest.mark.parametrize("with_u", [True, False])
def test_ingest_batch_roundtrip(with_u):
    batch = _chunks(with_u)
    msg = W.decode(W.encode(W.IngestBatch.from_chunks(batch, force=True)))
    assert isinstance(msg, W.IngestBatch) and msg.force
    assert msg.n_samples == 9
    out = list(msg.chunks())
    assert [c[0] for c in out] == [c[0] for c in batch]
    for (_, y, u), (_, y0, u0) in zip(out, batch):
        np.testing.assert_array_equal(y, y0)
        if with_u:
            np.testing.assert_array_equal(u, u0)
        else:
            assert u is None


def test_tick_done_roundtrip():
    done = W.TickDone(tick=7, latency_s=0.25, deadline_met=True, n_active=3,
                      n_twins=5, n_guarded=2, degraded_level=1, pressure=0.5,
                      loss=0.125, ckpt_tick=4,
                      events=[[3, "ALERT", 2.5, 7]])
    out = W.decode(W.encode(done))
    assert out.tick == 7 and out.ckpt_tick == 4 and out.loss == 0.125
    assert out.events == [[3, "ALERT", 2.5, 7]]


def test_hello_sample_keys_stringify_over_json():
    """JSON stringifies int dict keys — the coordinator converts back when
    computing the replay suffix; the codec itself must not hide it."""
    out = W.decode(W.encode(W.Hello(shard=1, tick=3, ckpt_tick=2,
                                    samples={5: 10})))
    assert out.samples == {"5": 10}
    assert {int(k): int(v) for k, v in out.samples.items()} == {5: 10}


def test_decode_rejects_foreign_version():
    payload = bytearray(W.encode(W.Ack(n=1)))
    payload[:2] = struct.pack(">H", W.WIRE_VERSION + 1)
    with pytest.raises(W.WireError, match="version"):
        W.decode(bytes(payload))


def test_untrusted_decode_admits_only_ingest():
    """The front-door trust boundary: nothing that deserializes beyond
    JSON + raw arrays crosses it."""
    blob = W.encode(W.SnapshotBlob.pack({"theta": np.zeros(3)}))
    with pytest.raises(W.WireError):
        W.decode(blob, trusted=False)
    ok = W.decode(W.encode(W.IngestBatch.from_chunks(_chunks())),
                  trusted=False)
    assert isinstance(ok, W.IngestBatch)


def test_stream_framing_eof():
    a, b = socket.socketpair()
    try:
        payload = W.encode(W.DrainCmd())
        W.write_frame(a, payload)
        a.close()
        assert W.read_frame(b) == payload
        assert W.read_frame(b) is None     # clean EOF, not an exception
    finally:
        b.close()


# --------------------------------------------------------------------------- #
# live federation
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def lv_world():
    sys_ = LotkaVolterra()
    tr = simulate_batch(sys_, jax.random.PRNGKey(0), batch=N_TWINS,
                        horizon=300, noise_std=0.002)
    return sys_, np.asarray(tr.ys_noisy)


def _worker_cfg(sys_, **kw):
    kw.setdefault("refit_slots", 4)
    return TwinServerConfig(
        merinda=MerindaConfig(n=2, m=0, order=2, hidden=8, head_hidden=8,
                              n_active=4, dt=sys_.spec.dt),
        max_twins=N_TWINS // WORKERS + 1, capacity=128, window=16, stride=8,
        windows_per_twin=4, steps_per_tick=1, deploy_after=2,
        min_residency=1, max_residency=4, guard=GuardConfig(window=16), **kw)


def _feed(srv, ys, tick, per_tick=PER_TICK):
    lo = tick * per_tick
    return srv.ingest_many([(tid, ys[tid, lo:lo + per_tick])
                            for tid in range(N_TWINS)])


@pytest.fixture(scope="module")
def fed_srv(lv_world):
    sys_, _ = lv_world
    srv = FederatedTwinServer(FederatedTwinConfig.uniform(
        _worker_cfg(sys_), WORKERS, rebalance_every=2, front_door=True))
    yield srv
    srv.close()
    srv.close()                            # idempotent


def test_federated_serves_through_the_protocol(fed_srv, lv_world):
    sys_, ys = lv_world
    assert conforms(fed_srv) == []
    assert fed_srv.register(3) == 3 % WORKERS
    assert _feed(fed_srv, ys, 0) == N_TWINS * PER_TICK
    fed_srv.drain()
    for t in range(4):
        rep = fed_srv.tick()
    assert rep.n_twins == N_TWINS
    assert len(rep.grants) == WORKERS and sum(rep.grants) > 0
    assert rep.dead_shards == 0
    s = fed_srv.latency_summary()
    assert s["ticks"] >= 4 and s["dropped_samples"] == 0
    assert set(fed_srv.snapshot_state()) == {"shard0", "shard1"}


def test_predict_refusal_leaves_worker_alive(fed_srv, lv_world):
    _, ys = lv_world
    with pytest.raises(RuntimeError):
        fed_srv.predict(999, horizon=4)    # unknown twin: logical refusal
    _feed(fed_srv, ys, 5)
    rep = fed_srv.tick()                   # ...but the worker still serves
    assert rep.dead_shards == 0


def test_predict_roundtrip_after_deploy(fed_srv, lv_world):
    sys_, ys = lv_world
    theta = np.asarray(sys_.true_theta(_worker_cfg(sys_).merinda.library))
    fed_srv.deploy_many(list(range(N_TWINS)), theta)
    _feed(fed_srv, ys, 0)                  # predict rolls from newest samples
    fed_srv.drain()
    ys_hat = fed_srv.predict(1, horizon=5)
    assert np.asarray(ys_hat).shape[0] == 6    # horizon+1, row 0 = observed
    assert np.all(np.isfinite(ys_hat))


def test_front_door_feeds_the_fleet(fed_srv, lv_world):
    _, ys = lv_world
    client = FrontDoorClient(fed_srv.front_address)
    try:
        staged = client.ingest_many(
            [(tid, ys[tid, 48:56]) for tid in range(N_TWINS)])
        assert staged == N_TWINS * 8
        assert client.ingest(0, ys[0, 56:60]) == 4
    finally:
        client.close()
    fed_srv.drain()
    assert fed_srv.tick().n_twins == N_TWINS


def test_register_rejects_conflicting_pin(fed_srv):
    with pytest.raises(ValueError):
        fed_srv.register(3, shard=(3 % WORKERS) + 1)


@pytest.mark.chaos
def test_kill_restart_replays_journal_and_migrates_grants(lv_world,
                                                          tmp_path):
    """ISSUE 9 acceptance: SIGKILL a worker mid-serve -> the survivor
    inherits its slot grant under scarcity, the supervised restart replays
    the journal tail with 0 lost samples, and the grant shape recovers."""
    sys_, ys = lv_world
    victim, total_slots = 1, 4             # scarcity: half the pool sum
    srv = FederatedTwinServer(FederatedTwinConfig.uniform(
        _worker_cfg(sys_, refit_slots=4), WORKERS,
        rebalance_every=1, total_slots=total_slots,
        recovery=RecoveryConfig(ckpt_dir=str(tmp_path), ckpt_every=2,
                                restart_delay_ticks=2)))
    try:
        for tid in range(N_TWINS):
            srv.register(tid)
        for t in range(4):                 # build state + checkpoints
            _feed(srv, ys, t)
            srv.drain()
            pre = srv.tick()
        assert pre.grants[victim] > 0

        srv.kill_worker(victim)
        _feed(srv, ys, 4)                  # journal-only for the dead half
        down = srv.tick()
        assert down.dead_shards == 1
        assert down.grants[victim] == 0
        assert sum(down.grants) == total_slots          # migrated, not lost
        assert down.grants[1 - victim] > pre.grants[1 - victim]

        _feed(srv, ys, 5)
        back = srv.tick()                  # restart_delay_ticks=2 elapsed
        assert len(back.restarted) == 1
        rec = back.restarted[0]
        assert rec["shard"] == victim
        assert rec["lost"] == 0
        assert rec["replayed"] > 0
        assert back.dead_shards == 0
        assert back.grants[victim] > 0     # share flowed back

        _feed(srv, ys, 6)                  # the fleet keeps serving
        assert srv.tick().n_twins == N_TWINS
    finally:
        srv.close()
