"""Planner equivalence: PackedRefitScheduler == RefitScheduler, exactly.

The packed planner (one fused device scoring call + PriorityBuckets pops)
promises BYTE-IDENTICAL admit/evict/release decisions to the dict-sorting
reference planner — not "usually the same", identical.  That promise is what
lets the serving default change without re-litigating six tests' worth of
admission semantics, so it gets three layers of enforcement here:

  * a seeded random sweep that always runs (no optional deps) — hundreds of
    random fleets through both planners, plans compared field by field;
  * plan invariants the server's `_apply_plan` relies on (unique slot
    assignments, released slots re-fillable within the same plan);
  * a hypothesis property test (skipped when hypothesis is not installed)
    that searches the same space adversarially.

Fleet generation keeps every priority EXACTLY representable in both float32
(device ranking) and float64 (host comparisons): min_samples a power of two,
weights in {0.5, 1, 2, 4}, divergence a multiple of 1/8, integer samples.
Cross-precision ranking swaps are then impossible, so any plan mismatch is a
real semantics bug, not a rounding coin-flip (see twin/packed.py's precision
contract for why near-ties are the one tolerated divergence in production).
"""
import random

import pytest

from repro.twin.scheduler import (PackedRefitScheduler, PriorityBuckets,
                                  RefitScheduler, SchedulerConfig, TwinRecord)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # container image ships without hypothesis
    HAVE_HYPOTHESIS = False

MIN_SAMPLES = (1, 2, 4, 8, 16)
WEIGHTS = (0.5, 1.0, 2.0, 4.0)


def _random_case(rng):
    """One random (cfg, twins, max_active) planning problem."""
    slots = rng.randint(1, 6)
    cfg = SchedulerConfig(
        slots=slots,
        min_samples=rng.choice(MIN_SAMPLES),
        staleness_weight=rng.choice(WEIGHTS),
        divergence_weight=rng.choice(WEIGHTS),
        evict_margin=rng.choice([0.0, 0.5, 1.0]),
        min_residency=rng.choice([0, 1, 2, 4]),
        max_residency=rng.choice([2, 4, 8]),
        release_divergence=rng.choice([0.05, 0.25, 1.0]))
    n = rng.randint(0, 40)
    free_slots = list(range(slots))
    rng.shuffle(free_slots)
    twins = {}
    for tid in range(n):
        resident = bool(free_slots) and rng.random() < 0.3
        rec = TwinRecord(
            twin_id=tid, ring_slot=tid,
            refit_slot=free_slots.pop() if resident else None,
            samples=rng.randint(0, 48),
            deployed=rng.random() < 0.5,
            residency=rng.randint(0, 20) if resident else 0,
            divergence=rng.randint(0, 24) / 8)
        rec.samples_at_deploy = rng.randint(0, rec.samples)
        twins[tid] = rec
    max_active = rng.choice([None, rng.randint(0, slots)])
    return cfg, twins, max_active


def _both_plans(cfg, twins, max_active):
    ref = RefitScheduler(cfg).plan(twins, max_active=max_active)
    got = PackedRefitScheduler(cfg).plan_records(twins,
                                                 max_active=max_active)
    return ref, got


def test_random_fleets_plan_identically():
    rng = random.Random(1234)
    for _ in range(400):
        cfg, twins, max_active = _random_case(rng)
        ref, got = _both_plans(cfg, twins, max_active)
        assert got.admit == ref.admit
        assert got.evict == ref.evict
        assert got.release == ref.release


def test_plans_obey_slot_invariants():
    """What `TwinServer._apply_plan` assumes: admitted slots are distinct,
    every admitted twin appears once, no admitted twin is simultaneously
    evicted/released, and evicted/released twins were residents."""
    rng = random.Random(99)
    for _ in range(200):
        cfg, twins, max_active = _random_case(rng)
        plan = PackedRefitScheduler(cfg).plan_records(twins,
                                                      max_active=max_active)
        slots_assigned = [s for s, _ in plan.admit]
        tids_admitted = [t for _, t in plan.admit]
        assert len(set(slots_assigned)) == len(slots_assigned)
        assert len(set(tids_admitted)) == len(tids_admitted)
        outgoing = set(plan.evict) | set(plan.release)
        assert not outgoing & set(tids_admitted)
        for tid in outgoing:
            assert twins[tid].refit_slot is not None
        for _, tid in plan.admit:
            assert twins[tid].refit_slot is None
        # applying the plan never double-books a slot
        occupied = {r.refit_slot for r in twins.values()
                    if r.refit_slot is not None and r.twin_id not in outgoing}
        for slot, _ in plan.admit:
            assert slot not in occupied
            occupied.add(slot)


def test_released_slot_is_readmittable_same_tick():
    """A converged resident's slot can be handed to a waiting twin within
    the SAME plan — release and admit are one turnover, not two ticks."""
    cfg = SchedulerConfig(slots=2, min_samples=10, min_residency=2,
                          max_residency=8)
    resident = TwinRecord(twin_id=0, ring_slot=0, refit_slot=0, samples=50,
                          deployed=True, samples_at_deploy=50, residency=9,
                          divergence=0.01)
    other = TwinRecord(twin_id=2, ring_slot=2, refit_slot=1, samples=50,
                       deployed=True, samples_at_deploy=50, residency=4)
    waiting = TwinRecord(twin_id=1, ring_slot=1, samples=50)
    twins = {0: resident, 1: waiting, 2: other}
    plan = PackedRefitScheduler(cfg).plan_records(twins)
    assert plan.release == [0]
    assert plan.admit == [(0, 1)]      # the freed slot, refilled this tick


def test_priority_buckets_orders_exactly():
    """Pops come out in exact (-priority, key) order across buckets, with
    lazy deletion and reprioritization honored."""
    rng = random.Random(7)
    q = PriorityBuckets(quantum=0.25)
    live = {}
    for key in range(200):
        prio = rng.randint(0, 64) / 8
        q.push(key, prio)
        live[key] = prio
    for key in rng.sample(list(live), 60):       # lazy deletions
        q.discard(key)
        del live[key]
    for key in rng.sample(list(live), 40):       # reprioritizations
        live[key] = rng.randint(0, 64) / 8
        q.push(key, live[key])
    assert len(q) == len(live)
    expect = sorted(live.items(), key=lambda kv: (-kv[1], kv[0]))
    got = []
    while len(q):
        key, prio, _ = q.pop()
        got.append((key, prio))
    assert got == expect
    assert q.pop() is None and q.peek() is None


if HAVE_HYPOTHESIS:
    @st.composite
    def _cases(draw):
        seed = draw(st.integers(min_value=0, max_value=2**32 - 1))
        return _random_case(random.Random(seed))

    @pytest.mark.hypothesis
    @settings(deadline=None, max_examples=60)
    @given(_cases())
    def test_property_plans_identical(case):
        cfg, twins, max_active = case
        ref, got = _both_plans(cfg, twins, max_active)
        assert (got.admit, got.evict, got.release) == \
            (ref.admit, ref.evict, ref.release)
else:
    @pytest.mark.hypothesis
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_plans_identical():
        pass
