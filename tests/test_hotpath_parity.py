"""Pallas serving hot-path parity lane (CI-gated, CPU interpret mode).

The serving stack promises that ``use_pallas=True`` is a pure backend swap:
every fused call in the online loop — the fleet refit train step (GRU scan +
RK4 rollout under ``jax.vmap(jax.value_and_grad)``), the divergence guard's
rollouts, and ``TwinServer.predict`` — produces the same numbers as the jnp
reference path within float32 kernel tolerance.  These tests pin that
contract on CPU by running the Pallas kernels in interpreter mode
(``interpret=True`` — semantics identical to the compiled kernels, no TPU
required), from single-kernel vmap+grad parity up to a full 64-twin
`TwinServer` serving run compared tick by tick against the reference server.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fleet import FleetConfig, FleetMerinda
from repro.core.library import make_library
from repro.core.merinda import MerindaConfig
from repro.kernels.backend import bucket_pow2, resolve_interpret
from repro.kernels.gru.ops import gru_scan
from repro.kernels.gru.ref import gru_scan_ref, init_gru_params
from repro.kernels.rk4.ops import rk4_poly_solve
from repro.kernels.rk4.ref import rk4_poly_solve_ref

# interpret=True runs on any backend, so the lane needs no platform pin
# (a module-level jax.config.update would leak onto every later test module)
PALLAS = dict(use_pallas=True, interpret=True)


# --------------------------------------------------------------------------- #
# backend policy helpers
# --------------------------------------------------------------------------- #
@pytest.mark.skipif(jax.default_backend() == "tpu",
                    reason="auto resolves to compiled on TPU")
def test_resolve_interpret_auto_and_override():
    # off-TPU, auto (None) must choose interpreter mode
    assert resolve_interpret(None) is True
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False


def test_bucket_pow2_bounds_shapes():
    assert [bucket_pow2(b, 8) for b in (1, 8, 9, 16, 17, 24, 33, 64)] \
        == [8, 8, 16, 16, 32, 32, 64, 64]
    # distinct padded widths over 1..512 are log-bounded, not linear
    widths = {bucket_pow2(b, 8) for b in range(1, 513)}
    assert len(widths) == 7


# --------------------------------------------------------------------------- #
# kernel-level parity: fleet-shaped (vmapped, per-twin weights) + gradients
# --------------------------------------------------------------------------- #
def _fleet_gru_inputs(seed, F, B, T, D, H):
    keys = jax.random.split(jax.random.PRNGKey(seed), F + 1)
    params = jax.vmap(lambda k: init_gru_params(k, D, H))(keys[:F])
    xs = jax.random.normal(keys[F], (F, B, T, D))
    h0 = jnp.zeros((F, B, H))
    return params, xs, h0


def test_gru_fleet_vmap_grad_parity():
    """Per-twin weights under vmap(grad): the exact refit-path invocation."""
    p, xs, h0 = _fleet_gru_inputs(0, 3, 8, 12, 5, 16)

    def loss(kw):
        def one(wx, wh, b, x, h):
            hs, hT = gru_scan(x, h, wx, wh, b, **kw)
            return jnp.sum(hT ** 2) + jnp.mean(hs ** 2)
        return jax.vmap(one)(p["wx"], p["wh"], p["b"], xs, h0)

    def grads(kw):
        def one(wx, wh, b, x, h):
            def inner(wx):
                hs, hT = gru_scan(x, h, wx, wh, b, **kw)
                return jnp.sum(hT ** 2) + jnp.mean(hs ** 2)
            return jax.grad(inner)(wx)
        return jax.vmap(one)(p["wx"], p["wh"], p["b"], xs, h0)

    np.testing.assert_allclose(np.asarray(loss(PALLAS)), np.asarray(loss({})),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(grads(PALLAS)),
                               np.asarray(grads({})), rtol=1e-4, atol=1e-5)


def test_gru_batched_entry_folds_leading_axes():
    """Shared-weight 4-d xs folds into the batch axis inside the wrapper."""
    key = jax.random.PRNGKey(1)
    p = init_gru_params(key, 4, 8)
    xs = jax.random.normal(key, (3, 5, 7, 4))
    h0 = jnp.zeros((3, 5, 8))
    hs_p, hT_p = gru_scan(xs, h0, p["wx"], p["wh"], p["b"], **PALLAS)
    hs_r, hT_r = gru_scan_ref(xs.reshape(15, 7, 4), h0.reshape(15, 8),
                              p["wx"], p["wh"], p["b"])
    assert hs_p.shape == (3, 5, 7, 8) and hT_p.shape == (3, 5, 8)
    np.testing.assert_allclose(np.asarray(hs_p),
                               np.asarray(hs_r.reshape(3, 5, 7, 8)), atol=1e-5)
    np.testing.assert_allclose(np.asarray(hT_p),
                               np.asarray(hT_r.reshape(3, 5, 8)), atol=1e-5)


def test_gru_shape_guard_raises():
    key = jax.random.PRNGKey(2)
    p = init_gru_params(key, 4, 8)
    xs = jax.random.normal(key, (2, 7, 4))
    with pytest.raises(ValueError, match="inconsistent"):
        gru_scan(xs, jnp.zeros((2, 9)), p["wx"], p["wh"], p["b"])


def _rk4_inputs(seed, B, n, m, order, T, fleet=None):
    lib = make_library(n, m, order)
    shape = (B,) if fleet is None else (fleet, B)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    theta = 0.1 * jax.random.normal(k1, shape + (n, lib.size))
    y0 = 0.3 * jax.random.normal(k2, shape + (n,))
    us = 0.2 * jax.random.normal(k3, shape + (T, m))
    return lib, theta, y0, us


def test_rk4_fleet_vmap_grad_parity():
    """RK4 under vmap(grad) — the decode leg of the refit train step."""
    lib, theta, y0, us = _rk4_inputs(3, 6, 2, 1, 2, 10, fleet=3)

    def grads(kw):
        def one(th, y, u):
            def inner(th):
                ys = rk4_poly_solve(th, y, u, dt=0.02, library=lib, **kw)
                return jnp.mean(ys ** 2)
            return jax.grad(inner)(th)
        return jax.vmap(one)(theta, y0, us)

    np.testing.assert_allclose(np.asarray(grads(PALLAS)),
                               np.asarray(grads({})), rtol=1e-4, atol=1e-6)


def test_rk4_batched_entry_folds_leading_axes():
    lib, theta, y0, us = _rk4_inputs(4, 5, 3, 1, 2, 8, fleet=2)
    ys_p = rk4_poly_solve(theta, y0, us, dt=0.02, library=lib, **PALLAS)
    ys_r = rk4_poly_solve_ref(theta.reshape(10, 3, lib.size),
                              y0.reshape(10, 3), us.reshape(10, 8, 1),
                              0.02, lib.term_indices)
    assert ys_p.shape == (2, 5, 9, 3)
    np.testing.assert_allclose(np.asarray(ys_p),
                               np.asarray(ys_r.reshape(2, 5, 9, 3)), atol=1e-5)


def test_rk4_autonomous_grad_parity():
    """m == 0 exercises the dummy-input-channel leg with gradients."""
    lib, theta, y0, us = _rk4_inputs(5, 4, 2, 0, 2, 6)

    def g(kw):
        def inner(th):
            return jnp.mean(rk4_poly_solve(th, y0, us, dt=0.02, library=lib,
                                           **kw) ** 2)
        return jax.grad(inner)(theta)

    np.testing.assert_allclose(np.asarray(g(PALLAS)), np.asarray(g({})),
                               rtol=1e-4, atol=1e-6)


def test_rk4_shape_guard_raises():
    lib, theta, y0, us = _rk4_inputs(6, 4, 2, 1, 2, 6)
    with pytest.raises(ValueError, match="library"):
        rk4_poly_solve(theta[:, :, :-1], y0, us, dt=0.02, library=lib)


# --------------------------------------------------------------------------- #
# fleet refit parity: the fused train step is a pure backend swap
# --------------------------------------------------------------------------- #
def _fleet(use_pallas):
    m = MerindaConfig(n=2, m=1, order=2, hidden=16, head_hidden=16,
                      n_active=6, use_pallas=use_pallas,
                      interpret=True if use_pallas else None)
    return FleetMerinda(FleetConfig(merinda=m, fleet=4, windows_per_twin=8,
                                    sparsify_after=3))


def test_fleet_train_step_parity():
    key = jax.random.PRNGKey(0)
    y = 0.3 * jax.random.normal(key, (4, 8, 13, 2))
    u = 0.2 * jax.random.normal(key, (4, 8, 12, 1))
    ref, pal = _fleet(False), _fleet(True)
    s_r, s_p = ref.init(jax.random.PRNGKey(1)), pal.init(jax.random.PRNGKey(1))
    for _ in range(6):     # crosses the sparsify_after=3 warmup boundary
        s_r, loss_r, ok_r = ref.train_step_per_slot(s_r, y, u)
        s_p, loss_p, ok_p = pal.train_step_per_slot(s_p, y, u)
        np.testing.assert_allclose(np.asarray(loss_r), np.asarray(loss_p),
                                   rtol=1e-4, atol=1e-5)
        assert bool(jnp.all(ok_r == ok_p))
    for a, b in zip(jax.tree.leaves(s_r["params"]),
                    jax.tree.leaves(s_p["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)
    th_r = ref.recover_all(s_r, y, u)
    th_p = pal.recover_all(s_p, y, u)
    np.testing.assert_allclose(np.asarray(th_r), np.asarray(th_p),
                               rtol=1e-3, atol=1e-4)


# --------------------------------------------------------------------------- #
# end-to-end: the 64-twin online serving loop, reference vs Pallas backend
# --------------------------------------------------------------------------- #
def _server_cfg(use_pallas, n, m, dt):
    from repro.twin.monitor import GuardConfig
    from repro.twin.server import TwinServerConfig
    return TwinServerConfig(
        merinda=MerindaConfig(n=n, m=m, order=2, dt=dt, hidden=16,
                              head_hidden=16, n_active=12,
                              use_pallas=use_pallas,
                              interpret=True if use_pallas else None),
        max_twins=64, refit_slots=8, capacity=128, window=16, stride=8,
        windows_per_twin=4, steps_per_tick=2, deploy_after=4,
        min_residency=2, max_residency=8, guard=GuardConfig(window=16),
        seed=7)


def test_server_64twin_parity():
    """Acceptance gate: `use_pallas=True` runs the 64-twin online loop end to
    end (interpret mode on CPU) and every per-tick output — refit loss,
    deployed theta store, per-twin divergence scores, prediction rollouts —
    matches the reference backend within float32 kernel tolerance."""
    from repro.systems.f8_crusader import F8Crusader
    from repro.systems.simulate import simulate_batch
    from repro.twin.server import TwinServer

    system = F8Crusader()
    n_twins, chunk, ticks = 64, 8, 10
    trace = simulate_batch(system, jax.random.PRNGKey(3), batch=n_twins,
                           horizon=chunk * ticks + 1, noise_std=0.002)
    ys, us = np.asarray(trace.ys_noisy), np.asarray(trace.us)

    servers = [TwinServer(_server_cfg(up, system.spec.n, system.spec.m,
                                      system.spec.dt)) for up in (False, True)]
    reports = [[], []]
    for t in range(ticks):
        lo = t * chunk
        for j, srv in enumerate(servers):
            for i in range(n_twins):
                srv.ingest(i, ys[i, lo:lo + chunk], us[i, lo:lo + chunk])
            reports[j].append(srv.tick())

    for rep_r, rep_p in zip(*reports):
        assert rep_r.n_active == rep_p.n_active
        assert rep_r.admitted == rep_p.admitted
        if rep_r.loss is None:
            assert rep_p.loss is None
        else:
            np.testing.assert_allclose(rep_r.loss, rep_p.loss,
                                       rtol=1e-3, atol=1e-4)
    ref, pal = servers
    deployed_r = {t for t, r in ref.twins.items() if r.deployed}
    deployed_p = {t for t, r in pal.twins.items() if r.deployed}
    assert deployed_r == deployed_p and deployed_r
    np.testing.assert_allclose(np.asarray(ref._theta), np.asarray(pal._theta),
                               rtol=1e-3, atol=1e-4)
    div_r = [ref.twins[t].divergence for t in sorted(ref.twins)]
    div_p = [pal.twins[t].divergence for t in sorted(pal.twins)]
    np.testing.assert_allclose(div_r, div_p, rtol=1e-3, atol=1e-5)
    tid = sorted(deployed_r)[0]
    np.testing.assert_allclose(np.asarray(ref.predict(tid, 12)),
                               np.asarray(pal.predict(tid, 12)),
                               rtol=1e-3, atol=1e-4)
