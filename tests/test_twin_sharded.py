"""Sharded serving: federation rebalance, async ingest, guard rotation."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.merinda import MerindaConfig
from repro.systems.lotka_volterra import LotkaVolterra
from repro.systems.simulate import simulate_batch
from repro.twin.monitor import GuardConfig, GuardRotation
from repro.twin.scheduler import (FederationConfig, RefitScheduler,
                                  SchedulerConfig, SlotFederation, TwinRecord)
from repro.twin.server import TwinServer, TwinServerConfig
from repro.twin.sharded import ShardedTwinConfig, ShardedTwinServer
from repro.twin.stream import StagingBuffer, prepare_flush

jax.config.update("jax_platform_name", "cpu")


# --------------------------------------------------------------------- #
# guard rotation (pure host logic)
# --------------------------------------------------------------------- #
def test_rotation_covers_every_twin_within_bound():
    """Round-robin freshness floor: every eligible twin is scored within
    ceil(twins / budget) ticks, regardless of the divergence pattern."""
    n, budget = 23, 5
    rot = GuardRotation(budget=budget, carry=2)
    rows = np.arange(n)
    div = np.zeros(n)
    div[[4, 17]] = 3.0                                  # permanently flagged
    bound = -(-n // budget)                              # ceil(23/5) = 5
    last_scored = {row: 0 for row in range(n)}
    for tick in range(1, 4 * bound + 1):
        for row in rot.select(rows, div, threshold=0.1):
            last_scored[int(row)] = tick
        gaps = [tick - t for t in last_scored.values()]
        assert max(gaps) <= bound, f"tick {tick}: twin starved {max(gaps)}"


def test_rotation_carry_rescores_flagged_every_tick():
    rot = GuardRotation(budget=2, carry=2)
    rows = np.arange(10)
    div = np.zeros(10)
    div[7] = 5.0                                        # flagged
    hits = sum(7 in rot.select(rows, div, threshold=0.1) for _ in range(5))
    assert hits == 5                                    # carry-over every tick


def test_rotation_fixed_fused_width():
    rot = GuardRotation(budget=3, carry=1)
    assert rot.size == 4
    pick = rot.select(np.arange(3), np.asarray([0.0, 9.0, 9.0]),
                      threshold=0.1)
    assert len(pick) <= 4 and len(set(pick.tolist())) == len(pick)


# --------------------------------------------------------------------- #
# staging buffer + flush preparation (thread-safety, overflow assert)
# --------------------------------------------------------------------- #
def test_staging_swap_is_atomic_handoff():
    buf = StagingBuffer()
    buf.append(0, np.ones((4, 2), np.float32), np.zeros((4, 1), np.float32))
    taken = buf.swap()
    assert list(taken) == [0] and buf.empty()
    assert buf.staged_samples == 4 and buf.swapped_samples == 4
    assert buf.swap() == {}


def test_staging_concurrent_appends_lose_nothing():
    buf = StagingBuffer()
    per_thread, n_threads = 200, 8

    def pump(row):
        for _ in range(per_thread):
            buf.append(row, np.ones((1, 2), np.float32),
                       np.zeros((1, 1), np.float32))

    threads = [threading.Thread(target=pump, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    taken = buf.swap()
    total = sum(len(c[0]) for chunks in taken.values() for c in chunks)
    assert total == per_thread * n_threads


def test_prepare_flush_overflow_raises_not_wraps():
    """A chunk the padded buffer cannot hold must raise, not silently lap."""
    staged = {0: [(np.ones((12, 2), np.float32),
                   np.zeros((12, 1), np.float32))]}
    with pytest.raises(RuntimeError, match="lap"):
        prepare_flush(staged, capacity=8, pad=4, scratch=3, n=2, m=1)


def test_prepare_flush_accounts_raw_received():
    staged = {1: [(np.ones((30, 2), np.float32),
                   np.zeros((30, 1), np.float32)),
                  (2 * np.ones((10, 2), np.float32),
                   np.zeros((10, 1), np.float32))]}
    batch = prepare_flush(staged, capacity=32, pad=8, scratch=5, n=2, m=1)
    assert batch.received == {1: 40}            # raw, pre-truncation
    assert int(batch.counts[0]) == 32           # newest capacity-worth kept
    np.testing.assert_allclose(batch.ys[0, -10:], 2.0)


# --------------------------------------------------------------------- #
# scheduler: federation grant cap
# --------------------------------------------------------------------- #
def _sched(**kw):
    d = dict(slots=4, min_samples=10, min_residency=2, max_residency=8,
             evict_margin=0.5)
    d.update(kw)
    return RefitScheduler(SchedulerConfig(**d))


def _resident(tid, slot, **kw):
    d = dict(twin_id=tid, ring_slot=tid, refit_slot=slot, samples=50,
             deployed=True, samples_at_deploy=50, residency=4)
    d.update(kw)
    return TwinRecord(**d)


def test_plan_respects_grant_cap_on_admission():
    s = _sched()
    twins = {i: TwinRecord(twin_id=i, ring_slot=i, samples=20)
             for i in range(6)}
    plan = s.plan(twins, max_active=2)
    assert len(plan.admit) == 2                 # 4 physical, grant only 2


def test_plan_sheds_lowest_priority_when_grant_shrinks():
    s = _sched()
    twins = {i: _resident(i, i) for i in range(4)}
    twins[2].divergence = 9.0                   # highest priority: keep
    plan = s.plan(twins, max_active=1)
    assert len(plan.release) == 3 and 2 not in plan.release


def test_federation_moves_slots_toward_pressure():
    fed = SlotFederation(FederationConfig(total_slots=6, min_slots=1,
                                          smooth=1.0), [4, 4])
    assert fed.rebalance([1.0, 1.0]) == [3, 3]          # symmetric demand
    grants = fed.rebalance([0.1, 10.0])
    assert grants[1] > grants[0] and sum(grants) == 6
    assert grants == [2, 4]                             # clamped at physical


def test_federation_floor_keeps_idle_shard_alive():
    fed = SlotFederation(FederationConfig(total_slots=4, min_slots=1,
                                          smooth=1.0), [4, 4])
    assert fed.rebalance([0.0, 50.0]) == [1, 3]


# --------------------------------------------------------------------- #
# sharded server end-to-end (tiny model so CI stays fast)
# --------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def lv_world():
    sys_ = LotkaVolterra()
    tr = simulate_batch(sys_, jax.random.PRNGKey(0), batch=8, horizon=400,
                        noise_std=0.002)
    return sys_, np.asarray(tr.ys_noisy), np.asarray(tr.us)


def _server_cfg(sys_, **kw):
    d = dict(
        merinda=MerindaConfig(n=2, m=0, order=2, hidden=8, head_hidden=8,
                              n_active=4, dt=sys_.spec.dt),
        max_twins=6, refit_slots=2, capacity=128, window=16, stride=8,
        windows_per_twin=4, steps_per_tick=1, deploy_after=2,
        min_residency=1, max_residency=4,
        guard=GuardConfig(window=16))
    d.update(kw)
    return TwinServerConfig(**d)


def test_sharded_routes_and_serves(lv_world):
    sys_, ys, us = lv_world
    srv = ShardedTwinServer(
        ShardedTwinConfig.uniform(_server_cfg(sys_), 2, total_slots=3))
    try:
        for t in range(8):
            for i in range(6):
                srv.ingest(i, ys[i, t * 10:(t + 1) * 10],
                           us[i, t * 10:(t + 1) * 10])
            rep = srv.tick()
        assert rep.n_twins == 6
        assert rep.n_active <= 3                 # global grant respected
        assert sum(srv.grants) == 3
        # placement is modulo and sticky
        assert srv.shard_of(4) == 0 and srv.shard_of(5) == 1
        assert sorted(srv.shards[0].twins) == [0, 2, 4]
        assert len(srv.latencies) == 8
    finally:
        srv.close()


def test_sharded_grants_follow_divergence_pressure(lv_world):
    """Slots migrate toward the shard whose twins diverged: deploy WRONG
    physics on shard 1's twins, right physics on shard 0's."""
    sys_, ys, us = lv_world
    srv = ShardedTwinServer(ShardedTwinConfig.uniform(
        _server_cfg(sys_, deploy_after=10 ** 6), 2,
        total_slots=3, rebalance_every=2, pressure_smooth=1.0))
    try:
        lib = srv.shards[0].fleet.model.lib
        true = sys_.true_theta(lib)
        srv.deploy_many([0, 2, 4], true)         # shard 0: healthy models
        srv.deploy_many([1, 3, 5], -true)        # shard 1: wrong physics
        for t in range(8):
            for i in range(6):
                srv.ingest(i, ys[i, t * 10:(t + 1) * 10],
                           us[i, t * 10:(t + 1) * 10])
            srv.tick()
        assert srv.grants[1] > srv.grants[0]     # slots followed the pressure
        assert any(e.twin_id % 2 == 1 for e in
                   [e for s in srv.shards for e in s.events])
    finally:
        srv.close()


def test_async_ingest_no_drops_no_duplicates(lv_world):
    """Concurrent ingest threads + serving ticks: after drain, per-twin
    sample accounting and ring write heads both match exactly what was sent
    (no drops, no duplicates)."""
    sys_, ys, us = lv_world
    srv = TwinServer(_server_cfg(sys_, max_twins=4, capacity=128,
                                 async_ingest=True))
    try:
        n_tw, chunks, chunk = 4, 24, 5
        sent = {i: 0 for i in range(n_tw)}

        def pump(i):
            for c in range(chunks):
                lo = (c * chunk) % 300
                srv.ingest(i, ys[i, lo:lo + chunk], us[i, lo:lo + chunk])
                sent[i] += chunk

        threads = [threading.Thread(target=pump, args=(i,))
                   for i in range(n_tw)]
        for t in threads:
            t.start()
        for _ in range(6):
            srv.tick()
        for t in threads:
            t.join()
        srv.drain()
        for i in range(n_tw):
            rec = srv.twins[i]
            assert rec.samples == sent[i] == chunks * chunk
            # ring write head counts every sample exactly once
            assert int(srv._rstate["count"][rec.ring_slot]) == sent[i]
    finally:
        srv.close()


def test_async_ingest_preserves_chronology(lv_world):
    """Samples must land in the ring in ingest order even when flushes are
    prepared on the background thread across several ticks."""
    sys_, ys, us = lv_world
    srv = TwinServer(_server_cfg(sys_, max_twins=2, async_ingest=True))
    try:
        for c in range(10):
            srv.ingest(0, ys[0, c * 10:(c + 1) * 10],
                       us[0, c * 10:(c + 1) * 10])
            if c % 3 == 0:
                srv.tick()
        srv.drain()
        yl, _ = srv.ring.latest(srv._rstate, jnp.asarray([0]), 20)
        np.testing.assert_allclose(np.asarray(yl[0]), ys[0, 79:100],
                                   rtol=1e-6)
    finally:
        srv.close()


def test_guard_rotation_budget_bounds_fused_width(lv_world):
    """With guard_budget set, every tick scores at most budget+carry twins,
    and all deployed twins are still scored within the rotation bound."""
    sys_, ys, us = lv_world
    budget = 2
    srv = TwinServer(_server_cfg(sys_, deploy_after=10 ** 6,
                                 guard_budget=budget, guard_carry=1))
    lib = srv.fleet.model.lib
    true = sys_.true_theta(lib)
    n_tw = 6
    for t in range(5):                  # enough samples for the guard window
        for i in range(n_tw):
            srv.ingest(i, ys[i, t * 10:(t + 1) * 10],
                       us[i, t * 10:(t + 1) * 10])
        srv.tick()
    for i in range(n_tw):
        srv.deploy(i, true)
    bound = -(-n_tw // budget)          # ceil(6/2) = 3 ticks
    scored_ticks = {i: None for i in range(n_tw)}
    for t in range(5, 5 + bound):
        for i in range(n_tw):
            srv.ingest(i, ys[i, t * 10:(t + 1) * 10],
                       us[i, t * 10:(t + 1) * 10])
        rep = srv.tick()
        assert rep.n_guarded <= budget + 1
        for i in range(n_tw):
            prev = srv.twins[i].divergence
            if scored_ticks[i] is None and prev != 0.0:
                scored_ticks[i] = rep.tick
    # every deployed twin was folded into the EMA within the bound — the
    # true model tracks, so scores are tiny but nonzero
    assert all(v is not None for v in scored_ticks.values())


def test_shared_modules_require_identical_shapes(lv_world):
    sys_, _, _ = lv_world
    a = TwinServer(_server_cfg(sys_))
    with pytest.raises(ValueError, match="identical"):
        TwinServer(_server_cfg(sys_, refit_slots=4), share_modules_from=a)
    b = TwinServer(_server_cfg(sys_), share_modules_from=a)
    assert b.ring is a.ring and b.fleet is a.fleet and b.guard is a.guard
