"""Serving engine: batched decode with slot management matches sequential
generation; continuous admission retires/admits correctly."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.zoo import build
from repro.serve.engine import Request, ServeEngine


def _greedy_reference(api, params, prompt, n_new):
    """Sequential single-request reference: prefill + n_new decode steps."""
    cache, logits = api.prefill(
        params, {"tokens": jnp.asarray(prompt[None])}, 64)
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        cache, logits = api.decode(params, cache,
                                   jnp.asarray([toks[-1]], jnp.int32))
        toks.append(int(jnp.argmax(logits[0])))
    return toks


@pytest.mark.parametrize("arch", ["qwen3-8b", "rwkv6-3b",
                                  pytest.param("gemma3-12b",
                                               marks=pytest.mark.slow)])
def test_engine_matches_sequential(arch):
    api = build(get_arch(arch).smoke)
    params = api.init(jax.random.PRNGKey(0))
    prompts = [np.arange(5, 13, dtype=np.int32),
               np.arange(40, 44, dtype=np.int32)]

    engine = ServeEngine(api, slots=2, max_len=64)
    engine.load(params)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    done = engine.generate(reqs)
    assert len(done) == 2

    for req in done:
        ref = _greedy_reference(api, params, req.prompt, 6)
        assert req.generated == ref, (req.rid, req.generated, ref)


def test_continuous_admission():
    api = build(get_arch("qwen3-8b").smoke)
    params = api.init(jax.random.PRNGKey(0))
    engine = ServeEngine(api, slots=2, max_len=64)
    engine.load(params)
    reqs = [Request(rid=i, prompt=np.arange(3 + i, dtype=np.int32) + 1,
                    max_new_tokens=3 + i % 2) for i in range(5)]
    done = engine.generate(reqs)
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]
    for r in done:
        assert len(r.generated) == r.max_new_tokens
