"""linear_scan kernel: chunked XLA form and Pallas kernel vs the exact
sequential oracle, over modes x shapes x dtypes x chunk sizes."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.linear_scan.linear_scan import linear_scan_pallas
from repro.kernels.linear_scan.ref import (linear_scan_chunked,
                                           linear_scan_seq)


def _inputs(key, B, H, T, K, V, dtype):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, H, T, K), dtype) * 0.5
    k = jax.random.normal(ks[1], (B, H, T, K), dtype) * 0.5
    v = jax.random.normal(ks[2], (B, H, T, V), dtype) * 0.5
    # log-decay in [-0.2, -1e-3] (realistic data-dependent decay range)
    w = -jnp.exp(jax.random.uniform(ks[3], (B, H, T, K), jnp.float32,
                                    -7.0, -1.5)).astype(dtype)
    u = jax.random.normal(ks[4], (H, K), jnp.float32) * 0.3
    return q, k, v, w, u


CASES = [
    # (B, H, T, K, V, chunk)
    (1, 1, 32, 8, 8, 8),
    (2, 3, 65, 16, 8, 16),   # non-divisible T -> padding path
    (2, 2, 128, 32, 64, 64),
    (1, 2, 17, 8, 8, 64),    # chunk > T
]


@pytest.mark.parametrize("mode", ["ssd", "rwkv6"])
@pytest.mark.parametrize("case", CASES)
def test_chunked_matches_seq(mode, case):
    B, H, T, K, V, chunk = case
    q, k, v, w, u = _inputs(jax.random.PRNGKey(0), B, H, T, K, V, jnp.float32)
    uu = u if mode == "rwkv6" else None
    o_ref, s_ref = linear_scan_seq(q, k, v, w, uu, mode=mode)
    o, s = linear_scan_chunked(q, k, v, w, uu, mode=mode, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("mode", ["ssd", "rwkv6"])
@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_matches_seq(mode, case, dtype):
    B, H, T, K, V, chunk = case
    q, k, v, w, u = _inputs(jax.random.PRNGKey(1), B, H, T, K, V, dtype)
    uu = u if mode == "rwkv6" else None
    o_ref, s_ref = linear_scan_seq(q, k, v, w, uu, mode=mode)
    o, s = linear_scan_pallas(q, k, v, w, uu, mode=mode, chunk=chunk,
                              interpret=True)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("mode", ["ssd", "rwkv6"])
def test_initial_state_carry(mode):
    """Splitting a sequence in two with state carry == one full scan."""
    B, H, T, K, V = 2, 2, 64, 16, 16
    q, k, v, w, u = _inputs(jax.random.PRNGKey(2), B, H, T, K, V, jnp.float32)
    uu = u if mode == "rwkv6" else None
    o_full, s_full = linear_scan_seq(q, k, v, w, uu, mode=mode)

    half = T // 2
    cut = lambda x, a, b: x[:, :, a:b]
    o1, s1 = linear_scan_chunked(cut(q, 0, half), cut(k, 0, half),
                                 cut(v, 0, half), cut(w, 0, half), uu,
                                 mode=mode, chunk=16)
    o2, s2 = linear_scan_chunked(cut(q, half, T), cut(k, half, T),
                                 cut(v, half, T), cut(w, half, T), uu,
                                 mode=mode, chunk=16, initial_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], axis=2)),
                               np.asarray(o_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=2e-4, atol=2e-4)

    # pallas with initial state
    o2p, s2p = linear_scan_pallas(cut(q, half, T), cut(k, half, T),
                                  cut(v, half, T), cut(w, half, T), uu,
                                  mode=mode, chunk=16, initial_state=s1,
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(o2p), np.asarray(o2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2p), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)
