"""Unit + property tests for the polynomial library."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.library import make_library, n_library_terms

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("n,m,order", [(2, 0, 2), (3, 0, 2), (3, 1, 3), (2, 1, 2)])
def test_term_count(n, m, order):
    lib = make_library(n, m, order)
    assert lib.size == n_library_terms(n + m, order)
    assert len(lib.names) == lib.size
    assert len(set(lib.names)) == lib.size          # no duplicate monomials


def test_eval_matches_manual():
    lib = make_library(2, 1, 2)
    y = jnp.asarray([[2.0, 3.0]])
    u = jnp.asarray([[0.5]])
    phi = np.asarray(lib.eval(y, u))[0]
    by_name = dict(zip(lib.names, phi))
    assert by_name["1"] == pytest.approx(1.0)
    assert by_name["y0"] == pytest.approx(2.0)
    assert by_name["y1"] == pytest.approx(3.0)
    assert by_name["u0"] == pytest.approx(0.5)
    assert by_name["y0*y1"] == pytest.approx(6.0)
    assert by_name["u0*y0"] == pytest.approx(1.0)
    assert by_name["y1*y1"] == pytest.approx(9.0)


def test_theta_roundtrip():
    lib = make_library(2, 0, 2)
    rows = [{"y0": 1.0, "y0*y1": -0.1}, {"y1": -1.5, "y0*y1": 0.075}]
    theta = lib.theta_from_terms(rows)
    d = lib.coeff_dict(theta)
    assert d["dy0/dt"] == {"y0": 1.0, "y0*y1": -0.1}
    assert d["dy1/dt"] == {"y1": -1.5, "y0*y1": 0.075}


def test_theta_from_terms_canonicalizes_order():
    lib = make_library(2, 1, 2)
    a = lib.theta_from_terms([{"y1*y0": 2.0}, {"y0*u0": 3.0}])
    b = lib.theta_from_terms([{"y0*y1": 2.0}, {"u0*y0": 3.0}])
    np.testing.assert_array_equal(a, b)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 3), m=st.integers(0, 2), order=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_eval_degree_bound_property(n, m, order, seed):
    """Scaling every variable by s scales each term by at most s^order —
    and term j by exactly s^deg(j)."""
    lib = make_library(n, m, order)
    key = jax.random.PRNGKey(seed)
    ky, ku = jax.random.split(key)
    y = jax.random.uniform(ky, (4, n), minval=0.5, maxval=2.0)
    u = jax.random.uniform(ku, (4, m), minval=0.5, maxval=2.0) if m else None
    s = 3.0
    phi1 = lib.eval(y, u)
    phi2 = lib.eval(s * y, s * u if m else None)
    degrees = (np.asarray(lib.term_indices) > 0).sum(-1)
    expected = phi1 * (s ** degrees)[None, :]
    np.testing.assert_allclose(np.asarray(phi2), np.asarray(expected),
                               rtol=2e-4)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 3), order=st.integers(1, 3))
def test_library_batch_shape_property(n, order):
    lib = make_library(n, 0, order)
    y = jnp.ones((2, 5, n))
    assert lib.eval(y, None).shape == (2, 5, lib.size)
