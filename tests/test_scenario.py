"""Scenario engine unit + integration tests (src/repro/twin/scenario.py).

Covers the pure pieces (config validation, the deterministic degradation
ladder in `effective_k`, runner envelope math) and the `TwinServer`
integration surface: result shapes, input validation, the theta-history
confidence ensemble, snapshot/restore of the history ring, and the
shrink/refuse behavior under the `DegradationPolicy` ladder.  Cross-server
conformance (single vs sharded vs federated) lives in
tests/test_service_conformance.py.
"""
import jax
import numpy as np
import pytest

from repro.core.merinda import MerindaConfig
from repro.systems.simulate import simulate_batch
from repro.systems.van_der_pol import VanDerPol
from repro.twin.monitor import GuardConfig
from repro.twin.scenario import (ScenarioConfig, ScenarioRefused,
                                 ScenarioRunner, effective_k)
from repro.twin.server import TwinServer, TwinServerConfig

jax.config.update("jax_platform_name", "cpu")

pytestmark = pytest.mark.scenario


# --------------------------------------------------------------------- #
# config + ladder (pure, no device work)
# --------------------------------------------------------------------- #
def test_config_validation():
    with pytest.raises(ValueError):
        ScenarioConfig(max_k=0)
    with pytest.raises(ValueError):
        ScenarioConfig(ensemble=0)
    with pytest.raises(ValueError):
        ScenarioConfig(degraded_shrink=1)
    with pytest.raises(ValueError):
        ScenarioConfig(shrink_level=0)
    with pytest.raises(ValueError):
        ScenarioConfig(shrink_level=3, refuse_level=2)


def test_effective_k_ladder():
    cfg = ScenarioConfig(max_k=16, shrink_level=2, degraded_shrink=4,
                         refuse_level=3)
    assert effective_k(8, 0, cfg) == 8          # healthy: passthrough
    assert effective_k(8, 1, cfg) == 8          # below shrink_level
    assert effective_k(8, 2, cfg) == 2          # 8 // 4
    assert effective_k(3, 2, cfg) == 1          # floor at 1, never 0
    with pytest.raises(ScenarioRefused, match="^scenario refused"):
        effective_k(8, 3, cfg)
    with pytest.raises(ScenarioRefused):
        effective_k(1, 5, cfg)                  # any level past refuse
    with pytest.raises(ValueError):
        effective_k(0, 0, cfg)
    with pytest.raises(ValueError):
        effective_k(17, 0, cfg)                 # over max_k


# --------------------------------------------------------------------- #
# runner envelope math (direct, no server)
# --------------------------------------------------------------------- #
def _runner(sys_, ensemble=4):
    lib = sys_.library()
    return ScenarioRunner(lib, sys_.spec.dt,
                          ScenarioConfig(max_k=8, ensemble=ensemble)), lib


def test_runner_envelope_contains_center():
    sys_ = VanDerPol()
    runner, lib = _runner(sys_)
    theta = np.asarray(sys_.true_theta(lib), np.float32)
    hist = np.stack([theta * (1.0 + 0.05 * i) for i in range(4)])
    y0 = np.asarray([0.5, -0.3], np.float32)
    us = np.zeros((3, 20, 1), np.float32)
    us[:, :, 0] = np.linspace(0.1, 0.3, 3)[:, None]
    center, lo, hi, conf = runner.rollout(hist, 4, y0, us)
    assert center.shape == lo.shape == hi.shape == (3, 21, 2)
    assert conf.shape == (3,)
    assert (lo <= center + 1e-6).all() and (center <= hi + 1e-6).all()
    assert ((0.0 < conf) & (conf <= 1.0)).all()


def test_runner_single_deploy_degenerate_envelope():
    """count=1: unfilled ring slots fall back to the live theta, so the
    envelope collapses to the center and confidence is 1."""
    sys_ = VanDerPol()
    runner, lib = _runner(sys_)
    theta = np.asarray(sys_.true_theta(lib), np.float32)
    hist = np.zeros((4,) + theta.shape, np.float32)
    hist[0] = theta                              # only slot 0 is real
    y0 = np.asarray([0.5, -0.3], np.float32)
    us = np.zeros((2, 10, 1), np.float32)
    center, lo, hi, conf = runner.rollout(hist, 1, y0, us)
    np.testing.assert_allclose(lo, center, atol=1e-6)
    np.testing.assert_allclose(hi, center, atol=1e-6)
    np.testing.assert_allclose(conf, 1.0, atol=1e-5)


def test_runner_confidence_decreases_with_spread():
    """Wider theta disagreement -> wider envelope -> lower confidence."""
    sys_ = VanDerPol()
    runner, lib = _runner(sys_)
    theta = np.asarray(sys_.true_theta(lib), np.float32)
    y0 = np.asarray([0.5, -0.3], np.float32)
    us = np.zeros((1, 20, 1), np.float32)
    confs = []
    for jitter in (0.0, 0.05, 0.25):
        hist = np.stack([theta * (1.0 + jitter * i) for i in range(4)])
        *_, conf = runner.rollout(hist, 4, y0, us)
        confs.append(float(conf[0]))
    assert confs[0] > confs[1] > confs[2]
    assert confs[0] == pytest.approx(1.0, abs=1e-5)


def test_runner_rejects_bad_us_rank():
    sys_ = VanDerPol()
    runner, lib = _runner(sys_)
    theta = np.asarray(sys_.true_theta(lib), np.float32)
    hist = np.broadcast_to(theta, (4,) + theta.shape)
    with pytest.raises(ValueError, match="us must be"):
        runner.rollout(hist, 1, np.zeros(2, np.float32),
                       np.zeros((10, 1), np.float32))


# --------------------------------------------------------------------- #
# server integration
# --------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def vdp_world():
    sys_ = VanDerPol()
    tr = simulate_batch(sys_, jax.random.PRNGKey(0), batch=3, horizon=200,
                        noise_std=0.002)
    return sys_, np.asarray(tr.ys_noisy), np.asarray(tr.us)


def _server(sys_, **kw):
    d = dict(
        merinda=MerindaConfig(n=2, m=1, order=sys_.spec.order, hidden=8,
                              head_hidden=8, n_active=8, dt=sys_.spec.dt),
        max_twins=6, refit_slots=2, capacity=128, window=16, stride=8,
        windows_per_twin=4, steps_per_tick=1, deploy_after=2,
        min_residency=2, max_residency=6,
        guard=GuardConfig(window=16),
        scenario=ScenarioConfig(max_k=8, ensemble=4))
    d.update(kw)
    return TwinServer(TwinServerConfig(**d))


def _warm(srv, sys_, ys, us, n=2, chunks=3):
    theta = sys_.true_theta(srv.fleet.model.lib)
    for i in range(n):
        srv.register(i)
        srv.deploy(i, theta)
        for t in range(chunks):
            srv.ingest(i, ys[i, t * 10:(t + 1) * 10],
                       us[i, t * 10:(t + 1) * 10])
    srv.tick()
    return theta


def test_server_scenario_shapes_and_bounds(vdp_world):
    sys_, ys, us = vdp_world
    srv = _server(sys_)
    _warm(srv, sys_, ys, us)
    qus = np.zeros((4, 15, 1), np.float32)
    qus[:, :, 0] = np.linspace(-0.2, 0.2, 4)[:, None]
    res = srv.scenario(0, 15, qus)
    assert res.twin_id == 0 and res.horizon == 15
    assert res.requested_k == res.k == 4 and res.degraded_level == 0
    assert res.ys.shape == res.lo.shape == res.hi.shape == (4, 16, 2)
    assert res.confidence.shape == (4,)
    assert (res.lo <= res.ys + 1e-6).all() and (res.ys <= res.hi + 1e-6).all()
    assert np.isfinite(res.ys).all()


def test_server_scenario_input_surface(vdp_world):
    sys_, ys, us = vdp_world
    srv = _server(sys_)
    _warm(srv, sys_, ys, us)
    # 2-D us promotes to K=1
    res = srv.scenario(0, 10, np.zeros((10, 1), np.float32))
    assert res.k == 1 and res.ys.shape == (1, 11, 2)
    # us=None + k: zero-input counterfactuals
    res = srv.scenario(0, 10, k=3)
    assert res.k == 3
    # k may select a prefix of the provided sequences, never more
    res = srv.scenario(0, 10, np.zeros((4, 10, 1), np.float32), k=2)
    assert res.k == 2
    with pytest.raises(ValueError):
        srv.scenario(0, 10, np.zeros((2, 10, 1), np.float32), k=3)
    with pytest.raises(ValueError):
        srv.scenario(0, 10, np.zeros((2, 9, 1), np.float32))   # H mismatch
    with pytest.raises(ValueError):
        srv.scenario(0, 0)
    with pytest.raises(KeyError):
        srv.scenario(99, 10)


def test_server_scenario_requires_deploy_and_telemetry(vdp_world):
    sys_, ys, us = vdp_world
    srv = _server(sys_)
    srv.register(0)
    with pytest.raises(RuntimeError, match="no deployed model"):
        srv.scenario(0, 10)
    srv.deploy(0, sys_.true_theta(srv.fleet.model.lib))
    with pytest.raises(RuntimeError, match="no telemetry"):
        srv.scenario(0, 10)


def test_server_degradation_ladder(vdp_world):
    sys_, ys, us = vdp_world
    srv = _server(sys_)
    _warm(srv, sys_, ys, us)
    qus = np.zeros((8, 10, 1), np.float32)
    srv._degradation.level = 2
    res = srv.scenario(0, 10, qus)
    assert res.requested_k == 8 and res.k == 2     # 8 // degraded_shrink(4)
    assert res.degraded_level == 2
    srv._degradation.level = 3
    with pytest.raises(ScenarioRefused):
        srv.scenario(0, 10, qus)
    srv._degradation.level = 0
    assert srv.scenario(0, 10, qus).k == 8         # recovers fully


def test_server_theta_hist_survives_snapshot(vdp_world):
    """The confidence ensemble is state: snapshot/restore must reproduce
    the exact scenario answer, envelope included."""
    sys_, ys, us = vdp_world
    srv = _server(sys_)
    theta = _warm(srv, sys_, ys, us)
    # push history: redeploys widen the ensemble
    for j in (0.02, 0.05):
        srv.deploy(0, np.asarray(theta) * (1.0 + j))
    qus = np.zeros((2, 12, 1), np.float32)
    before = srv.scenario(0, 12, qus)
    assert int(srv._hist_count[srv.twins[0].ring_slot]) == 3
    state = srv.snapshot_state()

    srv2 = _server(sys_)
    srv2.restore_state(state)
    after = srv2.scenario(0, 12, qus)
    np.testing.assert_allclose(after.ys, before.ys, rtol=1e-6)
    np.testing.assert_allclose(after.lo, before.lo, rtol=1e-6)
    np.testing.assert_allclose(after.hi, before.hi, rtol=1e-6)
    np.testing.assert_allclose(after.confidence, before.confidence,
                               rtol=1e-6)


def test_server_confidence_tracks_redeploy_churn(vdp_world):
    sys_, ys, us = vdp_world
    srv = _server(sys_)
    theta = _warm(srv, sys_, ys, us)
    calm = srv.scenario(0, 12, k=1)
    for j in (0.1, 0.2, 0.3):                      # thrash the model
        srv.deploy(0, np.asarray(theta) * (1.0 + j))
    churned = srv.scenario(0, 12, k=1)
    assert float(churned.confidence[0]) < float(calm.confidence[0])
    assert (churned.hi - churned.lo).mean() > (calm.hi - calm.lo).mean()


@pytest.mark.slow
def test_server_scenario_k_large(vdp_world):
    """max_k-wide query: one fused dispatch, all envelopes ordered."""
    sys_, ys, us = vdp_world
    srv = _server(sys_, scenario=ScenarioConfig(max_k=32, ensemble=4))
    _warm(srv, sys_, ys, us)
    qus = np.zeros((32, 30, 1), np.float32)
    qus[:, :, 0] = np.linspace(-0.3, 0.3, 32)[:, None]
    res = srv.scenario(0, 30, qus)
    assert res.k == 32 and res.ys.shape == (32, 31, 2)
    assert (res.lo <= res.hi + 1e-6).all()
    assert np.isfinite(res.confidence).all()
