"""Telemetry ring buffers: wraparound, masked ingest, make_windows parity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import make_windows
from repro.twin.stream import RingConfig, TelemetryRing

jax.config.update("jax_platform_name", "cpu")


def _ring(slots=3, capacity=64, n=2, m=1):
    r = TelemetryRing(RingConfig(slots=slots, capacity=capacity, n=n, m=m))
    return r, r.init()


def _push(ring, state, slot, ys, us):
    return ring.ingest(state, jnp.asarray([slot]),
                       jnp.asarray(ys[None]), jnp.asarray(us[None]),
                       jnp.asarray([len(ys)]))


def test_latest_returns_chronological_tail():
    ring, st = _ring()
    rng = np.random.RandomState(0)
    ys = rng.randn(50, 2).astype(np.float32)
    us = rng.randn(50, 1).astype(np.float32)
    st = _push(ring, st, 1, ys, us)
    yl, ul = ring.latest(st, jnp.asarray([1]), 20)
    np.testing.assert_allclose(np.asarray(yl[0]), ys[-21:], rtol=1e-6)
    # u alignment: u[t] is the input during y step t -> t+1
    np.testing.assert_allclose(np.asarray(ul[0]), us[-21:-1], rtol=1e-6)


def test_wraparound_preserves_order():
    ring, st = _ring(capacity=64)
    rng = np.random.RandomState(1)
    ys = rng.randn(90, 2).astype(np.float32)   # 90 > 64: ring laps
    us = rng.randn(90, 1).astype(np.float32)
    st = _push(ring, st, 0, ys[:60], us[:60])
    st = _push(ring, st, 0, ys[60:], us[60:])
    assert int(st["count"][0]) == 90
    yl, ul = ring.latest(st, jnp.asarray([0]), 40)
    np.testing.assert_allclose(np.asarray(yl[0]), ys[-41:], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ul[0]), us[-41:-1], rtol=1e-6)


def test_masked_ingest_ignores_padding():
    """Padded chunk tails (counts < C) must not corrupt ring contents."""
    ring, st = _ring()
    rng = np.random.RandomState(2)
    ys = rng.randn(2, 16, 2).astype(np.float32)
    us = rng.randn(2, 16, 1).astype(np.float32)
    counts = np.asarray([10, 16], np.int32)     # slot 0 chunk padded past 10
    st = ring.ingest(st, jnp.asarray([0, 1]), jnp.asarray(ys),
                     jnp.asarray(us), jnp.asarray(counts))
    assert int(st["count"][0]) == 10 and int(st["count"][1]) == 16
    y0, _ = ring.latest(st, jnp.asarray([0]), 9)
    np.testing.assert_allclose(np.asarray(y0[0]), ys[0, :10], rtol=1e-6)
    # next ingest lands right after the valid prefix, not after the pad
    more = rng.randn(4, 2).astype(np.float32)
    st = _push(ring, st, 0, more, np.zeros((4, 1), np.float32))
    y0, _ = ring.latest(st, jnp.asarray([0]), 13)
    np.testing.assert_allclose(np.asarray(y0[0]),
                               np.concatenate([ys[0, :10], more]), rtol=1e-6)


def test_windows_parity_with_make_windows():
    """Ring windows == make_windows on the chronological trace, bitwise."""
    ring, st = _ring(capacity=64)
    rng = np.random.RandomState(3)
    ys = rng.randn(80, 2).astype(np.float32)    # wraps the 64-ring
    us = rng.randn(80, 1).astype(np.float32)
    st = _push(ring, st, 2, ys[:50], us[:50])
    st = _push(ring, st, 2, ys[50:], us[50:])
    length = TelemetryRing.span(window=8, stride=4, n_windows=5)   # 24
    y_w, u_w = ring.windows(st, jnp.asarray([2]), window=8, stride=4,
                            length=length)
    assert y_w.shape == (1, 5, 9, 2) and u_w.shape == (1, 5, 8, 1)
    y_ref, u_ref = make_windows(jnp.asarray(ys[-length - 1:]),
                                jnp.asarray(us[-length - 1:-1]), 8, 4)
    np.testing.assert_array_equal(np.asarray(y_w[0]), np.asarray(y_ref))
    np.testing.assert_array_equal(np.asarray(u_w[0]), np.asarray(u_ref))


def test_slots_are_independent():
    ring, st = _ring()
    a = np.ones((8, 2), np.float32)
    b = 2 * np.ones((8, 2), np.float32)
    z = np.zeros((8, 1), np.float32)
    st = _push(ring, st, 0, a, z)
    st = _push(ring, st, 1, b, z)
    ya, _ = ring.latest(st, jnp.asarray([0]), 7)
    yb, _ = ring.latest(st, jnp.asarray([1]), 7)
    assert float(ya.mean()) == 1.0 and float(yb.mean()) == 2.0
    st = ring.clear(st, jnp.int32(0))
    assert int(st["count"][0]) == 0 and int(st["count"][1]) == 8
