"""Integrator correctness: analytic solutions + RK4 convergence order."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.library import make_library
from repro.core.odeint import integrate, poly_ode_integrate, rk4_step

jax.config.update("jax_platform_name", "cpu")


def test_rk4_exact_linear():
    """dy/dt = -y: RK4 error ~ O(dt^4) vs exp(-t)."""
    f = lambda y, u: -y
    y0 = jnp.ones((1,))
    us = jnp.zeros((100, 0))
    ys = integrate(f, y0, us, dt=0.05)
    t = jnp.arange(101) * 0.05
    np.testing.assert_allclose(np.asarray(ys[:, 0]), np.exp(-np.asarray(t)),
                               rtol=1e-6)


def test_rk4_convergence_order():
    """Halving dt must reduce RK4 global error ~16x (4th order)."""
    f = lambda y, u: jnp.stack([y[1], -y[0]])   # harmonic oscillator
    y0 = jnp.asarray([1.0, 0.0])
    T = 2.0

    def err(dt):
        steps = int(T / dt)
        ys = integrate(f, y0, jnp.zeros((steps, 0)), dt=dt)
        return abs(float(ys[-1, 0]) - np.cos(T))

    e1, e2 = err(0.1), err(0.05)
    assert e1 / e2 > 10.0, (e1, e2)             # ~16 in theory


def test_substeps_improve_accuracy():
    f = lambda y, u: -(y ** 2)                  # dy = -y^2, y(t)=1/(1+t)
    y0 = jnp.ones((1,))
    us = jnp.zeros((20, 0))
    coarse = integrate(f, y0, us, dt=0.2, substeps=1)
    fine = integrate(f, y0, us, dt=0.2, substeps=10)
    truth = 1.0 / (1.0 + 0.2 * np.arange(21))
    e_c = np.abs(np.asarray(coarse[:, 0]) - truth).max()
    e_f = np.abs(np.asarray(fine[:, 0]) - truth).max()
    assert e_f < e_c


def test_poly_ode_matches_generic():
    """Library-form integration == generic integration of the same rhs."""
    lib = make_library(2, 1, 2)
    key = jax.random.PRNGKey(0)
    theta = 0.2 * jax.random.normal(key, (2, lib.size))
    y0 = jnp.asarray([0.3, -0.2])
    us = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (30, 1))

    def rhs(y, u):
        return lib.eval(y, u) @ theta.T

    ys_a = integrate(rhs, y0, us, dt=0.05)
    ys_b = poly_ode_integrate(theta[None], y0[None], us[:, None, :], 0.05,
                              library=lib)[:, 0]
    np.testing.assert_allclose(np.asarray(ys_a), np.asarray(ys_b), atol=1e-6)


def test_zero_theta_is_constant():
    lib = make_library(3, 0, 2)
    y0 = jnp.asarray([[1.0, 2.0, 3.0]])
    ys = poly_ode_integrate(jnp.zeros((1, 3, lib.size)), y0,
                            jnp.zeros((10, 1, 0)), 0.1, library=lib)
    np.testing.assert_allclose(np.asarray(ys),
                               np.broadcast_to(np.asarray(y0), (11, 1, 3)))
