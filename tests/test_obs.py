"""Observability layer: registry accuracy, thread-safety, trace format,
exporters, the bench-regression gate, and serving-loop non-interference."""
import importlib.util
import json
import sys
import threading
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core.merinda import MerindaConfig
from repro.obs import (DEFAULT_LATENCY_BUCKETS, MetricRegistry, NULL_SPAN,
                       SnapshotWriter, Tracer, log_buckets)
from repro.systems.lotka_volterra import LotkaVolterra
from repro.systems.simulate import simulate_batch
from repro.twin.monitor import GuardConfig
from repro.twin.server import TwinServer, TwinServerConfig

jax.config.update("jax_platform_name", "cpu")


# --------------------------------------------------------------------- #
# histogram: bucket layout + quantile accuracy vs exact
# --------------------------------------------------------------------- #
def test_log_buckets_geometric():
    b = log_buckets(1e-3, 1.0, 10)
    assert b[0] == pytest.approx(1e-3) and b[-1] >= 1.0
    ratios = [b[i + 1] / b[i] for i in range(len(b) - 1)]
    assert all(r == pytest.approx(10 ** 0.1) for r in ratios)
    with pytest.raises(ValueError):
        log_buckets(1.0, 0.5)


@pytest.mark.parametrize("dist", ["lognormal", "uniform", "bimodal"])
def test_histogram_quantiles_match_exact(dist):
    """The bounded-memory histogram must track exact quantiles within one
    bucket ratio (the documented error bound) on realistic latency shapes."""
    rng = np.random.default_rng(0)
    if dist == "lognormal":
        xs = rng.lognormal(mean=-6.0, sigma=1.0, size=20000)   # ~ms scale
    elif dist == "uniform":
        xs = rng.uniform(1e-4, 1e-2, size=20000)
    else:
        # unequal modes so no tested quantile lands in the empty gap
        # between them (there, ANY in-gap value is a valid quantile and
        # the relative-error bound is meaningless)
        xs = np.concatenate([rng.normal(2e-3, 1e-4, 9000),
                             rng.normal(5e-2, 2e-3, 11000)]).clip(1e-5)
    reg = MetricRegistry()
    h = reg.histogram("t_seconds", bounds=DEFAULT_LATENCY_BUCKETS)
    for x in xs:
        h.observe(float(x))
    bucket_ratio = 10 ** (1 / 60) - 1            # per_decade=60 -> ~3.9%
    for q in (0.5, 0.9, 0.99):
        exact = float(np.quantile(xs, q))
        approx = h.quantile(q)
        assert abs(approx - exact) / exact < bucket_ratio + 0.01, \
            f"{dist} q={q}: {approx} vs exact {exact}"
    assert h.max == pytest.approx(float(xs.max()))
    assert h.sum == pytest.approx(float(xs.sum()), rel=1e-6)
    assert h.count == len(xs)


def test_histogram_overflow_bucket_uses_exact_max():
    reg = MetricRegistry()
    h = reg.histogram("t", bounds=(1.0, 2.0))
    for v in (0.5, 3.0, 500.0):
        h.observe(v)
    assert h.quantile(1.0) == pytest.approx(500.0)   # +inf bucket -> max
    assert h.quantile(0.0) > 0.0
    h.reset()
    assert h.count == 0 and h.quantile(0.5) == 0.0


# --------------------------------------------------------------------- #
# thread-safety: concurrent updates must not lose increments
# --------------------------------------------------------------------- #
def test_counter_and_histogram_concurrent_updates():
    reg = MetricRegistry()
    c = reg.counter("hits_total")
    h = reg.histogram("lat_seconds")
    n_threads, per = 8, 5000

    def work(k):
        for i in range(per):
            c.inc()
            h.observe(1e-4 * (1 + (i + k) % 7))

    threads = [threading.Thread(target=work, args=(k,))
               for k in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == n_threads * per            # no lost increments
    assert h.count == n_threads * per


def test_counter_rejects_negative():
    c = MetricRegistry().counter("x_total")
    with pytest.raises(ValueError):
        c.inc(-1)


# --------------------------------------------------------------------- #
# registry semantics: families, labels, exposition, snapshot
# --------------------------------------------------------------------- #
def test_registry_get_or_create_and_type_conflict():
    reg = MetricRegistry()
    a = reg.counter("ticks_total", labels={"shard": "0"})
    b = reg.counter("ticks_total", labels={"shard": "0"})
    c = reg.counter("ticks_total", labels={"shard": "1"})
    assert a is b and a is not c                 # same child per label set
    with pytest.raises(ValueError):
        reg.gauge("ticks_total")                 # one name, one type


def test_expose_prometheus_text_format():
    reg = MetricRegistry()
    reg.counter("req_total", help="requests").inc(3)
    reg.gauge("depth", labels={"shard": "1"}).set(7)
    h = reg.histogram("lat_seconds", bounds=(0.1, 1.0))
    for v in (0.05, 0.5, 2.0):
        h.observe(v)
    text = reg.expose()
    assert "# TYPE req_total counter" in text
    assert "req_total 3" in text
    assert 'depth{shard="1"} 7' in text
    # cumulative buckets: 1 <= 0.1, 2 <= 1.0, 3 <= +Inf == _count
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="1.0"} 2' in text
    assert 'lat_seconds_bucket{le="+Inf"} 3' in text
    assert "lat_seconds_count 3" in text


def test_snapshot_is_json_able():
    reg = MetricRegistry()
    reg.counter("c_total", labels={"shard": "0"}).inc()
    reg.histogram("h_seconds").observe(0.01)
    snap = json.loads(json.dumps(reg.snapshot()))
    assert snap["c_total"]["kind"] == "counter"
    series = snap["h_seconds"]["series"][0]
    assert series["count"] == 1 and "p99" in series


# --------------------------------------------------------------------- #
# tracer: Chrome trace-event validity, sampling, ring bound, off-switch
# --------------------------------------------------------------------- #
def test_trace_json_is_valid_chrome_trace(tmp_path):
    tr = Tracer()
    with tr.span("tick", tick=1):
        with tr.span("flush"):
            pass
        with tr.span("guard", shard="0"):
            pass
    path = tmp_path / "trace.json"
    tr.write(path)
    doc = json.loads(path.read_text())
    assert isinstance(doc["traceEvents"], list)
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    metas = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert {e["name"] for e in xs} == {"tick", "flush", "guard"}
    for e in xs:                                  # required complete-event keys
        for k in ("name", "cat", "ph", "ts", "dur", "pid", "tid"):
            assert k in e
        assert isinstance(e["ts"], float) and e["dur"] >= 0
    assert any(m["name"] == "thread_name" for m in metas)
    # children nest inside the root span's window
    tick = next(e for e in xs if e["name"] == "tick")
    for e in xs:
        assert e["ts"] >= tick["ts"] - 1e-6
        assert e["ts"] + e["dur"] <= tick["ts"] + tick["dur"] + 1e-6
    assert tick["args"]["tick"] == 1
    assert next(e for e in xs if e["name"] == "guard")["args"]["shard"] == "0"


def test_tracer_sampling_keeps_subtrees_whole():
    tr = Tracer(sample_every=3)
    for i in range(9):
        with tr.span("root", i=i):
            with tr.span("child"):
                pass
    names = [e["name"] for e in tr.to_chrome_trace()["traceEvents"]
             if e["ph"] == "X"]
    # roots 0, 3, 6 sampled — each with its child (whole subtree or nothing)
    assert names.count("root") == 3 and names.count("child") == 3


def test_tracer_ring_bound_and_drop_count():
    tr = Tracer(capacity=4)
    for i in range(10):
        with tr.span("s", i=i):
            pass
    assert len(tr) == 4
    assert tr.dropped_events == 6
    kept = [e["args"]["i"] for e in tr.to_chrome_trace()["traceEvents"]
            if e["ph"] == "X"]
    assert kept == [6, 7, 8, 9]                   # newest survive


def test_tracer_disabled_is_noop():
    tr = Tracer(enabled=False)
    assert tr.span("x") is NULL_SPAN              # shared object, no alloc
    with tr.span("x"):
        pass
    assert len(tr) == 0


# --------------------------------------------------------------------- #
# exporters
# --------------------------------------------------------------------- #
def test_snapshot_writer_period_gate_and_atomic_write(tmp_path):
    reg = MetricRegistry()
    reg.counter("c_total").inc(5)
    tr = Tracer()
    path = tmp_path / "snap.json"
    w = SnapshotWriter(reg, path, every_s=3600.0, tracer=tr)
    assert w.maybe_write() is True
    assert w.maybe_write() is False               # inside the period
    assert w.writes == 1
    doc = json.loads(path.read_text())
    assert doc["metrics"]["c_total"]["series"][0]["value"] == 5
    assert doc["trace"]["enabled"] is True
    assert not path.with_suffix(".json.tmp").exists()


# --------------------------------------------------------------------- #
# bench-regression gate (tools/check_bench.py)
# --------------------------------------------------------------------- #
def _load_check_bench():
    root = Path(__file__).resolve().parent.parent
    spec = importlib.util.spec_from_file_location(
        "check_bench", root / "tools" / "check_bench.py")
    mod = importlib.util.module_from_spec(spec)
    sys.modules.setdefault("check_bench", mod)
    spec.loader.exec_module(mod)
    return mod


def test_check_bench_flags_latency_and_violations():
    cb = _load_check_bench()
    base = [{"twins": "64", "shards": "1", "p50_ms": "10.0",
             "p99_ms": "20.0", "violations": "0"}]
    fresh = [{"twins": "64", "shards": "1", "p50_ms": "14.0",
              "p99_ms": "20.5", "violations": "1"}]
    reg, checked, skipped = cb.compare_rows(fresh, base, tolerance=0.25)
    assert checked == 1 and not skipped
    assert len(reg) == 2                          # p50 +40%, violations +1
    assert any("p50_ms" in r for r in reg)
    assert any("violations" in r for r in reg)


def test_check_bench_skips_new_configs_and_non_numeric():
    cb = _load_check_bench()
    base = [{"twins": "64", "p50_ms": "10.0", "violations": "0",
             "trace_overhead_pct": "n/a"}]
    fresh = [{"twins": "64", "p50_ms": "10.2", "violations": "0",
              "trace_overhead_pct": "n/a"},            # within tolerance
             {"twins": "128", "p50_ms": "99.0", "violations": "9",
              "trace_overhead_pct": "n/a"}]            # no baseline -> skip
    reg, checked, skipped = cb.compare_rows(fresh, base, tolerance=0.25)
    assert checked == 1 and len(skipped) == 1 and reg == []


# --------------------------------------------------------------------- #
# non-interference: tracing must not change serving behaviour
# --------------------------------------------------------------------- #
def _run_server(ys, us, dt, tracer):
    cfg = TwinServerConfig(
        merinda=MerindaConfig(n=2, m=0, order=2, hidden=8, head_hidden=8,
                              n_active=4, dt=dt),
        max_twins=64, refit_slots=2, capacity=128, window=16, stride=8,
        windows_per_twin=4, steps_per_tick=1, deploy_after=2,
        min_residency=2, max_residency=6, guard=GuardConfig(window=16),
        seed=0)
    srv = TwinServer(cfg, tracer=tracer)
    chunk = 10
    reports = []
    for t in range(8):
        for i in range(64):
            srv.ingest(i, ys[i, t * chunk:(t + 1) * chunk],
                       us[i, t * chunk:(t + 1) * chunk])
        reports.append(srv.tick())
    return reports


def test_tracing_on_off_identical_tick_reports():
    """64-twin serving run twice — tracing off vs every-tick spans — must
    produce IDENTICAL TickReports (scheduling, losses, guard events); the
    tracer only measures, never steers."""
    sys_ = LotkaVolterra()
    tr = simulate_batch(sys_, jax.random.PRNGKey(1), batch=64, horizon=90,
                        noise_std=0.002)
    ys, us = np.asarray(tr.ys_noisy), np.asarray(tr.us)

    off = _run_server(ys, us, sys_.spec.dt, Tracer(enabled=False))
    tracer = Tracer(sample_every=1)
    on = _run_server(ys, us, sys_.spec.dt, tracer)

    assert len(tracer) > 0                        # spans actually recorded
    for a, b in zip(off, on):
        assert a.tick == b.tick
        assert a.admitted == b.admitted
        assert a.evicted == b.evicted
        assert a.released == b.released
        assert a.n_active == b.n_active
        assert a.n_twins == b.n_twins
        assert a.n_guarded == b.n_guarded
        assert [(e.kind, e.twin_id) for e in a.events] == \
               [(e.kind, e.twin_id) for e in b.events]
        if a.loss is None:
            assert b.loss is None
        else:
            assert a.loss == pytest.approx(b.loss, rel=1e-6)
    names = {e["name"] for e in tracer.to_chrome_trace()["traceEvents"]
             if e["ph"] == "X"}
    assert {"tick", "flush", "guard", "schedule", "refit"} <= names
