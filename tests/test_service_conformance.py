"""TwinService conformance: one scenario, three implementations, one truth.

The protocol (twin/service.py, docs/API.md) promises that `TwinServer`,
`ShardedTwinServer`, and `FederatedTwinServer` are interchangeable to a
caller.  This suite runs the canonical mission scenario — ingest healthy
telemetry, inflict mid-stream model damage, watch the guard escalate to
ALERT, repair, watch it de-escalate — against all three and asserts the
GUARD EVENT STREAMS ARE IDENTICAL: same (tick, twin, kind) transitions,
same scores.  Guard-only serving (deploy_after never reached) makes the
event stream a pure function of deployed thetas + telemetry, so any
divergence is a routing/ordering/wire bug, not noise.

The federated run covers the whole tentpole path in passing: worker spawn,
columnar `IngestBatch` framing, `Deploy` frames, tick fan-out/collect, and
event reconstruction from `TickDone` — if any of it bends the data, this
suite sees a different event stream.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.merinda import MerindaConfig
from repro.systems.lotka_volterra import LotkaVolterra
from repro.systems.simulate import simulate_batch
from repro.twin import (DegradationConfig, FederatedTwinConfig,
                        FederatedTwinServer, GuardConfig, ScenarioRefused,
                        ShardedTwinConfig, ShardedTwinServer, TwinServer,
                        TwinServerConfig, TwinService, conforms)

N_TWINS = 8
DAMAGED = {2, 5}
PER_TICK = 10
HEALTHY_TICKS = 4      # all models correct
DAMAGED_TICKS = 6      # twins in DAMAGED serve a negated theta
RECOVER_TICKS = 6      # repaired; guard must de-escalate
IMPLS = ("single", "sharded", "federated")


@pytest.fixture(scope="module")
def lv_world():
    sys_ = LotkaVolterra()
    tr = simulate_batch(sys_, jax.random.PRNGKey(0), batch=N_TWINS,
                        horizon=400, noise_std=0.002)
    return sys_, np.asarray(tr.ys_noisy)


def _base_cfg(sys_):
    """Guard-only serving: deploy_after is unreachable, so guard events are
    a deterministic function of (deployed theta, telemetry) — identical
    across implementations by contract."""
    return TwinServerConfig(
        merinda=MerindaConfig(n=2, m=0, order=2, hidden=8, head_hidden=8,
                              n_active=4, dt=sys_.spec.dt),
        max_twins=N_TWINS, refit_slots=2, capacity=128, window=16, stride=8,
        windows_per_twin=4, steps_per_tick=1, deploy_after=10 ** 6,
        min_residency=1, guard=GuardConfig(window=16))


def _make(impl, cfg):
    if impl == "single":
        return TwinServer(cfg)
    if impl == "sharded":
        return ShardedTwinServer(ShardedTwinConfig.uniform(cfg, 2))
    return FederatedTwinServer(FederatedTwinConfig.uniform(cfg, 2))


def _run_scenario(srv, sys_, ys, cfg):
    """ingest -> damage -> ALERT -> recover; returns the full event log."""
    true = np.asarray(sys_.true_theta(cfg.merinda.library))
    thetas = np.stack([true] * N_TWINS)
    for tid in range(N_TWINS):
        srv.register(tid)
    srv.deploy_many(list(range(N_TWINS)), thetas)
    events = []
    tick = 0

    def serve(n_ticks):
        nonlocal tick
        for _ in range(n_ticks):
            staged = srv.ingest_many(
                [(tid, ys[tid, tick * PER_TICK:(tick + 1) * PER_TICK])
                 for tid in range(N_TWINS)])
            assert staged == N_TWINS * PER_TICK
            rep = srv.tick()
            events.extend(rep.events)
            tick += 1

    serve(HEALTHY_TICKS)
    damaged = sorted(DAMAGED)
    srv.deploy_many(damaged, np.stack([-true] * len(damaged)))   # damage
    serve(DAMAGED_TICKS)
    srv.deploy_many(damaged, np.stack([true] * len(damaged)))    # repair
    serve(RECOVER_TICKS)
    srv.drain()
    return events


@pytest.fixture(scope="module")
def scenario_events(lv_world):
    """Event log per implementation (one federated boot for the module)."""
    sys_, ys = lv_world
    cfg = _base_cfg(sys_)
    out = {}
    for impl in IMPLS:
        srv = _make(impl, cfg)
        try:
            assert conforms(srv) == []
            assert isinstance(srv, TwinService)
            out[impl] = _run_scenario(srv, sys_, ys, cfg)
        finally:
            srv.close()
    return out


def _keyed(events):
    """Canonical order: multi-shard servers report per shard, the single
    server in ring order — same transitions, different within-tick order."""
    return sorted((e.tick, e.twin_id, e.kind, e.score) for e in events)


def test_scenario_emits_the_mission_sequence(scenario_events):
    """Sanity on ONE implementation before comparing them: damage drives
    exactly the damaged twins to ALERT (a negated theta is severe enough to
    skip the REFIT rung), repair de-escalates."""
    ev = scenario_events["single"]
    assert ev, "scenario produced no guard events at all"
    alerted = {e.twin_id for e in ev if e.kind == "ALERT"}
    assert alerted == DAMAGED
    assert {e.twin_id for e in ev} == DAMAGED     # healthy twins stay silent
    for tid in DAMAGED:
        kinds = [e.kind for e in ev if e.twin_id == tid]
        first_alert = kinds.index("ALERT")
        assert ("REFIT" in kinds[first_alert:]    # de-escalated after repair
                ), f"twin {tid} never came down from ALERT"
        assert all(e.tick > HEALTHY_TICKS for e in ev if e.twin_id == tid)


@pytest.mark.parametrize("impl", [i for i in IMPLS if i != "single"])
def test_guard_events_identical_across_implementations(scenario_events, impl):
    """THE conformance claim: the exact (tick, twin, kind) transition set —
    and the scores — survive sharding and the process/wire boundary."""
    ref = _keyed(scenario_events["single"])
    got = _keyed(scenario_events[impl])
    assert [(t, i, k) for t, i, k, _ in got] \
        == [(t, i, k) for t, i, k, _ in ref]
    np.testing.assert_allclose([s for *_, s in got], [s for *_, s in ref],
                               rtol=1e-6)


def test_sample_accounting_identical(lv_world):
    """`ingest_many` returns the same staged-sample count on every
    implementation, including the force path (protocol contract)."""
    sys_, ys = lv_world
    cfg = _base_cfg(sys_)
    batch = [(tid, ys[tid, :PER_TICK]) for tid in range(N_TWINS)]
    for impl in ("single", "sharded"):
        srv = _make(impl, cfg)
        try:
            assert srv.ingest_many(batch) == N_TWINS * PER_TICK
            assert srv.ingest_many(batch, force=True) == N_TWINS * PER_TICK
            srv.drain()
        finally:
            srv.close()


# --------------------------------------------------------------------- #
# scenario conformance: the what-if answer is part of the protocol
# --------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def scenario_answers(lv_world):
    """Identical deploy history + telemetry on each implementation, then
    the same what-if query — the answers (center, envelope, confidence)
    must match to f32 tolerance across the process/wire boundary."""
    sys_, ys = lv_world
    cfg = _base_cfg(sys_)
    true = np.asarray(sys_.true_theta(cfg.merinda.library))
    out = {}
    for impl in IMPLS:
        srv = _make(impl, cfg)
        try:
            for tid in range(N_TWINS):
                srv.register(tid)
            srv.deploy_many(list(range(N_TWINS)),
                            np.stack([true] * N_TWINS))
            for t in range(3):
                srv.ingest_many(
                    [(tid, ys[tid, t * PER_TICK:(t + 1) * PER_TICK])
                     for tid in range(N_TWINS)])
                srv.tick()
            # a second deploy widens the confidence ensemble identically
            srv.deploy_many(list(range(N_TWINS)),
                            np.stack([true * 1.05] * N_TWINS))
            srv.drain()
            out[impl] = {tid: srv.scenario(tid, 12, k=3)
                         for tid in (0, 1, 5)}
        finally:
            srv.close()
    return out


@pytest.mark.parametrize("impl", [i for i in IMPLS if i != "single"])
def test_scenario_results_identical_across_implementations(scenario_answers,
                                                           impl):
    for tid, ref in scenario_answers["single"].items():
        got = scenario_answers[impl][tid]
        assert (got.twin_id, got.horizon, got.requested_k, got.k,
                got.degraded_level) == (ref.twin_id, ref.horizon,
                                        ref.requested_k, ref.k,
                                        ref.degraded_level)
        for f in ("ys", "lo", "hi", "confidence"):
            np.testing.assert_allclose(getattr(got, f), getattr(ref, f),
                                       rtol=1e-6, atol=1e-7,
                                       err_msg=f"{impl} twin {tid} {f}")


def test_scenario_envelope_sane(scenario_answers):
    """The two-deploy history must produce a REAL envelope (not the
    degenerate single-theta band), on every implementation."""
    for impl, answers in scenario_answers.items():
        res = answers[0]
        assert (res.hi - res.lo).max() > 0, f"{impl}: degenerate envelope"
        assert (res.confidence < 1.0).all(), f"{impl}: confidence stuck at 1"
        assert (res.lo <= res.ys + 1e-6).all()
        assert (res.ys <= res.hi + 1e-6).all()


def _ladder_cfgs(sys_):
    """Shard 0 under an impossible deadline with fast escalation — its
    OWN ladder must shrink/refuse scenarios; shard 1 stays healthy."""
    base = _base_cfg(sys_)
    degraded = dataclasses.replace(
        base, deadline_s=1e-4,
        degradation=DegradationConfig(enabled=True, hold_ticks=1))
    return (degraded, base)


@pytest.mark.parametrize("impl", ["sharded", "federated"])
def test_scenario_degraded_ladder_is_per_shard(lv_world, impl):
    """Deadline pressure on ONE shard refuses ITS twins' scenarios while
    the other shard answers at full K — including across the federation
    wire, where `ScenarioRefused` must survive the ErrorMsg round trip."""
    sys_, ys = lv_world
    cfgs = _ladder_cfgs(sys_)
    srv = (ShardedTwinServer(ShardedTwinConfig(servers=cfgs))
           if impl == "sharded"
           else FederatedTwinServer(FederatedTwinConfig(servers=cfgs)))
    try:
        true = np.asarray(sys_.true_theta(cfgs[0].merinda.library))
        for tid in range(N_TWINS):
            srv.register(tid)
        srv.deploy_many(list(range(N_TWINS)), np.stack([true] * N_TWINS))
        for t in range(8):                 # every tick misses 0.1 ms: the
            srv.ingest_many(               # ladder climbs one level per tick
                [(tid, ys[tid, t * PER_TICK:(t + 1) * PER_TICK])
                 for tid in range(N_TWINS)])
            srv.tick()
        srv.drain()
        with pytest.raises(ScenarioRefused):
            srv.scenario(0, 10, k=4)       # twin 0 -> shard 0 (degraded)
        res = srv.scenario(1, 10, k=4)     # twin 1 -> shard 1 (healthy)
        assert res.k == res.requested_k == 4 and res.degraded_level == 0
    finally:
        srv.close()


def test_scenario_shrink_is_deterministic_across_shards(lv_world):
    """At shrink_level the SAME query gets the SAME reduced K on any
    shard (deterministic shrink, not sampling — the conformance property
    that keeps multi-shard answers reproducible)."""
    sys_, ys = lv_world
    cfg = _base_cfg(sys_)
    srv = ShardedTwinServer(ShardedTwinConfig.uniform(cfg, 2))
    try:
        true = np.asarray(sys_.true_theta(cfg.merinda.library))
        for tid in range(N_TWINS):
            srv.register(tid)
        srv.deploy_many(list(range(N_TWINS)), np.stack([true] * N_TWINS))
        srv.ingest_many([(tid, ys[tid, :PER_TICK])
                         for tid in range(N_TWINS)])
        srv.tick()
        srv.drain()
        for shard in srv.shards:
            shard._degradation.level = 2
        ks = {srv.scenario(tid, 10, k=8).k for tid in range(N_TWINS)}
        assert ks == {2}                   # 8 // degraded_shrink(4), always
    finally:
        srv.close()


def test_federation_config_deprecated_kwargs():
    """Satellite of the config consolidation: old `FederationConfig`
    kwargs keep working for one release, warning, and route to the new
    field names; mixing old and new spellings is an error."""
    from repro.twin import FederationConfig

    with pytest.warns(DeprecationWarning, match="min_slots"):
        cfg = FederationConfig(8, min_slots=2)
    assert cfg.min_shard_slots == 2
    with pytest.warns(DeprecationWarning):
        assert cfg.min_slots == 2          # deprecated read-alias
    with pytest.warns(DeprecationWarning, match="smooth"):
        cfg = FederationConfig(8, smooth=0.25)
    assert cfg.pressure_smooth == 0.25
    with pytest.raises(TypeError):
        FederationConfig(8, min_shard_slots=1, min_slots=1)


def test_conforms_reports_missing_surface():
    class Half:
        def ingest(self):
            pass

    missing = conforms(Half())
    assert "tick" in missing and "ingest_many" in missing
    assert "scenario" in missing          # the what-if surface is protocol
    assert "ingest" not in missing
