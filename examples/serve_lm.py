"""Serve a small LM with batched requests through the production engine.

    PYTHONPATH=src python examples/serve_lm.py [--arch qwen3-8b]

Uses the reduced (smoke) config so it runs on CPU; the serving path —
prefill into slots, fused batched decode, continuous admission — is the same
program the decode_* dry-run cells lower at production scale.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models.zoo import build
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=10)
    args = ap.parse_args()

    api = build(get_arch(args.arch).smoke)
    params = api.init(jax.random.PRNGKey(0))
    engine = ServeEngine(api, slots=args.slots, max_len=96)
    engine.load(params)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, api.cfg.vocab, size=8,
                                        dtype=np.int64).astype(np.int32),
                    max_new_tokens=16,
                    temperature=0.0 if i % 2 == 0 else 0.8)
            for i in range(args.requests)]

    t0 = time.perf_counter()
    done = engine.generate(reqs)
    dt = time.perf_counter() - t0
    n_tokens = sum(len(r.generated) for r in done)
    print(f"{args.arch} (smoke config), {args.slots} slots: "
          f"served {len(done)} requests / {n_tokens} tokens "
          f"in {dt:.2f}s ({n_tokens / dt:.1f} tok/s on 1 CPU core)")
    for r in done[:4]:
        print(f"  req {r.rid}: prompt {r.prompt[:6].tolist()}... -> "
              f"{r.generated}")


if __name__ == "__main__":
    main()
