"""Online digital twinning, end to end: 64 F-8 twins served live.

    PYTHONPATH=src python examples/online_twinning.py [--twins 64]

The paper's mission scenario as a running system.  A fleet of F-8 Crusaders
streams telemetry into `TwinServer`; every twin starts from an
offline-recovered model (the warm-start deployment path).  Mid-stream, a
subset of airframes suffers elevator damage — their true dynamics change
while the deployed models do not.  The divergence guard catches the mismatch
(REFIT, escalating to ALERT), the scheduler readmits the damaged twins into
refit slots, and the fleet re-recovers online — all while per-refresh latency
is accounted against the 1 s deadline (5x under the 5 s human-pilot
reaction time).
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.merinda import MerindaConfig
from repro.core.odeint import integrate
from repro.systems.f8_crusader import F8Crusader, _f8_rows
from repro.systems.simulate import simulate_batch
from repro.twin.monitor import GuardConfig
from repro.twin.server import TwinServer, TwinServerConfig

CHUNK = 8   # telemetry samples per twin per serving tick


class DamagedF8(F8Crusader):
    """F-8 with partial elevator loss: every input-dependent coefficient is
    scaled by `effectiveness` — the control surface answers, but weakly."""

    def __init__(self, effectiveness: float = 0.25):
        super().__init__()
        self.effectiveness = effectiveness

    def rows(self):
        rows = _f8_rows(0, self.spec.n, "u0")
        return [{k: (v * self.effectiveness if "u0" in k else v)
                 for k, v in row.items()} for row in rows]


def trim_neighborhood(system, y0_frac: float = 0.5, input_scale: float = 0.03):
    """Confine the scenario to the F-8's trim neighborhood: the open-loop
    cubic terms (3.846 y0^3) depart controlled flight in finite time for
    large angle-of-attack excursions, and a 7+ second open-loop stream from
    the full y0 range reliably finds that boundary for a few airframes."""
    system.spec = dataclasses.replace(
        system.spec,
        y0_low=tuple(v * y0_frac for v in system.spec.y0_low),
        y0_high=tuple(v * y0_frac for v in system.spec.y0_high),
        input_scale=input_scale)
    return system


def roll(system, y0s, us, noise_std, key):
    """Continue each twin's trajectory under `system` from its own state."""
    ys = jax.vmap(lambda y0, u: integrate(system.rhs, y0, u,
                                          system.spec.dt, substeps=10))(y0s, us)
    noise = noise_std * jax.random.normal(key, ys.shape) \
        * jnp.std(ys, axis=1, keepdims=True)
    return ys + noise


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--twins", type=int, default=64)
    ap.add_argument("--damaged", type=int, default=12,
                    help="airframes that lose elevator authority mid-stream")
    ap.add_argument("--pre-ticks", type=int, default=25)
    ap.add_argument("--post-ticks", type=int, default=45)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    nominal = trim_neighborhood(F8Crusader())
    damaged = trim_neighborhood(DamagedF8())
    n_tw = args.twins
    dmg_ids = list(range(args.damaged))

    # ---- telemetry: nominal phase, then a mid-stream dynamics switch ---- #
    t1 = CHUNK * args.pre_ticks
    t2 = CHUNK * args.post_ticks
    print(f"simulating {n_tw} airframes "
          f"({args.damaged} lose elevator authority at t={t1 * 0.01:.1f}s)...")
    tr = simulate_batch(nominal, key, batch=n_tw, horizon=t1, noise_std=0.002)
    k_u, k_n1, k_n2 = jax.random.split(jax.random.PRNGKey(1), 3)
    us2 = jnp.transpose(
        nominal.sample_inputs(k_u, t2, batch=(n_tw,)), (1, 0, 2))
    y_end = tr.ys[:, -1, :]
    ys2 = np.array(roll(nominal, y_end, us2, 0.002, k_n1))
    if dmg_ids:
        idx = jnp.asarray(dmg_ids, jnp.int32)
        ys2[dmg_ids] = np.asarray(
            roll(damaged, y_end[idx], us2[idx], 0.002, k_n2))
    ys = np.concatenate([np.asarray(tr.ys_noisy[:, :-1]), ys2[:, :-1]], 1)
    us = np.concatenate([np.asarray(tr.us), np.asarray(us2)], 1)

    # ---- the serving loop ---------------------------------------------- #
    cfg = TwinServerConfig(
        merinda=MerindaConfig(n=3, m=1, order=3, dt=nominal.spec.dt,
                              hidden=32, head_hidden=32, n_active=24),
        max_twins=n_tw, refit_slots=8, capacity=256,
        window=24, stride=8, windows_per_twin=8, steps_per_tick=2,
        sparsify_after=40, deploy_after=16, min_residency=4, max_residency=24,
        guard=GuardConfig(window=32), deadline_s=1.0)
    server = TwinServer(cfg)

    # warm start: every twin begins with its offline-recovered model
    theta0 = nominal.true_theta(server.fleet.model.lib)
    for i in range(n_tw):
        server.register(i)
        server.deploy(i, theta0)

    print(f"serving {n_tw} twins ({cfg.refit_slots} refit slots, "
          f"{CHUNK} samples/twin/tick, deadline {cfg.deadline_s:.0f} s)...")
    first_refit_tick = None
    for t in range(args.pre_ticks + args.post_ticks):
        lo = t * CHUNK
        for i in range(n_tw):
            server.ingest(i, ys[i, lo:lo + CHUNK], us[i, lo:lo + CHUNK])
        rep = server.tick()
        for ev in rep.events:
            tag = "<-- dynamics switch detected" \
                if first_refit_tick is None else ""
            if first_refit_tick is None:
                first_refit_tick = rep.tick
            print(f"  tick {rep.tick:3d}  [{ev.kind}] twin {ev.twin_id} "
                  f"score={ev.score:.3f} {tag}")
        if rep.admitted and first_refit_tick is not None:
            print(f"  tick {rep.tick:3d}  scheduler admitted "
                  f"{[tid for _, tid in rep.admitted]} into slots "
                  f"{[s for s, _ in rep.admitted]}")
        if t % 10 == 9:
            print(f"  tick {rep.tick:3d}  lat={rep.latency_s * 1e3:6.1f} ms "
                  f"deadline_met={rep.deadline_met} active={rep.n_active} "
                  f"loss={'-' if rep.loss is None else f'{rep.loss:.3f}'}")

    # ---- report --------------------------------------------------------- #
    s = server.latency_summary()
    div_d = (np.mean([server.twins[i].divergence for i in dmg_ids])
             if dmg_ids else float("nan"))
    div_h = np.mean([server.twins[i].divergence for i in range(n_tw)
                     if i not in dmg_ids])
    kinds = [e.kind for e in server.events]
    print(f"\n== per-refresh latency vs the {s['deadline_s']:.0f} s deadline ==")
    print(f"  p50 {s['p50_ms']:.1f} ms | p99 {s['p99_ms']:.1f} ms | "
          f"max {s['max_ms']:.1f} ms | violations {s['violations']}/{s['ticks']}"
          f" | {s['twin_refreshes_per_s']:.0f} twin refreshes/s")
    print(f"== divergence guard ==")
    print(f"  events: {kinds.count('REFIT')} REFIT, "
          f"{kinds.count('ALERT')} ALERT "
          f"(first at tick {first_refit_tick}; switch at tick "
          f"{args.pre_ticks + 1})")
    print(f"  mean divergence: damaged {div_d:.3f} vs healthy {div_h:.4f}")
    refit_set = {e.twin_id for e in server.events}
    print(f"  flagged twins: {sorted(refit_set)}")
    print(f"  (true damaged: {dmg_ids})")
    horizon = 50
    probe = dmg_ids[0] if dmg_ids else 0
    pred = server.predict(probe, horizon)
    print(f"== prediction ==\n  twin {probe} lookahead "
          f"{horizon * cfg.merinda.dt:.1f} s: y(T)="
          f"{np.asarray(pred[-1]).round(4).tolist()}")


if __name__ == "__main__":
    main()
