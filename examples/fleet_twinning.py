"""Fleet digital twinning: many concurrent MERINDA twins on one device mesh.

    PYTHONPATH=src python examples/fleet_twinning.py [--fleet 16]

The paper's deployment scenario scaled out: every tracked aircraft gets a
continuously-refit digital twin.  One fused train step advances EVERY twin
(vmapped over the fleet axis; on the production mesh the fleet axis shards
over ('pod','data') — see launch/dryrun.py's merinda fleet cell).  Prints
per-refresh latency against the paper's 5-second human-pilot baseline.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.fleet import FleetConfig, FleetMerinda
from repro.core.merinda import MerindaConfig
from repro.data.pipeline import make_windows
from repro.systems.f8_crusader import F8Crusader
from repro.systems.simulate import simulate_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fleet", type=int, default=16)
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    system = F8Crusader()
    print(f"simulating {args.fleet} aircraft...")
    trace = simulate_batch(system, key, batch=args.fleet, noise_std=0.005)
    y_win, u_win = make_windows(trace.ys_noisy, trace.us, window=24, stride=8)
    # regroup windows per twin: [F, S_B, k+1, n]
    S_B = y_win.shape[0] // args.fleet
    y_win = y_win.reshape(args.fleet, S_B, *y_win.shape[1:])[:, :32]
    u_win = u_win.reshape(args.fleet, S_B, *u_win.shape[1:])[:, :32]

    mcfg = MerindaConfig(n=system.spec.n, m=system.spec.m, order=3,
                         dt=system.spec.dt, hidden=64, n_active=24)
    fleet = FleetMerinda(FleetConfig(merinda=mcfg, fleet=args.fleet))
    state = fleet.init(key)

    print(f"refitting {args.fleet} twins concurrently "
          f"({args.steps} fused steps)...")
    state, loss = fleet.train_step(state, y_win, u_win)  # compile
    t0 = time.perf_counter()
    for _ in range(args.steps - 1):
        state, loss = fleet.train_step(state, y_win, u_win)
    jax.block_until_ready(loss)
    dt = (time.perf_counter() - t0) / (args.steps - 1)
    print(f"  mean fused step: {dt * 1e3:.1f} ms for {args.fleet} twins "
          f"({dt * 1e3 / args.fleet:.2f} ms/twin on 1 CPU core)")
    print(f"  vs 5 s human-pilot reaction baseline: "
          f"{5.0 / dt:.0f}x headroom per refresh")

    thetas = fleet.recover_all(state, y_win, u_win)
    print(f"  recovered fleet models: theta {tuple(thetas.shape)}, "
          f"mean |theta| {float(jnp.mean(jnp.abs(thetas))):.3f}")


if __name__ == "__main__":
    main()
