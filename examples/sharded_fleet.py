"""Sharded serving of a heterogeneous 1k+ twin fleet, end to end.

    PYTHONPATH=src python examples/sharded_fleet.py [--per-family 384]

Three FAMILIES of tracked objects live in one `ShardedTwinServer`, one shard
per family — each shard owns its own telemetry rings, refit-slot pool, theta
store, and scheduler, with its own model configuration (state dims differ!):

  shard 0: F-8 Crusader airframes   (n=3, m=1, order 3, dt 10 ms)
  shard 1: Van der Pol oscillators  (n=2, m=1, order 3, dt 20 ms)
  shard 2: Lotka-Volterra systems   (n=2, m=0, order 2, dt 20 ms)

Every twin warm-starts from its family's offline-recovered model.  A subset
of F-8s flies with DAMAGED elevators (their true dynamics differ from the
deployed model): the budgeted guard rotation flags them, the F-8 shard's
aggregate pressure rises, and the slot FEDERATION migrates refit grants from
the quiet families toward the emergency — watch the `grants` column move.

Ingestion runs async (background staging flush per shard) and the guard
scores a rotating budget per tick, so the tick cost is bounded regardless of
fleet size — the same architecture benchmarks/online_scale.py pushes to 10k.
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.core.merinda import MerindaConfig
from repro.systems.f8_crusader import F8Crusader, _f8_rows
from repro.systems.lotka_volterra import LotkaVolterra
from repro.systems.simulate import simulate_batch
from repro.systems.van_der_pol import VanDerPol
from repro.twin.monitor import GuardConfig
from repro.twin.server import TwinServerConfig
from repro.twin.sharded import ShardedTwinConfig, ShardedTwinServer

CHUNK = 8   # telemetry samples per twin per serving tick


class DamagedF8(F8Crusader):
    """F-8 with partial elevator loss (see examples/online_twinning.py)."""

    def __init__(self, effectiveness: float = 0.25):
        super().__init__()
        self.effectiveness = effectiveness

    def rows(self):
        rows = _f8_rows(0, self.spec.n, "u0")
        return [{k: (v * self.effectiveness if "u0" in k else v)
                 for k, v in row.items()} for row in rows]


def trim(system, y0_frac: float = 0.5, input_scale: float = 0.03):
    """Confine the F-8 to its trim neighborhood (open-loop cubic terms
    depart controlled flight for large excursions; see online_twinning)."""
    system.spec = dataclasses.replace(
        system.spec,
        y0_low=tuple(v * y0_frac for v in system.spec.y0_low),
        y0_high=tuple(v * y0_frac for v in system.spec.y0_high),
        input_scale=input_scale)
    return system


def family_cfg(system, n_active: int, seed: int) -> TwinServerConfig:
    return TwinServerConfig(
        merinda=MerindaConfig(n=system.spec.n, m=system.spec.m,
                              order=system.spec.order, dt=system.spec.dt,
                              hidden=16, head_hidden=16, n_active=n_active),
        max_twins=4096, refit_slots=8,
        capacity=64, window=16, stride=8, windows_per_twin=4,
        steps_per_tick=1, sparsify_after=30, deploy_after=8,
        min_residency=4, max_residency=16,
        guard=GuardConfig(window=24), guard_budget=96,
        async_ingest=True, seed=seed)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--per-family", type=int, default=384)
    ap.add_argument("--damaged", type=int, default=16,
                    help="F-8s flying with degraded elevator authority")
    ap.add_argument("--ticks", type=int, default=40)
    ap.add_argument("--warmup", type=int, default=20,
                    help="ticks excluded from the latency report (jit "
                         "compile, slot fill, first promote compilation)")
    args = ap.parse_args()

    nf = args.per_family
    families = [("f8", trim(F8Crusader()), 24),
                ("vdp", VanDerPol(), 12),
                ("lv", LotkaVolterra(), 6)]
    horizon = CHUNK * args.ticks + 1

    # ---- telemetry: one simulated batch per family; the damaged F-8s fly
    # DamagedF8 dynamics while serving the nominal model -------------------
    print(f"simulating {3 * nf} twins "
          f"({args.damaged} F-8s have damaged elevators)...")
    telemetry = []
    for i, (name, system, _) in enumerate(families):
        tr = simulate_batch(system, jax.random.PRNGKey(i), batch=nf,
                            horizon=horizon, noise_std=0.002)
        telemetry.append([np.array(tr.ys_noisy), np.array(tr.us)])
    dmg = trim(DamagedF8())
    tr = simulate_batch(dmg, jax.random.PRNGKey(0), batch=args.damaged,
                        horizon=horizon, noise_std=0.002)
    telemetry[0][0][:args.damaged] = np.asarray(tr.ys_noisy)
    telemetry[0][1][:args.damaged] = np.asarray(tr.us)

    # ---- the sharded server: one shard per family, global slot budget ----
    cfg = ShardedTwinConfig(
        servers=tuple(family_cfg(system, n_active, seed=i)
                      for i, (_, system, n_active) in enumerate(families)),
        total_slots=12, min_shard_slots=1, rebalance_every=4,
        pressure_smooth=0.5)
    server = ShardedTwinServer(cfg)

    # family routing: twin id i*nf + k -> shard i; warm-start every family
    # from its offline-recovered model in one fused scatter per shard
    for i, (name, system, _) in enumerate(families):
        ids = [i * nf + k for k in range(nf)]
        for tid in ids:
            server.register(tid, shard=i)
        theta0 = system.true_theta(server.shards[i].fleet.model.lib)
        server.deploy_many(ids, theta0)

    print(f"serving {3 * nf} twins on {server.n_shards} shards "
          f"(global budget {cfg.total_slots} refit slots, guard budget "
          f"{cfg.servers[0].guard_budget}/shard/tick)...")
    flagged: set[int] = set()
    for t in range(args.ticks):
        lo = t * CHUNK
        for i in range(3):
            ys, us = telemetry[i]
            for k in range(nf):
                server.ingest(i * nf + k, ys[k, lo:lo + CHUNK],
                              us[k, lo:lo + CHUNK])
        rep = server.tick()
        flagged |= {e.twin_id for e in rep.events}
        if rep.tick == args.warmup:
            server.reset_latency_stats()
        if t % 8 == 7 or rep.tick == 1:
            print(f"  tick {rep.tick:3d}  lat={rep.latency_s * 1e3:6.1f} ms"
                  f"  grants={rep.grants}  active={rep.n_active}"
                  f"  guarded={rep.n_guarded}  events={len(rep.events)}")
    server.drain()

    # ---- report ---------------------------------------------------------- #
    s = server.latency_summary()
    st = server.stage_summary()
    dmg_ids = set(range(args.damaged))
    f8 = server.shards[0]
    div_d = np.mean([f8.twins[i].divergence for i in dmg_ids])
    div_h = np.mean([f8.twins[i].divergence for i in range(nf)
                     if i not in dmg_ids])
    print(f"\n== per-refresh latency vs the {s['deadline_s']:.0f} s deadline ==")
    print(f"  p50 {s['p50_ms']:.1f} ms | p99 {s['p99_ms']:.1f} ms | "
          f"max {s['max_ms']:.1f} ms | violations {s['violations']}/"
          f"{s['ticks']} | {s['twin_refreshes_per_s']:.0f} twin refreshes/s")
    print(f"  stage cost/tick: flush {st['flush_ms']:.1f} | guard "
          f"{st['guard_ms']:.1f} | schedule {st['schedule_ms']:.1f} | "
          f"refit {st['refit_ms']:.1f} ms")
    print("== federation ==")
    print(f"  final grants {server.grants} (f8/vdp/lv), pressures "
          f"{[round(p, 1) for p in server.federation.pressures]}")
    print("== divergence guard (F-8 shard) ==")
    print(f"  mean divergence: damaged {div_d:.3f} vs healthy {div_h:.4f}")
    caught = sorted(i for i in flagged if i in dmg_ids)
    print(f"  flagged {len(flagged)} twins, {len(caught)}/{args.damaged} "
          f"true damaged among them")
    probe = 0
    pred = server.predict(probe, 50)
    print(f"== prediction ==\n  twin {probe} lookahead "
          f"{50 * families[0][1].spec.dt:.1f} s: "
          f"y(T)={np.asarray(pred[-1]).round(4).tolist()}")
    server.close()


if __name__ == "__main__":
    main()
