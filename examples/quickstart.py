"""Quickstart: recover the Lotka-Volterra equations with MERINDA in ~2 min.

    PYTHONPATH=src python examples/quickstart.py

Simulates predator-prey traces, trains the GRU-flow model-recovery network
(the paper's architecture: GRU -> pruned dense head -> RK4 ODE loss), and
prints the recovered governing equations next to the ground truth.
"""
import jax

from repro.core.merinda import Merinda, MerindaConfig
from repro.core.trainer import fit
from repro.data.pipeline import WindowDataset
from repro.systems.lotka_volterra import LotkaVolterra
from repro.systems.simulate import simulate_batch


def main():
    key = jax.random.PRNGKey(0)
    system = LotkaVolterra()
    print("simulating traces...")
    trace = simulate_batch(system, key, batch=4, horizon=250, noise_std=0.01)
    ds = WindowDataset.from_trace(trace.ys_noisy, trace.us, trace.dt,
                                  window=40, stride=12)

    true_theta = system.true_theta()
    n_active = int((abs(true_theta) > 0).sum())
    model = Merinda(MerindaConfig(n=2, m=0, order=2, dt=trace.dt,
                                  hidden=64, n_active=n_active))
    params = model.init(key, model.norm_stats(ds.y_win, ds.u_win))

    print("training MERINDA (400 steps)...")
    result = fit(model, params, ds.batches(key, 64, epochs=10_000),
                 steps=400, lr=3e-3, log_every=100)

    theta = model.recover(result.params, ds.y_win, ds.u_win)
    mse = float(model.reconstruction_mse(theta, ds.y_win, ds.u_win))
    print(f"\nreconstruction MSE: {mse:.4f}")
    print("\nrecovered model:")
    for eq, terms in model.lib.coeff_dict(theta).items():
        rhs = " + ".join(f"{c:+.3f}*{t}" for t, c in terms.items())
        print(f"  {eq} = {rhs}")
    print("\nground truth:")
    for eq, terms in model.lib.coeff_dict(true_theta).items():
        rhs = " + ".join(f"{c:+.3f}*{t}" for t, c in terms.items())
        print(f"  {eq} = {rhs}")


if __name__ == "__main__":
    main()
