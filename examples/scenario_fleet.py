"""What-if scenario serving over a mixed-fidelity heterogeneous fleet.

    PYTHONPATH=src python examples/scenario_fleet.py [--per-family 64]

Five FAMILIES of tracked objects live in one `ShardedTwinServer`, one shard
per family — the full serving zoo, mixing flight dynamics with process
models of very different stiffness and fidelity:

  shard 0: F-8 Crusader airframes      (n=3, m=1, order 3, dt 10 ms)
  shard 1: quadrotors (near hover)     (n=3, m=1, order 3, dt 10 ms)
  shard 2: pathogen outbreaks          (n=2, m=1, order 2, dt 20 ms)
  shard 3: battery thermal models      (n=2, m=1, order 2, dt 50 ms)
  shard 4: grid-frequency areas        (n=2, m=1, order 2, dt 20 ms)

After a short serving warmup the example asks each family its natural
WHAT-IF question through `server.scenario()` — K counterfactual input
sequences rolled forward in one fused ensemble call, answered with
confidence bounds from the recent-theta history:

  F-8:      "elevator authority fades 30% over the next 2 s"
  quad:     "differential thrust saturates high for 1 s"
  pathogen: "treatment stops vs doubles"
  battery:  "cell pulls 0 / 1x / 2x current for a minute"
  grid:     "a feeder trips: load steps 0.1 / 0.2 / 0.3 pu"

The point of the demo: one service call shape answers operator questions
across every physics family, and the confidence column tells you which
answers to trust (families whose online refits thrash report wider bands).
"""
import argparse

import jax
import numpy as np

from repro.core.merinda import MerindaConfig
from repro.systems.f8_crusader import F8Crusader
from repro.systems.grid_frequency import GridFrequency
from repro.systems.pathogen import PathogenicAttack
from repro.systems.quadrotor import Quadrotor
from repro.systems.simulate import simulate_batch
from repro.systems.thermal_battery import ThermalBattery
from repro.twin.monitor import GuardConfig
from repro.twin.scenario import ScenarioConfig
from repro.twin.server import TwinServerConfig
from repro.twin.sharded import ShardedTwinConfig, ShardedTwinServer

CHUNK = 8   # telemetry samples per twin per serving tick


def trim_f8(system, y0_frac: float = 0.5, input_scale: float = 0.03):
    """Confine the F-8 to its trim neighborhood (see sharded_fleet.py)."""
    import dataclasses
    system.spec = dataclasses.replace(
        system.spec,
        y0_low=tuple(v * y0_frac for v in system.spec.y0_low),
        y0_high=tuple(v * y0_frac for v in system.spec.y0_high),
        input_scale=input_scale)
    return system


def family_cfg(system, n_active: int, seed: int) -> TwinServerConfig:
    return TwinServerConfig(
        merinda=MerindaConfig(n=system.spec.n, m=system.spec.m,
                              order=system.spec.order, dt=system.spec.dt,
                              hidden=16, head_hidden=16, n_active=n_active),
        max_twins=1024, refit_slots=4,
        capacity=64, window=16, stride=8, windows_per_twin=4,
        steps_per_tick=1, sparsify_after=30, deploy_after=8,
        min_residency=4, max_residency=16,
        guard=GuardConfig(window=24), guard_budget=32,
        scenario=ScenarioConfig(max_k=8, ensemble=4),
        async_ingest=True, seed=seed)


def ramp(scale, horizon, m, frac):
    """One input channel ramping linearly to `frac`*scale over the horizon."""
    us = np.zeros((horizon, m), np.float32)
    us[:, 0] = scale * frac * np.linspace(0.0, 1.0, horizon)
    return us


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--per-family", type=int, default=64)
    ap.add_argument("--ticks", type=int, default=24)
    ap.add_argument("--horizon", type=int, default=40,
                    help="what-if lookahead steps")
    args = ap.parse_args()

    nf = args.per_family
    families = [
        ("f8", trim_f8(F8Crusader()), 24,
         "elevator fades 30% over the lookahead"),
        ("quadrotor", Quadrotor(), 8,
         "differential thrust ramps to saturation"),
        ("pathogen", PathogenicAttack(), 8,
         "treatment stops vs doubles"),
        ("battery", ThermalBattery(), 8,
         "cell current 0x / 1x / 2x for the lookahead"),
        ("grid", GridFrequency(), 8,
         "feeder trip: load steps 0.1 / 0.2 / 0.3 pu"),
    ]
    horizon = CHUNK * args.ticks + 1

    print(f"simulating {len(families) * nf} twins in {len(families)} "
          "families...")
    telemetry = []
    for i, (name, system, _, _) in enumerate(families):
        tr = simulate_batch(system, jax.random.PRNGKey(i), batch=nf,
                            horizon=horizon, noise_std=0.002)
        telemetry.append((np.asarray(tr.ys_noisy), np.asarray(tr.us)))

    cfg = ShardedTwinConfig(
        servers=tuple(family_cfg(system, n_active, seed=i)
                      for i, (_, system, n_active, _) in enumerate(families)),
        total_slots=12, min_shard_slots=1, rebalance_every=4,
        pressure_smooth=0.5)
    server = ShardedTwinServer(cfg)

    for i, (name, system, _, _) in enumerate(families):
        ids = [i * nf + k for k in range(nf)]
        for tid in ids:
            server.register(tid, shard=i)
        theta0 = system.true_theta(server.shards[i].fleet.model.lib)
        server.deploy_many(ids, theta0)

    print(f"serving {len(families) * nf} twins on {server.n_shards} "
          "shards...")
    for t in range(args.ticks):
        lo = t * CHUNK
        for i in range(len(families)):
            ys, us = telemetry[i]
            server.ingest_many(
                [(i * nf + k, ys[k, lo:lo + CHUNK], us[k, lo:lo + CHUNK])
                 for k in range(nf)])
        rep = server.tick()
        if t % 8 == 7 or rep.tick == 1:
            print(f"  tick {rep.tick:3d}  lat={rep.latency_s * 1e3:6.1f} ms"
                  f"  active={rep.n_active}  events={len(rep.events)}")
    server.drain()

    # ---- one what-if per family ----------------------------------------- #
    H = args.horizon
    print(f"\n== what-if scenarios (horizon {H} steps, K counterfactuals, "
          "ensemble confidence) ==")
    for i, (name, system, _, question) in enumerate(families):
        m, scale = system.spec.m, system.spec.input_scale
        if name == "battery":
            us = np.stack([np.full((H, m), f * scale, np.float32)
                           for f in (0.0, 1.0, 2.0)])
        elif name == "grid":
            us = np.stack([np.full((H, m), f, np.float32)
                           for f in (0.1, 0.2, 0.3)])
        elif name == "pathogen":
            us = np.stack([np.zeros((H, m), np.float32),
                           np.full((H, m), 2.0 * scale, np.float32)])
        else:
            us = np.stack([ramp(scale, H, m, f) for f in (0.3, 0.6, 1.0)])
        res = server.scenario(i * nf, H, us)
        width = np.mean(res.hi - res.lo, axis=(1, 2))
        yT = res.ys[:, -1, :]
        print(f"  {name:10s} {question}")
        for j in range(res.k):
            print(f"     K={j}: y(T)={np.round(yT[j], 3).tolist()}  "
                  f"band={width[j]:.4f}  conf={res.confidence[j]:.3f}")

    s = server.latency_summary()
    print(f"\n== serving health ==\n  p50 {s['p50_ms']:.1f} ms | "
          f"p99 {s['p99_ms']:.1f} ms | violations {s['violations']}/"
          f"{s['ticks']}")
    server.close()


if __name__ == "__main__":
    main()
