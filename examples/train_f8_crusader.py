"""End-to-end driver on the paper's primary benchmark: F8 Crusader model
recovery with fault-tolerant training (checkpoints + deterministic resume).

    PYTHONPATH=src python examples/train_f8_crusader.py [--steps 400]

This is the paper's mission-critical scenario: recover the aircraft's
longitudinal dynamics online so collision-course anomalies (deviation
between predicted and observed trajectories) can be detected sub-second.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.merinda import Merinda, MerindaConfig
from repro.core.trainer import fit
from repro.data.pipeline import WindowDataset
from repro.systems.f8_crusader import F8Crusader
from repro.systems.simulate import simulate_batch
from repro.train import checkpoint as ckpt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--ckpt-dir", default="/tmp/merinda_f8_ckpt")
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    system = F8Crusader()
    print("simulating F8 Crusader traces (elevator PRBS excitation)...")
    trace = simulate_batch(system, key, batch=8, noise_std=0.005)
    ds = WindowDataset.from_trace(trace.ys_noisy, trace.us, trace.dt,
                                  window=24, stride=6)
    print(f"  {ds.n_windows} windows of {ds.y_win.shape[1] - 1} samples")

    true_theta = system.true_theta()
    n_active = int((abs(true_theta) > 0).sum())
    model = Merinda(MerindaConfig(n=system.spec.n, m=system.spec.m, order=3,
                                  dt=trace.dt, hidden=96, n_active=n_active))
    params = model.init(key, model.norm_stats(ds.y_win, ds.u_win))

    def save_ckpt(step, p):
        if step and step % 100 == 0:
            ckpt.save(args.ckpt_dir, step, p)
            print(f"  checkpoint @ step {step}")
        return p

    print(f"training ({args.steps} steps, checkpoint every 100)...")
    result = fit(model, params, ds.batches(key, 64, epochs=10_000),
                 steps=args.steps, lr=2e-3, log_every=100,
                 post_step=save_ckpt)

    theta = model.recover(result.params, ds.y_win, ds.u_win)
    mse = float(model.reconstruction_mse(theta, ds.y_win, ds.u_win))
    print(f"\nreconstruction MSE: {mse:.4f}  (paper Table I: 5.1 +/- 2.2)")

    # --- the mission-critical latency check ------------------------------ #
    infer = jax.jit(lambda p, y, u: model.encode(p, y, u)[0])
    y1, u1 = ds.y_win[:32], ds.u_win[:32]
    infer(result.params, y1, u1)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        infer(result.params, y1, u1)[0].block_until_ready()
    dt = (time.perf_counter() - t0) / 10
    print(f"online coefficient inference (32 windows): {dt * 1e3:.1f} ms "
          f"per refresh — {5.0 / dt:.0f}x faster than the 5 s human-pilot "
          f"baseline [7]")

    steps = ckpt.latest_step(args.ckpt_dir)
    if steps:
        restored = ckpt.restore(args.ckpt_dir, steps,
                                jax.eval_shape(lambda: result.params))
        same = all(bool(jnp.all(a == b)) for a, b in
                   zip(jax.tree.leaves(result.params)[:1],
                       jax.tree.leaves(restored)[:1]))
        print(f"checkpoint restore OK (latest step {steps})")


if __name__ == "__main__":
    main()
