#!/usr/bin/env python3
"""Bench-regression gate: fresh bench_out/*.csv vs checked-in baselines.

The perf trajectory of the serving stack used to live in commit messages;
this makes it a CI signal.  Every CSV in `bench_out/baselines/` is a
checked-in reference run; after a benchmark pass, this tool joins fresh rows
to baseline rows on their CONFIG columns (twins/shards/backend/…, i.e.
everything that is not a measurement) and flags:

  * latency regressions — p50_ms / p99_ms / fwd_ms / grad_ms above
    baseline * (1 + tolerance), default tolerance 25% (CI machines are
    noisy; the gate is for trajectory, not microbenchmarking);
  * violation regressions — `violations` above the baseline count (deadline
    misses are the paper's SLO; any increase is a finding).

Rows with no baseline match (new configs) and non-numeric cells (`n/a`)
are skipped and reported, never failed — growing the sweep must not break
the gate.  Run from the repo root:

    python tools/check_bench.py                 # strict: exit 1 on regression
    python tools/check_bench.py --warn-only     # report, exit 0
    python tools/check_bench.py --update        # bless fresh runs as baseline

CI runs the STRICT mode against its smoke rows (tiny configs are stable
enough to gate on); use `--warn-only` for full local sweeps on noisy
machines where the trajectory report is wanted without the exit code.

Stdlib only (runs in the docs/bench CI lanes without installing the repo).
"""
from __future__ import annotations

import argparse
import csv
import shutil
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
FRESH_DIR = ROOT / "bench_out"
BASELINE_DIR = ROOT / "bench_out" / "baselines"

# measurement columns: never part of the row-join identity
LATENCY_COLS = ("p50_ms", "p99_ms", "fwd_ms", "grad_ms",
                "plan_p50_ms", "plan_p99_ms", "tick_p50_ms")
COUNT_COLS = ("violations",)
# quality columns: DECREASE beyond tolerance is the regression (recovery
# term-selection F1 in recovery_quality.csv — tracked, warn-only gated)
QUALITY_COLS = ("f1",)
NOISY_COLS = ("max_ms", "twin_refreshes_per_s", "flush_ms", "guard_ms",
              "schedule_ms", "refit_ms", "deployed",
              "dropped_samples", "flush_overflows", "trace_overhead_pct",
              "pressure_ms", "pressure", "turnover",
              # online_chaos.csv recovery columns: counts depend on where
              # the injected schedule lands relative to measured ticks —
              # reported, not gated (the chaos TESTS gate the semantics)
              "degraded_ticks", "recovery_ticks", "replayed_samples",
              "lost_samples", "shard_deaths", "ckpt_overhead_pct",
              # online_federated.csv: the federated/in-process throughput
              # ratio depends on host core count (HOST-LIMITED on starved
              # machines) — reported, never gated
              "speedup", "grants_migrated",
              # scenarios.csv: what-if throughput is host-load sensitive;
              # the gated signals are its latency/violation columns
              "scenarios_per_s", "shrunk", "refused",
              # recovery_quality.csv companions to the gated f1 column
              "precision", "recall", "mse")
# NOTE: "ticks" stays in the identity — it separates smoke (6) / quick (12)
# / full (24) rows of the same sweep point, which have different baselines.
MEASURE_COLS = frozenset(LATENCY_COLS + COUNT_COLS + QUALITY_COLS
                         + NOISY_COLS)

# fault-injection tables are gated WARN-ONLY even in strict mode: the
# kill-shard row's tail latency is the restore tick (disk + replay bound,
# machine-dependent), so its trajectory is reported but never exit-1s CI.
# The chaos TESTS (pytest -m chaos) are the hard gate on recovery semantics.
# online_federated.csv is warn-only for its first release: worker-process
# boot and IPC latency vary with CI host load far more than in-process
# ticks do; tests/test_federation.py is the hard gate on the semantics.
# recovery_quality.csv is warn-only by design: it exists to make recovery
# accuracy (incl. the Lotka-Volterra identifiability xfail) a TRACKED
# number; promoting it to a hard gate is the ROADMAP's recovery-quality
# item, not this file's.
WARN_ONLY_FILES = frozenset({"online_chaos.csv", "online_federated.csv",
                             "recovery_quality.csv"})


def load_csv(path: Path) -> list[dict]:
    with open(path, newline="") as f:
        return list(csv.DictReader(f))


def _num(cell) -> float | None:
    try:
        return float(cell)
    except (TypeError, ValueError):
        return None


def _identity(row: dict) -> tuple:
    return tuple(sorted((k, v) for k, v in row.items()
                        if k not in MEASURE_COLS))


def compare_rows(fresh: list[dict], base: list[dict], *,
                 tolerance: float) -> tuple[list[str], int, list[str]]:
    """Join fresh rows to baseline rows by config identity and compare.

    Returns (regressions, rows_checked, skipped_notes).  A fresh row whose
    identity has no baseline counterpart is skipped (new config); baseline
    rows missing from the fresh run are skipped too (narrower sweep, e.g.
    CI smoke vs a full local run).
    """
    by_id = {_identity(r): r for r in base}
    regressions: list[str] = []
    skipped: list[str] = []
    checked = 0
    for row in fresh:
        ref = by_id.get(_identity(row))
        ident = ",".join(f"{k}={v}" for k, v in _identity(row))
        if ref is None:
            skipped.append(f"no baseline for [{ident}]")
            continue
        checked += 1
        for col in LATENCY_COLS:
            new, old = _num(row.get(col)), _num(ref.get(col))
            if new is None or old is None or old <= 0:
                continue
            if new > old * (1.0 + tolerance):
                regressions.append(
                    f"[{ident}] {col}: {new:.2f} vs baseline {old:.2f} "
                    f"(+{(new / old - 1) * 100:.0f}% > "
                    f"{tolerance * 100:.0f}% tolerance)")
        for col in COUNT_COLS:
            new, old = _num(row.get(col)), _num(ref.get(col))
            if new is None or old is None:
                continue
            if new > old:
                regressions.append(
                    f"[{ident}] {col}: {new:.0f} vs baseline {old:.0f} "
                    f"(deadline misses must not increase)")
        for col in QUALITY_COLS:
            new, old = _num(row.get(col)), _num(ref.get(col))
            if new is None or old is None or old <= 0:
                continue
            if new < old * (1.0 - tolerance):
                regressions.append(
                    f"[{ident}] {col}: {new:.3f} vs baseline {old:.3f} "
                    f"(-{(1 - new / old) * 100:.0f}% > "
                    f"{tolerance * 100:.0f}% tolerance)")
    return regressions, checked, skipped


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh-dir", type=Path, default=FRESH_DIR)
    ap.add_argument("--baseline-dir", type=Path, default=BASELINE_DIR)
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed relative latency growth (default 0.25)")
    ap.add_argument("--warn-only", action="store_true",
                    help="report regressions but exit 0 (CI smoke lane)")
    ap.add_argument("--update", action="store_true",
                    help="copy fresh CSVs over the baselines and exit")
    args = ap.parse_args(argv)

    if args.update:
        args.baseline_dir.mkdir(parents=True, exist_ok=True)
        for path in sorted(args.fresh_dir.glob("*.csv")):
            shutil.copy2(path, args.baseline_dir / path.name)
            print(f"[check_bench] blessed {path.name}")
        return 0

    if not args.baseline_dir.is_dir():
        print(f"[check_bench] no baseline dir {args.baseline_dir}; "
              "run with --update to create one")
        return 0 if args.warn_only else 1

    total_reg: list[str] = []
    total_warn: list[str] = []
    total_checked = 0
    for base_path in sorted(args.baseline_dir.glob("*.csv")):
        fresh_path = args.fresh_dir / base_path.name
        if not fresh_path.exists():
            print(f"[check_bench] {base_path.name}: no fresh run, skipped")
            continue
        reg, checked, skipped = compare_rows(
            load_csv(fresh_path), load_csv(base_path),
            tolerance=args.tolerance)
        total_checked += checked
        if base_path.name in WARN_ONLY_FILES:
            total_warn.extend(f"{base_path.name}: {r}" for r in reg)
        else:
            total_reg.extend(f"{base_path.name}: {r}" for r in reg)
        warn_note = " (warn-only file)" if base_path.name in WARN_ONLY_FILES \
            else ""
        note = f"; {len(skipped)} unmatched" if skipped else ""
        print(f"[check_bench] {base_path.name}: {checked} rows checked, "
              f"{len(reg)} regressions{warn_note}{note}")
        for s in skipped:
            print(f"  (skip) {s}")
    for r in total_warn:
        print(f"WARNING {r}")
    for r in total_reg:
        print(f"REGRESSION {r}")
    verdict = ("ok" if not total_reg else
               f"{len(total_reg)} regressions"
               + (" (warn-only)" if args.warn_only else ""))
    print(f"[check_bench] {total_checked} rows vs baselines — {verdict}")
    return 0 if (args.warn_only or not total_reg) else 1


if __name__ == "__main__":
    sys.exit(main())
