#!/usr/bin/env python3
"""Fail on broken relative links in README.md and docs/*.md (stdlib only).

Checks every markdown inline link `[text](target)` whose target is not an
external URL (http/https/mailto) or a pure in-page anchor.  Relative targets
are resolved against the file they appear in; a `#fragment` suffix is
stripped before the existence check (anchor names are not validated —
file-level breakage is what bites in reviews).  Exits 1 listing every broken
link.  Run from the repo root:

    python tools/check_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP = ("http://", "https://", "mailto:")


def check(md: Path) -> list[str]:
    errors = []
    for lineno, line in enumerate(md.read_text().splitlines(), 1):
        for target in LINK.findall(line):
            if target.startswith(SKIP) or target.startswith("#"):
                continue
            path = target.split("#", 1)[0]
            if path.startswith("/"):
                # GitHub-style root-absolute link: resolve against the repo
                # root, not the filesystem root
                resolved = (ROOT / path.lstrip("/")).resolve()
            else:
                resolved = (md.parent / path).resolve()
            if not resolved.is_relative_to(ROOT):
                # escapes the repo (e.g. the ../../actions/... CI badge):
                # points at the hosting site, nothing on disk to validate
                continue
            if not resolved.exists():
                errors.append(f"{md.relative_to(ROOT)}:{lineno}: broken "
                              f"link -> {target}")
    return errors


def main() -> int:
    files = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
    missing = [f for f in files if not f.exists()]
    if missing:
        for f in missing:
            print(f"missing expected file: {f.relative_to(ROOT)}")
        return 1
    errors = [e for f in files for e in check(f)]
    for e in errors:
        print(e)
    print(f"[check_links] {len(files)} files, "
          f"{'FAIL: ' + str(len(errors)) + ' broken' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
