"""Serving hot-path microbenchmark: GRU scan + RK4 roll, per backend.

Times the two fused kernels of the online serving loop — the GRU sequence
scan (refit encoder) and the RK4 polynomial rollout (refit decoder + guard) —
at SERVING batch shapes, across the three backends the wrappers dispatch to:

  * ``reference``        — the pure-jnp oracle under jit (the CPU baseline
    every serving number so far was measured on),
  * ``pallas_interpret`` — the Pallas kernel in interpreter mode (what CI and
    CPU runs of ``use_pallas=True`` execute; semantics of the compiled
    kernel, interpreter cost),
  * ``pallas_compiled``  — the compiled Pallas kernel (TPU; recorded as
    ``n/a`` where the platform cannot compile Pallas, e.g. CPU CI).

Each kernel is timed on its two serving invocations: ``fwd`` (guard / predict
rollouts) and ``grad`` (the refit train step's value_and_grad, which for the
Pallas backend runs the kernel forward + the reference backward via the
custom-VJP rule — so `grad` rows price the full training hot path, not just
the kernel).  Shapes mirror the 64-twin online benchmark (refit: 8 slots x 8
windows, window 24; guard: budget-128 fused call, window 32) plus a 10k-scale
guard shape.  Emitted to bench_out/hotpath.csv by ``benchmarks/run.py --only
hotpath``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import print_rows, time_fn, write_csv
from repro.core.library import make_library
from repro.kernels.gru.ops import gru_scan
from repro.kernels.gru.ref import init_gru_params
from repro.kernels.rk4.ops import rk4_poly_solve

BACKENDS = [
    # optional=True marks the backend that legitimately cannot run off-TPU
    # (compiled Pallas) -> recorded as n/a; failures on the other two are
    # real regressions and must fail the run (the CI smoke lane included).
    ("reference", dict(use_pallas=False), False),
    ("pallas_interpret", dict(use_pallas=True, interpret=True), False),
    ("pallas_compiled", dict(use_pallas=True, interpret=False), True),
]


def _try_time(fn, grad_fn, optional: bool) \
        -> tuple[float | None, float | None]:
    """(fwd_ms, grad_ms); None only where an `optional` backend cannot run."""
    try:
        fwd = 1e3 * time_fn(fn)
    except Exception as e:
        if not optional:
            raise
        print(f"  [hotpath] backend unavailable ({type(e).__name__}): "
              f"{str(e).splitlines()[0][:120]}")
        return None, None
    try:
        grad = 1e3 * time_fn(grad_fn)
    except Exception as e:
        if not optional:
            raise
        print(f"  [hotpath] grad unavailable ({type(e).__name__}): "
              f"{str(e).splitlines()[0][:120]}")
        grad = None
    return fwd, grad


def _gru_rows(B, T, D, H, tag):
    key = jax.random.PRNGKey(0)
    p = init_gru_params(key, D, H)
    xs = jax.random.normal(key, (B, T, D))
    h0 = jnp.zeros((B, H))
    rows = []
    for name, kw, optional in BACKENDS:
        def loss(wx):
            hs, hT = gru_scan(xs, h0, wx, p["wh"], p["b"], **kw)
            return jnp.sum(hT ** 2) + jnp.mean(hs ** 2)

        # jit once per backend: timing must price the compiled step, not
        # per-call retracing of jax.grad
        grad_fn = jax.jit(jax.grad(loss))

        def fwd():
            return gru_scan(xs, h0, p["wx"], p["wh"], p["b"], **kw)

        def grad():
            return grad_fn(p["wx"])

        fwd_ms, grad_ms = _try_time(fwd, grad, optional)
        rows.append({"op": "gru_scan", "shape": tag,
                     "B": B, "T": T, "backend": name,
                     "fwd_ms": _fmt(fwd_ms), "grad_ms": _fmt(grad_ms)})
    return rows


def _rk4_rows(B, T, n, m, order, tag):
    lib = make_library(n, m, order)
    key = jax.random.PRNGKey(1)
    theta = 0.1 * jax.random.normal(key, (B, n, lib.size))
    y0 = 0.3 * jax.random.normal(key, (B, n))
    us = 0.2 * jax.random.normal(key, (B, T, m))
    rows = []
    for name, kw, optional in BACKENDS:
        def loss(th):
            ys = rk4_poly_solve(th, y0, us, dt=0.02, library=lib, **kw)
            return jnp.mean(ys ** 2)

        grad_fn = jax.jit(jax.grad(loss))

        def fwd():
            return rk4_poly_solve(theta, y0, us, dt=0.02, library=lib, **kw)

        def grad():
            return grad_fn(theta)

        fwd_ms, grad_ms = _try_time(fwd, grad, optional)
        rows.append({"op": "rk4_roll", "shape": tag,
                     "B": B, "T": T, "backend": name,
                     "fwd_ms": _fmt(fwd_ms), "grad_ms": _fmt(grad_ms)})
    return rows


def _fmt(ms: float | None):
    return "n/a" if ms is None else round(ms, 3)


def run(quick: bool = True, smoke: bool = False) -> None:
    # serving shapes: refit encoder sees refit_slots*windows_per_twin window
    # batches; the guard's fused call is budget (+carry) wide.
    if smoke:
        shapes_gru = [(16, 16, 5, 16, "smoke")]
        shapes_rk4 = [(16, 16, 4, 1, 2, "smoke")]
    else:
        shapes_gru = [(64, 24, 5, 32, "refit-64twin"),
                      (128, 24, 5, 32, "refit-128slotwin")]
        shapes_rk4 = [(64, 24, 4, 1, 3, "refit-64twin"),
                      (128, 32, 4, 1, 3, "guard-budget128"),
                      (512, 32, 4, 1, 3, "guard-budget512")]
        if not quick:
            shapes_gru.append((512, 24, 5, 32, "refit-512slotwin"))
            shapes_rk4.append((2048, 32, 4, 1, 3, "guard-10kscale"))
    rows = []
    for B, T, D, H, tag in shapes_gru:
        rows += _gru_rows(B, T, D, H, tag)
    for B, T, n, m, order, tag in shapes_rk4:
        rows += _rk4_rows(B, T, n, m, order, tag)
    print_rows("serving hot path: reference vs pallas backends "
               f"(platform={jax.default_backend()})", rows)
    path = write_csv("hotpath.csv", rows)
    print(f"[hotpath] wrote {path}")


if __name__ == "__main__":
    run()
