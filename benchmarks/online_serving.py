"""Online serving benchmark: sustained refresh latency + twin throughput.

Streams simulated F-8 telemetry through `TwinServer` and measures the
steady-state serving tick against the paper's mission budget (refresh every
deployed twin in <= 1 s — 5x under the 5 s human-pilot reaction time).

Reported per fleet size:
  p50/p99/max per-tick refresh latency (ms), deadline violations, and
  twin-refreshes-per-second (refit slots advanced per wall second) — the
  number every scaling PR (sharded fleets, async ingestion, multi-backend)
  must move.  Emitted to bench_out/online.csv by benchmarks/run.py
  (`--only online`).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import print_rows, write_csv
from repro.core.merinda import MerindaConfig
from repro.systems.f8_crusader import F8Crusader
from repro.systems.simulate import simulate_batch
from repro.twin.monitor import GuardConfig
from repro.twin.server import TwinServer, TwinServerConfig

CHUNK = 8          # telemetry samples per twin per tick
WARMUP = 18        # ticks excluded from stats: jit compile, slot fill, and
                   # the first deploy/guard activations all land in warmup


def _serve(n_twins: int, refit_slots: int, ticks: int, seed: int = 0,
           use_pallas: bool = False) -> dict:
    system = F8Crusader()
    horizon = CHUNK * (WARMUP + ticks) + 1
    trace = simulate_batch(system, jax.random.PRNGKey(seed), batch=n_twins,
                           horizon=horizon, noise_std=0.002)
    ys, us = np.asarray(trace.ys_noisy), np.asarray(trace.us)

    cfg = TwinServerConfig(
        merinda=MerindaConfig(n=system.spec.n, m=system.spec.m, order=3,
                              dt=system.spec.dt, hidden=32, head_hidden=32,
                              n_active=24, use_pallas=use_pallas),
        max_twins=n_twins, refit_slots=refit_slots,
        capacity=256, window=24, stride=8, windows_per_twin=8,
        steps_per_tick=2, deploy_after=8, min_residency=4, max_residency=16,
        guard=GuardConfig(window=32), seed=seed)
    srv = TwinServer(cfg)

    for t in range(WARMUP + ticks):
        lo = t * CHUNK
        for i in range(n_twins):
            srv.ingest(i, ys[i, lo:lo + CHUNK], us[i, lo:lo + CHUNK])
        srv.tick()
        if t == WARMUP - 1:
            srv.reset_latency_stats()
    # latency_summary/stage_summary read the server's obs metrics registry —
    # the SAME histograms/counters `srv.metrics.expose()` scrapes in
    # production, so the CSV and an operator dashboard cannot disagree
    s = srv.latency_summary()
    st = srv.stage_summary()
    deployed = sum(r.deployed for r in srv.twins.values())
    return {
        "twins": n_twins, "refit_slots": refit_slots,
        "backend": "pallas" if use_pallas else "reference",
        "ticks": s["ticks"],
        "p50_ms": round(s["p50_ms"], 2), "p99_ms": round(s["p99_ms"], 2),
        "max_ms": round(s["max_ms"], 2),
        "deadline_s": s["deadline_s"], "violations": s["violations"],
        "twin_refreshes_per_s": round(s["twin_refreshes_per_s"], 1),
        "flush_ms": round(st["flush_ms"], 2),
        "guard_ms": round(st["guard_ms"], 2),
        "schedule_ms": round(st["schedule_ms"], 2),
        "refit_ms": round(st["refit_ms"], 2),
        "dropped_samples": s["dropped_samples"],
        "flush_overflows": s["flush_overflows"],
        "deployed": deployed,
    }


def run(quick: bool = True, smoke: bool = False,
        use_pallas: bool = False) -> None:
    """`use_pallas=True` serves the same sweep on the Pallas hot path
    (compiled on TPU, interpreter mode elsewhere — `--pallas` in run.py);
    tick-level output parity with the reference backend is CI-gated in
    tests/test_hotpath_parity.py."""
    if smoke:
        sweeps = [(16, 4, 8)]          # CI smoke: exercise the loop, not perf
    else:
        sweeps = ([(64, 8, 30)] if quick
                  else [(64, 8, 60), (128, 8, 60), (256, 16, 60)])
    rows = [_serve(n, s, t, use_pallas=use_pallas) for n, s, t in sweeps]
    print_rows("online serving: sustained refresh latency (1 s deadline)",
               rows)
    path = write_csv("online_pallas.csv" if use_pallas else "online.csv",
                     rows)
    print(f"[online] wrote {path}")


if __name__ == "__main__":
    run()
