"""Scenario-engine benchmark: what-if throughput under live serving load.

The paper's predictive claim, measured: a 10k-twin sharded fleet keeps its
serving ticks inside the mission deadline WHILE answering a stream of
batched what-if queries (`TwinServer.scenario()` — K counterfactual input
sequences x confidence ensemble, one fused rollout per query).  Each
measured tick interleaves `queries` scenario calls (round-robin over the
fleet) with the full ingest/guard/refit/promote cycle, so the numbers are
the contended ones an operator would see, not an idle-fleet microbenchmark.

Reported per sweep point (bench_out/scenarios.csv):

  * p50_ms / p99_ms — per-scenario-call wall latency (gated);
  * tick_p50_ms     — serving-tick latency under query load (gated);
  * violations      — tick deadline misses PLUS scenario calls that
                      exceeded the deadline (gated: the acceptance bar is
                      0 at every sweep size);
  * scenarios_per_s — counterfactual trajectories answered per wall
                      second over the measured region (noisy, reported).

Sync ingest (the contention-free reference mode on starved hosts) keeps
scenario-call latencies attributable.  Emitted by benchmarks/run.py
(`--only scenarios`); `--smoke` runs the tiny CI config.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import print_rows, write_csv
from repro.core.merinda import MerindaConfig
from repro.systems.f8_crusader import F8Crusader
from repro.systems.simulate import simulate_batch
from repro.twin.monitor import GuardConfig
from repro.twin.scenario import ScenarioConfig, ScenarioRefused
from repro.twin.server import TwinServerConfig
from repro.twin.sharded import ShardedTwinConfig, ShardedTwinServer

CHUNK = 8          # telemetry samples per twin per tick
GUARD_BUDGET = 128
WARMUP = 18        # jit compile (tick AND scenario shapes) lands in warmup


def _serve_scenarios(n_twins: int, shards: int, ticks: int, *,
                     k: int = 8, horizon: int = 20, queries: int = 8,
                     ensemble: int = 4, seed: int = 0) -> dict:
    system = F8Crusader()
    sim_h = CHUNK * (WARMUP + ticks) + 1
    sim = simulate_batch(system, jax.random.PRNGKey(seed), batch=n_twins,
                         horizon=sim_h, noise_std=0.002)
    ys, us = np.asarray(sim.ys_noisy), np.asarray(sim.us)

    per_shard = -(-n_twins // shards)
    scfg = TwinServerConfig(
        merinda=MerindaConfig(n=system.spec.n, m=system.spec.m, order=3,
                              dt=system.spec.dt, hidden=16, head_hidden=16,
                              n_active=24),
        max_twins=per_shard, refit_slots=8,
        capacity=64, window=16, stride=8, windows_per_twin=4,
        steps_per_tick=1, deploy_after=8, min_residency=4, max_residency=16,
        guard=GuardConfig(window=24),
        guard_budget=min(GUARD_BUDGET, per_shard),
        scenario=ScenarioConfig(max_k=max(k, 32), ensemble=ensemble),
        async_ingest=False, seed=seed)
    srv = ShardedTwinServer(ShardedTwinConfig.uniform(
        scfg, shards, rebalance_every=4))
    # K elevator-fade counterfactuals: channel 0 ramps to a fraction of the
    # input scale — the "what if authority degrades xx%" family of queries
    fracs = np.linspace(0.1, 1.0, k, dtype=np.float32)
    qus = np.zeros((k, horizon, system.spec.m), np.float32)
    qus[:, :, 0] = (0.03 * fracs[:, None]
                    * np.linspace(0.0, 1.0, horizon, dtype=np.float32))
    try:
        theta0 = system.true_theta(srv.shards[0].fleet.model.lib)
        srv.deploy_many(list(range(n_twins)), theta0)

        lat: list[float] = []
        answered = 0
        shrunk = refused = 0
        qcursor = 0
        wall = 0.0
        for t in range(WARMUP + ticks):
            lo = t * CHUNK
            srv.ingest_many(
                [(i, ys[i, lo:lo + CHUNK], us[i, lo:lo + CHUNK])
                 for i in range(n_twins)])
            if t == WARMUP - 2:
                # compile the scenario shape before the stats reset
                srv.drain()
                srv.scenario(0, horizon, qus)
            measured = t >= WARMUP
            t0 = time.perf_counter()
            if measured:
                for _ in range(queries):
                    tid = qcursor % n_twins
                    qcursor += 1
                    q0 = time.perf_counter()
                    try:
                        res = srv.scenario(tid, horizon, qus)
                    except ScenarioRefused:
                        refused += 1
                        continue
                    lat.append(time.perf_counter() - q0)
                    answered += res.k
                    shrunk += res.k < res.requested_k
            srv.tick()
            if measured:
                wall += time.perf_counter() - t0
            if t == WARMUP - 1:
                srv.reset_latency_stats()
        srv.drain()
        s = srv.latency_summary()
        lat_ms = np.asarray(lat) * 1e3 if lat else np.zeros((1,))
        deadline_ms = s["deadline_s"] * 1e3
        q_violations = int((lat_ms > deadline_ms).sum())
        return {
            "twins": n_twins, "shards": shards, "k": k, "horizon": horizon,
            "queries": queries, "ensemble": ensemble, "ticks": s["ticks"],
            "deadline_s": s["deadline_s"],
            "p50_ms": round(float(np.percentile(lat_ms, 50)), 2),
            "p99_ms": round(float(np.percentile(lat_ms, 99)), 2),
            "tick_p50_ms": round(s["p50_ms"], 2),
            "violations": s["violations"] + q_violations,
            "scenarios_per_s": round(answered / max(wall, 1e-9), 1),
            "shrunk": shrunk, "refused": refused,
        }
    finally:
        srv.close()


def run(quick: bool = True, smoke: bool = False) -> None:
    if smoke:
        sweeps = [(128, 2, 6, dict(k=8, horizon=20, queries=8))]
    elif quick:
        sweeps = [(1000, 2, 12, dict(k=8, horizon=20, queries=8)),
                  (10000, 4, 12, dict(k=8, horizon=20, queries=8))]
    else:
        sweeps = [(1000, 2, 24, dict(k=8, horizon=20, queries=8)),
                  (10000, 4, 24, dict(k=8, horizon=20, queries=8)),
                  (10000, 4, 24, dict(k=16, horizon=40, queries=16))]
    rows = [_serve_scenarios(n, s, t, **kw) for n, s, t, kw in sweeps]
    for r in rows:
        verdict = ("0 deadline violations" if r["violations"] == 0
                   else f"{r['violations']} VIOLATIONS")
        print(f"[scenarios] {r['twins']} twins / {r['shards']} shards: "
              f"{r['scenarios_per_s']:.0f} scenarios/s "
              f"(K={r['k']}, H={r['horizon']}, p50 {r['p50_ms']} ms) — "
              f"{verdict}")
    print_rows("what-if scenario serving under live load", rows)
    path = write_csv("scenarios.csv", rows)
    print(f"[scenarios] wrote {path}")


if __name__ == "__main__":
    run()
