"""Federated serving benchmark: multi-process workers vs one process.

Sweeps `FederatedTwinServer` (coordinator + N worker subprocesses, wire
messages per tick) against the in-process `ShardedTwinServer` at the SAME
twin count and shard count — both driven through the `TwinService` protocol
with identical call sites, so the delta is the process split itself.  Rows
land in bench_out/online_federated.csv (`--only online_federated`).  The
claims under test:

  * refresh throughput scales with worker processes: send-all-then-collect
    ticks run workers CONCURRENTLY, so at 10k twins / 4 workers the
    federated fleet must reach >= 3x the single-process refresh rate —
    ON A HOST WITH THE CORES TO SHOW IT (>= workers + 1).  The verdict
    printed at the end is honest about this: on fewer cores the workers
    time-slice one core and the measured "speedup" is IPC overhead, not
    the architecture, and is reported as HOST-LIMITED rather than FAIL.
  * the ingestion front door is affordable: one sweep point ingests over
    the length-prefixed TCP door (`ingest=tcp`) instead of in-process
    calls (`ingest=direct`) — same protocol batch, one socket hop added.
  * federation survives a worker kill: the `kill_restart` scenario
    SIGKILLs a worker mid-measurement and reports recovery ticks,
    journal-replay accounting (lost_samples must be 0 — every routed
    sample is journaled supervisor-side BEFORE the worker sees it), and
    whether slot grants migrated to the survivors while the worker was
    down.  tests/test_federation.py gates the same semantics.

Workers serve with sync in-worker ingest (the pipe already decouples
producers from the serving loop), and the in-process baseline runs sync
ingest too — the comparison is contention-free by construction on any
host.  Checkpoint/journal machinery is OFF in the throughput rows and ON
in the kill row (its cost is benchmarked separately in online_chaos.csv).
"""
from __future__ import annotations

import os
import shutil
import tempfile

import jax
import numpy as np

from benchmarks.common import print_rows, write_csv
from repro.core.merinda import MerindaConfig
from repro.systems.f8_crusader import F8Crusader
from repro.systems.simulate import simulate_batch
from repro.twin import (ChaosConfig, FederatedTwinConfig, FederatedTwinServer,
                        FrontDoorClient, GuardConfig, RecoveryConfig,
                        ShardedTwinConfig, ShardedTwinServer, TwinServerConfig)

CHUNK = 8           # telemetry samples per twin per tick
GUARD_BUDGET = 128  # per-worker rotating guard subset
WARMUP = 18         # jit compile + slot fill + first deploys, per worker
SPEEDUP_TARGET = 3.0


def _shard_cfg(system, n_twins: int, workers: int, *, seed: int,
               deadline_s: float = 1.0) -> TwinServerConfig:
    per_shard = -(-n_twins // workers)
    return TwinServerConfig(
        merinda=MerindaConfig(n=system.spec.n, m=system.spec.m, order=3,
                              dt=system.spec.dt, hidden=16, head_hidden=16,
                              n_active=24),
        max_twins=per_shard, refit_slots=8,
        capacity=64, window=16, stride=8, windows_per_twin=4,
        steps_per_tick=1, deploy_after=8, min_residency=4, max_residency=16,
        guard=GuardConfig(window=24),
        guard_budget=min(GUARD_BUDGET, per_shard),
        deadline_s=deadline_s, async_ingest=False, seed=seed)


def _row(scenario, mode, n_twins, workers, ingest, s, deadline_s) -> dict:
    return {
        "scenario": scenario, "mode": mode, "twins": n_twins,
        "workers": workers, "ingest": ingest, "ticks": s["ticks"],
        "deadline_s": deadline_s,
        "p50_ms": round(s["p50_ms"], 2), "p99_ms": round(s["p99_ms"], 2),
        "max_ms": round(s["max_ms"], 2), "violations": s["violations"],
        "twin_refreshes_per_s": round(s["twin_refreshes_per_s"], 1),
        "speedup": "n/a",
        "shard_deaths": 0, "recovery_ticks": 0,
        "replayed_samples": 0, "lost_samples": 0, "grants_migrated": "n/a",
    }


def _serve(mode: str, n_twins: int, workers: int, ticks: int, *,
           tcp: bool = False, seed: int = 0) -> dict:
    """One throughput run: identical protocol call sites for both modes."""
    system = F8Crusader()
    horizon = CHUNK * (WARMUP + ticks) + 1
    sim = simulate_batch(system, jax.random.PRNGKey(seed), batch=n_twins,
                         horizon=horizon, noise_std=0.002)
    ys, us = np.asarray(sim.ys_noisy), np.asarray(sim.us)
    scfg = _shard_cfg(system, n_twins, workers, seed=seed)
    if mode == "federated":
        srv = FederatedTwinServer(FederatedTwinConfig.uniform(
            scfg, workers, rebalance_every=4, front_door=tcp))
    else:
        srv = ShardedTwinServer(ShardedTwinConfig.uniform(
            scfg, workers, rebalance_every=4))
    door = FrontDoorClient(srv.front_address) if tcp else None
    sink = door if door is not None else srv
    try:
        theta0 = np.asarray(system.true_theta(scfg.merinda.library))
        srv.deploy_many(list(range(n_twins)), theta0)
        for t in range(WARMUP + ticks):
            lo = t * CHUNK
            sink.ingest_many([(i, ys[i, lo:lo + CHUNK], us[i, lo:lo + CHUNK])
                              for i in range(n_twins)])
            if t < WARMUP:
                srv.drain()
            srv.tick()
            if t == WARMUP - 1:
                srv.reset_latency_stats()
        srv.drain()
        return _row("serve", mode, n_twins, workers,
                    "tcp" if tcp else "direct", srv.latency_summary(),
                    scfg.deadline_s)
    finally:
        if door is not None:
            door.close()
        srv.close()


def _serve_kill(n_twins: int, workers: int, ticks: int, *,
                seed: int = 0) -> dict:
    """kill_restart: SIGKILL one worker a third into the measured region,
    supervised restart after 1 tick, journal-tail replay.  Deadline 5 s so
    the restore tick (process boot + compile) is reported, not flaky."""
    system = F8Crusader()
    horizon = CHUNK * (WARMUP + ticks) + 1
    sim = simulate_batch(system, jax.random.PRNGKey(seed), batch=n_twins,
                         horizon=horizon, noise_std=0.002)
    ys, us = np.asarray(sim.ys_noisy), np.asarray(sim.us)
    scfg = _shard_cfg(system, n_twins, workers, seed=seed, deadline_s=5.0)
    victim = workers - 1
    kill_tick = WARMUP + max(2, ticks // 3)
    ckpt_dir = tempfile.mkdtemp(prefix="twin_fed_ckpt_")
    # grant migration is only OBSERVABLE under scarcity: at the default
    # budget (sum of pools) every worker sits at its pool cap, so a death
    # just revokes the victim's grant.  Serve half the aggregate capacity
    # and the victim's share visibly flows to the survivors while it is
    # down, then back on restart.
    total_slots = max(workers, (workers * scfg.refit_slots) // 2)
    cfg = FederatedTwinConfig.uniform(
        scfg, workers, rebalance_every=4, total_slots=total_slots,
        recovery=RecoveryConfig(ckpt_dir=ckpt_dir, ckpt_every=4,
                                restart_delay_ticks=1),
        chaos=ChaosConfig(kill_shard=victim, kill_at_tick=kill_tick))
    srv = FederatedTwinServer(cfg)
    try:
        theta0 = np.asarray(system.true_theta(scfg.merinda.library))
        srv.deploy_many(list(range(n_twins)), theta0)
        reports = []
        for t in range(WARMUP + ticks):
            lo = t * CHUNK
            srv.ingest_many([(i, ys[i, lo:lo + CHUNK], us[i, lo:lo + CHUNK])
                             for i in range(n_twins)])
            if t < WARMUP:
                srv.drain()
            rep = srv.tick()
            if t >= WARMUP:
                reports.append(rep)
            if t == WARMUP - 1:
                srv.reset_latency_stats()
        srv.drain()
        pre = next((r.grants for r in reports if r.dead_shards == 0),
                   [0] * workers)
        migrated = any(
            r.dead_shards > 0 and r.grants[victim] == 0
            and sum(r.grants) == total_slots
            and any(g > p for i, (g, p) in enumerate(zip(r.grants, pre))
                    if i != victim)
            for r in reports)
        restarted = [x for r in reports for x in r.restarted]
        row = _row("kill_restart", "federated", n_twins, workers, "direct",
                   srv.latency_summary(), scfg.deadline_s)
        row.update({
            "shard_deaths": len(restarted),
            "recovery_ticks": sum(x["down_ticks"] for x in restarted),
            "replayed_samples": sum(x["replayed"] for x in restarted),
            "lost_samples": sum(x["lost"] for x in restarted),
            "grants_migrated": "yes" if migrated else "NO",
        })
        return row
    finally:
        srv.close()
        shutil.rmtree(ckpt_dir, ignore_errors=True)


def _speedup_verdicts(rows: list[dict]) -> None:
    """Fill `speedup` on federated serve rows against the in-process row at
    the same (twins, workers) and print the throughput verdict — honest
    about host cores: the >= 3x contract needs >= workers + 1 cores."""
    inproc = {(r["twins"], r["workers"]): r for r in rows
              if r["mode"] == "inproc"}
    cores = os.cpu_count() or 1
    for r in rows:
        if r["mode"] != "federated" or r["scenario"] != "serve":
            continue
        base = inproc.get((r["twins"], r["workers"]))
        if base is None:
            continue
        ratio = (r["twin_refreshes_per_s"]
                 / max(base["twin_refreshes_per_s"], 1e-9))
        r["speedup"] = round(ratio, 2)
        need = r["workers"] + 1
        if cores < need:
            verdict = (f"HOST-LIMITED ({cores} core(s) < {need} needed: "
                       f"workers time-slice one core, so this measures IPC "
                       f"overhead, not concurrency — rerun on >= {need} "
                       f"cores for the >= {SPEEDUP_TARGET:.0f}x contract)")
        elif ratio >= SPEEDUP_TARGET:
            verdict = f">= {SPEEDUP_TARGET:.0f}x contract holds"
        else:
            verdict = f"BELOW the {SPEEDUP_TARGET:.0f}x contract"
        print(f"[online_federated] {r['twins']} twins / {r['workers']} "
              f"workers [{r['ingest']}]: {base['twin_refreshes_per_s']:.1f} "
              f"-> {r['twin_refreshes_per_s']:.1f} refreshes/s "
              f"({ratio:.2f}x single-process) — {verdict}")


def _kill_verdict(row: dict) -> None:
    ok = (row["lost_samples"] == 0 and row["shard_deaths"] >= 1
          and row["grants_migrated"] == "yes")
    print(f"[online_federated] kill_restart @ {row['twins']} twins / "
          f"{row['workers']} workers: {row['shard_deaths']} death(s), "
          f"{row['recovery_ticks']} recovery tick(s), "
          f"{row['replayed_samples']} samples replayed, "
          f"{row['lost_samples']} lost, grants migrated: "
          f"{row['grants_migrated']} — "
          f"{'crash-safe' if ok else 'RECOVERY CONTRACT BROKEN'}")


def run(quick: bool = True, smoke: bool = False) -> None:
    if smoke:
        sweeps = [("inproc", 256, 2, 6, False), ("federated", 256, 2, 6,
                                                 False)]
        kill = (256, 2, 8)
    elif quick:
        sweeps = [("inproc", 10000, 4, 10, False),
                  ("federated", 10000, 4, 10, False),
                  ("federated", 1000, 2, 10, True)]
        kill = (1000, 4, 12)
    else:
        sweeps = [("inproc", 10000, 4, 16, False),
                  ("federated", 10000, 4, 16, False),
                  ("inproc", 100000, 8, 10, False),
                  ("federated", 100000, 8, 10, False),
                  ("federated", 10000, 4, 16, True)]
        kill = (10000, 4, 16)
    rows = [_serve(m, n, w, t, tcp=tcp) for m, n, w, t, tcp in sweeps]
    rows.append(_serve_kill(*kill))
    _speedup_verdicts(rows)
    _kill_verdict(rows[-1])
    print_rows("federated serving: worker processes vs in-process shards, "
               "TCP front door, kill+restart", rows)
    path = write_csv("online_federated.csv", rows)
    print(f"[online_federated] wrote {path}")


if __name__ == "__main__":
    run()
