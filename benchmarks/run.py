"""Benchmark harness — one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Outputs CSVs to bench_out/ and prints each table.  The LM roofline table
(beyond-paper) renders from artifacts/dryrun/ when present (produced by
launch/dryrun.py).
"""
from __future__ import annotations

import argparse
from pathlib import Path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-size sweeps (slow on 1 CPU core)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny configs (CI smoke lane; overrides --full)")
    ap.add_argument("--only", default=None,
                    choices=["table1", "table2", "table3", "roofline",
                             "online", "online_scale", "online_federated",
                             "sched_scale", "hotpath", "scenarios"])
    ap.add_argument("--pallas", action="store_true",
                    help="serve the online benchmark on the Pallas hot path "
                         "(use_pallas=True; compiled on TPU, interpreter "
                         "mode elsewhere) -> bench_out/online_pallas.csv")
    ap.add_argument("--chaos", action="store_true",
                    help="fault-injected serving: checkpoint overhead, "
                         "shard-kill recovery + journal replay, degradation "
                         "ladder -> bench_out/online_chaos.csv (use with "
                         "--only online_scale)")
    args = ap.parse_args()
    quick = not args.full

    if args.only in (None, "table3"):
        from benchmarks import table3_ablation
        table3_ablation.run(quick=quick)
    if args.only in (None, "table2"):
        from benchmarks import table2_scaling
        table2_scaling.run(quick=quick)
    if args.only in (None, "table1"):
        from benchmarks import table1_accuracy
        table1_accuracy.run(quick=quick)
    if args.only in (None, "online"):
        from benchmarks import online_serving
        online_serving.run(quick=quick, smoke=args.smoke,
                           use_pallas=args.pallas)
    if args.only in (None, "online_scale"):
        from benchmarks import online_scale
        online_scale.run(quick=quick, smoke=args.smoke, chaos=args.chaos)
    if args.only in (None, "online_federated"):
        from benchmarks import online_federated
        online_federated.run(quick=quick, smoke=args.smoke)
    if args.only in (None, "scenarios"):
        from benchmarks import scenarios
        scenarios.run(quick=quick, smoke=args.smoke)
    if args.only in (None, "sched_scale"):
        from benchmarks import sched_scale
        sched_scale.run(quick=quick, smoke=args.smoke)
    if args.only in (None, "hotpath"):
        from benchmarks import hotpath
        hotpath.run(quick=quick, smoke=args.smoke)
    if args.only in (None, "roofline"):
        d = Path("artifacts/dryrun")
        if d.exists() and any(d.glob("*.json")):
            from repro.launch.roofline import load_records, render_table
            recs = load_records(d)
            print("\n== LM roofline (single-pod; see EXPERIMENTS.md) ==")
            print(render_table(recs, "16x16"))
        else:
            print("\n[roofline] no artifacts/dryrun JSONs; run "
                  "PYTHONPATH=src python -m repro.launch.dryrun first")


if __name__ == "__main__":
    main()
