"""Paper Table I: reconstruction MSE of MERINDA vs EMILY vs PINN+SR on the
four benchmark systems.

Published numbers (quoted for reference in EXPERIMENTS.md):
    system              EMILY        PINN+SR      MERINDA
    Lotka-Volterra      0.03(0.02)   0.05(0.03)   0.03(0.018)
    Chaotic Lorenz      1.7(0.6)     2.11(1.4)    1.68(0.4)
    F8 Crusader         4.2(2.1)     6.9(4.4)     5.1(2.2)
    Pathogenic Attack   14.3(12.1)   21.4(5.4)    15.1(10.2)
"""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import print_rows, write_csv
from repro.core.emily import Emily, EmilyConfig
from repro.core.merinda import Merinda, MerindaConfig
from repro.core.metrics import reconstruction_mse
from repro.core.pinn_sr import PinnSR, PinnSRConfig
from repro.core.trainer import fit
from repro.data.pipeline import WindowDataset
from repro.systems.simulate import register_systems
from repro.systems.simulate import simulate_batch

SYSTEMS = ["lotka_volterra", "lorenz", "f8_crusader", "pathogenic_attack"]


def _mse_merinda(system, ds, key, steps):
    true_theta = system.true_theta()
    n_active = int((np.abs(true_theta) > 0).sum())
    m = Merinda(MerindaConfig(n=system.spec.n, m=system.spec.m,
                              order=system.spec.order, dt=system.spec.dt,
                              hidden=64, n_active=n_active))
    p = m.init(key, m.norm_stats(ds.y_win, ds.u_win))
    res = fit(m, p, ds.batches(key, 64, epochs=100_000), steps=steps, lr=3e-3)
    theta = m.recover(res.params, ds.y_win, ds.u_win)
    return reconstruction_mse(m.lib, theta, ds.y_win, ds.u_win,
                              system.spec.dt)


def _mse_emily(system, ds, key, steps):
    em = Emily(EmilyConfig(n=system.spec.n, m=system.spec.m,
                           order=system.spec.order, dt=system.spec.dt,
                           hidden=64))
    p = em.init(key)
    res = fit(em, p, ds.batches(key, 64, epochs=100_000), steps=steps,
              lr=3e-3)
    theta = em.recover(res.params, ds.y_win, ds.u_win)
    return reconstruction_mse(em.lib, theta, ds.y_win, ds.u_win,
                              system.spec.dt)


def _mse_pinnsr(system, trace, ds, key, steps):
    pm = PinnSR(PinnSRConfig(n=system.spec.n, m=system.spec.m,
                             order=system.spec.order, dt=system.spec.dt,
                             horizon=trace.ys.shape[1] - 1))
    p = pm.init(key, trace.ys[0])
    batch = (trace.ys_noisy[0], trace.us[0])

    # sequential-thresholding rounds (the SR part) at 60% and 80% of training
    def post(step, params):
        if step in (int(steps * 0.6), int(steps * 0.8)):
            return pm.apply_threshold(params)
        return params

    res = fit(pm, p, iter(lambda: batch, None), steps=steps, lr=2e-3,
              post_step=post)
    theta = pm.recover(res.params)
    return reconstruction_mse(pm.lib, theta, ds.y_win, ds.u_win,
                              system.spec.dt)


def run(quick: bool = True) -> list[dict]:
    steps = 400 if quick else 800
    seeds = 2 if quick else 3
    rows = []
    registry = register_systems()
    for name in SYSTEMS:
        system = registry[name]()
        per_model = {"merinda": [], "emily": [], "pinn_sr": []}
        for seed in range(seeds):
            # F8's true cubic dynamics diverge for some sampled initial
            # conditions; resample until the ground-truth trace is finite
            # (bounded flight envelope — the regime the paper evaluates).
            for attempt in range(10):
                key = jax.random.PRNGKey(seed + 1000 * attempt)
                trace = simulate_batch(system, key, batch=4,
                                       horizon=250 if quick else None,
                                       noise_std=0.01)
                if bool(np.isfinite(np.asarray(trace.ys)).all()):
                    break
            ds = WindowDataset.from_trace(trace.ys_noisy, trace.us, trace.dt,
                                          window=24, stride=8)
            per_model["merinda"].append(_mse_merinda(system, ds, key, steps))
            per_model["emily"].append(_mse_emily(system, ds, key, steps))
            per_model["pinn_sr"].append(
                _mse_pinnsr(system, trace, ds, key, steps))
        row = {"system": name}
        for model, vals in per_model.items():
            row[f"{model}_mse"] = round(float(np.mean(vals)), 4)
            row[f"{model}_std"] = round(float(np.std(vals)), 4)
        rows.append(row)
    write_csv("table1_accuracy.csv", rows)
    print_rows("Table I — reconstruction MSE (MERINDA vs EMILY vs PINN+SR)",
               rows)
    return rows


if __name__ == "__main__":
    run(quick=True)
