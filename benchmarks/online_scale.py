"""Scale benchmark: sharded serving from 64 to 10k tracked objects.

Sweeps the `ShardedTwinServer` over fleet size x shard count with a FIXED
per-shard guard budget — async (`BackgroundPump`) ingestion by default, plus
sync-ingest twin rows (the `ingest` CSV column) that isolate the 1-core
pump-contention artifact from real stage-cost regressions — and reports
per-tick
latency (p50/p99/max vs the 1 s refresh deadline), twin refreshes/s, and the
per-stage cost breakdown.  The claims under test:

  * the sharded architecture keeps the serving tick inside the mission
    deadline as the tracked fleet grows 64 -> 10k (shards absorb the load);
  * guard cost per tick is O(budget), not O(twins): at fixed shards and
    budget, guard_ms must stay flat (within 2x) from 1k -> 10k twins — the
    `GuardRotation` contract, checked and printed at the end;
  * observability is affordable at full scale: the LARGEST sweep re-runs
    with span tracing enabled (every tick sampled) and reports the p50
    overhead in the `trace_overhead_pct` column — the obs-layer contract
    is < 5%.  The traced run also emits the operator artifacts:
    bench_out/trace_online_scale.json (Perfetto-loadable Chrome trace with
    per-shard tick/stage spans), bench_out/metrics_online_scale.prom
    (Prometheus text exposition incl. per-shard stage histograms), and
    bench_out/metrics_online_scale.json (registry snapshot).

All latency/stage columns come from the servers' obs metrics registry
(`latency_summary`/`stage_summary` are registry-backed) — benchmarks and
production dashboards read the same numbers.  Emitted to
bench_out/online_scale.csv by benchmarks/run.py (`--only online_scale`);
`--smoke` runs a tiny sweep for CI.

`--chaos` switches to the CRASH-SAFETY table (bench_out/online_chaos.csv):
a checkpoint-off/on pair proving the on-tick snapshot cost is <= 5% of tick
p50 (same contract tracing carries), a kill-one-shard run reporting recovery
ticks + journal-replay accounting, and an injected-straggler run showing the
degradation ladder shedding with zero deadline violations.
"""
from __future__ import annotations

import shutil
import tempfile

import jax
import numpy as np

from benchmarks.common import OUT_DIR, print_rows, write_csv
from repro.core.merinda import MerindaConfig
from repro.obs import SnapshotWriter, Tracer
from repro.systems.f8_crusader import F8Crusader
from repro.systems.simulate import simulate_batch
from repro.twin.monitor import GuardConfig
from repro.twin.recovery import (ChaosConfig, DegradationConfig,
                                 RecoveryConfig)
from repro.twin.server import TwinServerConfig
from repro.twin.sharded import ShardedTwinConfig, ShardedTwinServer

CHUNK = 8          # telemetry samples per twin per tick
GUARD_BUDGET = 128 # per-shard rotating guard subset (fixed across the sweep)
WARMUP = 18        # ticks excluded from stats: jit compile, slot fill, and
                   # the first deploy/promote compilations all land in warmup


def _serve_scale(n_twins: int, shards: int, ticks: int, *,
                 guard_budget: int = GUARD_BUDGET, seed: int = 0,
                 trace: bool = False, sync: bool = False) -> dict:
    system = F8Crusader()
    horizon = CHUNK * (WARMUP + ticks) + 1
    sim = simulate_batch(system, jax.random.PRNGKey(seed), batch=n_twins,
                         horizon=horizon, noise_std=0.002)
    ys, us = np.asarray(sim.ys_noisy), np.asarray(sim.us)

    per_shard = -(-n_twins // shards)
    scfg = TwinServerConfig(
        merinda=MerindaConfig(n=system.spec.n, m=system.spec.m, order=3,
                              dt=system.spec.dt, hidden=16, head_hidden=16,
                              n_active=24),
        max_twins=per_shard, refit_slots=8,
        capacity=64, window=16, stride=8, windows_per_twin=4,
        steps_per_tick=1, deploy_after=8, min_residency=4, max_residency=16,
        guard=GuardConfig(window=24),
        guard_budget=min(guard_budget, per_shard),
        async_ingest=not sync, seed=seed)
    tracer = Tracer(sample_every=1) if trace else None
    srv = ShardedTwinServer(ShardedTwinConfig.uniform(
        scfg, shards, rebalance_every=4), tracer=tracer)
    try:
        # warm start: every twin serves the offline-recovered model from tick
        # 1 (broadcast deploy), so the guard is active across the whole store
        theta0 = system.true_theta(srv.shards[0].fleet.model.lib)
        srv.deploy_many(list(range(n_twins)), theta0)

        for t in range(WARMUP + ticks):
            lo = t * CHUNK
            for i in range(n_twins):
                srv.ingest(i, ys[i, lo:lo + CHUNK], us[i, lo:lo + CHUNK])
            if t < WARMUP:
                # bootstrap is paced faster than any real sensor stream:
                # barrier the async flush so readiness, admissions, and every
                # jit compile land before the stats reset; measured ticks run
                # free (ingest prep overlapped on the pump thread)
                srv.drain()
            srv.tick()
            if t == WARMUP - 1:
                srv.reset_latency_stats()
        srv.drain()
        s = srv.latency_summary()
        st = srv.stage_summary()
        deployed = sum(r.deployed for shard in srv.shards
                       for r in shard.twins.values())
        if trace:
            # the operator artifact set: Perfetto trace + Prometheus
            # exposition + JSON snapshot, from the live run's registry
            OUT_DIR.mkdir(parents=True, exist_ok=True)
            tracer.write(OUT_DIR / "trace_online_scale.json")
            (OUT_DIR / "metrics_online_scale.prom").write_text(
                srv.metrics.expose())
            SnapshotWriter(srv.metrics,
                           OUT_DIR / "metrics_online_scale.json",
                           tracer=tracer).write()
            print(f"[online_scale] traced run: {len(tracer)} span events "
                  f"({tracer.dropped_events} dropped) -> "
                  f"{OUT_DIR / 'trace_online_scale.json'}")
        return {
            "twins": n_twins, "shards": shards,
            "slots": sum(x.cfg.refit_slots for x in srv.shards),
            "guard_budget": scfg.guard_budget,
            # ingest mode is part of the row identity: on hosts with fewer
            # cores than pump threads, "pump" rows carry background flush
            # work time-sliced into the stage columns — "sync" rows are the
            # contention-free reference (see _check_guard_flat)
            "ingest": "sync" if sync else "pump",
            "tracing": "on" if trace else "off", "ticks": s["ticks"],
            "p50_ms": round(s["p50_ms"], 2), "p99_ms": round(s["p99_ms"], 2),
            "max_ms": round(s["max_ms"], 2),
            "deadline_s": s["deadline_s"], "violations": s["violations"],
            "twin_refreshes_per_s": round(s["twin_refreshes_per_s"], 1),
            "flush_ms": round(st["flush_ms"], 2),
            "guard_ms": round(st["guard_ms"], 2),
            "schedule_ms": round(st["schedule_ms"], 2),
            "refit_ms": round(st["refit_ms"], 2),
            "dropped_samples": s["dropped_samples"],
            "flush_overflows": s["flush_overflows"],
            "trace_overhead_pct": "n/a",
            "deployed": deployed,
        }
    finally:
        srv.close()


def _check_guard_flat(rows: list[dict]) -> None:
    """The O(budget) contract: guard_ms within 2x from 1k -> 10k twins at
    fixed shard count and budget, checked PER INGEST MODE.

    Stage columns are WALL time between tick timestamps.  On hosts with
    fewer cores than pump threads, async ("pump") flush preparation
    time-slices into the guard/refit windows and inflates their attribution
    with work that scales with twins — a known 1-core contention artifact
    (PR 6's NOT-FLAT verdict).  The "sync" rows exist precisely to separate
    that artifact from a real guard regression: the contract verdict that
    matters is the sync one."""
    by_group: dict[tuple, list[dict]] = {}
    for r in rows:
        by_group.setdefault((r["shards"], r["ingest"]), []).append(r)
    for (shards, ingest), group in sorted(by_group.items()):
        group = [r for r in group if r["twins"] >= 1000]
        if len(group) < 2:
            continue
        lo = min(group, key=lambda r: r["twins"])
        hi = max(group, key=lambda r: r["twins"])
        ratio = hi["guard_ms"] / max(lo["guard_ms"], 1e-9)
        flat = "FLAT (O(budget) holds)" if ratio < 2.0 else (
            "NOT FLAT (pump contention artifact on starved hosts — "
            "trust the sync row)" if ingest == "pump" else "NOT FLAT")
        print(f"[online_scale] guard cost {lo['twins']} -> {hi['twins']} "
              f"twins @ {shards} shards [{ingest}]: {lo['guard_ms']:.2f} -> "
              f"{hi['guard_ms']:.2f} ms/tick ({ratio:.2f}x) — {flat}")


def _tracing_overhead(rows: list[dict], off: dict, on: dict) -> None:
    """Fill `trace_overhead_pct` on the traced row and report against the
    obs-layer contract (p50 within 5% of the tracing-off run)."""
    pct = (on["p50_ms"] - off["p50_ms"]) / max(off["p50_ms"], 1e-9) * 100.0
    on["trace_overhead_pct"] = round(pct, 2)
    verdict = "within the 5% budget" if pct <= 5.0 else "OVER the 5% budget"
    print(f"[online_scale] tracing overhead @ {on['twins']} twins / "
          f"{on['shards']} shards: p50 {off['p50_ms']:.2f} -> "
          f"{on['p50_ms']:.2f} ms ({pct:+.2f}%) — {verdict}")


# ------------------------------------------------------------------------- #
# --chaos mode: crash-safety + degradation cost, bench_out/online_chaos.csv
# ------------------------------------------------------------------------- #
def _serve_chaos(scenario: str, n_twins: int, shards: int, ticks: int, *,
                 ckpt_every: int | None = None,
                 chaos: ChaosConfig | None = None,
                 degradation: bool = False,
                 deadline_s: float = 1.0, seed: int = 0) -> dict:
    """One fault-injected serving run; returns an online_chaos.csv row.

    Sync ingest (the contention-free reference mode) so the recovery
    columns are deterministic; measured ticks start after warmup, with the
    kill/slow schedules placed INSIDE the measured region."""
    system = F8Crusader()
    horizon = CHUNK * (WARMUP + ticks) + 1
    sim = simulate_batch(system, jax.random.PRNGKey(seed), batch=n_twins,
                         horizon=horizon, noise_std=0.002)
    ys, us = np.asarray(sim.ys_noisy), np.asarray(sim.us)

    per_shard = -(-n_twins // shards)
    scfg = TwinServerConfig(
        merinda=MerindaConfig(n=system.spec.n, m=system.spec.m, order=3,
                              dt=system.spec.dt, hidden=16, head_hidden=16,
                              n_active=24),
        max_twins=per_shard, refit_slots=8,
        capacity=64, window=16, stride=8, windows_per_twin=4,
        steps_per_tick=1, deploy_after=8, min_residency=4, max_residency=16,
        guard=GuardConfig(window=24),
        guard_budget=min(GUARD_BUDGET, per_shard),
        deadline_s=deadline_s,
        degradation=DegradationConfig(enabled=degradation, hold_ticks=1,
                                      alpha=0.9),
        async_ingest=False, seed=seed)
    ckpt_dir = tempfile.mkdtemp(prefix="twin_chaos_ckpt_")
    recovery = (RecoveryConfig(ckpt_dir=ckpt_dir, ckpt_every=ckpt_every)
                if ckpt_every is not None else None)
    srv = ShardedTwinServer(ShardedTwinConfig.uniform(
        scfg, shards, rebalance_every=4, recovery=recovery, chaos=chaos))
    try:
        theta0 = system.true_theta(srv.shards[0].fleet.model.lib)
        srv.deploy_many(list(range(n_twins)), theta0)
        reports = []
        for t in range(WARMUP + ticks):
            lo = t * CHUNK
            for i in range(n_twins):
                srv.ingest(i, ys[i, lo:lo + CHUNK], us[i, lo:lo + CHUNK])
            rep = srv.tick()
            if t >= WARMUP:
                reports.append(rep)
            if t == WARMUP - 1:
                srv.reset_latency_stats()
        srv.drain()
        s = srv.latency_summary()
        restarted = [r for rep in reports for r in rep.restarted]
        return {
            "scenario": scenario, "twins": n_twins, "shards": shards,
            "ticks": s["ticks"],
            "ckpt_every": "off" if ckpt_every is None else ckpt_every,
            "deadline_s": deadline_s,
            "p50_ms": round(s["p50_ms"], 2), "p99_ms": round(s["p99_ms"], 2),
            "max_ms": round(s["max_ms"], 2), "violations": s["violations"],
            "degraded_ticks": sum(r.degraded_level > 0 for r in reports),
            "recovery_ticks": sum(r["down_ticks"] for r in restarted),
            "replayed_samples": sum(r["replayed"] for r in restarted),
            "lost_samples": sum(r["lost"] for r in restarted),
            "shard_deaths": len(restarted),
            "ckpt_overhead_pct": "n/a",
        }
    finally:
        srv.close()
        shutil.rmtree(ckpt_dir, ignore_errors=True)


def _ckpt_overhead(off: dict, on: dict) -> None:
    """Fill `ckpt_overhead_pct` on the checkpointing row and report against
    the crash-safety contract: p50 within 5% of the checkpoint-off run
    (same budget tracing gets — the snapshot is on-tick, the write is not).
    """
    pct = (on["p50_ms"] - off["p50_ms"]) / max(off["p50_ms"], 1e-9) * 100.0
    on["ckpt_overhead_pct"] = round(pct, 2)
    if on["twins"] >= 1000:
        verdict = ("within the 5% budget" if pct <= 5.0
                   else "OVER the 5% budget")
    else:
        # tiny smoke fleets have ~20 ms p50: the few-ms background-writer
        # contention on a starved host dominates the ratio.  The contract
        # is evaluated at fleet scale (>= 1k twins, quick/full runs).
        verdict = "informational at smoke size (contract is >= 1k twins)"
    print(f"[online_chaos] checkpoint overhead @ {on['twins']} twins / "
          f"{on['shards']} shards (every {on['ckpt_every']} ticks): p50 "
          f"{off['p50_ms']:.2f} -> {on['p50_ms']:.2f} ms ({pct:+.2f}%) — "
          f"{verdict}")


def run_chaos(quick: bool = True, smoke: bool = False) -> None:
    """`--chaos`: the crash-safety cost/recovery table.

    Rows: a checkpoint-off/-on pair at the largest fleet (the <= 5%
    on-tick overhead contract), a kill-one-shard run (recovery +
    replay accounting; deadline 5.0 s so the restore tick itself is
    not a flaky violation), and an injected-straggler run with the
    degradation ladder enabled (sheds before the deadline breaks:
    violations must stay 0 while degraded_ticks > 0)."""
    if smoke:
        size, kill_size, ticks = (128, 2), (128, 2), 8
    elif quick:
        size, kill_size, ticks = (10000, 4), (1000, 4), 12
    else:
        size, kill_size, ticks = (10000, 4), (10000, 4), 24
    kill_tick = WARMUP + ticks // 3 + 1
    slow_lo, slow_hi = WARMUP + 2, WARMUP + 2 + max(3, ticks // 4)
    rows = [
        _serve_chaos("baseline", *size, ticks),
        _serve_chaos("checkpoint", *size, ticks, ckpt_every=8),
        _serve_chaos("kill_shard", *kill_size, ticks, ckpt_every=4,
                     deadline_s=5.0,
                     chaos=ChaosConfig(kill_shard=kill_size[1] - 1,
                                       kill_at_tick=kill_tick)),
        # deadline 2 s, stall 1.7 s: pressure 0.85 > high_water drives the
        # ladder, while organic tick cost (< 300 ms at every sweep size)
        # keeps the stalled ticks under the deadline — the scenario proves
        # shedding engages BEFORE violations happen, so violations stays 0
        _serve_chaos("degrade", *kill_size, ticks, degradation=True,
                     deadline_s=2.0,
                     chaos=ChaosConfig(slow_shard=0, slow_s=1.7,
                                       slow_from_tick=slow_lo,
                                       slow_until_tick=slow_hi)),
    ]
    _ckpt_overhead(rows[0], rows[1])
    kill = rows[2]
    print(f"[online_chaos] kill_shard: {kill['shard_deaths']} death(s), "
          f"{kill['recovery_ticks']} recovery tick(s), "
          f"{kill['replayed_samples']} samples replayed, "
          f"{kill['lost_samples']} lost")
    deg = rows[3]
    shed = ("shed under pressure, 0 violations" if deg["violations"] == 0
            else f"{deg['violations']} VIOLATIONS despite shedding")
    print(f"[online_chaos] degrade: {deg['degraded_ticks']} degraded "
          f"tick(s) — {shed}")
    print_rows("crash-safe serving: checkpoint overhead, failover, "
               "degradation", rows)
    path = write_csv("online_chaos.csv", rows)
    print(f"[online_chaos] wrote {path}")


def run(quick: bool = True, smoke: bool = False,
        chaos: bool = False) -> None:
    if chaos:
        run_chaos(quick=quick, smoke=smoke)
        return
    # sweep entries: (twins, shards, ticks, sync_ingest).  Each pump sweep
    # point >= 1k twins gets a sync twin row so the guard-flatness verdict
    # can separate pump contention from a real regression (see
    # _check_guard_flat).
    if smoke:
        sweeps = [(64, 1, 6, False), (128, 2, 6, False), (128, 2, 6, True)]
    elif quick:
        sweeps = [(64, 1, 12, False), (1000, 1, 12, False),
                  (1000, 2, 12, False), (1000, 4, 12, False),
                  (10000, 4, 12, False),
                  (1000, 4, 12, True), (10000, 4, 12, True)]
    else:
        sweeps = [(64, 1, 24, False), (1000, 1, 24, False),
                  (1000, 2, 24, False), (1000, 4, 24, False),
                  (10000, 4, 24, False), (10000, 2, 24, False),
                  (1000, 4, 24, True), (10000, 4, 24, True)]
    rows = [_serve_scale(n, s, t, sync=sy) for n, s, t, sy in sweeps]
    # re-run the LARGEST pump config with full-sampling tracing on: the
    # overhead column is the proof tracing is affordable at scale, and the
    # traced run writes the Perfetto/Prometheus artifacts next to the CSV
    big = max((i for i in range(len(sweeps)) if not sweeps[i][3]),
              key=lambda i: (sweeps[i][0], sweeps[i][1]))
    n, s, t, _ = sweeps[big]
    traced = _serve_scale(n, s, t, trace=True)
    _tracing_overhead(rows, rows[big], traced)
    rows.append(traced)
    print_rows("online serving at scale: sharded fleets, async ingest, "
               "budgeted guard", rows)
    _check_guard_flat([r for r in rows if r["tracing"] == "off"])
    path = write_csv("online_scale.csv", rows)
    print(f"[online_scale] wrote {path}")


if __name__ == "__main__":
    run()
