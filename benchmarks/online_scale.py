"""Scale benchmark: sharded serving from 64 to 10k tracked objects.

Sweeps the `ShardedTwinServer` over fleet size x shard count with a FIXED
per-shard guard budget — async (`BackgroundPump`) ingestion by default, plus
sync-ingest twin rows (the `ingest` CSV column) that isolate the 1-core
pump-contention artifact from real stage-cost regressions — and reports
per-tick
latency (p50/p99/max vs the 1 s refresh deadline), twin refreshes/s, and the
per-stage cost breakdown.  The claims under test:

  * the sharded architecture keeps the serving tick inside the mission
    deadline as the tracked fleet grows 64 -> 10k (shards absorb the load);
  * guard cost per tick is O(budget), not O(twins): at fixed shards and
    budget, guard_ms must stay flat (within 2x) from 1k -> 10k twins — the
    `GuardRotation` contract, checked and printed at the end;
  * observability is affordable at full scale: the LARGEST sweep re-runs
    with span tracing enabled (every tick sampled) and reports the p50
    overhead in the `trace_overhead_pct` column — the obs-layer contract
    is < 5%.  The traced run also emits the operator artifacts:
    bench_out/trace_online_scale.json (Perfetto-loadable Chrome trace with
    per-shard tick/stage spans), bench_out/metrics_online_scale.prom
    (Prometheus text exposition incl. per-shard stage histograms), and
    bench_out/metrics_online_scale.json (registry snapshot).

All latency/stage columns come from the servers' obs metrics registry
(`latency_summary`/`stage_summary` are registry-backed) — benchmarks and
production dashboards read the same numbers.  Emitted to
bench_out/online_scale.csv by benchmarks/run.py (`--only online_scale`);
`--smoke` runs a tiny sweep for CI.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import OUT_DIR, print_rows, write_csv
from repro.core.merinda import MerindaConfig
from repro.obs import SnapshotWriter, Tracer
from repro.systems.f8_crusader import F8Crusader
from repro.systems.simulate import simulate_batch
from repro.twin.monitor import GuardConfig
from repro.twin.server import TwinServerConfig
from repro.twin.sharded import ShardedTwinConfig, ShardedTwinServer

CHUNK = 8          # telemetry samples per twin per tick
GUARD_BUDGET = 128 # per-shard rotating guard subset (fixed across the sweep)
WARMUP = 18        # ticks excluded from stats: jit compile, slot fill, and
                   # the first deploy/promote compilations all land in warmup


def _serve_scale(n_twins: int, shards: int, ticks: int, *,
                 guard_budget: int = GUARD_BUDGET, seed: int = 0,
                 trace: bool = False, sync: bool = False) -> dict:
    system = F8Crusader()
    horizon = CHUNK * (WARMUP + ticks) + 1
    sim = simulate_batch(system, jax.random.PRNGKey(seed), batch=n_twins,
                         horizon=horizon, noise_std=0.002)
    ys, us = np.asarray(sim.ys_noisy), np.asarray(sim.us)

    per_shard = -(-n_twins // shards)
    scfg = TwinServerConfig(
        merinda=MerindaConfig(n=system.spec.n, m=system.spec.m, order=3,
                              dt=system.spec.dt, hidden=16, head_hidden=16,
                              n_active=24),
        max_twins=per_shard, refit_slots=8,
        capacity=64, window=16, stride=8, windows_per_twin=4,
        steps_per_tick=1, deploy_after=8, min_residency=4, max_residency=16,
        guard=GuardConfig(window=24),
        guard_budget=min(guard_budget, per_shard),
        async_ingest=not sync, seed=seed)
    tracer = Tracer(sample_every=1) if trace else None
    srv = ShardedTwinServer(ShardedTwinConfig.uniform(
        scfg, shards, rebalance_every=4), tracer=tracer)
    try:
        # warm start: every twin serves the offline-recovered model from tick
        # 1 (broadcast deploy), so the guard is active across the whole store
        theta0 = system.true_theta(srv.shards[0].fleet.model.lib)
        srv.deploy_many(list(range(n_twins)), theta0)

        for t in range(WARMUP + ticks):
            lo = t * CHUNK
            for i in range(n_twins):
                srv.ingest(i, ys[i, lo:lo + CHUNK], us[i, lo:lo + CHUNK])
            if t < WARMUP:
                # bootstrap is paced faster than any real sensor stream:
                # barrier the async flush so readiness, admissions, and every
                # jit compile land before the stats reset; measured ticks run
                # free (ingest prep overlapped on the pump thread)
                srv.drain()
            srv.tick()
            if t == WARMUP - 1:
                srv.reset_latency_stats()
        srv.drain()
        s = srv.latency_summary()
        st = srv.stage_summary()
        deployed = sum(r.deployed for shard in srv.shards
                       for r in shard.twins.values())
        if trace:
            # the operator artifact set: Perfetto trace + Prometheus
            # exposition + JSON snapshot, from the live run's registry
            OUT_DIR.mkdir(parents=True, exist_ok=True)
            tracer.write(OUT_DIR / "trace_online_scale.json")
            (OUT_DIR / "metrics_online_scale.prom").write_text(
                srv.metrics.expose())
            SnapshotWriter(srv.metrics,
                           OUT_DIR / "metrics_online_scale.json",
                           tracer=tracer).write()
            print(f"[online_scale] traced run: {len(tracer)} span events "
                  f"({tracer.dropped_events} dropped) -> "
                  f"{OUT_DIR / 'trace_online_scale.json'}")
        return {
            "twins": n_twins, "shards": shards,
            "slots": sum(x.cfg.refit_slots for x in srv.shards),
            "guard_budget": scfg.guard_budget,
            # ingest mode is part of the row identity: on hosts with fewer
            # cores than pump threads, "pump" rows carry background flush
            # work time-sliced into the stage columns — "sync" rows are the
            # contention-free reference (see _check_guard_flat)
            "ingest": "sync" if sync else "pump",
            "tracing": "on" if trace else "off", "ticks": s["ticks"],
            "p50_ms": round(s["p50_ms"], 2), "p99_ms": round(s["p99_ms"], 2),
            "max_ms": round(s["max_ms"], 2),
            "deadline_s": s["deadline_s"], "violations": s["violations"],
            "twin_refreshes_per_s": round(s["twin_refreshes_per_s"], 1),
            "flush_ms": round(st["flush_ms"], 2),
            "guard_ms": round(st["guard_ms"], 2),
            "schedule_ms": round(st["schedule_ms"], 2),
            "refit_ms": round(st["refit_ms"], 2),
            "dropped_samples": s["dropped_samples"],
            "flush_overflows": s["flush_overflows"],
            "trace_overhead_pct": "n/a",
            "deployed": deployed,
        }
    finally:
        srv.close()


def _check_guard_flat(rows: list[dict]) -> None:
    """The O(budget) contract: guard_ms within 2x from 1k -> 10k twins at
    fixed shard count and budget, checked PER INGEST MODE.

    Stage columns are WALL time between tick timestamps.  On hosts with
    fewer cores than pump threads, async ("pump") flush preparation
    time-slices into the guard/refit windows and inflates their attribution
    with work that scales with twins — a known 1-core contention artifact
    (PR 6's NOT-FLAT verdict).  The "sync" rows exist precisely to separate
    that artifact from a real guard regression: the contract verdict that
    matters is the sync one."""
    by_group: dict[tuple, list[dict]] = {}
    for r in rows:
        by_group.setdefault((r["shards"], r["ingest"]), []).append(r)
    for (shards, ingest), group in sorted(by_group.items()):
        group = [r for r in group if r["twins"] >= 1000]
        if len(group) < 2:
            continue
        lo = min(group, key=lambda r: r["twins"])
        hi = max(group, key=lambda r: r["twins"])
        ratio = hi["guard_ms"] / max(lo["guard_ms"], 1e-9)
        flat = "FLAT (O(budget) holds)" if ratio < 2.0 else (
            "NOT FLAT (pump contention artifact on starved hosts — "
            "trust the sync row)" if ingest == "pump" else "NOT FLAT")
        print(f"[online_scale] guard cost {lo['twins']} -> {hi['twins']} "
              f"twins @ {shards} shards [{ingest}]: {lo['guard_ms']:.2f} -> "
              f"{hi['guard_ms']:.2f} ms/tick ({ratio:.2f}x) — {flat}")


def _tracing_overhead(rows: list[dict], off: dict, on: dict) -> None:
    """Fill `trace_overhead_pct` on the traced row and report against the
    obs-layer contract (p50 within 5% of the tracing-off run)."""
    pct = (on["p50_ms"] - off["p50_ms"]) / max(off["p50_ms"], 1e-9) * 100.0
    on["trace_overhead_pct"] = round(pct, 2)
    verdict = "within the 5% budget" if pct <= 5.0 else "OVER the 5% budget"
    print(f"[online_scale] tracing overhead @ {on['twins']} twins / "
          f"{on['shards']} shards: p50 {off['p50_ms']:.2f} -> "
          f"{on['p50_ms']:.2f} ms ({pct:+.2f}%) — {verdict}")


def run(quick: bool = True, smoke: bool = False) -> None:
    # sweep entries: (twins, shards, ticks, sync_ingest).  Each pump sweep
    # point >= 1k twins gets a sync twin row so the guard-flatness verdict
    # can separate pump contention from a real regression (see
    # _check_guard_flat).
    if smoke:
        sweeps = [(64, 1, 6, False), (128, 2, 6, False), (128, 2, 6, True)]
    elif quick:
        sweeps = [(64, 1, 12, False), (1000, 1, 12, False),
                  (1000, 2, 12, False), (1000, 4, 12, False),
                  (10000, 4, 12, False),
                  (1000, 4, 12, True), (10000, 4, 12, True)]
    else:
        sweeps = [(64, 1, 24, False), (1000, 1, 24, False),
                  (1000, 2, 24, False), (1000, 4, 24, False),
                  (10000, 4, 24, False), (10000, 2, 24, False),
                  (1000, 4, 24, True), (10000, 4, 24, True)]
    rows = [_serve_scale(n, s, t, sync=sy) for n, s, t, sy in sweeps]
    # re-run the LARGEST pump config with full-sampling tracing on: the
    # overhead column is the proof tracing is affordable at scale, and the
    # traced run writes the Perfetto/Prometheus artifacts next to the CSV
    big = max((i for i in range(len(sweeps)) if not sweeps[i][3]),
              key=lambda i: (sweeps[i][0], sweeps[i][1]))
    n, s, t, _ = sweeps[big]
    traced = _serve_scale(n, s, t, trace=True)
    _tracing_overhead(rows, rows[big], traced)
    rows.append(traced)
    print_rows("online serving at scale: sharded fleets, async ingest, "
               "budgeted guard", rows)
    _check_guard_flat([r for r in rows if r["tracing"] == "off"])
    path = write_csv("online_scale.csv", rows)
    print(f"[online_scale] wrote {path}")


if __name__ == "__main__":
    run()
