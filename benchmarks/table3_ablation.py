"""Paper Table III: optimization-strategy ablation at dimension 30.

FPGA configurations -> TPU-native analogues (DESIGN.md §2):
    No Optimization   -> naive per-step GRU (separate gate matmuls, no
                         hoisting) + per-step library RK4
    Unroll            -> gate FUSION: z/r/c share fused [*,3H] matmuls
                         (the paper's unrolled parallel MACs)
    Pipeline + Unroll -> fusion + hoisted input projection (ONE big matmul
                         for all timesteps) — the kernels/gru formulation
                         whose Pallas kernel double-buffers batch tiles
                         (PIPELINE II=1)

Reports wall ms/step (CPU, relative speedups are the metric), matmul FLOPs,
and the Pallas kernel's VMEM working set (BRAM analogue) for the fused
config.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import print_rows, time_fn, write_csv
from repro.kernels.gru.ref import gru_scan_ref, init_gru_params

DIM = 30           # paper's reference point
B, T, H = 80, 16, 64
D_IN = 4           # per-twin (3 states + elevator)


def _naive(xs, h0, wx, wh, b):
    Hh = h0.shape[-1]
    wxz, wxr, wxc = wx[:, :Hh], wx[:, Hh:2 * Hh], wx[:, 2 * Hh:]
    whz, whr, whc = wh[:, :Hh], wh[:, Hh:2 * Hh], wh[:, 2 * Hh:]
    bz, br, bc = b[:Hh], b[Hh:2 * Hh], b[2 * Hh:]

    def step(h, x_t):
        z = jax.nn.sigmoid(x_t @ wxz + h @ whz + bz)
        r = jax.nn.sigmoid(x_t @ wxr + h @ whr + br)
        c = jnp.tanh(x_t @ wxc + (r * h) @ whc + bc)
        return (1.0 - z) * h + z * c, None

    return jax.lax.scan(step, h0, jnp.swapaxes(xs, 0, 1))[0]


def _fused_gates(xs, h0, wx, wh, b):
    """Gate fusion only: fused weight matmuls per step, input NOT hoisted."""
    Hh = h0.shape[-1]

    def step(h, x_t):
        xp = x_t @ wx + b
        hp = h @ wh[:, :2 * Hh]
        z = jax.nn.sigmoid(xp[..., :Hh] + hp[..., :Hh])
        r = jax.nn.sigmoid(xp[..., Hh:2 * Hh] + hp[..., Hh:])
        c = jnp.tanh(xp[..., 2 * Hh:] + (r * h) @ wh[:, 2 * Hh:])
        return (1.0 - z) * h + z * c, None

    return jax.lax.scan(step, h0, jnp.swapaxes(xs, 0, 1))[0]


def _hoisted(xs, h0, wx, wh, b):
    return gru_scan_ref(xs, h0, wx, wh, b)[1]


def run(quick: bool = True) -> list[dict]:
    del quick
    key = jax.random.PRNGKey(0)
    p = init_gru_params(key, D_IN, H)
    xs = jax.random.normal(jax.random.PRNGKey(1), (B, T, D_IN))
    h0 = jnp.zeros((B, H))

    flops = 2 * B * T * (D_IN * 3 * H + H * 3 * H)
    vmem_fused = 4 * (D_IN * 3 * H + H * 3 * H + 3 * H      # weights+bias
                      + 8 * T * D_IN + 8 * T * 3 * H + 8 * H)  # one tile
    configs = [
        ("no_optimization", _naive),
        ("unroll_gate_fusion", _fused_gates),
        ("pipeline_unroll_hoisted", _hoisted),
    ]
    rows = []
    base_ms = None
    for name, fn in configs:
        jf = jax.jit(lambda a, b2, f=fn: f(a, b2, p["wx"], p["wh"], p["b"]))
        ms = time_fn(jf, xs, h0, warmup=2, repeats=5) * 1e3
        base_ms = base_ms or ms
        rows.append({
            "configuration": name,
            "ms_per_scan": round(ms, 3),
            "speedup_vs_baseline": round(base_ms / ms, 2),
            "matmul_flops": flops,
            "vmem_working_set_bytes": vmem_fused
            if name == "pipeline_unroll_hoisted" else "-",
        })
    write_csv("table3_ablation.csv", rows)
    print_rows("Table III — optimization ablation (dim=30 analogue)", rows)
    return rows


if __name__ == "__main__":
    run()
